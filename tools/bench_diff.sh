#!/usr/bin/env bash
# CI perf gate: diff a freshly produced BENCH_<fig>.json against the
# baseline committed at HEAD.
#
#   tools/bench_diff.sh <fig> [tolerance]
#
# e.g. after `cd rust && cargo bench --bench fig18_sched_overhead -- --json`:
#   tools/bench_diff.sh fig18 0.25
#
# CI gates fig19 (fleet scaling) and fig15 (the artifact-free 15d
# prefix-share sweep; 15a-c only appear on artifact-bearing machines,
# and a shape change from their absence is expected there) at the
# default 25% tolerance.
#
# Bootstrap: when HEAD carries no baseline yet, the run is reported
# and the gate passes — commit the generated rust/BENCH_<fig>.json to
# arm the gate for subsequent changes.
set -euo pipefail

fig="${1:?usage: tools/bench_diff.sh <fig> [tolerance]}"
tol="${2:-0.25}"
repo="$(cd "$(dirname "$0")/.." && pwd)"
cand="$repo/rust/BENCH_${fig}.json"
snap="rust/BENCH_${fig}.json"

if [[ ! -f "$cand" ]]; then
    echo "bench_diff: candidate $cand not found — run the bench with --json first" >&2
    exit 2
fi

base="$(mktemp)"
trap 'rm -f "$base"' EXIT
if ! git -C "$repo" show "HEAD:$snap" > "$base" 2>/dev/null; then
    echo "bench_diff: no baseline at HEAD:$snap — bootstrap run, gate passes." >&2
    echo "bench_diff: commit $snap to arm the gate." >&2
    exit 0
fi

cargo run --quiet --release --manifest-path "$repo/rust/Cargo.toml" \
    --bin bench_diff -- "$base" "$cand" --tol "$tol"
