//! CI perf gate: compare two `BENCH_<name>.json` snapshots.
//!
//! ```text
//! bench_diff <baseline.json> <candidate.json> [--tol 0.25]
//! ```
//!
//! Prints the delta table to stderr and exits nonzero when any
//! non-timing metric drifts beyond the tolerance or the snapshot
//! shape changed. See `synera::bench::diff` for the rules and
//! `tools/bench_diff.sh` for the CI wrapper that supplies the
//! committed baseline.

use anyhow::{Context, Result};
use synera::bench::diff::{diff_snapshots, DEFAULT_TOL};
use synera::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        synera::log!(Error, "bench_diff: {e:#}");
        std::process::exit(2);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    // no subcommand: the first two operands are the snapshot paths
    let mut paths = Vec::new();
    paths.extend(args.command.clone());
    paths.extend(args.positionals.iter().cloned());
    let [base, cand] = paths.as_slice() else {
        anyhow::bail!("usage: bench_diff <baseline.json> <candidate.json> [--tol 0.25]");
    };
    let tol = args.get_f64("tol", DEFAULT_TOL)?;
    let b = std::fs::read_to_string(base).with_context(|| format!("reading {base}"))?;
    let c = std::fs::read_to_string(cand).with_context(|| format!("reading {cand}"))?;
    let rep = diff_snapshots(&b, &c, tol)?;
    synera::log!(Info, "bench {} (tolerance {:.0}%):", rep.bench, tol * 100.0);
    for line in rep.table_string().lines() {
        synera::log!(Info, "{line}");
    }
    if !rep.passed() {
        std::process::exit(1);
    }
    Ok(())
}
