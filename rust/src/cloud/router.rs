//! Router tier: one front door over `R` independent scheduler replicas
//! (ROADMAP "multi-replica cloud"; SNIPPETS.md §2 router/dispatcher
//! pattern). Batching scales *within* a [`Scheduler`] up to its engine
//! capacity; past that knee the only way up is out — more replicas.
//! The router owns placement, session affinity and cross-replica
//! session migration, so every other layer (coordinator, simulator,
//! CLI, benches) talks to one object whether `R` is 1 or 8.
//!
//! ## The affinity / migration contract
//!
//! * **Placement is load-driven and deterministic.** A session-opening
//!   request lands on the replica minimising `(queued + in-flight,
//!   same-tenant open sessions, open sessions, replica index)` — the
//!   tenant-sessions component spreads a hot tenant across replicas
//!   instead of piling it onto one. No randomness, no wall clock: same
//!   submission sequence ⇒ same placement at any fixed `R`.
//! * **Session affinity holds within a round.** Every follow-up
//!   request of a known session is forwarded to its *home* replica —
//!   the KV lives there and nowhere else. A session is **never**
//!   migrated while it has queued or in-flight work
//!   ([`Scheduler::session_busy`]): migration happens only at round
//!   boundaries, between an accepted verify outcome and the next
//!   uplink.
//! * **Migration is explicit, priced, and atomic.** [`Router::rebalance`]
//!   moves quiescent sessions from the most- to the least-loaded
//!   replica only while the load gap exceeds
//!   [`Router::rebalance_threshold`]. Each move exports the session's
//!   committed KV ([`Scheduler::export_session`]), round-trips it
//!   through the real [`KvMigrateMsg`] wire encoding (f32 planes —
//!   bit-identical by construction, gated by `tests/router_replicas`),
//!   imports it on the destination, and charges the encoded byte count
//!   to [`RouterStats::migration_bytes`] (priced in the cost model at
//!   [`crate::metrics::cost::MIGRATION_COST_PER_BYTE`]). A failed
//!   import restores the session at its source — a session is always
//!   resident on exactly one replica, never two, never zero.
//! * **A replica is never bypassed.** The router holds no KV and runs
//!   no model; it only forwards, counts and migrates.
//!
//! Determinism: replica 0 inherits the caller's seed unchanged, so at
//! `R = 1` the router is a transparent pass-through and every
//! pre-router result is reproduced bit-for-bit. Replicas `r > 0` get
//! deterministic seed variations (their verifier RNG streams must not
//! be correlated with replica 0's).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::cloud::scheduler::{CloudEvent, CloudRequest, Scheduler};
use crate::config::BatchPolicy;
use crate::model::cloud_engine::BatchEngine;
use crate::net::wire::KvMigrateMsg;
use crate::obs::trace::{self, TraceShared, PID_ROUTER};

/// Router-level counters (per-replica stats live on the replicas).
#[derive(Debug, Clone, Default)]
pub struct RouterStats {
    /// Requests forwarded to a replica (releases included).
    pub routed: u64,
    /// Completed cross-replica session migrations.
    pub migrations: u64,
    /// Wire bytes those migrations moved (the priced quantity).
    pub migration_bytes: u64,
    /// Rebalance rounds that found a load gap but no movable session
    /// (everything on the hot replica was busy or too big to import).
    pub rebalance_skips: u64,
}

/// One completed cross-replica session move, as surfaced to the caller
/// (the fleet simulator charges its bytes to the wire and the tenant's
/// energy account).
#[derive(Debug, Clone)]
pub struct MigrationRecord {
    pub request_id: u64,
    pub from: usize,
    pub to: usize,
    /// [`KvMigrateMsg`] wire bytes.
    pub bytes: u64,
    pub tenant: Option<usize>,
}

/// Front door over `R` scheduler replicas: deterministic tenant-aware
/// placement, per-session home affinity, threshold-driven rebalancing
/// with priced KV migration. See the module docs for the contract.
pub struct Router<E: BatchEngine> {
    replicas: Vec<Scheduler<E>>,
    /// Home replica of every live session (single-residency invariant:
    /// `home[id]` is the one replica whose scheduler may know `id`).
    home: HashMap<u64, usize>,
    /// Load gap (queued + in-flight + open sessions) above which
    /// [`Router::rebalance`] migrates sessions. `0` = rebalancing off.
    pub rebalance_threshold: usize,
    /// Cap on migrations per [`Router::rebalance`] call (bounds the
    /// stall a rebalance can add to one scheduling round).
    pub max_migrations_per_round: usize,
    pub stats: RouterStats,
    /// Placement/migration trace sink (router track; replicas record
    /// their own events on the cloud track).
    trace: Option<TraceShared>,
}

impl<E: BatchEngine> Router<E> {
    /// Build a router over one scheduler per engine. Replica 0 keeps
    /// `seed` exactly (R = 1 reproduces the single-scheduler stack
    /// bit-for-bit); later replicas get deterministic variations.
    pub fn new(engines: Vec<E>, seed: u64, policy: &BatchPolicy) -> Result<Router<E>> {
        if engines.is_empty() {
            bail!("the router needs at least one replica engine");
        }
        let replicas = engines
            .into_iter()
            .enumerate()
            .map(|(r, engine)| {
                let rseed = if r == 0 {
                    seed
                } else {
                    seed ^ (0x5EED ^ r as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                };
                Scheduler::with_policy(engine, rseed, policy.clone())
            })
            .collect();
        Ok(Router {
            replicas,
            home: HashMap::new(),
            rebalance_threshold: policy.rebalance_threshold,
            max_migrations_per_round: 8,
            stats: RouterStats::default(),
            trace: None,
        })
    }

    /// Attach (or detach) a trace sink: the router records placement
    /// and migration on the router track, and every replica scheduler
    /// gets the same sink with its replica index as cloud-track thread.
    pub fn set_trace(&mut self, trace: Option<TraceShared>) {
        for (r, s) in self.replicas.iter_mut().enumerate() {
            s.set_trace(trace.clone(), r as u32);
        }
        self.trace = trace;
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn replica(&self, r: usize) -> &Scheduler<E> {
        &self.replicas[r]
    }

    /// Direct replica access (serving drivers read stats and drain
    /// engines; tests force states). Going around the router for
    /// *submissions* voids the single-residency invariant.
    pub fn replica_mut(&mut self, r: usize) -> &mut Scheduler<E> {
        &mut self.replicas[r]
    }

    /// The home replica of a live session.
    pub fn home_of(&self, id: u64) -> Option<usize> {
        self.home.get(&id).copied()
    }

    pub fn is_idle(&self) -> bool {
        self.replicas.iter().all(|s| s.is_idle())
    }

    pub fn replica_idle(&self, r: usize) -> bool {
        self.replicas[r].is_idle()
    }

    /// Total queued requests across replicas.
    pub fn queue_depth(&self) -> usize {
        self.replicas.iter().map(|s| s.queue_depth()).sum()
    }

    pub fn submit(&mut self, req: CloudRequest) -> Result<usize> {
        self.submit_from(None, req)
    }

    pub fn submit_tenant(&mut self, tenant: usize, req: CloudRequest) -> Result<usize> {
        self.submit_from(Some(tenant), req)
    }

    /// Route one request, returning the replica it landed on (the
    /// fleet simulator wakes that replica's tick loop). Known sessions
    /// go home (affinity); new sessions are placed by load; releases
    /// follow the session home and retire it from the table.
    fn submit_from(&mut self, tenant: Option<usize>, req: CloudRequest) -> Result<usize> {
        let id = match &req {
            CloudRequest::Generate { request_id, .. }
            | CloudRequest::Verify { request_id, .. }
            | CloudRequest::Release { request_id } => *request_id,
        };
        if matches!(req, CloudRequest::Release { .. }) {
            let Some(r) = self.home.remove(&id) else {
                return Ok(0); // releasing a session no replica knows: no-op
            };
            self.forward(r, tenant, req)?;
            self.stats.routed += 1;
            return Ok(r);
        }
        let (r, placed) = match self.home.get(&id) {
            Some(&r) => (r, false),
            None => (self.place(tenant), true),
        };
        if placed && self.trace.is_some() {
            let mut args = vec![("replica", r as f64)];
            if let CloudRequest::Verify { ctx, .. } = &req {
                // causal join key: which device round this placement serves
                args.push(("round", ctx.round as f64));
            }
            trace::with(&self.trace, |s| s.instant(PID_ROUTER, 0, "place", id, args));
        }
        self.forward(r, tenant, req)?;
        self.home.insert(id, r);
        self.stats.routed += 1;
        Ok(r)
    }

    fn forward(&mut self, r: usize, tenant: Option<usize>, req: CloudRequest) -> Result<()> {
        match tenant {
            Some(t) => self.replicas[r].submit_tenant(t, req),
            None => self.replicas[r].submit(req),
        }
    }

    /// Placement load: work a new arrival would queue behind.
    fn load(s: &Scheduler<E>) -> usize {
        s.queue_depth() + s.in_flight()
    }

    /// Deterministic placement: first replica minimising (load,
    /// same-tenant sessions, open sessions, index).
    fn place(&self, tenant: Option<usize>) -> usize {
        let key = |r: usize| {
            let s = &self.replicas[r];
            (Self::load(s), tenant.map_or(0, |t| s.tenant_sessions(t)), s.active_sessions(), r)
        };
        (0..self.replicas.len()).min_by_key(|&r| key(r)).expect("≥1 replica")
    }

    /// Advance replica `r` one scheduler iteration. Sessions whose
    /// generation completed retire from the home table (the scheduler
    /// already closed them).
    pub fn tick_replica(&mut self, r: usize) -> Result<(Vec<CloudEvent>, f64)> {
        let (events, dt) = self.replicas[r].tick()?;
        for e in &events {
            if let CloudEvent::Generated { request_id, .. } = e {
                self.home.remove(request_id);
            }
        }
        Ok((events, dt))
    }

    /// Threshold-driven rebalancing: while the (queued + in-flight +
    /// open-session) gap between the most- and least-loaded replica
    /// exceeds [`Router::rebalance_threshold`], migrate the cheapest
    /// quiescent session (fewest committed KV rows; id breaks ties)
    /// from hot to cold. Open sessions count toward the gap because a
    /// quiescent session *is* future load — and because migrating one
    /// moves exactly one unit, so the loop converges. Returns the
    /// completed moves for the caller to price (wire seconds, energy).
    pub fn rebalance(&mut self) -> Result<Vec<MigrationRecord>> {
        let mut out = Vec::new();
        if self.rebalance_threshold == 0 || self.replicas.len() < 2 {
            return Ok(out);
        }
        while out.len() < self.max_migrations_per_round {
            // explicit first-max/first-min scans: deterministic on ties
            let gap_load =
                |s: &Scheduler<E>| Self::load(s) + s.active_sessions();
            let loads: Vec<usize> = self.replicas.iter().map(gap_load).collect();
            let (mut src, mut dst) = (0usize, 0usize);
            for (r, &l) in loads.iter().enumerate() {
                if l > loads[src] {
                    src = r;
                }
                if l < loads[dst] {
                    dst = r;
                }
            }
            if loads[src] - loads[dst] <= self.rebalance_threshold {
                break;
            }
            // candidates homed on the hot replica, in sorted id order
            // (HashMap iteration order must not leak into policy)
            let mut cands: Vec<u64> =
                self.home.iter().filter(|&(_, &r)| r == src).map(|(&id, _)| id).collect();
            cands.sort_unstable();
            let hot = &self.replicas[src];
            let pick = cands
                .into_iter()
                .filter(|&id| {
                    hot.sessions().contains(id)
                        && !hot.session_busy(id)
                        && self.replicas[dst].can_import(hot.sessions().len_of(id))
                })
                .min_by_key(|&id| (hot.sessions().len_of(id), id));
            let Some(id) = pick else {
                // a gap with nothing movable: everything hot is busy
                // (affinity forbids mid-round moves) or won't fit cold
                self.stats.rebalance_skips += 1;
                break;
            };
            out.push(self.migrate(id, src, dst)?);
        }
        Ok(out)
    }

    /// Force-migrate a session to replica `to` (test hook and operator
    /// override; [`Router::rebalance`] is the policy path). Fails —
    /// leaving the session untouched at its source — if the session is
    /// unknown, busy, already on `to`, or does not fit there.
    pub fn migrate_session(&mut self, id: u64, to: usize) -> Result<MigrationRecord> {
        let Some(&src) = self.home.get(&id) else {
            bail!("session {id} has no home replica");
        };
        if to >= self.replicas.len() {
            bail!("replica {to} out of range ({} replicas)", self.replicas.len());
        }
        if src == to {
            bail!("session {id} already lives on replica {to}");
        }
        self.migrate(id, src, to)
    }

    /// Export → wire round trip → import, with source restore on a
    /// failed import. The *decoded* KV is what lands on the
    /// destination, so any wire-format lossiness would surface as a KV
    /// mismatch in the round-trip gate, not hide behind a shortcut.
    fn migrate(&mut self, id: u64, src: usize, dst: usize) -> Result<MigrationRecord> {
        let (kv, tenant) = self.replicas[src].export_session(id)?;
        let msg = KvMigrateMsg { request_id: id, kv };
        let encoded = msg.encode();
        let bytes = msg.wire_bytes();
        debug_assert_eq!(bytes, encoded.len(), "priced bytes must match the real encoding");
        let decoded = KvMigrateMsg::decode(&encoded)?;
        if let Err(e) = self.replicas[dst].import_session(id, tenant, &decoded.kv) {
            self.replicas[src]
                .import_session(id, tenant, &msg.kv)
                .map_err(|restore| restore.context(format!("restore after failed import: {e}")))?;
            return Err(e);
        }
        self.home.insert(id, dst);
        self.stats.migrations += 1;
        self.stats.migration_bytes += bytes as u64;
        if self.trace.is_some() {
            let args = vec![
                ("from", src as f64),
                ("to", dst as f64),
                ("bytes", bytes as f64),
            ];
            trace::with(&self.trace, |s| s.instant(PID_ROUTER, 0, "migrate", id, args));
        }
        Ok(MigrationRecord { request_id: id, from: src, to: dst, bytes: bytes as u64, tenant })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::MockBatchEngine;
    use crate::workload::vocab::VOCAB;

    fn two_replica_router() -> Router<MockBatchEngine> {
        let engines = (0..2).map(|_| MockBatchEngine::new(4, 32, VOCAB, 4096)).collect();
        Router::new(engines, 0x7E57, &BatchPolicy::default()).unwrap()
    }

    fn gen_req(id: u64) -> CloudRequest {
        CloudRequest::Generate { request_id: id, prompt: vec![5, 6, 7], max_new: 2 }
    }

    #[test]
    fn rejects_zero_replicas() {
        let none: Vec<MockBatchEngine> = Vec::new();
        assert!(Router::new(none, 1, &BatchPolicy::default()).is_err());
    }

    #[test]
    fn placement_spreads_new_sessions() {
        let mut router = two_replica_router();
        let a = router.submit(gen_req(1)).unwrap();
        let b = router.submit(gen_req(2)).unwrap();
        assert_ne!(a, b, "second session must land on the empty replica");
        assert_eq!(router.home_of(1), Some(a));
        assert_eq!(router.home_of(2), Some(b));
    }

    #[test]
    fn release_of_unknown_session_is_a_noop() {
        let mut router = two_replica_router();
        router.submit(CloudRequest::Release { request_id: 99 }).unwrap();
        assert!(router.is_idle());
        assert_eq!(router.stats.routed, 0);
    }

    #[test]
    fn generation_retires_from_the_home_table() {
        let mut router = two_replica_router();
        let r = router.submit(gen_req(7)).unwrap();
        let mut guard = 0;
        while router.home_of(7).is_some() {
            router.tick_replica(r).unwrap();
            guard += 1;
            assert!(guard < 64, "generation must complete and retire");
        }
        assert!(router.replica_idle(r));
    }
}
