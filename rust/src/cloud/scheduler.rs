//! Mixed continuous-batching scheduler (paper Algorithm 1, evolved to a
//! Sarathi-style single-queue iteration) over **paged logical
//! sessions**.
//!
//! Each `tick()` packs **one** engine call from *all* runnable work:
//!
//! * **decode rows** — cloud-centric generations past their prefill;
//!   each contributes a 1-token chunk (latency-critical, packed first);
//! * **verification chunks** — pending Synera verify rounds, executed
//!   as chunked partial prefill (chunk = C after Sarathi-Serve) and
//!   verified when their last chunk lands;
//! * **prefill chunks** — newly admitted generation prompts.
//!
//! Packing runs under a per-iteration token-row budget
//! ([`BatchPolicy::token_budget`]); while decode or verify rows are
//! runnable, prefill may claim at most [`BatchPolicy::prefill_share`]
//! of it (the chunked-prefill cap), so a long prompt stream cannot
//! induce head-of-line blocking. Any job skipped for
//! [`BatchPolicy::age_threshold`] consecutive iterations is promoted
//! ahead of all non-aged work — no class can starve another
//! indefinitely. Batches mixing 1-token and multi-token rows run on the
//! chunk executable; pure-decode batches take the engine's `step_b4`
//! fast path (see [`BatchEngine`]).
//!
//! **Admission can be tenant-fair**: with a non-empty
//! [`BatchPolicy::tenant_weights`], tenant-tagged submissions
//! ([`Scheduler::submit_tenant`]) first pass a weighted-fair frontend
//! ([`crate::cloud::fairness::WfqQueue`]) that grants logical sessions
//! in virtual-finish-time order over per-tenant token credits — ahead
//! of, and composing with, the per-iteration aging fairness below.
//!
//! **Admission is decoupled from the compiled batch width**: up to
//! [`BatchPolicy::max_sessions`] *logical* sessions are admitted, far
//! beyond the engine's B slots. A [`SessionManager`] pages the KV of
//! sessions that lose the slot race out to a host block pool
//! ([`crate::runtime::paging`]) and swaps it back in — LRU victims,
//! never a session picked by the current iteration — right before the
//! job's next engine call. Verification sessions keep their committed
//! KV prefix across rounds whether resident or parked; rejected draft
//! tails are rolled back by position masking. With paging enabled the
//! Fig. 15 queueing knee moves from B to `max_sessions`; swap traffic
//! is charged to [`SchedulerStats`] (and its copy time to the Fig. 18
//! scheduling-overhead column).

use std::collections::{HashMap, HashSet, VecDeque};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::cloud::fairness::{TenantStats, WfqQueue};
use crate::cloud::sessions::SessionManager;
use crate::cloud::verifier::{verify_chunk, VerifyOutcome};
use crate::config::BatchPolicy;
use crate::model::cloud_engine::{BatchEngine, CloudEngine, SlotChunk};
use crate::model::logits::argmax;
use crate::net::wire::{Dist, TraceContext};
use crate::obs::trace::{self, Ph, TraceShared, PID_CLOUD};
use crate::runtime::SlotKv;
use crate::util::rng::Rng;
use crate::workload::vocab::EOS;

/// Work submitted to the cloud.
#[derive(Debug, Clone)]
pub enum CloudRequest {
    /// Cloud-centric baseline: full generation on the LLM.
    Generate { request_id: u64, prompt: Vec<u32>, max_new: usize },
    /// Synera verification round (decoded `UplinkMsg`).
    Verify {
        request_id: u64,
        device_id: u32,
        /// Device-accepted tokens not yet in the cloud KV (first round:
        /// the whole prompt). Must be non-empty.
        uncached: Vec<u32>,
        draft: Vec<u32>,
        dists: Vec<Dist>,
        greedy: bool,
        /// Causal context from the originating device round (default =
        /// untraced); cloud-side trace events echo its round and close
        /// its flow arrow.
        ctx: TraceContext,
    },
    /// A device session finished; free its slot/blocks.
    Release { request_id: u64 },
}

/// Completions surfaced by `tick()`.
#[derive(Debug, Clone)]
pub enum CloudEvent {
    VerifyDone { request_id: u64, device_id: u32, outcome: VerifyOutcome },
    /// Cloud-centric generation finished (tokens exclude the prompt).
    Generated { request_id: u64, tokens: Vec<u32> },
}

#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    pub iterations: u64,
    /// Iterations whose batch contained ≥1 prefill chunk.
    pub prefill_iters: u64,
    /// Iterations whose batch contained ≥1 verification chunk.
    pub verify_iters: u64,
    /// Iterations whose batch contained ≥1 decode row.
    pub decode_iters: u64,
    /// Iterations that co-scheduled more than one work class.
    pub mixed_iters: u64,
    /// Jobs scheduled via the aging promotion (fairness escape hatch).
    pub aged_promotions: u64,
    pub rows_executed: u64,
    /// Engine compute inside ticks.
    pub busy_s: f64,
    /// Scheduling bookkeeping outside engine calls (Fig. 18 overhead;
    /// includes paged-KV swap copies).
    pub sched_overhead_s: f64,
    pub verifies_done: u64,
    pub draft_tokens_seen: u64,
    pub draft_tokens_accepted: u64,
    /// Paged-KV swap traffic (mirrors the session manager's counters).
    pub swap_ins: u64,
    pub swap_outs: u64,
    pub swap_bytes: u64,
    pub swap_s: f64,
    /// Shared-prefix cache traffic (mirrors the session manager's
    /// [`crate::runtime::prefix::PrefixStats`]; zeros with the cache
    /// off).
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    pub prefix_hit_rows: u64,
    pub cow_copies: u64,
    /// Per-phase wall seconds inside `tick()` (always-on cheap timers;
    /// the Fig. 18 breakdown and the `BENCH_fig18.json` phase schema).
    /// `wfq_drain` covers the admission pass (WFQ drain + session
    /// grants), `paging` the host↔slot KV copies, `pack` candidate
    /// sort + batch planning net of paging, `engine` the engine's own
    /// measured compute, `commit` result application and completion
    /// handling.
    pub phase_wfq_s: f64,
    pub phase_paging_s: f64,
    pub phase_pack_s: f64,
    pub phase_engine_s: f64,
    pub phase_commit_s: f64,
}

struct GenJob {
    request_id: u64,
    prompt: Vec<u32>,
    consumed: usize,
    max_new: usize,
    generated: Vec<u32>,
    next_token: Option<u32>,
    /// Consecutive iterations this job was runnable but not scheduled.
    wait_iters: u64,
}

struct VerifyJob {
    request_id: u64,
    device_id: u32,
    base_len: usize,
    tokens: Vec<u32>,
    u: usize,
    draft: Vec<u32>,
    dists: Vec<Dist>,
    greedy: bool,
    consumed: usize,
    rows: Vec<Vec<f32>>,
    /// Consecutive iterations this job was runnable but not scheduled.
    wait_iters: u64,
    /// Causal context of the originating device round.
    ctx: TraceContext,
}

/// Work classes in packing-priority order (lower = packed earlier).
const CLASS_DECODE: u8 = 0;
const CLASS_VERIFY: u8 = 1;
const CLASS_PREFILL: u8 = 2;

/// One packed entry of an iteration's batch plan.
struct Pick {
    class: u8,
    /// Index into the class's job pool.
    idx: usize,
    /// The session's request id (slot-independent job identity).
    id: u64,
    /// Slot the session is resident in *this* iteration.
    slot: usize,
    /// Token rows granted this iteration.
    n: usize,
    /// Scheduled via the aging promotion.
    aged: bool,
}

/// Reusable per-tick buffers (ROADMAP hot-path item). `tick()` used to
/// allocate fresh vectors for its candidate list, batch plan,
/// pick-tracking bitmaps and slot-indexed result join on **every**
/// iteration; at fleet scale (millions of ticks per run) that
/// allocation churn is pure scheduling overhead. These are cleared and
/// refilled each tick, never shrunk — capacities converge to the job-
/// pool and slot counts after the first few iterations.
#[derive(Default)]
struct TickScratch {
    /// Candidates: (class, pool index, session id, runnable rows, waited).
    cands: Vec<(u8, usize, u64, usize, u64)>,
    picks: Vec<Pick>,
    picked_decode: Vec<bool>,
    picked_verify: Vec<bool>,
    picked_prefill: Vec<bool>,
    items: Vec<SlotChunk>,
    res_by_slot: Vec<Option<usize>>,
    /// Sessions granted a slot this iteration — ineligible as swap
    /// victims, and a hard cap of one chunk per physical slot.
    pinned: HashSet<u64>,
}

/// The mixed continuous-batching scheduler bound to one [`BatchEngine`]
/// (the PJRT [`CloudEngine`] in production, a mock in tests).
pub struct Scheduler<E: BatchEngine = CloudEngine> {
    pub engine: E,
    pub policy: BatchPolicy,
    waiting_gen: VecDeque<CloudRequest>,
    waiting_verify: VecDeque<CloudRequest>,
    prefilling: Vec<GenJob>,
    decoding: Vec<GenJob>,
    verifying: Vec<VerifyJob>,
    /// Logical sessions over the engine's slots (paged KV residency).
    sessions: SessionManager,
    /// Sessions released while a verify round was in flight; their
    /// slot/blocks are freed when that round completes (freeing earlier
    /// would hand the slot — and its live KV positions — to another
    /// job).
    pending_release: HashSet<u64>,
    /// Round-robin toggle between the generate and verify admission
    /// queues (admission capacity is shared; neither queue can starve).
    admit_verify_first: bool,
    /// Weighted-fair admission frontend across device tenants
    /// ([`BatchPolicy::tenant_weights`]; `None` = single-queue FIFO).
    /// Session-opening requests wait here in virtual-finish-time order;
    /// follow-up rounds of open sessions bypass it but are charged.
    wfq: Option<WfqQueue<CloudRequest>>,
    /// Tenant of each tenant-tagged request id (per-tenant accounting).
    tenant_of: HashMap<u64, usize>,
    /// Per-tenant service counters (empty when WFQ is off).
    pub tenant_stats: Vec<TenantStats>,
    rng: Rng,
    pub stats: SchedulerStats,
    /// Reusable per-tick buffers (no per-iteration allocation churn).
    scratch: TickScratch,
    /// Request-lifecycle trace sink (`None` ⇒ every record site is one
    /// branch); events land on the cloud process, thread `trace_tid`.
    trace: Option<TraceShared>,
    trace_tid: u32,
}

/// Admission cost of a request in engine token rows (the WFQ credit
/// currency: what the engine will have to execute for it).
fn request_cost(req: &CloudRequest) -> f64 {
    match req {
        CloudRequest::Generate { prompt, max_new, .. } => (prompt.len() + *max_new) as f64,
        CloudRequest::Verify { uncached, draft, .. } => (uncached.len() + draft.len()) as f64,
        CloudRequest::Release { .. } => 0.0,
    }
}

impl<E: BatchEngine> Scheduler<E> {
    pub fn new(engine: E, seed: u64) -> Scheduler<E> {
        Scheduler::with_policy(engine, seed, BatchPolicy::default())
    }

    /// Build a scheduler with an explicit batching policy (the
    /// `SyneraParams::batch` config block). A non-empty
    /// [`BatchPolicy::tenant_weights`] enables the weighted-fair
    /// admission frontend; weights must be finite and positive
    /// (validate them at the config boundary — bad weights panic here).
    pub fn with_policy(engine: E, seed: u64, policy: BatchPolicy) -> Scheduler<E> {
        let sessions = SessionManager::for_engine(&engine, &policy);
        let wfq = if policy.tenant_weights.is_empty() {
            None
        } else {
            Some(
                WfqQueue::new(&policy.tenant_weights)
                    .expect("tenant weights must be finite and positive"),
            )
        };
        let tenant_stats = vec![TenantStats::default(); policy.tenant_weights.len()];
        Scheduler {
            engine,
            policy,
            waiting_gen: VecDeque::new(),
            waiting_verify: VecDeque::new(),
            prefilling: Vec::new(),
            decoding: Vec::new(),
            verifying: Vec::new(),
            sessions,
            pending_release: HashSet::new(),
            admit_verify_first: true,
            wfq,
            tenant_of: HashMap::new(),
            tenant_stats,
            rng: Rng::new(seed ^ 0xC10D),
            stats: SchedulerStats::default(),
            scratch: TickScratch::default(),
            trace: None,
            trace_tid: 0,
        }
    }

    /// Attach (or detach) a trace sink; `tid` is this scheduler's
    /// replica index — its thread on the cloud trace track. Propagates
    /// to the session manager so swap events share the sink.
    pub fn set_trace(&mut self, trace: Option<TraceShared>, tid: u32) {
        self.sessions.set_trace(trace.clone(), tid);
        self.trace = trace;
        self.trace_tid = tid;
    }

    /// Record a point event on this replica's cloud track.
    fn trace_instant(&self, name: &'static str, id: u64, args: Vec<(&'static str, f64)>) {
        trace::with(&self.trace, |s| s.instant(PID_CLOUD, self.trace_tid, name, id, args));
    }

    /// The session manager (paged-KV residency state; test hooks).
    pub fn sessions(&self) -> &SessionManager {
        &self.sessions
    }

    pub fn submit(&mut self, req: CloudRequest) -> Result<()> {
        self.submit_from(None, req)
    }

    /// Submit on behalf of a device tenant: session-opening requests
    /// queue in the weighted-fair frontend; follow-up verify rounds of
    /// an already-open session bypass it (holding them back could
    /// deadlock a session against its own admission) but their row cost
    /// is still charged to the tenant. With no frontend configured
    /// (empty [`BatchPolicy::tenant_weights`]) this degrades to
    /// [`Scheduler::submit`].
    pub fn submit_tenant(&mut self, tenant: usize, req: CloudRequest) -> Result<()> {
        self.submit_from(Some(tenant), req)
    }

    fn submit_from(&mut self, tenant: Option<usize>, req: CloudRequest) -> Result<()> {
        match &req {
            CloudRequest::Generate { prompt, max_new, .. } => {
                if prompt.is_empty() {
                    bail!("generation requires ≥1 prompt token");
                }
                if *max_new == 0 {
                    bail!("generation requires max_new ≥ 1");
                }
                // reject here rather than let a mid-flight engine-call
                // failure take down the scheduling loop
                if prompt.len() + *max_new > self.engine.max_len() {
                    bail!(
                        "request needs {} rows but the slot cache holds {}",
                        prompt.len() + *max_new,
                        self.engine.max_len()
                    );
                }
            }
            CloudRequest::Verify { uncached, draft, .. } => {
                if uncached.is_empty() {
                    bail!("verify round must carry ≥1 uncached token");
                }
                if uncached.len() + draft.len() > self.engine.max_len() {
                    bail!(
                        "verify round of {} rows exceeds the slot cache ({})",
                        uncached.len() + draft.len(),
                        self.engine.max_len()
                    );
                }
            }
            CloudRequest::Release { request_id } => {
                let rid = *request_id;
                // queued rounds of a released session will never be read
                let keep = |r: &CloudRequest| {
                    !matches!(r, CloudRequest::Verify { request_id, .. } if *request_id == rid)
                };
                self.waiting_verify.retain(keep);
                if let Some(wfq) = &mut self.wfq {
                    wfq.retain(keep);
                }
                if self.verifying.iter().any(|j| j.request_id == rid) {
                    // the in-flight round still writes this session's KV;
                    // defer the free until it completes
                    self.pending_release.insert(rid);
                } else if self.prefilling.iter().any(|j| j.request_id == rid)
                    || self.decoding.iter().any(|j| j.request_id == rid)
                {
                    // generations own their session until they complete;
                    // a stray release of a generate id stays a no-op
                    // (pre-paging behavior)
                } else {
                    self.close_session(rid);
                }
                return Ok(());
            }
        }
        // ---- routing: weighted-fair frontend or direct FIFO ---------------
        let request_id = match &req {
            CloudRequest::Generate { request_id, .. }
            | CloudRequest::Verify { request_id, .. } => *request_id,
            CloudRequest::Release { .. } => unreachable!("handled above"),
        };
        if self.trace.is_some() {
            // WFQ queue wait = gap between this and the "admit" instant
            let mut args = vec![("cost", request_cost(&req))];
            if let CloudRequest::Verify { ctx, .. } = &req {
                args.push(("round", ctx.round as f64));
            }
            self.trace_instant("enqueue", request_id, args);
        }
        if let Some(t) = tenant {
            if let Some(wfq) = self.wfq.as_ref() {
                if t >= wfq.n_tenants() {
                    bail!("tenant {t} out of range ({} tenants)", wfq.n_tenants());
                }
                let cost = request_cost(&req);
                let follow_up = matches!(&req, CloudRequest::Verify { .. })
                    && self.sessions.contains(request_id);
                self.tenant_of.insert(request_id, t);
                self.tenant_stats[t].submitted += 1;
                let wfq = self.wfq.as_mut().expect("checked above");
                if follow_up {
                    wfq.charge(t, cost);
                    self.waiting_verify.push_back(req);
                } else {
                    wfq.push(t, cost, req)?;
                }
                return Ok(());
            }
            // no frontend configured: tenant-tagged traffic degrades to
            // the single-queue FIFO path below
        }
        if matches!(req, CloudRequest::Generate { .. }) {
            self.waiting_gen.push_back(req);
        } else {
            self.waiting_verify.push_back(req);
        }
        Ok(())
    }

    /// Close a session and drop its tenant attribution.
    fn close_session(&mut self, id: u64) {
        self.sessions.close(id, &mut self.engine);
        self.tenant_of.remove(&id);
    }

    /// Anything in flight or queued (including the tenant frontend)?
    pub fn is_idle(&self) -> bool {
        self.waiting_gen.is_empty()
            && self.waiting_verify.is_empty()
            && self.prefilling.is_empty()
            && self.decoding.is_empty()
            && self.verifying.is_empty()
            && self.wfq.as_ref().is_none_or(|w| w.is_empty())
    }

    pub fn queue_depth(&self) -> usize {
        self.waiting_gen.len()
            + self.waiting_verify.len()
            + self.wfq.as_ref().map_or(0, |w| w.len())
    }

    // ---- load-signal surface (consumed by `crate::cloud::router`) ---------

    /// Jobs mid-execution: prefilling, decoding, or in a verify round.
    /// Together with [`Scheduler::queue_depth`] this is the router's
    /// load metric for replica placement.
    pub fn in_flight(&self) -> usize {
        self.prefilling.len() + self.decoding.len() + self.verifying.len()
    }

    /// Open logical sessions on this scheduler.
    pub fn active_sessions(&self) -> usize {
        self.sessions.active()
    }

    /// Queued requests (staged + weighted-fair frontend) attributed to
    /// `tenant` — the per-tenant backlog the router balances on.
    pub fn tenant_backlog(&self, tenant: usize) -> usize {
        let staged = self
            .waiting_gen
            .iter()
            .chain(self.waiting_verify.iter())
            .filter(|r| {
                let id = match r {
                    CloudRequest::Generate { request_id, .. }
                    | CloudRequest::Verify { request_id, .. } => *request_id,
                    CloudRequest::Release { .. } => return false,
                };
                self.tenant_of.get(&id) == Some(&tenant)
            })
            .count();
        staged + self.wfq.as_ref().map_or(0, |w| w.len_of(tenant))
    }

    /// Open sessions attributed to `tenant` (session-affinity signal:
    /// the router prefers the replica already serving a tenant).
    pub fn tenant_sessions(&self, tenant: usize) -> usize {
        self.tenant_of
            .iter()
            .filter(|&(id, t)| *t == tenant && self.sessions.contains(*id))
            .count()
    }

    /// Fraction of this scheduler's time spent in engine compute (vs
    /// scheduling bookkeeping). Derived from wall-clock counters, so it
    /// is a **reporting/ops signal only** — the simulator's placement
    /// decisions never read it (virtual-clock determinism).
    pub fn busy_fraction(&self) -> f64 {
        let total = self.stats.busy_s + self.stats.sched_overhead_s;
        if total <= 0.0 {
            0.0
        } else {
            self.stats.busy_s / total
        }
    }

    // ---- cross-replica session migration (router rebalancing) -------------

    /// Does `id` have queued or in-flight work anywhere in this
    /// scheduler (staged queues, tenant frontend, job pools, deferred
    /// release)? A busy session must not migrate: its next round would
    /// race the move (session affinity holds within a round).
    pub fn session_busy(&self, id: u64) -> bool {
        let matches_id = |r: &CloudRequest| match r {
            CloudRequest::Generate { request_id, .. }
            | CloudRequest::Verify { request_id, .. }
            | CloudRequest::Release { request_id } => *request_id == id,
        };
        self.prefilling.iter().any(|j| j.request_id == id)
            || self.decoding.iter().any(|j| j.request_id == id)
            || self.verifying.iter().any(|j| j.request_id == id)
            || self.pending_release.contains(&id)
            || self.waiting_gen.iter().any(|r| matches_id(r))
            || self.waiting_verify.iter().any(|r| matches_id(r))
            || self.wfq.as_ref().is_some_and(|w| w.any(|r| matches_id(r)))
    }

    /// Detach a quiescent session for migration: its committed KV image
    /// plus its tenant attribution. The session's slot or pool blocks
    /// return to this scheduler; errors (unknown or busy session) leave
    /// it untouched.
    pub fn export_session(&mut self, id: u64) -> Result<(SlotKv, Option<usize>)> {
        if !self.sessions.contains(id) {
            bail!("export of unknown session {id}");
        }
        if self.session_busy(id) {
            bail!("session {id} has queued or in-flight work; migrate at a round boundary");
        }
        let kv = self.sessions.export(id, &mut self.engine)?;
        Ok((kv, self.tenant_of.remove(&id)))
    }

    /// Can this scheduler adopt a migrated session of `rows` committed
    /// rows right now without evicting anything?
    pub fn can_import(&self, rows: usize) -> bool {
        self.sessions.can_import(rows, &self.engine)
    }

    /// Adopt a migrated session (the KV image a peer's
    /// [`Scheduler::export_session`] produced, after its wire round
    /// trip) under its tenant attribution. Follow-up rounds then submit
    /// here exactly as if the session had always been local.
    pub fn import_session(&mut self, id: u64, tenant: Option<usize>, kv: &SlotKv) -> Result<()> {
        self.sessions.import(id, kv, &mut self.engine)?;
        if let Some(t) = tenant {
            // only track tenants this scheduler has counters for —
            // per-row accounting indexes tenant_stats
            if t < self.tenant_stats.len() {
                self.tenant_of.insert(id, t);
            }
        }
        Ok(())
    }

    /// One mixed continuous-batching iteration. Returns surfaced events
    /// plus the engine compute seconds consumed by this tick (the
    /// caller's clock).
    pub fn tick(&mut self) -> Result<(Vec<CloudEvent>, f64)> {
        let t_tick = Instant::now();
        // phase trace: stamp the tick start once up front; phase events
        // are recorded at the end with measured wall offsets (both the
        // offsets and the durations collapse to zero under a
        // deterministic virtual clock)
        let mut trace_t0 = 0.0;
        if let Some(t) = &self.trace {
            if let Ok(s) = t.lock() {
                trace_t0 = s.now_s();
            }
        }
        let swap_s0 = self.sessions.stats().swap_s;
        self.stats.iterations += 1;
        let mut events = Vec::new();

        self.admit(&mut events)?;
        let wfq_s = t_tick.elapsed().as_secs_f64();
        self.stats.phase_wfq_s += wfq_s;
        let t_plan = Instant::now();

        // ---- plan: pack one mixed batch under the token budget ------------
        let chunk = self.engine.chunk();
        let slots = self.engine.slots();
        let capacity = slots * chunk;
        let budget = if self.policy.token_budget == 0 {
            capacity
        } else {
            self.policy.token_budget.clamp(1, capacity)
        };
        let age_th = self.policy.age_threshold;

        // reusable scratch, destructured so its field borrows stay
        // disjoint from the session/engine borrows below
        let TickScratch {
            cands,
            picks,
            picked_decode,
            picked_verify,
            picked_prefill,
            items,
            res_by_slot,
            pinned,
        } = &mut self.scratch;
        cands.clear();
        for (i, j) in self.decoding.iter().enumerate() {
            if j.next_token.is_some() {
                cands.push((CLASS_DECODE, i, j.request_id, 1, j.wait_iters));
            }
        }
        for (i, j) in self.verifying.iter().enumerate() {
            cands.push((CLASS_VERIFY, i, j.request_id, j.tokens.len() - j.consumed, j.wait_iters));
        }
        for (i, j) in self.prefilling.iter().enumerate() {
            cands.push((CLASS_PREFILL, i, j.request_id, j.prompt.len() - j.consumed, j.wait_iters));
        }
        if cands.is_empty() {
            self.stats.sched_overhead_s += t_tick.elapsed().as_secs_f64();
            return Ok((events, 0.0));
        }

        // aged jobs first (longest wait leads), then decode < verify <
        // prefill; FIFO within a class (stable sort over pool order)
        cands.sort_by_key(|&(class, _, _, _, waited)| {
            if waited >= age_th {
                (0u8, u64::MAX - waited)
            } else {
                (1u8, class as u64)
            }
        });

        let latency_rows_present =
            cands.iter().any(|&(class, _, _, _, _)| class != CLASS_PREFILL);
        // chunked-prefill cap: prompts may not crowd out latency-critical
        // rows of the same iteration
        let prefill_cap = if latency_rows_present {
            (((budget as f64) * self.policy.prefill_share).ceil() as usize).max(1)
        } else {
            budget
        };

        let mut remaining = budget;
        let mut prefill_used = 0usize;
        pinned.clear();
        picks.clear();
        for &(class, idx, id, runnable, waited) in cands.iter() {
            if remaining == 0 || picks.len() == slots {
                break;
            }
            let mut grant = runnable.min(chunk).min(remaining);
            if class == CLASS_PREFILL {
                grant = grant.min(prefill_cap.saturating_sub(prefill_used));
            }
            if grant == 0 {
                continue;
            }
            // paged residency: resident sessions keep their slot; parked
            // ones are swapped in over an LRU victim (never one already
            // picked). No victim ⇒ the job waits and ages.
            let Some(slot) = self.sessions.ensure_resident(id, &mut self.engine, pinned)? else {
                continue;
            };
            if class == CLASS_PREFILL {
                prefill_used += grant;
            }
            remaining -= grant;
            pinned.insert(id);
            picks.push(Pick { class, idx, id, slot, n: grant, aged: waited >= age_th });
        }

        // fairness accounting: scheduled jobs reset their wait; skipped
        // runnable jobs age by one iteration
        picked_decode.clear();
        picked_decode.resize(self.decoding.len(), false);
        picked_verify.clear();
        picked_verify.resize(self.verifying.len(), false);
        picked_prefill.clear();
        picked_prefill.resize(self.prefilling.len(), false);
        for p in picks.iter() {
            match p.class {
                CLASS_DECODE => picked_decode[p.idx] = true,
                CLASS_VERIFY => picked_verify[p.idx] = true,
                _ => picked_prefill[p.idx] = true,
            }
            if p.aged {
                self.stats.aged_promotions += 1;
            }
        }
        for (i, j) in self.decoding.iter_mut().enumerate() {
            j.wait_iters = if picked_decode[i] { 0 } else { j.wait_iters + 1 };
        }
        for (i, j) in self.verifying.iter_mut().enumerate() {
            j.wait_iters = if picked_verify[i] { 0 } else { j.wait_iters + 1 };
        }
        for (i, j) in self.prefilling.iter_mut().enumerate() {
            j.wait_iters = if picked_prefill[i] { 0 } else { j.wait_iters + 1 };
        }

        let has_d = picks.iter().any(|p| p.class == CLASS_DECODE);
        let has_v = picks.iter().any(|p| p.class == CLASS_VERIFY);
        let has_p = picks.iter().any(|p| p.class == CLASS_PREFILL);
        self.stats.decode_iters += has_d as u64;
        self.stats.verify_iters += has_v as u64;
        self.stats.prefill_iters += has_p as u64;
        if (has_d as u8 + has_v as u8 + has_p as u8) > 1 {
            self.stats.mixed_iters += 1;
        }

        // ---- execute: one engine call for the whole mixed batch -----------
        items.clear();
        for p in picks.iter() {
            let toks = match p.class {
                CLASS_DECODE => {
                    let j = &self.decoding[p.idx];
                    vec![j.next_token.expect("decode has next")]
                }
                CLASS_VERIFY => {
                    let j = &self.verifying[p.idx];
                    j.tokens[j.consumed..j.consumed + p.n].to_vec()
                }
                _ => {
                    let j = &self.prefilling[p.idx];
                    j.prompt[j.consumed..j.consumed + p.n].to_vec()
                }
            };
            items.push(SlotChunk { slot: p.slot, tokens: toks });
        }
        let paging_s = self.sessions.stats().swap_s - swap_s0;
        let pack_s = (t_plan.elapsed().as_secs_f64() - paging_s).max(0.0);
        self.stats.phase_paging_s += paging_s;
        self.stats.phase_pack_s += pack_s;
        let (res, dt) = self.engine.run_batch(items)?;
        let compute_s = dt;
        self.stats.busy_s += dt;
        self.stats.phase_engine_s += dt;
        let t_commit = Instant::now();
        self.stats.rows_executed = self.engine.rows_executed();

        // ---- apply per-slot results to their jobs -------------------------
        // slot-indexed join (the per-item linear scan was O(picks²))
        res_by_slot.clear();
        res_by_slot.resize(slots, None);
        for (i, r) in res.iter().enumerate() {
            res_by_slot[r.slot] = Some(i);
        }
        let v = self.engine.vocab();
        for (p, item) in picks.iter().zip(items.iter()) {
            let ri = res_by_slot[item.slot].expect("engine result for scheduled slot");
            let r = &res[ri];
            self.sessions.note_rows(p.id, r.n_rows);
            // token ids behind the committed rows — block identity for
            // the prefix cache (no-op with the cache off)
            self.sessions.note_tokens(p.id, &item.tokens[..r.n_rows]);
            if let Some(&t) = self.tenant_of.get(&p.id) {
                self.tenant_stats[t].rows_executed += r.n_rows as u64;
            }
            match p.class {
                CLASS_DECODE => {
                    let job = &mut self.decoding[p.idx];
                    let committed = job.next_token.take().expect("token");
                    job.generated.push(committed);
                    let next = argmax(&r.rows) as u32;
                    if committed != EOS && job.generated.len() < job.max_new {
                        job.next_token = Some(next);
                    } // else: done (committed EOS or budget reached)
                }
                CLASS_VERIFY => {
                    let job = &mut self.verifying[p.idx];
                    for i in 0..r.n_rows {
                        let gi = job.consumed + i; // global row in the verify seq
                        if gi + 1 >= job.u {
                            job.rows.push(r.rows[i * v..(i + 1) * v].to_vec());
                        }
                    }
                    job.consumed += r.n_rows;
                }
                _ => {
                    let job = &mut self.prefilling[p.idx];
                    job.consumed += r.n_rows;
                    if job.consumed == job.prompt.len() {
                        job.next_token =
                            Some(argmax(&r.rows[(r.n_rows - 1) * v..r.n_rows * v]) as u32);
                    }
                }
            }
        }

        // ---- completions --------------------------------------------------
        // finished prefills join the decode pool (run from next tick on)
        let mut i = 0;
        while i < self.prefilling.len() {
            if self.prefilling[i].consumed == self.prefilling[i].prompt.len() {
                let job = self.prefilling.remove(i);
                self.decoding.push(job);
            } else {
                i += 1;
            }
        }
        // fully-forwarded verify rounds: run acceptance, roll back the
        // rejected tail, surface the outcome
        let mut i = 0;
        while i < self.verifying.len() {
            if self.verifying[i].consumed == self.verifying[i].tokens.len() {
                let job = self.verifying.remove(i);
                let outcome = verify_chunk(
                    &job.draft,
                    &job.dists,
                    &job.rows,
                    job.greedy,
                    &mut self.rng,
                );
                self.stats.verifies_done += 1;
                self.stats.draft_tokens_seen += job.draft.len() as u64;
                self.stats.draft_tokens_accepted += outcome.accepted as u64;
                if let Some(&t) = self.tenant_of.get(&job.request_id) {
                    self.tenant_stats[t].verifies_done += 1;
                    self.tenant_stats[t].draft_tokens_accepted += outcome.accepted as u64;
                }
                if self.pending_release.remove(&job.request_id) {
                    // the session was released mid-round: free it now
                    // that its last round has committed
                    self.close_session(job.request_id);
                } else {
                    // commit prefix + uncached + accepted; mask the rest.
                    // The session executed this tick, so it is resident.
                    let target = job.base_len + job.u + outcome.accepted;
                    let slot = self
                        .sessions
                        .slot_of(job.request_id)
                        .expect("just-executed session is resident");
                    self.engine.rollback(slot, target);
                    self.sessions.set_len(job.request_id, target);
                }
                if self.trace.is_some() {
                    self.trace_instant(
                        "verify_commit",
                        job.request_id,
                        vec![
                            ("accepted", outcome.accepted as f64),
                            ("draft", job.draft.len() as f64),
                            ("round", job.ctx.round as f64),
                        ],
                    );
                    // cloud hop of the device→cloud→device flow arrow
                    if job.ctx.parent_span != 0 {
                        let (tid, flow_id) = (self.trace_tid, job.ctx.parent_span);
                        trace::with(&self.trace, |s| {
                            s.flow(PID_CLOUD, tid, "offload", Ph::FlowStep, flow_id);
                        });
                    }
                }
                events.push(CloudEvent::VerifyDone {
                    request_id: job.request_id,
                    device_id: job.device_id,
                    outcome,
                });
            } else {
                i += 1;
            }
        }
        // finished generations leave the batch and free their session
        let mut i = 0;
        while i < self.decoding.len() {
            if self.decoding[i].next_token.is_none() {
                let job = self.decoding.remove(i);
                self.close_session(job.request_id);
                if self.trace.is_some() {
                    self.trace_instant(
                        "generated",
                        job.request_id,
                        vec![("tokens", job.generated.len() as f64)],
                    );
                }
                events.push(CloudEvent::Generated {
                    request_id: job.request_id,
                    tokens: job.generated,
                });
            } else {
                i += 1;
            }
        }

        // surface swap traffic alongside the batching counters
        let sw = self.sessions.stats();
        self.stats.swap_ins = sw.swap_ins;
        self.stats.swap_outs = sw.swap_outs;
        self.stats.swap_bytes = sw.bytes_in + sw.bytes_out;
        self.stats.swap_s = sw.swap_s;
        let ps = self.sessions.prefix_stats();
        self.stats.prefix_hits = ps.hits;
        self.stats.prefix_misses = ps.misses;
        self.stats.prefix_hit_rows = ps.hit_rows;
        self.stats.cow_copies = ps.cow_copies;

        let commit_s = t_commit.elapsed().as_secs_f64();
        self.stats.phase_commit_s += commit_s;

        if self.trace.is_some() {
            let tid = self.trace_tid;
            let picks = self.scratch.items.len() as f64;
            let rows = self.scratch.items.iter().map(|c| c.tokens.len()).sum::<usize>() as f64;
            let completions = events.len() as f64;
            let queue = self.queue_depth() as f64;
            trace::with(&self.trace, |s| {
                // wall offsets sequence the phases within the tick; a
                // deterministic clock collapses them onto the tick stamp
                let det = s.is_deterministic();
                let off = move |x: f64| if det { 0.0 } else { x };
                let t0 = trace_t0;
                s.complete(PID_CLOUD, tid, "wfq-drain", t0, wfq_s, vec![("queue", queue)]);
                s.complete(PID_CLOUD, tid, "paging", t0 + off(wfq_s), paging_s, vec![]);
                s.complete(
                    PID_CLOUD,
                    tid,
                    "pack",
                    t0 + off(wfq_s + paging_s),
                    pack_s,
                    vec![("picks", picks)],
                );
                let plan_s = paging_s + pack_s;
                s.complete(
                    PID_CLOUD,
                    tid,
                    "engine",
                    t0 + off(wfq_s + plan_s),
                    dt,
                    vec![("rows", rows)],
                );
                s.complete(
                    PID_CLOUD,
                    tid,
                    "commit",
                    t0 + off(wfq_s + plan_s + dt),
                    commit_s,
                    vec![("completions", completions)],
                );
            });
        }

        self.stats.sched_overhead_s += t_tick.elapsed().as_secs_f64() - dt;
        Ok((events, compute_s))
    }

    /// Admit waiting requests. Verify rounds whose session is already
    /// open are admitted unconditionally (they consume no new session;
    /// rounds of one session stay serialised — a round's `base_len`
    /// depends on its predecessor's acceptance). Remaining admission
    /// capacity ([`BatchPolicy::max_sessions`] logical sessions — the
    /// compiled slot count no longer caps concurrency) is then shared
    /// **round-robin** between the generate queue and new verify
    /// sessions, so neither admission queue can starve the other. A
    /// request of the wrong variant in either queue is an internal
    /// routing bug and surfaces as an error instead of being silently
    /// dropped.
    fn admit(&mut self, events: &mut Vec<CloudEvent>) -> Result<()> {
        self.drain_wfq();
        // pass 1: triage the verify queue
        let mut deferred: VecDeque<CloudRequest> = VecDeque::new();
        let mut new_sessions: VecDeque<CloudRequest> = VecDeque::new();
        while let Some(req) = self.waiting_verify.pop_front() {
            let CloudRequest::Verify { request_id, .. } = &req else {
                bail!("misrouted request in the verify queue: {req:?}");
            };
            let request_id = *request_id;
            let earlier_round_pending = new_sessions.iter().any(
                |r| matches!(r, CloudRequest::Verify { request_id: o, .. } if *o == request_id),
            );
            if self.verifying.iter().any(|j| j.request_id == request_id) || earlier_round_pending
            {
                deferred.push_back(req); // serialise rounds of one session
            } else if self.sessions.contains(request_id) {
                self.start_verify(req, events);
            } else {
                new_sessions.push_back(req);
            }
        }
        // pass 2: hand out session capacity alternately
        while self.sessions.can_open()
            && !(self.waiting_gen.is_empty() && new_sessions.is_empty())
        {
            let take_verify = if new_sessions.is_empty() {
                false
            } else if self.waiting_gen.is_empty() {
                true
            } else {
                self.admit_verify_first
            };
            self.admit_verify_first = !self.admit_verify_first;
            if take_verify {
                let mut req = new_sessions.pop_front().expect("checked non-empty");
                let CloudRequest::Verify { request_id, uncached, .. } = &mut req else {
                    unreachable!("triaged in pass 1");
                };
                let request_id = *request_id;
                // radix-match the round's prompt prefix: matched blocks
                // become shared references and the verify forward pass
                // starts at the first unmatched token (capped so ≥1
                // uncached token always reaches the engine)
                let matched = self.sessions.open_with_prompt(request_id, uncached)?;
                if matched > 0 {
                    uncached.drain(..matched);
                    if let Some(&t) = self.tenant_of.get(&request_id) {
                        self.tenant_stats[t].prefix_hit_rows += matched as u64;
                    }
                }
                self.start_verify(req, events);
            } else {
                match self.waiting_gen.pop_front() {
                    Some(CloudRequest::Generate { request_id, prompt, max_new }) => {
                        // prefill planning skips matched blocks: the
                        // packed prefill chunk starts at the first
                        // unmatched token (`consumed` = matched rows)
                        let matched = self.sessions.open_with_prompt(request_id, &prompt)?;
                        if matched > 0 {
                            if let Some(&t) = self.tenant_of.get(&request_id) {
                                self.tenant_stats[t].prefix_hit_rows += matched as u64;
                            }
                        }
                        self.trace_instant(
                            "admit",
                            request_id,
                            vec![("prompt", prompt.len() as f64)],
                        );
                        self.prefilling.push(GenJob {
                            request_id,
                            prompt,
                            consumed: matched,
                            max_new,
                            generated: Vec::new(),
                            next_token: None,
                            wait_iters: 0,
                        });
                    }
                    Some(other) => {
                        bail!("misrouted request in the generate queue: {other:?}")
                    }
                    None => unreachable!("checked non-empty"),
                }
            }
        }
        // unadmitted new sessions queue behind the serialised rounds
        deferred.append(&mut new_sessions);
        self.waiting_verify = deferred;
        Ok(())
    }

    /// Move requests from the weighted-fair frontend into the admission
    /// queues, in virtual-finish-time order, but only as many
    /// session-opening requests as there is session capacity for —
    /// popping more would collapse WFQ ordering into FIFO arrival order
    /// inside the staging queues. Verify rounds whose session is
    /// already open never wait on capacity (they consume none).
    fn drain_wfq(&mut self) {
        if self.wfq.is_none() {
            return;
        }
        // sessions that staged-but-unadmitted requests will open —
        // distinct ids, since several rounds of one unopened session
        // still open only one session
        let mut pending_new: HashSet<u64> = HashSet::new();
        for r in self.waiting_gen.iter().chain(self.waiting_verify.iter()) {
            match r {
                CloudRequest::Generate { request_id, .. } => {
                    pending_new.insert(*request_id);
                }
                CloudRequest::Verify { request_id, .. }
                    if !self.sessions.contains(*request_id) =>
                {
                    pending_new.insert(*request_id);
                }
                _ => {}
            }
        }
        loop {
            let head_open = {
                let Some(wfq) = self.wfq.as_ref() else { break };
                match wfq.peek() {
                    None => break,
                    Some((_, CloudRequest::Verify { request_id, .. })) => {
                        self.sessions.contains(*request_id)
                    }
                    Some(_) => false,
                }
            };
            if !head_open
                && self.sessions.active() + pending_new.len() >= self.sessions.max_sessions
            {
                // capacity exhausted — but open-session follow-up
                // rounds queued *behind* the blocked head consume no
                // capacity and may be exactly what a capacity-holding
                // session is waiting on; leaving them would deadlock
                while let Some((_, req)) =
                    self.wfq.as_mut().expect("checked above").pop_matching(|r| match r {
                        CloudRequest::Verify { request_id, .. } => {
                            self.sessions.contains(*request_id)
                        }
                        _ => false,
                    })
                {
                    self.waiting_verify.push_back(req);
                }
                break;
            }
            let (_, req) =
                self.wfq.as_mut().expect("checked above").pop().expect("peeked non-empty");
            let rid = match &req {
                CloudRequest::Generate { request_id, .. }
                | CloudRequest::Verify { request_id, .. } => *request_id,
                CloudRequest::Release { .. } => {
                    unreachable!("releases bypass the tenant frontend")
                }
            };
            if matches!(req, CloudRequest::Generate { .. }) {
                pending_new.insert(rid);
                self.waiting_gen.push_back(req);
            } else {
                if !head_open {
                    pending_new.insert(rid);
                }
                self.waiting_verify.push_back(req);
            }
        }
    }

    /// Start a verify round on its (already open) session. The caller
    /// ensures no round of the session is in flight; the session's
    /// committed length is tracked by the [`SessionManager`] whether
    /// the KV is resident or parked. A round that would overflow the
    /// slot's KV capacity ends the session gracefully (EOS correction,
    /// zero accepted) instead of failing the scheduling loop mid-tick.
    fn start_verify(&mut self, req: CloudRequest, events: &mut Vec<CloudEvent>) {
        let CloudRequest::Verify { request_id, device_id, uncached, draft, dists, greedy, ctx } =
            req
        else {
            unreachable!("start_verify takes only verify requests");
        };
        let base_len = self.sessions.len_of(request_id);
        if self.trace.is_some() {
            self.trace_instant(
                "admit",
                request_id,
                vec![
                    ("base_len", base_len as f64),
                    ("draft", draft.len() as f64),
                    ("round", ctx.round as f64),
                ],
            );
        }
        if base_len + uncached.len() + draft.len() > self.engine.max_len() {
            // the overflow verdict still commits (EOS, zero accepted):
            // trace it like any other round so the request's timeline
            // stays complete for `synera inspect`. A force-ended
            // request is a partial outcome — tail-interesting, so the
            // sampler must keep its full event set however fast it ran
            trace::with(&self.trace, |s| s.mark_interesting(request_id));
            if self.trace.is_some() {
                self.trace_instant(
                    "verify_commit",
                    request_id,
                    vec![
                        ("accepted", 0.0),
                        ("draft", draft.len() as f64),
                        ("round", ctx.round as f64),
                    ],
                );
                if ctx.parent_span != 0 {
                    let (tid, flow_id) = (self.trace_tid, ctx.parent_span);
                    trace::with(&self.trace, |s| {
                        s.flow(PID_CLOUD, tid, "offload", Ph::FlowStep, flow_id);
                    });
                }
            }
            events.push(CloudEvent::VerifyDone {
                request_id,
                device_id,
                outcome: VerifyOutcome { accepted: 0, next_token: EOS },
            });
            return;
        }
        let u = uncached.len();
        let mut tokens = uncached;
        tokens.extend_from_slice(&draft);
        self.verifying.push(VerifyJob {
            request_id,
            device_id,
            base_len,
            u,
            tokens,
            draft,
            dists,
            greedy,
            consumed: 0,
            rows: Vec::new(),
            wait_iters: 0,
            ctx,
        });
    }

    /// Empirical acceptance rate α (profiling support, paper §5).
    pub fn acceptance_rate(&self) -> f64 {
        if self.stats.draft_tokens_seen == 0 {
            return 0.0;
        }
        self.stats.draft_tokens_accepted as f64 / self.stats.draft_tokens_seen as f64
    }
}
