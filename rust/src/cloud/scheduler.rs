//! Verification-aware scheduler — paper Algorithm 1.
//!
//! Each `tick()` is one scheduling iteration over the slot-based engine:
//! prefill requests are admitted and batched first (lines 5–11); when no
//! prefill work exists, pending verification requests run as **chunked
//! partial prefill** (lines 12–21, chunk = 32 after Sarathi-Serve) and
//! are verified when their last chunk lands; cloud-centric decode
//! batches run when nothing else is waiting. Completed requests leave
//! the batch (line 22).
//!
//! Verification requests keep their slot across rounds (the KV prefix
//! persists; rejected draft tails are rolled back by position masking).
//! When all slots are busy, arrivals queue — that queueing is exactly
//! the latency knee the Fig. 15 scalability experiment measures.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::cloud::verifier::{verify_chunk, VerifyOutcome};
use crate::model::cloud_engine::{CloudEngine, SlotChunk};
use crate::model::logits::argmax;
use crate::net::wire::Dist;
use crate::util::rng::Rng;
use crate::workload::vocab::EOS;

/// Work submitted to the cloud.
#[derive(Debug, Clone)]
pub enum CloudRequest {
    /// Cloud-centric baseline: full generation on the LLM.
    Generate { request_id: u64, prompt: Vec<u32>, max_new: usize },
    /// Synera verification round (decoded `UplinkMsg`).
    Verify {
        request_id: u64,
        device_id: u32,
        /// Device-accepted tokens not yet in the cloud KV (first round:
        /// the whole prompt). Must be non-empty.
        uncached: Vec<u32>,
        draft: Vec<u32>,
        dists: Vec<Dist>,
        greedy: bool,
    },
    /// A device session finished; free its slot.
    Release { request_id: u64 },
}

/// Completions surfaced by `tick()`.
#[derive(Debug, Clone)]
pub enum CloudEvent {
    VerifyDone { request_id: u64, device_id: u32, outcome: VerifyOutcome },
    /// Cloud-centric generation finished (tokens exclude the prompt).
    Generated { request_id: u64, tokens: Vec<u32> },
}

#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    pub iterations: u64,
    pub prefill_iters: u64,
    pub verify_iters: u64,
    pub decode_iters: u64,
    pub rows_executed: u64,
    /// Engine compute inside ticks.
    pub busy_s: f64,
    /// Scheduling bookkeeping outside engine calls (Fig. 18 overhead).
    pub sched_overhead_s: f64,
    pub verifies_done: u64,
    pub draft_tokens_seen: u64,
    pub draft_tokens_accepted: u64,
}

struct GenJob {
    request_id: u64,
    prompt: Vec<u32>,
    consumed: usize,
    slot: usize,
    max_new: usize,
    generated: Vec<u32>,
    next_token: Option<u32>,
}

struct VerifyJob {
    request_id: u64,
    device_id: u32,
    slot: usize,
    base_len: usize,
    tokens: Vec<u32>,
    u: usize,
    draft: Vec<u32>,
    dists: Vec<Dist>,
    greedy: bool,
    consumed: usize,
    rows: Vec<Vec<f32>>,
}

/// The verification-aware scheduler bound to one [`CloudEngine`].
pub struct Scheduler {
    pub engine: CloudEngine,
    waiting_gen: VecDeque<CloudRequest>,
    waiting_verify: VecDeque<CloudRequest>,
    prefilling: Vec<GenJob>,
    decoding: Vec<GenJob>,
    verifying: Vec<VerifyJob>,
    /// Persistent slot per Synera session.
    session_slot: HashMap<u64, usize>,
    rng: Rng,
    pub stats: SchedulerStats,
}

impl Scheduler {
    pub fn new(engine: CloudEngine, seed: u64) -> Scheduler {
        Scheduler {
            engine,
            waiting_gen: VecDeque::new(),
            waiting_verify: VecDeque::new(),
            prefilling: Vec::new(),
            decoding: Vec::new(),
            verifying: Vec::new(),
            session_slot: HashMap::new(),
            rng: Rng::new(seed ^ 0xC10D),
            stats: SchedulerStats::default(),
        }
    }

    pub fn submit(&mut self, req: CloudRequest) -> Result<()> {
        match &req {
            CloudRequest::Generate { .. } => self.waiting_gen.push_back(req),
            CloudRequest::Verify { uncached, .. } => {
                if uncached.is_empty() {
                    bail!("verify round must carry ≥1 uncached token");
                }
                self.waiting_verify.push_back(req);
            }
            CloudRequest::Release { request_id } => {
                if let Some(slot) = self.session_slot.remove(request_id) {
                    self.engine.free_slot(slot);
                }
            }
        }
        Ok(())
    }

    /// Anything in flight or queued?
    pub fn is_idle(&self) -> bool {
        self.waiting_gen.is_empty()
            && self.waiting_verify.is_empty()
            && self.prefilling.is_empty()
            && self.decoding.is_empty()
            && self.verifying.is_empty()
    }

    pub fn queue_depth(&self) -> usize {
        self.waiting_gen.len() + self.waiting_verify.len()
    }

    /// One Algorithm-1 iteration. Returns surfaced events plus the
    /// engine compute seconds consumed by this tick (the caller's clock).
    pub fn tick(&mut self) -> Result<(Vec<CloudEvent>, f64)> {
        let t_tick = Instant::now();
        self.stats.iterations += 1;
        let mut events = Vec::new();
        let mut compute_s = 0.0;

        self.admit();

        // ---- lines 5–11: prefill-priority iteration -----------------------
        if !self.prefilling.is_empty() {
            self.stats.prefill_iters += 1;
            let chunk = self.engine.chunk;
            let mut items = Vec::new();
            for job in self.prefilling.iter_mut().take(self.engine.slots) {
                let n = (job.prompt.len() - job.consumed).min(chunk);
                items.push(SlotChunk {
                    slot: job.slot,
                    tokens: job.prompt[job.consumed..job.consumed + n].to_vec(),
                });
            }
            let sched_before = t_tick.elapsed().as_secs_f64();
            let (res, dt) = self.engine.run_batch(&items)?;
            compute_s += dt;
            self.stats.busy_s += dt;
            let v = self.engine.model.meta.vocab;
            for r in &res {
                let job = self
                    .prefilling
                    .iter_mut()
                    .find(|j| j.slot == r.slot)
                    .expect("job for slot");
                job.consumed += r.n_rows;
                if job.consumed == job.prompt.len() {
                    job.next_token =
                        Some(argmax(&r.rows[(r.n_rows - 1) * v..r.n_rows * v]) as u32);
                }
            }
            self.stats.rows_executed = self.engine.rows_executed;
            // move finished prefills to the decode pool
            let mut i = 0;
            while i < self.prefilling.len() {
                if self.prefilling[i].consumed == self.prefilling[i].prompt.len() {
                    let job = self.prefilling.remove(i);
                    self.decoding.push(job);
                } else {
                    i += 1;
                }
            }
            self.stats.sched_overhead_s += t_tick.elapsed().as_secs_f64() - sched_before - dt;
            return Ok((events, compute_s));
        }

        // ---- lines 12–21: verification iteration --------------------------
        if !self.verifying.is_empty() {
            self.stats.verify_iters += 1;
            let chunk = self.engine.chunk;
            let mut items = Vec::new();
            for job in self.verifying.iter_mut().take(self.engine.slots) {
                let n = (job.tokens.len() - job.consumed).min(chunk);
                items.push(SlotChunk {
                    slot: job.slot,
                    tokens: job.tokens[job.consumed..job.consumed + n].to_vec(),
                });
            }
            let sched_mark = t_tick.elapsed().as_secs_f64();
            let (res, dt) = self.engine.run_batch(&items)?;
            compute_s += dt;
            self.stats.busy_s += dt;
            let v = self.engine.model.meta.vocab;
            for r in &res {
                let job = self
                    .verifying
                    .iter_mut()
                    .find(|j| j.slot == r.slot)
                    .expect("job for slot");
                for i in 0..r.n_rows {
                    let gi = job.consumed + i; // global row in the verify seq
                    if gi + 1 >= job.u {
                        job.rows.push(r.rows[i * v..(i + 1) * v].to_vec());
                    }
                }
                job.consumed += r.n_rows;
            }
            self.stats.rows_executed = self.engine.rows_executed;

            let mut i = 0;
            while i < self.verifying.len() {
                if self.verifying[i].consumed == self.verifying[i].tokens.len() {
                    let job = self.verifying.remove(i);
                    let outcome = verify_chunk(
                        &job.draft,
                        &job.dists,
                        &job.rows,
                        job.greedy,
                        &mut self.rng,
                    );
                    self.stats.verifies_done += 1;
                    self.stats.draft_tokens_seen += job.draft.len() as u64;
                    self.stats.draft_tokens_accepted += outcome.accepted as u64;
                    // commit prefix + uncached + accepted; mask the rest
                    self.engine
                        .rollback(job.slot, job.base_len + job.u + outcome.accepted);
                    events.push(CloudEvent::VerifyDone {
                        request_id: job.request_id,
                        device_id: job.device_id,
                        outcome,
                    });
                } else {
                    i += 1;
                }
            }
            self.stats.sched_overhead_s += t_tick.elapsed().as_secs_f64() - sched_mark - dt;
            return Ok((events, compute_s));
        }

        // ---- cloud-centric decode batch ------------------------------------
        if !self.decoding.is_empty() {
            self.stats.decode_iters += 1;
            let toks: Vec<(usize, u32)> = self
                .decoding
                .iter()
                .take(self.engine.slots)
                .map(|j| (j.slot, j.next_token.expect("decode has next")))
                .collect();
            let sched_mark = t_tick.elapsed().as_secs_f64();
            let (res, dt) = self.engine.run_decode(&toks)?;
            compute_s += dt;
            self.stats.busy_s += dt;
            for r in &res {
                let job = self
                    .decoding
                    .iter_mut()
                    .find(|j| j.slot == r.slot)
                    .expect("job for slot");
                let committed = job.next_token.take().expect("token");
                job.generated.push(committed);
                let next = argmax(&r.rows) as u32;
                if committed == EOS || job.generated.len() >= job.max_new {
                    // done (committed EOS or budget reached)
                } else {
                    job.next_token = Some(next);
                }
            }
            self.stats.rows_executed = self.engine.rows_executed;
            let mut i = 0;
            while i < self.decoding.len() {
                if self.decoding[i].next_token.is_none() {
                    let job = self.decoding.remove(i);
                    self.engine.free_slot(job.slot);
                    events.push(CloudEvent::Generated {
                        request_id: job.request_id,
                        tokens: job.generated,
                    });
                } else {
                    i += 1;
                }
            }
            self.stats.sched_overhead_s += t_tick.elapsed().as_secs_f64() - sched_mark - dt;
            return Ok((events, compute_s));
        }

        self.stats.sched_overhead_s += t_tick.elapsed().as_secs_f64();
        Ok((events, compute_s))
    }

    /// Admit waiting requests into free slots.
    fn admit(&mut self) {
        while !self.waiting_gen.is_empty() && self.engine.free_slots() > 0 {
            if let Some(CloudRequest::Generate { request_id, prompt, max_new }) =
                self.waiting_gen.pop_front()
            {
                let slot = self.engine.alloc_slot(request_id).expect("free slot");
                self.prefilling.push(GenJob {
                    request_id,
                    prompt,
                    consumed: 0,
                    slot,
                    max_new,
                    generated: Vec::new(),
                    next_token: None,
                });
            }
        }
        let mut requeue = VecDeque::new();
        while let Some(req) = self.waiting_verify.pop_front() {
            let CloudRequest::Verify { request_id, device_id, uncached, draft, dists, greedy } =
                req
            else {
                continue;
            };
            let slot = match self.session_slot.get(&request_id) {
                Some(&s) => Some(s),
                None => {
                    let s = self.engine.alloc_slot(request_id);
                    if let Some(s) = s {
                        self.session_slot.insert(request_id, s);
                    }
                    s
                }
            };
            match slot {
                Some(slot) => {
                    let base_len = self.engine.slot_len[slot];
                    let mut tokens = uncached.clone();
                    tokens.extend_from_slice(&draft);
                    self.verifying.push(VerifyJob {
                        request_id,
                        device_id,
                        slot,
                        base_len,
                        u: uncached.len(),
                        tokens,
                        draft,
                        dists,
                        greedy,
                        consumed: 0,
                        rows: Vec::new(),
                    });
                }
                None => requeue.push_back(CloudRequest::Verify {
                    request_id,
                    device_id,
                    uncached,
                    draft,
                    dists,
                    greedy,
                }),
            }
        }
        self.waiting_verify = requeue;
    }

    /// Empirical acceptance rate α (profiling support, paper §5).
    pub fn acceptance_rate(&self) -> f64 {
        if self.stats.draft_tokens_seen == 0 {
            return 0.0;
        }
        self.stats.draft_tokens_accepted as f64 / self.stats.draft_tokens_seen as f64
    }
}
