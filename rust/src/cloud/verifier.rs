//! Speculative draft-&-verify (Leviathan et al.; paper Fig. 3).
//!
//! Given the device's draft tokens with their `p(x|·)` distributions and
//! the LLM's `q(x|·)` rows from the partial-prefill forward, accept the
//! longest valid prefix and produce the next token:
//!
//! * **greedy** — accept while `argmax q == draft`; on the first
//!   mismatch the correction is `argmax q`; full acceptance yields the
//!   bonus token `argmax q_γ`.
//! * **stochastic** — accept token `t` iff `u < q(t)/p(t)`; on rejection
//!   resample from `norm(max(0, q − p))`. A compressed `p` is 0 outside
//!   its top-k support; since honest devices sample inside the support,
//!   that case never arises for drafted tokens (and `q/p → ∞` would
//!   accept it anyway), so compression is verification-lossless.

use crate::model::logits::{argmax, sample_with};
use crate::net::wire::Dist;
use crate::util::rng::Rng;

/// Result of verifying one draft chunk.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifyOutcome {
    /// Accepted draft prefix length (0..=γ).
    pub accepted: usize,
    /// Correction at the rejection position, or the bonus token when all
    /// γ drafts were accepted.
    pub next_token: u32,
}

/// `q_rows`: γ+1 rows × vocab — `q_rows[j]` is the LLM distribution over
/// the token following `draft[j-1]` (row 0 follows the last uncached
/// token). The extra final row supplies the bonus token.
pub fn verify_chunk(
    draft: &[u32],
    dists: &[Dist],
    q_rows: &[Vec<f32>],
    greedy: bool,
    rng: &mut Rng,
) -> VerifyOutcome {
    let gamma = draft.len();
    assert_eq!(dists.len(), gamma, "one p-dist per draft token");
    assert!(q_rows.len() >= gamma + 1, "need γ+1 q rows");

    for j in 0..gamma {
        let q = &q_rows[j];
        let t = draft[j];
        let accepted = if greedy {
            argmax(q) as u32 == t
        } else {
            let p = dists[j].prob_of(t).max(1e-9);
            let qt = q[t as usize];
            rng.f64() < (qt / p) as f64
        };
        if !accepted {
            let next = if greedy {
                argmax(q) as u32
            } else {
                // residual distribution norm(max(0, q − p))
                let mut resid: Vec<f32> = q
                    .iter()
                    .enumerate()
                    .map(|(i, &qv)| (qv - dists[j].prob_of(i as u32)).max(0.0))
                    .collect();
                let s: f32 = resid.iter().sum();
                if s <= 0.0 {
                    argmax(q) as u32
                } else {
                    resid.iter_mut().for_each(|x| *x /= s);
                    sample_with(&resid, rng.f64()) as u32
                }
            };
            return VerifyOutcome { accepted: j, next_token: next };
        }
    }
    // everything accepted: bonus token from the extra row
    let bonus = &q_rows[gamma];
    let next = if greedy {
        argmax(bonus) as u32
    } else {
        sample_with(bonus, rng.f64()) as u32
    };
    VerifyOutcome { accepted: gamma, next_token: next }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn onehot(v: usize, i: usize) -> Vec<f32> {
        let mut x = vec![0f32; v];
        x[i] = 1.0;
        x
    }

    fn dense(probs: &[f32]) -> Dist {
        Dist::Dense(probs.to_vec())
    }

    #[test]
    fn greedy_full_accept_gives_bonus() {
        let mut rng = Rng::new(1);
        let draft = [3u32, 4];
        let dists = vec![dense(&onehot(8, 3)), dense(&onehot(8, 4))];
        let q = vec![onehot(8, 3), onehot(8, 4), onehot(8, 7)];
        let out = verify_chunk(&draft, &dists, &q, true, &mut rng);
        assert_eq!(out, VerifyOutcome { accepted: 2, next_token: 7 });
    }

    #[test]
    fn greedy_rejects_at_first_mismatch() {
        let mut rng = Rng::new(1);
        let draft = [3u32, 4, 5];
        let dists = vec![dense(&onehot(8, 3)); 3];
        let q = vec![onehot(8, 3), onehot(8, 6), onehot(8, 5), onehot(8, 0)];
        let out = verify_chunk(&draft, &dists, &q, true, &mut rng);
        assert_eq!(out, VerifyOutcome { accepted: 1, next_token: 6 });
    }

    #[test]
    fn stochastic_always_accepts_when_q_dominates() {
        let mut rng = Rng::new(7);
        // p puts 0.5 on token 2, q puts 1.0 → ratio 2 ≥ 1 → always accept
        let mut p = vec![0f32; 8];
        p[2] = 0.5;
        p[3] = 0.5;
        let out = verify_chunk(
            &[2],
            &[dense(&p)],
            &[onehot(8, 2), onehot(8, 1)],
            false,
            &mut rng,
        );
        assert_eq!(out.accepted, 1);
        assert_eq!(out.next_token, 1);
    }

    #[test]
    fn stochastic_rejection_samples_residual() {
        let mut rng = Rng::new(5);
        // p is all on token 0; q is all on token 1 → reject, resample → 1
        let out = verify_chunk(
            &[0],
            &[dense(&onehot(8, 0))],
            &[onehot(8, 1), onehot(8, 2)],
            false,
            &mut rng,
        );
        assert_eq!(out, VerifyOutcome { accepted: 0, next_token: 1 });
    }

    #[test]
    fn stochastic_matches_target_acceptance_rate() {
        // identical p == q → acceptance probability 1 per token
        let mut rng = Rng::new(9);
        let mut p = vec![0f32; 4];
        p[1] = 0.6;
        p[2] = 0.4;
        let mut accepts = 0;
        for _ in 0..500 {
            let out = verify_chunk(
                &[1],
                &[dense(&p)],
                &[p.clone(), p.clone()],
                false,
                &mut rng,
            );
            accepts += (out.accepted == 1) as usize;
        }
        assert_eq!(accepts, 500);
    }

    #[test]
    fn compressed_p_outside_support_accepts_when_q_backs_it() {
        let mut rng = Rng::new(3);
        let d = crate::device::codec::compress_dist(&onehot(8, 4), 1);
        // p(5)=0 under compression but q(5)=1 → ratio ∞ → accept; the
        // honest-sampling contract means this branch is unreachable in
        // the real pipeline, and acceptance is the lossless behaviour
        let out = verify_chunk(&[5], &[d], &[onehot(8, 5), onehot(8, 0)], false, &mut rng);
        assert_eq!(out.accepted, 1);
        // ...and when q gives it no mass either, it must reject
        let d2 = crate::device::codec::compress_dist(&onehot(8, 4), 1);
        let out2 = verify_chunk(&[5], &[d2], &[onehot(8, 2), onehot(8, 0)], false, &mut rng);
        assert_eq!(out2.accepted, 0);
    }
}
