//! Weighted fair queueing across device tenants (ROADMAP "Cloud
//! batching" open item; cf. the edge-inference survey's multi-tenant
//! queueing analyses in PAPERS.md).
//!
//! A [`WfqQueue`] is a self-clocked fair-queueing (SCFQ) frontend over
//! per-tenant FIFO queues: every submission carries a *cost* in token
//! rows, and is stamped with a **virtual finish time**
//!
//! ```text
//! F = max(V, F_tenant) + cost / weight
//! ```
//!
//! where `V` is the queue's virtual clock (the finish time of the last
//! dequeued item) and `F_tenant` the tenant's last stamped finish.
//! Dequeueing always takes the globally smallest `F`, so over any busy
//! interval each backlogged tenant receives service proportional to its
//! weight. Because a returning tenant restarts from `max(V, F_tenant)`,
//! idle periods earn **no credit**: a tenant that slept for an hour
//! cannot burst ahead of tenants that kept the queue busy, and its own
//! future service is not penalised by the sleep either.
//!
//! Traffic that must bypass the queue (follow-up verification rounds of
//! an already-admitted session — holding those back could deadlock a
//! session against its own slot) is still accounted via
//! [`WfqQueue::charge`], which advances the tenant's finish stamp
//! without enqueueing, so bypass volume counts against the tenant's
//! share of *future* admissions.
//!
//! The scheduler wires this in **ahead** of its per-iteration machinery
//! (see `cloud::scheduler`): WFQ decides which waiting request is next
//! granted a logical session, then the existing aging/packing fairness
//! takes over inside the batch.

use std::collections::VecDeque;

use anyhow::{bail, Result};

/// Per-tenant service counters (admission-frontend visibility).
#[derive(Debug, Clone, Default)]
pub struct TenantStats {
    /// Requests submitted through the tenant frontend.
    pub submitted: u64,
    /// Engine token rows executed on behalf of this tenant.
    pub rows_executed: u64,
    /// Verification rounds completed.
    pub verifies_done: u64,
    /// Draft tokens accepted across those rounds.
    pub draft_tokens_accepted: u64,
    /// Prompt rows served from shared prefix blocks at admission
    /// (prefill compute this tenant never paid for).
    pub prefix_hit_rows: u64,
}

/// One queued item with its virtual-time stamps.
#[derive(Debug, Clone)]
struct Queued<T> {
    /// Virtual finish time.
    finish: f64,
    /// Credit charged when stamped (`cost / weight`) — refunded if the
    /// item is purged before it runs.
    credit: f64,
    item: T,
}

/// A self-clocked weighted-fair queue over `T`-typed items.
#[derive(Debug, Clone)]
pub struct WfqQueue<T> {
    weights: Vec<f64>,
    /// Virtual clock: finish time of the most recently dequeued item.
    vtime: f64,
    /// Last stamped finish time per tenant.
    last_finish: Vec<f64>,
    /// Per-tenant FIFO in stamp order.
    queues: Vec<VecDeque<Queued<T>>>,
    len: usize,
}

impl<T> WfqQueue<T> {
    /// Build a queue for `weights.len()` tenants. Every weight must be
    /// finite and positive.
    pub fn new(weights: &[f64]) -> Result<WfqQueue<T>> {
        if weights.is_empty() {
            bail!("weighted fair queueing needs at least one tenant");
        }
        if let Some(w) = weights.iter().find(|w| !w.is_finite() || **w <= 0.0) {
            bail!("tenant weights must be finite and positive (got {w})");
        }
        Ok(WfqQueue {
            weights: weights.to_vec(),
            vtime: 0.0,
            last_finish: vec![0.0; weights.len()],
            queues: weights.iter().map(|_| VecDeque::new()).collect(),
            len: 0,
        })
    }

    pub fn n_tenants(&self) -> usize {
        self.weights.len()
    }

    pub fn weight(&self, tenant: usize) -> f64 {
        self.weights[tenant]
    }

    /// Queued items across all tenants.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Queued items for one tenant (0 for out-of-range tenants) — the
    /// per-tenant backlog signal the router tier load-balances on.
    pub fn len_of(&self, tenant: usize) -> usize {
        self.queues.get(tenant).map_or(0, |q| q.len())
    }

    /// Does any queued item (any tenant) satisfy `pred`? Read-only
    /// companion to [`WfqQueue::retain`] for busy-checks that must not
    /// disturb stamps or credit.
    pub fn any<F: FnMut(&T) -> bool>(&self, mut pred: F) -> bool {
        self.queues.iter().any(|q| q.iter().any(|e| pred(&e.item)))
    }

    /// Stamp the tenant's next virtual finish time for `cost` rows,
    /// returning `(finish, credit charged)`.
    fn stamp(&mut self, tenant: usize, cost: f64) -> (f64, f64) {
        let start = self.vtime.max(self.last_finish[tenant]);
        let credit = cost.max(1.0) / self.weights[tenant];
        let f = start + credit;
        self.last_finish[tenant] = f;
        (f, credit)
    }

    /// Enqueue `item` for `tenant` at a cost of `cost` token rows.
    pub fn push(&mut self, tenant: usize, cost: f64, item: T) -> Result<()> {
        if tenant >= self.weights.len() {
            bail!("tenant {tenant} out of range ({} tenants)", self.weights.len());
        }
        let (finish, credit) = self.stamp(tenant, cost);
        self.queues[tenant].push_back(Queued { finish, credit, item });
        self.len += 1;
        Ok(())
    }

    /// Account `cost` rows of bypass traffic against `tenant`'s share
    /// without enqueueing anything (follow-up rounds of open sessions).
    pub fn charge(&mut self, tenant: usize, cost: f64) {
        if tenant < self.weights.len() {
            self.stamp(tenant, cost);
        }
    }

    /// The tenant whose head item has the smallest virtual finish time
    /// (smaller tenant index breaks exact ties — deterministic).
    fn head_tenant(&self) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for (t, q) in self.queues.iter().enumerate() {
            if let Some(e) = q.front() {
                let better = match best {
                    None => true,
                    Some((bf, _)) => e.finish < bf,
                };
                if better {
                    best = Some((e.finish, t));
                }
            }
        }
        best.map(|(_, t)| t)
    }

    /// The next item in weighted-fair order, without dequeueing it.
    pub fn peek(&self) -> Option<(usize, &T)> {
        let t = self.head_tenant()?;
        self.queues[t].front().map(|e| (t, &e.item))
    }

    /// Drop queued items rejected by `f` (e.g. rounds of a released
    /// session) and **refund their stamped credit**: cancelled work
    /// that never ran must not count against the tenant's future
    /// share. Surviving items keep their stamps, so the refunded
    /// finish floor is the tenant's remaining tail stamp.
    pub fn retain<F: FnMut(&T) -> bool>(&mut self, mut f: F) {
        for (t, q) in self.queues.iter_mut().enumerate() {
            let mut refund = 0.0;
            q.retain(|e| {
                let keep = f(&e.item);
                if !keep {
                    refund += e.credit;
                }
                keep
            });
            if refund > 0.0 {
                let tail = q.back().map_or(f64::MIN, |e| e.finish);
                self.last_finish[t] = (self.last_finish[t] - refund).max(tail);
            }
        }
        self.len = self.queues.iter().map(|q| q.len()).sum();
    }

    /// Dequeue the earliest-stamped item satisfying `pred`, regardless
    /// of its position behind other tenants' heads. For bypass traffic
    /// that must not wait on admission capacity (e.g. a follow-up
    /// round of an already-open session stuck behind a capacity-blocked
    /// head — holding it would deadlock the session against its own
    /// admission). The virtual clock is left untouched: the item keeps
    /// its charge, but an out-of-order extraction must not leapfrog the
    /// clock past still-waiting smaller stamps.
    pub fn pop_matching<F: FnMut(&T) -> bool>(&mut self, mut pred: F) -> Option<(usize, T)> {
        let mut best: Option<(f64, usize, usize)> = None;
        for (t, q) in self.queues.iter().enumerate() {
            // within a tenant stamps are FIFO, so the first match is
            // that tenant's earliest match
            if let Some((i, e)) = q.iter().enumerate().find(|(_, e)| pred(&e.item)) {
                let better = match best {
                    None => true,
                    Some((bf, _, _)) => e.finish < bf,
                };
                if better {
                    best = Some((e.finish, t, i));
                }
            }
        }
        let (_, t, i) = best?;
        let e = self.queues[t].remove(i).expect("indexed above");
        self.len -= 1;
        Some((t, e.item))
    }

    /// Dequeue the next item in weighted-fair order, advancing the
    /// virtual clock to its finish time.
    pub fn pop(&mut self) -> Option<(usize, T)> {
        let t = self.head_tenant()?;
        let e = self.queues[t].pop_front().expect("head tenant has an item");
        self.vtime = self.vtime.max(e.finish);
        self.len -= 1;
        Some((t, e.item))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_bad_weights() {
        assert!(WfqQueue::<u32>::new(&[]).is_err());
        assert!(WfqQueue::<u32>::new(&[1.0, 0.0]).is_err());
        assert!(WfqQueue::<u32>::new(&[1.0, -2.0]).is_err());
        assert!(WfqQueue::<u32>::new(&[f64::NAN]).is_err());
        assert!(WfqQueue::<u32>::new(&[1.0, 2.0]).is_ok());
    }

    #[test]
    fn single_tenant_is_fifo() {
        let mut q = WfqQueue::new(&[1.0]).unwrap();
        for i in 0..10u32 {
            q.push(0, 4.0, i).unwrap();
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, x)| x)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    /// Backlogged tenants drain in proportion to their weights: over any
    /// prefix of the dequeue order, a weight-2 tenant appears ~2× as
    /// often as a weight-1 tenant with the same per-item cost.
    #[test]
    fn weighted_shares_over_a_busy_period() {
        let mut q = WfqQueue::new(&[1.0, 2.0]).unwrap();
        for i in 0..60u32 {
            q.push(0, 4.0, i).unwrap();
            q.push(1, 4.0, 1000 + i).unwrap();
        }
        let mut counts = [0usize; 2];
        for _ in 0..30 {
            let (t, _) = q.pop().unwrap();
            counts[t] += 1;
        }
        // 30 pops with weights 1:2 → ideal split 10:20
        assert!(counts[1] >= 18 && counts[1] <= 22, "{counts:?}");
        assert_eq!(counts[0] + counts[1], 30);
    }

    /// An idle tenant accrues no credit: after tenant 0 kept the queue
    /// busy alone, a late-arriving tenant 1 shares from *now* instead of
    /// monopolising the queue to "catch up".
    #[test]
    fn idle_tenant_earns_no_credit() {
        let mut q = WfqQueue::new(&[1.0, 1.0]).unwrap();
        for i in 0..50u32 {
            q.push(0, 4.0, i).unwrap();
        }
        for _ in 0..50 {
            q.pop().unwrap();
        }
        // tenant 1 wakes up; both tenants now push equal work
        for i in 0..20u32 {
            q.push(0, 4.0, i).unwrap();
            q.push(1, 4.0, 100 + i).unwrap();
        }
        let mut counts = [0usize; 2];
        for _ in 0..20 {
            let (t, _) = q.pop().unwrap();
            counts[t] += 1;
        }
        // an equal split (±2 for stamp interleaving), NOT 0:20
        assert!(counts[0] >= 8 && counts[0] <= 12, "{counts:?}");
    }

    /// `charge` makes bypass traffic count against future admissions.
    #[test]
    fn charged_bypass_traffic_defers_the_tenant() {
        let mut q = WfqQueue::new(&[1.0, 1.0]).unwrap();
        q.charge(0, 400.0); // tenant 0 consumed a lot out of band
        q.push(0, 4.0, 0u32).unwrap();
        q.push(1, 4.0, 1u32).unwrap();
        let (first, _) = q.pop().unwrap();
        assert_eq!(first, 1, "the uncharged tenant goes first");
    }

    /// Purged (cancelled-before-running) items refund their credit:
    /// the tenant is not deferred behind phantom debt.
    #[test]
    fn retain_refunds_cancelled_credit() {
        let mut q = WfqQueue::new(&[1.0, 1.0]).unwrap();
        for i in 0..50u32 {
            q.push(0, 8.0, i).unwrap();
        }
        q.retain(|&x| x >= 50); // cancel the whole burst
        assert!(q.is_empty());
        q.push(0, 4.0, 100u32).unwrap();
        q.push(1, 4.0, 200).unwrap();
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, x)| x)).collect();
        assert_eq!(order, vec![100, 200], "refunded tenant competes from scratch");
    }

    #[test]
    fn cost_scales_service_share() {
        // equal weights, tenant 0 sends 4× costlier items → tenant 1
        // should dequeue ~4 items per tenant-0 item
        let mut q = WfqQueue::new(&[1.0, 1.0]).unwrap();
        for i in 0..10u32 {
            q.push(0, 16.0, i).unwrap();
        }
        for i in 0..40u32 {
            q.push(1, 4.0, 100 + i).unwrap();
        }
        let mut counts = [0usize; 2];
        for _ in 0..25 {
            let (t, _) = q.pop().unwrap();
            counts[t] += 1;
        }
        assert!(counts[0] >= 3 && counts[0] <= 7, "{counts:?}");
    }

    #[test]
    fn deterministic_order() {
        let run = || {
            let mut q = WfqQueue::new(&[1.0, 3.0, 2.0]).unwrap();
            for i in 0..30u32 {
                q.push((i % 3) as usize, 2.0 + (i % 5) as f64, i).unwrap();
            }
            std::iter::from_fn(|| q.pop().map(|(_, x)| x)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
