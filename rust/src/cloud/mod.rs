//! Cloud runtime (paper §3.4, §4.5): speculative verification and the
//! mixed continuous-batching scheduler — prefill, verification and
//! decode rows co-scheduled per iteration under a token budget — over
//! the slot-based [`crate::model::CloudEngine`], with paged logical
//! sessions ([`sessions`]) so concurrency is bounded by host memory
//! rather than the compiled batch width. At fleet scale, a [`router`]
//! tier fronts `R` independent scheduler replicas with tenant-aware
//! load balancing, session affinity, and priced cross-replica KV
//! migration.

pub mod fairness;
pub mod router;
pub mod scheduler;
pub mod sessions;
pub mod verifier;

pub use fairness::{TenantStats, WfqQueue};
pub use router::{MigrationRecord, Router, RouterStats};
pub use scheduler::{CloudEvent, CloudRequest, Scheduler, SchedulerStats};
pub use sessions::{SessionManager, SwapStats};
pub use verifier::{verify_chunk, VerifyOutcome};
