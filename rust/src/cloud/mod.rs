//! Cloud runtime (paper §3.4, §4.5): speculative verification and the
//! verification-aware continuous-batching scheduler over the slot-based
//! [`crate::model::CloudEngine`].

pub mod scheduler;
pub mod verifier;

pub use scheduler::{CloudEvent, CloudRequest, Scheduler, SchedulerStats};
pub use verifier::{verify_chunk, VerifyOutcome};
