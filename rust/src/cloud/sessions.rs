//! Logical-session manager: many sessions over B compute slots.
//!
//! The compiled cloud executables fix the batch width (B=4 slots), but
//! paper-scale serving (§Scalable Cloud Batching, Fig. 15) needs far
//! more *concurrent device sessions* than that. This module decouples
//! the two: a [`SessionManager`] tracks every admitted session as
//!
//! * **Resident** — owns an engine slot; its KV lives in the engine
//!   cache and it can be scheduled this iteration;
//! * **Parked** — its committed KV rows sit in a host-side
//!   [`BlockPool`] (see [`crate::runtime::paging`]) under a block
//!   table; it holds no slot;
//! * **Swapping** — transient marker while rows are mid-copy (never
//!   observable between manager calls).
//!
//! Before each scheduler iteration, sessions picked for execution are
//! made resident on demand: if no slot is free, a resident session is
//! *parked* (swap-out via `BatchEngine::export_slot`), its slot is
//! reassigned, and the target session's rows are restored
//! (`import_slot`). The victim is **swap-cost-aware LRU**: among the
//! least-recently-scheduled resident sessions (a window capped at
//! [`EVICT_CANDIDATES`] and at half the resident set), the one with
//! the fewest committed KV rows is parked — it costs the least to
//! copy out now and back in later. Sessions **pinned** by
//! the current iteration's picks are never eviction victims, so a tick
//! can never swap out work it is about to run. Swap traffic and copy
//! time are charged to [`SwapStats`] (and surfaced through the
//! scheduler's Fig. 18 overhead accounting, since swaps happen outside
//! engine compute).
//!
//! Concurrency is therefore bounded by `max_sessions` (host memory),
//! not by the compiled batch width — the Fig. 15 latency knee moves
//! from B to `max_sessions`.

use std::collections::{HashMap, HashSet};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::BatchPolicy;
use crate::model::cloud_engine::{BatchEngine, SlotOwner};
use crate::obs::trace::{self, TraceShared, PID_CLOUD};
use crate::runtime::paging::{BlockPool, BlockTable};
use crate::runtime::SlotKv;

/// Token rows per host KV block (vLLM-style fixed granularity).
pub const BLOCK_TOKENS: usize = 16;

/// Eviction candidate window cap: the victim is the **cheapest to
/// swap** (fewest committed KV rows) among the least-recently-scheduled
/// resident sessions. The effective window is
/// `min(EVICT_CANDIDATES, ⌈residents/2⌉)` — `1` would be pure LRU, and
/// bounding by half the resident set guarantees the most recently
/// scheduled half is always recency-protected (otherwise, on a B=4
/// engine, a short hot session could be swap-thrashed on alternating
/// ticks while large idle sessions stay resident). A small window
/// trades a little recency precision for much smaller swap copies
/// (ROADMAP "swap-cost-aware eviction").
pub const EVICT_CANDIDATES: usize = 4;

#[derive(Debug)]
enum SessionState {
    /// Owns engine slot `slot`; KV lives in the engine cache.
    Resident { slot: usize },
    /// KV parked in the host block pool (empty table for new sessions).
    Parked { table: BlockTable },
    /// Transient mid-swap marker.
    Swapping,
}

#[derive(Debug)]
struct Session {
    state: SessionState,
    /// Committed KV rows (mirrors the engine `slot_len` while resident).
    len: usize,
    /// LRU stamp — bumped whenever the session is granted a slot or
    /// scheduled; the eviction victim is the smallest stamp.
    last_used: u64,
}

/// Swap-traffic accounting (paged-KV cost visibility).
#[derive(Debug, Clone, Default)]
pub struct SwapStats {
    pub swap_ins: u64,
    pub swap_outs: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Host copy seconds across all swaps.
    pub swap_s: f64,
}

/// Tracks logical sessions and pages their KV between engine slots and
/// the host [`BlockPool`]. Eviction is swap-cost-aware
/// LRU-with-pinning: the fewest-rows session among the least recently
/// scheduled residents (window capped at [`EVICT_CANDIDATES`] and at
/// half the resident set) is parked, but never one the current
/// iteration has already picked.
pub struct SessionManager {
    pool: BlockPool,
    sessions: HashMap<u64, Session>,
    clock: u64,
    /// Admission cap on concurrent logical sessions.
    pub max_sessions: usize,
    stats: SwapStats,
    /// Swap-event trace sink shared with the owning scheduler
    /// ([`crate::cloud::scheduler::Scheduler::set_trace`]).
    trace: Option<TraceShared>,
    trace_tid: u32,
}

impl SessionManager {
    pub fn new(max_sessions: usize, pool: BlockPool) -> SessionManager {
        SessionManager {
            pool,
            sessions: HashMap::new(),
            clock: 0,
            max_sessions: max_sessions.max(1),
            stats: SwapStats::default(),
            trace: None,
            trace_tid: 0,
        }
    }

    /// Attach (or detach) the trace sink swap events are recorded to;
    /// `tid` is the owning replica's cloud-track thread.
    pub fn set_trace(&mut self, trace: Option<TraceShared>, tid: u32) {
        self.trace = trace;
        self.trace_tid = tid;
    }

    /// Size a manager for `engine` under `policy`: `max_sessions == 0`
    /// means "the physical slot count" (paging never triggers, pool is
    /// empty); above the slot count, the pool capacity covers the worst
    /// case — every non-resident session parked at full length, plus
    /// one mid-swap victim — so swap-outs cannot fail. The capacity is
    /// only a cap: block storage materialises lazily as sessions
    /// actually park, so an oversized pool costs no host memory up
    /// front.
    pub fn for_engine<E: BatchEngine>(engine: &E, policy: &BatchPolicy) -> SessionManager {
        let slots = engine.slots().max(1);
        let max_sessions =
            if policy.max_sessions == 0 { slots } else { policy.max_sessions.max(1) };
        let block_tokens = BLOCK_TOKENS.min(engine.max_len().max(1));
        let per_session = engine.max_len().div_ceil(block_tokens);
        let capacity = if max_sessions > slots {
            (max_sessions - slots + 1) * per_session.max(1)
        } else {
            0 // sessions ≤ slots: every session can stay resident
        };
        let pool = BlockPool::new(capacity, block_tokens, engine.kv_row_width());
        SessionManager::new(max_sessions, pool)
    }

    pub fn contains(&self, id: u64) -> bool {
        self.sessions.contains_key(&id)
    }

    /// Number of open logical sessions.
    pub fn active(&self) -> usize {
        self.sessions.len()
    }

    /// Room for another logical session?
    pub fn can_open(&self) -> bool {
        self.sessions.len() < self.max_sessions
    }

    /// Committed KV rows of a session (0 for unknown ids).
    pub fn len_of(&self, id: u64) -> usize {
        self.sessions.get(&id).map_or(0, |s| s.len)
    }

    /// The engine slot of a resident session.
    pub fn slot_of(&self, id: u64) -> Option<usize> {
        match self.sessions.get(&id)?.state {
            SessionState::Resident { slot } => Some(slot),
            _ => None,
        }
    }

    pub fn stats(&self) -> &SwapStats {
        &self.stats
    }

    pub fn free_blocks(&self) -> usize {
        self.pool.free_blocks()
    }

    pub fn block_capacity(&self) -> usize {
        self.pool.capacity()
    }

    /// Open a logical session (no slot is claimed yet — the first
    /// `ensure_resident` call does that).
    pub fn open(&mut self, id: u64) -> Result<()> {
        if self.sessions.contains_key(&id) {
            bail!("session {id} already open");
        }
        if !self.can_open() {
            bail!("session table full ({} of {})", self.sessions.len(), self.max_sessions);
        }
        self.clock += 1;
        self.sessions.insert(
            id,
            Session {
                state: SessionState::Parked { table: BlockTable::empty() },
                len: 0,
                last_used: self.clock,
            },
        );
        Ok(())
    }

    /// Close a session, returning its slot or pool blocks. Unknown ids
    /// are a no-op (a release may race a session that never offloaded).
    pub fn close<E: BatchEngine>(&mut self, id: u64, engine: &mut E) {
        let Some(sess) = self.sessions.remove(&id) else { return };
        match sess.state {
            SessionState::Resident { slot } => engine.free_slot(slot),
            SessionState::Parked { table } => self.pool.release(table),
            SessionState::Swapping => unreachable!("close during an in-flight swap"),
        }
    }

    /// Record `n` freshly committed rows (after an engine call).
    pub fn note_rows(&mut self, id: u64, n: usize) {
        if let Some(s) = self.sessions.get_mut(&id) {
            s.len += n;
        }
    }

    /// Set the committed length (verification rollback).
    pub fn set_len(&mut self, id: u64, len: usize) {
        if let Some(s) = self.sessions.get_mut(&id) {
            s.len = len;
        }
    }

    /// Make `id` resident and return its slot, swapping a parked
    /// session in over the LRU victim if every slot is claimed.
    /// Sessions in `pinned` (already picked this iteration) are never
    /// evicted. Returns `Ok(None)` when no slot can be freed — the
    /// caller skips the job this iteration and lets it age.
    pub fn ensure_resident<E: BatchEngine>(
        &mut self,
        id: u64,
        engine: &mut E,
        pinned: &HashSet<u64>,
    ) -> Result<Option<usize>> {
        self.clock += 1;
        let clock = self.clock;
        {
            let Some(sess) = self.sessions.get_mut(&id) else {
                bail!("ensure_resident of unknown session {id}");
            };
            if let SessionState::Resident { slot } = sess.state {
                sess.last_used = clock;
                return Ok(Some(slot));
            }
        }
        if engine.free_slots() == 0 {
            // Swap-cost-aware LRU: gather the EVICT_CANDIDATES least
            // recently scheduled unpinned resident sessions, then park
            // the one with the fewest committed KV rows — it is the
            // cheapest to swap back in when its next round arrives.
            // (Stable (last_used, id) ordering: HashMap iteration order
            // must not leak into policy.)
            let mut cands: Vec<(u64, u64, usize)> = self
                .sessions
                .iter()
                .filter(|(vid, s)| {
                    !pinned.contains(vid) && matches!(s.state, SessionState::Resident { .. })
                })
                .map(|(&vid, s)| (s.last_used, vid, s.len))
                .collect();
            cands.sort_unstable_by_key(|&(used, vid, _)| (used, vid));
            let window = EVICT_CANDIDATES.min(cands.len().div_ceil(2)).max(1);
            cands.truncate(window);
            let victim = cands
                .iter()
                .min_by_key(|&&(used, vid, len)| (len, used, vid))
                .map(|&(_, vid, _)| vid);
            let Some(vid) = victim else { return Ok(None) };
            if !self.park(vid, engine)? {
                return Ok(None); // host pool exhausted; retry next tick
            }
        }
        let t0 = Instant::now();
        let sess = self.sessions.get_mut(&id).expect("looked up above");
        let state = std::mem::replace(&mut sess.state, SessionState::Swapping);
        let SessionState::Parked { table } = state else {
            unreachable!("non-resident session must be parked");
        };
        let slot = engine.alloc_slot(SlotOwner::Request(id)).expect("slot freed above");
        if table.len > 0 {
            let kv = self.pool.load(&table);
            self.stats.bytes_in += kv.bytes() as u64;
            self.stats.swap_ins += 1;
            let (rows, bytes) = (kv.len as f64, kv.bytes() as f64);
            if let Err(e) = engine.import_slot(slot, &kv) {
                // roll the half-swap back: return the slot, keep the
                // parked image authoritative (no stranded Swapping
                // state, no leaked blocks)
                engine.free_slot(slot);
                self.sessions.get_mut(&id).expect("still present").state =
                    SessionState::Parked { table };
                return Err(e);
            }
            if self.trace.is_some() {
                let tid = self.trace_tid;
                let wall = t0.elapsed().as_secs_f64();
                trace::with(&self.trace, |s| {
                    // the analyzer's paging attribution reads `s`; a
                    // deterministic (virtual-clock) sink zeroes it like
                    // every other wall duration
                    let secs = if s.is_deterministic() { 0.0 } else { wall };
                    let args = vec![("rows", rows), ("bytes", bytes), ("s", secs)];
                    s.instant(PID_CLOUD, tid, "swap_in", id, args)
                });
            }
        }
        self.pool.release(table);
        let sess = self.sessions.get_mut(&id).expect("still present");
        sess.state = SessionState::Resident { slot };
        sess.last_used = clock;
        self.stats.swap_s += t0.elapsed().as_secs_f64();
        Ok(Some(slot))
    }

    /// Remove a session and hand back its committed KV image — the
    /// swap-out half of a cross-replica migration. The slot or pool
    /// blocks it held are returned to this manager; the caller owns the
    /// bytes (typically to `import` them on another replica's manager).
    pub fn export<E: BatchEngine>(&mut self, id: u64, engine: &mut E) -> Result<SlotKv> {
        let Some(sess) = self.sessions.remove(&id) else {
            bail!("export of unknown session {id}");
        };
        match sess.state {
            SessionState::Resident { slot } => {
                let kv = engine.export_slot(slot);
                debug_assert_eq!(kv.len, sess.len, "engine/session committed-length divergence");
                engine.free_slot(slot);
                Ok(kv)
            }
            SessionState::Parked { table } => {
                let kv = self.pool.load(&table);
                self.pool.release(table);
                Ok(kv)
            }
            SessionState::Swapping => unreachable!("export during an in-flight swap"),
        }
    }

    /// Can this manager accept an imported session of `rows` committed
    /// rows right now — a free engine slot, or enough pool blocks to
    /// park it — without evicting anything?
    pub fn can_import<E: BatchEngine>(&self, rows: usize, engine: &E) -> bool {
        self.can_open()
            && (engine.free_slots() > 0 || self.pool.free_blocks() >= self.pool.blocks_for(rows))
    }

    /// Adopt a migrated session: land its KV in a free engine slot when
    /// one exists, else park it in the host pool. Never evicts — the
    /// router checks [`SessionManager::can_import`] first, and a failed
    /// import leaves this manager untouched so the source replica can
    /// restore the session.
    pub fn import<E: BatchEngine>(&mut self, id: u64, kv: &SlotKv, engine: &mut E) -> Result<()> {
        if self.sessions.contains_key(&id) {
            bail!("import of already-open session {id}");
        }
        if !self.can_open() {
            bail!("session table full ({} of {})", self.sessions.len(), self.max_sessions);
        }
        self.clock += 1;
        let state = if engine.free_slots() > 0 {
            let slot = engine.alloc_slot(SlotOwner::Request(id)).expect("free slot checked");
            if kv.len > 0 {
                if let Err(e) = engine.import_slot(slot, kv) {
                    engine.free_slot(slot);
                    return Err(e);
                }
            }
            SessionState::Resident { slot }
        } else if self.pool.free_blocks() >= self.pool.blocks_for(kv.len) {
            SessionState::Parked { table: self.pool.store(kv)? }
        } else {
            bail!("no slot and no pool room for an imported session of {} rows", kv.len);
        };
        self.sessions
            .insert(id, Session { state, len: kv.len, last_used: self.clock });
        Ok(())
    }

    /// Swap a resident session's KV out to the host pool and free its
    /// slot. Returns `false` (session left resident) when the pool
    /// cannot hold the rows.
    fn park<E: BatchEngine>(&mut self, id: u64, engine: &mut E) -> Result<bool> {
        let t0 = Instant::now();
        let Some(sess) = self.sessions.get_mut(&id) else {
            bail!("park of unknown session {id}");
        };
        let SessionState::Resident { slot } = sess.state else {
            bail!("park of non-resident session {id}");
        };
        // capacity check before the (potentially large) export copy —
        // the committed length is known without touching the engine
        if self.pool.free_blocks() < self.pool.blocks_for(sess.len) {
            return Ok(false);
        }
        let kv = engine.export_slot(slot);
        debug_assert_eq!(kv.len, sess.len, "engine/session committed-length divergence");
        sess.state = SessionState::Swapping;
        let table = match self.pool.store(&kv) {
            Ok(table) => table,
            Err(e) => {
                // undo the half-swap: the session stays resident
                self.sessions.get_mut(&id).expect("still present").state =
                    SessionState::Resident { slot };
                return Err(e);
            }
        };
        engine.free_slot(slot);
        self.stats.swap_outs += 1;
        self.stats.bytes_out += kv.bytes() as u64;
        self.stats.swap_s += t0.elapsed().as_secs_f64();
        if self.trace.is_some() {
            let tid = self.trace_tid;
            let wall = t0.elapsed().as_secs_f64();
            let (rows, bytes) = (kv.len as f64, kv.bytes() as f64);
            trace::with(&self.trace, |s| {
                let secs = if s.is_deterministic() { 0.0 } else { wall };
                let args = vec![("rows", rows), ("bytes", bytes), ("s", secs)];
                s.instant(PID_CLOUD, tid, "swap_out", id, args)
            });
        }
        self.sessions.get_mut(&id).expect("still present").state =
            SessionState::Parked { table };
        Ok(true)
    }
}
