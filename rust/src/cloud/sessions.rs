//! Logical-session manager: many sessions over B compute slots.
//!
//! The compiled cloud executables fix the batch width (B=4 slots), but
//! paper-scale serving (§Scalable Cloud Batching, Fig. 15) needs far
//! more *concurrent device sessions* than that. This module decouples
//! the two: a [`SessionManager`] tracks every admitted session as
//!
//! * **Resident** — owns an engine slot; its KV lives in the engine
//!   cache and it can be scheduled this iteration;
//! * **Parked** — its committed KV rows sit in a host-side
//!   [`BlockPool`] (see [`crate::runtime::paging`]) under a block
//!   table; it holds no slot;
//! * **Swapping** — transient marker while rows are mid-copy (never
//!   observable between manager calls).
//!
//! Before each scheduler iteration, sessions picked for execution are
//! made resident on demand: if no slot is free, a resident session is
//! *parked* (swap-out via `BatchEngine::export_slot`), its slot is
//! reassigned, and the target session's rows are restored
//! (`import_slot`). The victim is **swap-cost-aware LRU**: among the
//! least-recently-scheduled resident sessions (a window capped at
//! [`EVICT_CANDIDATES`] and at half the resident set), the one with
//! the fewest **private** committed KV rows is parked — shared prefix
//! rows never move on a swap, so only the private tail costs copy
//! bytes. Sessions **pinned** by the current iteration's picks are
//! never eviction victims, so a tick can never swap out work it is
//! about to run. Swap traffic and copy time are charged to
//! [`SwapStats`] (and surfaced through the scheduler's Fig. 18
//! overhead accounting, since swaps happen outside engine compute).
//!
//! **Shared-prefix cache** (opt-in via `BatchPolicy::prefix_cache`):
//! the manager owns a [`PrefixIndex`] over the pool. At admission
//! ([`SessionManager::open_with_prompt`]) the incoming prompt is
//! radix-matched and every fully-covered prefix block is mapped to an
//! existing shared block (refcount++, zero prefill — the scheduler
//! starts the prefill chunk at the first unmatched token). Sessions
//! keep their shared references across parks and swap-ins; a shared
//! block is reclaimable only at refcount 0. At park time, full private
//! blocks with known token history are offered to the index so later
//! admissions can share them (identical chains dedup onto one physical
//! block). Shared blocks are immutable: any truncation into shared
//! territory goes through [`BlockPool::cow`]. With the cache off
//! (default) every path below is bit-identical to plain private
//! paging.
//!
//! Concurrency is therefore bounded by `max_sessions` (host memory),
//! not by the compiled batch width — the Fig. 15 latency knee moves
//! from B to `max_sessions`, and prefix sharing moves the *host
//! memory* knee out again by the shared fraction (Fig. 15d).

use std::collections::{HashMap, HashSet};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::BatchPolicy;
use crate::model::cloud_engine::{BatchEngine, SlotOwner};
use crate::obs::trace::{self, TraceShared, PID_CLOUD};
use crate::runtime::paging::{BlockPool, BlockTable};
use crate::runtime::prefix::{chain_hash, Inserted, PrefixIndex, PrefixStats, ROOT};
use crate::runtime::SlotKv;

/// Token rows per host KV block (vLLM-style fixed granularity).
pub const BLOCK_TOKENS: usize = 16;

/// Eviction candidate window cap: the victim is the **cheapest to
/// swap** (fewest private committed KV rows) among the
/// least-recently-scheduled resident sessions. The effective window is
/// `min(EVICT_CANDIDATES, ⌈residents/2⌉)` — `1` would be pure LRU, and
/// bounding by half the resident set guarantees the most recently
/// scheduled half is always recency-protected (otherwise, on a B=4
/// engine, a short hot session could be swap-thrashed on alternating
/// ticks while large idle sessions stay resident). A small window
/// trades a little recency precision for much smaller swap copies
/// (ROADMAP "swap-cost-aware eviction").
pub const EVICT_CANDIDATES: usize = 4;

#[derive(Debug)]
enum SessionState {
    /// Owns engine slot `slot`; KV lives in the engine cache.
    Resident { slot: usize },
    /// Private-tail KV parked in the host block pool (empty table for
    /// new sessions; shared prefix blocks are tracked separately).
    Parked { table: BlockTable },
    /// Transient mid-swap marker.
    Swapping,
}

#[derive(Debug)]
struct Session {
    state: SessionState,
    /// Committed KV rows (mirrors the engine `slot_len` while resident).
    len: usize,
    /// LRU stamp — bumped whenever the session is granted a slot or
    /// scheduled; the eviction victim is the smallest stamp.
    last_used: u64,
    /// Rows `[0, shared_len)` live in `shared_blocks` (block-aligned;
    /// always 0 with the prefix cache off).
    shared_len: usize,
    /// Shared prefix blocks, one pool reference each, held from match
    /// (or park-time indexing) until close/export.
    shared_blocks: Vec<usize>,
    /// Committed token ids (tracked only with the cache enabled —
    /// block identity is a function of token history).
    tokens: Vec<u32>,
}

impl Session {
    /// Committed rows not covered by shared blocks — the only rows a
    /// park must copy.
    fn private_rows(&self) -> usize {
        self.len - self.shared_len
    }
}

/// Swap-traffic accounting (paged-KV cost visibility). With prefix
/// sharing, `bytes_out` counts only the **private** rows actually
/// copied on a swap-out; swap-ins copy the full materialised image
/// into the slot.
#[derive(Debug, Clone, Default)]
pub struct SwapStats {
    pub swap_ins: u64,
    pub swap_outs: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Host copy seconds across all swaps.
    pub swap_s: f64,
}

/// Tracks logical sessions and pages their KV between engine slots and
/// the host [`BlockPool`]. Eviction is swap-cost-aware
/// LRU-with-pinning: the fewest-private-rows session among the least
/// recently scheduled residents (window capped at [`EVICT_CANDIDATES`]
/// and at half the resident set) is parked, but never one the current
/// iteration has already picked.
pub struct SessionManager {
    pool: BlockPool,
    sessions: HashMap<u64, Session>,
    clock: u64,
    /// Admission cap on concurrent logical sessions.
    pub max_sessions: usize,
    stats: SwapStats,
    /// Shared-prefix index (`None` = cache off, zero behaviour change).
    prefix: Option<PrefixIndex>,
    pstats: PrefixStats,
    /// Swap-event trace sink shared with the owning scheduler
    /// ([`crate::cloud::scheduler::Scheduler::set_trace`]).
    trace: Option<TraceShared>,
    trace_tid: u32,
}

impl SessionManager {
    pub fn new(max_sessions: usize, pool: BlockPool) -> SessionManager {
        SessionManager {
            pool,
            sessions: HashMap::new(),
            clock: 0,
            max_sessions: max_sessions.max(1),
            stats: SwapStats::default(),
            prefix: None,
            pstats: PrefixStats::default(),
            trace: None,
            trace_tid: 0,
        }
    }

    /// Attach (or detach) the trace sink swap events are recorded to;
    /// `tid` is the owning replica's cloud-track thread.
    pub fn set_trace(&mut self, trace: Option<TraceShared>, tid: u32) {
        self.trace = trace;
        self.trace_tid = tid;
    }

    /// Turn the shared-prefix cache on (block geometry follows the
    /// pool). Idempotent; meant to be called before any session opens.
    pub fn enable_prefix_cache(&mut self) {
        if self.prefix.is_none() {
            self.prefix = Some(PrefixIndex::new(self.pool.block_tokens()));
        }
    }

    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Prefix-cache hit/miss/CoW counters (zeros when the cache is off).
    pub fn prefix_stats(&self) -> PrefixStats {
        self.pstats
    }

    /// Size a manager for `engine` under `policy`: `max_sessions == 0`
    /// means "the physical slot count" (paging never triggers, pool is
    /// empty); above the slot count, the pool capacity covers the worst
    /// case — every non-resident session parked at full length, plus
    /// one mid-swap victim — so swap-outs cannot fail. With
    /// `policy.prefix_cache` the cap gains headroom for index-retained
    /// chains (the index is trimmed under pressure before any park
    /// gives up). The capacity is only a cap: block storage
    /// materialises lazily as sessions actually park, so an oversized
    /// pool costs no host memory up front.
    pub fn for_engine<E: BatchEngine>(engine: &E, policy: &BatchPolicy) -> SessionManager {
        let slots = engine.slots().max(1);
        let max_sessions =
            if policy.max_sessions == 0 { slots } else { policy.max_sessions.max(1) };
        let block_tokens = BLOCK_TOKENS.min(engine.max_len().max(1));
        let per_session = engine.max_len().div_ceil(block_tokens);
        let capacity = if max_sessions > slots {
            let base = (max_sessions - slots + 1) * per_session.max(1);
            if policy.prefix_cache {
                base + slots * per_session.max(1)
            } else {
                base
            }
        } else {
            0 // sessions ≤ slots: every session can stay resident
        };
        let pool = BlockPool::new(capacity, block_tokens, engine.kv_row_width());
        let mut mgr = SessionManager::new(max_sessions, pool);
        if policy.prefix_cache {
            mgr.enable_prefix_cache();
        }
        mgr
    }

    pub fn contains(&self, id: u64) -> bool {
        self.sessions.contains_key(&id)
    }

    /// Number of open logical sessions.
    pub fn active(&self) -> usize {
        self.sessions.len()
    }

    /// Room for another logical session?
    pub fn can_open(&self) -> bool {
        self.sessions.len() < self.max_sessions
    }

    /// Committed KV rows of a session (0 for unknown ids).
    pub fn len_of(&self, id: u64) -> usize {
        self.sessions.get(&id).map_or(0, |s| s.len)
    }

    /// Rows of a session covered by shared prefix blocks (0 for
    /// unknown ids or with the cache off).
    pub fn shared_len_of(&self, id: u64) -> usize {
        self.sessions.get(&id).map_or(0, |s| s.shared_len)
    }

    /// The engine slot of a resident session.
    pub fn slot_of(&self, id: u64) -> Option<usize> {
        match self.sessions.get(&id)?.state {
            SessionState::Resident { slot } => Some(slot),
            _ => None,
        }
    }

    pub fn stats(&self) -> &SwapStats {
        &self.stats
    }

    pub fn free_blocks(&self) -> usize {
        self.pool.free_blocks()
    }

    pub fn block_capacity(&self) -> usize {
        self.pool.capacity()
    }

    /// Pool blocks currently referenced (shared blocks count once —
    /// the host-memory footprint the Fig. 15d sweep measures).
    pub fn blocks_in_use(&self) -> usize {
        self.pool.capacity() - self.pool.free_blocks()
    }

    /// Open a logical session (no slot is claimed yet — the first
    /// `ensure_resident` call does that).
    pub fn open(&mut self, id: u64) -> Result<()> {
        if self.sessions.contains_key(&id) {
            bail!("session {id} already open");
        }
        if !self.can_open() {
            bail!("session table full ({} of {})", self.sessions.len(), self.max_sessions);
        }
        self.clock += 1;
        self.sessions.insert(
            id,
            Session {
                state: SessionState::Parked { table: BlockTable::empty() },
                len: 0,
                last_used: self.clock,
                shared_len: 0,
                shared_blocks: Vec::new(),
                tokens: Vec::new(),
            },
        );
        Ok(())
    }

    /// Open a session and radix-match its prompt against the prefix
    /// index. Every fully-covered prefix block becomes a shared
    /// reference (refcount++, zero prefill); the session starts with
    /// `matched` committed rows and the caller's prefill begins at the
    /// first unmatched token. Matching is capped at `prompt.len() - 1`
    /// so at least one token always remains for the engine to execute
    /// (both prefill and verify need a live row to produce logits).
    /// Returns the matched row count — always 0 with the cache off,
    /// where this is exactly [`SessionManager::open`].
    pub fn open_with_prompt(&mut self, id: u64, prompt: &[u32]) -> Result<usize> {
        self.open(id)?;
        let Some(idx) = self.prefix.as_mut() else { return Ok(0) };
        let hits = if prompt.len() < 2 {
            Vec::new()
        } else {
            idx.match_prefix(prompt, prompt.len() - 1)
        };
        if hits.is_empty() {
            self.pstats.misses += 1;
            return Ok(0);
        }
        let matched = hits.len() * self.pool.block_tokens();
        for h in &hits {
            self.pool.share(h.block);
        }
        self.pstats.hits += 1;
        self.pstats.hit_rows += matched as u64;
        let sess = self.sessions.get_mut(&id).expect("opened above");
        sess.shared_blocks = hits.iter().map(|h| h.block).collect();
        sess.shared_len = matched;
        sess.len = matched;
        sess.tokens = prompt[..matched].to_vec();
        Ok(matched)
    }

    /// Close a session, returning its slot or pool blocks. Shared
    /// references are dropped; a shared block is reclaimed only when
    /// the index and every other session have also dropped it. Unknown
    /// ids are a no-op (a release may race a session that never
    /// offloaded).
    pub fn close<E: BatchEngine>(&mut self, id: u64, engine: &mut E) {
        let Some(sess) = self.sessions.remove(&id) else { return };
        match sess.state {
            SessionState::Resident { slot } => engine.free_slot(slot),
            SessionState::Parked { table } => self.pool.release(table),
            SessionState::Swapping => unreachable!("close during an in-flight swap"),
        }
        for blk in sess.shared_blocks {
            self.pool.unref(blk);
        }
    }

    /// Record `n` freshly committed rows (after an engine call).
    pub fn note_rows(&mut self, id: u64, n: usize) {
        if let Some(s) = self.sessions.get_mut(&id) {
            s.len += n;
        }
    }

    /// Record the token ids behind freshly committed rows — block
    /// identity is a function of token history, so the prefix cache
    /// can only index blocks whose tokens are fully known. No-op with
    /// the cache off.
    pub fn note_tokens(&mut self, id: u64, tokens: &[u32]) {
        if self.prefix.is_none() {
            return;
        }
        if let Some(s) = self.sessions.get_mut(&id) {
            s.tokens.extend_from_slice(tokens);
        }
    }

    /// Set the committed length (verification rollback). Truncating
    /// into shared territory drops the references on no-longer-covered
    /// shared blocks; a parked session whose surviving rows end midway
    /// through a shared block privatises that boundary block via
    /// copy-on-write, so the shared original stays bit-identical for
    /// its other holders. (The scheduler only rolls back *resident*
    /// sessions and never below the verified prefix, so the CoW branch
    /// is a correctness backstop, not a hot path.)
    pub fn set_len(&mut self, id: u64, len: usize) {
        let Some(s) = self.sessions.get_mut(&id) else { return };
        s.len = len;
        s.tokens.truncate(len);
        if len >= s.shared_len {
            return;
        }
        let bt = self.pool.block_tokens();
        let keep = len / bt; // full shared blocks still covered
        let boundary = len - keep * bt;
        // blocks wholly past `len` lose their session reference
        let dropped = s.shared_blocks.split_off(keep + usize::from(boundary > 0));
        if let SessionState::Parked { table } = &mut s.state {
            // every private-tail row sat at ≥ old shared_len > len: gone
            let old = std::mem::take(table);
            if boundary > 0 {
                // rows [keep*bt, len) live in the boundary shared
                // block — privatise it (CoW) into the new table. If
                // the pool is exhausted the block id moves into the
                // table still shared: parked tables are never written
                // in place, so aliasing is read-only and safe.
                let blk = s.shared_blocks.pop().expect("boundary block");
                let owned = match self.pool.cow(blk) {
                    Ok((fresh, copied)) => {
                        if copied {
                            self.pstats.cow_copies += 1;
                        }
                        fresh
                    }
                    Err(_) => blk,
                };
                *table = BlockTable { blocks: vec![owned], len: boundary };
            }
            for b in old.blocks {
                self.pool.unref(b);
            }
        } else if boundary > 0 {
            // resident: the surviving rows live in the slot; the
            // boundary block is no longer fully covered and cannot
            // stay in the shared prefix
            let blk = s.shared_blocks.pop().expect("boundary block");
            self.pool.unref(blk);
        }
        for b in dropped {
            self.pool.unref(b);
        }
        s.shared_len = s.shared_blocks.len() * bt;
    }

    /// Make `id` resident and return its slot, swapping a parked
    /// session in over the LRU victim if every slot is claimed.
    /// Sessions in `pinned` (already picked this iteration) are never
    /// evicted. Returns `Ok(None)` when no slot can be freed — the
    /// caller skips the job this iteration and lets it age.
    pub fn ensure_resident<E: BatchEngine>(
        &mut self,
        id: u64,
        engine: &mut E,
        pinned: &HashSet<u64>,
    ) -> Result<Option<usize>> {
        self.clock += 1;
        let clock = self.clock;
        {
            let Some(sess) = self.sessions.get_mut(&id) else {
                bail!("ensure_resident of unknown session {id}");
            };
            if let SessionState::Resident { slot } = sess.state {
                sess.last_used = clock;
                return Ok(Some(slot));
            }
        }
        if engine.free_slots() == 0 {
            // Swap-cost-aware LRU: gather the EVICT_CANDIDATES least
            // recently scheduled unpinned resident sessions, then park
            // the one with the fewest **private** committed KV rows —
            // shared prefix rows never move on a swap, so it is the
            // cheapest to copy out now and back in later. (Stable
            // (last_used, id) ordering: HashMap iteration order must
            // not leak into policy.)
            let mut cands: Vec<(u64, u64, usize)> = self
                .sessions
                .iter()
                .filter(|(vid, s)| {
                    !pinned.contains(vid) && matches!(s.state, SessionState::Resident { .. })
                })
                .map(|(&vid, s)| (s.last_used, vid, s.private_rows()))
                .collect();
            cands.sort_unstable_by_key(|&(used, vid, _)| (used, vid));
            let window = EVICT_CANDIDATES.min(cands.len().div_ceil(2)).max(1);
            cands.truncate(window);
            let victim = cands
                .iter()
                .min_by_key(|&&(used, vid, priv_rows)| (priv_rows, used, vid))
                .map(|&(_, vid, _)| vid);
            let Some(vid) = victim else { return Ok(None) };
            if !self.park(vid, engine)? {
                return Ok(None); // host pool exhausted; retry next tick
            }
        }
        let t0 = Instant::now();
        let sess = self.sessions.get_mut(&id).expect("looked up above");
        let state = std::mem::replace(&mut sess.state, SessionState::Swapping);
        let SessionState::Parked { table } = state else {
            unreachable!("non-resident session must be parked");
        };
        let slot = engine.alloc_slot(SlotOwner::Request(id)).expect("slot freed above");
        if sess.len > 0 {
            // materialise shared prefix + private tail into one image
            let kv = if sess.shared_blocks.is_empty() {
                self.pool.load(&table)
            } else {
                let mut blocks = sess.shared_blocks.clone();
                blocks.extend_from_slice(&table.blocks);
                self.pool.load_blocks(&blocks, sess.len)
            };
            self.stats.bytes_in += kv.bytes() as u64;
            self.stats.swap_ins += 1;
            let (rows, bytes) = (kv.len as f64, kv.bytes() as f64);
            if let Err(e) = engine.import_slot(slot, &kv) {
                // roll the half-swap back: return the slot, keep the
                // parked image authoritative (no stranded Swapping
                // state, no leaked blocks)
                engine.free_slot(slot);
                self.sessions.get_mut(&id).expect("still present").state =
                    SessionState::Parked { table };
                return Err(e);
            }
            if self.trace.is_some() {
                let tid = self.trace_tid;
                let wall = t0.elapsed().as_secs_f64();
                trace::with(&self.trace, |s| {
                    // the analyzer's paging attribution reads `s`; a
                    // deterministic (virtual-clock) sink zeroes it like
                    // every other wall duration
                    let secs = if s.is_deterministic() { 0.0 } else { wall };
                    let args = vec![("rows", rows), ("bytes", bytes), ("s", secs)];
                    s.instant(PID_CLOUD, tid, "swap_in", id, args)
                });
            }
        }
        self.pool.release(table);
        let sess = self.sessions.get_mut(&id).expect("still present");
        sess.state = SessionState::Resident { slot };
        sess.last_used = clock;
        self.stats.swap_s += t0.elapsed().as_secs_f64();
        Ok(Some(slot))
    }

    /// Remove a session and hand back its committed KV image — the
    /// swap-out half of a cross-replica migration. The image is a
    /// fresh deep copy (shared prefix rows are **materialised**, never
    /// aliased across replicas — block identity stops at this
    /// manager's pool); the slot, pool blocks and shared references it
    /// held are returned to this manager, and the caller owns the
    /// bytes (typically to `import` them on another replica's
    /// manager).
    pub fn export<E: BatchEngine>(&mut self, id: u64, engine: &mut E) -> Result<SlotKv> {
        let Some(sess) = self.sessions.remove(&id) else {
            bail!("export of unknown session {id}");
        };
        let kv = match sess.state {
            SessionState::Resident { slot } => {
                let kv = engine.export_slot(slot);
                debug_assert_eq!(kv.len, sess.len, "engine/session committed-length divergence");
                engine.free_slot(slot);
                kv
            }
            SessionState::Parked { table } => {
                let kv = if sess.shared_blocks.is_empty() {
                    self.pool.load(&table)
                } else {
                    let mut blocks = sess.shared_blocks.clone();
                    blocks.extend_from_slice(&table.blocks);
                    self.pool.load_blocks(&blocks, sess.len)
                };
                self.pool.release(table);
                kv
            }
            SessionState::Swapping => unreachable!("export during an in-flight swap"),
        };
        for blk in sess.shared_blocks {
            self.pool.unref(blk);
        }
        Ok(kv)
    }

    /// Can this manager accept an imported session of `rows` committed
    /// rows right now — a free engine slot, or enough pool blocks to
    /// park it — without evicting anything?
    pub fn can_import<E: BatchEngine>(&self, rows: usize, engine: &E) -> bool {
        self.can_open()
            && (engine.free_slots() > 0 || self.pool.free_blocks() >= self.pool.blocks_for(rows))
    }

    /// Adopt a migrated session: land its KV in a free engine slot when
    /// one exists, else park it in the host pool. The adopted KV is
    /// fully private — token history did not ride the wire, so the
    /// rows have no content identity here and are never offered to the
    /// prefix index. Never evicts — the router checks
    /// [`SessionManager::can_import`] first, and a failed import
    /// leaves this manager untouched so the source replica can restore
    /// the session.
    pub fn import<E: BatchEngine>(&mut self, id: u64, kv: &SlotKv, engine: &mut E) -> Result<()> {
        if self.sessions.contains_key(&id) {
            bail!("import of already-open session {id}");
        }
        if !self.can_open() {
            bail!("session table full ({} of {})", self.sessions.len(), self.max_sessions);
        }
        self.clock += 1;
        let state = if engine.free_slots() > 0 {
            let slot = engine.alloc_slot(SlotOwner::Request(id)).expect("free slot checked");
            if kv.len > 0 {
                if let Err(e) = engine.import_slot(slot, kv) {
                    engine.free_slot(slot);
                    return Err(e);
                }
            }
            SessionState::Resident { slot }
        } else if self.pool.free_blocks() >= self.pool.blocks_for(kv.len) {
            SessionState::Parked { table: self.pool.store(kv)? }
        } else {
            bail!("no slot and no pool room for an imported session of {} rows", kv.len);
        };
        self.sessions.insert(
            id,
            Session {
                state,
                len: kv.len,
                last_used: self.clock,
                shared_len: 0,
                shared_blocks: Vec::new(),
                tokens: Vec::new(),
            },
        );
        Ok(())
    }

    /// Swap a resident session's KV out to the host pool and free its
    /// slot. Only the **private tail** (rows past the shared prefix)
    /// is copied and charged to [`SwapStats`] — shared blocks already
    /// live in the pool. With the prefix cache on, freshly parked full
    /// private blocks whose token history is known are offered to the
    /// index so the next admission with this prefix matches them.
    /// Returns `false` (session left resident) when the pool cannot
    /// hold the rows.
    fn park<E: BatchEngine>(&mut self, id: u64, engine: &mut E) -> Result<bool> {
        let t0 = Instant::now();
        let (slot, shared_len, need) = {
            let Some(sess) = self.sessions.get(&id) else {
                bail!("park of unknown session {id}");
            };
            let SessionState::Resident { slot } = sess.state else {
                bail!("park of non-resident session {id}");
            };
            (slot, sess.shared_len, self.pool.blocks_for(sess.private_rows()))
        };
        // capacity check before the (potentially large) export copy —
        // the private length is known without touching the engine
        if self.pool.free_blocks() < need {
            // shed cold index-only chains before giving up
            if let Some(idx) = self.prefix.as_mut() {
                idx.trim(&mut self.pool, need);
            }
            if self.pool.free_blocks() < need {
                return Ok(false);
            }
        }
        let kv = engine.export_slot(slot);
        let sess = self.sessions.get_mut(&id).expect("looked up above");
        debug_assert_eq!(kv.len, sess.len, "engine/session committed-length divergence");
        let tail = if shared_len > 0 { kv.tail(shared_len) } else { kv };
        sess.state = SessionState::Swapping;
        let table = match self.pool.store(&tail) {
            Ok(table) => table,
            Err(e) => {
                // undo the half-swap: the session stays resident
                self.sessions.get_mut(&id).expect("still present").state =
                    SessionState::Resident { slot };
                return Err(e);
            }
        };
        engine.free_slot(slot);
        self.stats.swap_outs += 1;
        self.stats.bytes_out += tail.bytes() as u64;
        self.stats.swap_s += t0.elapsed().as_secs_f64();
        if self.trace.is_some() {
            let tid = self.trace_tid;
            let wall = t0.elapsed().as_secs_f64();
            let (rows, bytes) = (tail.len as f64, tail.bytes() as f64);
            trace::with(&self.trace, |s| {
                let secs = if s.is_deterministic() { 0.0 } else { wall };
                let args = vec![("rows", rows), ("bytes", bytes), ("s", secs)];
                s.instant(PID_CLOUD, tid, "swap_out", id, args)
            });
        }
        let table = self.index_parked_blocks(id, table);
        self.sessions.get_mut(&id).expect("still present").state =
            SessionState::Parked { table };
        Ok(true)
    }

    /// Offer the full private blocks of a freshly parked table to the
    /// prefix index, reclassifying indexed blocks from the private
    /// table into the session's shared prefix. Returns the table of
    /// the remaining (unindexed) private tail. No-op with the cache
    /// off or when the session's token history is incomplete (e.g.
    /// migrated-in sessions, whose rows have no known identity).
    fn index_parked_blocks(&mut self, id: u64, mut table: BlockTable) -> BlockTable {
        let Some(idx) = self.prefix.as_mut() else { return table };
        let sess = self.sessions.get_mut(&id).expect("parking session");
        if sess.tokens.len() != sess.len {
            return table; // identity unknown — keep everything private
        }
        let bt = self.pool.block_tokens();
        // chain hash of the existing shared prefix, recomputed from
        // token history (cheap, and avoids carrying a stale cached
        // hash across truncations)
        let mut chain = ROOT;
        for b in 0..(sess.shared_len / bt) {
            chain = chain_hash(chain, &sess.tokens[b * bt..(b + 1) * bt]);
        }
        let full = table.len / bt; // trailing partial block stays private
        let mut moved = 0;
        while moved < full {
            let lo = sess.shared_len + moved * bt;
            let toks = &sess.tokens[lo..lo + bt];
            let blk = table.blocks[moved];
            match idx.insert(chain, toks, blk, &mut self.pool) {
                Inserted::New(h) => {
                    // the table's reference transfers to the shared
                    // set; the index took its own on insert
                    sess.shared_blocks.push(blk);
                    chain = h;
                }
                Inserted::Existing { hash, block } => {
                    // identical chain ⇒ identical KV rows from
                    // position 0: dedup onto the canonical block and
                    // drop our freshly stored copy
                    self.pool.share(block);
                    self.pool.unref(blk);
                    sess.shared_blocks.push(block);
                    chain = hash;
                }
                Inserted::Skipped => break,
            }
            moved += 1;
        }
        if moved > 0 {
            sess.shared_len += moved * bt;
            table.blocks.drain(..moved);
            table.len -= moved * bt;
        }
        table
    }
}
