//! Device runtime (paper §4.2–§4.4): selective token-level offloading,
//! progressive early exit, stall-free parallel inference and
//! distribution compression.

pub mod codec;
pub mod early_exit;
pub mod offload;
pub mod parallel;

pub use codec::compress_dist;
pub use early_exit::SeqExitPolicy;
pub use offload::{OffloadDecision, Selector};
pub use parallel::{predict_rejection, PiPlan};
