//! Selective token-level offloading (paper §4.2, Fig. 9/10).
//!
//! Two-stage dispatch for each γ-token draft chunk:
//!
//! 1. **Confidence (coarse)** — `P_conf(c)`: a scaled sigmoid over the
//!    chunk's mean confidence; chunks at or below `c_th` always pass to
//!    stage 2, confident chunks are increasingly retained locally.
//! 2. **Importance (fine)** — `P_imp(i)`: a three-tier scaled sigmoid
//!    over the chunk's mean importance with lower bound `i_th/2`
//!    (never offload) and upper bound `i_th` (always offload). `i_th`
//!    is the *budget knob*: the profiler maps a budget fraction to the
//!    corresponding percentile of the importance distribution.
//!
//! The dispatch draws come from the deterministic splitmix64 stream, so
//! experiments are reproducible.

use crate::config::SyneraParams;
use crate::util::rng::Rng;

/// Per-chunk offloading decision with its intermediate scores
/// (logged by the motivation benches, Fig. 4/5).
#[derive(Debug, Clone, Copy)]
pub struct OffloadDecision {
    pub offload: bool,
    pub p_conf: f64,
    pub p_imp: f64,
    pub mean_conf: f64,
    pub mean_imp: f64,
}

/// Stateful dispatcher for one device session.
pub struct Selector {
    /// Profiled coarse threshold (paper: 0.7–1.0; from profile.json).
    pub c_th: f64,
    /// Fine threshold = importance percentile at (1 − budget).
    pub i_th: f64,
    pub params: SyneraParams,
    rng: Rng,
}

impl Selector {
    pub fn new(c_th: f64, i_th: f64, params: SyneraParams) -> Selector {
        let seed = params.seed ^ 0x5E1E_C70F;
        Selector { c_th, i_th, params, rng: Rng::new(seed) }
    }

    /// `P_conf` (paper Eq. 1): 1 below the threshold, scaled sigmoid above.
    pub fn p_conf(&self, c: f64) -> f64 {
        if c <= self.c_th {
            return 1.0;
        }
        if self.c_th >= 1.0 {
            return 1.0;
        }
        let norm = (c - self.c_th) / (1.0 - self.c_th) - 0.5;
        1.0 / (1.0 + (self.params.k_conf * norm).exp())
    }

    /// `P_imp` (paper Eq. 2): 0 below `i_th/2`, 1 above `i_th`, scaled
    /// sigmoid (θ < 0, so increasing) in between.
    pub fn p_imp(&self, i: f64) -> f64 {
        let half = self.i_th / 2.0;
        if i <= half {
            return 0.0;
        }
        if i > self.i_th {
            return 1.0;
        }
        if half <= 0.0 {
            return 1.0;
        }
        let norm = (i - half) / half - 0.5;
        1.0 / (1.0 + (self.params.theta_imp * norm).exp())
    }

    /// Decide for one draft chunk. `confs`/`imps` are the per-draft-token
    /// confidence and accumulated-importance signals.
    pub fn decide(&mut self, confs: &[f32], imps: &[f32]) -> OffloadDecision {
        let n = confs.len().max(1) as f64;
        let mean_conf = confs.iter().map(|&x| x as f64).sum::<f64>() / n;
        let mean_imp = imps.iter().map(|&x| x as f64).sum::<f64>() / n;
        let p_conf = self.p_conf(mean_conf);
        let p_imp = self.p_imp(mean_imp);

        if self.params.random_offload {
            let offload = self.rng.f64() < self.params.budget;
            return OffloadDecision { offload, p_conf, p_imp, mean_conf, mean_imp };
        }
        let offload = match (self.params.use_conf, self.params.use_imp) {
            (true, true) => {
                // Fig. 10: coarse filter retains confident chunks; the
                // survivors get the fine-grained budgeted decision.
                self.rng.f64() < p_conf && self.rng.f64() < p_imp
            }
            (true, false) => self.rng.f64() < p_conf,
            (false, true) => self.rng.f64() < p_imp,
            (false, false) => false,
        };
        OffloadDecision { offload, p_conf, p_imp, mean_conf, mean_imp }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sel(c_th: f64, i_th: f64) -> Selector {
        Selector::new(c_th, i_th, SyneraParams::default())
    }

    #[test]
    fn p_conf_shape() {
        let s = sel(0.7, 1.0);
        assert_eq!(s.p_conf(0.3), 1.0);
        assert_eq!(s.p_conf(0.7), 1.0);
        assert!(s.p_conf(0.71) > 0.9); // continuous at the threshold
        assert!(s.p_conf(0.99) < 0.05); // confident → retained locally
        let mid = s.p_conf(0.85);
        assert!((mid - 0.5).abs() < 0.01, "{mid}"); // sigmoid midpoint
    }

    #[test]
    fn p_imp_three_tiers() {
        let s = sel(0.7, 2.0);
        assert_eq!(s.p_imp(0.9), 0.0); // ≤ i_th/2 stays local
        assert_eq!(s.p_imp(2.4), 1.0); // > i_th always offloads
        assert!(s.p_imp(1.05) < 0.05); // just above lower bound
        assert!(s.p_imp(1.99) > 0.9); // just below upper bound
        let mid = s.p_imp(1.5);
        assert!((mid - 0.5).abs() < 0.01, "{mid}");
    }

    #[test]
    fn p_imp_monotone() {
        let s = sel(0.7, 2.0);
        let mut prev = -1.0;
        for i in 0..100 {
            let x = i as f64 * 0.03;
            let p = s.p_imp(x);
            assert!(p >= prev - 1e-12, "non-monotone at {x}");
            prev = p;
        }
    }

    #[test]
    fn budget_zero_never_offloads_by_importance() {
        // i_th at the maximum importance → almost nothing exceeds it
        let mut s = sel(0.0, f64::MAX);
        // c > c_th=0 → p_conf < 1 but the imp stage gates everything
        let d = s.decide(&[0.5; 4], &[0.1; 4]);
        assert_eq!(d.p_imp, 0.0);
        assert!(!d.offload || d.p_imp > 0.0);
    }

    #[test]
    fn uncertain_and_important_chunks_offload() {
        let mut s = sel(0.7, 0.5);
        let mut n_off = 0;
        for _ in 0..200 {
            let d = s.decide(&[0.2; 4], &[0.9; 4]); // low conf, high imp
            n_off += d.offload as usize;
        }
        assert!(n_off > 190, "{n_off}"); // p_conf=1, p_imp=1
    }

    #[test]
    fn confident_chunks_stay_local() {
        let mut s = sel(0.7, 0.5);
        let mut n_off = 0;
        for _ in 0..200 {
            let d = s.decide(&[0.99; 4], &[0.9; 4]);
            n_off += d.offload as usize;
        }
        assert!(n_off < 10, "{n_off}"); // coarse filter retains
    }

    #[test]
    fn ablation_conf_only_ignores_importance() {
        let mut p = SyneraParams::default();
        p.use_imp = false;
        let mut s = Selector::new(0.7, 0.5, p);
        let mut n_off = 0;
        for _ in 0..200 {
            n_off += s.decide(&[0.2; 4], &[0.0; 4]).offload as usize;
        }
        assert!(n_off > 190); // low confidence alone triggers offload
    }

    #[test]
    fn decisions_deterministic_per_seed() {
        let mut a = sel(0.7, 1.0);
        let mut b = sel(0.7, 1.0);
        for i in 0..50 {
            let c = 0.5 + 0.3 * ((i % 7) as f32 / 7.0);
            let da = a.decide(&[c; 4], &[1.0; 4]);
            let db = b.decide(&[c; 4], &[1.0; 4]);
            assert_eq!(da.offload, db.offload);
        }
    }
}
