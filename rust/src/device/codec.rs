//! Compression before transmission (paper §4.2).
//!
//! Verification needs each draft token's device-side distribution
//! `p(x|·)`. Dense, that is `V` f32s per token (the paper's Llama vocab:
//! 32k floats, >50 ms at 10 Mbps). Because sampling was already
//! restricted to the intended strategy's candidate set, shipping only
//! the top-k entries is lossless *for verification*: any token outside
//! the set has `p = 0`, so the cloud's `q/p` acceptance test and the
//! `norm(max(0, q − p))` correction are unchanged. We ship
//! `(u16 id, f16 prob)` pairs — >98% smaller at our vocab, >99.5% at 32k.

use crate::model::logits::top_k;
use crate::net::wire::{f32_to_f16, Dist};

/// Compress a dense distribution to its top-k (the sampling strategy's
/// support). `k = 1` corresponds to greedy, larger k to top-k sampling.
pub fn compress_dist(probs: &[f32], k: usize) -> Dist {
    let idx = top_k(probs, k);
    Dist::TopK {
        ids: idx.iter().map(|&i| i as u16).collect(),
        probs_f16: idx.iter().map(|&i| f32_to_f16(probs[i])).collect(),
    }
}

/// The uncompressed wire form (ablation: Synera w/o compression).
pub fn dense_dist(probs: &[f32]) -> Dist {
    Dist::Dense(probs.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_preserves_head_of_distribution() {
        let mut p = vec![0.001f32; 500];
        p[42] = 0.5;
        p[7] = 0.3;
        let d = compress_dist(&p, 4);
        assert!((d.prob_of(42) - 0.5).abs() < 1e-3);
        assert!((d.prob_of(7) - 0.3).abs() < 1e-3);
        assert_eq!(d.prob_of(400), 0.0); // outside support → 0
    }

    #[test]
    fn greedy_k1_keeps_only_argmax() {
        let p = vec![0.1f32, 0.7, 0.2];
        match compress_dist(&p, 1) {
            Dist::TopK { ids, .. } => assert_eq!(ids, vec![1]),
            _ => panic!(),
        }
    }

    #[test]
    fn verification_equivalence_under_compression() {
        // acceptance test q/p and correction residual are unchanged for
        // tokens inside the support; outside, p=0 → auto-reject, which is
        // exactly the semantics of sampling restricted to the support.
        let mut p = vec![0.0f32; 16];
        p[3] = 0.6;
        p[5] = 0.4;
        let d = compress_dist(&p, 2);
        for t in [3u32, 5] {
            let q = 0.5f32;
            let dense_ratio = q / p[t as usize];
            let sparse_ratio = q / d.prob_of(t);
            assert!((dense_ratio - sparse_ratio).abs() < 2e-2);
        }
    }
}
