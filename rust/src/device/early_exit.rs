//! Progressive early exit — the sequence-wise half (paper §4.3).
//!
//! The *layer-wise* half lives in the engine ([`crate::model::device_engine`]:
//! split execution, margin threshold, deferred backfill); this module is
//! the sequence-level policy that disables cloud verification near the
//! tail of generation, where the SLM's trajectory is established.

/// Sequence-wise exit policy: offloading is disabled once the generation
/// step passes `frac × max_new` (paper: γ_seq = 0.8).
#[derive(Debug, Clone, Copy)]
pub struct SeqExitPolicy {
    pub frac: f64,
    pub max_new: usize,
    pub enabled: bool,
}

impl SeqExitPolicy {
    pub fn new(frac: f64, max_new: usize, enabled: bool) -> Self {
        SeqExitPolicy { frac, max_new, enabled }
    }

    /// May the device still offload at generation step `t` (0-based)?
    pub fn offload_allowed(&self, t: usize) -> bool {
        if !self.enabled {
            return true;
        }
        (t as f64) <= self.frac * self.max_new as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tail_disables_offloading() {
        let p = SeqExitPolicy::new(0.8, 20, true);
        assert!(p.offload_allowed(0));
        assert!(p.offload_allowed(16));
        assert!(!p.offload_allowed(17));
        assert!(!p.offload_allowed(19));
    }

    #[test]
    fn disabled_policy_always_allows() {
        let p = SeqExitPolicy::new(0.8, 20, false);
        assert!(p.offload_allowed(19));
    }
}
