//! Stall-free parallel inference (paper §4.4).
//!
//! While the cloud verifies a draft chunk, the device keeps generating
//! from a *predicted* post-verification prefix instead of stalling:
//!
//! 1. **Rejection position prediction** — sample `r*` from the
//!    confidence-adjusted capped geometric
//!    `P(r=t) ∝ (1−α)αᵗ · (1−c_t)`, where α is the profiled per-token
//!    acceptance probability and `c_t` the draft confidences.
//! 2. **Parallel inference** — rewind to `r*`, substitute the rejected
//!    token with an alternative from the local top-3, and continue for δ
//!    tokens. On downlink, the speculation is adopted iff the cloud's
//!    actual `(rejection position, corrected token)` matches the bet.

use crate::model::logits::top_k;
use crate::util::rng::Rng;

/// The device's speculative bet for one in-flight verification.
#[derive(Debug, Clone)]
pub struct PiPlan {
    /// Predicted rejection position `r* ∈ [0, γ)`.
    pub r_star: usize,
    /// The alternative token substituted at `r*`.
    pub alt_token: u32,
}

/// Sample a rejection position from the confidence-adjusted capped
/// geometric (paper §4.4). Returns `None` when γ = 0.
pub fn predict_rejection(alpha: f64, confs: &[f32], rng: &mut Rng) -> Option<usize> {
    let gamma = confs.len();
    if gamma == 0 {
        return None;
    }
    // capped geometric base: P(r=t) = (1-α)α^t  (t < γ)
    let mut w = Vec::with_capacity(gamma);
    let mut total = 0.0f64;
    for (t, &c) in confs.iter().enumerate() {
        let base = (1.0 - alpha) * alpha.powi(t as i32);
        let adj = base * (1.0 - c as f64).max(1e-6);
        w.push(adj);
        total += adj;
    }
    if total <= 0.0 {
        return Some(0);
    }
    let u = rng.f64() * total;
    let mut acc = 0.0;
    for (t, &x) in w.iter().enumerate() {
        acc += x;
        if u < acc {
            return Some(t);
        }
    }
    Some(gamma - 1)
}

/// Choose the substitute token at the predicted rejection position: the
/// best *different* candidate among the local top-3 (paper: "sampled
/// from the top-3 candidates"; greedy mode takes the strongest).
pub fn alternative_token(probs: &[f32], rejected: u32) -> u32 {
    for &i in &top_k(probs, 3) {
        if i as u32 != rejected {
            return i as u32;
        }
    }
    rejected // degenerate distribution; keep the original
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_confidence_positions_attract_prediction() {
        let mut rng = Rng::new(11);
        // token 2 is very unconfident → predictions should concentrate there
        let confs = [0.95f32, 0.95, 0.05, 0.95];
        let mut hist = [0usize; 4];
        for _ in 0..2000 {
            hist[predict_rejection(0.8, &confs, &mut rng).unwrap()] += 1;
        }
        assert!(hist[2] > hist[0] && hist[2] > hist[1] && hist[2] > hist[3], "{hist:?}");
    }

    #[test]
    fn geometric_decay_prefers_early_positions_at_equal_conf() {
        let mut rng = Rng::new(3);
        let confs = [0.5f32; 4];
        let mut hist = [0usize; 4];
        for _ in 0..4000 {
            hist[predict_rejection(0.6, &confs, &mut rng).unwrap()] += 1;
        }
        assert!(hist[0] > hist[1] && hist[1] > hist[2] && hist[2] > hist[3], "{hist:?}");
    }

    #[test]
    fn alternative_differs_from_rejected() {
        let mut p = vec![0.0f32; 8];
        p[3] = 0.6;
        p[5] = 0.3;
        p[1] = 0.1;
        assert_eq!(alternative_token(&p, 3), 5);
        assert_eq!(alternative_token(&p, 5), 3);
    }

    #[test]
    fn empty_chunk_yields_none() {
        let mut rng = Rng::new(1);
        assert!(predict_rejection(0.8, &[], &mut rng).is_none());
    }
}
