//! Engine facades over the PJRT runtime: [`DeviceEngine`] (B=1 SLM with
//! optional split early-exit execution) and [`CloudEngine`] (slot-based
//! LLM batch engine), plus logits post-processing.

pub mod cloud_engine;
pub mod device_engine;
pub mod logits;

pub use cloud_engine::{BatchEngine, CloudEngine, SlotChunk, SlotLogits, SlotOwner};
pub use device_engine::{DeviceEngine, DeviceSession, StepOut};
pub use logits::{argmax, margin_top12, softmax, top_k};
