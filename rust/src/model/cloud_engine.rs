//! Cloud-side LLM engine: a slot-based batch executor over the
//! `chunk_b4_c32` / `step_b4` executables. One call advances up to B
//! slots by up to C tokens each — the uniform batch primitive that
//! serves plain prefill chunks, verification chunks AND decode rows
//! (paper Takeaway-3): a decode is simply a 1-token chunk, and when a
//! batch consists only of 1-token rows the engine transparently routes
//! it to the cheaper `step_b4` executable.
//!
//! The [`BatchEngine`] trait abstracts the slot/batch surface the
//! scheduler needs, so scheduling policy can be tested against a
//! deterministic in-memory engine (see `testutil::MockBatchEngine`)
//! without PJRT or compiled artifacts.

use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::{KvCache, Model, SlotKv};

/// Identity of a slot's claimant. Engine-internal claims (warmup
/// probes) get a dedicated variant instead of a magic sentinel id:
/// `u64::MAX` is a perfectly valid request id, so using it as an
/// in-band marker could collide with a real session.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SlotOwner {
    /// Engine-internal claim (e.g. warmup) — never a client request.
    Internal,
    /// A client request / logical session id.
    Request(u64),
}

impl From<u64> for SlotOwner {
    fn from(id: u64) -> SlotOwner {
        SlotOwner::Request(id)
    }
}

/// Work for one slot within a batch call: append `tokens` to the slot's
/// sequence (their K/V enter the cache; logits come back per row).
#[derive(Debug, Clone)]
pub struct SlotChunk {
    pub slot: usize,
    pub tokens: Vec<u32>,
}

/// Result rows for one slot of a batch call.
#[derive(Debug, Clone)]
pub struct SlotLogits {
    pub slot: usize,
    /// `tokens.len()` rows × vocab: row `i` is the distribution over the
    /// token following `tokens[i]`.
    pub rows: Vec<f32>,
    pub n_rows: usize,
}

/// The slot/batch execution surface the cloud scheduler schedules over.
///
/// Implemented by the real PJRT-backed [`CloudEngine`] and by the
/// in-memory mock in `testutil` (scheduler-policy tests run without
/// artifacts). One `run_batch` call advances each listed slot by its
/// chunk of tokens; 1-token chunks are decode rows.
pub trait BatchEngine {
    /// Number of batch slots (B).
    fn slots(&self) -> usize;
    /// Max tokens per slot per call (C).
    fn chunk(&self) -> usize;
    /// Vocabulary size (row width of returned logits).
    fn vocab(&self) -> usize;
    /// Per-slot KV cache capacity in token rows.
    fn max_len(&self) -> usize;
    /// Committed sequence length of a slot.
    fn slot_len(&self, slot: usize) -> usize;
    /// Cumulative executed token rows (cost accounting).
    fn rows_executed(&self) -> u64;
    /// Claim a free slot for `owner`; starts with an empty cache.
    fn alloc_slot(&mut self, owner: SlotOwner) -> Option<usize>;
    /// Release a slot (stale KV is masked by `slot_len`).
    fn free_slot(&mut self, slot: usize);
    /// Number of currently unclaimed slots.
    fn free_slots(&self) -> usize;
    /// Roll a slot's committed length back (verify rejects a tail).
    fn rollback(&mut self, slot: usize, len: usize);
    /// Floats per committed token row in each KV plane
    /// (layers × heads × d_head) — the geometry host-side block pools
    /// must match to page this engine's slots.
    fn kv_row_width(&self) -> usize;
    /// Export a slot's committed KV rows as raw slot-independent row
    /// data (paged-KV swap-out). The slot's own state is unchanged.
    fn export_slot(&self, slot: usize) -> SlotKv;
    /// Overwrite a claimed slot's KV with previously exported rows and
    /// set its committed length to `kv.len` (paged-KV swap-in).
    fn import_slot(&mut self, slot: usize, kv: &SlotKv) -> Result<()>;
    /// Execute one mixed batch iteration; returns per-slot logits rows
    /// and the measured compute seconds.
    fn run_batch(&mut self, items: &[SlotChunk]) -> Result<(Vec<SlotLogits>, f64)>;
}

/// Batched cloud executor with per-slot KV state.
pub struct CloudEngine {
    pub model: Rc<Model>,
    pub kv: KvCache,
    /// Committed sequence length per slot.
    pub slot_len: Vec<usize>,
    /// Slot occupancy (claimant or free).
    pub slot_owner: Vec<Option<SlotOwner>>,
    pub slots: usize,
    pub chunk: usize,
    /// Cumulative executed token rows (cost accounting).
    pub rows_executed: u64,
}

impl CloudEngine {
    pub fn new(model: Rc<Model>) -> Result<CloudEngine> {
        if model.meta.role != "cloud" {
            bail!("{} is not a cloud model", model.meta.name);
        }
        let spec = model.meta.exec("chunk_b4_c32")?.clone();
        let m = &model.meta;
        let kv = KvCache::new(m.n_layers, spec.b, m.max_len, m.n_heads, m.d_head);
        Ok(CloudEngine {
            kv,
            slot_len: vec![0; spec.b],
            slot_owner: vec![None; spec.b],
            slots: spec.b,
            chunk: spec.c,
            model,
            rows_executed: 0,
        })
    }

    /// Compile + run both executables once so first-request latency
    /// excludes compilation. Runs in a **free** slot (free slots carry
    /// no committed KV, so the throwaway rows cannot clobber live
    /// state); bails if every slot is occupied — warm up before
    /// admitting traffic.
    pub fn warmup(&mut self) -> Result<()> {
        let Some(s) = self.slot_owner.iter().position(|o| o.is_none()) else {
            bail!("warmup requires a free slot (all {} slots busy)", self.slots);
        };
        self.slot_owner[s] = Some(SlotOwner::Internal);
        self.slot_len[s] = 0;
        let rows = self.rows_executed;
        // 2-token chunk exercises `chunk_b4_c32`; the 1-token decode row
        // below takes the fast path and compiles `step_b4`.
        self.run_batch(&[SlotChunk { slot: s, tokens: vec![1, 1] }])?;
        self.slot_len[s] = 0;
        self.run_decode(&[(s, 1)])?;
        self.slot_owner[s] = None;
        self.slot_len[s] = 0;
        self.rows_executed = rows;
        Ok(())
    }

    /// Claim a free slot for `owner`; the slot starts with an empty
    /// cache. Plain `u64` request ids coerce via `Into<SlotOwner>`.
    pub fn alloc_slot(&mut self, owner: impl Into<SlotOwner>) -> Option<usize> {
        let s = self.slot_owner.iter().position(|o| o.is_none())?;
        self.slot_owner[s] = Some(owner.into());
        self.slot_len[s] = 0;
        Some(s)
    }

    pub fn free_slot(&mut self, slot: usize) {
        self.slot_owner[slot] = None;
        self.slot_len[slot] = 0;
        // stale KV is masked by slot_len; no need to zero eagerly
    }

    pub fn free_slots(&self) -> usize {
        self.slot_owner.iter().filter(|o| o.is_none()).count()
    }

    /// Roll a slot's committed length back (speculative verify rejects
    /// trailing draft tokens; stale KV is masked out by position).
    pub fn rollback(&mut self, slot: usize, len: usize) {
        assert!(len <= self.slot_len[slot]);
        self.slot_len[slot] = len;
    }

    /// Execute one mixed batch iteration. Each item's tokens must fit
    /// the chunk size and its slot's remaining cache; slots must be
    /// in-range and listed at most once. When every item is a single
    /// token (a pure-decode batch) the cheaper `step_b4` executable is
    /// used; otherwise `chunk_b4_c32`. Returns per-slot logits rows and
    /// the measured compute time.
    pub fn run_batch(&mut self, items: &[SlotChunk]) -> Result<(Vec<SlotLogits>, f64)> {
        if items.is_empty() {
            return Ok((Vec::new(), 0.0));
        }
        let (b, c) = (self.slots, self.chunk);
        let v = self.model.meta.vocab;
        let mut seen = vec![false; b];
        for it in items {
            let s = it.slot;
            if s >= b || seen[s] {
                bail!("bad/duplicate slot {s} in batch");
            }
            if it.tokens.is_empty() || it.tokens.len() > c {
                bail!("chunk size {} out of range 1..={c}", it.tokens.len());
            }
            if self.slot_len[s] + it.tokens.len() > self.model.meta.max_len {
                bail!("slot {s} cache overflow");
            }
            seen[s] = true;
        }
        // decode fast path: all rows single-token → `step_b4` (C = 1)
        let pure_decode = items.iter().all(|it| it.tokens.len() == 1);
        let (tag, cc) = if pure_decode { ("step_b4", 1) } else { ("chunk_b4_c32", c) };
        let mut tokens = vec![0i32; b * cc];
        let mut pos = vec![0i32; b];
        let mut nv = vec![0i32; b];
        for it in items {
            let s = it.slot;
            pos[s] = self.slot_len[s] as i32;
            nv[s] = it.tokens.len() as i32;
            for (i, &t) in it.tokens.iter().enumerate() {
                tokens[s * cc + i] = t as i32;
            }
        }
        let t0 = Instant::now();
        let out = self.model.run_chunk(tag, &tokens, &pos, &nv, &mut self.kv)?;
        let dt = t0.elapsed().as_secs_f64();

        let mut res = Vec::with_capacity(items.len());
        for it in items {
            let s = it.slot;
            let n = it.tokens.len();
            self.slot_len[s] += n;
            self.rows_executed += n as u64;
            let base = s * cc * v;
            res.push(SlotLogits {
                slot: s,
                rows: out.logits[base..base + n * v].to_vec(),
                n_rows: n,
            });
        }
        Ok((res, dt))
    }

    /// Single-token decode step across active slots. Thin wrapper over
    /// the unified [`CloudEngine::run_batch`] path (a decode is a
    /// 1-token chunk), which also supplies the slot-range/duplicate
    /// validation that raw indexing used to skip.
    pub fn run_decode(&mut self, toks: &[(usize, u32)]) -> Result<(Vec<SlotLogits>, f64)> {
        let items: Vec<SlotChunk> = toks
            .iter()
            .map(|&(slot, tok)| SlotChunk { slot, tokens: vec![tok] })
            .collect();
        self.run_batch(&items)
    }
}

impl BatchEngine for CloudEngine {
    fn slots(&self) -> usize {
        self.slots
    }

    fn chunk(&self) -> usize {
        self.chunk
    }

    fn vocab(&self) -> usize {
        self.model.meta.vocab
    }

    fn max_len(&self) -> usize {
        self.model.meta.max_len
    }

    fn slot_len(&self, slot: usize) -> usize {
        self.slot_len[slot]
    }

    fn rows_executed(&self) -> u64 {
        self.rows_executed
    }

    fn alloc_slot(&mut self, owner: SlotOwner) -> Option<usize> {
        CloudEngine::alloc_slot(self, owner)
    }

    fn free_slot(&mut self, slot: usize) {
        CloudEngine::free_slot(self, slot)
    }

    fn free_slots(&self) -> usize {
        CloudEngine::free_slots(self)
    }

    fn rollback(&mut self, slot: usize, len: usize) {
        CloudEngine::rollback(self, slot, len)
    }

    fn kv_row_width(&self) -> usize {
        let m = &self.model.meta;
        m.n_layers * m.n_heads * m.d_head
    }

    fn export_slot(&self, slot: usize) -> SlotKv {
        self.kv.export_slot_rows(slot, self.slot_len[slot])
    }

    fn import_slot(&mut self, slot: usize, kv: &SlotKv) -> Result<()> {
        if slot >= self.slots || self.slot_owner[slot].is_none() {
            bail!("import into unclaimed slot {slot}");
        }
        if kv.len > self.model.meta.max_len {
            bail!("imported {} rows exceed slot capacity {}", kv.len, self.model.meta.max_len);
        }
        self.kv.import_slot_rows(slot, kv);
        self.slot_len[slot] = kv.len;
        Ok(())
    }

    fn run_batch(&mut self, items: &[SlotChunk]) -> Result<(Vec<SlotLogits>, f64)> {
        CloudEngine::run_batch(self, items)
    }
}
