//! Cloud-side LLM engine: a slot-based batch executor over the
//! `chunk_b4_c32` executable. One call advances up to B slots by up to C
//! tokens each — the uniform "partial prefill" primitive that serves
//! plain prefill chunks AND verification chunks (paper Takeaway-3).

use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::{KvCache, Model};

/// Work for one slot within a batch call: append `tokens` to the slot's
/// sequence (their K/V enter the cache; logits come back per row).
#[derive(Debug, Clone)]
pub struct SlotChunk {
    pub slot: usize,
    pub tokens: Vec<u32>,
}

/// Result rows for one slot of a batch call.
#[derive(Debug, Clone)]
pub struct SlotLogits {
    pub slot: usize,
    /// `tokens.len()` rows × vocab: row `i` is the distribution over the
    /// token following `tokens[i]`.
    pub rows: Vec<f32>,
    pub n_rows: usize,
}

/// Batched cloud executor with per-slot KV state.
pub struct CloudEngine {
    pub model: Rc<Model>,
    pub kv: KvCache,
    /// Committed sequence length per slot.
    pub slot_len: Vec<usize>,
    /// Slot occupancy (request id or free).
    pub slot_owner: Vec<Option<u64>>,
    pub slots: usize,
    pub chunk: usize,
    /// Cumulative executed token rows (cost accounting).
    pub rows_executed: u64,
}

impl CloudEngine {
    pub fn new(model: Rc<Model>) -> Result<CloudEngine> {
        if model.meta.role != "cloud" {
            bail!("{} is not a cloud model", model.meta.name);
        }
        let spec = model.meta.exec("chunk_b4_c32")?.clone();
        let m = &model.meta;
        let kv = KvCache::new(m.n_layers, spec.b, m.max_len, m.n_heads, m.d_head);
        Ok(CloudEngine {
            kv,
            slot_len: vec![0; spec.b],
            slot_owner: vec![None; spec.b],
            slots: spec.b,
            chunk: spec.c,
            model,
            rows_executed: 0,
        })
    }

    /// Compile + run both executables once (slot state untouched) so
    /// first-request latency excludes compilation.
    pub fn warmup(&mut self) -> Result<()> {
        let save_len = self.slot_len[0];
        let save_owner = self.slot_owner[0];
        self.slot_owner[0] = Some(u64::MAX);
        self.slot_len[0] = 0;
        let rows = self.rows_executed;
        self.run_batch(&[SlotChunk { slot: 0, tokens: vec![1] }])?;
        self.slot_len[0] = 0;
        self.run_decode(&[(0, 1)])?;
        self.slot_len[0] = save_len;
        self.slot_owner[0] = save_owner;
        self.rows_executed = rows;
        Ok(())
    }

    /// Claim a free slot for `owner`; the slot starts with an empty cache.
    pub fn alloc_slot(&mut self, owner: u64) -> Option<usize> {
        let s = self.slot_owner.iter().position(|o| o.is_none())?;
        self.slot_owner[s] = Some(owner);
        self.slot_len[s] = 0;
        Some(s)
    }

    pub fn free_slot(&mut self, slot: usize) {
        self.slot_owner[slot] = None;
        self.slot_len[slot] = 0;
        // stale KV is masked by slot_len; no need to zero eagerly
    }

    pub fn free_slots(&self) -> usize {
        self.slot_owner.iter().filter(|o| o.is_none()).count()
    }

    /// Roll a slot's committed length back (speculative verify rejects
    /// trailing draft tokens; stale KV is masked out by position).
    pub fn rollback(&mut self, slot: usize, len: usize) {
        assert!(len <= self.slot_len[slot]);
        self.slot_len[slot] = len;
    }

    /// Execute one batch iteration. Each item's tokens must fit the chunk
    /// size and its slot's remaining cache. Returns per-slot logits rows
    /// and the measured compute time.
    pub fn run_batch(&mut self, items: &[SlotChunk]) -> Result<(Vec<SlotLogits>, f64)> {
        if items.is_empty() {
            return Ok((Vec::new(), 0.0));
        }
        let (b, c) = (self.slots, self.chunk);
        let v = self.model.meta.vocab;
        let mut tokens = vec![0i32; b * c];
        let mut pos = vec![0i32; b];
        let mut nv = vec![0i32; b];
        let mut seen = vec![false; b];
        for it in items {
            let s = it.slot;
            if s >= b || seen[s] {
                bail!("bad/duplicate slot {s} in batch");
            }
            if it.tokens.is_empty() || it.tokens.len() > c {
                bail!("chunk size {} out of range 1..={c}", it.tokens.len());
            }
            if self.slot_len[s] + it.tokens.len() > self.model.meta.max_len {
                bail!("slot {s} cache overflow");
            }
            seen[s] = true;
            pos[s] = self.slot_len[s] as i32;
            nv[s] = it.tokens.len() as i32;
            for (i, &t) in it.tokens.iter().enumerate() {
                tokens[s * c + i] = t as i32;
            }
        }
        let t0 = Instant::now();
        let out = self
            .model
            .run_chunk("chunk_b4_c32", &tokens, &pos, &nv, &mut self.kv)?;
        let dt = t0.elapsed().as_secs_f64();

        let mut res = Vec::with_capacity(items.len());
        for it in items {
            let s = it.slot;
            let n = it.tokens.len();
            self.slot_len[s] += n;
            self.rows_executed += n as u64;
            let base = s * c * v;
            res.push(SlotLogits {
                slot: s,
                rows: out.logits[base..base + n * v].to_vec(),
                n_rows: n,
            });
        }
        Ok((res, dt))
    }

    /// Single-token decode step across active slots (cloud-centric
    /// baseline path, `step_b4` executable).
    pub fn run_decode(&mut self, toks: &[(usize, u32)]) -> Result<(Vec<SlotLogits>, f64)> {
        if toks.is_empty() {
            return Ok((Vec::new(), 0.0));
        }
        let b = self.slots;
        let v = self.model.meta.vocab;
        let mut tokens = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut nv = vec![0i32; b];
        for &(s, t) in toks {
            if self.slot_len[s] + 1 > self.model.meta.max_len {
                bail!("slot {s} cache overflow");
            }
            tokens[s] = t as i32;
            pos[s] = self.slot_len[s] as i32;
            nv[s] = 1;
        }
        let t0 = Instant::now();
        let out = self
            .model
            .run_chunk("step_b4", &tokens, &pos, &nv, &mut self.kv)?;
        let dt = t0.elapsed().as_secs_f64();
        let mut res = Vec::with_capacity(toks.len());
        for &(s, _) in toks {
            self.slot_len[s] += 1;
            self.rows_executed += 1;
            res.push(SlotLogits { slot: s, rows: out.logits[s * v..(s + 1) * v].to_vec(), n_rows: 1 });
        }
        Ok((res, dt))
    }
}
