//! Device-side SLM engine: B=1 prefill/decode over the AOT executables,
//! with optional split execution for layer-wise early exit (paper §4.3).
//!
//! Split mode runs `step_p1` (layers `[0, k)` + shared exit head) every
//! step; when the exit margin clears the threshold the token is emitted
//! from the exit logits and the deep layers are **deferred**: the hidden
//! state queues up and is flushed through the `p2_c4` backfill executable
//! before the next full-depth event (a non-exited step or an offload),
//! keeping the deep KV cache dense. This is CALM-style state propagation
//! adapted to the AOT setting — exits save real compute as long as they
//! cluster, and the conf/imp offloading signals are available right after
//! part 1, which is the paper's primary goal for this module.

use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::model::logits::{argmax, margin_top12, softmax};
use crate::runtime::{KvCache, Model};

/// Outcome of one decode step.
#[derive(Debug, Clone)]
pub struct StepOut {
    /// Softmax distribution the token was drawn from (exit or final head).
    pub probs: Vec<f32>,
    /// Greedy token (callers may re-sample from `probs`).
    pub token: u32,
    /// Top-1 probability — the paper's confidence score.
    pub confidence: f32,
    /// Top-1 − top-2 margin (early-exit signal).
    pub margin: f32,
    /// True when the step exited at the split layer.
    pub exited: bool,
    /// Fraction of layers executed by this step (energy accounting).
    pub layer_fraction: f64,
    /// Measured PJRT compute seconds for this step (incl. any backfill).
    pub compute_s: f64,
}

/// Per-request device state. Cheap to snapshot (all host vectors), which
/// is how stall-free parallel inference rolls back mispredictions.
#[derive(Clone)]
pub struct DeviceSession {
    /// Prompt + committed generation (the cache holds K/V for all of it).
    pub tokens: Vec<u32>,
    /// Tokens committed to the part-1 (or full) cache.
    pub len: usize,
    /// Tokens committed to the part-2 (deep) cache; `len - p2_len` hidden
    /// states are queued in `pending`.
    pub p2_len: usize,
    kv_full: Option<KvCache>,
    kv1: Option<KvCache>,
    kv2: Option<KvCache>,
    /// Deferred part-2 inputs: hidden states of exited positions
    /// (contiguous from `p2_len`).
    pending: Vec<Vec<f32>>,
    /// Accumulated per-position importance mass (kernel colsums summed
    /// over steps — the H2O-style online importance signal).
    pub importance: Vec<f32>,
    /// Number of generated (non-prompt) tokens.
    pub generated: usize,
    /// Mean next-token NLL of the prompt under this SLM (EdgeFM-LLM's
    /// input-level offloading signal; ppl = exp of this).
    pub prompt_nll: f64,
}

impl DeviceSession {
    /// Rollback target for speculative work: restoring a clone reverts
    /// caches, queues and counters (stale KV beyond `len` is masked out).
    pub fn snapshot(&self) -> DeviceSession {
        self.clone()
    }

    /// Rewind the committed length to `new_len` (≥ prompt length). Stale
    /// KV beyond it is never attended to (position masking), so this is
    /// O(dropped) bookkeeping — the rollback primitive behind both
    /// verification corrections and PI mispredictions.
    pub fn rewind(&mut self, new_len: usize) {
        assert!(new_len <= self.len, "rewind {new_len} > len {}", self.len);
        let drop = self.len - new_len;
        self.tokens.truncate(self.tokens.len() - drop);
        self.len = new_len;
        self.generated -= drop.min(self.generated);
        // pending holds hidden states for positions [p2_len, len);
        // dropping the tail keeps the invariant p2_len + pending == len
        while !self.pending.is_empty() && self.p2_len + self.pending.len() > new_len {
            self.pending.pop();
        }
        // the deep cache may already cover positions ≥ new_len (full mode,
        // or split mode after a backfill): clamp — stale deep KV beyond the
        // logical length is position-masked and never attended to
        self.p2_len = self.p2_len.min(new_len);
    }

    /// Prompt perplexity under the SLM.
    pub fn prompt_ppl(&self) -> f64 {
        self.prompt_nll.exp()
    }
}

/// SLM executor bound to one model variant.
pub struct DeviceEngine {
    pub model: Rc<Model>,
    /// Execute split (early-exit capable) decode steps.
    pub split: bool,
}

impl DeviceEngine {
    pub fn new(model: Rc<Model>, split: bool) -> Result<DeviceEngine> {
        if model.meta.role != "device" {
            bail!("{} is not a device model", model.meta.name);
        }
        Ok(DeviceEngine { model, split })
    }

    fn dims(&self) -> (usize, usize, usize, usize, usize) {
        let m = &self.model.meta;
        (m.n_layers, m.max_len, m.n_heads, m.d_head, m.split_layer)
    }

    /// Prefill the prompt in chunks of 32; returns the session plus the
    /// distribution over the first generated token.
    pub fn prefill(&self, prompt: &[u32]) -> Result<(DeviceSession, StepOut)> {
        let (l, m, h, dh, split) = self.dims();
        if prompt.is_empty() || prompt.len() > m {
            bail!("prompt length {} out of range (max {m})", prompt.len());
        }
        let chunk = self.model.meta.exec("chunk_b1_c32")?.c;
        let mut kv = KvCache::new(l, 1, m, h, dh);
        let mut importance = vec![0f32; m];
        let t0 = Instant::now();
        let mut last_logits: Vec<f32> = Vec::new();
        let mut pos = 0usize;
        let mut nll_sum = 0f64;
        while pos < prompt.len() {
            let n = (prompt.len() - pos).min(chunk);
            let mut toks = vec![0i32; chunk];
            for i in 0..n {
                toks[i] = prompt[pos + i] as i32;
            }
            let out = self.model.run_chunk(
                "chunk_b1_c32",
                &toks,
                &[pos as i32],
                &[n as i32],
                &mut kv,
            )?;
            for (a, b) in importance.iter_mut().zip(&out.importance) {
                *a += b;
            }
            let v = self.model.meta.vocab;
            // prompt NLL: row i predicts prompt[pos+i+1]
            for i in 0..n {
                let next = if pos + i + 1 < prompt.len() {
                    prompt[pos + i + 1]
                } else {
                    break;
                };
                let row = softmax(&out.logits[i * v..(i + 1) * v]);
                nll_sum -= (row[next as usize].max(1e-9) as f64).ln();
            }
            last_logits = out.logits[(n - 1) * v..n * v].to_vec();
            pos += n;
        }
        let prompt_nll = nll_sum / (prompt.len().saturating_sub(1).max(1)) as f64;
        let compute_s = t0.elapsed().as_secs_f64();

        let (kv_full, kv1, kv2) = if self.split {
            // consuming split: the prefill cache is dead after the
            // handoff, so only the upper layer range is copied (halves
            // peak KV memory vs cloning both halves)
            let (a, b) = kv.split_into_at_layer(split);
            (None, Some(a), Some(b))
        } else {
            (Some(kv), None, None)
        };
        let sess = DeviceSession {
            tokens: prompt.to_vec(),
            len: prompt.len(),
            p2_len: prompt.len(),
            kv_full,
            kv1,
            kv2,
            pending: Vec::new(),
            importance,
            generated: 0,
            prompt_nll,
        };
        let probs = softmax(&last_logits);
        let token = argmax(&probs) as u32;
        let confidence = probs[token as usize];
        let margin = margin_top12(&probs);
        Ok((
            sess,
            StepOut {
                probs,
                token,
                confidence,
                margin,
                exited: false,
                layer_fraction: 1.0,
                compute_s,
            },
        ))
    }

    /// One decode step: append `token` (position `sess.len`) and return
    /// the distribution over the next token.
    ///
    /// `allow_exit` gates layer-wise early exit (sequence position and
    /// module toggles are the caller's policy); `exit_threshold` is the
    /// margin cut (paper default 0.7).
    pub fn step(
        &self,
        sess: &mut DeviceSession,
        token: u32,
        allow_exit: bool,
        exit_threshold: f32,
    ) -> Result<StepOut> {
        if sess.len + 1 > self.model.meta.max_len {
            bail!("KV cache exhausted at len {}", sess.len);
        }
        sess.tokens.push(token);
        sess.generated += 1;
        if self.split {
            self.step_split(sess, token, allow_exit, exit_threshold)
        } else {
            self.step_full(sess, token)
        }
    }

    fn step_full(&self, sess: &mut DeviceSession, token: u32) -> Result<StepOut> {
        let t0 = Instant::now();
        let kv = sess.kv_full.as_mut().expect("full-mode session");
        let out = self.model.run_chunk(
            "step_full",
            &[token as i32],
            &[sess.len as i32],
            &[1],
            kv,
        )?;
        sess.len += 1;
        sess.p2_len = sess.len;
        for (a, b) in sess.importance.iter_mut().zip(&out.importance) {
            *a += b;
        }
        let probs = softmax(&out.logits);
        let tok = argmax(&probs) as u32;
        Ok(StepOut {
            confidence: probs[tok as usize],
            margin: margin_top12(&probs),
            token: tok,
            probs,
            exited: false,
            layer_fraction: 1.0,
            compute_s: t0.elapsed().as_secs_f64(),
        })
    }

    fn step_split(
        &self,
        sess: &mut DeviceSession,
        token: u32,
        allow_exit: bool,
        exit_threshold: f32,
    ) -> Result<StepOut> {
        let (l, _, _, _, split) = self.dims();
        let t0 = Instant::now();
        let kv1 = sess.kv1.as_mut().expect("split-mode session");
        let out1 = self.model.run_chunk(
            "step_p1",
            &[token as i32],
            &[sess.len as i32],
            &[1],
            kv1,
        )?;
        let pos = sess.len;
        sess.len += 1;
        for (a, b) in sess.importance.iter_mut().zip(&out1.importance) {
            *a += b;
        }
        let exit_probs = softmax(&out1.logits);
        let margin = margin_top12(&exit_probs);
        let hidden = out1.hidden.expect("p1 returns hidden");

        if allow_exit && margin >= exit_threshold {
            // Early exit: emit from the exit head; defer deep layers.
            sess.pending.push(hidden);
            if sess.pending.len() >= self.backfill_capacity() {
                self.flush_backfill(sess)?;
            }
            let tok = argmax(&exit_probs) as u32;
            return Ok(StepOut {
                confidence: exit_probs[tok as usize],
                margin,
                token: tok,
                probs: exit_probs,
                exited: true,
                layer_fraction: split as f64 / l as f64,
                compute_s: t0.elapsed().as_secs_f64(),
            });
        }

        // No exit: backfill any deferred positions, then run deep layers.
        self.flush_backfill(sess)?;
        let kv2 = sess.kv2.as_mut().unwrap();
        let out2 = self.model.run_hidden(
            "step_p2",
            &hidden,
            &[pos as i32],
            &[1],
            kv2,
        )?;
        sess.p2_len = sess.len;
        // importance accumulates from part-1 only so the signal is
        // comparable between exited and non-exited steps
        let probs = softmax(&out2.logits);
        let tok = argmax(&probs) as u32;
        Ok(StepOut {
            confidence: probs[tok as usize],
            margin: margin_top12(&probs),
            token: tok,
            probs,
            exited: false,
            layer_fraction: 1.0,
            compute_s: t0.elapsed().as_secs_f64(),
        })
    }

    fn backfill_capacity(&self) -> usize {
        self.model.meta.exec("p2_c4").map(|e| e.c).unwrap_or(4)
    }

    /// Flush queued exit hiddens through the `p2_c4` backfill executable
    /// so the deep cache catches up to `sess.len`.
    fn flush_backfill(&self, sess: &mut DeviceSession) -> Result<()> {
        while !sess.pending.is_empty() {
            let cap = self.backfill_capacity();
            let d = self.model.meta.d_model;
            let n = sess.pending.len().min(cap);
            let mut hid = vec![0f32; cap * d];
            for (i, h) in sess.pending.drain(..n).enumerate() {
                hid[i * d..(i + 1) * d].copy_from_slice(&h);
            }
            let kv2 = sess.kv2.as_mut().unwrap();
            let _ = self.model.run_hidden(
                "p2_c4",
                &hid,
                &[sess.p2_len as i32],
                &[n as i32],
                kv2,
            )?;
            sess.p2_len += n;
        }
        Ok(())
    }
}
