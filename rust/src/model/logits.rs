//! Logits post-processing: softmax, argmax, top-k, margins, sampling.

/// Numerically stable in-place softmax; returns the probabilities.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut p: Vec<f32> = logits.iter().map(|&x| (x - mx).exp()).collect();
    let s: f32 = p.iter().sum();
    if s > 0.0 {
        p.iter_mut().for_each(|x| *x /= s);
    }
    p
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Indices of the k largest entries, descending.
pub fn top_k(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    let k = k.min(xs.len());
    idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap()
    });
    idx.truncate(k);
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
    idx
}

/// Top-1 minus top-2 probability — the layer-wise early-exit margin
/// score (paper §4.3, after EdgeFM).
pub fn margin_top12(probs: &[f32]) -> f32 {
    let (mut m1, mut m2) = (0f32, 0f32);
    for &p in probs {
        if p > m1 {
            m2 = m1;
            m1 = p;
        } else if p > m2 {
            m2 = p;
        }
    }
    m1 - m2
}

/// Sample from a distribution with a uniform draw `u ∈ [0,1)`.
pub fn sample_with(probs: &[f32], u: f64) -> usize {
    let mut acc = 0f64;
    for (i, &p) in probs.iter().enumerate() {
        acc += p as f64;
        if u < acc {
            return i;
        }
    }
    probs.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_handles_large_values() {
        let p = softmax(&[1000.0, 999.0]);
        assert!(p.iter().all(|x| x.is_finite()));
        assert!(p[0] > p[1]);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn top_k_sorted_desc() {
        let xs = [0.1f32, 0.9, 0.3, 0.5];
        assert_eq!(top_k(&xs, 3), vec![1, 3, 2]);
        assert_eq!(top_k(&xs, 10).len(), 4);
    }

    #[test]
    fn margin_of_onehot_is_high() {
        assert!(margin_top12(&[0.98, 0.01, 0.01]) > 0.9);
        assert!(margin_top12(&[0.5, 0.5]) < 1e-6);
    }

    #[test]
    fn sample_with_matches_cdf() {
        let p = [0.25f32, 0.25, 0.5];
        assert_eq!(sample_with(&p, 0.10), 0);
        assert_eq!(sample_with(&p, 0.30), 1);
        assert_eq!(sample_with(&p, 0.99), 2);
    }
}
