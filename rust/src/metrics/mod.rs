//! Quality, latency, cost and energy metrics (paper §6.1 "Metrics").

pub mod cost;
pub mod energy;
pub mod quality;
pub mod stats;

pub use cost::{CostModel, PackingFactors};
pub use energy::EnergyModel;
pub use quality::{accuracy, rouge1, score_sample};
pub use stats::{LatencyRecorder, Summary};
