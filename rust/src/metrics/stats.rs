//! Latency recording and summary statistics (mean / p50 / p95 / p99).

use std::time::Duration;

/// Summary statistics over a set of f64 observations.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub std: f64,
}

impl Summary {
    pub fn of(values: &[f64]) -> Summary {
        if values.is_empty() {
            return Summary::default();
        }
        let mut v: Vec<f64> = values.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| v[(((n - 1) as f64) * p).round() as usize];
        Summary {
            n,
            mean,
            min: v[0],
            max: v[n - 1],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            std: var.sqrt(),
        }
    }
}

/// Accumulates per-token / per-request latencies (in seconds).
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    values: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, seconds: f64) {
        self.values.push(seconds);
    }

    pub fn record_duration(&mut self, d: Duration) {
        self.values.push(d.as_secs_f64());
    }

    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.values.extend_from_slice(&other.values);
    }

    pub fn summary(&self) -> Summary {
        Summary::of(&self.values)
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 3.0); // nearest-rank on 4 samples
    }

    #[test]
    fn summary_empty_is_zero() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentiles_monotone() {
        let v: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::of(&v);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.p50 - 500.0).abs() < 2.0);
        assert!((s.p95 - 949.0).abs() < 2.0);
    }

    #[test]
    fn recorder_merge() {
        let mut a = LatencyRecorder::new();
        a.record(1.0);
        let mut b = LatencyRecorder::new();
        b.record(3.0);
        a.merge(&b);
        assert_eq!(a.summary().n, 2);
        assert_eq!(a.summary().mean, 2.0);
    }
}
