//! Latency recording and summary statistics (mean / p50 / p95 / p99).
//!
//! [`LatencyRecorder`] retains every sample by default; for
//! million-request simulation runs (`crate::sim`) a **bounded seeded
//! reservoir** mode (Vitter's Algorithm R) keeps a uniform sample of
//! fixed size, so [`Summary::of`] over the reservoir tracks the exact
//! percentiles within sampling tolerance at O(capacity) memory.

use std::time::Duration;

use crate::util::rng::Rng;

/// Summary statistics over a set of f64 observations.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub std: f64,
}

impl Summary {
    /// Summary of a non-empty sample, `None` for an empty one — the
    /// honest form of [`Summary::of`] (no zero sentinel that reads as
    /// a real 0-second percentile downstream).
    pub fn of_nonempty(values: &[f64]) -> Option<Summary> {
        if values.is_empty() {
            None
        } else {
            Some(Summary::of(values))
        }
    }

    /// Combine two summaries without the underlying samples: `n`,
    /// `mean`, `min`/`max` and the pooled `std` are exact; the merged
    /// percentiles are the *n-weighted blend* of the inputs'
    /// percentiles — an approximation that is exact when both sides
    /// were drawn from the same distribution (the per-replica /
    /// per-tenant roll-up case this exists for) and always lands
    /// between the two inputs.
    pub fn merge(&self, other: &Summary) -> Summary {
        if self.n == 0 {
            return other.clone();
        }
        if other.n == 0 {
            return self.clone();
        }
        let n = self.n + other.n;
        let (wa, wb) = (self.n as f64 / n as f64, other.n as f64 / n as f64);
        let mean = wa * self.mean + wb * other.mean;
        // pooled variance: E[var] + var of the component means
        let va = self.std * self.std + (self.mean - mean) * (self.mean - mean);
        let vb = other.std * other.std + (other.mean - mean) * (other.mean - mean);
        Summary {
            n,
            mean,
            min: self.min.min(other.min),
            max: self.max.max(other.max),
            p50: wa * self.p50 + wb * other.p50,
            p95: wa * self.p95 + wb * other.p95,
            p99: wa * self.p99 + wb * other.p99,
            std: (wa * va + wb * vb).sqrt(),
        }
    }

    pub fn of(values: &[f64]) -> Summary {
        if values.is_empty() {
            return Summary::default();
        }
        let mut v: Vec<f64> = values.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| v[(((n - 1) as f64) * p).round() as usize];
        Summary {
            n,
            mean,
            min: v[0],
            max: v[n - 1],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            std: var.sqrt(),
        }
    }
}

/// Accumulates per-token / per-request latencies (in seconds), either
/// exactly (default) or into a bounded seeded reservoir.
#[derive(Debug, Clone)]
pub struct LatencyRecorder {
    values: Vec<f64>,
    /// Reservoir capacity; `None` retains every sample.
    cap: Option<usize>,
    /// Samples offered (≥ `values.len()` in reservoir mode).
    seen: u64,
    rng: Rng,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        LatencyRecorder { values: Vec::new(), cap: None, seen: 0, rng: Rng::new(0) }
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bounded recorder: keeps a uniform random sample of at most
    /// `capacity` observations (Algorithm R over the deterministic
    /// seeded stream — same seed and record order ⇒ same reservoir).
    pub fn with_reservoir(capacity: usize, seed: u64) -> Self {
        LatencyRecorder {
            values: Vec::with_capacity(capacity.min(1 << 20)),
            cap: Some(capacity.max(1)),
            seen: 0,
            rng: Rng::new(seed ^ 0x5EED_4E5E),
        }
    }

    pub fn record(&mut self, seconds: f64) {
        self.seen += 1;
        match self.cap {
            Some(cap) if self.values.len() >= cap => {
                // each of the `seen` offers survives w.p. cap/seen
                let j = self.rng.below(self.seen);
                if (j as usize) < cap {
                    self.values[j as usize] = seconds;
                }
            }
            _ => self.values.push(seconds),
        }
    }

    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_secs_f64());
    }

    /// Fold `other`'s retained samples into this recorder. In reservoir
    /// mode the result is an approximation (the merged stream is
    /// re-sampled, so `other`'s discarded samples stay lost); exact
    /// recorders concatenate losslessly as before.
    pub fn merge(&mut self, other: &LatencyRecorder) {
        for &v in &other.values {
            self.record(v);
        }
    }

    /// Summary of the retained samples, `None` when nothing was
    /// recorded — callers must not mistake "no data" for "0 s p99".
    pub fn summary(&self) -> Option<Summary> {
        Summary::of_nonempty(&self.values)
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Total observations offered (not capped by the reservoir).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 3.0); // nearest-rank on 4 samples
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of_nonempty(&[]).is_none());
        assert!(LatencyRecorder::new().summary().is_none());
        // the raw constructor keeps its zero-default for struct fill-in
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn summary_merge_exact_moments() {
        let a = Summary::of(&[1.0, 2.0, 3.0]);
        let b = Summary::of(&[4.0, 5.0, 6.0, 7.0]);
        let m = a.merge(&b);
        let full = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        assert_eq!(m.n, full.n);
        assert!((m.mean - full.mean).abs() < 1e-12);
        assert_eq!(m.min, full.min);
        assert_eq!(m.max, full.max);
        assert!((m.std - full.std).abs() < 1e-12, "pooled std is exact");
        // blended percentiles stay within the input envelope
        assert!(m.p50 >= a.p50 && m.p50 <= b.p50);
    }

    #[test]
    fn summary_merge_with_empty_is_identity() {
        let a = Summary::of(&[1.0, 2.0]);
        let e = Summary::default();
        assert_eq!(a.merge(&e).n, 2);
        assert_eq!(e.merge(&a).n, 2);
        assert_eq!(a.merge(&e).mean, a.mean);
    }

    #[test]
    fn percentiles_monotone() {
        let v: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::of(&v);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.p50 - 500.0).abs() < 2.0);
        assert!((s.p95 - 949.0).abs() < 2.0);
    }

    #[test]
    fn reservoir_tracks_exact_percentiles() {
        // heavy-tailed stream: latency = u² (most samples small, rare
        // large ones) — the regime where naive truncation would shear
        // off exactly the tail percentiles that matter
        let mut rng = crate::util::rng::Rng::new(0xA11);
        let mut exact = LatencyRecorder::new();
        let mut res = LatencyRecorder::with_reservoir(4096, 7);
        for _ in 0..200_000 {
            let u = rng.f64();
            let v = u * u;
            exact.record(v);
            res.record(v);
        }
        assert_eq!(res.values().len(), 4096, "reservoir is bounded");
        assert_eq!(res.seen(), 200_000);
        let (e, r) = (exact.summary().unwrap(), res.summary().unwrap());
        for (pe, pr, name, tol) in [
            (e.p50, r.p50, "p50", 0.15),
            (e.p95, r.p95, "p95", 0.10),
            (e.p99, r.p99, "p99", 0.15),
            (e.mean, r.mean, "mean", 0.10),
        ] {
            let rel = (pe - pr).abs() / pe.max(1e-12);
            assert!(rel < tol, "{name}: exact {pe} vs reservoir {pr} (rel {rel:.3})");
        }
    }

    #[test]
    fn reservoir_is_deterministic_per_seed() {
        let fill = |seed| {
            let mut r = LatencyRecorder::with_reservoir(64, seed);
            for i in 0..10_000 {
                r.record((i % 997) as f64);
            }
            r.values().to_vec()
        };
        assert_eq!(fill(3), fill(3));
        assert_ne!(fill(3), fill(4), "different seeds sample differently");
    }

    #[test]
    fn recorder_merge() {
        let mut a = LatencyRecorder::new();
        a.record(1.0);
        let mut b = LatencyRecorder::new();
        b.record(3.0);
        a.merge(&b);
        let s = a.summary().unwrap();
        assert_eq!(s.n, 2);
        assert_eq!(s.mean, 2.0);
    }
}
