//! Latency recording and summary statistics (mean / p50 / p95 / p99).
//!
//! [`LatencyRecorder`] retains every sample by default; for
//! million-request simulation runs (`crate::sim`) a **bounded seeded
//! reservoir** mode (Vitter's Algorithm R) keeps a uniform sample of
//! fixed size, so [`Summary::of`] over the reservoir tracks the exact
//! percentiles within sampling tolerance at O(capacity) memory.
//!
//! [`QuantileSketch`] is the fleet-scale successor to the reservoir:
//! a DDSketch-style log-bucketed histogram with a *guaranteed*
//! relative error (the reservoir's error is probabilistic and
//! tail-hostile), an **exact** `merge` (bucket counts add — the
//! cross-replica / cross-tenant roll-up loses nothing, unlike
//! reservoir re-sampling), and deterministic serialization.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Summary statistics over a set of f64 observations.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub std: f64,
}

impl Summary {
    /// Summary of a non-empty sample, `None` for an empty one — the
    /// honest form of [`Summary::of`] (no zero sentinel that reads as
    /// a real 0-second percentile downstream).
    pub fn of_nonempty(values: &[f64]) -> Option<Summary> {
        if values.is_empty() {
            None
        } else {
            Some(Summary::of(values))
        }
    }

    /// Combine two summaries without the underlying samples: `n`,
    /// `mean`, `min`/`max` and the pooled `std` are exact; the merged
    /// percentiles are the *n-weighted blend* of the inputs'
    /// percentiles — an approximation that is exact when both sides
    /// were drawn from the same distribution (the per-replica /
    /// per-tenant roll-up case this exists for) and always lands
    /// between the two inputs.
    pub fn merge(&self, other: &Summary) -> Summary {
        if self.n == 0 {
            return other.clone();
        }
        if other.n == 0 {
            return self.clone();
        }
        let n = self.n + other.n;
        let (wa, wb) = (self.n as f64 / n as f64, other.n as f64 / n as f64);
        let mean = wa * self.mean + wb * other.mean;
        // pooled variance: E[var] + var of the component means
        let va = self.std * self.std + (self.mean - mean) * (self.mean - mean);
        let vb = other.std * other.std + (other.mean - mean) * (other.mean - mean);
        Summary {
            n,
            mean,
            min: self.min.min(other.min),
            max: self.max.max(other.max),
            p50: wa * self.p50 + wb * other.p50,
            p95: wa * self.p95 + wb * other.p95,
            p99: wa * self.p99 + wb * other.p99,
            std: (wa * va + wb * vb).sqrt(),
        }
    }

    pub fn of(values: &[f64]) -> Summary {
        if values.is_empty() {
            return Summary::default();
        }
        let mut v: Vec<f64> = values.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| v[(((n - 1) as f64) * p).round() as usize];
        Summary {
            n,
            mean,
            min: v[0],
            max: v[n - 1],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            std: var.sqrt(),
        }
    }
}

/// Accumulates per-token / per-request latencies (in seconds), either
/// exactly (default) or into a bounded seeded reservoir.
#[derive(Debug, Clone)]
pub struct LatencyRecorder {
    values: Vec<f64>,
    /// Reservoir capacity; `None` retains every sample.
    cap: Option<usize>,
    /// Samples offered (≥ `values.len()` in reservoir mode).
    seen: u64,
    rng: Rng,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        LatencyRecorder { values: Vec::new(), cap: None, seen: 0, rng: Rng::new(0) }
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bounded recorder: keeps a uniform random sample of at most
    /// `capacity` observations (Algorithm R over the deterministic
    /// seeded stream — same seed and record order ⇒ same reservoir).
    pub fn with_reservoir(capacity: usize, seed: u64) -> Self {
        LatencyRecorder {
            values: Vec::with_capacity(capacity.min(1 << 20)),
            cap: Some(capacity.max(1)),
            seen: 0,
            rng: Rng::new(seed ^ 0x5EED_4E5E),
        }
    }

    pub fn record(&mut self, seconds: f64) {
        self.seen += 1;
        match self.cap {
            Some(cap) if self.values.len() >= cap => {
                // each of the `seen` offers survives w.p. cap/seen
                let j = self.rng.below(self.seen);
                if (j as usize) < cap {
                    self.values[j as usize] = seconds;
                }
            }
            _ => self.values.push(seconds),
        }
    }

    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_secs_f64());
    }

    /// Fold `other`'s retained samples into this recorder. In reservoir
    /// mode the result is an approximation (the merged stream is
    /// re-sampled, so `other`'s discarded samples stay lost); exact
    /// recorders concatenate losslessly as before.
    pub fn merge(&mut self, other: &LatencyRecorder) {
        for &v in &other.values {
            self.record(v);
        }
    }

    /// Summary of the retained samples, `None` when nothing was
    /// recorded — callers must not mistake "no data" for "0 s p99".
    pub fn summary(&self) -> Option<Summary> {
        Summary::of_nonempty(&self.values)
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Total observations offered (not capped by the reservoir).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

/// Smallest magnitude the sketch resolves; anything at or below it
/// (including zero and negative inputs — latencies are non-negative)
/// lands in a dedicated zero bucket and reads back as `0.0`.
const SKETCH_MIN: f64 = 1e-9;

/// Mergeable log-bucketed quantile sketch (DDSketch-style).
///
/// Values are binned into geometric buckets `(γ^(k−1), γ^k]` with
/// `γ = (1+α)/(1−α)`; a bucket reads back as `2γ^k/(γ+1)`, its
/// midpoint in log space, so every reported quantile is within a
/// **relative error of α** of the exact order statistic. `n`, `sum`,
/// `sum²`, `min` and `max` are carried exactly, so [`summary`]
/// produces exact mean/std/min/max alongside α-bounded percentiles.
///
/// Contracts:
/// * **merge is exact** — bucket counts add, so merging per-replica
///   or per-device sketches equals one sketch fed the whole stream
///   (quantiles identical; `sum`/`mean` agree to float addition
///   order). Both sides must share the same α.
/// * **deterministic** — no RNG; same record order ⇒ bit-identical
///   state and [`to_json`] bytes.
/// * **bounded** — bucket count grows with the log of the value
///   range, not with `n` (~229 buckets per decade at α = 0.01).
///
/// [`summary`]: QuantileSketch::summary
/// [`to_json`]: QuantileSketch::to_json
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    alpha: f64,
    gamma: f64,
    ln_gamma: f64,
    /// Bucket index `k = ceil(ln(x)/ln γ)` → count, for `x > SKETCH_MIN`.
    buckets: BTreeMap<i32, u64>,
    /// Count of values ≤ [`SKETCH_MIN`] (reads back as exactly 0.0).
    zeros: u64,
    n: u64,
    sum: f64,
    sumsq: f64,
    min: f64,
    max: f64,
}

impl Default for QuantileSketch {
    /// The fleet default: α = 1% relative error.
    fn default() -> Self {
        QuantileSketch::new(0.01)
    }
}

impl QuantileSketch {
    /// Sketch with relative-error bound `alpha` (clamped to
    /// `[1e-4, 0.25]` — below that buckets explode, above it the
    /// "sketch" stops meaning anything).
    pub fn new(alpha: f64) -> Self {
        let alpha = alpha.clamp(1e-4, 0.25);
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        QuantileSketch {
            alpha,
            gamma,
            ln_gamma: gamma.ln(),
            buckets: BTreeMap::new(),
            zeros: 0,
            n: 0,
            sum: 0.0,
            sumsq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// The guaranteed relative-error bound α.
    pub fn relative_error(&self) -> f64 {
        self.alpha
    }

    pub fn record(&mut self, x: f64) {
        if x.is_nan() {
            return; // refuse to poison the moments
        }
        self.n += 1;
        self.sum += x;
        self.sumsq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if x > SKETCH_MIN {
            let k = (x.ln() / self.ln_gamma).ceil() as i32;
            *self.buckets.entry(k).or_insert(0) += 1;
        } else {
            self.zeros += 1;
        }
    }

    /// Fold `other` into `self`. Bucket counts add, so the merged
    /// sketch answers quantiles exactly as if it had seen both
    /// streams. Panics on an α mismatch — differently-binned sketches
    /// are not comparable, and silently blending them would corrupt
    /// the error bound.
    pub fn merge(&mut self, other: &QuantileSketch) {
        assert!(
            (self.alpha - other.alpha).abs() < 1e-12,
            "QuantileSketch::merge: alpha mismatch ({} vs {})",
            self.alpha,
            other.alpha
        );
        for (&k, &c) in &other.buckets {
            *self.buckets.entry(k).or_insert(0) += c;
        }
        self.zeros += other.zeros;
        self.n += other.n;
        self.sum += other.sum;
        self.sumsq += other.sumsq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of observations recorded.
    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Live bucket count — the sketch's actual memory footprint.
    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    /// The q-quantile (`q ∈ [0, 1]`), `None` when empty. Within a
    /// relative error of α of the exact order statistic, except the
    /// zero bucket which reads back as exactly `0.0`.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.n == 0 {
            return None;
        }
        // nearest-rank, matching Summary::of's v[round((n-1)·q)]
        let rank = ((self.n - 1) as f64 * q.clamp(0.0, 1.0)).round() as u64;
        if rank < self.zeros {
            return Some(0.0);
        }
        let mut seen = self.zeros;
        for (&k, &c) in &self.buckets {
            seen += c;
            if rank < seen {
                // log-space midpoint of (γ^(k−1), γ^k]
                let est = 2.0 * self.gamma.powi(k) / (self.gamma + 1.0);
                // exact extremes beat the bucket estimate at the edges
                return Some(est.clamp(self.min, self.max));
            }
        }
        Some(self.max) // unreachable unless counts desynced; fail soft
    }

    /// Summary with exact `n`/`mean`/`min`/`max`/`std` and α-bounded
    /// percentiles; `None` when nothing was recorded.
    pub fn summary(&self) -> Option<Summary> {
        if self.n == 0 {
            return None;
        }
        let n = self.n as f64;
        let mean = self.sum / n;
        let var = (self.sumsq / n - mean * mean).max(0.0);
        Some(Summary {
            n: self.n as usize,
            mean,
            min: self.min,
            max: self.max,
            p50: self.quantile(0.50).unwrap_or(0.0),
            p95: self.quantile(0.95).unwrap_or(0.0),
            p99: self.quantile(0.99).unwrap_or(0.0),
            std: var.sqrt(),
        })
    }

    /// Deterministic serialization: buckets in key order, counts as
    /// `[k, count]` pairs. Same state ⇒ identical bytes, so merged
    /// sketches can be compared structurally across replicas.
    pub fn to_json(&self) -> Json {
        let buckets = Json::arr(
            self.buckets
                .iter()
                .map(|(&k, &c)| Json::arr([Json::num(k as f64), Json::num(c as f64)])),
        );
        Json::obj(vec![
            ("alpha", Json::num(self.alpha)),
            ("n", Json::num(self.n as f64)),
            ("zeros", Json::num(self.zeros as f64)),
            ("sum", Json::num(self.sum)),
            ("sumsq", Json::num(self.sumsq)),
            ("min", Json::num(if self.n == 0 { 0.0 } else { self.min })),
            ("max", Json::num(if self.n == 0 { 0.0 } else { self.max })),
            ("buckets", buckets),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 3.0); // nearest-rank on 4 samples
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of_nonempty(&[]).is_none());
        assert!(LatencyRecorder::new().summary().is_none());
        // the raw constructor keeps its zero-default for struct fill-in
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn summary_merge_exact_moments() {
        let a = Summary::of(&[1.0, 2.0, 3.0]);
        let b = Summary::of(&[4.0, 5.0, 6.0, 7.0]);
        let m = a.merge(&b);
        let full = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        assert_eq!(m.n, full.n);
        assert!((m.mean - full.mean).abs() < 1e-12);
        assert_eq!(m.min, full.min);
        assert_eq!(m.max, full.max);
        assert!((m.std - full.std).abs() < 1e-12, "pooled std is exact");
        // blended percentiles stay within the input envelope
        assert!(m.p50 >= a.p50 && m.p50 <= b.p50);
    }

    #[test]
    fn summary_merge_with_empty_is_identity() {
        let a = Summary::of(&[1.0, 2.0]);
        let e = Summary::default();
        assert_eq!(a.merge(&e).n, 2);
        assert_eq!(e.merge(&a).n, 2);
        assert_eq!(a.merge(&e).mean, a.mean);
    }

    #[test]
    fn percentiles_monotone() {
        let v: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::of(&v);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.p50 - 500.0).abs() < 2.0);
        assert!((s.p95 - 949.0).abs() < 2.0);
    }

    #[test]
    fn reservoir_tracks_exact_percentiles() {
        // heavy-tailed stream: latency = u² (most samples small, rare
        // large ones) — the regime where naive truncation would shear
        // off exactly the tail percentiles that matter
        let mut rng = crate::util::rng::Rng::new(0xA11);
        let mut exact = LatencyRecorder::new();
        let mut res = LatencyRecorder::with_reservoir(4096, 7);
        for _ in 0..200_000 {
            let u = rng.f64();
            let v = u * u;
            exact.record(v);
            res.record(v);
        }
        assert_eq!(res.values().len(), 4096, "reservoir is bounded");
        assert_eq!(res.seen(), 200_000);
        let (e, r) = (exact.summary().unwrap(), res.summary().unwrap());
        for (pe, pr, name, tol) in [
            (e.p50, r.p50, "p50", 0.15),
            (e.p95, r.p95, "p95", 0.10),
            (e.p99, r.p99, "p99", 0.15),
            (e.mean, r.mean, "mean", 0.10),
        ] {
            let rel = (pe - pr).abs() / pe.max(1e-12);
            assert!(rel < tol, "{name}: exact {pe} vs reservoir {pr} (rel {rel:.3})");
        }
    }

    #[test]
    fn reservoir_is_deterministic_per_seed() {
        let fill = |seed| {
            let mut r = LatencyRecorder::with_reservoir(64, seed);
            for i in 0..10_000 {
                r.record((i % 997) as f64);
            }
            r.values().to_vec()
        };
        assert_eq!(fill(3), fill(3));
        assert_ne!(fill(3), fill(4), "different seeds sample differently");
    }

    #[test]
    fn recorder_merge() {
        let mut a = LatencyRecorder::new();
        a.record(1.0);
        let mut b = LatencyRecorder::new();
        b.record(3.0);
        a.merge(&b);
        let s = a.summary().unwrap();
        assert_eq!(s.n, 2);
        assert_eq!(s.mean, 2.0);
    }

    #[test]
    fn sketch_bounds_relative_error() {
        // same heavy-tailed u² stream the reservoir test uses, but the
        // sketch's bound is deterministic, not probabilistic
        let mut rng = crate::util::rng::Rng::new(0xA11);
        let mut exact = Vec::new();
        let mut sk = QuantileSketch::new(0.01);
        for _ in 0..100_000 {
            let u = rng.f64();
            let v = u * u;
            exact.push(v);
            sk.record(v);
        }
        let e = Summary::of(&exact);
        let s = sk.summary().unwrap();
        assert_eq!(s.n, e.n);
        assert!((s.mean - e.mean).abs() < 1e-9, "mean is exact");
        assert_eq!(s.min, e.min);
        assert_eq!(s.max, e.max);
        for (pe, ps, name) in [(e.p50, s.p50, "p50"), (e.p95, s.p95, "p95"), (e.p99, s.p99, "p99")]
        {
            let rel = (pe - ps).abs() / pe.max(1e-12);
            assert!(rel <= 0.011, "{name}: exact {pe} vs sketch {ps} (rel {rel:.4})");
        }
    }

    #[test]
    fn sketch_merge_equals_single_stream() {
        let mut rng = crate::util::rng::Rng::new(0x5C);
        let (mut a, mut b, mut whole) =
            (QuantileSketch::default(), QuantileSketch::default(), QuantileSketch::default());
        for i in 0..20_000 {
            let v = rng.exp(1.0) + 1e-3;
            whole.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        // quantiles depend only on bucket counts → exactly equal
        for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            assert_eq!(a.quantile(q), whole.quantile(q), "q={q}");
        }
        assert_eq!(a.to_json().get("zeros").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn sketch_serialization_is_deterministic() {
        let fill = || {
            let mut s = QuantileSketch::default();
            for i in 0..5_000 {
                s.record((i % 313) as f64 * 1e-3);
            }
            s.to_json().to_string()
        };
        assert_eq!(fill(), fill());
    }

    #[test]
    fn sketch_zero_and_negative_land_in_zero_bucket() {
        let mut s = QuantileSketch::default();
        s.record(0.0);
        s.record(-1.0);
        s.record(1e-12);
        s.record(2.0);
        assert_eq!(s.count(), 4);
        assert_eq!(s.quantile(0.0), Some(0.0));
        assert_eq!(s.quantile(1.0), Some(2.0));
        assert_eq!(s.bucket_count(), 1, "only the 2.0 sample holds a log bucket");
    }

    #[test]
    fn sketch_quantiles_monotone_and_bounded_memory() {
        let mut s = QuantileSketch::new(0.01);
        for i in 1..=100_000u64 {
            s.record(i as f64 * 1e-4); // 4 decades
        }
        let q: Vec<f64> = [0.1, 0.5, 0.9, 0.95, 0.99]
            .iter()
            .map(|&q| s.quantile(q).unwrap())
            .collect();
        assert!(q.windows(2).all(|w| w[0] <= w[1]), "monotone: {q:?}");
        assert!(s.bucket_count() < 1200, "4 decades at α=1%: {}", s.bucket_count());
    }
}
