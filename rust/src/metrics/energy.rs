//! Device energy model (Table 5 substitute for `tegrastats`).
//!
//! Energy is proportional to executed work: each decode step costs the
//! device profile's J/token scaled by the fraction of layers actually
//! executed (early exit runs fewer), plus a radio cost per byte moved.
//! This reproduces Table 5's *relative* findings (EE saves energy, PI
//! adds some, Synera nets out ≈ even) from first principles.

/// Energy accounting for one device over one request/benchmark.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Joules for one full-depth decode step on this device profile.
    pub joules_per_token: f64,
    /// Radio energy per transmitted/received byte (J/B).
    pub joules_per_byte: f64,
    total_j: f64,
    tokens: u64,
}

impl EnergyModel {
    pub fn new(joules_per_token: f64, joules_per_byte: f64) -> Self {
        EnergyModel { joules_per_token, joules_per_byte, total_j: 0.0, tokens: 0 }
    }

    /// Record one decode step that executed `layer_fraction` of the model
    /// (1.0 = full depth, e.g. 0.75 when early exit fired at 3/4 layers).
    pub fn record_step(&mut self, layer_fraction: f64) {
        self.total_j += self.joules_per_token * layer_fraction;
        self.tokens += 1;
    }

    /// Record `n` decode steps at one `layer_fraction` — bulk form of
    /// [`EnergyModel::record_step`] for fleet-scale accounting (one call
    /// per committed chunk instead of one per token).
    pub fn record_steps(&mut self, n: u64, layer_fraction: f64) {
        self.total_j += self.joules_per_token * layer_fraction * n as f64;
        self.tokens += n;
    }

    /// Record radio activity (uplink + downlink bytes).
    pub fn record_bytes(&mut self, bytes: u64) {
        self.total_j += self.joules_per_byte * bytes as f64;
    }

    pub fn total_joules(&self) -> f64 {
        self.total_j
    }

    pub fn joules_per_generated_token(&self) -> f64 {
        if self.tokens == 0 {
            0.0
        } else {
            self.total_j / self.tokens as f64
        }
    }

    pub fn reset(&mut self) {
        self.total_j = 0.0;
        self.tokens = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn early_exit_saves_energy() {
        let mut full = EnergyModel::new(1.86, 0.0);
        let mut ee = EnergyModel::new(1.86, 0.0);
        for _ in 0..100 {
            full.record_step(1.0);
            ee.record_step(0.75);
        }
        assert!(ee.total_joules() < full.total_joules());
        assert!((full.joules_per_generated_token() - 1.86).abs() < 1e-9);
    }

    #[test]
    fn bulk_steps_match_single_steps() {
        let mut one = EnergyModel::new(1.3, 0.0);
        let mut bulk = EnergyModel::new(1.3, 0.0);
        for _ in 0..7 {
            one.record_step(0.8);
        }
        bulk.record_steps(7, 0.8);
        assert!((one.total_joules() - bulk.total_joules()).abs() < 1e-12);
        assert_eq!(
            one.joules_per_generated_token(),
            bulk.joules_per_generated_token()
        );
    }

    #[test]
    fn radio_energy_accumulates() {
        let mut e = EnergyModel::new(0.0, 1e-6);
        e.record_bytes(1_000_000);
        assert!((e.total_joules() - 1.0).abs() < 1e-9);
    }
}
