//! Generation-quality metrics: Rouge-1 F1 over token ids and exact-match
//! accuracy — the paper's Table 2 metric assignment (CSQA/SST2/LLQA →
//! accuracy, summarisation/QA-generation → Rouge-1).

use std::collections::BTreeMap;

use crate::workload::synthlang::Sample;

/// Rouge-1 F1 between predicted and reference token sequences, on the
/// same 0–1 scale the paper reports as 0–100%.
pub fn rouge1(pred: &[u32], reference: &[u32]) -> f64 {
    if pred.is_empty() || reference.is_empty() {
        return 0.0;
    }
    let mut cp: BTreeMap<u32, usize> = BTreeMap::new();
    let mut cr: BTreeMap<u32, usize> = BTreeMap::new();
    for &t in pred {
        *cp.entry(t).or_insert(0) += 1;
    }
    for &t in reference {
        *cr.entry(t).or_insert(0) += 1;
    }
    let overlap: usize = cr
        .iter()
        .map(|(t, &n)| n.min(cp.get(t).copied().unwrap_or(0)))
        .sum();
    if overlap == 0 {
        return 0.0;
    }
    let p = overlap as f64 / pred.len() as f64;
    let r = overlap as f64 / reference.len() as f64;
    2.0 * p * r / (p + r)
}

/// Exact-match on the first answer token (classification tasks decode a
/// single label/value token).
pub fn accuracy(pred: &[u32], reference: &[u32]) -> f64 {
    if pred.first() == reference.first() && !reference.is_empty() {
        1.0
    } else {
        0.0
    }
}

/// Task-appropriate quality score for a generated continuation.
pub fn score_sample(sample: &Sample, generated: &[u32]) -> f64 {
    if sample.task.is_classification() {
        accuracy(generated, &sample.answer)
    } else {
        rouge1(generated, &sample.answer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rouge_perfect_and_empty() {
        assert_eq!(rouge1(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(rouge1(&[], &[1]), 0.0);
        assert_eq!(rouge1(&[1], &[]), 0.0);
        assert_eq!(rouge1(&[4, 5], &[1, 2]), 0.0);
    }

    #[test]
    fn rouge_partial_overlap() {
        // pred {1,2}, ref {2,3}: overlap 1, p=0.5, r=0.5 → f1=0.5
        assert!((rouge1(&[1, 2], &[2, 3]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rouge_counts_multiplicity() {
        // pred [7,7], ref [7]: overlap 1, p=0.5, r=1 → 2/3
        assert!((rouge1(&[7, 7], &[7]) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn rouge_order_invariant() {
        assert_eq!(rouge1(&[1, 2, 3], &[3, 2, 1]), 1.0);
    }

    #[test]
    fn accuracy_first_token() {
        assert_eq!(accuracy(&[5, 9], &[5]), 1.0);
        assert_eq!(accuracy(&[9, 5], &[5]), 0.0);
        assert_eq!(accuracy(&[], &[5]), 0.0);
    }
}
