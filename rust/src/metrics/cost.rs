//! Cloud serving cost model (paper §6.1): `c = (1/Pf) × T × W` where `Pf`
//! is the packing factor (concurrent model instances per cluster, a unit
//! cost proxy from Cocktail/Tabi), `T` the average TBT and `W` the average
//! fraction of tokens generated on the cloud for the dataset. With a
//! multi-replica cloud, cross-replica KV migration traffic is charged
//! on top at [`MIGRATION_COST_PER_BYTE`] — rebalancing is not free, and
//! a policy that thrashes sessions between replicas must show up in `c`.

use std::collections::BTreeMap;

/// Packing factors for the model zoo, mirroring the *relative* ladder of
/// the paper's Table 3 (Pf normalised by the largest model; smaller
/// models pack exponentially better).
#[derive(Debug, Clone)]
pub struct PackingFactors {
    map: BTreeMap<String, f64>,
}

impl Default for PackingFactors {
    fn default() -> Self {
        // Derived from parameter ratios the same way the paper's Table 3
        // does for Llama-2 (Pf 1 / 6 / 13 / 86 / 558): Pf ≈ P_largest / P.
        let mut map = BTreeMap::new();
        map.insert("l70b".into(), 1.0);
        map.insert("l13b".into(), 6.0);
        map.insert("s7b".into(), 13.0);
        map.insert("s1b".into(), 86.0);
        map.insert("s160m".into(), 558.0);
        PackingFactors { map }
    }
}

impl PackingFactors {
    pub fn get(&self, model: &str) -> f64 {
        // quantized variants pack like their base model
        let base = model.split('_').next().unwrap_or(model);
        self.map.get(base).copied().unwrap_or(1.0)
    }
}

/// Cost units charged per byte of cross-replica KV migration traffic
/// (same arbitrary unit scale as the base `c`; intra-cluster bytes are
/// cheap relative to model compute, but not free).
pub const MIGRATION_COST_PER_BYTE: f64 = 1e-9;

/// Accumulates cloud-side work and produces the paper's estimated cost.
#[derive(Debug, Clone, Default)]
pub struct CostModel {
    /// Tokens processed by the cloud LLM (prefill+verify+decode).
    pub cloud_tokens: u64,
    /// Tokens in the final generations (denominator for W).
    pub generated_tokens: u64,
    /// Mean time-between-tokens observed end to end (seconds).
    pub mean_tbt_s: f64,
    /// Which cloud model served the requests.
    pub cloud_model: String,
    /// Cross-replica KV migration wire bytes (router rebalancing).
    pub migration_bytes: u64,
}

impl CostModel {
    pub fn new(cloud_model: &str) -> Self {
        CostModel { cloud_model: cloud_model.to_string(), ..Default::default() }
    }

    /// `W`: average fraction of generated tokens that required cloud work.
    pub fn w(&self) -> f64 {
        if self.generated_tokens == 0 {
            return 0.0;
        }
        self.cloud_tokens as f64 / self.generated_tokens as f64
    }

    /// Estimated cost `c = (1/Pf) × T × W + migration` (arbitrary
    /// units; compare across methods, not absolutely). The migration
    /// term charges router rebalancing traffic at
    /// [`MIGRATION_COST_PER_BYTE`].
    pub fn cost(&self, pf: &PackingFactors) -> f64 {
        (1.0 / pf.get(&self.cloud_model)) * self.mean_tbt_s * self.w()
            + MIGRATION_COST_PER_BYTE * self.migration_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pf_ladder_matches_paper_shape() {
        let pf = PackingFactors::default();
        assert!(pf.get("s160m") > pf.get("s1b"));
        assert!(pf.get("s1b") > pf.get("s7b"));
        assert!(pf.get("s7b") > pf.get("l13b"));
        assert!(pf.get("l13b") > pf.get("l70b"));
        assert_eq!(pf.get("l70b"), 1.0);
    }

    #[test]
    fn quant_variant_uses_base_pf() {
        let pf = PackingFactors::default();
        assert_eq!(pf.get("s7b_bnb4"), pf.get("s7b"));
    }

    #[test]
    fn cost_scales_with_w_and_tbt() {
        let pf = PackingFactors::default();
        let mut c = CostModel::new("l13b");
        c.generated_tokens = 100;
        c.cloud_tokens = 20;
        c.mean_tbt_s = 0.05;
        let cost_low = c.cost(&pf);
        c.cloud_tokens = 100;
        assert!(c.cost(&pf) > cost_low);
        c.mean_tbt_s = 0.10;
        let cost_hi = c.cost(&pf);
        assert!((cost_hi - (1.0 / 6.0) * 0.1 * 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_generation_costs_nothing() {
        let pf = PackingFactors::default();
        let c = CostModel::new("l70b");
        assert_eq!(c.cost(&pf), 0.0);
    }

    #[test]
    fn migration_bytes_are_charged() {
        let pf = PackingFactors::default();
        let mut c = CostModel::new("l13b");
        c.generated_tokens = 100;
        c.cloud_tokens = 20;
        c.mean_tbt_s = 0.05;
        let base = c.cost(&pf);
        c.migration_bytes = 1_000_000;
        let with_migration = c.cost(&pf);
        assert!(with_migration > base, "migrated bytes must raise the cost");
        assert!(
            (with_migration - base - MIGRATION_COST_PER_BYTE * 1e6).abs() < 1e-15,
            "the delta is exactly the priced bytes"
        );
    }
}
