//! SynthLang vocabulary layout — MUST match `python/compile/synthlang.py`.

pub const VOCAB: usize = 512;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
pub const SEP: u32 = 3;
pub const QUERY: u32 = 4;

pub const TM_KGQA: u32 = 10;
pub const TM_SENT: u32 = 11;
pub const TM_SUM: u32 = 12;
pub const TM_XSUM: u32 = 13;
pub const TM_LLQA: u32 = 14;
pub const TM_HEY: u32 = 15;
pub const TM_SENSOR: u32 = 16;

pub const POS_TOK: u32 = 20;
pub const NEG_TOK: u32 = 21;
pub const AGG_MODE: u32 = 24;
pub const UNIT: u32 = 25;

pub const SLOT0: u32 = 30;
pub const N_SLOTS: u64 = 16;
pub const ACT0: u32 = 50;
pub const N_ACTS: u64 = 32;
pub const ENT0: u32 = 100;
pub const N_ENTS: u64 = 48;
pub const REL0: u32 = 170;
pub const N_RELS: u64 = 8;
pub const VAL0: u32 = 200;
pub const N_VALS: u64 = 128;
pub const TOPIC0: u32 = 350;
pub const N_TOPICS: u64 = 24;
pub const FILL0: u32 = 400;
pub const N_FILLS: u64 = 112;

pub const N_KEYWORDS: u64 = 8;

/// Fixed world identity ("SYNERA!"), mirror of `synthlang.WORLD_SEED`.
pub const WORLD_SEED: u64 = 0x0053_594E_4552_4121;
