//! Multi-user request traces for the scalability experiments (Fig. 15).
//!
//! Poisson arrivals of evaluation samples from a task mix, attributed to
//! a population of simulated devices.

use crate::util::rng::Rng;
use crate::workload::synthlang::{generate, Sample, Task, TASKS};

/// One request in an open-loop arrival trace.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Arrival time in seconds from trace start.
    pub at_s: f64,
    /// Originating device id `0..n_devices`.
    pub device: usize,
    pub sample: Sample,
}

/// Open-loop Poisson trace: `rate_rps` requests/second across `n_devices`.
pub fn poisson_trace(
    seed: u64,
    n_devices: usize,
    rate_rps: f64,
    duration_s: f64,
    tasks: &[Task],
) -> Vec<TraceEvent> {
    assert!(!tasks.is_empty() && n_devices > 0 && rate_rps > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::new();
    let mut idx = 0u64;
    while t < duration_s {
        t += rng.exp(rate_rps);
        if t >= duration_s {
            break;
        }
        let task = tasks[rng.below(tasks.len() as u64) as usize];
        let device = rng.below(n_devices as u64) as usize;
        out.push(TraceEvent { at_s: t, device, sample: generate(task, 1, 1000 + idx) });
        idx += 1;
    }
    out
}

/// Fixed-size eval set for a dataset (deterministic, held-out split).
pub fn eval_set(task: Task, n: usize) -> Vec<Sample> {
    (0..n as u64).map(|i| generate(task, 1, i)).collect()
}

/// A balanced mixed-task eval set (used by profiling and cost experiments).
pub fn mixed_eval_set(n_per_task: usize) -> Vec<Sample> {
    let mut v = Vec::new();
    for t in TASKS {
        v.extend(eval_set(t, n_per_task));
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_calibrated() {
        let tr = poisson_trace(1, 4, 50.0, 20.0, &[Task::Xsum]);
        let rate = tr.len() as f64 / 20.0;
        assert!((rate - 50.0).abs() < 5.0, "rate {rate}");
        // arrivals are sorted and in range
        for w in tr.windows(2) {
            assert!(w[0].at_s <= w[1].at_s);
        }
        assert!(tr.iter().all(|e| e.device < 4));
    }

    #[test]
    fn trace_is_deterministic() {
        let a = poisson_trace(7, 2, 5.0, 10.0, &TASKS);
        let b = poisson_trace(7, 2, 5.0, 10.0, &TASKS);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.device, y.device);
            assert_eq!(x.sample.prompt, y.sample.prompt);
        }
    }

    #[test]
    fn eval_set_distinct_and_stable() {
        let s = eval_set(Task::Cnndm, 16);
        assert_eq!(s.len(), 16);
        assert!(s.windows(2).any(|w| w[0].prompt != w[1].prompt));
    }
}
