//! Multi-user request traces for the scalability experiments (Fig. 15)
//! and the fleet simulator (`crate::sim`).
//!
//! Arrivals of evaluation samples from a task mix, attributed to a
//! population of simulated devices: homogeneous Poisson
//! ([`poisson_trace`]) or a two-state Markov-modulated Poisson process
//! ([`mmpp_trace`]) for flash-crowd scenarios.

use crate::util::rng::Rng;
use crate::workload::synthlang::{generate, Sample, Task, TASKS};

/// One request in an open-loop arrival trace.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Arrival time in seconds from trace start.
    pub at_s: f64,
    /// Originating device id `0..n_devices`.
    pub device: usize,
    pub sample: Sample,
}

/// Open-loop Poisson trace: `rate_rps` requests/second across `n_devices`.
pub fn poisson_trace(
    seed: u64,
    n_devices: usize,
    rate_rps: f64,
    duration_s: f64,
    tasks: &[Task],
) -> Vec<TraceEvent> {
    assert!(!tasks.is_empty() && n_devices > 0 && rate_rps > 0.0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::new();
    let mut idx = 0u64;
    while t < duration_s {
        t += rng.exp(rate_rps);
        if t >= duration_s {
            break;
        }
        let task = tasks[rng.below(tasks.len() as u64) as usize];
        let device = rng.below(n_devices as u64) as usize;
        out.push(TraceEvent { at_s: t, device, sample: generate(task, 1, 1000 + idx) });
        idx += 1;
    }
    out
}

/// Two-state Markov-modulated Poisson arrival profile: the trace
/// alternates between a *quiet* and a *burst* regime, each holding for
/// an exponentially distributed dwell time, with Poisson arrivals at
/// the regime's rate while it holds. The long-run offered rate is
/// `(quiet_rps·mean_quiet_s + burst_rps·mean_burst_s) /
/// (mean_quiet_s + mean_burst_s)`.
#[derive(Debug, Clone, Copy)]
pub struct BurstProfile {
    /// Arrival rate in the quiet regime (req/s; may be 0).
    pub quiet_rps: f64,
    /// Arrival rate in the burst regime (req/s).
    pub burst_rps: f64,
    /// Mean dwell time of the quiet regime (s).
    pub mean_quiet_s: f64,
    /// Mean dwell time of the burst regime (s).
    pub mean_burst_s: f64,
}

impl BurstProfile {
    /// Long-run average offered rate (req/s).
    pub fn mean_rps(&self) -> f64 {
        (self.quiet_rps * self.mean_quiet_s + self.burst_rps * self.mean_burst_s)
            / (self.mean_quiet_s + self.mean_burst_s)
    }

    /// A flash-crowd profile averaging `rate_rps`: quiet at 40% of the
    /// mean for 8 s spells, bursting to ~4× the mean for 2 s spells.
    pub fn flash_crowd(rate_rps: f64) -> BurstProfile {
        let (mq, mb) = (8.0, 2.0);
        let quiet = 0.4 * rate_rps;
        // solve burst_rps so mean_rps() == rate_rps
        let burst = (rate_rps * (mq + mb) - quiet * mq) / mb;
        BurstProfile { quiet_rps: quiet, burst_rps: burst, mean_quiet_s: mq, mean_burst_s: mb }
    }
}

/// Open-loop bursty trace (two-state MMPP, starting in the quiet
/// regime). Deterministic given the seed; arrivals are sorted. Regime
/// switches exploit the memorylessness of the exponential: a candidate
/// arrival falling past the regime boundary is discarded and redrawn
/// under the next regime, which leaves the process exact.
pub fn mmpp_trace(
    seed: u64,
    n_devices: usize,
    profile: &BurstProfile,
    duration_s: f64,
    tasks: &[Task],
) -> Vec<TraceEvent> {
    assert!(!tasks.is_empty() && n_devices > 0);
    assert!(
        profile.quiet_rps >= 0.0 && profile.burst_rps > 0.0,
        "burst regime must have a positive rate"
    );
    assert!(profile.mean_quiet_s > 0.0 && profile.mean_burst_s > 0.0);
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut burst = false;
    let mut regime_end = rng.exp(1.0 / profile.mean_quiet_s);
    let mut idx = 0u64;
    while t < duration_s {
        let rate = if burst { profile.burst_rps } else { profile.quiet_rps };
        let cand = if rate > 0.0 { t + rng.exp(rate) } else { f64::INFINITY };
        if cand >= regime_end {
            t = regime_end;
            burst = !burst;
            let dwell = if burst { profile.mean_burst_s } else { profile.mean_quiet_s };
            regime_end = t + rng.exp(1.0 / dwell);
            continue;
        }
        t = cand;
        if t >= duration_s {
            break;
        }
        let task = tasks[rng.below(tasks.len() as u64) as usize];
        let device = rng.below(n_devices as u64) as usize;
        out.push(TraceEvent { at_s: t, device, sample: generate(task, 1, 5000 + idx) });
        idx += 1;
    }
    out
}

/// Fixed-size eval set for a dataset (deterministic, held-out split).
pub fn eval_set(task: Task, n: usize) -> Vec<Sample> {
    (0..n as u64).map(|i| generate(task, 1, i)).collect()
}

/// A balanced mixed-task eval set (used by profiling and cost experiments).
pub fn mixed_eval_set(n_per_task: usize) -> Vec<Sample> {
    let mut v = Vec::new();
    for t in TASKS {
        v.extend(eval_set(t, n_per_task));
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_calibrated() {
        let tr = poisson_trace(1, 4, 50.0, 20.0, &[Task::Xsum]);
        let rate = tr.len() as f64 / 20.0;
        assert!((rate - 50.0).abs() < 5.0, "rate {rate}");
        // arrivals are sorted and in range
        for w in tr.windows(2) {
            assert!(w[0].at_s <= w[1].at_s);
        }
        assert!(tr.iter().all(|e| e.device < 4));
    }

    #[test]
    fn trace_is_deterministic() {
        let a = poisson_trace(7, 2, 5.0, 10.0, &TASKS);
        let b = poisson_trace(7, 2, 5.0, 10.0, &TASKS);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.device, y.device);
            assert_eq!(x.sample.prompt, y.sample.prompt);
        }
    }

    #[test]
    fn mmpp_trace_is_deterministic() {
        let p = BurstProfile::flash_crowd(20.0);
        let a = mmpp_trace(11, 8, &p, 30.0, &TASKS);
        let b = mmpp_trace(11, 8, &p, 30.0, &TASKS);
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
            assert_eq!(x.device, y.device);
            assert_eq!(x.sample.prompt, y.sample.prompt);
        }
        for w in a.windows(2) {
            assert!(w[0].at_s <= w[1].at_s, "arrivals sorted");
        }
        assert!(a.iter().all(|e| e.device < 8 && e.at_s < 30.0));
    }

    #[test]
    fn mmpp_rate_is_calibrated_and_bursty() {
        let p = BurstProfile {
            quiet_rps: 2.0,
            burst_rps: 50.0,
            mean_quiet_s: 5.0,
            mean_burst_s: 1.0,
        };
        // expected long-run rate: (2·5 + 50·1)/6 = 10 req/s
        assert!((p.mean_rps() - 10.0).abs() < 1e-12);
        let dur = 3000.0;
        let tr = mmpp_trace(5, 4, &p, dur, &[Task::Xsum]);
        let rate = tr.len() as f64 / dur;
        assert!((rate - 10.0).abs() < 1.5, "long-run rate {rate}");
        // burstiness: per-second arrival counts must be overdispersed
        // relative to Poisson (index of dispersion ≫ 1)
        let mut counts = vec![0usize; dur as usize];
        for e in &tr {
            counts[e.at_s as usize] += 1;
        }
        let n = counts.len() as f64;
        let mean = counts.iter().sum::<usize>() as f64 / n;
        let var = counts.iter().map(|&c| (c as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(var / mean > 2.0, "dispersion {:.2} not bursty", var / mean);
    }

    #[test]
    fn flash_crowd_profile_hits_target_mean() {
        for r in [1.0, 16.0, 250.0] {
            let p = BurstProfile::flash_crowd(r);
            assert!((p.mean_rps() - r).abs() < 1e-9, "rate {r}");
            assert!(p.burst_rps > p.quiet_rps);
        }
    }

    #[test]
    fn eval_set_distinct_and_stable() {
        let s = eval_set(Task::Cnndm, 16);
        assert_eq!(s.len(), 16);
        assert!(s.windows(2).any(|w| w[0].prompt != w[1].prompt));
    }
}
