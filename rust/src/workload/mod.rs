//! Workloads: the SynthLang task suite (mirror of
//! `python/compile/synthlang.py`, verified against
//! `artifacts/golden_workload.json`) plus multi-user request traces.

pub mod synthlang;
pub mod trace;
pub mod vocab;

pub use synthlang::{generate, Sample, Task, TASKS};
