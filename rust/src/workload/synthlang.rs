//! SynthLang generators — exact mirror of `python/compile/synthlang.py`.
//!
//! `generate(task, split, index)` must produce byte-identical samples to
//! the Python side (same splitmix64 draws in the same order); the
//! integration test `tests/golden.rs` replays
//! `artifacts/golden_workload.json` to enforce this.

use crate::util::rng::{hash2, Rng};
use crate::workload::vocab::*;

/// The seven evaluation datasets (paper Table 2 stand-ins).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Kgqa,
    Sst2,
    Cnndm,
    Xsum,
    Llqa,
    Heysquad,
    Sensorqa,
}

pub const TASKS: [Task; 7] = [
    Task::Kgqa,
    Task::Sst2,
    Task::Cnndm,
    Task::Xsum,
    Task::Llqa,
    Task::Heysquad,
    Task::Sensorqa,
];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::Kgqa => "kgqa",
            Task::Sst2 => "sst2",
            Task::Cnndm => "cnndm",
            Task::Xsum => "xsum",
            Task::Llqa => "llqa",
            Task::Heysquad => "heysquad",
            Task::Sensorqa => "sensorqa",
        }
    }

    pub fn from_name(s: &str) -> Option<Task> {
        TASKS.iter().copied().find(|t| t.name() == s)
    }

    pub fn index(&self) -> u64 {
        TASKS.iter().position(|t| t == self).unwrap() as u64
    }

    /// Paper Table 2: CSQA/SST2/LLQA report accuracy, the rest Rouge-1.
    pub fn is_classification(&self) -> bool {
        matches!(self, Task::Kgqa | Task::Sst2 | Task::Llqa)
    }

    /// Paper's display name for report tables.
    pub fn paper_name(&self) -> &'static str {
        match self {
            Task::Kgqa => "CSQA",
            Task::Sst2 => "SST2",
            Task::Cnndm => "CNNDM",
            Task::Xsum => "XSum",
            Task::Llqa => "LLQA",
            Task::Heysquad => "HeySQuAD",
            Task::Sensorqa => "SensorQA",
        }
    }
}

/// One evaluation sample: prompt tokens and the reference answer.
#[derive(Debug, Clone)]
pub struct Sample {
    pub task: Task,
    pub prompt: Vec<u32>,
    pub answer: Vec<u32>,
}

// ----------------------------- static world --------------------------------

/// Knowledge-graph fact table: value token for (entity, relation) indices.
pub fn kg_value(ent: u64, rel: u64) -> u32 {
    VAL0 + (hash2(WORLD_SEED, ent * N_RELS + rel, 0x4B47) % N_VALS) as u32
}

pub fn topic_keyword(topic: u64, i: u64) -> u32 {
    VAL0 + (hash2(WORLD_SEED, topic * N_KEYWORDS + i, 0x544F) % N_VALS) as u32
}

/// 0 = negative-leaning, 1 = positive-leaning.
pub fn value_polarity(val_tok: u32) -> u64 {
    hash2(WORLD_SEED, val_tok as u64, 0x504F) % 2
}

pub fn sample_seed(task_idx: u64, split: u64, index: u64) -> u64 {
    WORLD_SEED ^ task_idx.wrapping_mul(0x0100_0003) ^ (split << 40) ^ index
}

// ------------------------------ generators ---------------------------------

fn gen_kgqa(rng: &mut Rng) -> Sample {
    let ent = ENT0 + rng.below(N_ENTS) as u32;
    let rel = REL0 + rng.below(N_RELS) as u32;
    Sample {
        task: Task::Kgqa,
        prompt: vec![TM_KGQA, QUERY, ent, rel, SEP],
        answer: vec![kg_value((ent - ENT0) as u64, (rel - REL0) as u64)],
    }
}

fn gen_sst2(rng: &mut Rng) -> Sample {
    let n = 8 + rng.below(5);
    let label = rng.below(2);
    let mut words = Vec::with_capacity(n as usize);
    for _ in 0..n {
        let w = if rng.chance(7, 10) {
            loop {
                let w = VAL0 + rng.below(N_VALS) as u32;
                if value_polarity(w) == label {
                    break w;
                }
            }
        } else {
            VAL0 + rng.below(N_VALS) as u32
        };
        words.push(w);
    }
    let pos: u64 = words.iter().map(|w| value_polarity(*w)).sum();
    let lab = if 2 * pos > words.len() as u64 { POS_TOK } else { NEG_TOK };
    let mut prompt = vec![TM_SENT];
    prompt.extend_from_slice(&words);
    prompt.push(SEP);
    Sample { task: Task::Sst2, prompt, answer: vec![lab] }
}

fn doc_sentences(rng: &mut Rng, n_sents: u64) -> (Vec<[u32; 4]>, Vec<u64>) {
    let mut sents = Vec::new();
    let mut ents = Vec::new();
    for _ in 0..n_sents {
        let e = rng.below(N_ENTS);
        let r = rng.below(N_RELS);
        ents.push(e);
        sents.push([
            ENT0 + e as u32,
            REL0 + r as u32,
            kg_value(e, r),
            FILL0 + rng.below(N_FILLS) as u32,
        ]);
    }
    (sents, ents)
}

fn gen_cnndm(rng: &mut Rng) -> Sample {
    let topic = rng.below(N_TOPICS);
    let n = 4 + rng.below(3);
    let (sents, _) = doc_sentences(rng, n);
    let mut prompt = vec![TM_SUM, TOPIC0 + topic as u32];
    for s in &sents {
        prompt.extend_from_slice(s);
    }
    prompt.push(SEP);
    let answer = (0..N_KEYWORDS).map(|i| topic_keyword(topic, i)).collect();
    Sample { task: Task::Cnndm, prompt, answer }
}

fn gen_xsum(rng: &mut Rng) -> Sample {
    let topic = rng.below(N_TOPICS);
    let n = 4 + rng.below(3);
    let (sents, ents) = doc_sentences(rng, n);
    let mut prompt = vec![TM_XSUM, TOPIC0 + topic as u32];
    for s in &sents {
        prompt.extend_from_slice(s);
    }
    prompt.push(SEP);
    // majority entity, ties toward larger count then smaller id — mirror of
    // python's max(set(ents), key=lambda e: (ents.count(e), -e))
    let mut uniq: Vec<u64> = Vec::new();
    for e in &ents {
        if !uniq.contains(e) {
            uniq.push(*e);
        }
    }
    let e_major = uniq
        .iter()
        .copied()
        .max_by_key(|e| {
            let cnt = ents.iter().filter(|x| *x == e).count() as i64;
            (cnt, -(*e as i64))
        })
        .unwrap();
    let rot = e_major % 4;
    let answer = (0..4)
        .map(|i| topic_keyword(topic, (rot + i) % N_KEYWORDS))
        .collect();
    Sample { task: Task::Xsum, prompt, answer }
}

fn gen_llqa(rng: &mut Rng) -> Sample {
    let n = (6 + rng.below(5)) as usize;
    let mut slots: Vec<u64> = (0..N_SLOTS).collect();
    // fisher-yates, mirror of python (i from N-1 down to 1)
    for i in (1..N_SLOTS as usize).rev() {
        let j = rng.below((i + 1) as u64) as usize;
        slots.swap(i, j);
    }
    let mut chosen: Vec<u64> = slots[..n].to_vec();
    chosen.sort_unstable();
    let mut log = Vec::new();
    let mut acts = std::collections::BTreeMap::new();
    for &s in &chosen {
        let a = rng.below(N_ACTS);
        acts.insert(s, a);
        log.push(SLOT0 + s as u32);
        log.push(ACT0 + a as u32);
    }
    let q = chosen[rng.below(n as u64) as usize];
    let mut prompt = vec![TM_LLQA];
    prompt.extend_from_slice(&log);
    prompt.extend_from_slice(&[QUERY, SLOT0 + q as u32, SEP]);
    Sample { task: Task::Llqa, prompt, answer: vec![ACT0 + acts[&q] as u32] }
}

fn gen_heysquad(rng: &mut Rng) -> Sample {
    let mut facts = Vec::new();
    for _ in 0..3 {
        let e = rng.below(N_ENTS);
        let r = rng.below(N_RELS);
        facts.push((e, r));
    }
    let mut ctx = Vec::new();
    for &(e, r) in &facts {
        ctx.push(ENT0 + e as u32);
        ctx.push(REL0 + r as u32);
        ctx.push(kg_value(e, r));
        ctx.push(FILL0 + rng.below(N_FILLS) as u32);
    }
    let (qe, qr) = facts[rng.below(3) as usize];
    let answer = vec![kg_value(qe, qr)];
    let noisy: Vec<u32> = ctx
        .iter()
        .map(|&t| {
            // python evaluates the replacement draw BEFORE the chance test?
            // No: `(VAL0 + rng.below(N_VALS)) if rng.chance(1,10) else t`
            // evaluates chance first, then the replacement draw when taken.
            if rng.chance(1, 10) {
                VAL0 + rng.below(N_VALS) as u32
            } else {
                t
            }
        })
        .collect();
    let mut prompt = vec![TM_HEY];
    prompt.extend_from_slice(&noisy);
    prompt.extend_from_slice(&[QUERY, ENT0 + qe as u32, REL0 + qr as u32, SEP]);
    Sample { task: Task::Heysquad, prompt, answer }
}

fn gen_sensorqa(rng: &mut Rng) -> Sample {
    let n_kinds = 3 + rng.below(3);
    let kinds: Vec<u32> = (0..n_kinds).map(|_| VAL0 + rng.below(N_VALS) as u32).collect();
    let n = 10 + rng.below(6);
    let readings: Vec<u32> = (0..n).map(|_| kinds[rng.below(n_kinds) as usize]).collect();
    let mut counts = std::collections::BTreeMap::new();
    for &r in &readings {
        *counts.entry(r).or_insert(0usize) += 1;
    }
    // mode; ties toward smaller token id (mirror of python min by (-count, k))
    let mode = *counts
        .iter()
        .min_by_key(|(k, v)| (-(**v as i64), **k))
        .unwrap()
        .0;
    let mut prompt = vec![TM_SENSOR];
    prompt.extend_from_slice(&readings);
    prompt.extend_from_slice(&[QUERY, AGG_MODE, SEP]);
    Sample { task: Task::Sensorqa, prompt, answer: vec![mode, UNIT] }
}

/// Seed salt for [`shared_preamble`]; disjoint from every `sample_seed`
/// stream so preamble tokens never correlate with sample bodies.
const PREAMBLE_SALT: u64 = 0x5052_4541_4D42_4C45; // "PREAMBLE"

/// Deterministic shared preamble of `len` tokens for preamble family
/// `family` — a stand-in for the system prompts / few-shot headers that
/// real serving traffic repeats verbatim across requests. Same
/// `(family, len)` ⇒ identical token sequence on every call, so two
/// requests drawing the same family share a byte-identical prompt
/// prefix that the cloud's prefix cache can deduplicate. Tokens are
/// plain value tokens: prepending a preamble never changes what a
/// sample's answer means, only where its body starts.
pub fn shared_preamble(family: u64, len: usize) -> Vec<u32> {
    let mut rng = Rng::new(hash2(WORLD_SEED, family, PREAMBLE_SALT));
    (0..len).map(|_| VAL0 + rng.below(N_VALS) as u32).collect()
}

/// Cross-language entry point: same `(task, split, index)` → same sample
/// as `synthlang.generate` in Python. `split`: 0 = train, 1 = eval.
pub fn generate(task: Task, split: u64, index: u64) -> Sample {
    let mut rng = Rng::new(sample_seed(task.index(), split, index));
    match task {
        Task::Kgqa => gen_kgqa(&mut rng),
        Task::Sst2 => gen_sst2(&mut rng),
        Task::Cnndm => gen_cnndm(&mut rng),
        Task::Xsum => gen_xsum(&mut rng),
        Task::Llqa => gen_llqa(&mut rng),
        Task::Heysquad => gen_heysquad(&mut rng),
        Task::Sensorqa => gen_sensorqa(&mut rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        for task in TASKS {
            let a = generate(task, 1, 3);
            let b = generate(task, 1, 3);
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.answer, b.answer);
        }
    }

    #[test]
    fn split_and_index_vary() {
        let a = generate(Task::Kgqa, 1, 0);
        let b = generate(Task::Kgqa, 1, 1);
        let c = generate(Task::Kgqa, 0, 0);
        assert!(a.prompt != b.prompt || a.answer != b.answer);
        assert!(a.prompt != c.prompt || a.answer != c.answer);
    }

    #[test]
    fn prompts_fit_runtime_budget() {
        // device prefill assumes prompt ≤ 40 and prompt+answer ≤ 56 (< max_len 64)
        for task in TASKS {
            for i in 0..200 {
                let s = generate(task, 1, i);
                assert!(s.prompt.len() <= 40, "{} prompt {}", task.name(), s.prompt.len());
                assert!(s.prompt.len() + s.answer.len() <= 56);
                assert!(!s.answer.is_empty());
            }
        }
    }

    #[test]
    fn shared_preamble_is_deterministic_and_family_keyed() {
        let a = shared_preamble(0, 32);
        let b = shared_preamble(0, 32);
        let c = shared_preamble(1, 32);
        assert_eq!(a, b, "same family ⇒ identical preamble");
        assert_ne!(a, c, "families produce distinct preambles");
        assert_eq!(a.len(), 32);
        assert!(a.iter().all(|&t| t >= VAL0 && t < VAL0 + N_VALS as u32));
        // longer request for the same family shares the short one as a prefix
        let long = shared_preamble(0, 48);
        assert_eq!(&long[..32], &a[..]);
    }

    #[test]
    fn kgqa_answer_matches_fact_table() {
        for i in 0..50 {
            let s = generate(Task::Kgqa, 1, i);
            let e = (s.prompt[2] - ENT0) as u64;
            let r = (s.prompt[3] - REL0) as u64;
            assert_eq!(s.answer[0], kg_value(e, r));
        }
    }

    #[test]
    fn sensorqa_mode_is_true_mode() {
        for i in 0..50 {
            let s = generate(Task::Sensorqa, 1, i);
            let readings = &s.prompt[1..s.prompt.len() - 3];
            let mode = s.answer[0];
            let mode_count = readings.iter().filter(|&&t| t == mode).count();
            for &t in readings {
                let c = readings.iter().filter(|&&x| x == t).count();
                assert!(
                    c < mode_count || (c == mode_count && mode <= t),
                    "mode {mode} not maximal vs {t}"
                );
            }
        }
    }

    #[test]
    fn sst2_label_is_majority_polarity() {
        for i in 0..50 {
            let s = generate(Task::Sst2, 1, i);
            let words = &s.prompt[1..s.prompt.len() - 1];
            let pos: u64 = words.iter().map(|w| value_polarity(*w)).sum();
            let expect = if 2 * pos > words.len() as u64 { POS_TOK } else { NEG_TOK };
            assert_eq!(s.answer[0], expect);
        }
    }
}
