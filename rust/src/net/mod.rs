//! Device↔cloud network substrate.
//!
//! The paper's testbed varies real Wi-Fi/LTE links from 0.1 to 100 Mbps;
//! here a [`SimLink`] computes transfer delays from the *actual
//! serialized payload sizes* (bandwidth × bytes + RTT/2 per direction),
//! which is exactly the arithmetic those experiments measure. The wire
//! format lives in [`wire`]; top-k distribution compression (paper §4.2
//! "Compression before transmission") in [`super::device::codec`].

pub mod link;
pub mod wire;

pub use link::{LinkProfile, SimLink};
pub use wire::{DownlinkMsg, UplinkMsg};
