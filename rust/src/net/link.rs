//! Bandwidth + RTT link model.

/// Static link parameters.
#[derive(Debug, Clone, Copy)]
pub struct LinkProfile {
    pub bandwidth_mbps: f64,
    pub rtt_ms: f64,
    /// Per-message probability of a retransmission-equivalent delay spike.
    pub loss: f64,
}

impl LinkProfile {
    pub fn new(bandwidth_mbps: f64, rtt_ms: f64) -> Self {
        LinkProfile { bandwidth_mbps, rtt_ms, loss: 0.0 }
    }

    /// The paper's default evaluation link (§6: typical 10 Mbps).
    pub fn wifi() -> Self {
        LinkProfile::new(10.0, 20.0)
    }

    pub fn lte() -> Self {
        LinkProfile::new(5.0, 50.0)
    }

    /// Severely constrained (Fig. 13 leftmost point).
    pub fn constrained(mbps: f64) -> Self {
        LinkProfile::new(mbps, 40.0)
    }

    /// Deterministic heterogeneous-population link for fleet device
    /// `idx`: ~70% Wi-Fi, ~20% LTE, ~10% constrained stragglers. Keeps
    /// large simulated fleets from all sharing one idealised link
    /// without introducing another RNG stream.
    pub fn fleet_mix(idx: usize) -> Self {
        match idx % 10 {
            0..=6 => LinkProfile::wifi(),
            7 | 8 => LinkProfile::lte(),
            _ => LinkProfile::constrained(1.0),
        }
    }
}

/// A simulated half-duplex link; returns *delays* so callers can either
/// sleep them (threaded mode) or add them to a virtual clock (timeline
/// mode). Deterministic given the seed.
#[derive(Debug, Clone)]
pub struct SimLink {
    pub profile: LinkProfile,
    rng: crate::util::rng::Rng,
    pub bytes_up: u64,
    pub bytes_down: u64,
}

impl SimLink {
    pub fn new(profile: LinkProfile, seed: u64) -> Self {
        SimLink { profile, rng: crate::util::rng::Rng::new(seed), bytes_up: 0, bytes_down: 0 }
    }

    fn transfer_s(&mut self, bytes: usize) -> f64 {
        let bw_bytes_per_s = self.profile.bandwidth_mbps * 1e6 / 8.0;
        let mut d = self.profile.rtt_ms / 2.0 / 1e3 + bytes as f64 / bw_bytes_per_s;
        if self.profile.loss > 0.0 && self.rng.f64() < self.profile.loss {
            d += self.profile.rtt_ms / 1e3; // one retransmission round
        }
        d
    }

    /// Delay to move `bytes` device → cloud.
    pub fn uplink_s(&mut self, bytes: usize) -> f64 {
        self.bytes_up += bytes as u64;
        self.transfer_s(bytes)
    }

    /// Delay to move `bytes` cloud → device.
    pub fn downlink_s(&mut self, bytes: usize) -> f64 {
        self.bytes_down += bytes as u64;
        self.transfer_s(bytes)
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_up + self.bytes_down
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delay_arithmetic() {
        let mut l = SimLink::new(LinkProfile::new(8.0, 20.0), 1);
        // 8 Mbps = 1e6 B/s; 10 KB → 10 ms + half-RTT 10 ms = 20 ms
        let d = l.uplink_s(10_000);
        assert!((d - 0.020).abs() < 1e-9, "{d}");
        assert_eq!(l.bytes_up, 10_000);
    }

    #[test]
    fn narrow_link_dominates() {
        let mut slow = SimLink::new(LinkProfile::constrained(0.1), 1);
        let mut fast = SimLink::new(LinkProfile::constrained(100.0), 1);
        assert!(slow.uplink_s(5000) > 15.0 * fast.uplink_s(5000)); // RTT floors the fast link
    }

    #[test]
    fn fleet_mix_is_heterogeneous_and_deterministic() {
        let n_wifi = (0..100).filter(|&i| LinkProfile::fleet_mix(i).bandwidth_mbps == 10.0).count();
        let n_slow = (0..100).filter(|&i| LinkProfile::fleet_mix(i).bandwidth_mbps == 1.0).count();
        assert_eq!(n_wifi, 70);
        assert_eq!(n_slow, 10);
    }

    #[test]
    fn loss_adds_delay_deterministically() {
        let p = LinkProfile { bandwidth_mbps: 10.0, rtt_ms: 20.0, loss: 1.0 };
        let mut l = SimLink::new(p, 3);
        let mut base = SimLink::new(LinkProfile::new(10.0, 20.0), 3);
        assert!(l.uplink_s(100) > base.uplink_s(100));
    }
}
