//! Wire format for device↔cloud messages.
//!
//! Hand-rolled, length-prefixed little-endian encoding; the byte counts
//! these encoders produce are what [`super::SimLink`] charges against the
//! link — the compression ablation (Fig. 13) is therefore measured on
//! real payloads, not estimates. The same discipline covers the
//! cloud-internal [`KvMigrateMsg`]: cross-replica session migration is
//! priced over its real encoding, not a per-row guess.

use anyhow::{bail, Result};

use crate::runtime::SlotKv;

/// One draft token's probability distribution, as shipped to the verifier.
#[derive(Debug, Clone, PartialEq)]
pub enum Dist {
    /// Full dense distribution over the vocabulary (no compression).
    Dense(Vec<f32>),
    /// Top-k sparse distribution (paper §4.2): token ids + f16 probs.
    /// Sound for verification because sampling was already restricted to
    /// these candidates (greedy/top-k/top-p).
    TopK { ids: Vec<u16>, probs_f16: Vec<u16> },
}

impl Dist {
    pub fn prob_of(&self, token: u32) -> f32 {
        match self {
            Dist::Dense(p) => p.get(token as usize).copied().unwrap_or(0.0),
            Dist::TopK { ids, probs_f16 } => ids
                .iter()
                .position(|&i| i as u32 == token)
                .map(|j| f16_to_f32(probs_f16[j]))
                .unwrap_or(0.0),
        }
    }
}

/// Causal trace context carried on device→cloud messages. Together
/// with the message's `request_id` this identifies exactly which
/// offload round of which device request a piece of cloud work belongs
/// to, so cloud-side trace events can be joined back to the
/// originating device span (Chrome trace-event flow arrows, `synera
/// inspect`).
///
/// `parent_span` is the flow id binding the device-side round span to
/// the cloud events it caused; [`TraceContext::for_round`] derives it
/// deterministically so both ends agree without a handshake. A
/// default (all-zero) context means "untraced" and costs nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TraceContext {
    /// 0-based offload round within the request.
    pub round: u32,
    /// Flow/span id of the originating device-side round (0 = none).
    pub parent_span: u64,
}

impl TraceContext {
    /// Bytes this context adds to an uplink encoding.
    pub const WIRE_BYTES: usize = 4 + 8;

    /// Context for offload round `round` of `request_id`, with the
    /// deterministic flow id both sides of the wire agree on.
    pub fn for_round(request_id: u64, round: u32) -> TraceContext {
        TraceContext { round, parent_span: Self::flow_id(request_id, round) }
    }

    /// Deterministic nonzero flow id for one offload round. The high
    /// bit keeps flow ids disjoint from raw request ids (a separate id
    /// namespace in the trace); rounds wrap at 2^16, which aliases
    /// only for requests exceeding 65536 offload rounds.
    pub fn flow_id(request_id: u64, round: u32) -> u64 {
        (1u64 << 63) | (request_id << 16) | (round as u64 & 0xFFFF)
    }

    /// Inverse of [`flow_id`](Self::flow_id): the request id a flow id
    /// belongs to, or `None` when `id` is not in the flow namespace
    /// (high bit clear — a raw request/session id or 0). The trace
    /// sampler uses this to attribute flow-arrow events to the request
    /// whose round they annotate.
    pub fn request_of_flow(id: u64) -> Option<u64> {
        if id >> 63 == 1 {
            Some((id & !(1u64 << 63)) >> 16)
        } else {
            None
        }
    }
}

/// Device → cloud verification request (paper Fig. 7).
#[derive(Debug, Clone, PartialEq)]
pub struct UplinkMsg {
    pub request_id: u64,
    pub device_id: u32,
    /// Causal context: which offload round this is and which device
    /// span caused it (zeroed when tracing is off).
    pub ctx: TraceContext,
    /// Device-accepted tokens the cloud has not cached yet (need KV).
    pub uncached: Vec<u32>,
    /// The γ draft tokens pending verification.
    pub draft: Vec<u32>,
    /// p(x|·) for each draft token (for rejection sampling).
    pub dists: Vec<Dist>,
    /// True when this uplink also carries the initial prompt (first
    /// contact for a request — the cloud has no KV at all).
    pub is_first: bool,
}

/// Cloud → device verification result.
#[derive(Debug, Clone, PartialEq)]
pub struct DownlinkMsg {
    pub request_id: u64,
    /// Number of draft tokens accepted (0..=γ).
    pub accepted: u32,
    /// Correction sampled from norm(max(0, q−p)) at the first rejection,
    /// or the bonus token when everything was accepted.
    pub next_token: u32,
}

// ------------------------------ encoding -----------------------------------

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_tokens(out: &mut Vec<u8>, toks: &[u32]) {
    put_u32(out, toks.len() as u32);
    for &t in toks {
        out.extend_from_slice(&(t as u16).to_le_bytes()); // vocab < 65536
    }
}

impl UplinkMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&self.request_id.to_le_bytes());
        put_u32(&mut out, self.device_id);
        put_u32(&mut out, self.ctx.round);
        out.extend_from_slice(&self.ctx.parent_span.to_le_bytes());
        out.push(self.is_first as u8);
        put_tokens(&mut out, &self.uncached);
        put_tokens(&mut out, &self.draft);
        put_u32(&mut out, self.dists.len() as u32);
        for d in &self.dists {
            match d {
                Dist::Dense(p) => {
                    out.push(0);
                    put_u32(&mut out, p.len() as u32);
                    for &x in p {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Dist::TopK { ids, probs_f16 } => {
                    out.push(1);
                    put_u32(&mut out, ids.len() as u32);
                    for (&i, &p) in ids.iter().zip(probs_f16) {
                        out.extend_from_slice(&i.to_le_bytes());
                        out.extend_from_slice(&p.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    /// Wire size in bytes (what the link is charged). Computed without
    /// materialising the encoding — this runs on every offload round
    /// (see EXPERIMENTS.md §Perf).
    pub fn wire_bytes(&self) -> usize {
        Self::wire_bytes_for(self.uncached.len(), self.draft.len(), &self.dists)
    }

    /// [`UplinkMsg::wire_bytes`] from the message's components, for
    /// callers that account link bytes without building (and cloning
    /// into) a throwaway message — e.g. the fleet simulator's offload
    /// hot path.
    pub fn wire_bytes_for(n_uncached: usize, n_draft: usize, dists: &[Dist]) -> usize {
        // request_id, device_id, trace context, is_first
        let mut n = 8 + 4 + TraceContext::WIRE_BYTES + 1;
        n += 4 + 2 * n_uncached;
        n += 4 + 2 * n_draft;
        n += 4;
        for d in dists {
            n += 1 + 4
                + match d {
                    Dist::Dense(p) => 4 * p.len(),
                    Dist::TopK { ids, .. } => 4 * ids.len(),
                };
        }
        n
    }
}

impl DownlinkMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&self.request_id.to_le_bytes());
        put_u32(&mut out, self.accepted);
        put_u32(&mut out, self.next_token);
        out
    }

    pub fn wire_bytes(&self) -> usize {
        self.encode().len()
    }
}

/// Cloud-internal replica→replica session migration payload: a parked
/// session's committed KV image moving between schedulers behind the
/// router (see `crate::cloud::router`).
///
/// KV planes ship as **f32** little-endian words, not f16: the
/// acceptance gate for migration is a *bit-identical* round trip (the
/// destination replica must resume from exactly the KV the source
/// committed), so the lossy f16 path used for probability payloads is
/// off the table here.
#[derive(Debug, Clone, PartialEq)]
pub struct KvMigrateMsg {
    pub request_id: u64,
    pub kv: SlotKv,
}

impl KvMigrateMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        out.extend_from_slice(&self.request_id.to_le_bytes());
        put_u32(&mut out, self.kv.len as u32);
        put_u32(&mut out, self.kv.row as u32);
        for &x in &self.kv.k {
            out.extend_from_slice(&x.to_le_bytes());
        }
        for &x in &self.kv.v {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<KvMigrateMsg> {
        if buf.len() < 16 {
            bail!("kv migrate message truncated ({} bytes)", buf.len());
        }
        let request_id = u64::from_le_bytes(buf[0..8].try_into().unwrap());
        let len = u32::from_le_bytes(buf[8..12].try_into().unwrap()) as usize;
        let row = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
        let plane = len * row;
        if buf.len() != 16 + 8 * plane {
            bail!(
                "kv migrate message size mismatch: {} bytes for len={len} row={row}",
                buf.len()
            );
        }
        let word = |i: usize| {
            f32::from_le_bytes(buf[16 + 4 * i..16 + 4 * i + 4].try_into().unwrap())
        };
        let k = (0..plane).map(word).collect();
        let v = (plane..2 * plane).map(word).collect();
        Ok(KvMigrateMsg { request_id, kv: SlotKv { len, row, k, v } })
    }

    /// Wire size in bytes — what the migration is priced at.
    pub fn wire_bytes(&self) -> usize {
        Self::wire_bytes_for(self.kv.len, self.kv.row)
    }

    /// [`KvMigrateMsg::wire_bytes`] from the session's dimensions,
    /// without materialising a message: header (request_id + len + row)
    /// plus two f32 planes of `len × row` words each.
    pub fn wire_bytes_for(len: usize, row: usize) -> usize {
        8 + 4 + 4 + 2 * 4 * len * row
    }
}

// ------------------------------- f16 ---------------------------------------

/// f32 → IEEE 754 half bits (round-to-nearest-even, good enough for probs).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x7f_ffff;
    if exp == 0xff {
        return sign | 0x7c00 | ((frac != 0) as u16); // inf/nan
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow → 0
        }
        let m = (frac | 0x80_0000) >> (1 - e);
        return sign | ((m + 0x1000) >> 13) as u16;
    }
    sign | ((e as u32) << 10 | ((frac + 0x1000) >> 13)) as u16
}

pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1f;
    let frac = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = -1i32;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            sign | (((127 - 15 + e + 1) as u32) << 23) | ((f & 0x3ff) << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (frac << 13)
    } else {
        sign | ((exp as u32 - 15 + 127) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_roundtrip_probs() {
        for &x in &[0.0f32, 1.0, 0.5, 0.25, 0.1, 0.9, 1e-3, 0.333] {
            let y = f16_to_f32(f32_to_f16(x));
            assert!((x - y).abs() < 2e-3, "{x} -> {y}");
        }
    }

    #[test]
    fn compressed_much_smaller_than_dense() {
        let dense = UplinkMsg {
            request_id: 1,
            device_id: 0,
            ctx: TraceContext::for_round(1, 0),
            uncached: vec![5; 4],
            draft: vec![7; 4],
            dists: vec![Dist::Dense(vec![0.001; 512]); 4],
            is_first: false,
        };
        let topk = UplinkMsg {
            dists: vec![
                Dist::TopK { ids: vec![1, 2, 3], probs_f16: vec![0x3c00, 0, 0] };
                4
            ],
            ..dense.clone()
        };
        let (d, t) = (dense.wire_bytes(), topk.wire_bytes());
        assert!(d > 8000, "{d}");
        assert!(t < 140, "{t}");
        // the paper claims >99.5% reduction at vocab 32k; at vocab 512 the
        // same top-k scheme still saves >98%
        assert!((t as f64) < 0.02 * d as f64);
    }

    #[test]
    fn dist_prob_lookup() {
        let d = Dist::TopK { ids: vec![10, 20], probs_f16: vec![f32_to_f16(0.75), f32_to_f16(0.25)] };
        assert!((d.prob_of(10) - 0.75).abs() < 1e-3);
        assert_eq!(d.prob_of(99), 0.0);
        let dd = Dist::Dense(vec![0.0, 0.5]);
        assert_eq!(dd.prob_of(1), 0.5);
        assert_eq!(dd.prob_of(7), 0.0);
    }

    #[test]
    fn trace_context_flow_ids_are_nonzero_and_distinct() {
        // flow ids live in their own namespace (high bit set) and must
        // differ per round so Perfetto joins the right arrows
        let a = TraceContext::for_round(0, 0);
        let b = TraceContext::for_round(0, 1);
        let c = TraceContext::for_round((3 << 32) | 7, 0);
        assert_ne!(a.parent_span, 0);
        assert_ne!(a.parent_span, b.parent_span);
        assert_ne!(a.parent_span, c.parent_span);
        for ctx in [a, b, c] {
            assert!(ctx.parent_span & (1 << 63) != 0, "own id namespace");
        }
    }

    #[test]
    fn flow_id_round_trips_to_request_id() {
        for req in [0u64, 1, 7, (3 << 32) | 7, (16383u64 << 32) | 1000] {
            for round in [0u32, 1, 9, 65535] {
                let id = TraceContext::flow_id(req, round);
                assert_eq!(TraceContext::request_of_flow(id), Some(req), "req {req} round {round}");
            }
        }
        // raw request ids are not in the flow namespace
        assert_eq!(TraceContext::request_of_flow(0), None);
        assert_eq!(TraceContext::request_of_flow((3 << 32) | 7), None);
    }

    #[test]
    fn downlink_is_tiny() {
        let m = DownlinkMsg { request_id: 9, accepted: 3, next_token: 42 };
        assert!(m.wire_bytes() <= 16);
    }
}

#[cfg(test)]
mod wire_size_tests {
    use super::*;

    #[test]
    fn wire_bytes_equals_encoded_len() {
        // the fast path must agree with the actual encoding, always
        for n_unc in [0usize, 1, 7, 30] {
            for dense in [false, true] {
                let dists = (0..4)
                    .map(|i| {
                        if dense {
                            Dist::Dense(vec![0.1; 512])
                        } else {
                            Dist::TopK {
                                ids: vec![i as u16; 8],
                                probs_f16: vec![0x3c00; 8],
                            }
                        }
                    })
                    .collect();
                let m = UplinkMsg {
                    request_id: 7,
                    device_id: 3,
                    ctx: TraceContext::for_round(7, 2),
                    uncached: vec![9; n_unc],
                    draft: vec![5; 4],
                    dists,
                    is_first: n_unc == 0,
                };
                assert_eq!(m.wire_bytes(), m.encode().len());
            }
        }
    }

    #[test]
    fn kv_migrate_wire_bytes_equals_encoded_len() {
        for (len, row) in [(0usize, 4usize), (1, 4), (17, 4), (5, 8)] {
            let m = KvMigrateMsg {
                request_id: 0xAB,
                kv: SlotKv {
                    len,
                    row,
                    k: (0..len * row).map(|i| i as f32).collect(),
                    v: (0..len * row).map(|i| -(i as f32)).collect(),
                },
            };
            assert_eq!(m.wire_bytes(), m.encode().len(), "len={len} row={row}");
            assert_eq!(m.wire_bytes(), KvMigrateMsg::wire_bytes_for(len, row));
        }
    }

    #[test]
    fn kv_migrate_roundtrips_bit_identical() {
        let m = KvMigrateMsg {
            request_id: (3u64 << 32) | 7,
            kv: SlotKv {
                len: 9,
                row: 4,
                k: (0..36).map(|i| (i * 31 + 5) as f32).collect(),
                v: (0..36).map(|i| -((i * 17 + 3) as f32)).collect(),
            },
        };
        let back = KvMigrateMsg::decode(&m.encode()).unwrap();
        assert_eq!(back, m, "f32 planes must survive the wire bit-for-bit");
        // malformed inputs are rejected, not misread
        assert!(KvMigrateMsg::decode(&[0u8; 3]).is_err());
        assert!(KvMigrateMsg::decode(&m.encode()[..20]).is_err());
    }
}
