//! `synera` — the leader CLI.
//!
//! ```text
//! synera generate  --slm s1b --llm l13b --task xsum --index 0 [--budget 0.2]
//!                  [--token-budget 0] [--prefill-share 0.5] [--age-threshold 4]
//!                  [--max-sessions 0]   (0 = engine slots; >slots enables KV paging)
//! synera eval      --method synera --slm s1b --llm l13b --task xsum --n 16
//! synera profile   [--slm s1b --llm l13b] [--refresh]
//! synera serve     --devices 4 --requests 8 --task xsum
//!                  [--tenants 2 --tenant-weights 1,2] [--replicas 2]
//!                  [--slo-ttft 2.0 --slo-tbt 0.25 --slo-budget 0.1]
//!                  [--trace serve.trace.json]  (wall-clock Chrome trace)
//!                  [--trace-sample 64 --trace-tail-k 32]  (tail-based
//!                                     retention: keep 1-in-N head
//!                                     samples + every SLO-miss/error
//!                                     + the k slowest requests)
//! synera fleet     --devices 1024 --duration 60 [--rate 256]
//!                  [--tenants 4] [--tenant-weights 1,1,2,4]
//!                  [--max-sessions 64] [--burst] [--seed N]
//!                  [--replicas 4 --rebalance 8]  (router-fronted
//!                                     multi-replica cloud; rebalance
//!                                     = load-gap migration threshold)
//!                  [--cloud-iter-s 2e-3 --cloud-row-s 4e-4]
//!                  [--migrate-gbps 10]
//!                  [--prefix-share 0.3 --prefix-len 32]  (fraction of
//!                                     arrivals carrying a shared
//!                                     preamble; >0 turns on the
//!                                     cloud's prefix cache)
//!                  [--real-engine]   (virtual-clock sim; artifact-free
//!                                     over the mock engine by default)
//!                  [--trace fleet.trace.json]  (virtual-time Chrome
//!                                     trace, loadable in Perfetto)
//!                  [--slo-ttft 2.0 --slo-tbt 0.25 --slo-budget 0.1]
//!                  [--metrics fleet.jsonl [--metrics-cadence 1.0]]
//!                  [--trace-sample 64 --trace-tail-k 32]  (tail-based
//!                                     retention, as under serve)
//!                  [--flight-dir dumps/ [--flight-burn 2.0]]  (flight
//!                                     recorder: when a tenant's SLO
//!                                     burn crosses the threshold,
//!                                     snapshot the retained trace to
//!                                     a Chrome-trace dump in the dir)
//! synera inspect   fleet.trace.json [--out breakdown.jsonl]
//!                  [--summary]       (per-component p50/p95/p99
//!                                     latency attribution table)
//!                  [--slo-miss-only] (keep only requests whose
//!                                     trace-derived TTFT/TBT miss the
//!                                     --slo-ttft/--slo-tbt policy)
//!                  (critical-path analysis of a --trace file:
//!                   per-tenant table on stderr, per-request JSONL
//!                   breakdowns to --out or stdout)
//! synera info
//! ```
//!
//! Every subcommand takes `--verbose` (Debug-level diagnostics on
//! stderr). Human-readable output goes to stderr via `synera::log!`;
//! stdout stays reserved for machine-readable artifacts.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};
use synera::baselines::ALL_METHODS;
use synera::config::{BatchPolicy, Scenario, SloPolicy};
use synera::coordinator::eval::{eval_method, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::coordinator::serve::{run_threaded, ServeConfig};
use synera::obs::analyze;
use synera::obs::export::{write_chrome_trace, write_metrics_jsonl};
use synera::obs::registry;
use synera::obs::sampler::SamplerConfig;
use synera::obs::trace::{self, TraceShared, TraceSink};
use synera::profiling;
use synera::runtime::{artifacts_dir, Runtime};
use synera::sim::{run_fleet, run_fleet_on, FleetConfig};
use synera::util::cli::Args;
use synera::workload::synthlang::Task;
use synera::workload::trace::BurstProfile;

/// Trace ring-buffer capacity for CLI-attached sinks: large enough for
/// hour-scale fleet runs, bounded so `--trace` can't exhaust memory.
const TRACE_CAP: usize = 1 << 20;

fn main() {
    if let Err(e) = run() {
        synera::log!(Error, "error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_method(s: &str) -> Result<Method> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "edge" | "edge-centric" => Method::EdgeCentric,
        "cloud" | "cloud-centric" => Method::CloudCentric,
        "hybrid" => Method::Hybrid,
        "edgefm" | "edgefm-llm" => Method::EdgeFmLlm,
        "synera" => Method::Synera,
        _ => bail!("unknown method {s:?} (edge|cloud|hybrid|edgefm|synera)"),
    })
}

fn scenario_from(args: &Args) -> Result<Scenario> {
    let slm = args.get_or("slm", "s1b");
    let llm = args.get_or("llm", "l13b");
    let mut scen = Scenario::default_pair(&slm, &llm);
    scen.params.budget = args.get_f64("budget", scen.params.budget)?;
    scen.params.max_new_tokens = args.get_usize("max-new", scen.params.max_new_tokens)?;
    scen.link.bandwidth_mbps = args.get_f64("bandwidth", scen.link.bandwidth_mbps)?;
    // cloud mixed-batching policy knobs
    scen.params.batch.token_budget =
        args.get_usize("token-budget", scen.params.batch.token_budget)?;
    scen.params.batch.prefill_share =
        args.get_f64("prefill-share", scen.params.batch.prefill_share)?;
    scen.params.batch.age_threshold =
        args.get_usize("age-threshold", scen.params.batch.age_threshold as usize)? as u64;
    scen.params.batch.max_sessions =
        args.get_usize("max-sessions", scen.params.batch.max_sessions)?;
    scen.params.batch.replicas = args.get_usize("replicas", scen.params.batch.replicas)?;
    scen.params.batch.rebalance_threshold =
        args.get_usize("rebalance", scen.params.batch.rebalance_threshold)?;
    scen.params.batch.tenant_weights = synera::config::BatchPolicy::tenant_weights_from(
        args.get_usize("tenants", 0)?,
        args.get("tenant-weights"),
    )?;
    if let Some(w) = args.get("slm-weights") {
        scen.pair.slm_weights = Some(w.to_string());
    }
    Ok(scen)
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    synera::obs::set_verbose(args.has_flag("verbose"));
    match args.command.as_deref() {
        Some("info") => info(),
        Some("generate") => generate(&args),
        Some("eval") => eval(&args),
        Some("profile") => profile(&args),
        Some("serve") => serve(&args),
        Some("fleet") => fleet(&args),
        Some("inspect") => inspect(&args),
        _ => {
            synera::log!(
                Error,
                "usage: synera <info|generate|eval|profile|serve|fleet|inspect> [--opts]\n\
                 see rust/src/main.rs header for examples"
            );
            Ok(())
        }
    }
}

fn info() -> Result<()> {
    let rt = Runtime::load_default()?;
    synera::log!(Info, "artifacts: {} (fingerprint {})", rt.dir.display(), rt.meta.fingerprint);
    synera::log!(
        Info,
        "gamma={} chunk={} cloud_slots={} vocab={}",
        rt.meta.gamma, rt.meta.chunk, rt.meta.cloud_slots, rt.meta.vocab
    );
    for (name, m) in &rt.meta.models {
        synera::log!(
            Info,
            "  {name:<6} {:>8} params  d={} L={} H={} role={} execs={}",
            m.param_count(),
            m.d_model,
            m.n_layers,
            m.n_heads,
            m.role,
            m.execs.len()
        );
    }
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let rt = Runtime::load_default()?;
    let scen = scenario_from(args)?;
    let task = Task::from_name(&args.get_or("task", "xsum")).context("bad --task")?;
    let index = args.get_usize("index", 0)? as u64;
    let method = parse_method(&args.get_or("method", "synera"))?;

    let sample = synera::workload::synthlang::generate(task, 1, index);
    let profile = profiling::load_or_profile(
        &rt,
        &scen.pair.slm,
        scen.pair.slm_weights.as_deref(),
        &scen.pair.llm,
    )?;
    let dev = synera::model::DeviceEngine::new(
        rt.model_variant(&scen.pair.slm, scen.pair.slm_weights.as_deref())?,
        scen.params.early_exit,
    )?;
    let mut sched = synera::cloud::Scheduler::with_policy(
        synera::model::CloudEngine::new(rt.model(&scen.pair.llm)?)?,
        scen.params.seed,
        scen.params.batch.clone(),
    );
    let mut link = synera::net::SimLink::new(scen.link, 1);
    let mut clock = synera::coordinator::pipeline::CloudClock::default();
    let mut rng = synera::util::rng::Rng::new(scen.params.seed);
    let mut ctx = synera::coordinator::pipeline::PipelineCtx {
        dev: &dev,
        sched: &mut sched,
        scen: &scen,
        profile: &profile,
        link: &mut link,
        cloud_clock: &mut clock,
        rng: &mut rng,
    };
    let rep = synera::coordinator::pipeline::run_request(&mut ctx, method, &sample.prompt)?;
    synera::log!(Info, "prompt  : {:?}", sample.prompt);
    synera::log!(Info, "answer  : {:?}", sample.answer);
    synera::log!(Info, "generated: {:?}", rep.generated);
    synera::log!(
        Info,
        "quality={:.3} latency={:.3}s tbt={:.1}ms offloads={} local={} pi={}+{} exits={}",
        synera::metrics::quality::score_sample(&sample, &rep.generated),
        rep.total_s,
        rep.tbt() * 1e3,
        rep.offload_chunks,
        rep.local_chunks,
        rep.pi_hits,
        rep.pi_misses,
        rep.exits,
    );
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let rt = Runtime::load_default()?;
    let scen = scenario_from(args)?;
    let task = Task::from_name(&args.get_or("task", "xsum")).context("bad --task")?;
    let n = args.get_usize("n", 16)?;
    let methods: Vec<Method> = match args.get("method") {
        Some("all") | None => ALL_METHODS.to_vec(),
        Some(m) => vec![parse_method(m)?],
    };
    synera::log!(
        Info,
        "pair={} task={} n={n} budget={}",
        scen.pair.label(),
        task.name(),
        scen.params.budget
    );
    for m in methods {
        let rep = eval_method(&rt, &scen, m, &EvalOptions { n_samples: n, task })?;
        synera::log!(
            Info,
            "{:<13} quality={:.3} tbt={:6.1}ms p95={:6.1}ms cost={:.4} W={:.2} offl={:.2} pi_hit={:.2} exits={:.2}",
            rep.method.name(),
            rep.quality,
            rep.tbt_s * 1e3,
            rep.latency.p95 * 1e3,
            rep.cost * 1e3,
            rep.w,
            rep.offload_rate,
            rep.pi_hit_rate,
            rep.exit_rate,
        );
    }
    Ok(())
}

fn profile(args: &Args) -> Result<()> {
    let rt = Runtime::load_default()?;
    if args.has_flag("refresh") {
        profiling::clear_cache(&rt.dir);
    }
    let pairs: Vec<(String, Option<String>, String)> = match (args.get("slm"), args.get("llm")) {
        (Some(s), Some(l)) => vec![(s.into(), args.get("slm-weights").map(|w| w.into()), l.into())],
        _ => vec![
            ("s160m".into(), None, "l13b".into()),
            ("s1b".into(), None, "l13b".into()),
            ("s7b".into(), None, "l70b".into()),
        ],
    };
    for (slm, w, llm) in pairs {
        let p = profiling::load_or_profile(&rt, &slm, w.as_deref(), &llm)?;
        synera::log!(
            Info,
            "{}&{}: c_th={:.3} alpha={:.3} i_th(b=0.2)={:.3} ppl_th={:.2}",
            p.slm,
            p.llm,
            p.c_th,
            p.alpha,
            p.i_th_for_budget(0.2),
            p.ppl_threshold
        );
    }
    Ok(())
}

/// `--slo-ttft` / `--slo-tbt` / `--slo-budget`: one policy shared by
/// `serve` and `fleet` so attainment and burn read identically.
fn slo_from(args: &Args) -> Result<SloPolicy> {
    let base = SloPolicy::default();
    Ok(SloPolicy {
        ttft_s: args.get_f64("slo-ttft", base.ttft_s)?,
        tbt_s: args.get_f64("slo-tbt", base.tbt_s)?,
        violation_budget: args.get_f64("slo-budget", base.violation_budget)?,
    })
}

/// `--trace-sample` / `--trace-tail-k`: tail-based retention policy
/// shared by `serve` and `fleet`. Returns `None` (retain everything,
/// today's behaviour) unless at least one knob is set. `--trace-tail-k`
/// defaults to 32 once head sampling is on; SLO-miss/error retention is
/// unconditional whenever a sampler is attached.
fn sampler_from(args: &Args, seed: u64) -> Result<Option<SamplerConfig>> {
    let head_every = args.get_usize("trace-sample", 0)? as u64;
    let tail_k = args.get_usize("trace-tail-k", if head_every > 0 { 32 } else { 0 })?;
    Ok((head_every > 0 || tail_k > 0).then_some(SamplerConfig { head_every, tail_k, seed }))
}

/// Build a trace sink, attaching the retention sampler when configured.
fn sink_with(sink: TraceSink, sampler: Option<SamplerConfig>) -> TraceShared {
    trace::shared(match sampler {
        Some(cfg) => sink.with_sampler(cfg),
        None => sink,
    })
}

fn serve(args: &Args) -> Result<()> {
    let scen = scenario_from(args)?;
    let task = Task::from_name(&args.get_or("task", "xsum")).context("bad --task")?;
    let trace_path = args.get("trace").map(PathBuf::from);
    let sampler = sampler_from(args, scen.params.seed)?;
    let cfg = ServeConfig {
        scenario: scen,
        task,
        n_devices: args.get_usize("devices", 4)?,
        requests_per_device: args.get_usize("requests", 4)?,
        slo: slo_from(args)?,
        artifacts: artifacts_dir(),
        // real OS threads share one wall clock
        trace: trace_path
            .as_ref()
            .map(|_| sink_with(TraceSink::wall_time(TRACE_CAP), sampler)),
    };
    synera::log!(
        Debug,
        "serving: {} devices × {} requests, pair={}, task={}",
        cfg.n_devices,
        cfg.requests_per_device,
        cfg.scenario.pair.label(),
        task.name()
    );
    let rep = run_threaded(&cfg)?;
    synera::log!(
        Info,
        "completed={} wall={:.2}s throughput={:.2} req/s tokens/s={:.1}",
        rep.completed, rep.wall_s, rep.throughput_rps, rep.tokens_per_s
    );
    synera::log!(
        Info,
        "e2e p50={:.0}ms p95={:.0}ms  verify-rtt p50={:.0}ms p95={:.0}ms  quality={:.3} offload={:.2}",
        rep.e2e_latency.p50 * 1e3,
        rep.e2e_latency.p95 * 1e3,
        rep.verify_rtt.p50 * 1e3,
        rep.verify_rtt.p95 * 1e3,
        rep.quality,
        rep.offload_rate,
    );
    synera::log!(
        Info,
        "ttft p50={:.0}ms p95={:.0}ms  slo: ttft {:.1}% (burn {:.2}) tbt {:.1}% (burn {:.2})",
        rep.ttft.p50 * 1e3,
        rep.ttft.p95 * 1e3,
        rep.slo_ttft_frac * 100.0,
        rep.ttft_burn,
        rep.slo_tbt_frac * 100.0,
        rep.tbt_burn,
    );
    synera::log!(
        Info,
        "paged-kv swaps: in={} out={} ({} cloud replicas)",
        rep.swap_ins, rep.swap_outs, rep.replicas
    );
    if let Some(path) = &trace_path {
        write_trace_file(path, &cfg.trace)?;
    }
    Ok(())
}

/// Flush an attached sink to `path` as Chrome trace JSON.
fn write_trace_file(path: &std::path::Path, trace: &Option<TraceShared>) -> Result<()> {
    let Some(tr) = trace else { return Ok(()) };
    let Ok(sink) = tr.lock() else { bail!("trace sink poisoned") };
    write_chrome_trace(path, &sink)?;
    synera::log!(
        Info,
        "trace: {} events ({} dropped) -> {}",
        sink.len(),
        sink.dropped(),
        path.display()
    );
    if sink.dropped() > 0 {
        synera::log!(
            Warn,
            "trace: ring overflowed — {} events were dropped and the export is incomplete \
             (raise the capacity or enable --trace-sample to bound retention)",
            sink.dropped()
        );
    }
    if let Some(st) = sink.sampler_stats() {
        synera::log!(
            Info,
            "trace sampler: {}/{} requests retained ({} head, {} tail-interesting), \
             {} events kept, {} discarded, peak staging {} events",
            st.retained_requests,
            st.completed,
            st.head_retained,
            st.tail_retained,
            st.retained_events,
            st.discarded_events,
            st.peak_staged_events,
        );
    }
    Ok(())
}

/// Virtual-clock fleet simulation (`sim::fleet`): thousands of devices
/// through the real scheduler in seconds of wall time.
fn fleet(args: &Args) -> Result<()> {
    let base = FleetConfig::default();
    let n_devices = args.get_usize("devices", 1024)?;
    let rate_rps = args.get_f64("rate", (n_devices as f64 * 0.25).max(1.0))?;
    let tenants = args.get_usize("tenants", 4)?;
    let mut params = base.params.clone();
    params.budget = args.get_f64("budget", params.budget)?;
    params.max_new_tokens = args.get_usize("max-new", params.max_new_tokens)?;
    params.batch.max_sessions = args.get_usize("max-sessions", 64)?;
    params.batch.token_budget = args.get_usize("token-budget", 0)?;
    params.batch.replicas = args.get_usize("replicas", 1)?.max(1);
    params.batch.rebalance_threshold = args.get_usize("rebalance", 0)?;
    let trace_path = args.get("trace").map(PathBuf::from);
    let metrics_path = args.get("metrics").map(PathBuf::from);
    let metrics_cadence = args.get_f64("metrics-cadence", 1.0)?;
    let seed = args.get_usize("seed", base.seed as usize)? as u64;
    let sampler = sampler_from(args, seed)?;
    let flight_dir = args.get("flight-dir").map(PathBuf::from);
    // The flight recorder snapshots the trace buffer, so a sink must
    // exist even when no --trace export was asked for.
    let want_trace = trace_path.is_some() || flight_dir.is_some();
    let cfg = FleetConfig {
        n_devices,
        duration_s: args.get_f64("duration", 60.0)?,
        rate_rps,
        burst: if args.has_flag("burst") {
            Some(BurstProfile::flash_crowd(rate_rps))
        } else {
            None
        },
        tenants,
        tenant_weights: BatchPolicy::tenant_weights_from(tenants, args.get("tenant-weights"))?,
        params,
        seed,
        // modelled cloud service time (satellite knobs: sweep the
        // service curve without recompiling)
        cloud_iter_s: args.get_f64("cloud-iter-s", base.cloud_iter_s)?,
        cloud_row_s: args.get_f64("cloud-row-s", base.cloud_row_s)?,
        migrate_gbps: args.get_f64("migrate-gbps", base.migrate_gbps)?,
        prefix_share: args.get_f64("prefix-share", base.prefix_share)?,
        prefix_len: args.get_usize("prefix-len", base.prefix_len)?,
        slo: slo_from(args)?,
        // keep the cost model's packing factor in step with the engine
        // actually selected on the --real-engine path
        cloud_model: args.get_or("llm", &base.cloud_model),
        // the simulator stamps events in virtual time (byte-identical
        // same-seed traces); a snapshot every `metrics_cadence` virtual s
        trace: want_trace.then(|| sink_with(TraceSink::virtual_time(TRACE_CAP), sampler)),
        // the flight recorder reads per-tenant burn gauges, so it
        // needs a registry even without a --metrics export
        registry: (metrics_path.is_some() || flight_dir.is_some())
            .then(|| registry::shared(metrics_cadence)),
        flight_dir,
        flight_burn: args.get_f64("flight-burn", base.flight_burn)?,
        ..base
    };
    synera::log!(
        Debug,
        "fleet: {} devices, {:.0} virtual s at {:.1} req/s ({}), {} tenants, max_sessions={}, replicas={}",
        cfg.n_devices,
        cfg.duration_s,
        cfg.rate_rps,
        if cfg.burst.is_some() { "bursty" } else { "poisson" },
        cfg.tenants,
        cfg.params.batch.max_sessions,
        cfg.params.batch.replicas.max(1),
    );
    let rep = if args.has_flag("real-engine") {
        // artifact path: measured engine compute drives the clock
        let rt = Runtime::load_default()?;
        let llm = args.get_or("llm", "l13b");
        let profile =
            profiling::load_or_profile(&rt, &args.get_or("slm", "s1b"), None, &llm)?;
        let mut engines = Vec::new();
        for _ in 0..cfg.params.batch.replicas.max(1) {
            let mut engine = synera::model::CloudEngine::new(rt.model(&llm)?)?;
            engine.warmup()?;
            engines.push(engine);
        }
        run_fleet_on(&cfg, engines, &profile, true)?
    } else {
        run_fleet(&cfg)?
    };
    synera::log!(
        Info,
        "completed {}/{} requests ({} tokens) in {:.1} virtual s / {:.2} wall s",
        rep.completed,
        rep.offered,
        rep.generated_tokens,
        rep.virtual_s,
        rep.wall_s,
    );
    synera::log!(
        Info,
        "cloud: {} iterations, {} draft rows verified, cost={:.5}, swaps in/out={}/{} ({} B), pi hit/miss={}/{}",
        rep.cloud_iterations,
        rep.cloud_draft_rows,
        rep.cost * 1e3,
        rep.swap_ins,
        rep.swap_outs,
        rep.swap_bytes,
        rep.pi_hits,
        rep.pi_misses,
    );
    synera::log!(
        Info,
        "router: {} replicas, {} migrations ({} B wire), per-replica iters={:?} rows={:?}",
        rep.replicas,
        rep.migrations,
        rep.migration_bytes,
        rep.replica_iterations,
        rep.replica_rows,
    );
    synera::log!(
        Info,
        "traffic: {} offload rounds / {} local chunks, {} B up / {} B down",
        rep.offload_rounds, rep.local_chunks, rep.bytes_up, rep.bytes_down
    );
    synera::log!(
        Info,
        "{:<7} {:>6} {:>5} {:>5} | {:>9} {:>9} {:>9} | {:>9} {:>9} | {:>7} {:>7} {:>6} {:>6} | {:>10} {:>8} {:>10}",
        "tenant", "weight", "req", "done", "ttft p50", "ttft p95", "ttft p99", "tbt p50",
        "tbt p95", "slo-ttft", "slo-tbt", "burn-t", "burn-b", "rows", "pfx-rows", "energy",
    );
    for t in &rep.tenants {
        synera::log!(
            Info,
            "{:<7} {:>6.1} {:>5} {:>5} | {:>8.0}ms {:>8.0}ms {:>8.0}ms | {:>8.1}ms {:>8.1}ms | {:>6.1}% {:>6.1}% {:>6.2} {:>6.2} | {:>10} {:>8} {:>9.1}J",
            t.tenant,
            t.weight,
            t.requests,
            t.completed,
            t.ttft.p50 * 1e3,
            t.ttft.p95 * 1e3,
            t.ttft.p99 * 1e3,
            t.tbt.p50 * 1e3,
            t.tbt.p95 * 1e3,
            t.slo_ttft_frac * 100.0,
            t.slo_tbt_frac * 100.0,
            t.ttft_burn,
            t.tbt_burn,
            t.rows_executed,
            t.prefix_hit_rows,
            t.energy_j,
        );
    }
    if let Some(path) = &trace_path {
        write_trace_file(path, &cfg.trace)?;
    }
    if let (Some(path), Some(reg)) = (&metrics_path, &cfg.registry) {
        let Ok(r) = reg.lock() else { bail!("metrics registry poisoned") };
        write_metrics_jsonl(path, &r)?;
        synera::log!(Info, "metrics: {} samples -> {}", r.samples.len(), path.display());
    }
    Ok(())
}

/// Critical-path analysis of a Chrome trace written by `--trace`
/// (fleet or serve). Table to stderr (human); per-request JSONL
/// breakdowns to `--out` or stdout (machine) — same stream contract
/// as every other subcommand.
fn inspect(args: &Args) -> Result<()> {
    let path = args
        .positionals
        .first()
        .map(String::as_str)
        .or_else(|| args.get("trace"))
        .context("usage: synera inspect <trace.json> [--out breakdown.jsonl] [--summary] [--slo-miss-only]")?;
    let mut rep = analyze::analyze_file(path)?;
    synera::log!(
        Info,
        "{path}: {} requests attributed, {} partial (incomplete event sets)",
        rep.requests.len(),
        rep.partial
    );
    if args.has_flag("slo-miss-only") {
        let policy = slo_from(args)?;
        rep = analyze::slo_miss_only(&rep, &policy);
        synera::log!(
            Info,
            "slo-miss-only: {} requests miss ttft≤{:.3}s / tbt≤{:.3}s",
            rep.requests.len(),
            policy.ttft_s,
            policy.tbt_s
        );
    }
    for line in analyze::table_string(&rep).lines() {
        synera::log!(Info, "{line}");
    }
    if args.has_flag("summary") {
        for line in analyze::summary_table_string(&rep).lines() {
            synera::log!(Info, "{line}");
        }
    }
    let jsonl = analyze::requests_jsonl_string(&rep);
    match args.get("out") {
        Some(out) => {
            std::fs::write(out, &jsonl).with_context(|| format!("writing {out}"))?;
            synera::log!(Info, "breakdowns: {} lines -> {out}", rep.requests.len());
        }
        None => print!("{jsonl}"),
    }
    Ok(())
}
