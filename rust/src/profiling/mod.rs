//! Synera offline profiling (paper §5).
//!
//! For an SLM–LLM pair, run a profiling pass with **every** chunk
//! offloaded and collect:
//!
//! * `c_th` — mean chunk confidence over *fully accepted* chunks (the
//!   coarse-filter threshold);
//! * the distribution of chunk mean-importance → a percentile table so
//!   the budget knob maps to `i_th` at runtime;
//! * `α` — the per-token draft acceptance probability (drives the
//!   capped-geometric rejection-position prior);
//! * the SLM prompt-perplexity distribution → the EdgeFM-LLM baseline's
//!   input-offloading threshold.
//!
//! Results are cached as `artifacts/profile_<slm>_<llm>.json`.

use std::path::Path;
use std::rc::Rc;

use anyhow::Result;

use crate::cloud::scheduler::{CloudEvent, CloudRequest, Scheduler};
use crate::model::cloud_engine::CloudEngine;
use crate::model::device_engine::DeviceEngine;
use crate::model::logits::argmax;
use crate::net::wire::Dist;
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::workload::trace::mixed_eval_set;
use crate::workload::vocab::EOS;

/// Profiled parameters for one SLM–LLM pair.
#[derive(Debug, Clone)]
pub struct OffloadProfile {
    pub slm: String,
    pub llm: String,
    pub c_th: f64,
    pub alpha: f64,
    /// Percentiles 0..=100 of chunk mean-importance.
    pub imp_percentiles: Vec<f64>,
    pub ppl_threshold: f64,
}

impl OffloadProfile {
    /// Budget → fine threshold: offloading the top `budget` fraction by
    /// importance means `i_th` sits at the (1−budget) percentile.
    pub fn i_th_for_budget(&self, budget: f64) -> f64 {
        let b = budget.clamp(0.0, 1.0);
        let idx = ((1.0 - b) * 100.0).round() as usize;
        self.imp_percentiles[idx.min(100)]
    }

    /// A neutral profile for unit tests (no artifacts needed).
    pub fn synthetic() -> OffloadProfile {
        OffloadProfile {
            slm: "test".into(),
            llm: "test".into(),
            c_th: 0.7,
            alpha: 0.6,
            imp_percentiles: (0..=100).map(|i| i as f64 / 25.0).collect(),
            ppl_threshold: 8.0,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("slm", Json::str(self.slm.clone())),
            ("llm", Json::str(self.llm.clone())),
            ("c_th", Json::num(self.c_th)),
            ("alpha", Json::num(self.alpha)),
            (
                "imp_percentiles",
                Json::arr(self.imp_percentiles.iter().map(|&x| Json::num(x))),
            ),
            ("ppl_threshold", Json::num(self.ppl_threshold)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<OffloadProfile> {
        Ok(OffloadProfile {
            slm: j.get("slm")?.as_str()?.into(),
            llm: j.get("llm")?.as_str()?.into(),
            c_th: j.get("c_th")?.as_f64()?,
            alpha: j.get("alpha")?.as_f64()?,
            imp_percentiles: j
                .get("imp_percentiles")?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Result<_>>()?,
            ppl_threshold: j.get("ppl_threshold")?.as_f64()?,
        })
    }
}

fn percentiles_0_100(values: &mut Vec<f64>) -> Vec<f64> {
    if values.is_empty() {
        return vec![0.0; 101];
    }
    values.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (0..=100)
        .map(|p| values[((values.len() - 1) as f64 * p as f64 / 100.0).round() as usize])
        .collect()
}

/// Run the offload-everything profiling pass (paper §5). `n_samples`
/// mixed-task prompts; γ-token chunks; greedy drafting.
pub fn profile_pair(
    rt: &Rc<Runtime>,
    slm: &str,
    slm_weights: Option<&str>,
    llm: &str,
    n_samples: usize,
    gamma: usize,
    max_new: usize,
) -> Result<OffloadProfile> {
    // split mode (exits disabled) so the importance signal is measured by
    // the same part-1 layer range the Synera runtime reads — calibrating
    // i_th on a different layer range would shift the budget mapping
    let dev = DeviceEngine::new(rt.model_variant(slm, slm_weights)?, true)?;
    let mut sched = Scheduler::new(CloudEngine::new(rt.model(llm)?)?, 0xBEEF);

    let mut conf_full_accept: Vec<f64> = Vec::new();
    let mut conf_all: Vec<f64> = Vec::new();
    let mut chunk_imps: Vec<f64> = Vec::new();
    let mut ppls: Vec<f64> = Vec::new();

    let samples = mixed_eval_set((n_samples / 7).max(1));
    for (si, s) in samples.iter().enumerate() {
        let req_id = 0x5000 + si as u64;
        let (mut sess, mut cur) = dev.prefill(&s.prompt)?;
        ppls.push(sess.prompt_ppl());
        let mut cloud_len = 0usize;
        while sess.len - s.prompt.len() < max_new {
            let start_len = sess.len;
            let mut draft = Vec::new();
            let mut confs = Vec::new();
            let mut dists = Vec::new();
            for _ in 0..gamma.min(max_new - (sess.len - s.prompt.len())) {
                let tok = argmax(&cur.probs) as u32;
                if tok == EOS {
                    break;
                }
                draft.push(tok);
                confs.push(cur.probs[tok as usize] as f64);
                dists.push(Dist::Dense(cur.probs.clone()));
                cur = dev.step(&mut sess, tok, false, 1.0)?;
            }
            if draft.is_empty() {
                break;
            }
            let imps: Vec<f64> = (0..draft.len())
                .map(|j| sess.importance[start_len + j] as f64)
                .collect();
            chunk_imps.push(imps.iter().sum::<f64>() / imps.len() as f64);

            let uncached: Vec<u32> = sess.tokens[cloud_len..start_len].to_vec();
            sched.submit(CloudRequest::Verify {
                request_id: req_id,
                device_id: 0,
                uncached,
                draft: draft.clone(),
                dists,
                greedy: true,
                ctx: Default::default(),
            })?;
            let mut outcome = None;
            while outcome.is_none() {
                let (events, _) = sched.tick()?;
                for e in events {
                    if let CloudEvent::VerifyDone { outcome: o, .. } = e {
                        outcome = Some(o);
                    }
                }
            }
            let o = outcome.unwrap();
            let accepted = o.accepted.min(draft.len());
            let mean_conf = confs.iter().sum::<f64>() / confs.len() as f64;
            conf_all.push(mean_conf);
            if accepted == draft.len() {
                conf_full_accept.push(mean_conf);
            }
            cloud_len = start_len + accepted;
            sess.rewind(start_len + accepted);
            if o.next_token == EOS {
                break;
            }
            cur = dev.step(&mut sess, o.next_token, false, 1.0)?;
        }
        sched.submit(CloudRequest::Release { request_id: req_id })?;
    }

    let alpha = sched.acceptance_rate().clamp(0.05, 0.98);
    // coarse threshold: paper §4.2/Fig 10 — the confidence filter should
    // retain only the most confident ~20% of chunks locally, so c_th
    // sits at the 80th percentile of profiled chunk confidences, floored
    // by the mean confidence of fully accepted chunks (paper §5).
    let accept_mean = if conf_full_accept.is_empty() {
        0.8
    } else {
        conf_full_accept.iter().sum::<f64>() / conf_full_accept.len() as f64
    };
    let c_th = if conf_all.is_empty() {
        accept_mean
    } else {
        conf_all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p80 = conf_all[(conf_all.len() - 1) * 80 / 100];
        p80.max(accept_mean)
    };
    ppls.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ppl_threshold = if ppls.is_empty() {
        8.0
    } else {
        ppls[(ppls.len() - 1) * 60 / 100] // offload the worst ~40% of inputs
    };
    Ok(OffloadProfile {
        slm: match slm_weights {
            Some(w) => w.to_string(),
            None => slm.to_string(),
        },
        llm: llm.to_string(),
        c_th,
        alpha,
        imp_percentiles: percentiles_0_100(&mut chunk_imps),
        ppl_threshold,
    })
}

/// Load the cached profile or compute and cache it.
pub fn load_or_profile(
    rt: &Rc<Runtime>,
    slm: &str,
    slm_weights: Option<&str>,
    llm: &str,
) -> Result<OffloadProfile> {
    let key = match slm_weights {
        Some(w) => format!("profile_{w}_{llm}.json"),
        None => format!("profile_{slm}_{llm}.json"),
    };
    let path = rt.dir.join(&key);
    if path.exists() {
        if let Ok(j) = Json::parse_file(&path) {
            if let Ok(p) = OffloadProfile::from_json(&j) {
                return Ok(p);
            }
        }
    }
    let p = profile_pair(rt, slm, slm_weights, llm, 28, rt.meta.gamma, 12)?;
    let _ = std::fs::write(&path, p.to_json().to_string());
    Ok(p)
}

/// Remove cached profiles (CLI `profile --refresh`).
pub fn clear_cache(dir: &Path) {
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let name = e.file_name();
            if name.to_string_lossy().starts_with("profile_") {
                let _ = std::fs::remove_file(e.path());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_to_threshold_mapping() {
        let p = OffloadProfile::synthetic();
        // budget 0 → 100th percentile (max importance): nothing offloads
        assert_eq!(p.i_th_for_budget(0.0), p.imp_percentiles[100]);
        // budget 1 → 0th percentile: everything passes the fine filter
        assert_eq!(p.i_th_for_budget(1.0), p.imp_percentiles[0]);
        // monotone: higher budget → lower threshold
        assert!(p.i_th_for_budget(0.6) <= p.i_th_for_budget(0.2));
    }

    #[test]
    fn json_roundtrip() {
        let p = OffloadProfile::synthetic();
        let q = OffloadProfile::from_json(&Json::parse(&p.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(p.c_th, q.c_th);
        assert_eq!(p.imp_percentiles, q.imp_percentiles);
    }

    #[test]
    fn percentile_table_is_monotone() {
        let mut v: Vec<f64> = (0..500).map(|i| ((i * 7919) % 101) as f64).collect();
        let p = percentiles_0_100(&mut v);
        assert_eq!(p.len(), 101);
        for w in p.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }
}
