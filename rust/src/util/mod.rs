//! Small self-contained utilities (the offline crate mirror carries no
//! serde/clap, so JSON and CLI parsing are hand-rolled here).

pub mod cli;
pub mod json;
pub mod rng;

/// Monotonic nanosecond timestamp helper used by metrics and benches.
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}
