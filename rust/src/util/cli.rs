//! Tiny CLI argument parser (`--key value` / `--flag` style).

use std::collections::BTreeMap;

use anyhow::Result;

/// Parsed command line: a subcommand plus `--key value` options and
/// any bare positional operands after the subcommand (e.g. the trace
/// path in `synera inspect fleet.trace.json`).
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub opts: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()`-style input (program name excluded).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.opts.insert(key.to_string(), v);
                    }
                    _ => out.flags.push(key.to_string()),
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positionals.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::parse(s(&["eval", "--dataset", "xsum", "--budget", "0.2", "--verbose"]))
            .unwrap();
        assert_eq!(a.command.as_deref(), Some("eval"));
        assert_eq!(a.get("dataset"), Some("xsum"));
        assert_eq!(a.get_f64("budget", 0.0).unwrap(), 0.2);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn collects_positionals_after_subcommand() {
        let a = Args::parse(s(&["inspect", "t.json", "--out", "o.jsonl", "u.json"])).unwrap();
        assert_eq!(a.command.as_deref(), Some("inspect"));
        assert_eq!(a.positionals, vec!["t.json".to_string(), "u.json".to_string()]);
        assert_eq!(a.get("out"), Some("o.jsonl"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(s(&[])).unwrap();
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_or("x", "d"), "d");
    }
}
