//! splitmix64 RNG — bit-for-bit identical to `python/compile/synthlang.py`.
//!
//! Every stochastic decision in the system (workload generation, dispatch
//! sampling, rejection sampling, Poisson traces) draws from this stream so
//! experiments are reproducible and the Python/Rust workload generators
//! agree exactly (checked against `artifacts/golden_workload.json`).

/// One splitmix64 step: `(state', output)`.
#[inline]
pub fn splitmix64(state: u64) -> (u64, u64) {
    let state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    (state, z ^ (z >> 31))
}

/// Deterministic stream RNG (mirror of `synthlang.Rng`).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let (s, z) = splitmix64(self.state);
        self.state = s;
        z
    }

    /// Uniform integer in `[0, n)` (modulo method, as in the Python mirror).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Bernoulli(num/den).
    #[inline]
    pub fn chance(&mut self, num: u64, den: u64) -> bool {
        self.below(den) < num
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Exponential variate with the given rate (for Poisson arrivals).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.f64();
        -u.ln() / rate
    }
}

/// Order-sensitive 2-arg hash for static world tables (mirror of
/// `synthlang.hash2`).
pub fn hash2(world_seed: u64, a: u64, b: u64) -> u64 {
    let x = world_seed ^ a.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ b;
    splitmix64(x).1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // cross-checked against the python implementation
        let (s, z) = splitmix64(0);
        assert_eq!(s, 0x9E37_79B9_7F4A_7C15);
        let (_, z2) = splitmix64(s);
        assert_ne!(z, z2);
    }

    #[test]
    fn below_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.below(17), b.below(17));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_positive_mean_close() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
