//! Minimal JSON reader/writer (the offline mirror has no serde).
//!
//! Supports the full JSON grammar we emit from Python (objects, arrays,
//! strings with escapes, numbers, bools, null). Used for
//! `artifacts/meta.json`, `artifacts/profile.json`,
//! `artifacts/golden_workload.json` and experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------ accessors ------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // ----------------------------- constructors ----------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ------------------------------- writing -------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ------------------------------- parsing -------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char)
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // (surrogate pairs unsupported; we never emit them)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-sync to char boundary for multi-byte utf-8
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + width])?;
                        s.push_str(chunk);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number {s:?}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [1.5, true, null, "x\ny"], "c": {"d": -2e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_usize().unwrap(), 1);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64().unwrap(), -2000.0);
        // write → parse → equal
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\cA\n""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\cA\n");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integer_formatting_is_exact() {
        let v = Json::Num(4096.0);
        assert_eq!(v.to_string(), "4096");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo→");
    }
}
