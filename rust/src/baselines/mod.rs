//! Baseline systems (paper §6.1).
//!
//! All four baselines share substrates with Synera so comparisons are
//! apples-to-apples:
//!
//! * **Edge-centric** — pure on-device SLM decoding
//!   ([`pipeline::run_edge_centric`]).
//! * **Cloud-centric** — the whole request served by the LLM through the
//!   continuous-batching engine ([`pipeline::run_cloud_centric`]).
//! * **Hybrid** (Hao et al. [9]) — token-level offloading gated by the
//!   confidence threshold only, with the vanilla (stalling) pipeline:
//!   expressed as a Synera parameterisation in
//!   [`eval::method_params`] (`use_imp=false`, no PI/EE/compression).
//! * **EdgeFM-LLM** (EdgeFM [38] adapted to generation) — input-level
//!   offloading on prompt perplexity ([`pipeline::run_edgefm`]); the PPL
//!   threshold comes from the offline profile.
//!
//! This module re-exports the method enum for discoverability.

pub use crate::coordinator::eval::method_params;
pub use crate::coordinator::pipeline::Method;

/// All methods in the paper's comparison order.
pub const ALL_METHODS: [Method; 5] = [
    Method::EdgeCentric,
    Method::CloudCentric,
    Method::EdgeFmLlm,
    Method::Hybrid,
    Method::Synera,
];

/// The quality-table subset (Table 4 omits cloud-centric — it is the
/// quality ceiling by construction).
pub const TABLE4_METHODS: [Method; 4] =
    [Method::EdgeCentric, Method::EdgeFmLlm, Method::Hybrid, Method::Synera];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SyneraParams;

    #[test]
    fn hybrid_is_conf_only_vanilla() {
        let p = method_params(Method::Hybrid, &SyneraParams::default());
        assert!(p.use_conf && !p.use_imp);
        assert!(!p.parallel_inference && !p.early_exit && !p.compression);
    }

    #[test]
    fn synera_keeps_all_modules() {
        let p = method_params(Method::Synera, &SyneraParams::default());
        assert!(p.use_conf && p.use_imp && p.parallel_inference && p.early_exit && p.compression);
    }
}
