//! Benchmark snapshot comparison — the logic behind the CI perf gate
//! (`tools/bench_diff.sh` → `cargo run --bin bench_diff`).
//!
//! Two `BENCH_<name>.json` snapshots ([`super::write_bench_json`]) are
//! compared leaf by leaf. Numeric leaves must agree within a relative
//! tolerance (default 25% — sim metrics are deterministic, so the slack
//! exists for counters that legitimately shift with small code
//! changes); **timing** leaves (key ending in `_s`, or containing
//! `wall` or `ms`) are reported but never gate, because CI machine
//! noise would make them flaky. Structural drift — a missing or new
//! key, a type change, a `schema` bump — always gates: a snapshot
//! whose shape silently changed is not being compared at all.

use anyhow::{bail, Context};

use crate::util::json::Json;
use crate::Result;

/// Default relative tolerance for gating numeric leaves.
pub const DEFAULT_TOL: f64 = 0.25;

/// Outcome of one compared leaf.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Within tolerance (or exactly equal for non-numerics).
    Ok,
    /// Numeric drift beyond tolerance — gates the build.
    Fail,
    /// Timing leaf: reported, never gates.
    Info,
    /// Key present on one side only, or type changed — gates.
    Shape,
}

/// One row of the delta table.
#[derive(Debug, Clone)]
pub struct DiffRow {
    /// Dotted path into `results` (e.g. `points.3.p95_s`).
    pub path: String,
    pub baseline: Option<f64>,
    pub candidate: Option<f64>,
    /// Relative delta `(cand − base) / |base|`; `None` when either
    /// side is missing/non-numeric or the baseline is zero.
    pub rel: Option<f64>,
    pub verdict: Verdict,
}

/// Full comparison of two snapshots.
#[derive(Debug, Clone, Default)]
pub struct DiffReport {
    pub bench: String,
    pub rows: Vec<DiffRow>,
}

impl DiffReport {
    /// Rows that gate (numeric drift or shape change).
    pub fn failures(&self) -> usize {
        self.rows.iter().filter(|r| matches!(r.verdict, Verdict::Fail | Verdict::Shape)).count()
    }

    pub fn passed(&self) -> bool {
        self.failures() == 0
    }

    /// Deterministic delta table (rows are generated in `BTreeMap`
    /// key order, so same inputs produce identical bytes).
    pub fn table_string(&self) -> String {
        let mut out = format!(
            "{:<40} {:>14} {:>14} {:>9}  {}\n",
            "metric", "baseline", "candidate", "delta", "verdict"
        );
        for r in &self.rows {
            let num = |v: Option<f64>| v.map_or("-".to_string(), |x| format!("{x:.6}"));
            let rel = r.rel.map_or("-".to_string(), |d| format!("{:+.1}%", d * 100.0));
            let verdict = match r.verdict {
                Verdict::Ok => "ok",
                Verdict::Fail => "FAIL",
                Verdict::Info => "info",
                Verdict::Shape => "SHAPE",
            };
            out.push_str(&format!(
                "{:<40} {:>14} {:>14} {:>9}  {}\n",
                r.path,
                num(r.baseline),
                num(r.candidate),
                rel,
                verdict
            ));
        }
        out.push_str(&format!(
            "{} leaves compared, {} gating failure(s)\n",
            self.rows.len(),
            self.failures()
        ));
        out
    }
}

/// Is this leaf a timing measurement (informational, never gates)?
fn is_timing_key(key: &str) -> bool {
    key.ends_with("_s") || key.contains("wall") || key.ends_with("_ms")
}

/// Compare two `BENCH_*.json` documents (full file contents).
pub fn diff_snapshots(baseline: &str, candidate: &str, tol: f64) -> Result<DiffReport> {
    let b = Json::parse(baseline).context("baseline snapshot is not valid JSON")?;
    let c = Json::parse(candidate).context("candidate snapshot is not valid JSON")?;
    let name = b.get("bench")?.as_str()?.to_string();
    if c.get("bench")?.as_str()? != name {
        bail!("snapshots are from different benches");
    }
    let mut rep = DiffReport { bench: name, rows: Vec::new() };
    if b.get("schema")?.as_f64()? != c.get("schema")?.as_f64()? {
        rep.rows.push(DiffRow {
            path: "schema".into(),
            baseline: b.get("schema")?.as_f64().ok(),
            candidate: c.get("schema")?.as_f64().ok(),
            rel: None,
            verdict: Verdict::Shape,
        });
        return Ok(rep); // incomparable layouts: stop at the version gate
    }
    diff_value("results", b.get("results")?, c.get("results")?, tol, &mut rep.rows);
    Ok(rep)
}

fn diff_value(path: &str, b: &Json, c: &Json, tol: f64, out: &mut Vec<DiffRow>) {
    match (b, c) {
        (Json::Obj(bm), Json::Obj(cm)) => {
            // union of keys, sorted: drift on either side is visible
            let keys: std::collections::BTreeSet<&String> =
                bm.keys().chain(cm.keys()).collect();
            for k in keys {
                let p = format!("{path}.{k}");
                match (bm.get(k), cm.get(k)) {
                    (Some(bv), Some(cv)) => diff_value(&p, bv, cv, tol, out),
                    (bv, cv) => out.push(DiffRow {
                        path: p,
                        baseline: bv.and_then(|v| v.as_f64().ok()),
                        candidate: cv.and_then(|v| v.as_f64().ok()),
                        rel: None,
                        verdict: Verdict::Shape,
                    }),
                }
            }
        }
        (Json::Arr(ba), Json::Arr(ca)) => {
            if ba.len() != ca.len() {
                out.push(DiffRow {
                    path: format!("{path}.len"),
                    baseline: Some(ba.len() as f64),
                    candidate: Some(ca.len() as f64),
                    rel: None,
                    verdict: Verdict::Shape,
                });
                return;
            }
            for (i, (bv, cv)) in ba.iter().zip(ca).enumerate() {
                diff_value(&format!("{path}.{i}"), bv, cv, tol, out);
            }
        }
        (Json::Num(bx), Json::Num(cx)) => {
            let leaf = path.rsplit('.').next().unwrap_or(path);
            let rel = if *bx != 0.0 { Some((cx - bx) / bx.abs()) } else { None };
            let verdict = if is_timing_key(leaf) {
                Verdict::Info
            } else {
                let within = match rel {
                    Some(d) => d.abs() <= tol,
                    // zero baseline: require the candidate to stay
                    // within the same tolerance of zero in absolute
                    // terms (counters that were 0 should stay ~0)
                    None => cx.abs() <= tol,
                };
                if within {
                    Verdict::Ok
                } else {
                    Verdict::Fail
                }
            };
            out.push(DiffRow {
                path: path.to_string(),
                baseline: Some(*bx),
                candidate: Some(*cx),
                rel,
                verdict,
            });
        }
        _ => {
            // strings/bools/nulls must match exactly; a type change is
            // always a shape failure
            let same = match (b, c) {
                (Json::Str(x), Json::Str(y)) => x == y,
                (Json::Bool(x), Json::Bool(y)) => x == y,
                (Json::Null, Json::Null) => true,
                _ => false,
            };
            out.push(DiffRow {
                path: path.to_string(),
                baseline: None,
                candidate: None,
                rel: None,
                verdict: if same { Verdict::Ok } else { Verdict::Shape },
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(results: &str) -> String {
        format!("{{\"bench\":\"figX\",\"schema\":1,\"results\":{results}}}")
    }

    #[test]
    fn identical_snapshots_pass() {
        let s = snap("{\"throughput\": 100.0, \"points\": [{\"p95_s\": 0.5}]}");
        let rep = diff_snapshots(&s, &s, DEFAULT_TOL).unwrap();
        assert!(rep.passed(), "{}", rep.table_string());
        assert_eq!(rep.bench, "figX");
    }

    #[test]
    fn drift_beyond_tolerance_fails() {
        let b = snap("{\"throughput\": 100.0}");
        let c = snap("{\"throughput\": 60.0}");
        let rep = diff_snapshots(&b, &c, 0.25).unwrap();
        assert_eq!(rep.failures(), 1);
        assert!(rep.table_string().contains("FAIL"));
        // 10% drift under a 25% tolerance is fine
        let c2 = snap("{\"throughput\": 110.0}");
        assert!(diff_snapshots(&b, &c2, 0.25).unwrap().passed());
    }

    #[test]
    fn timing_leaves_never_gate() {
        let b = snap("{\"p95_s\": 0.1, \"wall_s\": 3.0}");
        let c = snap("{\"p95_s\": 5.0, \"wall_s\": 90.0}");
        let rep = diff_snapshots(&b, &c, 0.25).unwrap();
        assert!(rep.passed(), "timing drift is informational: {}", rep.table_string());
        assert!(rep.rows.iter().all(|r| r.verdict == Verdict::Info));
    }

    #[test]
    fn shape_drift_gates() {
        let b = snap("{\"a\": 1.0, \"b\": 2.0}");
        let missing = snap("{\"a\": 1.0}");
        assert!(!diff_snapshots(&b, &missing, 0.25).unwrap().passed());
        let extra = snap("{\"a\": 1.0, \"b\": 2.0, \"c\": 3.0}");
        assert!(!diff_snapshots(&b, &extra, 0.25).unwrap().passed());
        let arr_b = snap("{\"pts\": [1.0, 2.0]}");
        let arr_c = snap("{\"pts\": [1.0]}");
        assert!(!diff_snapshots(&arr_b, &arr_c, 0.25).unwrap().passed());
        let ty = snap("{\"a\": \"one\", \"b\": 2.0}");
        assert!(!diff_snapshots(&b, &ty, 0.25).unwrap().passed());
    }

    #[test]
    fn schema_bump_short_circuits() {
        let b = snap("{\"a\": 1.0}");
        let c = b.replace("\"schema\":1", "\"schema\":2");
        let rep = diff_snapshots(&b, &c, 0.25).unwrap();
        assert_eq!(rep.rows.len(), 1);
        assert_eq!(rep.rows[0].verdict, Verdict::Shape);
    }

    #[test]
    fn zero_baseline_uses_absolute_tolerance() {
        let b = snap("{\"migrations\": 0.0}");
        assert!(diff_snapshots(&b, &snap("{\"migrations\": 0.0}"), 0.25).unwrap().passed());
        assert!(!diff_snapshots(&b, &snap("{\"migrations\": 7.0}"), 0.25).unwrap().passed());
    }

    #[test]
    fn table_is_deterministic() {
        let b = snap("{\"z\": 1.0, \"a\": 2.0, \"m\": {\"q\": 3.0}}");
        let c = snap("{\"z\": 1.1, \"a\": 2.0, \"m\": {\"q\": 3.5}}");
        let r1 = diff_snapshots(&b, &c, 0.25).unwrap().table_string();
        let r2 = diff_snapshots(&b, &c, 0.25).unwrap().table_string();
        assert_eq!(r1, r2);
    }

    #[test]
    fn different_benches_refuse_to_compare() {
        let b = snap("{\"a\": 1.0}");
        let c = b.replace("figX", "figY");
        assert!(diff_snapshots(&b, &c, 0.25).is_err());
    }
}
