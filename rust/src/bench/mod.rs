//! Bench harness (the offline mirror carries no criterion): a small
//! timing/reporting toolkit used by every `cargo bench` target
//! (`harness = false`). Provides warmup + repeated measurement with
//! mean/p50/p95, paper-style table printing, and the stable
//! `BENCH_<name>.json` snapshot writer ([`write_bench_json`]) that
//! benches use under `--json` so the perf trajectory is tracked in
//! machine-readable form.

pub mod diff;

use std::path::PathBuf;
use std::time::Instant;

use crate::metrics::stats::Summary;
use crate::util::json::Json;

/// Time `f` over `iters` iterations after `warmup` runs; returns
/// per-iteration seconds.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// Human units for seconds.
pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Fixed-width paper-style table writer (also mirrors rows to a
/// results file under `target/bench-results/`).
pub struct Table {
    title: String,
    header: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            widths: header.iter().map(|s| s.len()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count");
        for (i, c) in cells.iter().enumerate() {
            self.widths[i] = self.widths[i].max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        crate::log!(Info, "\n=== {} ===", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:<w$} | ", w = w));
            }
            s
        };
        crate::log!(Info, "{}", line(&self.header, &self.widths));
        let sep: usize = self.widths.iter().sum::<usize>() + 3 * self.widths.len() + 1;
        crate::log!(Info, "{}", "-".repeat(sep));
        for r in &self.rows {
            crate::log!(Info, "{}", line(r, &self.widths));
        }
        self.save();
    }

    fn save(&self) {
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let mut out = String::new();
        out.push_str(&self.header.join("\t"));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join("\t"));
            out.push('\n');
        }
        let _ = std::fs::write(dir.join(format!("{slug}.tsv")), out);
    }
}

/// Write a stable machine-readable benchmark snapshot next to the
/// bench's working directory: `BENCH_<name>.json` holding
/// `{"bench": name, "schema": 1, "results": <results>}`. The schema
/// field versions the layout so downstream diffing of snapshots across
/// commits can detect shape changes; `results` is bench-specific but
/// must keep its keys stable within a schema version.
pub fn write_bench_json(name: &str, results: Json) -> crate::Result<PathBuf> {
    let doc = Json::obj(vec![
        ("bench", Json::str(name)),
        ("schema", Json::num(1)),
        ("results", results),
    ]);
    let path = PathBuf::from(format!("BENCH_{name}.json"));
    std::fs::write(&path, doc.to_string())?;
    Ok(path)
}

/// `fN` formatting helpers for table cells.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures() {
        let s = time_it(1, 5, || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(s.mean >= 0.002 && s.mean < 0.05, "{}", s.mean);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_s(2.0).ends_with(" s"));
        assert!(fmt_s(2e-3).ends_with(" ms"));
        assert!(fmt_s(2e-6).ends_with(" µs"));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into()]);
    }
}
