//! Named counters, gauges and histograms sampled on a cadence.
//!
//! A [`Registry`] is a flat name → value store. Instrumented code
//! *sets* gauges and *adds* to counters at any rate; the driver calls
//! [`Registry::due`] / [`Registry::snapshot`] on its own clock (the
//! fleet sim uses virtual time) so the sampled series
//! ([`Registry::samples`]) is bounded by the cadence, not the event
//! rate. [`sample_scheduler`] and [`sample_router`] capture the
//! standard cloud-tier gauges — queue depth, in-flight verifies,
//! resident/open sessions, free KV blocks, engine rows per tick,
//! migration bytes — which `tests/paging_invariants.rs` and
//! `tests/router_replicas.rs` cross-check against the live invariants.
//!
//! Names are dotted paths with a trailing replica index, e.g.
//! `cloud.free_blocks.0` or `router.migration_bytes`. Everything is
//! `f64`; counts below 2^53 are exact.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::cloud::router::Router;
use crate::cloud::scheduler::Scheduler;
use crate::model::cloud_engine::BatchEngine;

const HIST_BUCKETS: usize = 64;
/// Bucket 0 lower bound: 2^-40 s (≈ 1 ns); bucket 63 ≈ 2^23 s.
const HIST_MIN_EXP: f64 = -40.0;

/// Fixed-size log2 histogram: 64 power-of-two buckets spanning
/// roughly 1 ns .. 97 days when values are seconds.
#[derive(Debug, Clone)]
pub struct Hist {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    buckets: [u64; HIST_BUCKETS],
}

impl Default for Hist {
    fn default() -> Self {
        Hist {
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: [0; HIST_BUCKETS],
        }
    }
}

fn bucket_of(v: f64) -> usize {
    let idx = (v.max(1e-12).log2() - HIST_MIN_EXP).floor() as i64;
    idx.clamp(0, HIST_BUCKETS as i64 - 1) as usize
}

impl Hist {
    pub fn record(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.buckets[bucket_of(v)] += 1;
    }

    /// Mean of recorded values, `None` when empty.
    pub fn mean(&self) -> Option<f64> {
        if self.n == 0 {
            None
        } else {
            Some(self.sum / self.n as f64)
        }
    }

    /// Occupied buckets as `(lower_bound, count)` pairs, ascending.
    /// The lower bound is in value units (seconds for latency hists);
    /// a bucket spans `[lo, 2·lo)`. This is what the metrics JSONL
    /// exports so full distributions survive offline.
    pub fn nonzero_buckets(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        self.buckets
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(i, &c)| ((HIST_MIN_EXP + i as f64).exp2(), c))
    }

    /// Bucket-resolution quantile estimate (upper bound of the bucket
    /// holding the q-th value), `None` when empty.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.n == 0 {
            return None;
        }
        let rank = (q.clamp(0.0, 1.0) * self.n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some((HIST_MIN_EXP + i as f64 + 1.0).exp2());
            }
        }
        Some(self.max)
    }
}

/// One sampled point of the metric series.
#[derive(Debug, Clone)]
pub struct Sample {
    pub t_s: f64,
    pub name: String,
    pub value: f64,
}

/// Flat metric store with cadence-gated sampling (see module docs).
#[derive(Debug)]
pub struct Registry {
    cadence_s: f64,
    next_s: f64,
    counters: BTreeMap<String, f64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Hist>,
    /// Cadence-gated time series, one point per (snapshot, name).
    pub samples: Vec<Sample>,
}

impl Registry {
    /// A registry snapshotting at most every `cadence_s` seconds of
    /// driver time (0 ⇒ every call to [`Registry::snapshot`]).
    pub fn new(cadence_s: f64) -> Registry {
        Registry {
            cadence_s: cadence_s.max(0.0),
            next_s: 0.0,
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            hists: BTreeMap::new(),
            samples: Vec::new(),
        }
    }

    pub fn counter_add(&mut self, name: &str, delta: f64) {
        *self.counters.entry(name.to_string()).or_insert(0.0) += delta;
    }

    pub fn gauge_set(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Retire a gauge so subsequent snapshots stop sampling it (used
    /// by [`SloMonitor::sample`] to keep idle-tenant windows out of
    /// the series instead of republishing stale or 0/0 values).
    pub fn gauge_remove(&mut self, name: &str) {
        self.gauges.remove(name);
    }

    pub fn hist_record(&mut self, name: &str, value: f64) {
        self.hists.entry(name.to_string()).or_default().record(value);
    }

    pub fn counter(&self, name: &str) -> f64 {
        self.counters.get(name).copied().unwrap_or(0.0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.get(name)
    }

    /// Iterate final counter values (sorted by name).
    pub fn counters(&self) -> impl Iterator<Item = (&str, f64)> {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Iterate current gauge values (sorted by name).
    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Iterate histograms (sorted by name).
    pub fn hists(&self) -> impl Iterator<Item = (&str, &Hist)> {
        self.hists.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Is a snapshot due at driver time `t_s`?
    pub fn due(&self, t_s: f64) -> bool {
        t_s >= self.next_s
    }

    /// Append every gauge and counter to [`Registry::samples`] at
    /// `t_s` and arm the next cadence window. Callers gate on
    /// [`Registry::due`]; calling unconditionally forces a sample
    /// (e.g. one final end-of-run snapshot).
    pub fn snapshot(&mut self, t_s: f64) {
        for (name, &value) in &self.gauges {
            self.samples.push(Sample {
                t_s,
                name: name.clone(),
                value,
            });
        }
        for (name, &value) in &self.counters {
            self.samples.push(Sample {
                t_s,
                name: name.clone(),
                value,
            });
        }
        self.next_s = t_s + self.cadence_s;
    }
}

/// Shared handle drivers hold as `Option<RegistryShared>`.
pub type RegistryShared = Arc<Mutex<Registry>>;

/// A shareable registry with the given sampling cadence.
pub fn shared(cadence_s: f64) -> RegistryShared {
    Arc::new(Mutex::new(Registry::new(cadence_s)))
}

/// Run `f` against the registry if one is attached (single-branch
/// disabled path, mirroring [`crate::obs::trace::with`]).
pub fn with<F: FnOnce(&mut Registry)>(registry: &Option<RegistryShared>, f: F) {
    if let Some(r) = registry {
        if let Ok(mut reg) = r.lock() {
            f(&mut reg);
        }
    }
}

// ----------------------------- SLO monitor ---------------------------------

/// Per-tenant rolling window of SLO outcomes.
#[derive(Debug, Clone, Copy, Default)]
struct SloAcc {
    // cumulative (whole run)
    ttft_n: u64,
    ttft_ok: u64,
    tbt_n: u64,
    tbt_ok: u64,
    // current burn window (reset on every sample)
    win_ttft_n: u64,
    win_ttft_viol: u64,
    win_tbt_n: u64,
    win_tbt_viol: u64,
}

/// Per-tenant TTFT/TBT SLO attainment plus a rolling **burn rate**:
/// the fraction of the violation budget consumed per sampling window
/// (1.0 = violations arriving exactly at the budgeted rate, >1.0 =
/// the error budget is burning down faster than allowed — the sensing
/// half of the overload-survival control loop).
///
/// Drivers call [`SloMonitor::record_ttft`] / [`SloMonitor::record_tbt`]
/// as requests finish and [`SloMonitor::sample`] on the registry
/// cadence; sampling publishes the gauges and opens a new window.
#[derive(Debug, Clone)]
pub struct SloMonitor {
    policy: crate::config::SloPolicy,
    tenants: Vec<SloAcc>,
}

impl SloMonitor {
    pub fn new(n_tenants: usize, policy: crate::config::SloPolicy) -> SloMonitor {
        SloMonitor { policy, tenants: vec![SloAcc::default(); n_tenants.max(1)] }
    }

    pub fn policy(&self) -> &crate::config::SloPolicy {
        &self.policy
    }

    fn acc(&mut self, tenant: usize) -> &mut SloAcc {
        let last = self.tenants.len() - 1;
        &mut self.tenants[tenant.min(last)]
    }

    /// Record one finished request's time-to-first-token.
    pub fn record_ttft(&mut self, tenant: usize, ttft_s: f64) {
        let ok = ttft_s <= self.policy.ttft_s;
        let a = self.acc(tenant);
        a.ttft_n += 1;
        a.ttft_ok += ok as u64;
        a.win_ttft_n += 1;
        a.win_ttft_viol += !ok as u64;
    }

    /// Record one finished request's mean time-between-tokens.
    pub fn record_tbt(&mut self, tenant: usize, tbt_s: f64) {
        let ok = tbt_s <= self.policy.tbt_s;
        let a = self.acc(tenant);
        a.tbt_n += 1;
        a.tbt_ok += ok as u64;
        a.win_tbt_n += 1;
        a.win_tbt_viol += !ok as u64;
    }

    /// Cumulative TTFT attainment ∈ [0,1] (0.0 before any completion,
    /// matching the fleet report's convention).
    pub fn ttft_attainment(&self, tenant: usize) -> f64 {
        let a = &self.tenants[tenant.min(self.tenants.len() - 1)];
        a.ttft_ok as f64 / a.ttft_n.max(1) as f64
    }

    /// Cumulative TBT attainment over TBT-eligible requests (≥2
    /// tokens), 0.0 before any.
    pub fn tbt_attainment(&self, tenant: usize) -> f64 {
        let a = &self.tenants[tenant.min(self.tenants.len() - 1)];
        a.tbt_ok as f64 / a.tbt_n.max(1) as f64
    }

    fn burn(policy: &crate::config::SloPolicy, viol: u64, n: u64) -> f64 {
        if n == 0 || policy.violation_budget <= 0.0 {
            return 0.0;
        }
        (viol as f64 / n as f64) / policy.violation_budget
    }

    /// Publish per-tenant gauges (`slo.ttft_attainment.<t>`,
    /// `slo.tbt_attainment.<t>`, `slo.ttft_burn.<t>`,
    /// `slo.tbt_burn.<t>`) and reset the burn window. Call on the
    /// same cadence as [`Registry::snapshot`] so the burn window is
    /// the sampling window.
    ///
    /// A metric with **zero completions in the window** publishes no
    /// burn gauge for that window (the gauge is retired so the
    /// snapshot skips it — a 0/0 window must not put NaN or a stale
    /// rate into the series); attainment likewise stays unpublished
    /// until the tenant's first completion. Returns the per-tenant
    /// burn for the window — `max(ttft_burn, tbt_burn)`, `None` for a
    /// fully idle tenant — which the fleet driver feeds to the
    /// flight-recorder trigger.
    pub fn sample(&mut self, reg: &mut Registry) -> Vec<Option<f64>> {
        let mut burns = Vec::with_capacity(self.tenants.len());
        for (t, a) in self.tenants.iter_mut().enumerate() {
            if a.ttft_n > 0 {
                reg.gauge_set(
                    &format!("slo.ttft_attainment.{t}"),
                    a.ttft_ok as f64 / a.ttft_n as f64,
                );
            }
            if a.tbt_n > 0 {
                reg.gauge_set(
                    &format!("slo.tbt_attainment.{t}"),
                    a.tbt_ok as f64 / a.tbt_n as f64,
                );
            }
            let mut burn_now: Option<f64> = None;
            if a.win_ttft_n > 0 {
                let b = Self::burn(&self.policy, a.win_ttft_viol, a.win_ttft_n);
                reg.gauge_set(&format!("slo.ttft_burn.{t}"), b);
                burn_now = Some(b);
            } else {
                reg.gauge_remove(&format!("slo.ttft_burn.{t}"));
            }
            if a.win_tbt_n > 0 {
                let b = Self::burn(&self.policy, a.win_tbt_viol, a.win_tbt_n);
                reg.gauge_set(&format!("slo.tbt_burn.{t}"), b);
                burn_now = Some(burn_now.map_or(b, |x| x.max(b)));
            } else {
                reg.gauge_remove(&format!("slo.tbt_burn.{t}"));
            }
            burns.push(burn_now);
            a.win_ttft_n = 0;
            a.win_ttft_viol = 0;
            a.win_tbt_n = 0;
            a.win_tbt_viol = 0;
        }
        burns
    }
}

/// Capture the standard gauges of one scheduler replica under
/// `cloud.<gauge>.<tid>` names.
pub fn sample_scheduler<E: BatchEngine>(reg: &mut Registry, tid: usize, s: &Scheduler<E>) {
    let g = |name: &str| format!("cloud.{name}.{tid}");
    reg.gauge_set(&g("queue_depth"), s.queue_depth() as f64);
    reg.gauge_set(&g("in_flight"), s.in_flight() as f64);
    reg.gauge_set(&g("sessions_open"), s.active_sessions() as f64);
    let slots = s.engine.slots();
    let free_slots = s.engine.free_slots();
    reg.gauge_set(&g("sessions_resident"), (slots - free_slots) as f64);
    reg.gauge_set(&g("slots_free"), free_slots as f64);
    reg.gauge_set(&g("free_blocks"), s.sessions().free_blocks() as f64);
    reg.gauge_set(&g("block_capacity"), s.sessions().block_capacity() as f64);
    reg.gauge_set(&g("rows_executed"), s.stats.rows_executed as f64);
    let rows_per_tick = if s.stats.iterations > 0 {
        s.stats.rows_executed as f64 / s.stats.iterations as f64
    } else {
        0.0
    };
    reg.gauge_set(&g("rows_per_tick"), rows_per_tick);
    reg.gauge_set(&g("swap_ins"), s.sessions().stats().swap_ins as f64);
    reg.gauge_set(&g("swap_outs"), s.sessions().stats().swap_outs as f64);
    // shared-prefix cache traffic (zeros with the cache off) — these
    // live under `paging.` because block identity is a paging-layer
    // property, not a scheduler one
    let ps = s.sessions().prefix_stats();
    reg.gauge_set(&format!("paging.prefix_hits.{tid}"), ps.hits as f64);
    reg.gauge_set(&format!("paging.prefix_misses.{tid}"), ps.misses as f64);
    reg.gauge_set(&format!("paging.cow_copies.{tid}"), ps.cow_copies as f64);
}

/// Capture every replica of a router plus the router-level placement
/// and migration counters.
pub fn sample_router<E: BatchEngine>(reg: &mut Registry, router: &Router<E>) {
    for r in 0..router.n_replicas() {
        sample_scheduler(reg, r, router.replica(r));
    }
    reg.gauge_set("router.routed", router.stats.routed as f64);
    reg.gauge_set("router.migrations", router.stats.migrations as f64);
    reg.gauge_set("router.migration_bytes", router.stats.migration_bytes as f64);
    reg.gauge_set("router.rebalance_skips", router.stats.rebalance_skips as f64);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cadence_gates_snapshots() {
        let mut r = Registry::new(1.0);
        r.gauge_set("g", 1.0);
        assert!(r.due(0.0));
        r.snapshot(0.0);
        assert!(!r.due(0.5));
        assert!(r.due(1.0));
        r.gauge_set("g", 2.0);
        r.snapshot(1.0);
        let vals: Vec<f64> = r.samples.iter().map(|s| s.value).collect();
        assert_eq!(vals, vec![1.0, 2.0]);
    }

    #[test]
    fn counters_accumulate_and_gauges_overwrite() {
        let mut r = Registry::new(0.0);
        r.counter_add("c", 2.0);
        r.counter_add("c", 3.0);
        r.gauge_set("g", 7.0);
        r.gauge_set("g", 9.0);
        assert_eq!(r.counter("c"), 5.0);
        assert_eq!(r.gauge("g"), Some(9.0));
        assert_eq!(r.gauge("missing"), None);
    }

    #[test]
    fn hist_quantiles_are_bucket_bounds() {
        let mut r = Registry::new(0.0);
        assert!(r.hist("h").is_none());
        for _ in 0..90 {
            r.hist_record("h", 0.001);
        }
        for _ in 0..10 {
            r.hist_record("h", 1.0);
        }
        let h = r.hist("h").unwrap();
        assert_eq!(h.n, 100);
        let p50 = h.quantile(0.5).unwrap();
        assert!(p50 >= 0.001 && p50 < 0.01, "p50 ~ 1 ms bucket, got {p50}");
        let p99 = h.quantile(0.99).unwrap();
        assert!(p99 >= 1.0, "p99 in the 1 s bucket, got {p99}");
        assert_eq!(h.quantile(0.0).map(|_| ()), Some(()));
    }

    #[test]
    fn empty_hist_reports_none() {
        let h = Hist::default();
        assert_eq!(h.mean(), None);
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.nonzero_buckets().count(), 0);
    }

    #[test]
    fn hist_buckets_cover_recorded_values() {
        let mut h = Hist::default();
        h.record(0.25);
        h.record(0.3);
        h.record(4.0);
        let buckets: Vec<(f64, u64)> = h.nonzero_buckets().collect();
        assert_eq!(buckets.iter().map(|&(_, c)| c).sum::<u64>(), 3, "counts conserve n");
        for &(lo, _) in &buckets {
            assert!(lo > 0.0);
        }
        // 0.25 and 0.3 share the [0.25, 0.5) bucket; 4.0 is alone
        assert_eq!(buckets.len(), 2);
        assert_eq!(buckets[0], (0.25, 2));
        assert_eq!(buckets[1], (4.0, 1));
    }

    #[test]
    fn slo_monitor_burn_rate_is_windowed() {
        let policy =
            crate::config::SloPolicy { ttft_s: 1.0, tbt_s: 0.1, violation_budget: 0.25 };
        let mut m = SloMonitor::new(2, policy);
        let mut reg = Registry::new(0.0);
        // window 1, tenant 0: 3 ok + 1 violation of 4 → 25% violations
        // = exactly the budget → burn 1.0
        for _ in 0..3 {
            m.record_ttft(0, 0.5);
        }
        m.record_ttft(0, 2.0);
        let burns = m.sample(&mut reg);
        assert_eq!(reg.gauge("slo.ttft_attainment.0"), Some(0.75));
        assert_eq!(reg.gauge("slo.ttft_burn.0"), Some(1.0));
        assert_eq!(reg.gauge("slo.ttft_burn.1"), None, "idle tenant emits no burn sample");
        assert_eq!(reg.gauge("slo.ttft_attainment.1"), None, "…nor attainment");
        assert_eq!(burns, vec![Some(1.0), None]);
        // window 2: all violations → burn 1/0.25 = 4; cumulative
        // attainment decays but is not reset
        m.record_ttft(0, 3.0);
        m.record_ttft(0, 3.0);
        m.sample(&mut reg);
        assert_eq!(reg.gauge("slo.ttft_burn.0"), Some(4.0));
        assert_eq!(reg.gauge("slo.ttft_attainment.0"), Some(0.5));
        // TBT path is independent
        m.record_tbt(1, 0.05);
        m.record_tbt(1, 0.5);
        let burns = m.sample(&mut reg);
        assert_eq!(reg.gauge("slo.tbt_attainment.1"), Some(0.5));
        assert_eq!(reg.gauge("slo.tbt_burn.1"), Some(2.0));
        assert_eq!(burns[1], Some(2.0));
        // empty window after sampling → the burn gauge is retired (no
        // 0/0 sample), while cumulative attainment keeps publishing
        let burns = m.sample(&mut reg);
        assert_eq!(reg.gauge("slo.tbt_burn.1"), None);
        assert_eq!(reg.gauge("slo.tbt_attainment.1"), Some(0.5));
        assert_eq!(burns, vec![None, None]);
    }
}
