//! Named counters, gauges and histograms sampled on a cadence.
//!
//! A [`Registry`] is a flat name → value store. Instrumented code
//! *sets* gauges and *adds* to counters at any rate; the driver calls
//! [`Registry::due`] / [`Registry::snapshot`] on its own clock (the
//! fleet sim uses virtual time) so the sampled series
//! ([`Registry::samples`]) is bounded by the cadence, not the event
//! rate. [`sample_scheduler`] and [`sample_router`] capture the
//! standard cloud-tier gauges — queue depth, in-flight verifies,
//! resident/open sessions, free KV blocks, engine rows per tick,
//! migration bytes — which `tests/paging_invariants.rs` and
//! `tests/router_replicas.rs` cross-check against the live invariants.
//!
//! Names are dotted paths with a trailing replica index, e.g.
//! `cloud.free_blocks.0` or `router.migration_bytes`. Everything is
//! `f64`; counts below 2^53 are exact.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::cloud::router::Router;
use crate::cloud::scheduler::Scheduler;
use crate::model::cloud_engine::BatchEngine;

const HIST_BUCKETS: usize = 64;
/// Bucket 0 lower bound: 2^-40 s (≈ 1 ns); bucket 63 ≈ 2^23 s.
const HIST_MIN_EXP: f64 = -40.0;

/// Fixed-size log2 histogram: 64 power-of-two buckets spanning
/// roughly 1 ns .. 97 days when values are seconds.
#[derive(Debug, Clone)]
pub struct Hist {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    buckets: [u64; HIST_BUCKETS],
}

impl Default for Hist {
    fn default() -> Self {
        Hist {
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: [0; HIST_BUCKETS],
        }
    }
}

fn bucket_of(v: f64) -> usize {
    let idx = (v.max(1e-12).log2() - HIST_MIN_EXP).floor() as i64;
    idx.clamp(0, HIST_BUCKETS as i64 - 1) as usize
}

impl Hist {
    pub fn record(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.buckets[bucket_of(v)] += 1;
    }

    /// Mean of recorded values, `None` when empty.
    pub fn mean(&self) -> Option<f64> {
        if self.n == 0 {
            None
        } else {
            Some(self.sum / self.n as f64)
        }
    }

    /// Bucket-resolution quantile estimate (upper bound of the bucket
    /// holding the q-th value), `None` when empty.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.n == 0 {
            return None;
        }
        let rank = (q.clamp(0.0, 1.0) * self.n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some((HIST_MIN_EXP + i as f64 + 1.0).exp2());
            }
        }
        Some(self.max)
    }
}

/// One sampled point of the metric series.
#[derive(Debug, Clone)]
pub struct Sample {
    pub t_s: f64,
    pub name: String,
    pub value: f64,
}

/// Flat metric store with cadence-gated sampling (see module docs).
#[derive(Debug)]
pub struct Registry {
    cadence_s: f64,
    next_s: f64,
    counters: BTreeMap<String, f64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Hist>,
    /// Cadence-gated time series, one point per (snapshot, name).
    pub samples: Vec<Sample>,
}

impl Registry {
    /// A registry snapshotting at most every `cadence_s` seconds of
    /// driver time (0 ⇒ every call to [`Registry::snapshot`]).
    pub fn new(cadence_s: f64) -> Registry {
        Registry {
            cadence_s: cadence_s.max(0.0),
            next_s: 0.0,
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            hists: BTreeMap::new(),
            samples: Vec::new(),
        }
    }

    pub fn counter_add(&mut self, name: &str, delta: f64) {
        *self.counters.entry(name.to_string()).or_insert(0.0) += delta;
    }

    pub fn gauge_set(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    pub fn hist_record(&mut self, name: &str, value: f64) {
        self.hists.entry(name.to_string()).or_default().record(value);
    }

    pub fn counter(&self, name: &str) -> f64 {
        self.counters.get(name).copied().unwrap_or(0.0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.get(name)
    }

    /// Iterate final counter values (sorted by name).
    pub fn counters(&self) -> impl Iterator<Item = (&str, f64)> {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Iterate current gauge values (sorted by name).
    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Iterate histograms (sorted by name).
    pub fn hists(&self) -> impl Iterator<Item = (&str, &Hist)> {
        self.hists.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Is a snapshot due at driver time `t_s`?
    pub fn due(&self, t_s: f64) -> bool {
        t_s >= self.next_s
    }

    /// Append every gauge and counter to [`Registry::samples`] at
    /// `t_s` and arm the next cadence window. Callers gate on
    /// [`Registry::due`]; calling unconditionally forces a sample
    /// (e.g. one final end-of-run snapshot).
    pub fn snapshot(&mut self, t_s: f64) {
        for (name, &value) in &self.gauges {
            self.samples.push(Sample {
                t_s,
                name: name.clone(),
                value,
            });
        }
        for (name, &value) in &self.counters {
            self.samples.push(Sample {
                t_s,
                name: name.clone(),
                value,
            });
        }
        self.next_s = t_s + self.cadence_s;
    }
}

/// Shared handle drivers hold as `Option<RegistryShared>`.
pub type RegistryShared = Arc<Mutex<Registry>>;

/// A shareable registry with the given sampling cadence.
pub fn shared(cadence_s: f64) -> RegistryShared {
    Arc::new(Mutex::new(Registry::new(cadence_s)))
}

/// Run `f` against the registry if one is attached (single-branch
/// disabled path, mirroring [`crate::obs::trace::with`]).
pub fn with<F: FnOnce(&mut Registry)>(registry: &Option<RegistryShared>, f: F) {
    if let Some(r) = registry {
        if let Ok(mut reg) = r.lock() {
            f(&mut reg);
        }
    }
}

/// Capture the standard gauges of one scheduler replica under
/// `cloud.<gauge>.<tid>` names.
pub fn sample_scheduler<E: BatchEngine>(reg: &mut Registry, tid: usize, s: &Scheduler<E>) {
    let g = |name: &str| format!("cloud.{name}.{tid}");
    reg.gauge_set(&g("queue_depth"), s.queue_depth() as f64);
    reg.gauge_set(&g("in_flight"), s.in_flight() as f64);
    reg.gauge_set(&g("sessions_open"), s.active_sessions() as f64);
    let slots = s.engine.slots();
    let free_slots = s.engine.free_slots();
    reg.gauge_set(&g("sessions_resident"), (slots - free_slots) as f64);
    reg.gauge_set(&g("slots_free"), free_slots as f64);
    reg.gauge_set(&g("free_blocks"), s.sessions().free_blocks() as f64);
    reg.gauge_set(&g("block_capacity"), s.sessions().block_capacity() as f64);
    reg.gauge_set(&g("rows_executed"), s.stats.rows_executed as f64);
    let rows_per_tick = if s.stats.iterations > 0 {
        s.stats.rows_executed as f64 / s.stats.iterations as f64
    } else {
        0.0
    };
    reg.gauge_set(&g("rows_per_tick"), rows_per_tick);
    reg.gauge_set(&g("swap_ins"), s.sessions().stats().swap_ins as f64);
    reg.gauge_set(&g("swap_outs"), s.sessions().stats().swap_outs as f64);
}

/// Capture every replica of a router plus the router-level placement
/// and migration counters.
pub fn sample_router<E: BatchEngine>(reg: &mut Registry, router: &Router<E>) {
    for r in 0..router.n_replicas() {
        sample_scheduler(reg, r, router.replica(r));
    }
    reg.gauge_set("router.routed", router.stats.routed as f64);
    reg.gauge_set("router.migrations", router.stats.migrations as f64);
    reg.gauge_set("router.migration_bytes", router.stats.migration_bytes as f64);
    reg.gauge_set("router.rebalance_skips", router.stats.rebalance_skips as f64);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cadence_gates_snapshots() {
        let mut r = Registry::new(1.0);
        r.gauge_set("g", 1.0);
        assert!(r.due(0.0));
        r.snapshot(0.0);
        assert!(!r.due(0.5));
        assert!(r.due(1.0));
        r.gauge_set("g", 2.0);
        r.snapshot(1.0);
        let vals: Vec<f64> = r.samples.iter().map(|s| s.value).collect();
        assert_eq!(vals, vec![1.0, 2.0]);
    }

    #[test]
    fn counters_accumulate_and_gauges_overwrite() {
        let mut r = Registry::new(0.0);
        r.counter_add("c", 2.0);
        r.counter_add("c", 3.0);
        r.gauge_set("g", 7.0);
        r.gauge_set("g", 9.0);
        assert_eq!(r.counter("c"), 5.0);
        assert_eq!(r.gauge("g"), Some(9.0));
        assert_eq!(r.gauge("missing"), None);
    }

    #[test]
    fn hist_quantiles_are_bucket_bounds() {
        let mut r = Registry::new(0.0);
        assert!(r.hist("h").is_none());
        for _ in 0..90 {
            r.hist_record("h", 0.001);
        }
        for _ in 0..10 {
            r.hist_record("h", 1.0);
        }
        let h = r.hist("h").unwrap();
        assert_eq!(h.n, 100);
        let p50 = h.quantile(0.5).unwrap();
        assert!(p50 >= 0.001 && p50 < 0.01, "p50 ~ 1 ms bucket, got {p50}");
        let p99 = h.quantile(0.99).unwrap();
        assert!(p99 >= 1.0, "p99 in the 1 s bucket, got {p99}");
        assert_eq!(h.quantile(0.0).map(|_| ()), Some(()));
    }

    #[test]
    fn empty_hist_reports_none() {
        let h = Hist::default();
        assert_eq!(h.mean(), None);
        assert_eq!(h.quantile(0.5), None);
    }
}
