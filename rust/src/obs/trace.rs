//! Spans and events: the [`TraceSink`] ring buffer and the [`Clock`]
//! contract.
//!
//! ## The clock contract
//!
//! Every event is stamped by the sink's [`Clock`] at record time:
//!
//! * the fleet simulator attaches a [`VirtualClock`] and calls
//!   [`TraceSink::set_now`] with the firing time of each discrete
//!   event, so timestamps are *virtual seconds* and a same-seed run
//!   reproduces the event stream byte for byte
//!   (`tests/obs_trace.rs`);
//! * the threaded server attaches a [`WallClock`] (seconds since the
//!   sink was built); `set_now` is a no-op there.
//!
//! A deterministic (virtual) clock additionally zeroes the measured
//! wall durations of [`TraceSink::complete`] events — wall time must
//! never leak into a simulator trace.
//!
//! ## Pay-for-what-you-use
//!
//! Instrumented code holds an `Option<TraceShared>`; a disabled sink
//! is `None` and every record site is one branch ([`with`]). Enabled
//! sinks are `Arc<Mutex<_>>` so the threaded server's replica and
//! device threads can share one wall clock; the simulator is
//! single-threaded, so the lock is uncontended and ordering stays
//! deterministic.
//!
//! ## Track layout (Perfetto)
//!
//! `pid`/`tid` place events on tracks: process [`PID_ROUTER`] is the
//! router, process [`PID_CLOUD`] holds one thread per scheduler
//! replica, and [`tenant_pid`]`(t)` is one process per device tenant
//! holding one thread per device. Begin/end spans of one device are
//! strictly sequential (a device runs one request and one round at a
//! time), so span nesting per track is always well formed.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::sampler::{Sampler, SamplerConfig, SamplerStats};

/// Timestamp source for a [`TraceSink`] (see the module docs for the
/// virtual-vs-wall contract).
pub trait Clock: Send {
    /// Seconds since the run started.
    fn now_s(&self) -> f64;
    /// Advance a virtual clock; wall clocks ignore this.
    fn advance_to(&mut self, _now_s: f64) {}
    /// Deterministic clocks force measured wall durations to zero.
    fn is_deterministic(&self) -> bool {
        false
    }
}

/// Caller-advanced clock for discrete-event simulation: time moves
/// only via [`Clock::advance_to`] (monotone — moving backwards is
/// ignored), so same inputs give identical stamps.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now_s: f64,
}

impl Clock for VirtualClock {
    fn now_s(&self) -> f64 {
        self.now_s
    }

    fn advance_to(&mut self, now_s: f64) {
        if now_s > self.now_s {
            self.now_s = now_s;
        }
    }

    fn is_deterministic(&self) -> bool {
        true
    }
}

/// Wall clock: seconds since construction (the threaded server).
#[derive(Debug, Clone, Copy)]
pub struct WallClock {
    t0: Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock { t0: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

/// Track process of the router tier.
pub const PID_ROUTER: u32 = 0;
/// Track process of the cloud tier (one thread per scheduler replica).
pub const PID_CLOUD: u32 = 1;

/// Track process of device tenant `t` (one thread per device).
pub fn tenant_pid(tenant: usize) -> u32 {
    2 + tenant as u32
}

/// Chrome trace-event phase of a recorded event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ph {
    /// Span open (`"B"`).
    Begin,
    /// Span close (`"E"`).
    End,
    /// Point event (`"i"`).
    Instant,
    /// Self-contained span with a duration (`"X"`).
    Complete,
    /// Counter sample (`"C"`).
    Counter,
    /// Flow arrow start (`"s"`): the device-side end of a causal
    /// device→cloud link; `id` binds the arrow's events together.
    FlowStart,
    /// Flow arrow step (`"t"`): an intermediate hop (cloud side).
    FlowStep,
    /// Flow arrow end (`"f"`): the arrow's terminus (back on device).
    FlowEnd,
}

impl Ph {
    /// The Chrome trace-event `ph` code.
    pub fn code(self) -> &'static str {
        match self {
            Ph::Begin => "B",
            Ph::End => "E",
            Ph::Instant => "i",
            Ph::Complete => "X",
            Ph::Counter => "C",
            Ph::FlowStart => "s",
            Ph::FlowStep => "t",
            Ph::FlowEnd => "f",
        }
    }

    /// Is this one of the flow-arrow phases (`s`/`t`/`f`)?
    pub fn is_flow(self) -> bool {
        matches!(self, Ph::FlowStart | Ph::FlowStep | Ph::FlowEnd)
    }
}

/// One recorded trace event. `name`/`cat` are static so a record is
/// two words and no allocation on the hot path; `args` carry numeric
/// payloads only (deterministic serialization).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Seconds since run start (the sink's clock).
    pub ts_s: f64,
    /// Duration for [`Ph::Complete`] events (0 otherwise).
    pub dur_s: f64,
    pub ph: Ph,
    pub name: &'static str,
    pub cat: &'static str,
    pub pid: u32,
    pub tid: u32,
    /// Request/session id (0 = none).
    pub id: u64,
    pub args: Vec<(&'static str, f64)>,
    /// Global record sequence number, stamped by the sink. Orders the
    /// merged export stream when a sampler splits events between the
    /// ring and per-request buffers (ties in `ts_s` are common — many
    /// events fire at one discrete-event time).
    pub seq: u64,
}

/// Bounded ring buffer of trace events stamped by a [`Clock`]. On
/// overflow the *oldest* event is dropped (and counted), so the tail
/// of a run is always retained and drops are as deterministic as the
/// event stream itself.
///
/// With a [`Sampler`] attached ([`TraceSink::with_sampler`]), events
/// that name a request are staged per request instead of entering the
/// ring; at [`TraceSink::complete_request`] the sampler retains or
/// discards the request's whole set (head draw / tail interest /
/// top-k latency — see [`crate::obs::sampler`]). Background events
/// (phase slices, counters, id-0 instants) still ride the ring.
/// [`TraceSink::snapshot_events`] merges both sides back into one
/// seq-ordered stream for export.
pub struct TraceSink {
    clock: Box<dyn Clock>,
    deterministic: bool,
    cap: usize,
    events: VecDeque<TraceEvent>,
    dropped: u64,
    next_seq: u64,
    sampler: Option<Sampler>,
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceSink")
            .field("deterministic", &self.deterministic)
            .field("cap", &self.cap)
            .field("events", &self.events.len())
            .field("dropped", &self.dropped)
            .field("sampled", &self.sampler.is_some())
            .finish()
    }
}

impl TraceSink {
    pub fn new(clock: Box<dyn Clock>, cap: usize) -> TraceSink {
        TraceSink {
            deterministic: clock.is_deterministic(),
            clock,
            cap: cap.max(1),
            events: VecDeque::new(),
            dropped: 0,
            next_seq: 0,
            sampler: None,
        }
    }

    /// Attach outcome-based retention: request-classified events stage
    /// per request and survive only per the sampler's policy
    /// (builder-style, for sink construction).
    pub fn with_sampler(mut self, cfg: SamplerConfig) -> TraceSink {
        self.sampler = Some(Sampler::new(cfg));
        self
    }

    /// The attached sampler, if any.
    pub fn sampler(&self) -> Option<&Sampler> {
        self.sampler.as_ref()
    }

    /// Sampler accounting, `None` when no sampler is attached.
    pub fn sampler_stats(&self) -> Option<SamplerStats> {
        self.sampler.as_ref().map(|s| s.stats())
    }

    /// Sink over a [`VirtualClock`] starting at 0 (simulators).
    pub fn virtual_time(cap: usize) -> TraceSink {
        TraceSink::new(Box::new(VirtualClock::default()), cap)
    }

    /// Sink over a [`WallClock`] started now (threaded serving).
    pub fn wall_time(cap: usize) -> TraceSink {
        TraceSink::new(Box::new(WallClock::new()), cap)
    }

    /// Advance a virtual clock to the current discrete-event time
    /// (no-op on wall clocks).
    pub fn set_now(&mut self, now_s: f64) {
        self.clock.advance_to(now_s);
    }

    pub fn now_s(&self) -> f64 {
        self.clock.now_s()
    }

    /// Does this sink zero measured wall durations (virtual clock)?
    pub fn is_deterministic(&self) -> bool {
        self.deterministic
    }

    fn push(&mut self, mut e: TraceEvent) {
        e.seq = self.next_seq;
        self.next_seq += 1;
        if let Some(sampler) = &mut self.sampler {
            if let Some(req) = Sampler::request_of(&e) {
                sampler.stage(req, e);
                return;
            }
        }
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(e);
    }

    /// Settle a finished request with the attached sampler (no-op
    /// without one): `latency_s` keys the top-k-slowest heap,
    /// `interesting` forces tail retention (SLO miss, error/partial
    /// outcome).
    pub fn complete_request(&mut self, request_id: u64, latency_s: f64, interesting: bool) {
        if let Some(sampler) = &mut self.sampler {
            sampler.complete(request_id, latency_s, interesting);
        }
    }

    /// Flag an in-flight request as tail-interesting regardless of its
    /// eventual completion verdict (no-op without a sampler).
    pub fn mark_interesting(&mut self, request_id: u64) {
        if let Some(sampler) = &mut self.sampler {
            sampler.mark_interesting(request_id);
        }
    }

    /// Open a span on track `(pid, tid)` for request/session `id`.
    pub fn begin(&mut self, pid: u32, tid: u32, name: &'static str, id: u64) {
        let ts_s = self.clock.now_s();
        self.push(TraceEvent {
            ts_s,
            dur_s: 0.0,
            ph: Ph::Begin,
            name,
            cat: "span",
            pid,
            tid,
            id,
            args: Vec::new(),
            seq: 0,
        });
    }

    /// Close the innermost open span `name` on track `(pid, tid)`.
    pub fn end(&mut self, pid: u32, tid: u32, name: &'static str, id: u64) {
        let ts_s = self.clock.now_s();
        self.push(TraceEvent {
            ts_s,
            dur_s: 0.0,
            ph: Ph::End,
            name,
            cat: "span",
            pid,
            tid,
            id,
            args: Vec::new(),
            seq: 0,
        });
    }

    /// Point event with numeric args.
    pub fn instant(
        &mut self,
        pid: u32,
        tid: u32,
        name: &'static str,
        id: u64,
        args: Vec<(&'static str, f64)>,
    ) {
        let ts_s = self.clock.now_s();
        self.push(TraceEvent {
            ts_s,
            dur_s: 0.0,
            ph: Ph::Instant,
            name,
            cat: "event",
            pid,
            tid,
            id,
            args,
            seq: 0,
        });
    }

    /// Self-contained span at `ts_s` lasting `dur_s` (both measured by
    /// the caller against this sink's clock). Under a deterministic
    /// clock the duration is forced to 0 — measured wall time must not
    /// leak into a virtual-time trace.
    pub fn complete(
        &mut self,
        pid: u32,
        tid: u32,
        name: &'static str,
        ts_s: f64,
        dur_s: f64,
        args: Vec<(&'static str, f64)>,
    ) {
        self.push(TraceEvent {
            ts_s,
            dur_s: if self.deterministic { 0.0 } else { dur_s },
            ph: Ph::Complete,
            name,
            cat: "phase",
            pid,
            tid,
            id: 0,
            args,
            seq: 0,
        });
    }

    /// Flow-arrow event: `ph` must be one of the flow phases and `id`
    /// the nonzero flow id shared by the arrow's start/step/end
    /// (`net::wire::TraceContext::flow_id`). Flow events attach to the
    /// slice enclosing their timestamp on track `(pid, tid)`, which is
    /// how Perfetto draws the device→cloud→device arrows.
    pub fn flow(&mut self, pid: u32, tid: u32, name: &'static str, ph: Ph, id: u64) {
        debug_assert!(ph.is_flow(), "flow() takes a flow phase, got {ph:?}");
        debug_assert!(id != 0, "flow id 0 would be dropped by the exporter");
        let ts_s = self.clock.now_s();
        self.push(TraceEvent {
            ts_s,
            dur_s: 0.0,
            ph,
            name,
            cat: "flow",
            pid,
            tid,
            id,
            args: Vec::new(),
            seq: 0,
        });
    }

    /// Counter sample (`value` lands in the args).
    pub fn counter(&mut self, pid: u32, tid: u32, name: &'static str, value: f64) {
        let ts_s = self.clock.now_s();
        self.push(TraceEvent {
            ts_s,
            dur_s: 0.0,
            ph: Ph::Counter,
            name,
            cat: "counter",
            pid,
            tid,
            id: 0,
            args: vec![("value", value)],
            seq: 0,
        });
    }

    /// Ring-buffer events, oldest first. Without a sampler this is
    /// every recorded event; with one it is only the background stream
    /// — use [`TraceSink::snapshot_events`] for the merged view.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter()
    }

    /// All currently held events — ring plus (with a sampler) retained
    /// and still-staged request buffers — in record order. This is the
    /// stream the exporters serialize; for an unsampled sink it equals
    /// [`TraceSink::events`] exactly.
    pub fn snapshot_events(&self) -> Vec<TraceEvent> {
        let mut out: Vec<TraceEvent> = self.events.iter().cloned().collect();
        if let Some(sampler) = &self.sampler {
            out.extend(sampler.events().cloned());
            out.sort_unstable_by_key(|e| e.seq);
        }
        out
    }

    /// Events currently held (ring + sampler buffers).
    pub fn len(&self) -> usize {
        self.events.len() + self.sampler.as_ref().map_or(0, |s| s.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events the ring buffer discarded (oldest-first overflow). Does
    /// not count events a sampler discarded *by policy* — those are in
    /// [`TraceSink::sampler_stats`].
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Number of `(pid, tid, id, name)` span keys whose begin/end
    /// counts differ — 0 for a fully drained run with no ring drops
    /// (the per-request balance gate in `tests/obs_trace.rs`). With a
    /// sampler attached, computed over the merged retained view, so
    /// retained requests must carry complete span sets.
    pub fn span_imbalance(&self) -> usize {
        let mut bal: BTreeMap<(u32, u32, u64, &'static str), i64> = BTreeMap::new();
        let sampled = self.sampler.iter().flat_map(|s| s.events());
        for e in self.events.iter().chain(sampled) {
            match e.ph {
                Ph::Begin => *bal.entry((e.pid, e.tid, e.id, e.name)).or_insert(0) += 1,
                Ph::End => *bal.entry((e.pid, e.tid, e.id, e.name)).or_insert(0) -= 1,
                _ => {}
            }
        }
        bal.values().filter(|&&v| v != 0).count()
    }
}

/// Shared handle instrumented code holds as `Option<TraceShared>`.
pub type TraceShared = Arc<Mutex<TraceSink>>;

/// Wrap a sink for sharing across the instrumented layers.
pub fn shared(sink: TraceSink) -> TraceShared {
    Arc::new(Mutex::new(sink))
}

/// Run `f` against the sink if one is attached — the single-branch
/// disabled path every instrumentation site compiles down to.
pub fn with<F: FnOnce(&mut TraceSink)>(trace: &Option<TraceShared>, f: F) {
    if let Some(t) = trace {
        if let Ok(mut sink) = t.lock() {
            f(&mut sink);
        }
    }
}

/// Advance an attached sink's virtual clock (no-op when disabled or
/// on a wall clock).
pub fn set_now(trace: &Option<TraceShared>, now_s: f64) {
    if let Some(t) = trace {
        if let Ok(mut sink) = t.lock() {
            sink.set_now(now_s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_monotone_and_deterministic() {
        let mut s = TraceSink::virtual_time(16);
        assert!(s.is_deterministic());
        s.set_now(2.0);
        s.set_now(1.0); // backwards move ignored
        assert_eq!(s.now_s(), 2.0);
        s.instant(1, 0, "x", 7, vec![("v", 3.0)]);
        let e = s.events().next().unwrap();
        assert_eq!(e.ts_s, 2.0);
        assert_eq!(e.id, 7);
    }

    #[test]
    fn deterministic_sink_zeroes_complete_durations() {
        let mut s = TraceSink::virtual_time(16);
        s.complete(1, 0, "phase", 1.0, 0.125, vec![]);
        assert_eq!(s.events().next().unwrap().dur_s, 0.0);
        let mut w = TraceSink::wall_time(16);
        w.complete(1, 0, "phase", 1.0, 0.125, vec![]);
        assert_eq!(w.events().next().unwrap().dur_s, 0.125);
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut s = TraceSink::virtual_time(2);
        for i in 0..5u64 {
            s.instant(0, 0, "e", i, vec![]);
        }
        assert_eq!(s.len(), 2);
        assert_eq!(s.dropped(), 3);
        let ids: Vec<u64> = s.events().map(|e| e.id).collect();
        assert_eq!(ids, vec![3, 4], "newest events survive");
    }

    #[test]
    fn span_imbalance_counts_unclosed_spans() {
        let mut s = TraceSink::virtual_time(16);
        s.begin(2, 0, "request", 1);
        s.begin(2, 0, "round", 1);
        s.end(2, 0, "round", 1);
        assert_eq!(s.span_imbalance(), 1);
        s.end(2, 0, "request", 1);
        assert_eq!(s.span_imbalance(), 0);
    }

    #[test]
    fn flow_events_carry_phase_and_id() {
        let mut s = TraceSink::virtual_time(16);
        s.set_now(1.0);
        s.flow(2, 0, "offload", Ph::FlowStart, 0xF1);
        s.flow(1, 0, "offload", Ph::FlowStep, 0xF1);
        s.flow(2, 0, "offload", Ph::FlowEnd, 0xF1);
        let phases: Vec<&str> = s.events().map(|e| e.ph.code()).collect();
        assert_eq!(phases, vec!["s", "t", "f"]);
        assert!(s.events().all(|e| e.id == 0xF1 && e.cat == "flow"));
        assert_eq!(s.span_imbalance(), 0, "flows are not spans");
    }

    #[test]
    fn disabled_sink_is_a_noop_branch() {
        let none: Option<TraceShared> = None;
        with(&none, |_| panic!("must not run"));
        set_now(&none, 1.0);
    }
}
