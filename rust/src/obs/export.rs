//! Serialize traces and metrics: Chrome trace-event JSON and JSONL.
//!
//! Everything routes through [`crate::util::json::Json`] (object keys
//! in `BTreeMap` order, integer-exact number formatting), so a
//! deterministic event stream serializes to deterministic *bytes* —
//! the byte-identity gate in `tests/obs_trace.rs` compares these
//! strings directly.
//!
//! ## Chrome trace-event schema
//!
//! [`chrome_trace_string`] emits `{"traceEvents": [...]}` in the
//! [Trace Event Format]: one object per event with `ph` (`B`/`E`/`i`/
//! `X`/`C`), `ts`/`dur` in **microseconds**, `pid`/`tid` track ids,
//! `name`, `cat`, optional `id` and numeric `args` — plus `M`
//! (metadata) events naming every process and thread seen, so the
//! file opens in Perfetto (<https://ui.perfetto.dev>) with readable
//! tracks: `router`, `cloud` (one thread per replica), and one
//! process per device tenant (one thread per device).
//!
//! [Trace Event Format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
//!
//! ## JSONL schemas
//!
//! * [`events_jsonl_string`]: one event object per line, seconds (not
//!   µs), same field names as the in-memory [`TraceEvent`].
//! * [`metrics_jsonl_string`]: one `{"t_s", "name", "value"}` line per
//!   registry sample, then one `{"hist", "n", "mean", "p50", "p95",
//!   "max"}` line per histogram.

use std::collections::BTreeSet;
use std::path::Path;

use crate::obs::registry::Registry;
use crate::obs::trace::{Ph, TraceEvent, TraceSink, PID_CLOUD, PID_ROUTER};
use crate::util::json::Json;
use crate::Result;

fn process_name(pid: u32) -> String {
    match pid {
        PID_ROUTER => "router".to_string(),
        PID_CLOUD => "cloud".to_string(),
        p => format!("tenant {}", p - 2),
    }
}

fn thread_name(pid: u32, tid: u32) -> String {
    match pid {
        PID_ROUTER => "router".to_string(),
        PID_CLOUD => format!("replica {tid}"),
        _ => format!("dev {tid}"),
    }
}

fn metadata_event(name: &'static str, pid: u32, tid: u32, label: String) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("pid", Json::num(pid)),
        ("tid", Json::num(tid)),
        ("name", Json::str(name)),
        ("args", Json::obj(vec![("name", Json::Str(label))])),
    ])
}

fn args_json(args: &[(&'static str, f64)]) -> Json {
    Json::obj(args.iter().map(|&(k, v)| (k, Json::num(v))).collect())
}

fn event_json(e: &TraceEvent) -> Json {
    let mut fields = vec![
        ("ph", Json::str(e.ph.code())),
        ("ts", Json::num(e.ts_s * 1e6)),
        ("pid", Json::num(e.pid)),
        ("tid", Json::num(e.tid)),
        ("name", Json::str(e.name)),
        ("cat", Json::str(e.cat)),
    ];
    if e.ph == Ph::Complete {
        fields.push(("dur", Json::num(e.dur_s * 1e6)));
    }
    if e.ph == Ph::Instant {
        // process-scoped instants render as full-height markers
        fields.push(("s", Json::str("t")));
    }
    if e.ph == Ph::FlowEnd {
        // bind the arrow head to the enclosing slice, not the next one
        fields.push(("bp", Json::str("e")));
    }
    if e.id != 0 {
        fields.push(("id", Json::num(e.id as f64)));
    }
    if !e.args.is_empty() {
        fields.push(("args", args_json(&e.args)));
    }
    Json::obj(fields)
}

/// The whole sink as one Chrome trace-event JSON document (see the
/// module docs for the schema). With a sampler attached the stream is
/// the merged retained + in-flight view ([`TraceSink::snapshot_events`]),
/// in record order, so all-retain mode is byte-identical to an
/// unsampled sink.
pub fn chrome_trace_string(sink: &TraceSink) -> String {
    chrome_trace_string_from(&sink.snapshot_events(), sink.dropped())
}

/// Serialize an explicit event slice (record order) as a Chrome trace
/// document — the flight recorder uses this to dump a sampler snapshot
/// without a sink.
pub fn chrome_trace_string_from(events_in: &[TraceEvent], dropped: u64) -> String {
    let mut tracks: BTreeSet<(u32, u32)> = BTreeSet::new();
    for e in events_in {
        tracks.insert((e.pid, e.tid));
    }
    let mut events: Vec<Json> = Vec::with_capacity(events_in.len() + 2 * tracks.len());
    let mut pids: BTreeSet<u32> = BTreeSet::new();
    for &(pid, tid) in &tracks {
        if pids.insert(pid) {
            events.push(metadata_event("process_name", pid, 0, process_name(pid)));
        }
        events.push(metadata_event("thread_name", pid, tid, thread_name(pid, tid)));
    }
    for e in events_in {
        events.push(event_json(e));
    }
    for e in drop_marker_events(events_in, dropped) {
        events.push(e);
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
    .to_string()
}

/// Ring-wrap accounting (never silently truncate): when the sink
/// dropped events, the export ends with a `trace.dropped` counter
/// sample plus an instant so both Perfetto and offline consumers see
/// the loss. Empty when nothing was dropped, keeping intact exports
/// byte-identical to earlier schema versions.
fn drop_marker_events(events: &[TraceEvent], dropped: u64) -> Vec<Json> {
    if dropped == 0 {
        return Vec::new();
    }
    let ts = events.last().map(|e| e.ts_s).unwrap_or(0.0) * 1e6;
    let base = |ph: &'static str| {
        vec![
            ("ph", Json::str(ph)),
            ("ts", Json::num(ts)),
            ("pid", Json::num(PID_ROUTER)),
            ("tid", Json::num(0)),
            ("name", Json::str("trace.dropped")),
            ("cat", Json::str("meta")),
        ]
    };
    let mut counter = base("C");
    counter.push(("args", Json::obj(vec![("value", Json::num(dropped as f64))])));
    let mut instant = base("i");
    instant.push(("s", Json::str("t")));
    instant.push(("args", Json::obj(vec![("dropped", Json::num(dropped as f64))])));
    vec![Json::obj(counter), Json::obj(instant)]
}

/// One JSON object per line per event, timestamps in seconds.
pub fn events_jsonl_string(sink: &TraceSink) -> String {
    events_jsonl_string_from(&sink.snapshot_events(), sink.dropped())
}

/// JSONL over an explicit event slice (record order); see
/// [`events_jsonl_string`].
pub fn events_jsonl_string_from(events: &[TraceEvent], dropped: u64) -> String {
    let mut out = String::new();
    for e in events {
        let mut fields = vec![
            ("ts_s", Json::num(e.ts_s)),
            ("ph", Json::str(e.ph.code())),
            ("pid", Json::num(e.pid)),
            ("tid", Json::num(e.tid)),
            ("name", Json::str(e.name)),
        ];
        if e.dur_s != 0.0 {
            fields.push(("dur_s", Json::num(e.dur_s)));
        }
        if e.id != 0 {
            fields.push(("id", Json::num(e.id as f64)));
        }
        if !e.args.is_empty() {
            fields.push(("args", args_json(&e.args)));
        }
        out.push_str(&Json::obj(fields).to_string());
        out.push('\n');
    }
    if dropped > 0 {
        let line = Json::obj(vec![
            ("name", Json::str("trace.dropped")),
            ("value", Json::num(dropped as f64)),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

/// Registry samples (then histogram summaries) as JSONL.
pub fn metrics_jsonl_string(reg: &Registry) -> String {
    let mut out = String::new();
    for s in &reg.samples {
        let line = Json::obj(vec![
            ("t_s", Json::num(s.t_s)),
            ("name", Json::Str(s.name.clone())),
            ("value", Json::num(s.value)),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    for (name, h) in reg.hists() {
        // full log2 bucket occupancy (not just the summary), so TBT
        // distributions survive into offline analysis: [lo, count]
        // pairs where lo is the bucket's lower bound in value units
        let buckets: Vec<Json> = h
            .nonzero_buckets()
            .map(|(lo, count)| Json::Arr(vec![Json::num(lo), Json::num(count as f64)]))
            .collect();
        let line = Json::obj(vec![
            ("hist", Json::Str(name.to_string())),
            ("n", Json::num(h.n as f64)),
            ("mean", h.mean().map(Json::num).unwrap_or(Json::Null)),
            ("p50", h.quantile(0.5).map(Json::num).unwrap_or(Json::Null)),
            ("p95", h.quantile(0.95).map(Json::num).unwrap_or(Json::Null)),
            ("max", if h.n == 0 { Json::Null } else { Json::num(h.max) }),
            ("buckets", Json::Arr(buckets)),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

/// Write the Chrome trace JSON for `sink` to `path`.
pub fn write_chrome_trace(path: &Path, sink: &TraceSink) -> Result<()> {
    std::fs::write(path, chrome_trace_string(sink))?;
    Ok(())
}

/// Write the registry's sample series as JSONL to `path`.
pub fn write_metrics_jsonl(path: &Path, reg: &Registry) -> Result<()> {
    std::fs::write(path, metrics_jsonl_string(reg))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceSink;

    #[test]
    fn chrome_trace_is_valid_json_with_metadata() {
        let mut s = TraceSink::virtual_time(64);
        s.set_now(0.5);
        s.begin(2, 3, "request", 9);
        s.set_now(1.0);
        s.end(2, 3, "request", 9);
        s.instant(1, 0, "enqueue", 9, vec![("cost", 4.0)]);
        let text = chrome_trace_string(&s);
        let doc = Json::parse(&text).expect("valid JSON");
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata names for pid 2 + 2 for pid 1 + 3 events
        assert_eq!(evs.len(), 7);
        let metas = evs
            .iter()
            .filter(|e| matches!(e.opt("ph"), Some(Json::Str(p)) if p == "M"))
            .count();
        assert_eq!(metas, 4);
        // µs scaling: the begin event lands at ts = 500000
        assert!(text.contains("\"ts\":500000"), "got: {text}");
    }

    #[test]
    fn jsonl_lines_each_parse() {
        let mut s = TraceSink::virtual_time(8);
        s.instant(0, 0, "place", 3, vec![("replica", 1.0)]);
        s.counter(1, 0, "queue", 5.0);
        let text = events_jsonl_string(&s);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            Json::parse(l).expect("line parses");
        }
    }

    #[test]
    fn metrics_jsonl_covers_samples_and_hists() {
        let mut r = Registry::new(0.0);
        r.gauge_set("cloud.queue_depth.0", 2.0);
        r.snapshot(1.0);
        r.hist_record("ttft_s", 0.25);
        let text = metrics_jsonl_string(&r);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("cloud.queue_depth.0"));
        assert!(lines[1].contains("\"hist\":\"ttft_s\""));
        for l in lines {
            Json::parse(l).expect("line parses");
        }
        // bucket occupancy survives into the export: one [lo, count]
        // pair for the single recorded value
        let hist = Json::parse(lines[1]).unwrap();
        let buckets = hist.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), 1);
        let pair = buckets[0].as_arr().unwrap();
        let lo = pair[0].as_f64().unwrap();
        assert!(lo <= 0.25 && 0.25 < 2.0 * lo, "0.25 in bucket [{lo}, {})", 2.0 * lo);
        assert_eq!(pair[1].as_f64().unwrap(), 1.0);
    }

    #[test]
    fn ring_drops_surface_in_exports() {
        let mut s = TraceSink::virtual_time(2);
        s.set_now(1.0);
        for i in 1..=5u64 {
            s.instant(0, 0, "e", i, vec![]);
        }
        assert_eq!(s.dropped(), 3);
        let doc = Json::parse(&chrome_trace_string(&s)).unwrap();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let dropped: Vec<&Json> = evs
            .iter()
            .filter(|e| matches!(e.opt("name"), Some(Json::Str(n)) if n == "trace.dropped"))
            .collect();
        assert_eq!(dropped.len(), 2, "counter + final instant");
        let counter = dropped
            .iter()
            .find(|e| matches!(e.opt("ph"), Some(Json::Str(p)) if p == "C"))
            .expect("counter present");
        let v = counter.get("args").unwrap().get("value").unwrap().as_f64().unwrap();
        assert_eq!(v, 3.0, "counter pins the drop count");
        let jsonl = events_jsonl_string(&s);
        let last = jsonl.lines().last().unwrap();
        assert!(last.contains("trace.dropped") && last.contains("3"), "got: {last}");
        // an intact sink stays marker-free (schema unchanged)
        let mut ok = TraceSink::virtual_time(16);
        ok.instant(0, 0, "e", 1, vec![]);
        assert!(!chrome_trace_string(&ok).contains("trace.dropped"));
        assert!(!events_jsonl_string(&ok).contains("trace.dropped"));
    }

    #[test]
    fn flow_end_binds_to_enclosing_slice() {
        let mut s = TraceSink::virtual_time(8);
        s.set_now(0.25);
        s.flow(2, 0, "offload", Ph::FlowStart, 0xAB);
        s.flow(2, 0, "offload", Ph::FlowEnd, 0xAB);
        let text = chrome_trace_string(&s);
        let doc = Json::parse(&text).unwrap();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let f = evs
            .iter()
            .find(|e| matches!(e.opt("ph"), Some(Json::Str(p)) if p == "f"))
            .expect("flow end exported");
        assert!(matches!(f.opt("bp"), Some(Json::Str(b)) if b == "e"));
        let s_ev = evs
            .iter()
            .find(|e| matches!(e.opt("ph"), Some(Json::Str(p)) if p == "s"))
            .expect("flow start exported");
        assert!(s_ev.opt("bp").is_none(), "bp only on the arrow head");
        assert_eq!(s_ev.get("id").unwrap().as_f64().unwrap(), 0xAB as f64);
    }
}
