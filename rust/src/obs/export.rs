//! Serialize traces and metrics: Chrome trace-event JSON and JSONL.
//!
//! Everything routes through [`crate::util::json::Json`] (object keys
//! in `BTreeMap` order, integer-exact number formatting), so a
//! deterministic event stream serializes to deterministic *bytes* —
//! the byte-identity gate in `tests/obs_trace.rs` compares these
//! strings directly.
//!
//! ## Chrome trace-event schema
//!
//! [`chrome_trace_string`] emits `{"traceEvents": [...]}` in the
//! [Trace Event Format]: one object per event with `ph` (`B`/`E`/`i`/
//! `X`/`C`), `ts`/`dur` in **microseconds**, `pid`/`tid` track ids,
//! `name`, `cat`, optional `id` and numeric `args` — plus `M`
//! (metadata) events naming every process and thread seen, so the
//! file opens in Perfetto (<https://ui.perfetto.dev>) with readable
//! tracks: `router`, `cloud` (one thread per replica), and one
//! process per device tenant (one thread per device).
//!
//! [Trace Event Format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
//!
//! ## JSONL schemas
//!
//! * [`events_jsonl_string`]: one event object per line, seconds (not
//!   µs), same field names as the in-memory [`TraceEvent`].
//! * [`metrics_jsonl_string`]: one `{"t_s", "name", "value"}` line per
//!   registry sample, then one `{"hist", "n", "mean", "p50", "p95",
//!   "max"}` line per histogram.

use std::collections::BTreeSet;
use std::path::Path;

use crate::obs::registry::Registry;
use crate::obs::trace::{Ph, TraceEvent, TraceSink, PID_CLOUD, PID_ROUTER};
use crate::util::json::Json;
use crate::Result;

fn process_name(pid: u32) -> String {
    match pid {
        PID_ROUTER => "router".to_string(),
        PID_CLOUD => "cloud".to_string(),
        p => format!("tenant {}", p - 2),
    }
}

fn thread_name(pid: u32, tid: u32) -> String {
    match pid {
        PID_ROUTER => "router".to_string(),
        PID_CLOUD => format!("replica {tid}"),
        _ => format!("dev {tid}"),
    }
}

fn metadata_event(name: &'static str, pid: u32, tid: u32, label: String) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("pid", Json::num(pid)),
        ("tid", Json::num(tid)),
        ("name", Json::str(name)),
        ("args", Json::obj(vec![("name", Json::Str(label))])),
    ])
}

fn args_json(args: &[(&'static str, f64)]) -> Json {
    Json::obj(args.iter().map(|&(k, v)| (k, Json::num(v))).collect())
}

fn event_json(e: &TraceEvent) -> Json {
    let mut fields = vec![
        ("ph", Json::str(e.ph.code())),
        ("ts", Json::num(e.ts_s * 1e6)),
        ("pid", Json::num(e.pid)),
        ("tid", Json::num(e.tid)),
        ("name", Json::str(e.name)),
        ("cat", Json::str(e.cat)),
    ];
    if e.ph == Ph::Complete {
        fields.push(("dur", Json::num(e.dur_s * 1e6)));
    }
    if e.ph == Ph::Instant {
        // process-scoped instants render as full-height markers
        fields.push(("s", Json::str("t")));
    }
    if e.id != 0 {
        fields.push(("id", Json::num(e.id as f64)));
    }
    if !e.args.is_empty() {
        fields.push(("args", args_json(&e.args)));
    }
    Json::obj(fields)
}

/// The whole sink as one Chrome trace-event JSON document (see the
/// module docs for the schema).
pub fn chrome_trace_string(sink: &TraceSink) -> String {
    let mut tracks: BTreeSet<(u32, u32)> = BTreeSet::new();
    for e in sink.events() {
        tracks.insert((e.pid, e.tid));
    }
    let mut events: Vec<Json> = Vec::with_capacity(sink.len() + 2 * tracks.len());
    let mut pids: BTreeSet<u32> = BTreeSet::new();
    for &(pid, tid) in &tracks {
        if pids.insert(pid) {
            events.push(metadata_event("process_name", pid, 0, process_name(pid)));
        }
        events.push(metadata_event("thread_name", pid, tid, thread_name(pid, tid)));
    }
    for e in sink.events() {
        events.push(event_json(e));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
    .to_string()
}

/// One JSON object per line per event, timestamps in seconds.
pub fn events_jsonl_string(sink: &TraceSink) -> String {
    let mut out = String::new();
    for e in sink.events() {
        let mut fields = vec![
            ("ts_s", Json::num(e.ts_s)),
            ("ph", Json::str(e.ph.code())),
            ("pid", Json::num(e.pid)),
            ("tid", Json::num(e.tid)),
            ("name", Json::str(e.name)),
        ];
        if e.dur_s != 0.0 {
            fields.push(("dur_s", Json::num(e.dur_s)));
        }
        if e.id != 0 {
            fields.push(("id", Json::num(e.id as f64)));
        }
        if !e.args.is_empty() {
            fields.push(("args", args_json(&e.args)));
        }
        out.push_str(&Json::obj(fields).to_string());
        out.push('\n');
    }
    out
}

/// Registry samples (then histogram summaries) as JSONL.
pub fn metrics_jsonl_string(reg: &Registry) -> String {
    let mut out = String::new();
    for s in &reg.samples {
        let line = Json::obj(vec![
            ("t_s", Json::num(s.t_s)),
            ("name", Json::Str(s.name.clone())),
            ("value", Json::num(s.value)),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    for (name, h) in reg.hists() {
        let line = Json::obj(vec![
            ("hist", Json::Str(name.to_string())),
            ("n", Json::num(h.n as f64)),
            ("mean", h.mean().map(Json::num).unwrap_or(Json::Null)),
            ("p50", h.quantile(0.5).map(Json::num).unwrap_or(Json::Null)),
            ("p95", h.quantile(0.95).map(Json::num).unwrap_or(Json::Null)),
            ("max", if h.n == 0 { Json::Null } else { Json::num(h.max) }),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

/// Write the Chrome trace JSON for `sink` to `path`.
pub fn write_chrome_trace(path: &Path, sink: &TraceSink) -> Result<()> {
    std::fs::write(path, chrome_trace_string(sink))?;
    Ok(())
}

/// Write the registry's sample series as JSONL to `path`.
pub fn write_metrics_jsonl(path: &Path, reg: &Registry) -> Result<()> {
    std::fs::write(path, metrics_jsonl_string(reg))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceSink;

    #[test]
    fn chrome_trace_is_valid_json_with_metadata() {
        let mut s = TraceSink::virtual_time(64);
        s.set_now(0.5);
        s.begin(2, 3, "request", 9);
        s.set_now(1.0);
        s.end(2, 3, "request", 9);
        s.instant(1, 0, "enqueue", 9, vec![("cost", 4.0)]);
        let text = chrome_trace_string(&s);
        let doc = Json::parse(&text).expect("valid JSON");
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata names for pid 2 + 2 for pid 1 + 3 events
        assert_eq!(evs.len(), 7);
        let metas = evs
            .iter()
            .filter(|e| matches!(e.opt("ph"), Some(Json::Str(p)) if p == "M"))
            .count();
        assert_eq!(metas, 4);
        // µs scaling: the begin event lands at ts = 500000
        assert!(text.contains("\"ts\":500000"), "got: {text}");
    }

    #[test]
    fn jsonl_lines_each_parse() {
        let mut s = TraceSink::virtual_time(8);
        s.instant(0, 0, "place", 3, vec![("replica", 1.0)]);
        s.counter(1, 0, "queue", 5.0);
        let text = events_jsonl_string(&s);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            Json::parse(l).expect("line parses");
        }
    }

    #[test]
    fn metrics_jsonl_covers_samples_and_hists() {
        let mut r = Registry::new(0.0);
        r.gauge_set("cloud.queue_depth.0", 2.0);
        r.snapshot(1.0);
        r.hist_record("ttft_s", 0.25);
        let text = metrics_jsonl_string(&r);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("cloud.queue_depth.0"));
        assert!(lines[1].contains("\"hist\":\"ttft_s\""));
        for l in lines {
            Json::parse(l).expect("line parses");
        }
    }
}
