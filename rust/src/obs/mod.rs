//! Zero-dependency observability: request tracing, a metrics
//! registry, leveled logging, and deterministic exporters.
//!
//! The layer has three parts plus the [`log!`](crate::log) macro:
//!
//! * [`trace`] — spans/events in a [`trace::TraceSink`] ring buffer,
//!   stamped by a [`trace::Clock`]. **Clock contract:** the fleet
//!   simulator attaches a [`trace::VirtualClock`] and advances it to
//!   each discrete-event firing time, so same-seed runs produce
//!   byte-identical traces and measured wall durations are forced to
//!   zero; the threaded server attaches a [`trace::WallClock`]
//!   (seconds since run start). Instrumented structs hold an
//!   `Option<`[`trace::TraceShared`]`>` — disabled tracing is one
//!   branch per site.
//! * [`sampler`] — tail-based trace retention for fleet scale: each
//!   request's events stage in a per-request buffer; at completion the
//!   full set is retained only for a deterministic seeded 1-in-N head
//!   sample, every tail-interesting request (SLO miss, partial/error
//!   outcome, forced mark), or the top-k slowest; the boring bulk is
//!   discarded so memory is O(retained + in-flight), not O(total).
//! * [`registry`] — named counters/gauges/histograms sampled on a
//!   caller-driven cadence ([`registry::Registry::due`] /
//!   [`registry::Registry::snapshot`]); the standard cloud gauges are
//!   captured by [`registry::sample_router`].
//! * [`export`] — Chrome trace-event JSON and JSONL serializers over
//!   [`crate::util::json::Json`] (deterministic bytes).
//! * [`analyze`] — offline critical-path analysis of an exported
//!   trace (`synera inspect`): per-request latency attributed to
//!   device / queue / paging / engine / network / stall.
//!
//! ## Event schema
//!
//! Request lifecycle (ids are request ids; device tracks live in
//! process [`trace::tenant_pid`]`(t)`, thread = device):
//!
//! | name | kind | track | meaning |
//! |---|---|---|---|
//! | `arrive` | instant | device | request entered the device queue |
//! | `request` | span | device | request start → final token |
//! | `draft` / `local` / `offload` | instant | device | SLM chunk drafted; offload decision with confidence/importance scores |
//! | `round` | span | device | one offload round (send → verdict applied) |
//! | `uplink` | span | device | draft chunk on the wire |
//! | `place` / `migrate` | instant | router | replica placement; parked-KV migration (with bytes) |
//! | `enqueue` / `admit` | instant | cloud replica | WFQ arrival; session admission (queue wait = gap) |
//! | `swap_in` / `swap_out` | instant | cloud replica | paged-KV slot traffic |
//! | `wfq-drain`, `paging`, `pack`, `engine`, `commit` | complete | cloud replica | per-tick scheduler phases |
//! | `verify_commit` / `generated` | instant | cloud replica | verdict committed; generate finished |
//! | `reply` | instant | cloud replica | verdict reply dispatched (args: `round`, `service`, `dl` seconds) |
//! | `device_commit` | instant | device | verdict applied on-device (downlink end) |
//! | `offload` | flow `s`/`f` | device | causal arrow: draft left the device / verdict landed |
//! | `offload` | flow `t` | cloud replica | causal arrow step at `verify_commit` |
//! | `trace.dropped` | instant + counter | router | ring-buffer overflow marker (drop count in args) |
//!
//! Verify-path cloud instants carry a `round` arg from the wire-level
//! [`crate::net::wire::TraceContext`], joining them to the k-th
//! `round` span of the originating request; `swap_in`/`swap_out`
//! carry their wall seconds in an `s` arg (zero under a virtual
//! clock). The SLO monitor ([`registry::SloMonitor`]) publishes
//! `slo.ttft_attainment.<tenant>` / `slo.tbt_attainment.<tenant>` and
//! the matching `slo.*_burn.<tenant>` burn-rate gauges each cadence.
//!
//! ## Perfetto how-to
//!
//! ```text
//! synera fleet --devices 4096 --replicas 4 --trace fleet.trace.json
//! ```
//!
//! then open <https://ui.perfetto.dev> → *Open trace file* →
//! `fleet.trace.json`. Tracks appear as one `cloud` process with a
//! thread per replica, a `router` process, and one process per device
//! tenant with a thread per device. See `docs/observability.md`.
//!
//! ## Logging
//!
//! [`log!`](crate::log) writes leveled lines to **stderr** (stdout
//! stays clean for machine-readable output). Default level is
//! [`Level::Info`]; `--verbose` on the CLI raises it to
//! [`Level::Debug`].

pub mod analyze;
pub mod export;
pub mod registry;
pub mod sampler;
pub mod trace;

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LOG_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log threshold (messages above it are suppressed).
pub fn set_level(level: Level) {
    LOG_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// `--verbose` toggle: Debug on, Info off.
pub fn set_verbose(verbose: bool) {
    set_level(if verbose { Level::Debug } else { Level::Info });
}

/// Current global threshold as its `u8` rank.
pub fn level() -> u8 {
    LOG_LEVEL.load(Ordering::Relaxed)
}

/// Would a message at `level` currently print?
pub fn enabled(level: Level) -> bool {
    level as u8 <= LOG_LEVEL.load(Ordering::Relaxed)
}

/// Leveled logging to stderr: `log!(Info, "packed {} rows", n)`.
///
/// Levels are the [`Level`](crate::obs::Level) variants. Messages at
/// or above the global threshold ([`crate::obs::set_level`]) print to
/// stderr; everything else is one atomic load. Library code must use
/// this instead of `println!`/`eprintln!` so stdout stays parseable.
#[macro_export]
macro_rules! log {
    ($lvl:ident, $($arg:tt)*) => {
        if $crate::obs::enabled($crate::obs::Level::$lvl) {
            eprintln!($($arg)*);
        }
    };
}

pub use crate::log;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        let prev = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_verbose(true);
        assert!(enabled(Level::Debug));
        set_verbose(false);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        // restore whatever the harness had (tests share the global)
        LOG_LEVEL.store(prev, Ordering::Relaxed);
    }

    #[test]
    fn log_macro_compiles_at_every_level() {
        log!(Error, "e {}", 1);
        log!(Warn, "w");
        log!(Info, "i");
        log!(Debug, "d");
    }
}
