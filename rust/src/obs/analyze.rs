//! Offline critical-path analysis of Chrome trace exports — the engine
//! behind `synera inspect <trace.json>`.
//!
//! The analyzer reconstructs one timeline per request from the causal
//! event stream ([`crate::obs::trace`], exported by
//! [`crate::obs::export::chrome_trace_string`]) and attributes every
//! second of request latency to exactly one of six components:
//!
//! * **device** — on-device drafting/prefill (time outside any offload
//!   round),
//! * **queue** — WFQ/admission wait on the cloud (`enqueue` → `admit`),
//! * **paging** — KV swap work inside the round's cloud window
//!   (`swap_in`/`swap_out` instants carry their wall seconds in an
//!   `s` arg; a virtual-clock sim zeroes them like every other wall
//!   duration),
//! * **engine** — the remaining cloud window (`admit` →
//!   `verify_commit` plus the modelled/measured service interval),
//! * **network** — uplink span plus the reply's downlink seconds,
//! * **stall** — the residual: device idle awaiting the verify while
//!   no cloud phase ran for it (pipeline bubble).
//!
//! The decomposition is exact by construction: per round,
//! `stall = rtt − uplink − queue − cloud_window − downlink`, and a
//! negative residual (overlapped phases) is absorbed into `engine`, so
//! `device + queue + paging + engine + network + stall` always equals
//! the measured request-span latency to float rounding. In the
//! perfect-pipeline fleet simulator the stall component is ~0 *by
//! construction* — every cloud wait is accounted as queue/engine — so
//! a nonzero stall in a wall-clock trace is a genuine scheduling
//! bubble, not model noise.
//!
//! Requests whose events are incomplete (ring-buffer drops, a
//! windowed `stop_s` run cutting replies off) are counted in
//! [`InspectReport::partial`] and excluded from the breakdowns rather
//! than silently mis-attributed.
//!
//! Everything is deterministic: events are keyed and sorted by
//! `(start, request_id)`, output goes through
//! [`crate::util::json::Json`], and same-seed sim traces produce
//! byte-identical tables and JSONL.

use std::collections::BTreeMap;

use anyhow::{bail, Context};

use crate::obs::trace::PID_CLOUD;
use crate::util::json::Json;
use crate::Result;

/// Per-request latency attribution (all fields in seconds).
#[derive(Debug, Clone)]
pub struct RequestBreakdown {
    pub request_id: u64,
    pub tenant: usize,
    pub device: u32,
    /// Request-span start (trace clock).
    pub t_start_s: f64,
    /// Request-span duration; equals the component sum to rounding.
    pub latency_s: f64,
    /// Offload rounds the request performed (0 = fully local).
    pub rounds: usize,
    pub device_s: f64,
    pub queue_s: f64,
    pub paging_s: f64,
    pub engine_s: f64,
    pub network_s: f64,
    pub stall_s: f64,
    /// Trace-derived time to first committed token (first `local` /
    /// `device_commit` instant minus request start); `None` when the
    /// request never committed a token. Commit instants mark chunk
    /// *ends*, so this upper-bounds the driver's own TTFT by at most
    /// one chunk — good enough for SLO-miss filtering.
    pub ttft_s: Option<f64>,
    /// Trace-derived mean time between tokens over commit instants;
    /// `None` for requests with fewer than two committed tokens.
    pub tbt_s: Option<f64>,
}

impl RequestBreakdown {
    /// Does this request miss `policy` on trace-derived TTFT/TBT? A
    /// request that never committed a token counts as a miss.
    pub fn slo_miss(&self, policy: &crate::config::SloPolicy) -> bool {
        match self.ttft_s {
            None => true,
            Some(ttft) => {
                ttft > policy.ttft_s || self.tbt_s.is_some_and(|tbt| tbt > policy.tbt_s)
            }
        }
    }
    /// Sum of the six attribution components.
    pub fn component_sum_s(&self) -> f64 {
        let parts = [
            self.device_s,
            self.queue_s,
            self.paging_s,
            self.engine_s,
            self.network_s,
            self.stall_s,
        ];
        parts.iter().sum()
    }
}

/// Per-tenant totals over complete requests.
#[derive(Debug, Clone, Default)]
pub struct TenantBreakdown {
    pub tenant: usize,
    pub requests: usize,
    pub latency_s: f64,
    pub device_s: f64,
    pub queue_s: f64,
    pub paging_s: f64,
    pub engine_s: f64,
    pub network_s: f64,
    pub stall_s: f64,
}

/// The full analysis of one trace file.
#[derive(Debug, Clone, Default)]
pub struct InspectReport {
    /// Complete requests, sorted by `(t_start_s, request_id)`.
    pub requests: Vec<RequestBreakdown>,
    /// Per-tenant totals, sorted by tenant id.
    pub tenants: Vec<TenantBreakdown>,
    /// Requests with missing spans/instants (dropped events or a
    /// windowed run): counted, never silently folded in.
    pub partial: usize,
}

/// Device-track state gathered for one request id.
#[derive(Default)]
struct ReqState {
    tenant: usize,
    device: u32,
    tb: Option<f64>,
    te: Option<f64>,
    round_b: Vec<f64>,
    round_e: Vec<f64>,
    up_b: Vec<f64>,
    up_e: Vec<f64>,
}

/// Cloud-track instants for one `(request_id, round)`.
#[derive(Default)]
struct CloudRound {
    replica: Option<u32>,
    enqueue: Option<f64>,
    admit: Option<f64>,
    commit: Option<f64>,
    service: Option<f64>,
    dl: Option<f64>,
}

fn f(e: &Json, key: &str) -> Option<f64> {
    e.opt(key).and_then(|v| v.as_f64().ok())
}

fn arg(e: &Json, key: &str) -> Option<f64> {
    e.opt("args").and_then(|a| a.opt(key)).and_then(|v| v.as_f64().ok())
}

/// Analyze a Chrome trace-event JSON document (the string form of
/// [`crate::obs::export::chrome_trace_string`]).
pub fn analyze_chrome_trace(text: &str) -> Result<InspectReport> {
    let doc = Json::parse(text).context("trace file is not valid JSON")?;
    let events = doc
        .get("traceEvents")
        .context("not a Chrome trace: missing traceEvents")?
        .as_arr()?;

    let mut reqs: BTreeMap<u64, ReqState> = BTreeMap::new();
    let mut cloud: BTreeMap<(u64, u32), CloudRound> = BTreeMap::new();
    // per-replica swap instants: (ts_s, seconds of swap work)
    let mut swaps: BTreeMap<u32, Vec<(f64, f64)>> = BTreeMap::new();
    // token-commit instants per request: (ts_s, tokens committed) —
    // kept apart from `reqs` so a stray instant cannot conjure a
    // request entry that would then be miscounted as partial
    let mut commits: BTreeMap<u64, Vec<(f64, f64)>> = BTreeMap::new();

    for e in events {
        let Some(ph) = e.opt("ph").and_then(|p| p.as_str().ok()) else { continue };
        let Some(name) = e.opt("name").and_then(|n| n.as_str().ok()) else { continue };
        let (Some(pid), Some(tid)) = (f(e, "pid"), f(e, "tid")) else { continue };
        let (pid, tid) = (pid as u32, tid as u32);
        let ts = f(e, "ts").unwrap_or(0.0) / 1e6; // µs → s
        let id = f(e, "id").unwrap_or(0.0) as u64;

        if pid >= 2 {
            // device tracks (one process per tenant, one thread per
            // device). Only span B/E events key a request: instants,
            // metadata, and flow arrows (whose ids are synthetic flow
            // ids, not request ids) must not create entries.
            if ph == "i" && (name == "local" || name == "device_commit") {
                // token-commit instants feed the TTFT/TBT derivation;
                // `local` commits `gamma` tokens, `device_commit` the
                // round's `committed` (sim) or `accepted` (serve) count
                let tokens = if name == "local" {
                    arg(e, "gamma").unwrap_or(0.0)
                } else {
                    arg(e, "committed").or_else(|| arg(e, "accepted")).unwrap_or(0.0)
                };
                if tokens > 0.0 {
                    commits.entry(id).or_default().push((ts, tokens));
                }
                continue;
            }
            if ph != "B" && ph != "E" {
                continue;
            }
            let slot = match name {
                "request" | "round" | "uplink" => name,
                _ => continue,
            };
            let r = reqs.entry(id).or_default();
            r.tenant = (pid - 2) as usize;
            r.device = tid;
            match (slot, ph) {
                ("request", "B") => r.tb = Some(ts),
                ("request", "E") => r.te = Some(ts),
                ("round", "B") => r.round_b.push(ts),
                ("round", "E") => r.round_e.push(ts),
                ("uplink", "B") => r.up_b.push(ts),
                ("uplink", "E") => r.up_e.push(ts),
                _ => {}
            }
            continue;
        }
        if pid == PID_CLOUD && ph == "i" {
            match name {
                "swap_in" | "swap_out" => {
                    if let Some(s) = arg(e, "s") {
                        swaps.entry(tid).or_default().push((ts, s));
                    }
                }
                "enqueue" | "admit" | "verify_commit" | "reply" => {
                    // only instants stamped with a causal round join a
                    // request timeline (Release traffic has none)
                    let Some(round) = arg(e, "round") else { continue };
                    if round < 0.0 {
                        continue;
                    }
                    let c = cloud.entry((id, round as u32)).or_default();
                    c.replica = Some(tid);
                    match name {
                        "enqueue" => c.enqueue = Some(ts),
                        "admit" => c.admit = Some(ts),
                        "verify_commit" => c.commit = Some(ts),
                        "reply" => {
                            c.service = arg(e, "service");
                            c.dl = arg(e, "dl");
                        }
                        _ => unreachable!(),
                    }
                }
                _ => {}
            }
        }
        // router placement instants (pid 0) carry no latency: skipped
    }

    let mut out = InspectReport::default();
    for (&id, r) in &reqs {
        match breakdown_for(id, r, &cloud, &swaps, commits.get(&id).map(Vec::as_slice)) {
            Some(b) => out.requests.push(b),
            None => out.partial += 1,
        }
    }
    // deterministic report order: by request start, then id
    out.requests.sort_by(|a, b| {
        a.t_start_s
            .partial_cmp(&b.t_start_s)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.request_id.cmp(&b.request_id))
    });
    out.tenants = tenant_totals(&out.requests);
    Ok(out)
}

/// Per-tenant totals over a request set, sorted by tenant id.
fn tenant_totals(requests: &[RequestBreakdown]) -> Vec<TenantBreakdown> {
    let mut tenants: BTreeMap<usize, TenantBreakdown> = BTreeMap::new();
    for b in requests {
        let t = tenants.entry(b.tenant).or_insert_with(|| TenantBreakdown {
            tenant: b.tenant,
            ..TenantBreakdown::default()
        });
        t.requests += 1;
        t.latency_s += b.latency_s;
        t.device_s += b.device_s;
        t.queue_s += b.queue_s;
        t.paging_s += b.paging_s;
        t.engine_s += b.engine_s;
        t.network_s += b.network_s;
        t.stall_s += b.stall_s;
    }
    tenants.into_values().collect()
}

/// Restrict a report to requests missing `policy` on trace-derived
/// TTFT/TBT (the `--slo-miss-only` inspect filter); per-tenant totals
/// are recomputed over the surviving set, `partial` is carried over.
pub fn slo_miss_only(rep: &InspectReport, policy: &crate::config::SloPolicy) -> InspectReport {
    let requests: Vec<RequestBreakdown> =
        rep.requests.iter().filter(|b| b.slo_miss(policy)).cloned().collect();
    let tenants = tenant_totals(&requests);
    InspectReport { requests, tenants, partial: rep.partial }
}

/// Attribute one request, or `None` if its event set is incomplete.
fn breakdown_for(
    id: u64,
    r: &ReqState,
    cloud: &BTreeMap<(u64, u32), CloudRound>,
    swaps: &BTreeMap<u32, Vec<(f64, f64)>>,
    commits: Option<&[(f64, f64)]>,
) -> Option<RequestBreakdown> {
    let (tb, te) = (r.tb?, r.te?);
    let n_rounds = r.round_b.len();
    if r.round_e.len() != n_rounds || r.up_b.len() != n_rounds || r.up_e.len() != n_rounds {
        return None; // a round or uplink span never closed
    }
    let latency = te - tb;
    let mut b = RequestBreakdown {
        request_id: id,
        tenant: r.tenant,
        device: r.device,
        t_start_s: tb,
        latency_s: latency,
        rounds: n_rounds,
        device_s: 0.0,
        queue_s: 0.0,
        paging_s: 0.0,
        engine_s: 0.0,
        network_s: 0.0,
        stall_s: 0.0,
        ttft_s: None,
        tbt_s: None,
    };
    if let Some(cs) = commits {
        // commit instants are scanned in export order ⇒ ascending ts
        let (t_first, _) = cs[0];
        let (t_last, _) = cs[cs.len() - 1];
        let tokens: f64 = cs.iter().map(|&(_, n)| n).sum();
        b.ttft_s = Some(t_first - tb);
        if tokens >= 2.0 {
            b.tbt_s = Some((t_last - t_first) / (tokens - 1.0));
        }
    }
    let mut rtt_total = 0.0;
    for k in 0..n_rounds {
        let (rb, re) = (r.round_b[k], r.round_e[k]);
        let rtt = re - rb;
        rtt_total += rtt;
        let up = r.up_e[k] - r.up_b[k];
        let c = cloud.get(&(id, k as u32))?;
        let (eq, ta, tv) = (c.enqueue?, c.admit?, c.commit?);
        let (service, dl) = (c.service?, c.dl?);
        let queue = (ta - eq).max(0.0);
        let cloud_w = (tv - ta).max(0.0) + service;
        // swap work inside this round's cloud window, on its replica
        let mut paging = 0.0;
        if let Some(sw) = c.replica.and_then(|rep| swaps.get(&rep)) {
            let hi = tv + service;
            for &(ts, s) in sw {
                if ts >= ta && ts <= hi {
                    paging += s;
                }
            }
        }
        let mut engine = cloud_w - paging;
        if engine < 0.0 {
            // wall swap seconds can exceed the bracketing instants;
            // paging then owns the whole window
            paging = cloud_w;
            engine = 0.0;
        }
        let mut stall = rtt - up - queue - cloud_w - dl;
        if stall < 0.0 {
            // overlapped phases (e.g. PI hiding part of the window):
            // absorb into engine so the component sum stays exact
            engine += stall;
            stall = 0.0;
            if engine < 0.0 {
                b.queue_s += engine;
                engine = 0.0;
            }
        }
        b.queue_s += queue;
        b.paging_s += paging;
        b.engine_s += engine;
        b.network_s += up + dl;
        b.stall_s += stall;
    }
    b.device_s = latency - rtt_total;
    Some(b)
}

/// The per-tenant critical-path table as deterministic text.
pub fn table_string(rep: &InspectReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<7} {:>6} {:>11} | {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}\n",
        "tenant", "reqs", "latency", "device", "queue", "paging", "engine", "network", "stall",
    ));
    let pct = |part: f64, whole: f64| if whole > 0.0 { 100.0 * part / whole } else { 0.0 };
    for t in &rep.tenants {
        out.push_str(&format!(
            "{:<7} {:>6} {:>10.3}s | {:>8.1}% {:>8.1}% {:>8.1}% {:>8.1}% {:>8.1}% {:>8.1}%\n",
            t.tenant,
            t.requests,
            t.latency_s,
            pct(t.device_s, t.latency_s),
            pct(t.queue_s, t.latency_s),
            pct(t.paging_s, t.latency_s),
            pct(t.engine_s, t.latency_s),
            pct(t.network_s, t.latency_s),
            pct(t.stall_s, t.latency_s),
        ));
    }
    if rep.partial > 0 {
        out.push_str(&format!("({} partial requests excluded)\n", rep.partial));
    }
    out
}

/// Aggregate per-component attribution across all reconstructed
/// requests (the `--summary` inspect view): p50/p95/p99 of each
/// component's per-request seconds, plus its share of total latency.
/// Deterministic for same-seed traces like every other export.
pub fn summary_table_string(rep: &InspectReport) -> String {
    use crate::metrics::stats::Summary;
    let mut out = String::new();
    out.push_str(&format!(
        "{:<9} {:>10} {:>10} {:>10} {:>10} {:>8}\n",
        "component", "p50", "p95", "p99", "mean", "share",
    ));
    let total_latency: f64 = rep.requests.iter().map(|b| b.latency_s).sum();
    let rows: [(&str, fn(&RequestBreakdown) -> f64); 7] = [
        ("latency", |b| b.latency_s),
        ("device", |b| b.device_s),
        ("queue", |b| b.queue_s),
        ("paging", |b| b.paging_s),
        ("engine", |b| b.engine_s),
        ("network", |b| b.network_s),
        ("stall", |b| b.stall_s),
    ];
    for (name, get) in rows {
        let vals: Vec<f64> = rep.requests.iter().map(get).collect();
        let s = Summary::of(&vals);
        let share =
            if total_latency > 0.0 { 100.0 * vals.iter().sum::<f64>() / total_latency } else { 0.0 };
        out.push_str(&format!(
            "{:<9} {:>9.4}s {:>9.4}s {:>9.4}s {:>9.4}s {:>7.1}%\n",
            name, s.p50, s.p95, s.p99, s.mean, share,
        ));
    }
    out.push_str(&format!("({} requests", rep.requests.len()));
    if rep.partial > 0 {
        out.push_str(&format!(", {} partial excluded", rep.partial));
    }
    out.push_str(")\n");
    out
}

/// One JSON object per complete request (keys in lexicographic order,
/// so same-seed traces inspect to byte-identical JSONL).
pub fn requests_jsonl_string(rep: &InspectReport) -> String {
    let mut out = String::new();
    for b in &rep.requests {
        let mut line = vec![
            ("request_id", Json::num(b.request_id as f64)),
            ("tenant", Json::num(b.tenant as f64)),
            ("device", Json::num(b.device)),
            ("t_start_s", Json::num(b.t_start_s)),
            ("latency_s", Json::num(b.latency_s)),
            ("rounds", Json::num(b.rounds as f64)),
            ("device_s", Json::num(b.device_s)),
            ("queue_s", Json::num(b.queue_s)),
            ("paging_s", Json::num(b.paging_s)),
            ("engine_s", Json::num(b.engine_s)),
            ("network_s", Json::num(b.network_s)),
            ("stall_s", Json::num(b.stall_s)),
        ];
        if let Some(ttft) = b.ttft_s {
            line.push(("ttft_s", Json::num(ttft)));
        }
        if let Some(tbt) = b.tbt_s {
            line.push(("tbt_s", Json::num(tbt)));
        }
        out.push_str(&Json::obj(line).to_string());
        out.push('\n');
    }
    out
}

/// Analyze a trace file on disk.
pub fn analyze_file(path: impl AsRef<std::path::Path>) -> Result<InspectReport> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    if text.trim().is_empty() {
        bail!("empty trace file {}", path.as_ref().display());
    }
    analyze_chrome_trace(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::chrome_trace_string;
    use crate::obs::trace::{TraceSink, PID_CLOUD};

    /// One hand-crafted request: arrive 0.0, round with 0.1s uplink,
    /// 0.2s queue, 0.3s engine window + 0.25s service, 0.05s downlink,
    /// 0.1s stall, finishing at 2.0 → device time fills the rest.
    fn craft() -> TraceSink {
        let mut s = TraceSink::virtual_time(256);
        let (pid, dev, id) = (2, 0, 42);
        s.set_now(0.0);
        s.begin(pid, dev, "request", id);
        s.set_now(0.5); // 0.5 s of drafting
        s.instant(pid, dev, "offload", id, vec![("round", 0.0)]);
        s.begin(pid, dev, "round", id);
        s.begin(pid, dev, "uplink", id);
        s.set_now(0.6); // 0.1 s uplink
        s.end(pid, dev, "uplink", id);
        s.instant(PID_CLOUD, 0, "enqueue", id, vec![("cost", 4.0), ("round", 0.0)]);
        s.set_now(0.8); // 0.2 s queue wait
        s.instant(PID_CLOUD, 0, "admit", id, vec![("round", 0.0)]);
        s.instant(PID_CLOUD, 0, "swap_in", id, vec![("rows", 8.0), ("bytes", 64.0), ("s", 0.04)]);
        s.set_now(1.1); // 0.3 s to the commit tick
        s.instant(PID_CLOUD, 0, "verify_commit", id, vec![("accepted", 3.0), ("round", 0.0)]);
        s.instant(
            PID_CLOUD,
            0,
            "reply",
            id,
            vec![("round", 0.0), ("service", 0.25), ("dl", 0.05)],
        );
        s.set_now(1.5); // reply lands 0.1 s later than accounted: stall
        s.end(pid, dev, "round", id);
        s.instant(pid, dev, "device_commit", id, vec![("accepted", 3.0), ("round", 0.0)]);
        s.set_now(2.0); // 0.5 s more drafting
        s.end(pid, dev, "request", id);
        s
    }

    #[test]
    fn hand_crafted_trace_attributes_exactly() {
        let rep = analyze_chrome_trace(&chrome_trace_string(&craft())).unwrap();
        assert_eq!(rep.partial, 0);
        assert_eq!(rep.requests.len(), 1);
        let b = &rep.requests[0];
        let eps = 1e-9;
        assert!((b.latency_s - 2.0).abs() < eps);
        assert_eq!(b.rounds, 1);
        assert!((b.network_s - 0.15).abs() < eps, "uplink 0.1 + dl 0.05: {}", b.network_s);
        assert!((b.queue_s - 0.2).abs() < eps, "queue: {}", b.queue_s);
        assert!((b.paging_s - 0.04).abs() < eps, "paging: {}", b.paging_s);
        // cloud window 0.3 + 0.25 service, minus 0.04 swap
        assert!((b.engine_s - 0.51).abs() < eps, "engine: {}", b.engine_s);
        // round rtt 1.0 − 0.1 up − 0.2 queue − 0.55 window − 0.05 dl
        assert!((b.stall_s - 0.1).abs() < eps, "stall: {}", b.stall_s);
        assert!((b.device_s - 1.0).abs() < eps, "device: {}", b.device_s);
        assert!((b.component_sum_s() - b.latency_s).abs() < eps);
        assert_eq!(rep.tenants.len(), 1);
        assert_eq!(rep.tenants[0].requests, 1);
    }

    #[test]
    fn incomplete_requests_count_as_partial() {
        let mut s = craft();
        // a second request whose reply never arrived (windowed run)
        s.set_now(3.0);
        s.begin(2, 1, "request", 77);
        s.instant(2, 1, "offload", 77, vec![("round", 0.0)]);
        s.begin(2, 1, "round", 77);
        s.begin(2, 1, "uplink", 77);
        let rep = analyze_chrome_trace(&chrome_trace_string(&s)).unwrap();
        assert_eq!(rep.requests.len(), 1, "complete request still attributed");
        assert_eq!(rep.partial, 1);
        assert!(table_string(&rep).contains("1 partial"), "partial surfaced in the table");
    }

    #[test]
    fn local_only_requests_are_pure_device_time() {
        let mut s = TraceSink::virtual_time(64);
        s.set_now(1.0);
        s.begin(3, 2, "request", 5);
        s.instant(3, 2, "local", 5, vec![("gamma", 4.0)]);
        s.set_now(1.75);
        s.end(3, 2, "request", 5);
        let rep = analyze_chrome_trace(&chrome_trace_string(&s)).unwrap();
        let b = &rep.requests[0];
        assert_eq!(b.rounds, 0);
        assert_eq!(b.tenant, 1, "pid 3 → tenant 1");
        assert!((b.device_s - 0.75).abs() < 1e-9);
        assert_eq!(b.component_sum_s(), b.latency_s);
    }

    #[test]
    fn inspect_output_is_deterministic() {
        let a = analyze_chrome_trace(&chrome_trace_string(&craft())).unwrap();
        let b = analyze_chrome_trace(&chrome_trace_string(&craft())).unwrap();
        assert_eq!(table_string(&a), table_string(&b));
        assert_eq!(requests_jsonl_string(&a), requests_jsonl_string(&b));
        for l in requests_jsonl_string(&a).lines() {
            Json::parse(l).expect("jsonl line parses");
        }
    }

    #[test]
    fn rejects_non_trace_input() {
        assert!(analyze_chrome_trace("not json").is_err());
        assert!(analyze_chrome_trace("{\"foo\": 1}").is_err());
    }

    #[test]
    fn trace_derived_ttft_feeds_the_slo_filter() {
        let rep = analyze_chrome_trace(&chrome_trace_string(&craft())).unwrap();
        let b = &rep.requests[0];
        // the only commit is the 3-token device_commit at t = 1.5
        assert_eq!(b.ttft_s, Some(1.5));
        assert_eq!(b.tbt_s, Some(0.0), "all 3 tokens in one instant");
        let strict =
            crate::config::SloPolicy { ttft_s: 1.0, tbt_s: 0.1, violation_budget: 0.1 };
        assert!(b.slo_miss(&strict));
        let miss = slo_miss_only(&rep, &strict);
        assert_eq!(miss.requests.len(), 1);
        assert_eq!(miss.tenants.len(), 1);
        let lax = crate::config::SloPolicy { ttft_s: 2.0, tbt_s: 0.1, violation_budget: 0.1 };
        let none = slo_miss_only(&rep, &lax);
        assert_eq!(none.requests.len(), 0, "TTFT 1.5 ≤ 2.0 and TBT 0.0 ≤ 0.1");
        assert!(none.tenants.is_empty());
        // the optional fields ride into the JSONL
        let jsonl = requests_jsonl_string(&rep);
        assert!(jsonl.contains("\"ttft_s\"") && jsonl.contains("\"tbt_s\""), "got: {jsonl}");
    }

    #[test]
    fn summary_table_covers_every_component() {
        let rep = analyze_chrome_trace(&chrome_trace_string(&craft())).unwrap();
        let t = summary_table_string(&rep);
        assert_eq!(t, summary_table_string(&rep), "deterministic");
        for name in ["latency", "device", "queue", "paging", "engine", "network", "stall"] {
            assert!(t.lines().any(|l| l.starts_with(name)), "row {name} in:\n{t}");
        }
        // header + 7 component rows + request-count footer
        assert_eq!(t.lines().count(), 9, "table:\n{t}");
        assert!(t.contains("(1 requests)"));
    }
}
