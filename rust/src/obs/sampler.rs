//! Tail-based trace sampling: keep every *interesting* request's full
//! event set, a deterministic head sample of the rest, and nothing
//! else.
//!
//! At fleet scale the [`super::trace::TraceSink`] ring is the wrong
//! retention policy: a 65536-device run emits orders of magnitude more
//! events than any bounded buffer holds, and oldest-drop discards
//! exactly the early/overload events the critical-path analyzer and
//! SLO gauges need. The [`Sampler`] replaces *time-based* retention
//! with *outcome-based* retention:
//!
//! 1. every request-classified event is **staged** in a per-request
//!    buffer while the request is in flight;
//! 2. at [`Sampler::complete`] the staged set is either retained or
//!    discarded wholesale:
//!    * **head sample** — a deterministic seeded 1-in-N draw
//!      (`splitmix64(seed ^ request_id)`), giving an unbiased
//!      population baseline independent of arrival order;
//!    * **tail sample** — every request flagged interesting by the
//!      caller (SLO miss, error/partial outcome) or marked mid-flight
//!      via [`Sampler::mark_interesting`] (e.g. the scheduler's
//!      overflow-rejected verifies) is always retained;
//!    * **top-k slowest** — a bounded min-heap keyed
//!      `(latency, request_id)` keeps the k slowest requests seen so
//!      far; requests evicted from the heap lose their events unless
//!      head- or tail-retained.
//! 3. everything else is dropped on the spot, so retained memory is
//!    `O(retained + in-flight staging)` instead of `O(total events)`.
//!
//! Events that never name a request (phase slices, counters, `arrive`
//! instants) stay in the sink's ring buffer; the export path merges
//! ring + retained + still-staged events back into one stream ordered
//! by record sequence. **All-retain mode** (`head_every = 1`) therefore
//! reproduces the unsampled export byte for byte, and the sampler
//! never perturbs the simulation (pure observer, same determinism
//! contract as the sink).

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};

use crate::net::wire::TraceContext;
use crate::obs::trace::TraceEvent;
use crate::util::rng::splitmix64;

/// Retention policy of a [`Sampler`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SamplerConfig {
    /// Head sample: retain 1 in `head_every` completed requests
    /// (deterministic per-request draw). `0` disables head retention,
    /// `1` retains everything (all-retain mode).
    pub head_every: u64,
    /// Keep the `tail_k` slowest requests seen so far (0 disables).
    pub tail_k: usize,
    /// Seed of the head draw — same seed ⇒ same retained population.
    pub seed: u64,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig { head_every: 64, tail_k: 32, seed: 0 }
    }
}

/// Point-in-time sampler accounting, exported as `obs.sampler_*`
/// gauges and asserted by the CI retained-budget smoke.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SamplerStats {
    /// Requests completed through the sampler.
    pub completed: u64,
    /// Completions retained by the head draw.
    pub head_retained: u64,
    /// Completions retained as tail-interesting (SLO miss / error /
    /// marked). Always equals the number of interesting completions —
    /// tail retention is unconditional.
    pub tail_retained: u64,
    /// Requests currently retained (all reasons, after top-k churn).
    pub retained_requests: u64,
    /// Events currently held for retained requests.
    pub retained_events: u64,
    /// In-flight requests currently staged.
    pub staged_requests: u64,
    /// Events currently staged for in-flight requests.
    pub staged_events: u64,
    /// High-water mark of `staged_events` over the run.
    pub peak_staged_events: u64,
    /// Completions discarded outright (plus top-k evictions).
    pub discarded_requests: u64,
    /// Events dropped with them.
    pub discarded_events: u64,
}

/// One retained request's events plus why they were kept.
#[derive(Debug)]
struct Retained {
    events: Vec<TraceEvent>,
    head: bool,
    tail: bool,
    topk: bool,
}

/// Outcome-based trace retention (see the module docs). Owned by a
/// [`super::trace::TraceSink`]; not used standalone.
#[derive(Debug, Default)]
pub struct Sampler {
    cfg: SamplerConfig,
    staging: BTreeMap<u64, Vec<TraceEvent>>,
    retained: BTreeMap<u64, Retained>,
    /// Min-heap over `(latency bits, request id)` — the k slowest
    /// survive; `f64::to_bits` is order-preserving for non-negatives.
    topk: BinaryHeap<Reverse<(u64, u64)>>,
    /// Requests flagged interesting before completion.
    marked: BTreeSet<u64>,
    /// Every id that ever completed, so late events (e.g. a session's
    /// final `swap_out` on the tick after release) follow their
    /// request's fate instead of re-opening a staging entry.
    completed_ids: BTreeSet<u64>,
    stats: SamplerStats,
}

impl Sampler {
    pub fn new(cfg: SamplerConfig) -> Sampler {
        Sampler { cfg, ..Sampler::default() }
    }

    pub fn config(&self) -> SamplerConfig {
        self.cfg
    }

    pub fn stats(&self) -> SamplerStats {
        self.stats
    }

    /// The request id an event belongs to, or `None` for background
    /// events (phase slices, counters, id-0 instants) that stay in the
    /// sink's ring. Flow arrows carry synthetic ids in their own
    /// namespace and are decoded back to the originating request.
    pub fn request_of(e: &TraceEvent) -> Option<u64> {
        if e.ph.is_flow() {
            TraceContext::request_of_flow(e.id)
        } else if e.id != 0 {
            Some(e.id)
        } else {
            None
        }
    }

    /// Would the deterministic head draw retain `request_id`? Pure —
    /// callers can predict the retained population without running.
    pub fn head_retains(cfg: &SamplerConfig, request_id: u64) -> bool {
        cfg.head_every == 1
            || (cfg.head_every > 1 && splitmix64(cfg.seed ^ request_id).1 % cfg.head_every == 0)
    }

    /// Stage a request-classified event (the sink routes here from its
    /// record path). Late events of an already-completed request follow
    /// that request's retain/discard decision.
    pub fn stage(&mut self, request_id: u64, e: TraceEvent) {
        if let Some(r) = self.retained.get_mut(&request_id) {
            r.events.push(e);
            self.stats.retained_events += 1;
            return;
        }
        if self.completed_ids.contains(&request_id) {
            self.stats.discarded_events += 1;
            return;
        }
        self.staging.entry(request_id).or_default().push(e);
        self.stats.staged_events += 1;
        self.stats.staged_requests = self.staging.len() as u64;
        self.stats.peak_staged_events = self.stats.peak_staged_events.max(self.stats.staged_events);
    }

    /// Flag an in-flight request as tail-interesting regardless of how
    /// it later completes (e.g. a verify rejected for exceeding the
    /// engine context window).
    pub fn mark_interesting(&mut self, request_id: u64) {
        if !self.completed_ids.contains(&request_id) {
            self.marked.insert(request_id);
        }
    }

    /// Settle a request: retain its staged events (head draw, tail
    /// interest, or top-k latency) or discard them. `latency_s` keys
    /// the top-k heap; `interesting` is the caller's tail verdict (SLO
    /// miss or error/partial outcome).
    pub fn complete(&mut self, request_id: u64, latency_s: f64, interesting: bool) {
        let events = self.staging.remove(&request_id).unwrap_or_default();
        self.stats.staged_events -= events.len() as u64;
        self.stats.staged_requests = self.staging.len() as u64;
        self.stats.completed += 1;
        self.completed_ids.insert(request_id);

        let head = Self::head_retains(&self.cfg, request_id);
        let tail = interesting || self.marked.remove(&request_id);
        let mut topk = false;
        if self.cfg.tail_k > 0 {
            let key = (latency_s.max(0.0).to_bits(), request_id);
            if self.topk.len() < self.cfg.tail_k {
                self.topk.push(Reverse(key));
                topk = true;
            } else if self.topk.peek().is_some_and(|&Reverse(min)| key > min) {
                let Reverse((_, evicted)) = self.topk.pop().expect("non-empty heap");
                self.drop_topk_claim(evicted);
                self.topk.push(Reverse(key));
                topk = true;
            }
        }
        if head {
            self.stats.head_retained += 1;
        }
        if tail {
            self.stats.tail_retained += 1;
        }
        if head || tail || topk {
            self.stats.retained_events += events.len() as u64;
            self.stats.retained_requests += 1;
            self.retained.insert(request_id, Retained { events, head, tail, topk });
        } else {
            self.stats.discarded_requests += 1;
            self.stats.discarded_events += events.len() as u64;
        }
    }

    /// A request fell out of the top-k heap: drop its events unless it
    /// is also head- or tail-retained.
    fn drop_topk_claim(&mut self, request_id: u64) {
        if let Some(r) = self.retained.get_mut(&request_id) {
            r.topk = false;
            if !r.head && !r.tail {
                let r = self.retained.remove(&request_id).expect("just fetched");
                self.stats.retained_events -= r.events.len() as u64;
                self.stats.retained_requests -= 1;
                self.stats.discarded_requests += 1;
                self.stats.discarded_events += r.events.len() as u64;
            }
        }
    }

    /// Is `request_id` currently retained (any reason)?
    pub fn is_retained(&self, request_id: u64) -> bool {
        self.retained.contains_key(&request_id)
    }

    /// Currently retained request ids with their reasons as
    /// `(id, head, tail, topk)`, in id order.
    pub fn retained_requests(&self) -> impl Iterator<Item = (u64, bool, bool, bool)> + '_ {
        self.retained.iter().map(|(&id, r)| (id, r.head, r.tail, r.topk))
    }

    /// Events currently held: retained requests' sets plus still-staged
    /// (in-flight — retained as partial at export time) ones. Unsorted
    /// across requests; the sink merges and seq-orders them with the
    /// ring.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.retained
            .values()
            .flat_map(|r| r.events.iter())
            .chain(self.staging.values().flatten())
    }

    /// Total events currently held (retained + staged).
    pub fn len(&self) -> usize {
        (self.stats.retained_events + self.stats.staged_events) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Ph;

    fn ev(id: u64, seq: u64) -> TraceEvent {
        TraceEvent {
            ts_s: seq as f64,
            dur_s: 0.0,
            ph: Ph::Instant,
            name: "e",
            cat: "event",
            pid: 2,
            tid: 0,
            id,
            args: Vec::new(),
            seq,
        }
    }

    fn stage_n(s: &mut Sampler, id: u64, n: u64) {
        for i in 0..n {
            s.stage(id, ev(id, id * 100 + i));
        }
    }

    #[test]
    fn classification_routes_flows_to_their_request() {
        let mut e = ev(42, 0);
        assert_eq!(Sampler::request_of(&e), Some(42));
        e.ph = Ph::FlowStart;
        e.id = TraceContext::flow_id(42, 3);
        assert_eq!(Sampler::request_of(&e), Some(42));
        e.ph = Ph::Instant;
        e.id = 0;
        assert_eq!(Sampler::request_of(&e), None, "id-0 instants are background");
    }

    #[test]
    fn interesting_requests_are_always_retained() {
        let mut s = Sampler::new(SamplerConfig { head_every: 0, tail_k: 0, seed: 1 });
        for id in 1..=50u64 {
            stage_n(&mut s, id, 3);
            s.complete(id, 0.1, id % 10 == 0);
        }
        let st = s.stats();
        assert_eq!(st.completed, 50);
        assert_eq!(st.tail_retained, 5);
        assert_eq!(st.retained_requests, 5);
        assert_eq!(st.retained_events, 15);
        assert_eq!(st.discarded_requests, 45);
        assert_eq!(st.discarded_events, 135);
        for id in [10u64, 20, 30, 40, 50] {
            assert!(s.is_retained(id));
        }
        assert_eq!(st.staged_events, 0);
        assert_eq!(st.peak_staged_events, 3, "one request in flight at a time");
    }

    #[test]
    fn head_draw_is_deterministic_and_seeded() {
        let cfg_a = SamplerConfig { head_every: 8, tail_k: 0, seed: 7 };
        let cfg_b = SamplerConfig { head_every: 8, tail_k: 0, seed: 8 };
        let pick = |cfg: &SamplerConfig| -> Vec<u64> {
            (0..1000).filter(|&id| Sampler::head_retains(cfg, id)).collect()
        };
        assert_eq!(pick(&cfg_a), pick(&cfg_a), "same seed, same population");
        assert_ne!(pick(&cfg_a), pick(&cfg_b), "different seed, different population");
        let n = pick(&cfg_a).len();
        assert!((60..=190).contains(&n), "~1-in-8 of 1000: {n}");
        assert!((0..1000).all(|id| Sampler::head_retains(
            &SamplerConfig { head_every: 1, tail_k: 0, seed: 0 },
            id
        )));
    }

    #[test]
    fn topk_keeps_slowest_and_evicts_deterministically() {
        let mut s = Sampler::new(SamplerConfig { head_every: 0, tail_k: 3, seed: 0 });
        for id in 1..=10u64 {
            stage_n(&mut s, id, 2);
            s.complete(id, id as f64 * 0.01, false);
        }
        let kept: Vec<u64> = s.retained_requests().map(|(id, ..)| id).collect();
        assert_eq!(kept, vec![8, 9, 10], "three slowest survive");
        let st = s.stats();
        assert_eq!(st.retained_events, 6);
        assert_eq!(st.discarded_requests, 7);
        // equal latencies tie-break on request id (larger id wins)
        let mut t = Sampler::new(SamplerConfig { head_every: 0, tail_k: 1, seed: 0 });
        for id in [5u64, 9, 7] {
            t.complete(id, 0.25, false);
        }
        let kept: Vec<u64> = t.retained_requests().map(|(id, ..)| id).collect();
        assert_eq!(kept, vec![9]);
    }

    #[test]
    fn topk_eviction_spares_head_and_tail_claims() {
        let mut s = Sampler::new(SamplerConfig { head_every: 0, tail_k: 1, seed: 0 });
        stage_n(&mut s, 1, 2);
        s.complete(1, 0.5, true); // tail + (briefly) top-k
        stage_n(&mut s, 2, 2);
        s.complete(2, 0.9, false); // evicts 1 from the heap
        assert!(s.is_retained(1), "tail claim outlives top-k eviction");
        assert!(s.is_retained(2));
        let reasons: Vec<_> = s.retained_requests().collect();
        assert_eq!(reasons, vec![(1, false, true, false), (2, false, false, true)]);
    }

    #[test]
    fn mark_interesting_forces_retention() {
        let mut s = Sampler::new(SamplerConfig { head_every: 0, tail_k: 0, seed: 0 });
        stage_n(&mut s, 3, 4);
        s.mark_interesting(3);
        s.complete(3, 0.01, false);
        assert!(s.is_retained(3));
        assert_eq!(s.stats().tail_retained, 1);
    }

    #[test]
    fn late_events_follow_their_requests_fate() {
        let mut s = Sampler::new(SamplerConfig { head_every: 0, tail_k: 0, seed: 0 });
        stage_n(&mut s, 1, 1);
        s.complete(1, 0.1, true); // retained
        stage_n(&mut s, 2, 1);
        s.complete(2, 0.1, false); // discarded
        s.stage(1, ev(1, 900)); // post-completion swap_out et al.
        s.stage(2, ev(2, 901));
        let st = s.stats();
        assert_eq!(st.retained_events, 2, "late event joins the retained set");
        assert_eq!(st.discarded_events, 2, "late event of a discarded request is dropped");
        assert_eq!(st.staged_requests, 0, "no staging entry is re-opened");
    }

    #[test]
    fn still_staged_requests_surface_in_events() {
        let mut s = Sampler::new(SamplerConfig::default());
        stage_n(&mut s, 9, 3);
        assert_eq!(s.events().count(), 3, "in-flight events visible to export");
        assert_eq!(s.len(), 3);
    }
}
