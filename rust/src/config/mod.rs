//! System configuration: model pairs, device/link profiles and the
//! Synera runtime parameters (paper §4/§5 hyper-parameters).

use crate::net::LinkProfile;

/// An SLM–LLM pairing (paper Table 4 rows). `slm_weights` selects a
/// quantized variant ("s7b_bnb4" / "s7b_awq") for the Table 6 runs.
#[derive(Debug, Clone)]
pub struct PairConfig {
    pub slm: String,
    pub llm: String,
    pub slm_weights: Option<String>,
}

impl PairConfig {
    pub fn new(slm: &str, llm: &str) -> Self {
        PairConfig { slm: slm.into(), llm: llm.into(), slm_weights: None }
    }

    /// The paper's three Table-4 pairs, mapped onto our zoo
    /// (160M&13B, 1.1B&13B, 7B&70B).
    pub fn table4_pairs() -> Vec<PairConfig> {
        vec![
            PairConfig::new("s160m", "l13b"),
            PairConfig::new("s1b", "l13b"),
            PairConfig::new("s7b", "l70b"),
        ]
    }

    pub fn label(&self) -> String {
        match &self.slm_weights {
            Some(w) => format!("{}({w})&{}", self.slm, self.llm),
            None => format!("{}&{}", self.slm, self.llm),
        }
    }
}

/// Device compute/energy profile (stands in for Jetson Orin power modes
/// and the Pixel 7 — DESIGN.md §1). `compute_scale` multiplies measured
/// PJRT step time when accounting device-side latency, so one CPU testbed
/// can represent devices of different speeds.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: String,
    pub compute_scale: f64,
    pub joules_per_token: f64,
    pub joules_per_byte: f64,
}

impl DeviceProfile {
    pub fn jetson_orin_50w() -> Self {
        DeviceProfile {
            name: "orin-50w".into(),
            compute_scale: 1.0,
            joules_per_token: 1.86, // Table 5 edge-centric J/token
            joules_per_byte: 2e-7,
        }
    }

    pub fn jetson_orin_30w() -> Self {
        DeviceProfile {
            name: "orin-30w".into(),
            compute_scale: 1.6,
            joules_per_token: 1.30,
            joules_per_byte: 2e-7,
        }
    }

    pub fn pixel7() -> Self {
        DeviceProfile {
            name: "pixel7".into(),
            compute_scale: 3.5,
            joules_per_token: 0.55,
            joules_per_byte: 4e-7,
        }
    }

    /// 4-bit weight variants run memory-bound decode faster (Table 6).
    pub fn with_quant_speedup(mut self, factor: f64) -> Self {
        self.compute_scale /= factor;
        self
    }
}

/// Cloud mixed continuous-batching policy (Sarathi-style).
///
/// Each scheduler iteration packs **all** runnable work — decode rows,
/// verification chunks and prefill chunks — into one engine call under a
/// per-iteration token-row budget. Decode rows (1 token each) are packed
/// first, then verification chunks, then prefill chunks; prefill is
/// additionally capped at `prefill_share` of the budget whenever
/// latency-critical rows are present, so a long prompt stream cannot
/// monopolise the iteration. Any job skipped for `age_threshold`
/// consecutive iterations is promoted ahead of all non-aged work, which
/// bounds worst-case queueing delay for every class.
///
/// `max_sessions` decouples *admission* from the compiled batch width:
/// the scheduler admits up to that many logical sessions and pages the
/// KV of slot-less ones through a host block pool
/// (`runtime::paging` + `cloud::sessions`), so the Fig. 15 queueing
/// knee sits at `max_sessions` instead of the engine's B.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Max token rows per engine iteration. `0` = auto (slots × chunk,
    /// i.e. the engine's full capacity — non-constraining).
    pub token_budget: usize,
    /// Fraction of the budget prefill chunks may claim while decode or
    /// verify rows are runnable (chunked-prefill cap; ∈ (0,1]).
    pub prefill_share: f64,
    /// Iterations a runnable job may be skipped before it jumps the
    /// priority order.
    pub age_threshold: u64,
    /// Max concurrent *logical* sessions. `0` = auto (the engine's
    /// physical slot count — paged-KV swapping never triggers); values
    /// above the slot count enable host-side KV paging.
    pub max_sessions: usize,
    /// Per-tenant weights for the weighted-fair admission frontend
    /// (`cloud::fairness`). Empty = frontend off (single-queue FIFO
    /// admission); entries must be finite and positive.
    pub tenant_weights: Vec<f64>,
    /// Scheduler replicas behind the router tier (`cloud::router`).
    /// `0` is normalised to `1`; with one replica the router is a
    /// transparent pass-through and behavior is bit-identical to the
    /// pre-router single-scheduler stack.
    pub replicas: usize,
    /// Cross-replica rebalance trigger: migrate parked sessions from
    /// the most to the least loaded replica whenever their load gap
    /// (queued + in-flight + open sessions) exceeds this. `0` =
    /// rebalancing off.
    pub rebalance_threshold: usize,
    /// Shared-prefix KV cache (`runtime::prefix`): content-hashed
    /// block identity with radix matching at admission and
    /// copy-on-write paging. Off by default — with `false` the paging
    /// stack is behaviorally bit-identical to private-only paging.
    pub prefix_cache: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            token_budget: 0,
            prefill_share: 0.5,
            age_threshold: 4,
            max_sessions: 0,
            tenant_weights: Vec::new(),
            replicas: 1,
            rebalance_threshold: 0,
            prefix_cache: false,
        }
    }
}

impl BatchPolicy {
    /// Parse `--tenants` / `--tenant-weights` style CLI input into the
    /// weight vector: an explicit comma-separated list wins; otherwise
    /// `n_tenants > 1` yields equal weights; otherwise the frontend
    /// stays off.
    pub fn tenant_weights_from(
        n_tenants: usize,
        weights_csv: Option<&str>,
    ) -> anyhow::Result<Vec<f64>> {
        let weights = match weights_csv {
            Some(csv) => csv
                .split(',')
                .map(|s| s.trim().parse::<f64>().map_err(anyhow::Error::from))
                .collect::<anyhow::Result<Vec<f64>>>()?,
            None if n_tenants > 1 => vec![1.0; n_tenants],
            None => Vec::new(),
        };
        if weights.iter().any(|w| !w.is_finite() || *w <= 0.0) {
            anyhow::bail!("tenant weights must be finite and positive: {weights:?}");
        }
        if n_tenants > 0 && !weights.is_empty() && weights.len() != n_tenants {
            anyhow::bail!(
                "--tenant-weights lists {} weights but --tenants is {n_tenants}",
                weights.len()
            );
        }
        Ok(weights)
    }
}

/// Per-tenant latency SLO: the thresholds a request must meet and the
/// violation budget the burn-rate monitor (`obs::registry::SloMonitor`)
/// measures consumption against. Shared by `synera fleet` and `synera
/// serve` via `--slo-ttft` / `--slo-tbt`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloPolicy {
    /// Time-to-first-token target in seconds.
    pub ttft_s: f64,
    /// Time-between-tokens target in seconds.
    pub tbt_s: f64,
    /// Tolerated violation fraction (error budget): a burn rate of 1.0
    /// means violations are arriving exactly at the budgeted rate;
    /// above 1.0 the budget is being consumed faster than allowed.
    pub violation_budget: f64,
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy { ttft_s: 2.0, tbt_s: 0.25, violation_budget: 0.1 }
    }
}

impl SloPolicy {
    /// Cumulative burn: the fraction of the violation budget consumed
    /// given an attainment level (1.0 − attainment violations observed).
    pub fn burn(&self, attainment: f64) -> f64 {
        if self.violation_budget <= 0.0 {
            return 0.0;
        }
        ((1.0 - attainment).max(0.0)) / self.violation_budget
    }
}

/// Synera runtime parameters (paper defaults annotated).
#[derive(Debug, Clone)]
pub struct SyneraParams {
    /// Draft chunk length γ (paper §5: 4).
    pub gamma: usize,
    /// Parallel-inference speculative continuation length δ.
    pub delta: usize,
    /// Offloading budget knob ∈ [0,1] → i_th percentile (paper §4.2).
    pub budget: f64,
    /// Sigmoid steepness k for P_conf (paper: 10).
    pub k_conf: f64,
    /// Sigmoid slope θ for P_imp (paper: −10).
    pub theta_imp: f64,
    /// Layer-wise early-exit margin threshold (paper §4.3: 0.7).
    pub exit_threshold: f64,
    /// Sequence-wise early-exit fraction γ_seq (paper §4.3: 0.8).
    pub seq_exit_frac: f64,
    pub max_new_tokens: usize,
    /// Module toggles (ablations).
    pub early_exit: bool,
    pub parallel_inference: bool,
    pub compression: bool,
    pub use_conf: bool,
    pub use_imp: bool,
    /// Fig. 5 ablation: ignore scores, offload each chunk w.p. `budget`.
    pub random_offload: bool,
    /// Greedy decoding (vs stochastic speculative sampling).
    pub greedy: bool,
    /// Dispatch-sampling seed (P_conf/P_imp draws).
    pub seed: u64,
    /// Cloud mixed continuous-batching policy.
    pub batch: BatchPolicy,
}

impl Default for SyneraParams {
    fn default() -> Self {
        SyneraParams {
            gamma: 4,
            delta: 2,
            budget: 0.2, // the paper's typical working point (§6.3)
            k_conf: 10.0,
            theta_imp: -10.0,
            exit_threshold: 0.7,
            seq_exit_frac: 0.8,
            max_new_tokens: 16,
            early_exit: true,
            parallel_inference: true,
            compression: true,
            use_conf: true,
            use_imp: true,
            random_offload: false,
            greedy: true,
            seed: 0xC0FFEE,
            batch: BatchPolicy::default(),
        }
    }
}

/// A full experiment configuration.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub pair: PairConfig,
    pub device: DeviceProfile,
    pub link: LinkProfile,
    pub params: SyneraParams,
}

impl Scenario {
    pub fn default_pair(slm: &str, llm: &str) -> Scenario {
        Scenario {
            pair: PairConfig::new(slm, llm),
            device: DeviceProfile::jetson_orin_50w(),
            link: LinkProfile::wifi(),
            params: SyneraParams::default(),
        }
    }

    /// The five deployment configurations of Fig. 11/12 (SLM × device ×
    /// energy mode × LLM).
    pub fn fig11_configs() -> Vec<(String, Scenario)> {
        let mk = |slm: &str, llm: &str, dev: DeviceProfile| Scenario {
            pair: PairConfig::new(slm, llm),
            device: dev,
            link: LinkProfile::wifi(),
            params: SyneraParams::default(),
        };
        vec![
            ("s160m&13B/orin50".into(), mk("s160m", "l13b", DeviceProfile::jetson_orin_50w())),
            ("s160m&13B/orin30".into(), mk("s160m", "l13b", DeviceProfile::jetson_orin_30w())),
            ("s1b&13B/orin50".into(), mk("s1b", "l13b", DeviceProfile::jetson_orin_50w())),
            ("s1b&13B/pixel7".into(), mk("s1b", "l13b", DeviceProfile::pixel7())),
            ("s7b&70B/orin50".into(), mk("s7b", "l70b", DeviceProfile::jetson_orin_50w())),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let p = SyneraParams::default();
        assert_eq!(p.gamma, 4);
        assert_eq!(p.k_conf, 10.0);
        assert_eq!(p.theta_imp, -10.0);
        assert_eq!(p.exit_threshold, 0.7);
        assert_eq!(p.seq_exit_frac, 0.8);
        assert!((p.budget - 0.2).abs() < 1e-12);
    }

    #[test]
    fn five_fig11_configs() {
        assert_eq!(Scenario::fig11_configs().len(), 5);
    }

    #[test]
    fn quant_speedup_reduces_scale() {
        let d = DeviceProfile::jetson_orin_50w().with_quant_speedup(1.3);
        assert!(d.compute_scale < 1.0);
    }

    #[test]
    fn batch_policy_defaults_sane() {
        let b = BatchPolicy::default();
        assert_eq!(b.token_budget, 0, "default budget is auto (engine capacity)");
        assert!(b.prefill_share > 0.0 && b.prefill_share <= 1.0);
        assert!(b.age_threshold >= 1);
        assert_eq!(b.max_sessions, 0, "default session cap is auto (slot count, no paging)");
        assert!(b.tenant_weights.is_empty(), "tenant frontend defaults off");
        assert_eq!(b.replicas, 1, "default is the single-replica stack");
        assert_eq!(b.rebalance_threshold, 0, "rebalancing defaults off");
        assert!(!b.prefix_cache, "prefix sharing defaults off (bit-identical paging)");
    }

    #[test]
    fn slo_policy_burn_is_budget_relative() {
        let slo = SloPolicy { ttft_s: 1.0, tbt_s: 0.1, violation_budget: 0.1 };
        assert_eq!(slo.burn(1.0), 0.0, "full attainment burns nothing");
        assert!((slo.burn(0.9) - 1.0).abs() < 1e-12, "at-budget violations burn 1.0");
        assert!((slo.burn(0.8) - 2.0).abs() < 1e-12, "double-budget violations burn 2.0");
        let degenerate = SloPolicy { violation_budget: 0.0, ..slo };
        assert_eq!(degenerate.burn(0.5), 0.0, "zero budget never divides by zero");
    }

    #[test]
    fn tenant_weight_parsing() {
        assert_eq!(BatchPolicy::tenant_weights_from(0, None).unwrap(), Vec::<f64>::new());
        assert_eq!(BatchPolicy::tenant_weights_from(1, None).unwrap(), Vec::<f64>::new());
        assert_eq!(BatchPolicy::tenant_weights_from(3, None).unwrap(), vec![1.0; 3]);
        assert_eq!(
            BatchPolicy::tenant_weights_from(3, Some("1, 2,4")).unwrap(),
            vec![1.0, 2.0, 4.0]
        );
        assert!(BatchPolicy::tenant_weights_from(2, Some("1,2,3")).is_err(), "count mismatch");
        assert!(BatchPolicy::tenant_weights_from(2, Some("1,-2")).is_err(), "negative");
        assert!(BatchPolicy::tenant_weights_from(2, Some("1,zero")).is_err(), "non-numeric");
    }
}
