//! Per-request serving pipelines: Synera (paper §4) and the four
//! baselines (§6.1), in discrete-event timeline mode.

use anyhow::{bail, Result};

use crate::cloud::scheduler::{CloudEvent, CloudRequest, Scheduler};
use crate::cloud::verifier::VerifyOutcome;
use crate::config::Scenario;
use crate::device::codec::{compress_dist, dense_dist};
use crate::device::early_exit::SeqExitPolicy;
use crate::device::offload::Selector;
use crate::device::parallel::{alternative_token, predict_rejection};
use crate::metrics::energy::EnergyModel;
use crate::model::cloud_engine::{BatchEngine, CloudEngine};
use crate::model::device_engine::{DeviceEngine, DeviceSession, StepOut};
use crate::model::logits::argmax;
use crate::net::link::SimLink;
use crate::net::wire::{DownlinkMsg, UplinkMsg};
use crate::profiling::OffloadProfile;
use crate::util::rng::Rng;
use crate::workload::vocab::EOS;

/// Serving method under evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// All inference on the device SLM.
    EdgeCentric,
    /// All inference on the cloud LLM (Sarathi-style engine).
    CloudCentric,
    /// Hybrid [9]: confidence-threshold token offloading, vanilla
    /// pipeline (no PI/EE/importance/compression).
    Hybrid,
    /// EdgeFM [38] adapted to LLMs: perplexity-based *input-level*
    /// offloading (whole request to the cloud when prompt PPL is high).
    EdgeFmLlm,
    /// The full system.
    Synera,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::EdgeCentric => "Edge-centric",
            Method::CloudCentric => "Cloud-centric",
            Method::Hybrid => "Hybrid",
            Method::EdgeFmLlm => "EdgeFM-LLM",
            Method::Synera => "Synera",
        }
    }
}

/// Shared cloud busy-clock: orders verification service across requests
/// in timeline mode (a single-server queue over measured service times).
#[derive(Debug, Clone, Default)]
pub struct CloudClock {
    pub free_at: f64,
}

impl CloudClock {
    /// Serve a job arriving at `arrive` taking `service_s`; returns the
    /// completion time.
    pub fn serve(&mut self, arrive: f64, service_s: f64) -> f64 {
        let start = self.free_at.max(arrive);
        self.free_at = start + service_s;
        self.free_at
    }
}

/// Everything a pipeline run needs. The scheduler (and its engine) is
/// shared across requests of an experiment; sessions are per-request.
/// Generic over the cloud [`BatchEngine`] (PJRT in production, the
/// testutil mock in scheduler tests); defaults to [`CloudEngine`].
pub struct PipelineCtx<'a, E: BatchEngine = CloudEngine> {
    pub dev: &'a DeviceEngine,
    pub sched: &'a mut Scheduler<E>,
    pub scen: &'a Scenario,
    pub profile: &'a OffloadProfile,
    pub link: &'a mut SimLink,
    pub cloud_clock: &'a mut CloudClock,
    pub rng: &'a mut Rng,
}

/// Outcome + accounting for one request.
#[derive(Debug, Clone, Default)]
pub struct RequestReport {
    pub generated: Vec<u32>,
    /// Virtual finalization time of each generated token (s).
    pub token_times: Vec<f64>,
    /// End-to-end completion time (s).
    pub total_s: f64,
    /// Time the device spent stalled on the cloud (s).
    pub stall_s: f64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    /// LLM token rows executed for this request (cost `W` numerator).
    pub cloud_rows: u64,
    pub offload_chunks: u32,
    pub local_chunks: u32,
    pub pi_hits: u32,
    /// Rejection-position prediction matches (paper §6.5's hit rate).
    pub pi_pos_hits: u32,
    pub pi_misses: u32,
    pub exits: u32,
    pub steps: u32,
    pub energy_j: f64,
    /// Mean verification round-trip as seen by the device (s).
    pub verify_rtts: Vec<f64>,
}

impl RequestReport {
    pub fn tbt(&self) -> f64 {
        if self.generated.is_empty() {
            return 0.0;
        }
        self.total_s / self.generated.len() as f64
    }
}

fn strip_eos(mut v: Vec<u32>) -> Vec<u32> {
    if v.last() == Some(&EOS) {
        v.pop();
    }
    v
}

// --------------------------------------------------------------------------
// Edge-centric
// --------------------------------------------------------------------------

pub fn run_edge_centric<E: BatchEngine>(
    ctx: &mut PipelineCtx<E>,
    prompt: &[u32],
) -> Result<RequestReport> {
    let mut rep = RequestReport::default();
    let mut energy = EnergyModel::new(
        ctx.scen.device.joules_per_token,
        ctx.scen.device.joules_per_byte,
    );
    let scale = ctx.scen.device.compute_scale;
    let params = &ctx.scen.params;
    let (mut sess, mut cur) = ctx.dev.prefill(prompt)?;
    let mut t = cur.compute_s * scale;
    let exit_th = params.exit_threshold as f32;
    while rep.generated.len() < params.max_new_tokens {
        let tok = argmax(&cur.probs) as u32;
        if tok == EOS {
            break;
        }
        cur = ctx.dev.step(&mut sess, tok, params.early_exit, exit_th)?;
        t += cur.compute_s * scale;
        rep.exits += cur.exited as u32;
        rep.steps += 1;
        energy.record_step(cur.layer_fraction);
        rep.generated.push(tok);
        rep.token_times.push(t);
    }
    rep.total_s = t;
    rep.energy_j = energy.total_joules();
    rep.generated = strip_eos(rep.generated);
    Ok(rep)
}

// --------------------------------------------------------------------------
// Cloud-centric
// --------------------------------------------------------------------------

pub fn run_cloud_centric<E: BatchEngine>(
    ctx: &mut PipelineCtx<E>,
    prompt: &[u32],
) -> Result<RequestReport> {
    let mut rep = RequestReport::default();
    let params = &ctx.scen.params;
    let req_id = ctx.rng.next_u64();
    // prompt uplink: 2 bytes/token + small header (mirrors wire.rs rates)
    let up_bytes = prompt.len() * 2 + 16;
    rep.bytes_up = up_bytes as u64;
    let up = ctx.link.uplink_s(up_bytes);
    ctx.sched.submit(CloudRequest::Generate {
        request_id: req_id,
        prompt: prompt.to_vec(),
        max_new: params.max_new_tokens,
    })?;
    let mut service = 0.0;
    let mut tokens = Vec::new();
    loop {
        let (events, dt) = ctx.sched.tick()?;
        service += dt;
        let mut done = false;
        for e in events {
            if let CloudEvent::Generated { request_id, tokens: t } = e {
                if request_id == req_id {
                    tokens = t;
                    done = true;
                }
            }
        }
        if done {
            break;
        }
        if ctx.sched.is_idle() {
            bail!("cloud-centric request vanished");
        }
    }
    // W = 1: every generated token is cloud work (prefill charged as in
    // Synera's uncached forwarding — excluded from W on both sides)
    rep.cloud_rows = tokens.len() as u64;
    let finish = ctx.cloud_clock.serve(up, service);
    let down_bytes = tokens.len() * 2 + 16;
    rep.bytes_down = down_bytes as u64;
    let t_end = finish + ctx.link.downlink_s(down_bytes);
    let mut energy = EnergyModel::new(0.0, ctx.scen.device.joules_per_byte);
    energy.record_bytes((up_bytes + down_bytes) as u64);
    rep.energy_j = energy.total_joules();
    rep.generated = strip_eos(tokens);
    let n = rep.generated.len().max(1);
    // tokens stream back as decoded; approximate per-token times linearly
    for i in 0..rep.generated.len() {
        rep.token_times.push(up + (finish - up) * ((i + 1) as f64 / n as f64));
    }
    rep.total_s = t_end;
    Ok(rep)
}

// --------------------------------------------------------------------------
// EdgeFM-LLM (input-level offloading)
// --------------------------------------------------------------------------

pub fn run_edgefm<E: BatchEngine>(
    ctx: &mut PipelineCtx<E>,
    prompt: &[u32],
) -> Result<RequestReport> {
    // score the prompt with the SLM; high-PPL inputs go to the cloud whole
    let (score_sess, first) = ctx.dev.prefill(prompt)?;
    let scale = ctx.scen.device.compute_scale;
    let score_s = first.compute_s * scale;
    let ppl = score_sess.prompt_ppl();
    if ppl > ctx.profile.ppl_threshold {
        let mut rep = run_cloud_centric(ctx, prompt)?;
        rep.total_s += score_s; // scoring happened before offload
        rep.token_times.iter_mut().for_each(|t| *t += score_s);
        Ok(rep)
    } else {
        run_edge_centric(ctx, prompt)
    }
}

// --------------------------------------------------------------------------
// Synera (and Hybrid as a configuration of it)
// --------------------------------------------------------------------------

struct DraftChunk {
    start_len: usize,
    tokens: Vec<u32>,
    confs: Vec<f32>,
    /// Dense probs per draft token (for compression / PI alternatives).
    probs: Vec<Vec<f32>>,
    hit_eos: bool,
}

fn draft_chunk(
    dev: &DeviceEngine,
    sess: &mut DeviceSession,
    cur: &mut StepOut,
    gamma: usize,
    early_exit: bool,
    exit_th: f32,
    scale: f64,
    t: &mut f64,
    energy: &mut EnergyModel,
    rep: &mut RequestReport,
) -> Result<DraftChunk> {
    let start_len = sess.len;
    let mut ch = DraftChunk {
        start_len,
        tokens: Vec::new(),
        confs: Vec::new(),
        probs: Vec::new(),
        hit_eos: false,
    };
    for _ in 0..gamma {
        let tok = argmax(&cur.probs) as u32;
        ch.tokens.push(tok);
        ch.confs.push(cur.probs[tok as usize]);
        ch.probs.push(cur.probs.clone());
        if tok == EOS {
            // EOS is a draft token like any other (plain speculative
            // decoding): it rides to the verifier, which may veto a
            // premature ending. It is not stepped locally (nothing can
            // follow it on the device).
            ch.hit_eos = true;
            break;
        }
        *cur = dev.step(sess, tok, early_exit, exit_th)?;
        *t += cur.compute_s * scale;
        rep.exits += cur.exited as u32;
        rep.steps += 1;
        energy.record_step(cur.layer_fraction);
    }
    Ok(ch)
}

/// Full Synera pipeline. `Hybrid` runs through the same code with its
/// restricted parameterisation (see [`eval::method_scenario`]).
pub fn run_synera<E: BatchEngine>(
    ctx: &mut PipelineCtx<E>,
    prompt: &[u32],
) -> Result<RequestReport> {
    let params = ctx.scen.params.clone();
    let scale = ctx.scen.device.compute_scale;
    let exit_th = params.exit_threshold as f32;
    let mut rep = RequestReport::default();
    let mut energy = EnergyModel::new(
        ctx.scen.device.joules_per_token,
        ctx.scen.device.joules_per_byte,
    );
    let mut selector = Selector::new(
        ctx.profile.c_th,
        ctx.profile.i_th_for_budget(params.budget),
        params.clone(),
    );
    let seq_exit = SeqExitPolicy::new(
        params.seq_exit_frac,
        params.max_new_tokens,
        params.early_exit,
    );
    let req_id = ctx.rng.next_u64();

    let (mut sess, mut cur) = ctx.dev.prefill(prompt)?;
    let mut t = cur.compute_s * scale;
    let mut cloud_len = 0usize; // tokens validated in the cloud's KV

    'outer: while sess.len - prompt.len() < params.max_new_tokens {
        let remaining = params.max_new_tokens - (sess.len - prompt.len());
        let gamma = params.gamma.min(remaining);
        let chunk = draft_chunk(
            ctx.dev, &mut sess, &mut cur, gamma, params.early_exit, exit_th,
            scale, &mut t, &mut energy, &mut rep,
        )?;
        if chunk.tokens.is_empty() {
            break; // immediate EOS
        }
        let imps: Vec<f32> = (0..chunk.tokens.len())
            .map(|j| sess.importance[chunk.start_len + j])
            .collect();
        let decision = selector.decide(&chunk.confs, &imps);
        let gen_step = chunk.start_len - prompt.len();
        // chunks that drafted EOS still offload: a premature EOS is
        // exactly the kind of quality-critical prediction the LLM should
        // get to veto (the correction supersedes the drafted ending)
        let may_offload = seq_exit.offload_allowed(gen_step);

        if !(decision.offload && may_offload) {
            rep.local_chunks += 1;
            for (j, &tok) in chunk.tokens.iter().enumerate() {
                let _ = j;
                rep.generated.push(tok);
                rep.token_times.push(t);
            }
            if chunk.hit_eos {
                break 'outer;
            }
            continue;
        }

        // ---------------- offload round ----------------
        rep.offload_chunks += 1;
        let uncached: Vec<u32> = sess.tokens[cloud_len..chunk.start_len].to_vec();
        let dists: Vec<_> = chunk
            .probs
            .iter()
            .map(|p| if params.compression { compress_dist(p, 8) } else { dense_dist(p) })
            .collect();
        let msg = UplinkMsg {
            request_id: req_id,
            device_id: 0,
            uncached: uncached.clone(),
            draft: chunk.tokens.clone(),
            dists: dists.clone(),
            is_first: cloud_len == 0,
            ctx: Default::default(),
        };
        let up_bytes = msg.wire_bytes();
        rep.bytes_up += up_bytes as u64;
        energy.record_bytes(up_bytes as u64);
        let t_sent = t + ctx.link.uplink_s(up_bytes);

        ctx.sched.submit(CloudRequest::Verify {
            request_id: req_id,
            device_id: 0,
            uncached: uncached.clone(),
            draft: chunk.tokens.clone(),
            dists,
            greedy: params.greedy,
            ctx: Default::default(),
        })?;
        // cost accounting (paper W): cloud-*generated/verified* tokens;
        // KV prefill of uncached context is charged like prompt prefill
        // in the cloud-centric baseline, i.e. not against W
        rep.cloud_rows += chunk.tokens.len() as u64;
        let mut service = 0.0;
        let mut outcome: Option<VerifyOutcome> = None;
        while outcome.is_none() {
            let (events, dt) = ctx.sched.tick()?;
            service += dt;
            for e in events {
                if let CloudEvent::VerifyDone { request_id, outcome: o, .. } = e {
                    if request_id == req_id {
                        outcome = Some(o);
                    }
                }
            }
            if outcome.is_none() && ctx.sched.is_idle() {
                bail!("verification vanished from the scheduler");
            }
        }
        let outcome = outcome.unwrap();
        let verify_done = ctx.cloud_clock.serve(t_sent, service);
        let reply = DownlinkMsg {
            request_id: req_id,
            accepted: outcome.accepted as u32,
            next_token: outcome.next_token,
        };
        let down_bytes = reply.wire_bytes();
        rep.bytes_down += down_bytes as u64;
        energy.record_bytes(down_bytes as u64);
        let t_result = verify_done + ctx.link.downlink_s(down_bytes);
        rep.verify_rtts.push(t_result - t);

        // cloud now holds: previous prefix + uncached + accepted drafts
        let accepted = outcome.accepted.min(chunk.tokens.len());
        cloud_len = chunk.start_len + accepted;

        if chunk.hit_eos && accepted == chunk.tokens.len() {
            // the verifier agreed with the drafted EOS: commit and end
            rep.stall_s += (t_result - t).max(0.0);
            t = t.max(t_result);
            for &tok in &chunk.tokens {
                rep.generated.push(tok);
                rep.token_times.push(t);
            }
            break 'outer;
        }

        // ------------- stall-free parallel inference -------------
        let mut adopted_pi = false;
        if params.parallel_inference && chunk.tokens.len() > 1 {
            if let Some(r_star) =
                predict_rejection(ctx.profile.alpha, &chunk.confs, ctx.rng)
            {
                let alt = alternative_token(&chunk.probs[r_star], chunk.tokens[r_star]);
                let mut spec = sess.snapshot();
                spec.rewind(chunk.start_len + r_star);
                let mut pi_cur =
                    ctx.dev.step(&mut spec, alt, params.early_exit, exit_th)?;
                let mut t_dev = t + pi_cur.compute_s * scale;
                rep.steps += 1;
                energy.record_step(pi_cur.layer_fraction);
                let mut pi_tokens = vec![alt];
                while pi_tokens.len() < 1 + params.delta
                    && t_dev < t_result
                    && spec.len - prompt.len() < params.max_new_tokens
                {
                    let tok = argmax(&pi_cur.probs) as u32;
                    if tok == EOS {
                        break;
                    }
                    pi_tokens.push(tok);
                    pi_cur = ctx.dev.step(&mut spec, tok, params.early_exit, exit_th)?;
                    t_dev += pi_cur.compute_s * scale;
                    rep.steps += 1;
                    energy.record_step(pi_cur.layer_fraction);
                }
                // paper §4.4 counts a hit when the actual rejection
                // position matches the prediction (§6.5's 31–38%); we
                // report that rate but only *adopt* the speculation when
                // the substituted token also equals the cloud's
                // correction — otherwise adoption would silently replace
                // the LLM's fix with the SLM's guess and leak quality.
                let pos_hit = accepted == r_star && accepted < chunk.tokens.len();
                let hit = pos_hit && outcome.next_token == alt;
                if pos_hit {
                    rep.pi_pos_hits += 1;
                }
                if hit {
                    rep.pi_hits += 1;
                    adopted_pi = true;
                    sess = spec;
                    cur = pi_cur;
                    t = t_dev.max(t_result);
                    // committed: draft[0..r*] + pi_tokens
                    for &tok in chunk.tokens.iter().take(r_star) {
                        rep.generated.push(tok);
                        rep.token_times.push(t);
                    }
                    for &tok in &pi_tokens {
                        rep.generated.push(tok);
                        rep.token_times.push(t);
                    }
                } else {
                    rep.pi_misses += 1;
                    rep.stall_s += (t_result - t_dev).max(0.0);
                    t = t_dev.max(t_result);
                }
            }
        } else {
            // vanilla pipeline: the device stalls for the round trip
            rep.stall_s += (t_result - t).max(0.0);
            t = t.max(t_result);
        }

        if !adopted_pi {
            // resume from the cloud-corrected prefix
            sess.rewind(chunk.start_len + accepted);
            for &tok in chunk.tokens.iter().take(accepted) {
                rep.generated.push(tok);
                rep.token_times.push(t);
            }
            if outcome.next_token == EOS {
                break 'outer;
            }
            if sess.len - prompt.len() >= params.max_new_tokens {
                break 'outer;
            }
            cur = ctx.dev.step(&mut sess, outcome.next_token, params.early_exit, exit_th)?;
            t += cur.compute_s * scale;
            rep.steps += 1;
            energy.record_step(cur.layer_fraction);
            rep.generated.push(outcome.next_token);
            rep.token_times.push(t);
        }
        // (a drafted EOS that reaches this point was rejected by the
        // verifier — generation continues from the correction)
    }

    ctx.sched.submit(CloudRequest::Release { request_id: req_id })?;
    rep.total_s = t;
    rep.energy_j = energy.total_joules();
    rep.generated = strip_eos(rep.generated);
    rep.generated.truncate(params.max_new_tokens);
    Ok(rep)
}

/// Dispatch by method.
pub fn run_request<E: BatchEngine>(
    ctx: &mut PipelineCtx<E>,
    method: Method,
    prompt: &[u32],
) -> Result<RequestReport> {
    match method {
        Method::EdgeCentric => run_edge_centric(ctx, prompt),
        Method::CloudCentric => run_cloud_centric(ctx, prompt),
        Method::EdgeFmLlm => run_edgefm(ctx, prompt),
        Method::Hybrid | Method::Synera => run_synera(ctx, prompt),
    }
}
