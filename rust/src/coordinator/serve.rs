//! Real-time threaded serving mode (the end-to-end driver behind
//! `examples/multi_device_serving.rs` and `synera serve`).
//!
//! Unlike the discrete-event pipelines, this runs actual OS threads
//! with real queues and wall-clock time: `R` cloud threads
//! (`params.batch.replicas`) each own a PJRT runtime plus the
//! verification-aware [`Scheduler`], fronted by a router thread — the
//! serving analogue of [`crate::cloud::router::Router`] — that places
//! new sessions on the least-open replica and forwards follow-ups to
//! their home (session affinity). Each device thread owns its own
//! runtime (PJRT objects are thread-confined) and executes the Synera
//! device loop, *really* overlapping speculative computation with the
//! in-flight verification (PI runs while polling the reply channel).
//! Network delays are injected as sleeps computed by the [`SimLink`].
//!
//! Cross-thread KV *migration* is deliberately not attempted here:
//! PJRT engines are thread-confined, so a live migration would mean
//! shipping buffers between runtimes mid-run. The deterministic fleet
//! simulator ([`crate::sim::fleet`]) is the migration testbed; this
//! tier scales by placement only.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::cloud::scheduler::{CloudEvent, CloudRequest, Scheduler, SchedulerStats};
use crate::config::{Scenario, SloPolicy};
use crate::device::codec::compress_dist;
use crate::device::early_exit::SeqExitPolicy;
use crate::device::offload::Selector;
use crate::device::parallel::{alternative_token, predict_rejection};
use crate::metrics::stats::{QuantileSketch, Summary};
use crate::model::cloud_engine::CloudEngine;
use crate::model::device_engine::DeviceEngine;
use crate::model::logits::argmax;
use crate::net::link::SimLink;
use crate::net::wire::{DownlinkMsg, TraceContext, UplinkMsg};
use crate::obs::trace::{self, tenant_pid, Ph, TraceShared, PID_CLOUD};
use crate::profiling::{load_or_profile, OffloadProfile};
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use crate::workload::synthlang::Task;
use crate::workload::vocab::EOS;

/// Multi-device serving run configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub scenario: Scenario,
    pub task: Task,
    pub n_devices: usize,
    pub requests_per_device: usize,
    pub artifacts: PathBuf,
    /// Attached trace sink; a *wall-clock* sink fits this tier (real
    /// OS threads share the one clock). `None` = tracing off.
    pub trace: Option<TraceShared>,
    /// Service-level objective shared with the fleet simulator
    /// (`--slo-ttft`/`--slo-tbt` set both tiers identically).
    pub slo: SloPolicy,
}

/// Wall-clock results of a serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub completed: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub tokens_per_s: f64,
    pub e2e_latency: Summary,
    pub verify_rtt: Summary,
    /// Wall-clock time to first committed token, per request.
    pub ttft: Summary,
    /// Fraction of completed requests with TTFT ≤ the SLO.
    pub slo_ttft_frac: f64,
    /// Fraction of TBT-eligible (≥2 token) requests within the SLO.
    pub slo_tbt_frac: f64,
    /// Whole-run burn rates ([`SloPolicy::burn`]; 1.0 = at budget).
    pub ttft_burn: f64,
    pub tbt_burn: f64,
    pub quality: f64,
    pub offload_rate: f64,
    /// Paged-KV swap traffic summed across cloud replicas (0/0 when
    /// `max_sessions` keeps every session resident).
    pub swap_ins: u64,
    pub swap_outs: u64,
    /// Cloud scheduler replicas behind the router thread.
    pub replicas: usize,
}

enum ToCloud {
    Up(UplinkMsg, Sender<DownlinkMsg>),
    Release(u64),
    #[allow(dead_code)] Shutdown,
}

/// Run the threaded server end to end; blocks until all requests finish.
pub fn run_threaded(cfg: &ServeConfig) -> Result<ServeReport> {
    let (tx_cloud, rx_cloud) = channel::<ToCloud>();
    let replicas = cfg.scenario.params.batch.replicas.max(1);

    // ---------------- cloud replica threads ----------------
    // one scheduler per thread, each with its own PJRT runtime/engine
    let mut cloud_handles = Vec::with_capacity(replicas);
    let mut replica_txs = Vec::with_capacity(replicas);
    for r in 0..replicas {
        let (tx_r, rx_r) = channel::<ToCloud>();
        replica_txs.push(tx_r);
        let artifacts = cfg.artifacts.clone();
        let llm = cfg.scenario.pair.llm.clone();
        let greedy = cfg.scenario.params.greedy;
        let batch = cfg.scenario.params.batch.clone();
        let trace_r = cfg.trace.clone();
        let handle = std::thread::Builder::new()
            .name(format!("synera-cloud{r}"))
            .spawn(move || -> Result<SchedulerStats> {
                let rt = Runtime::load(artifacts)?;
                let mut engine = CloudEngine::new(rt.model(&llm)?)?;
                engine.warmup()?; // compile before accepting traffic
                let n_tenants = batch.tenant_weights.len();
                // replica 0 keeps the historical seed (an R = 1 run
                // reproduces the pre-router server); later replicas
                // decorrelate their verifier RNG streams
                let seed = if r == 0 {
                    0xC10D
                } else {
                    0xC10D ^ (0x5EED ^ r as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                };
                let mut sched = Scheduler::with_policy(engine, seed, batch);
                let trace_c = trace_r.clone();
                sched.set_trace(trace_r, r as u32);
                let mut replies: HashMap<u64, Sender<DownlinkMsg>> = HashMap::new();
                // round index of each in-flight verify, for the
                // `reply` instant that `synera inspect` keys on
                let mut rounds: HashMap<u64, u32> = HashMap::new();
                let mut open = true;
                while open || !sched.is_idle() {
                    // drain incoming
                    loop {
                        match rx_r.recv_timeout(Duration::from_micros(200)) {
                            Ok(ToCloud::Up(msg, reply)) => {
                                replies.insert(msg.request_id, reply);
                                if trace_c.is_some() {
                                    rounds.insert(msg.request_id, msg.ctx.round);
                                }
                                let req = CloudRequest::Verify {
                                    request_id: msg.request_id,
                                    device_id: msg.device_id,
                                    uncached: msg.uncached,
                                    draft: msg.draft,
                                    dists: msg.dists,
                                    greedy,
                                    // the wire context crosses the thread
                                    // boundary with the message, so cloud
                                    // spans stay attributable to the round
                                    ctx: msg.ctx,
                                };
                                if n_tenants > 0 {
                                    // devices map onto tenants round-robin
                                    sched
                                        .submit_tenant(msg.device_id as usize % n_tenants, req)?;
                                } else {
                                    sched.submit(req)?;
                                }
                            }
                            Ok(ToCloud::Release(id)) => {
                                sched.submit(CloudRequest::Release { request_id: id })?;
                            }
                            Ok(ToCloud::Shutdown) => open = false,
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => {
                                open = false;
                                break;
                            }
                        }
                    }
                    let (events, _) = sched.tick()?;
                    for e in events {
                        if let CloudEvent::VerifyDone { request_id, outcome, .. } = e {
                            if trace_c.is_some() {
                                // wall traces carry no modelled service/
                                // downlink split: the real service time is
                                // already the admit→verify_commit gap, and
                                // the downlink sleep lands in the residual
                                let round =
                                    rounds.remove(&request_id).map_or(-1.0, |x| x as f64);
                                let args =
                                    vec![("round", round), ("service", 0.0), ("dl", 0.0)];
                                trace::with(&trace_c, |s| {
                                    s.instant(PID_CLOUD, r as u32, "reply", request_id, args)
                                });
                            }
                            if let Some(ch) = replies.get(&request_id) {
                                let _ = ch.send(DownlinkMsg {
                                    request_id,
                                    accepted: outcome.accepted as u32,
                                    next_token: outcome.next_token,
                                });
                            }
                        }
                    }
                }
                Ok(sched.stats.clone())
            })?;
        cloud_handles.push(handle);
    }

    // ---------------- router thread ----------------
    // session affinity via a home map; new sessions land on the
    // replica with the fewest open sessions (ties → smallest index),
    // mirroring the simulator router's deterministic placement
    let router = std::thread::Builder::new().name("synera-router".into()).spawn(move || {
        let mut home: HashMap<u64, usize> = HashMap::new();
        let mut open = vec![0usize; replica_txs.len()];
        while let Ok(msg) = rx_cloud.recv() {
            match msg {
                ToCloud::Up(up, reply) => {
                    let r = match home.get(&up.request_id) {
                        Some(&r) => r,
                        None => {
                            let r = (0..open.len())
                                .min_by_key(|&r| (open[r], r))
                                .expect("≥1 replica");
                            home.insert(up.request_id, r);
                            open[r] += 1;
                            r
                        }
                    };
                    if replica_txs[r].send(ToCloud::Up(up, reply)).is_err() {
                        break; // replica gone; devices will observe too
                    }
                }
                ToCloud::Release(id) => {
                    if let Some(r) = home.remove(&id) {
                        open[r] = open[r].saturating_sub(1);
                        let _ = replica_txs[r].send(ToCloud::Release(id));
                    }
                }
                ToCloud::Shutdown => break,
            }
        }
        // dropping replica_txs closes every replica inbox → they drain
    })?;

    // ---------------- device threads ----------------
    let profile = {
        let rt = Runtime::load(cfg.artifacts.clone())?;
        load_or_profile(
            &rt,
            &cfg.scenario.pair.slm,
            cfg.scenario.pair.slm_weights.as_deref(),
            &cfg.scenario.pair.llm,
        )?
    };
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for d in 0..cfg.n_devices {
        let cfg = cfg.clone();
        let profile = profile.clone();
        let tx = tx_cloud.clone();
        handles.push(std::thread::Builder::new().name(format!("synera-dev{d}")).spawn(
            move || -> Result<DeviceStats> {
                device_worker(d as u32, &cfg, &profile, tx)
            },
        )?);
    }
    drop(tx_cloud);

    let mut all = DeviceStats::default();
    for h in handles {
        let s = h.join().map_err(|_| anyhow!("device thread panicked"))??;
        all.merge(s);
    }
    let wall = t0.elapsed().as_secs_f64();
    // all device senders are gone → the router loop exits and drops
    // the replica inboxes → each replica drains and returns its stats
    router.join().map_err(|_| anyhow!("router thread panicked"))?;
    let (mut swap_ins, mut swap_outs) = (0u64, 0u64);
    for h in cloud_handles {
        let s = h.join().map_err(|_| anyhow!("cloud thread panicked"))??;
        swap_ins += s.swap_ins;
        swap_outs += s.swap_outs;
    }

    // SLO fractions come from exact per-worker counters; percentiles
    // from the merged sketches (merge is exact — the roll-up equals
    // one sketch fed every worker's stream)
    let slo_ttft_frac =
        if all.slo_ttft_n > 0 { all.slo_ttft_ok as f64 / all.slo_ttft_n as f64 } else { 0.0 };
    let slo_tbt_frac =
        if all.slo_tbt_n > 0 { all.slo_tbt_ok as f64 / all.slo_tbt_n as f64 } else { 0.0 };
    Ok(ServeReport {
        completed: all.completed,
        wall_s: wall,
        throughput_rps: all.completed as f64 / wall,
        tokens_per_s: all.tokens as f64 / wall,
        e2e_latency: all.e2e.summary().unwrap_or_default(),
        verify_rtt: all.rtts.summary().unwrap_or_default(),
        ttft: all.ttfts.summary().unwrap_or_default(),
        slo_ttft_frac,
        slo_tbt_frac,
        ttft_burn: if all.slo_ttft_n == 0 { 0.0 } else { cfg.slo.burn(slo_ttft_frac) },
        tbt_burn: if all.slo_tbt_n == 0 { 0.0 } else { cfg.slo.burn(slo_tbt_frac) },
        quality: if all.completed > 0 { all.quality / all.completed as f64 } else { 0.0 },
        offload_rate: if all.chunks > 0 { all.offloads as f64 / all.chunks as f64 } else { 0.0 },
        swap_ins,
        swap_outs,
        replicas,
    })
}

/// Per-worker accumulators: latency distributions live in
/// [`QuantileSketch`]es (bounded memory per thread, exact cross-worker
/// merge) plus exact SLO counters — the serving tier no longer carries
/// one `Vec<f64>` per latency metric per device thread.
#[derive(Default)]
struct DeviceStats {
    completed: usize,
    tokens: usize,
    quality: f64,
    e2e: QuantileSketch,
    rtts: QuantileSketch,
    ttfts: QuantileSketch,
    /// Per-request mean time between tokens (≥2-token requests only).
    tbts: QuantileSketch,
    slo_ttft_ok: u64,
    slo_ttft_n: u64,
    slo_tbt_ok: u64,
    slo_tbt_n: u64,
    offloads: usize,
    chunks: usize,
}

impl DeviceStats {
    fn merge(&mut self, o: DeviceStats) {
        self.completed += o.completed;
        self.tokens += o.tokens;
        self.quality += o.quality;
        self.e2e.merge(&o.e2e);
        self.rtts.merge(&o.rtts);
        self.ttfts.merge(&o.ttfts);
        self.tbts.merge(&o.tbts);
        self.slo_ttft_ok += o.slo_ttft_ok;
        self.slo_ttft_n += o.slo_ttft_n;
        self.slo_tbt_ok += o.slo_tbt_ok;
        self.slo_tbt_n += o.slo_tbt_n;
        self.offloads += o.offloads;
        self.chunks += o.chunks;
    }
}

fn device_worker(
    device_id: u32,
    cfg: &ServeConfig,
    profile: &OffloadProfile,
    tx: Sender<ToCloud>,
) -> Result<DeviceStats> {
    let rt = Runtime::load(cfg.artifacts.clone())?;
    let scen = &cfg.scenario;
    let params = &scen.params;
    let dev = DeviceEngine::new(
        rt.model_variant(&scen.pair.slm, scen.pair.slm_weights.as_deref())?,
        params.early_exit,
    )?;
    // compile all device executables before taking requests
    let tags: Vec<&str> = if params.early_exit {
        vec!["chunk_b1_c32", "step_p1", "step_p2", "p2_c4"]
    } else {
        vec!["chunk_b1_c32", "step_full"]
    };
    dev.model.warmup(&tags)?;
    let mut link = SimLink::new(scen.link, 0x99 ^ device_id as u64);
    let mut selector = Selector::new(
        profile.c_th,
        profile.i_th_for_budget(params.budget),
        params.clone(),
    );
    let seq_exit =
        SeqExitPolicy::new(params.seq_exit_frac, params.max_new_tokens, params.early_exit);
    let mut rng = Rng::new(0xD0 + device_id as u64);
    let exit_th = params.exit_threshold as f32;
    let mut stats = DeviceStats::default();
    // same round-robin device→tenant map the replica frontends use
    let n_tenants = params.batch.tenant_weights.len().max(1);
    let pid = tenant_pid(device_id as usize % n_tenants);

    for r in 0..cfg.requests_per_device {
        let sample = crate::workload::synthlang::generate(
            cfg.task,
            1,
            (device_id as u64) * 1000 + r as u64,
        );
        let req_id = ((device_id as u64) << 32) | r as u64;
        let t_req = Instant::now();
        trace::with(&cfg.trace, |s| s.begin(pid, device_id, "request", req_id));
        let (mut sess, mut cur) = dev.prefill(&sample.prompt)?;
        let mut cloud_len = 0usize;
        let mut generated: Vec<u32> = Vec::new();
        let mut round: u32 = 0;
        let mut t_first: Option<Instant> = None;
        let mut t_last = t_req;

        'gen: while generated.len() < params.max_new_tokens {
            let start_len = sess.len;
            let mut draft = Vec::new();
            let mut confs = Vec::new();
            let mut probs_all = Vec::new();
            let mut hit_eos = false;
            for _ in 0..params.gamma.min(params.max_new_tokens - generated.len()) {
                let tok = argmax(&cur.probs) as u32;
                draft.push(tok);
                confs.push(cur.probs[tok as usize]);
                probs_all.push(cur.probs.clone());
                if tok == EOS {
                    hit_eos = true; // EOS rides to the verifier like any draft
                    break;
                }
                cur = dev.step(&mut sess, tok, params.early_exit, exit_th)?;
            }
            if draft.is_empty() {
                break;
            }
            let imps: Vec<f32> =
                (0..draft.len()).map(|j| sess.importance[start_len + j]).collect();
            stats.chunks += 1;
            let dec = selector.decide(&confs, &imps);
            if !(dec.offload && seq_exit.offload_allowed(generated.len())) {
                if cfg.trace.is_some() {
                    let args = vec![("gamma", draft.len() as f64)];
                    trace::with(&cfg.trace, |s| s.instant(pid, device_id, "local", req_id, args));
                }
                generated.extend_from_slice(&draft);
                let now = Instant::now();
                t_first.get_or_insert(now);
                t_last = now;
                if hit_eos {
                    break;
                }
                continue;
            }
            stats.offloads += 1;
            let ctx = TraceContext::for_round(req_id, round);
            round = round.wrapping_add(1);
            if cfg.trace.is_some() {
                let args = vec![
                    ("gamma", draft.len() as f64),
                    ("p_conf", dec.p_conf),
                    ("p_imp", dec.p_imp),
                    ("mean_conf", dec.mean_conf),
                    ("mean_imp", dec.mean_imp),
                    ("round", ctx.round as f64),
                ];
                trace::with(&cfg.trace, |s| {
                    s.instant(pid, device_id, "offload", req_id, args);
                    s.begin(pid, device_id, "round", req_id);
                    s.flow(pid, device_id, "offload", Ph::FlowStart, ctx.parent_span);
                    // the uplink span covers the simulated link delay;
                    // `synera inspect` reads it as this round's uplink
                    // network share
                    s.begin(pid, device_id, "uplink", req_id);
                });
            }

            let uncached = sess.tokens[cloud_len..start_len].to_vec();
            let dists = probs_all.iter().map(|p| compress_dist(p, 8)).collect::<Vec<_>>();
            let msg = UplinkMsg {
                request_id: req_id,
                device_id,
                ctx,
                uncached: uncached.clone(),
                draft: draft.clone(),
                dists,
                is_first: cloud_len == 0,
            };
            let up_delay = link.uplink_s(msg.wire_bytes());
            std::thread::sleep(Duration::from_secs_f64(up_delay));
            if cfg.trace.is_some() {
                trace::with(&cfg.trace, |s| s.end(pid, device_id, "uplink", req_id));
            }
            let (reply_tx, reply_rx) = channel();
            let t_sent = Instant::now();
            tx.send(ToCloud::Up(msg, reply_tx)).map_err(|_| anyhow!("cloud gone"))?;

            // ---- stall-free PI: speculate while the reply is in flight ----
            let mut spec = None;
            if params.parallel_inference {
                if let Some(r_star) = predict_rejection(profile.alpha, &confs, &mut rng) {
                    let alt = alternative_token(&probs_all[r_star], draft[r_star]);
                    let mut s2 = sess.snapshot();
                    s2.rewind(start_len + r_star);
                    let mut c2 = dev.step(&mut s2, alt, params.early_exit, exit_th)?;
                    let mut pi_tokens = vec![alt];
                    loop {
                        match reply_rx.try_recv() {
                            Ok(reply) => {
                                spec = Some((r_star, alt, s2, c2, pi_tokens, Some(reply)));
                                break;
                            }
                            Err(_) => {
                                if pi_tokens.len() >= 1 + params.delta {
                                    spec = Some((r_star, alt, s2, c2, pi_tokens, None));
                                    break;
                                }
                                let tok = argmax(&c2.probs) as u32;
                                if tok == EOS {
                                    spec = Some((r_star, alt, s2, c2, pi_tokens, None));
                                    break;
                                }
                                pi_tokens.push(tok);
                                c2 = dev.step(&mut s2, tok, params.early_exit, exit_th)?;
                            }
                        }
                    }
                }
            }
            let (reply, pi) = match spec {
                Some((r_star, alt, s2, c2, pi_tokens, Some(reply))) => {
                    (reply, Some((r_star, alt, s2, c2, pi_tokens)))
                }
                Some((r_star, alt, s2, c2, pi_tokens, None)) => {
                    let reply = reply_rx
                        .recv_timeout(Duration::from_secs(30))
                        .map_err(|_| anyhow!("verify timeout"))?;
                    (reply, Some((r_star, alt, s2, c2, pi_tokens)))
                }
                None => {
                    let reply = reply_rx
                        .recv_timeout(Duration::from_secs(30))
                        .map_err(|_| anyhow!("verify timeout"))?;
                    (reply, None)
                }
            };
            stats.rtts.record(t_sent.elapsed().as_secs_f64());
            let down = DownlinkMsg {
                request_id: req_id,
                accepted: reply.accepted,
                next_token: reply.next_token,
            };
            std::thread::sleep(Duration::from_secs_f64(link.downlink_s(down.wire_bytes())));

            let accepted = (reply.accepted as usize).min(draft.len());
            cloud_len = start_len + accepted;
            if cfg.trace.is_some() {
                let args = vec![("accepted", accepted as f64), ("round", ctx.round as f64)];
                trace::with(&cfg.trace, |s| {
                    // the arrow head binds (`bp:"e"`) to the still-open
                    // round slice
                    s.flow(pid, device_id, "offload", Ph::FlowEnd, ctx.parent_span);
                    s.end(pid, device_id, "round", req_id);
                    s.instant(pid, device_id, "device_commit", req_id, args);
                });
            }
            if hit_eos && accepted == draft.len() {
                generated.extend_from_slice(&draft);
                let now = Instant::now();
                t_first.get_or_insert(now);
                t_last = now;
                break 'gen; // verifier agreed with the drafted EOS
            }
            let mut adopted = false;
            if let Some((r_star, alt, s2, c2, pi_tokens)) = pi {
                if accepted == r_star && accepted < draft.len() {
                    let _ = alt; // position-match adoption (paper §4.4)
                    sess = s2;
                    cur = c2;
                    generated.extend(draft.iter().take(r_star));
                    generated.extend(pi_tokens.iter());
                    adopted = true;
                }
            }
            if !adopted {
                sess.rewind(start_len + accepted);
                generated.extend(draft.iter().take(accepted));
                if reply.next_token == EOS || generated.len() >= params.max_new_tokens {
                    if !generated.is_empty() {
                        let now = Instant::now();
                        t_first.get_or_insert(now);
                        t_last = now;
                    }
                    break 'gen;
                }
                cur = dev.step(&mut sess, reply.next_token, params.early_exit, exit_th)?;
                generated.push(reply.next_token);
            }
            if !generated.is_empty() {
                let now = Instant::now();
                t_first.get_or_insert(now);
                t_last = now;
            }
        }

        trace::with(&cfg.trace, |s| s.end(pid, device_id, "request", req_id));
        let _ = tx.send(ToCloud::Release(req_id));
        generated.truncate(params.max_new_tokens);
        if generated.last() == Some(&EOS) {
            generated.pop();
        }
        stats.tokens += generated.len();
        stats.quality += crate::metrics::quality::score_sample(&sample, &generated);
        let e2e = t_req.elapsed().as_secs_f64();
        stats.e2e.record(e2e);
        let mut slo_miss = false;
        if let Some(tf) = t_first {
            let ttft = tf.duration_since(t_req).as_secs_f64();
            stats.ttfts.record(ttft);
            stats.slo_ttft_n += 1;
            stats.slo_ttft_ok += (ttft <= cfg.slo.ttft_s) as u64;
            slo_miss |= ttft > cfg.slo.ttft_s;
            if generated.len() >= 2 {
                let span = t_last.duration_since(tf).as_secs_f64();
                let tbt = span / (generated.len() - 1) as f64;
                stats.tbts.record(tbt);
                stats.slo_tbt_n += 1;
                stats.slo_tbt_ok += (tbt <= cfg.slo.tbt_s) as u64;
                slo_miss |= tbt > cfg.slo.tbt_s;
            }
        } else {
            // no token ever committed: an SLO-relevant failure mode
            slo_miss = true;
        }
        // settle the request with the sampler (no-op without one):
        // SLO-missing and token-free requests are tail-interesting
        trace::with(&cfg.trace, |s| s.complete_request(req_id, e2e, slo_miss));
        stats.completed += 1;
    }
    Ok(stats)
}
