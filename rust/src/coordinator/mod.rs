//! The coordinator: end-to-end serving pipelines (Synera + baselines),
//! dataset evaluation drivers and the threaded real-time server.
//!
//! Experiments run the pipelines in **timeline mode**: engine calls
//! execute for real on the PJRT client and their *measured* compute
//! times — scaled by the device profile — advance per-actor clocks,
//! while network and queueing delays come from the simulated link and
//! the shared cloud clock. This yields deterministic, reproducible
//! latency/cost numbers on one CPU testbed (DESIGN.md §1). The
//! `examples/multi_device_serving.rs` driver instead runs the real
//! threaded server ([`serve`]) with actual queues and wall-clock time.

pub mod eval;
pub mod pipeline;
pub mod serve;

pub use eval::{eval_method, EvalOptions, MethodReport};
pub use pipeline::{CloudClock, Method, PipelineCtx, RequestReport};
