//! Dataset-level evaluation driver: runs a (method, pair, dataset)
//! combination over the held-out split and aggregates the paper's
//! metrics. Every table/figure bench builds on this.

use std::rc::Rc;

use anyhow::Result;

use crate::cloud::scheduler::Scheduler;
use crate::config::{Scenario, SyneraParams};
use crate::coordinator::pipeline::{
    run_request, CloudClock, Method, PipelineCtx, RequestReport,
};
use crate::metrics::cost::{CostModel, PackingFactors};
use crate::metrics::quality::score_sample;
use crate::metrics::stats::Summary;
use crate::model::cloud_engine::CloudEngine;
use crate::model::device_engine::DeviceEngine;
use crate::net::link::SimLink;
use crate::profiling::{load_or_profile, OffloadProfile};
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use crate::workload::synthlang::Task;
use crate::workload::trace::eval_set;

/// Evaluation options.
#[derive(Debug, Clone)]
pub struct EvalOptions {
    pub n_samples: usize,
    pub task: Task,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions { n_samples: 16, task: Task::Xsum }
    }
}

/// Aggregated result of one (method, pair, dataset) evaluation.
#[derive(Debug, Clone)]
pub struct MethodReport {
    pub method: Method,
    pub pair_label: String,
    pub task: Task,
    pub quality: f64,
    pub tbt_s: f64,
    pub latency: Summary,
    /// Paper cost `c = (1/Pf) × T × W`.
    pub cost: f64,
    pub w: f64,
    pub energy_per_token_j: f64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub stall_frac: f64,
    pub pi_hit_rate: f64,
    /// Paper §6.5's metric: rejection-*position* prediction hit rate.
    pub pi_pos_hit_rate: f64,
    pub exit_rate: f64,
    pub offload_rate: f64,
    pub mean_verify_rtt_s: f64,
    pub n: usize,
}

/// Restrict the parameterisation per method (baseline definitions, §6.1).
pub fn method_params(method: Method, base: &SyneraParams) -> SyneraParams {
    let mut p = base.clone();
    match method {
        Method::Synera => {}
        Method::Hybrid => {
            // token-level offloading by confidence threshold only,
            // vanilla pipeline
            p.use_imp = false;
            p.parallel_inference = false;
            p.early_exit = false;
            p.compression = false;
        }
        Method::EdgeFmLlm => {
            p.parallel_inference = false;
            p.early_exit = false;
        }
        Method::EdgeCentric => {
            p.early_exit = false; // plain local decoding (Table 5 baseline)
        }
        Method::CloudCentric => {}
    }
    p
}

/// Evaluate one method on one dataset under one scenario.
pub fn eval_method(
    rt: &Rc<Runtime>,
    scen: &Scenario,
    method: Method,
    opts: &EvalOptions,
) -> Result<MethodReport> {
    let mut scen = scen.clone();
    scen.params = method_params(method, &scen.params);

    let profile = if matches!(method, Method::CloudCentric) {
        OffloadProfile::synthetic() // unused on the pure-cloud path
    } else {
        load_or_profile(rt, &scen.pair.slm, scen.pair.slm_weights.as_deref(), &scen.pair.llm)?
    };
    eval_with_profile(rt, &scen, method, opts, &profile)
}

/// Same, with an externally supplied profile (sweeps reuse one profile).
pub fn eval_with_profile(
    rt: &Rc<Runtime>,
    scen: &Scenario,
    method: Method,
    opts: &EvalOptions,
    profile: &OffloadProfile,
) -> Result<MethodReport> {
    let split = scen.params.early_exit && !matches!(method, Method::CloudCentric);
    let dev = DeviceEngine::new(
        rt.model_variant(&scen.pair.slm, scen.pair.slm_weights.as_deref())?,
        split,
    )?;
    let mut sched = Scheduler::with_policy(
        CloudEngine::new(rt.model(&scen.pair.llm)?)?,
        scen.params.seed,
        scen.params.batch.clone(),
    );
    let mut link = SimLink::new(scen.link, scen.params.seed ^ 0x11);
    let mut clock = CloudClock::default();
    let mut rng = Rng::new(scen.params.seed ^ 0x77);

    let samples = eval_set(opts.task, opts.n_samples);

    // warmup: compile every executable + fill caches before measurement
    sched.engine.warmup()?;
    {
        let mut ctx = PipelineCtx {
            dev: &dev,
            sched: &mut sched,
            scen: &scen,
            profile,
            link: &mut link,
            cloud_clock: &mut clock,
            rng: &mut rng,
        };
        let _ = run_request(&mut ctx, method, &samples[0].prompt)?;
        clock.free_at = 0.0;
    }

    let mut reports: Vec<RequestReport> = Vec::with_capacity(samples.len());
    let mut quality_sum = 0.0;
    for s in &samples {
        let mut ctx = PipelineCtx {
            dev: &dev,
            sched: &mut sched,
            scen: &scen,
            profile,
            link: &mut link,
            cloud_clock: &mut clock,
            rng: &mut rng,
        };
        let rep = run_request(&mut ctx, method, &s.prompt)?;
        quality_sum += score_sample(s, &rep.generated);
        reports.push(rep);
        // requests are independent in these experiments: reset the queue
        clock.free_at = 0.0;
    }

    let n = reports.len();
    let gen_tokens: u64 = reports.iter().map(|r| r.generated.len() as u64).sum();
    let cloud_rows: u64 = reports.iter().map(|r| r.cloud_rows).sum();
    let total_s: f64 = reports.iter().map(|r| r.total_s).sum();
    let tbt = if gen_tokens > 0 { total_s / gen_tokens as f64 } else { 0.0 };
    let mut cost = CostModel::new(&scen.pair.llm);
    cost.cloud_tokens = cloud_rows;
    cost.generated_tokens = gen_tokens.max(1);
    cost.mean_tbt_s = tbt;

    let offloads: u32 = reports.iter().map(|r| r.offload_chunks).sum();
    let locals: u32 = reports.iter().map(|r| r.local_chunks).sum();
    let pi_h: u32 = reports.iter().map(|r| r.pi_hits).sum();
    let pi_p: u32 = reports.iter().map(|r| r.pi_pos_hits).sum();
    let pi_m: u32 = reports.iter().map(|r| r.pi_misses).sum();
    let exits: u32 = reports.iter().map(|r| r.exits).sum();
    let steps: u32 = reports.iter().map(|r| r.steps).sum();
    let stall: f64 = reports.iter().map(|r| r.stall_s).sum();
    let energy: f64 = reports.iter().map(|r| r.energy_j).sum();
    let rtts: Vec<f64> = reports.iter().flat_map(|r| r.verify_rtts.clone()).collect();

    Ok(MethodReport {
        method,
        pair_label: scen.pair.label(),
        task: opts.task,
        quality: quality_sum / n.max(1) as f64,
        tbt_s: tbt,
        latency: Summary::of(&reports.iter().map(|r| r.total_s).collect::<Vec<_>>()),
        cost: cost.cost(&PackingFactors::default()),
        w: cost.w(),
        energy_per_token_j: if gen_tokens > 0 { energy / gen_tokens as f64 } else { 0.0 },
        bytes_up: reports.iter().map(|r| r.bytes_up).sum(),
        bytes_down: reports.iter().map(|r| r.bytes_down).sum(),
        stall_frac: if total_s > 0.0 { stall / total_s } else { 0.0 },
        pi_hit_rate: if pi_h + pi_m > 0 { pi_h as f64 / (pi_h + pi_m) as f64 } else { 0.0 },
        pi_pos_hit_rate: if pi_h + pi_m > 0 {
            pi_p as f64 / (pi_h + pi_m) as f64
        } else {
            0.0
        },
        exit_rate: if steps > 0 { exits as f64 / steps as f64 } else { 0.0 },
        offload_rate: if offloads + locals > 0 {
            offloads as f64 / (offloads + locals) as f64
        } else {
            0.0
        },
        mean_verify_rtt_s: if rtts.is_empty() {
            0.0
        } else {
            rtts.iter().sum::<f64>() / rtts.len() as f64
        },
        n,
    })
}
