//! # Synera — synergistic device–cloud LLM serving
//!
//! Reproduction of *Synera: Synergistic LLM Serving across Device and
//! Cloud at Scale* (CS.DC 2025) as a three-layer Rust + JAX + Pallas
//! stack. This crate is Layer 3: the serving system. It loads the
//! AOT-compiled model executables from `artifacts/` (built once by
//! `make artifacts`; Python never runs on the request path) and
//! implements:
//!
//! * the **device runtime** — SLM draft loop with selective token-level
//!   offloading ([`device::offload`]), progressive early exit
//!   ([`device::early_exit`]), stall-free parallel inference
//!   ([`device::parallel`]) and top-k distribution compression
//!   ([`device::codec`]);
//! * the **cloud runtime** — a mixed continuous-batching scheduler
//!   ([`cloud::scheduler`], paper Algorithm 1 evolved Sarathi-style:
//!   prefill, verification and decode rows co-scheduled per iteration
//!   under a token budget with aging-based fairness) over a slot-based
//!   batch engine ([`model::cloud_engine`]) with chunked partial
//!   prefill, speculative verification ([`cloud::verifier`]) and
//!   paged-KV logical sessions ([`cloud::sessions`] over
//!   [`runtime::paging`]: concurrency bounded by host memory, not the
//!   compiled batch width);
//! * the **substrates** the paper's testbed provided: a bandwidth/RTT
//!   network simulator ([`net`]), the seven SynthLang datasets
//!   ([`workload`]), quality/latency/cost/energy metrics ([`metrics`]),
//!   the offline profiler ([`profiling`], paper §5) and all four
//!   baselines ([`baselines`]);
//! * the **fleet simulator** ([`sim`]) — a deterministic virtual-clock
//!   discrete-event harness that serves thousands of simulated devices
//!   through the real scheduler/session/offload code (with per-tenant
//!   weighted fair queueing, [`cloud::fairness`]) in seconds of wall
//!   time (`synera fleet`, `benches/fig19_fleet.rs`);
//! * the **observability layer** ([`obs`]) — request-lifecycle tracing
//!   (virtual- or wall-clock spans, Chrome-trace/JSONL export for
//!   Perfetto), a sampled metrics registry, and the leveled
//!   [`log!`](crate::log) macro.
//!
//! Entry points: the `synera` binary (`serve`, `generate`, `eval`,
//! `profile`), `examples/`, and one bench target per paper table/figure.

pub mod baselines;
pub mod bench;
pub mod cloud;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod profiling;
pub mod runtime;
pub mod sim;
pub mod testutil;
pub mod util;
pub mod workload;

/// Crate-wide result type (anyhow-based; PJRT errors convert via `?`).
pub type Result<T> = anyhow::Result<T>;
