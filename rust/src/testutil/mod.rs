//! Test support: a property-testing mini-framework (the offline mirror
//! carries no proptest — seeded splitmix64 case generation with
//! failing-seed reporting; re-run with `SYNERA_PROP_SEED=<seed>` to
//! reproduce a case) and [`MockBatchEngine`], a deterministic
//! artifact-free [`BatchEngine`] for scheduler tests.

use anyhow::{bail, Result};

use crate::model::cloud_engine::{BatchEngine, SlotChunk, SlotLogits, SlotOwner};
use crate::runtime::SlotKv;
use crate::util::rng::Rng;

/// Deterministic in-memory [`BatchEngine`] — no PJRT, no artifacts.
///
/// Logits are a pure function of (slot, position): the argmax of the
/// row following position `p` in slot `s` is `8 + (7p + 13s) mod
/// (V−8)`, so generations are reproducible, never emit control tokens
/// (EOS = 2 is unreachable) and differ across slots. Slot/chunk
/// validation mirrors [`crate::model::CloudEngine`]; `free_slot` on an
/// unowned slot panics, which turns slot double-frees into test
/// failures. Every `run_batch` item list is recorded in `calls` so
/// tests can assert the *shape* of scheduling (e.g. that one iteration
/// co-scheduled decode and prefill rows).
pub struct MockBatchEngine {
    pub slots: usize,
    pub chunk: usize,
    pub vocab: usize,
    pub max_len: usize,
    pub slot_len: Vec<usize>,
    pub slot_owner: Vec<Option<SlotOwner>>,
    /// Synthetic committed KV rows per slot ([`MOCK_KV_ROW`] floats per
    /// token, content a pure function of (token, position)) so paging
    /// swap-out/swap-in round trips can be asserted bit-identical.
    pub slot_k: Vec<Vec<f32>>,
    pub slot_v: Vec<Vec<f32>>,
    pub rows_executed: u64,
    /// Item lists of every `run_batch` call, in order.
    pub calls: Vec<Vec<SlotChunk>>,
    pub allocs: u64,
    pub frees: u64,
}

/// Floats per synthetic mock KV row (per K/V plane).
pub const MOCK_KV_ROW: usize = 4;

impl MockBatchEngine {
    pub fn new(slots: usize, chunk: usize, vocab: usize, max_len: usize) -> MockBatchEngine {
        assert!(vocab > 16, "mock vocab must clear the control-token range");
        MockBatchEngine {
            slots,
            chunk,
            vocab,
            max_len,
            slot_len: vec![0; slots],
            slot_owner: vec![None; slots],
            slot_k: vec![Vec::new(); slots],
            slot_v: vec![Vec::new(); slots],
            rows_executed: 0,
            calls: Vec::new(),
            allocs: 0,
            frees: 0,
        }
    }

    /// The deterministic argmax of the row following position `pos` in
    /// `slot` (tests predict generations with this).
    pub fn peak(&self, slot: usize, pos: usize) -> u32 {
        (8 + (pos * 7 + slot * 13) % (self.vocab - 8)) as u32
    }
}

impl BatchEngine for MockBatchEngine {
    fn slots(&self) -> usize {
        self.slots
    }

    fn chunk(&self) -> usize {
        self.chunk
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn max_len(&self) -> usize {
        self.max_len
    }

    fn slot_len(&self, slot: usize) -> usize {
        self.slot_len[slot]
    }

    fn rows_executed(&self) -> u64 {
        self.rows_executed
    }

    fn alloc_slot(&mut self, owner: SlotOwner) -> Option<usize> {
        let s = self.slot_owner.iter().position(|o| o.is_none())?;
        self.slot_owner[s] = Some(owner);
        self.slot_len[s] = 0;
        self.slot_k[s].clear();
        self.slot_v[s].clear();
        self.allocs += 1;
        Some(s)
    }

    fn free_slot(&mut self, slot: usize) {
        assert!(self.slot_owner[slot].is_some(), "double free of slot {slot}");
        self.slot_owner[slot] = None;
        self.slot_len[slot] = 0;
        self.slot_k[slot].clear();
        self.slot_v[slot].clear();
        self.frees += 1;
    }

    fn free_slots(&self) -> usize {
        self.slot_owner.iter().filter(|o| o.is_none()).count()
    }

    fn rollback(&mut self, slot: usize, len: usize) {
        assert!(len <= self.slot_len[slot], "rollback past committed length");
        self.slot_len[slot] = len;
        self.slot_k[slot].truncate(len * MOCK_KV_ROW);
        self.slot_v[slot].truncate(len * MOCK_KV_ROW);
    }

    fn kv_row_width(&self) -> usize {
        MOCK_KV_ROW
    }

    fn export_slot(&self, slot: usize) -> SlotKv {
        SlotKv {
            len: self.slot_len[slot],
            row: MOCK_KV_ROW,
            k: self.slot_k[slot].clone(),
            v: self.slot_v[slot].clone(),
        }
    }

    fn import_slot(&mut self, slot: usize, kv: &SlotKv) -> Result<()> {
        if slot >= self.slots || self.slot_owner[slot].is_none() {
            bail!("import into unclaimed slot {slot}");
        }
        if kv.len > self.max_len {
            bail!("imported {} rows exceed slot capacity {}", kv.len, self.max_len);
        }
        if kv.row != MOCK_KV_ROW || kv.k.len() != kv.len * MOCK_KV_ROW {
            bail!("malformed mock kv import");
        }
        self.slot_len[slot] = kv.len;
        self.slot_k[slot] = kv.k.clone();
        self.slot_v[slot] = kv.v.clone();
        Ok(())
    }

    fn run_batch(&mut self, items: &[SlotChunk]) -> Result<(Vec<SlotLogits>, f64)> {
        if items.is_empty() {
            return Ok((Vec::new(), 0.0));
        }
        let mut seen = vec![false; self.slots];
        for it in items {
            let s = it.slot;
            if s >= self.slots || seen[s] {
                bail!("bad/duplicate slot {s} in batch");
            }
            // stricter than the real engine: executing rows in an
            // unowned slot is always a scheduler bug (use-after-free)
            if self.slot_owner[s].is_none() {
                bail!("slot {s} is not allocated");
            }
            if it.tokens.is_empty() || it.tokens.len() > self.chunk {
                bail!("chunk size {} out of range 1..={}", it.tokens.len(), self.chunk);
            }
            if self.slot_len[s] + it.tokens.len() > self.max_len {
                bail!("slot {s} cache overflow");
            }
            seen[s] = true;
        }
        self.calls.push(items.to_vec());
        let v = self.vocab;
        let mut res = Vec::with_capacity(items.len());
        for it in items {
            let s = it.slot;
            let n = it.tokens.len();
            let base = self.slot_len[s];
            let mut rows = vec![0f32; n * v];
            for i in 0..n {
                rows[i * v + self.peak(s, base + i) as usize] = 1.0;
                // synthetic KV: a pure function of (token, position), so
                // paged swap round trips are checkable bit-for-bit
                let (pos, tok) = (base + i, it.tokens[i] as usize);
                for d in 0..MOCK_KV_ROW {
                    self.slot_k[s].push((tok * 31 + pos * 7 + d) as f32);
                    self.slot_v[s].push(-((tok * 17 + pos * 3 + d) as f32));
                }
            }
            self.slot_len[s] += n;
            self.rows_executed += n as u64;
            res.push(SlotLogits { slot: s, rows, n_rows: n });
        }
        Ok((res, 1e-5))
    }
}

/// Number of cases per property (override with `SYNERA_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("SYNERA_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `n` seeded cases. The closure gets a per-case RNG;
/// return `Err(reason)` (or panic) to fail. Prints the failing seed.
pub fn check<F>(name: &str, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let forced: Option<u64> = std::env::var("SYNERA_PROP_SEED").ok().and_then(|s| s.parse().ok());
    let n = if forced.is_some() { 1 } else { default_cases() };
    for i in 0..n {
        let seed = forced.unwrap_or(0x9E37_0000 + i * 0x1001);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on seed {seed:#x} (case {i}): {msg}\n\
                 reproduce with SYNERA_PROP_SEED={seed}"
            );
        }
    }
}

/// Uniform usize in [lo, hi].
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below((hi - lo + 1) as u64) as usize
}

/// Uniform f64 in [lo, hi).
pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    lo + rng.f64() * (hi - lo)
}

/// Random probability vector of length `n` (sums to 1).
pub fn prob_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..n).map(|_| (rng.f64() as f32).max(1e-6)).collect();
    let s: f32 = v.iter().sum();
    v.iter_mut().for_each(|x| *x /= s);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prob_vec_sums_to_one() {
        check("prob_vec normalised", |rng| {
            let n = usize_in(rng, 1, 64);
            let p = prob_vec(rng, n);
            let s: f32 = p.iter().sum();
            if (s - 1.0).abs() > 1e-4 {
                return Err(format!("sum {s}"));
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        check("always fails", |_| Err("nope".into()));
    }
}
