//! Property-testing mini-framework (the offline mirror carries no
//! proptest). Seeded random case generation over splitmix64 with
//! failing-seed reporting; on failure, re-run with
//! `SYNERA_PROP_SEED=<seed>` to reproduce the exact case.

use crate::util::rng::Rng;

/// Number of cases per property (override with `SYNERA_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("SYNERA_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `n` seeded cases. The closure gets a per-case RNG;
/// return `Err(reason)` (or panic) to fail. Prints the failing seed.
pub fn check<F>(name: &str, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let forced: Option<u64> = std::env::var("SYNERA_PROP_SEED").ok().and_then(|s| s.parse().ok());
    let n = if forced.is_some() { 1 } else { default_cases() };
    for i in 0..n {
        let seed = forced.unwrap_or(0x9E37_0000 + i * 0x1001);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on seed {seed:#x} (case {i}): {msg}\n\
                 reproduce with SYNERA_PROP_SEED={seed}"
            );
        }
    }
}

/// Uniform usize in [lo, hi].
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below((hi - lo + 1) as u64) as usize
}

/// Uniform f64 in [lo, hi).
pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    lo + rng.f64() * (hi - lo)
}

/// Random probability vector of length `n` (sums to 1).
pub fn prob_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..n).map(|_| (rng.f64() as f32).max(1e-6)).collect();
    let s: f32 = v.iter().sum();
    v.iter_mut().for_each(|x| *x /= s);
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prob_vec_sums_to_one() {
        check("prob_vec normalised", |rng| {
            let n = usize_in(rng, 1, 64);
            let p = prob_vec(rng, n);
            let s: f32 = p.iter().sum();
            if (s - 1.0).abs() > 1e-4 {
                return Err(format!("sum {s}"));
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        check("always fails", |_| Err("nope".into()));
    }
}
