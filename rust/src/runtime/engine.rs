//! Model loading + execution over the PJRT CPU client.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::kv::KvCache;
use super::meta::{artifacts_dir, ExecMeta, ModelMeta, ZooMeta};
use super::weights::read_weights;

/// Per-thread runtime: one PJRT client + the artifact inventory.
pub struct Runtime {
    client: xla::PjRtClient,
    pub dir: PathBuf,
    pub meta: ZooMeta,
    models: RefCell<BTreeMap<String, Rc<Model>>>,
}

impl Runtime {
    pub fn load(dir: PathBuf) -> Result<Rc<Runtime>> {
        let meta = ZooMeta::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Rc::new(Runtime { client, dir, meta, models: RefCell::new(BTreeMap::new()) }))
    }

    /// Load from `$SYNERA_ARTIFACTS` or the nearest `artifacts/` ancestor.
    pub fn load_default() -> Result<Rc<Runtime>> {
        Self::load(artifacts_dir())
    }

    /// Get (and cache) a model with its default weights.
    pub fn model(self: &Rc<Self>, name: &str) -> Result<Rc<Model>> {
        self.model_variant(name, None)
    }

    /// Get a model with an alternate weight file (quantized variants, e.g.
    /// `model_variant("s7b", Some("s7b_bnb4"))`).
    pub fn model_variant(self: &Rc<Self>, name: &str, weights: Option<&str>) -> Result<Rc<Model>> {
        let key = match weights {
            Some(w) => format!("{name}@{w}"),
            None => name.to_string(),
        };
        if let Some(m) = self.models.borrow().get(&key) {
            return Ok(m.clone());
        }
        let meta = self.meta.model(name)?.clone();
        let wfile = match weights {
            Some(w) => format!("{w}.weights.bin"),
            None => meta.weights_file.clone(),
        };
        let model = Rc::new(Model::load(self, meta, &wfile)?);
        self.models.borrow_mut().insert(key, model.clone());
        Ok(model)
    }
}

/// Outputs of one executable call (see `aot.py` ABI).
#[derive(Debug, Clone)]
pub struct ExecOut {
    /// `[B, C, V]` logits — for part-1 executables these are the *exit*
    /// logits (shared head applied at the split layer).
    pub logits: Vec<f32>,
    /// `[B, C, D]` hidden states (part-1 executables only).
    pub hidden: Option<Vec<f32>>,
    /// `[B, M]` fused importance scores (mean over executed layers).
    pub importance: Vec<f32>,
}

struct LoadedExec {
    spec: ExecMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// One model: device-resident weights + lazily compiled executables.
pub struct Model {
    pub meta: ModelMeta,
    client: xla::PjRtClient,
    dir: PathBuf,
    weights: Vec<xla::PjRtBuffer>,
    execs: RefCell<BTreeMap<String, Rc<LoadedExec>>>,
    /// Cumulative PJRT execution count (perf accounting).
    pub calls: std::cell::Cell<u64>,
}

impl Model {
    fn load(rt: &Runtime, meta: ModelMeta, weights_file: &str) -> Result<Model> {
        let wpath = rt.dir.join(weights_file);
        let tensors = read_weights(&wpath)?;
        let mut weights = Vec::with_capacity(tensors.len());
        for t in &tensors {
            weights.push(
                rt.client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                    .with_context(|| format!("uploading {}", t.name))?,
            );
        }
        Ok(Model {
            meta,
            client: rt.client.clone(),
            dir: rt.dir.clone(),
            weights,
            execs: RefCell::new(BTreeMap::new()),
            calls: std::cell::Cell::new(0),
        })
    }

    fn exec(&self, tag: &str) -> Result<Rc<LoadedExec>> {
        if let Some(e) = self.execs.borrow().get(tag) {
            return Ok(e.clone());
        }
        let spec = self.meta.exec(tag)?.clone();
        let path = self.dir.join(format!("{}_{}.hlo.txt", self.meta.name, tag));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let le = Rc::new(LoadedExec { spec, exe });
        self.execs.borrow_mut().insert(tag.to_string(), le.clone());
        Ok(le)
    }

    /// Eagerly compile a set of executables (so first-token latency in
    /// experiments isn't a compile).
    pub fn warmup(&self, tags: &[&str]) -> Result<()> {
        for t in tags {
            self.exec(t)?;
        }
        Ok(())
    }

    /// Token-input executables (`chunk_*`, `step_full`, `step_p1`).
    ///
    /// `tokens`: `[B*C]` row-major; `pos_base`/`n_valid`: `[B]`;
    /// `kv` shape must match the executable's layer range.
    pub fn run_chunk(
        &self,
        tag: &str,
        tokens: &[i32],
        pos_base: &[i32],
        n_valid: &[i32],
        kv: &mut KvCache,
    ) -> Result<ExecOut> {
        let e = self.exec(tag)?;
        if e.spec.part2 {
            bail!("{tag} takes hidden states, not tokens");
        }
        let (b, c) = (e.spec.b, e.spec.c);
        if tokens.len() != b * c || pos_base.len() != b || n_valid.len() != b {
            bail!(
                "{tag}: arg shapes tokens={} pos={} nv={} (want {}x{})",
                tokens.len(), pos_base.len(), n_valid.len(), b, c
            );
        }
        let tok_buf = self.client.buffer_from_host_buffer::<i32>(tokens, &[b, c], None)?;
        self.dispatch(&e, tok_buf, pos_base, n_valid, kv)
    }

    /// Hidden-state-input executables (`step_p2`, `p2_c4`).
    pub fn run_hidden(
        &self,
        tag: &str,
        hidden: &[f32],
        pos_base: &[i32],
        n_valid: &[i32],
        kv: &mut KvCache,
    ) -> Result<ExecOut> {
        let e = self.exec(tag)?;
        if !e.spec.part2 {
            bail!("{tag} takes tokens, not hidden states");
        }
        let (b, c, d) = (e.spec.b, e.spec.c, self.meta.d_model);
        if hidden.len() != b * c * d {
            bail!("{tag}: hidden len {} != {}x{}x{}", hidden.len(), b, c, d);
        }
        let hbuf = self.client.buffer_from_host_buffer::<f32>(hidden, &[b, c, d], None)?;
        self.dispatch(&e, hbuf, pos_base, n_valid, kv)
    }

    fn dispatch(
        &self,
        e: &LoadedExec,
        first: xla::PjRtBuffer,
        pos_base: &[i32],
        n_valid: &[i32],
        kv: &mut KvCache,
    ) -> Result<ExecOut> {
        let spec = &e.spec;
        let (b, c) = (spec.b, spec.c);
        let lp = spec.hi - spec.lo;
        let m = self.meta.max_len;
        let (h, dh) = (self.meta.n_heads, self.meta.d_head);
        if kv.shape != [lp, b, m, h, dh] {
            bail!(
                "{}: kv shape {:?} != expected {:?}",
                spec.tag, kv.shape, [lp, b, m, h, dh]
            );
        }
        let kv_dims = [lp, b, m, h, dh];
        let pos_buf = self.client.buffer_from_host_buffer::<i32>(pos_base, &[b], None)?;
        let nv_buf = self.client.buffer_from_host_buffer::<i32>(n_valid, &[b], None)?;
        let kk = self.client.buffer_from_host_buffer::<f32>(&kv.k, &kv_dims, None)?;
        let vv = self.client.buffer_from_host_buffer::<f32>(&kv.v, &kv_dims, None)?;

        let mut args: Vec<&xla::PjRtBuffer> = vec![&first, &pos_buf, &nv_buf, &kk, &vv];
        args.extend(self.weights.iter());

        let out = e.exe.execute_b(&args)?;
        self.calls.set(self.calls.get() + 1);
        let mut lit = out[0][0].to_literal_sync()?;
        let parts = lit.decompose_tuple()?;

        let expected = if spec.exit_logits { 5 } else { 4 };
        if parts.len() != expected {
            bail!("{}: got {} outputs, want {expected}", spec.tag, parts.len());
        }
        let d = self.meta.d_model;
        let v = self.meta.vocab;
        let (hidden, logits, kv_at) = if spec.exit_logits {
            let mut hid = vec![0f32; b * c * d];
            parts[0].copy_raw_to(&mut hid)?;
            let mut lg = vec![0f32; b * c * v];
            parts[1].copy_raw_to(&mut lg)?;
            (Some(hid), lg, 2)
        } else {
            let mut lg = vec![0f32; b * c * v];
            parts[0].copy_raw_to(&mut lg)?;
            (None, lg, 1)
        };
        parts[kv_at].copy_raw_to(&mut kv.k)?;
        parts[kv_at + 1].copy_raw_to(&mut kv.v)?;
        let mut importance = vec![0f32; b * m];
        parts[kv_at + 2].copy_raw_to(&mut importance)?;
        Ok(ExecOut { logits, hidden, importance })
    }
}
