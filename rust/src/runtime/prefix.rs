//! Shared-prefix KV cache: content-hashed block identity plus a
//! radix-style prefix index over the [`BlockPool`].
//!
//! Fleet workloads overwhelmingly share system prompts and few-shot
//! preambles, but plain paging (PR 2) stores every session's KV
//! privately — the same preamble is prefilled and resident once *per
//! session*. This module gives pool blocks a **content identity**: the
//! chain hash of all token ids from position 0 through the end of the
//! block (a rolling FNV-1a seeded by the covering prefix's hash). Two
//! blocks with the same chain hash cover the same token sequence from
//! the same starting context, so their KV rows are interchangeable and
//! one physical block can serve every session that shares the prefix.
//!
//! The [`PrefixIndex`] is the radix structure over those identities:
//! each entry points at its parent entry (the chain hash of the prefix
//! one block shorter), so matching an incoming prompt is a walk from
//! the root taking one full block per step. **Only full blocks are
//! indexable** — a partially filled block has no stable identity yet —
//! which makes "radix matching never matches a partial block" true by
//! construction. Hash collisions are handled safely, not assumed away:
//! a match requires the stored token ids and parent to compare equal,
//! and an insert that collides with a different chain is skipped.
//!
//! Ownership: the index holds exactly one pool reference per entry
//! (taken via [`BlockPool::share`] at insert, dropped via
//! [`BlockPool::unref`] at trim), in addition to whatever references
//! matching sessions hold. A shared block is therefore reclaimed only
//! after the index *and* every session drop it — refcount 0 — and
//! mutation of shared rows goes through [`BlockPool::cow`]. Under
//! memory pressure [`PrefixIndex::trim`] evicts leaf entries in
//! least-recently-hit order (deterministic: ties break on hash), so
//! interior entries — prefixes other cached chains extend — are never
//! orphaned.

use std::collections::HashMap;

use crate::runtime::paging::BlockPool;

/// Chain hash of the empty prefix (the radix root).
pub const ROOT: u64 = 0xcbf2_9ce4_8422_2325;

/// Rolling content hash of one full block given the chain hash of the
/// prefix it extends: FNV-1a over the token-id bytes, seeded by
/// `parent`. Identity covers the whole chain — the same token ids
/// after a *different* prefix hash differently.
pub fn chain_hash(parent: u64, tokens: &[u32]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h = parent;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    }
    h
}

/// One matched full block: its chain hash and the pool block that
/// holds its KV rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixHit {
    pub hash: u64,
    pub block: usize,
}

/// Outcome of offering a block to the index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Inserted {
    /// Entry created; the index now holds one reference on `block`.
    New(u64),
    /// An equivalent chain entry already exists — the caller should
    /// dedup onto `block` (drop its own copy, share this one).
    Existing { hash: u64, block: usize },
    /// Hash collision with a different chain, or the parent entry was
    /// trimmed; the block stays private and unindexed.
    Skipped,
}

/// Prefix-cache counters, surfaced as `paging.prefix_*` gauges.
#[derive(Debug, Default, Clone, Copy)]
pub struct PrefixStats {
    /// Admissions that matched ≥ 1 full block.
    pub hits: u64,
    /// Admissions (with the cache enabled) that matched nothing.
    pub misses: u64,
    /// Total prompt rows covered by matched shared blocks.
    pub hit_rows: u64,
    /// Shared blocks privatised by copy-on-write.
    pub cow_copies: u64,
}

struct Entry {
    /// Chain hash of the covering prefix ([`ROOT`] for the first block).
    parent: u64,
    /// Exact token ids this block covers (collision guard).
    tokens: Vec<u32>,
    /// Pool block holding the KV rows.
    block: usize,
    /// Logical clock of the last match (LRU trim order).
    last_hit: u64,
    /// Live child entries; only leaves (0) are trimmable.
    children: u32,
}

/// Radix-style index from chain hash → shared pool block.
pub struct PrefixIndex {
    entries: HashMap<u64, Entry>,
    block_tokens: usize,
    clock: u64,
}

impl PrefixIndex {
    pub fn new(block_tokens: usize) -> PrefixIndex {
        assert!(block_tokens > 0, "degenerate block geometry");
        PrefixIndex { entries: HashMap::new(), block_tokens, clock: 0 }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Indexed entries (== pool references held by the index).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Walk the radix chain over `prompt`, matching one **full** block
    /// per step, never past `max_rows`. Returns the matched blocks in
    /// prefix order; the caller takes its own pool reference on each
    /// (`share`) before using them. Matched entries are touched for
    /// LRU purposes.
    pub fn match_prefix(&mut self, prompt: &[u32], max_rows: usize) -> Vec<PrefixHit> {
        let bt = self.block_tokens;
        let cap = max_rows.min(prompt.len());
        let mut hits = Vec::new();
        let mut parent = ROOT;
        let mut off = 0;
        while off + bt <= cap {
            let want = &prompt[off..off + bt];
            let h = chain_hash(parent, want);
            match self.entries.get_mut(&h) {
                Some(e) if e.parent == parent && e.tokens == want => {
                    self.clock += 1;
                    e.last_hit = self.clock;
                    hits.push(PrefixHit { hash: h, block: e.block });
                    parent = h;
                    off += bt;
                }
                _ => break,
            }
        }
        hits
    }

    /// Offer one full block (covering `tokens`, extending the chain at
    /// `parent`) to the index. On [`Inserted::New`] the index takes its
    /// own reference on `block`; on [`Inserted::Existing`] the caller
    /// should switch to the returned block and drop its own copy.
    pub fn insert(
        &mut self,
        parent: u64,
        tokens: &[u32],
        block: usize,
        pool: &mut BlockPool,
    ) -> Inserted {
        assert_eq!(tokens.len(), self.block_tokens, "only full blocks are indexable");
        let h = chain_hash(parent, tokens);
        if let Some(e) = self.entries.get_mut(&h) {
            return if e.parent == parent && e.tokens == tokens {
                self.clock += 1;
                e.last_hit = self.clock;
                Inserted::Existing { hash: h, block: e.block }
            } else {
                Inserted::Skipped
            };
        }
        if parent != ROOT {
            // chain integrity: never index a block whose covering
            // prefix is not itself indexed (it could never be matched)
            match self.entries.get_mut(&parent) {
                Some(p) => p.children += 1,
                None => return Inserted::Skipped,
            }
        }
        pool.share(block);
        self.clock += 1;
        self.entries
            .insert(h, Entry { parent, tokens: tokens.to_vec(), block, last_hit: self.clock, children: 0 });
        Inserted::New(h)
    }

    /// Evict leaf entries (least-recently-hit first, hash-tie-broken —
    /// deterministic regardless of map iteration order) until the pool
    /// has `need` free blocks or nothing droppable remains. Dropping an
    /// entry releases only the *index's* reference; blocks still held
    /// by sessions stay live and simply stop matching new admissions.
    pub fn trim(&mut self, pool: &mut BlockPool, need: usize) {
        while pool.free_blocks() < need && !self.entries.is_empty() {
            let mut leaves: Vec<(u64, u64)> = self
                .entries
                .iter()
                .filter(|(_, e)| e.children == 0)
                .map(|(&h, e)| (e.last_hit, h))
                .collect();
            if leaves.is_empty() {
                return;
            }
            leaves.sort_unstable();
            for (_, h) in leaves {
                if pool.free_blocks() >= need {
                    return;
                }
                let e = self.entries.remove(&h).expect("leaf collected this round");
                if e.parent != ROOT {
                    if let Some(p) = self.entries.get_mut(&e.parent) {
                        p.children -= 1;
                    }
                }
                pool.unref(e.block);
            }
        }
    }

    /// Drop every entry, releasing the index's pool references.
    pub fn clear(&mut self, pool: &mut BlockPool) {
        for (_, e) in self.entries.drain() {
            pool.unref(e.block);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::paging::SlotKv;

    fn kv(len: usize, row: usize, salt: f32) -> SlotKv {
        SlotKv {
            len,
            row,
            k: (0..len * row).map(|i| i as f32 + salt).collect(),
            v: (0..len * row).map(|i| -(i as f32) - salt).collect(),
        }
    }

    #[test]
    fn chain_hash_depends_on_parent_and_tokens() {
        let a = chain_hash(ROOT, &[1, 2, 3, 4]);
        let b = chain_hash(ROOT, &[1, 2, 3, 5]);
        let c = chain_hash(a, &[1, 2, 3, 4]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, chain_hash(ROOT, &[1, 2, 3, 4]));
    }

    #[test]
    fn insert_then_match_walks_the_chain() {
        let mut pool = BlockPool::new(8, 4, 2);
        let mut idx = PrefixIndex::new(4);
        let prompt: Vec<u32> = (10..20).collect(); // 2 full blocks + 2 spare
        let t = pool.store(&kv(8, 2, 0.0)).unwrap();
        let h0 = match idx.insert(ROOT, &prompt[0..4], t.blocks[0], &mut pool) {
            Inserted::New(h) => h,
            other => panic!("expected New, got {other:?}"),
        };
        assert!(matches!(
            idx.insert(h0, &prompt[4..8], t.blocks[1], &mut pool),
            Inserted::New(_)
        ));
        let hits = idx.match_prefix(&prompt, prompt.len());
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].block, t.blocks[0]);
        assert_eq!(hits[1].block, t.blocks[1]);
        // a diverging prompt matches only the common prefix
        let mut other = prompt.clone();
        other[5] = 999;
        assert_eq!(idx.match_prefix(&other, other.len()).len(), 1);
        // index holds one ref per entry on top of the table's
        assert_eq!(pool.ref_count(t.blocks[0]), 2);
        idx.clear(&mut pool);
        pool.release(t);
        assert_eq!(pool.free_blocks(), 8);
    }

    #[test]
    fn partial_blocks_never_match() {
        let mut pool = BlockPool::new(8, 4, 2);
        let mut idx = PrefixIndex::new(4);
        let prompt: Vec<u32> = (10..18).collect();
        let t = pool.store(&kv(8, 2, 0.0)).unwrap();
        idx.insert(ROOT, &prompt[0..4], t.blocks[0], &mut pool);
        // max_rows caps below a full second step — and a 7-token probe
        // can cover only one full block
        assert_eq!(idx.match_prefix(&prompt, 7).len(), 1);
        assert_eq!(idx.match_prefix(&prompt[..7], prompt.len()).len(), 1);
        assert_eq!(idx.match_prefix(&prompt[..3], prompt.len()).len(), 0);
        idx.clear(&mut pool);
        pool.release(t);
    }

    #[test]
    fn existing_entry_dedups_instead_of_duplicating() {
        let mut pool = BlockPool::new(8, 4, 2);
        let mut idx = PrefixIndex::new(4);
        let toks: Vec<u32> = (1..5).collect();
        let a = pool.store(&kv(4, 2, 0.0)).unwrap();
        let b = pool.store(&kv(4, 2, 0.0)).unwrap();
        let h = match idx.insert(ROOT, &toks, a.blocks[0], &mut pool) {
            Inserted::New(h) => h,
            other => panic!("expected New, got {other:?}"),
        };
        match idx.insert(ROOT, &toks, b.blocks[0], &mut pool) {
            Inserted::Existing { hash, block } => {
                assert_eq!(hash, h);
                assert_eq!(block, a.blocks[0]);
            }
            other => panic!("expected Existing, got {other:?}"),
        }
        // no reference was taken on b's block
        assert_eq!(pool.ref_count(b.blocks[0]), 1);
        idx.clear(&mut pool);
        pool.release(a);
        pool.release(b);
    }

    #[test]
    fn trim_drops_lru_leaves_first_and_never_interior_entries() {
        let mut pool = BlockPool::new(4, 2, 2);
        let mut idx = PrefixIndex::new(2);
        // chain A: two blocks; chain B: one block → pool full (refs
        // held by the index only once tables are released)
        let a = pool.store(&kv(4, 2, 0.0)).unwrap();
        let b = pool.store(&kv(2, 2, 9.0)).unwrap();
        let ha = match idx.insert(ROOT, &[1, 2], a.blocks[0], &mut pool) {
            Inserted::New(h) => h,
            other => panic!("{other:?}"),
        };
        idx.insert(ha, &[3, 4], a.blocks[1], &mut pool);
        idx.insert(ROOT, &[7, 8], b.blocks[0], &mut pool);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.free_blocks(), 1);
        // touch chain B so chain A's leaf is the LRU
        idx.match_prefix(&[7, 8], 2);
        idx.trim(&mut pool, 2);
        assert_eq!(pool.free_blocks(), 2);
        // the interior entry (ha) must have survived its leaf; chain B intact
        assert_eq!(idx.match_prefix(&[1, 2, 3, 4], 4).len(), 1);
        assert_eq!(idx.match_prefix(&[7, 8], 2).len(), 1);
        // asking for everything drops the whole index
        idx.trim(&mut pool, 4);
        assert!(idx.is_empty());
        assert_eq!(pool.free_blocks(), 4);
    }
}
