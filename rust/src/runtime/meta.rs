//! `artifacts/meta.json` schema (written by `python/compile/aot.py`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One exported executable of a model.
#[derive(Debug, Clone)]
pub struct ExecMeta {
    pub tag: String,
    /// Batch slots.
    pub b: usize,
    /// Chunk length (query tokens per call).
    pub c: usize,
    /// Layer range [lo, hi).
    pub lo: usize,
    pub hi: usize,
    /// Takes hidden states instead of token ids (early-exit part 2).
    pub part2: bool,
    /// Additionally returns exit logits (early-exit part 1).
    pub exit_logits: bool,
}

/// Model dimensions + executable inventory.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_len: usize,
    pub split_layer: usize,
    pub role: String,
    pub weights_file: String,
    pub execs: Vec<ExecMeta>,
}

impl ModelMeta {
    pub fn exec(&self, tag: &str) -> Result<&ExecMeta> {
        self.execs
            .iter()
            .find(|e| e.tag == tag)
            .with_context(|| format!("model {} has no executable {tag:?}", self.name))
    }

    /// Host-side parameter count (for the cost model / reports).
    pub fn param_count(&self) -> usize {
        let (d, l, f, v) = (self.d_model, self.n_layers, self.d_ff, self.vocab);
        v * d + l * (4 * d * d + 3 * d * f + 2 * d) + d
    }
}

/// The whole artifact bundle.
#[derive(Debug, Clone)]
pub struct ZooMeta {
    pub fingerprint: String,
    pub chunk: usize,
    pub cloud_slots: usize,
    pub gamma: usize,
    pub vocab: usize,
    pub models: BTreeMap<String, ModelMeta>,
}

impl ZooMeta {
    pub fn load(dir: &Path) -> Result<ZooMeta> {
        let path = dir.join("meta.json");
        if !path.exists() {
            bail!(
                "artifacts not built: {} missing — run `make artifacts`",
                path.display()
            );
        }
        let j = Json::parse_file(&path)?;
        let mut models = BTreeMap::new();
        for (name, m) in j.get("models")?.as_obj()? {
            let cfg = m.get("config")?;
            let mut execs = Vec::new();
            for e in m.get("execs")?.as_arr()? {
                execs.push(ExecMeta {
                    tag: e.get("tag")?.as_str()?.to_string(),
                    b: e.get("b")?.as_usize()?,
                    c: e.get("c")?.as_usize()?,
                    lo: e.get("lo")?.as_usize()?,
                    hi: e.get("hi")?.as_usize()?,
                    part2: e.get("part2")?.as_bool()?,
                    exit_logits: e.get("exit_logits")?.as_bool()?,
                });
            }
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    vocab: cfg.get("vocab")?.as_usize()?,
                    d_model: cfg.get("d_model")?.as_usize()?,
                    n_layers: cfg.get("n_layers")?.as_usize()?,
                    n_heads: cfg.get("n_heads")?.as_usize()?,
                    d_head: cfg.get("d_head")?.as_usize()?,
                    d_ff: cfg.get("d_ff")?.as_usize()?,
                    max_len: cfg.get("max_len")?.as_usize()?,
                    split_layer: cfg.get("split_layer")?.as_usize()?,
                    role: m.get("role")?.as_str()?.to_string(),
                    weights_file: m.get("weights")?.as_str()?.to_string(),
                    execs,
                },
            );
        }
        Ok(ZooMeta {
            fingerprint: j.get("fingerprint")?.as_str()?.to_string(),
            chunk: j.get("chunk")?.as_usize()?,
            cloud_slots: j.get("cloud_slots")?.as_usize()?,
            gamma: j.get("gamma")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .with_context(|| format!("unknown model {name:?} (have: {:?})",
                self.models.keys().collect::<Vec<_>>()))
    }
}

/// Default artifacts directory: `$SYNERA_ARTIFACTS` or `./artifacts`
/// (walking up from the current dir so tests/benches work from any cwd).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("SYNERA_ARTIFACTS") {
        return p.into();
    }
    let mut d = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = d.join("artifacts");
        if cand.join("meta.json").exists() {
            return cand;
        }
        if !d.pop() {
            return "artifacts".into();
        }
    }
}
