//! `*.weights.bin` reader — format written by `aot.write_weights`:
//! `b"SYNW1\n"`, u32-le header length, JSON header
//! `{"tensors": [{name, shape, offset}...], "total_bytes"}`, raw f32 payload.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// A named host tensor (row-major f32).
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

pub const MAGIC: &[u8] = b"SYNW1\n";

/// Read all tensors from a weight binary, in file (= `WEIGHT_ORDER`) order.
pub fn read_weights(path: &Path) -> Result<Vec<HostTensor>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() < MAGIC.len() + 4 || &bytes[..MAGIC.len()] != MAGIC {
        bail!("{}: bad magic (not a SYNW1 weight file)", path.display());
    }
    let hlen_off = MAGIC.len();
    let hlen = u32::from_le_bytes(bytes[hlen_off..hlen_off + 4].try_into().unwrap()) as usize;
    let hstart = hlen_off + 4;
    let header = std::str::from_utf8(&bytes[hstart..hstart + hlen])?;
    let j = Json::parse(header)?;
    let payload = &bytes[hstart + hlen..];
    let total = j.get("total_bytes")?.as_usize()?;
    if payload.len() != total {
        bail!("{}: payload {} != header total {}", path.display(), payload.len(), total);
    }

    let mut out = Vec::new();
    for t in j.get("tensors")?.as_arr()? {
        let name = t.get("name")?.as_str()?.to_string();
        let shape = t.get("shape")?.usize_arr()?;
        let offset = t.get("offset")?.as_usize()?;
        let numel: usize = shape.iter().product();
        let end = offset + numel * 4;
        if end > payload.len() {
            bail!("{}: tensor {name} overruns payload", path.display());
        }
        let mut data = vec![0f32; numel];
        // payload is little-endian f32; this target is little-endian
        let src = &payload[offset..end];
        for (i, chunk) in src.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        out.push(HostTensor { name, shape, data });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_test_file(path: &Path, tensors: &[(&str, Vec<usize>, Vec<f32>)]) {
        let mut headers = Vec::new();
        let mut payload: Vec<u8> = Vec::new();
        for (name, shape, data) in tensors {
            let offset = payload.len();
            for x in data {
                payload.extend_from_slice(&x.to_le_bytes());
            }
            let dims = shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",");
            headers.push(format!(
                r#"{{"name":"{name}","shape":[{dims}],"offset":{offset}}}"#
            ));
        }
        let header = format!(
            r#"{{"tensors":[{}],"total_bytes":{}}}"#,
            headers.join(","),
            payload.len()
        );
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(MAGIC).unwrap();
        f.write_all(&(header.len() as u32).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        f.write_all(&payload).unwrap();
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("synera_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        write_test_file(
            &path,
            &[
                ("a", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
                ("b", vec![3], vec![-1.0, 0.5, 9.0]),
            ],
        );
        let ts = read_weights(&path).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "a");
        assert_eq!(ts[0].shape, vec![2, 2]);
        assert_eq!(ts[0].data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ts[1].data, vec![-1.0, 0.5, 9.0]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("synera_wtest2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTAWEIGHTFILE....").unwrap();
        assert!(read_weights(&path).is_err());
    }
}
