//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `*.weights.bin` + `meta.json`) and executes them on the CPU PJRT
//! client via the `xla` crate.
//!
//! Conventions (must match `python/compile/aot.py`):
//! * interchange is HLO **text** (xla_extension 0.5.1 rejects jax≥0.5
//!   serialized protos — 64-bit instruction ids);
//! * executables are lowered with `return_tuple=True`, so each run
//!   returns ONE tuple buffer which we decompose into
//!   `(logits|hidden, [exit_logits,] kv_k, kv_v, importance)`;
//! * weights are uploaded to device buffers once per model and reused
//!   (`execute_b`); KV caches live host-side in [`KvCache`] and ride in
//!   per call (pure memcpy on the CPU plugin, ~µs at our sizes — see
//!   EXPERIMENTS.md §Perf).
//!
//! PJRT objects are `Rc`-based (thread-confined): every thread that
//! executes models owns its own [`Runtime`].

pub mod engine;
pub mod kv;
pub mod meta;
pub mod paging;
pub mod prefix;
pub mod weights;

pub use engine::{ExecOut, Model, Runtime};
pub use kv::KvCache;
pub use meta::{artifacts_dir, ExecMeta, ModelMeta, ZooMeta};
pub use paging::{BlockPool, BlockTable, SlotKv};
pub use prefix::{PrefixIndex, PrefixStats};
