//! Host-side KV cache state: a `[L, B, M, H, Dh]` f32 block per K and V.
//!
//! The cache rides into every executable call and comes back updated.
//! Because attention masks by position (`pos <= pos_base+i`), *rollback*
//! after mispredicted speculative work is just rewinding the logical
//! length — stale slots beyond it are never attended to. Splitting at the
//! early-exit layer is a contiguous copy (layer-major layout).

use crate::runtime::paging::SlotKv;

/// Mutable KV state for one executable family (a layer range).
#[derive(Debug, Clone)]
pub struct KvCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// `[layers, slots, max_len, heads, d_head]`
    pub shape: [usize; 5],
}

impl KvCache {
    pub fn new(layers: usize, slots: usize, max_len: usize, heads: usize, d_head: usize) -> Self {
        let n = layers * slots * max_len * heads * d_head;
        KvCache { k: vec![0.0; n], v: vec![0.0; n], shape: [layers, slots, max_len, heads, d_head] }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn layers(&self) -> usize {
        self.shape[0]
    }

    pub fn slots(&self) -> usize {
        self.shape[1]
    }

    /// Split into layer ranges `[0, at)` and `[at, L)` — used once after
    /// device prefill to hand the cache to the p1/p2 early-exit executables.
    pub fn split_at_layer(&self, at: usize) -> (KvCache, KvCache) {
        let [l, b, m, h, dh] = self.shape;
        assert!(at <= l, "split {at} > layers {l}");
        let per_layer = b * m * h * dh;
        let cut = at * per_layer;
        let mk = |k: &[f32], v: &[f32], layers| KvCache {
            k: k.to_vec(),
            v: v.to_vec(),
            shape: [layers, b, m, h, dh],
        };
        (
            mk(&self.k[..cut], &self.v[..cut], at),
            mk(&self.k[cut..], &self.v[cut..], l - at),
        )
    }

    /// Consuming variant of [`KvCache::split_at_layer`]: the lower range
    /// reuses the original allocation in place and only the upper range
    /// is copied out, so peak memory during the early-exit handoff is
    /// ~1.5× the cache instead of 2× (the borrowing variant clones both
    /// halves while the original is still alive).
    pub fn split_into_at_layer(self, at: usize) -> (KvCache, KvCache) {
        let [l, b, m, h, dh] = self.shape;
        assert!(at <= l, "split {at} > layers {l}");
        let cut = at * b * m * h * dh;
        let mut k = self.k;
        let mut v = self.v;
        let k_hi = k.split_off(cut);
        let v_hi = v.split_off(cut);
        (
            KvCache { k, v, shape: [at, b, m, h, dh] },
            KvCache { k: k_hi, v: v_hi, shape: [l - at, b, m, h, dh] },
        )
    }

    /// Export the first `len` committed rows of `slot` as contiguous
    /// slot-independent row data (paged-KV swap-out): row `p` is the
    /// concatenation over layers of that position's `H×Dh` block.
    pub fn export_slot_rows(&self, slot: usize, len: usize) -> SlotKv {
        let [l, b, m, h, dh] = self.shape;
        assert!(slot < b && len <= m, "export out of range");
        let row = h * dh;
        let width = l * row;
        let mut k = vec![0f32; len * width];
        let mut v = vec![0f32; len * width];
        for layer in 0..l {
            for p in 0..len {
                let src = ((layer * b + slot) * m + p) * row;
                let dst = p * width + layer * row;
                k[dst..dst + row].copy_from_slice(&self.k[src..src + row]);
                v[dst..dst + row].copy_from_slice(&self.v[src..src + row]);
            }
        }
        SlotKv { len, row: width, k, v }
    }

    /// Overwrite the leading rows of `slot` from exported data
    /// (paged-KV swap-in). Rows beyond `kv.len` keep their stale
    /// content — callers mask them by committed length, as everywhere
    /// else in the runtime.
    pub fn import_slot_rows(&mut self, slot: usize, kv: &SlotKv) {
        let [l, b, m, h, dh] = self.shape;
        assert!(slot < b && kv.len <= m, "import out of range");
        let row = h * dh;
        assert_eq!(kv.row, l * row, "kv row width mismatch");
        for layer in 0..l {
            for p in 0..kv.len {
                let dst = ((layer * b + slot) * m + p) * row;
                let src = p * kv.row + layer * row;
                self.k[dst..dst + row].copy_from_slice(&kv.k[src..src + row]);
                self.v[dst..dst + row].copy_from_slice(&kv.v[src..src + row]);
            }
        }
    }

    /// Zero the whole cache (slot reuse). Lengths are tracked by callers.
    pub fn clear(&mut self) {
        self.k.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Copy slot `src_slot` of `other` into our `dst_slot` (cloud KV
    /// migration between batches; layouts must match except slot count).
    pub fn copy_slot_from(&mut self, dst_slot: usize, other: &KvCache, src_slot: usize) {
        let [l, b, m, h, dh] = self.shape;
        let [ol, ob, om, oh, odh] = other.shape;
        assert_eq!((l, m, h, dh), (ol, om, oh, odh), "incompatible kv shapes");
        assert!(dst_slot < b && src_slot < ob);
        let row = m * h * dh;
        for layer in 0..l {
            let d0 = (layer * b + dst_slot) * row;
            let s0 = (layer * ob + src_slot) * row;
            self.k[d0..d0 + row].copy_from_slice(&other.k[s0..s0 + row]);
            self.v[d0..d0 + row].copy_from_slice(&other.v[s0..s0 + row]);
        }
    }

    /// Zero one slot across all layers.
    pub fn clear_slot(&mut self, slot: usize) {
        let [l, b, m, h, dh] = self.shape;
        assert!(slot < b);
        let row = m * h * dh;
        for layer in 0..l {
            let o = (layer * b + slot) * row;
            self.k[o..o + row].iter_mut().for_each(|x| *x = 0.0);
            self.v[o..o + row].iter_mut().for_each(|x| *x = 0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(l: usize, b: usize) -> KvCache {
        let mut kv = KvCache::new(l, b, 4, 2, 3);
        for (i, x) in kv.k.iter_mut().enumerate() {
            *x = i as f32;
        }
        for (i, x) in kv.v.iter_mut().enumerate() {
            *x = -(i as f32);
        }
        kv
    }

    #[test]
    fn split_is_contiguous_and_complete() {
        let kv = filled(4, 1);
        let (a, b) = kv.split_at_layer(3);
        assert_eq!(a.shape, [3, 1, 4, 2, 3]);
        assert_eq!(b.shape, [1, 1, 4, 2, 3]);
        let mut rejoined = a.k.clone();
        rejoined.extend_from_slice(&b.k);
        assert_eq!(rejoined, kv.k);
    }

    #[test]
    fn consuming_split_matches_borrowing_split() {
        let kv = filled(4, 2);
        let (a, b) = kv.split_at_layer(3);
        let (ca, cb) = filled(4, 2).split_into_at_layer(3);
        assert_eq!(ca.shape, a.shape);
        assert_eq!(cb.shape, b.shape);
        assert_eq!(ca.k, a.k);
        assert_eq!(ca.v, a.v);
        assert_eq!(cb.k, b.k);
        assert_eq!(cb.v, b.v);
    }

    #[test]
    fn export_import_slot_rows_round_trip() {
        let src = filled(3, 2);
        let snap = src.export_slot_rows(1, 4);
        assert_eq!(snap.len, 4);
        assert_eq!(snap.row, 3 * 2 * 3);
        // restore into a different slot of a fresh cache
        let mut dst = KvCache::new(3, 4, 4, 2, 3);
        dst.import_slot_rows(2, &snap);
        assert_eq!(dst.export_slot_rows(2, 4), snap, "round trip not bit-identical");
        // spot-check one row against the layer-major source layout
        let row = 2 * 3; // heads × d_head
        let (m, b) = (4, 2);
        let (layer, pos, slot) = (1usize, 2usize, 1usize);
        let src_off = ((layer * b + slot) * m + pos) * row;
        let snap_off = pos * snap.row + layer * row;
        assert_eq!(&snap.k[snap_off..snap_off + row], &src.k[src_off..src_off + row]);
    }

    #[test]
    fn copy_slot_moves_only_that_slot() {
        let src = filled(2, 3);
        let mut dst = KvCache::new(2, 2, 4, 2, 3);
        dst.copy_slot_from(1, &src, 2);
        let row = 4 * 2 * 3;
        // layer 0, slot 1 of dst == layer 0, slot 2 of src
        assert_eq!(&dst.k[row..2 * row], &src.k[2 * row..3 * row]);
        // slot 0 untouched
        assert!(dst.k[..row].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn clear_slot_zeroes_across_layers() {
        let mut kv = filled(2, 2);
        kv.clear_slot(0);
        let row = 4 * 2 * 3;
        assert!(kv.k[..row].iter().all(|&x| x == 0.0)); // layer0 slot0
        assert!(kv.k[2 * row..3 * row].iter().all(|&x| x == 0.0)); // layer1 slot0
        assert!(kv.k[row..2 * row].iter().any(|&x| x != 0.0)); // layer0 slot1 kept
    }
}
