//! Host-side paged KV storage: a [`BlockPool`] of fixed-size KV blocks
//! with a free-list allocator, plus the [`SlotKv`] interchange format
//! for raw committed rows of one engine slot.
//!
//! This is the storage half of the vLLM-style session paging that lets
//! the cloud serve far more *logical* sessions than the compiled batch
//! width B: a session that loses its compute slot has its committed KV
//! rows copied out into pool blocks (swap-out) and copied back into
//! whichever slot it is granted next (swap-in). Blocks are fixed-size
//! (`block_tokens` rows each) so allocation is O(1) pops off a free
//! list and there is no fragmentation; a session's blocks need not be
//! contiguous — its [`BlockTable`] records the ordering.
//!
//! The pool is engine-agnostic: it stores whatever
//! `BatchEngine::export_slot` produced and hands it back verbatim, so
//! a swapped-out-then-in session's KV is bit-identical by construction
//! (asserted by `tests/paging_invariants.rs`). Eviction *policy* (who
//! gets parked) lives in [`crate::cloud::sessions::SessionManager`];
//! this module is mechanism only.

use anyhow::{bail, Result};

/// Raw committed KV rows of one engine slot, in slot-independent
/// row-major layout: row `p` holds the concatenation over layers of
/// that position's `heads × d_head` keys (resp. values).
#[derive(Debug, Clone, PartialEq)]
pub struct SlotKv {
    /// Committed token rows.
    pub len: usize,
    /// Floats per row in each of `k`/`v` (layers × heads × d_head).
    pub row: usize,
    /// `len × row` keys.
    pub k: Vec<f32>,
    /// `len × row` values.
    pub v: Vec<f32>,
}

impl SlotKv {
    pub fn empty(row: usize) -> SlotKv {
        SlotKv { len: 0, row, k: Vec::new(), v: Vec::new() }
    }

    /// Payload size in bytes (both planes, f32) — swap-traffic accounting.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }
}

/// Block table of one parked session: ordered block ids plus the row
/// count (the last block may be partially filled).
#[derive(Debug, Default)]
pub struct BlockTable {
    pub blocks: Vec<usize>,
    /// Committed token rows stored across `blocks`.
    pub len: usize,
}

impl BlockTable {
    /// Table of a brand-new session: no rows, no blocks.
    pub fn empty() -> BlockTable {
        BlockTable::default()
    }
}

/// Fixed-size host KV block pool with a free-list allocator.
///
/// Backing storage grows **lazily**: `capacity` is a hard cap on live
/// blocks, but bytes are only committed when a block is first handed
/// out, so a pool sized for the worst case (every parkable session at
/// full length) costs nothing until sessions actually park.
pub struct BlockPool {
    /// Token rows per block.
    block_tokens: usize,
    /// Floats per token row (per K/V plane).
    row: usize,
    /// Storage for the blocks materialised so far (`used.len()` blocks).
    k: Vec<f32>,
    v: Vec<f32>,
    /// Free ids among materialised blocks (LIFO).
    free: Vec<usize>,
    /// Allocation bitmap over materialised blocks — turns double frees
    /// into panics instead of silent aliasing.
    used: Vec<bool>,
    capacity: usize,
}

impl BlockPool {
    pub fn new(capacity: usize, block_tokens: usize, row: usize) -> BlockPool {
        assert!(block_tokens > 0 && row > 0, "degenerate block geometry");
        BlockPool {
            block_tokens,
            row,
            k: Vec::new(),
            v: Vec::new(),
            free: Vec::new(),
            used: Vec::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Blocks still available: recycled ones plus never-materialised
    /// headroom under the capacity cap.
    pub fn free_blocks(&self) -> usize {
        self.free.len() + (self.capacity - self.used.len())
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn row_width(&self) -> usize {
        self.row
    }

    /// Blocks needed to park `len` committed rows.
    pub fn blocks_for(&self, len: usize) -> usize {
        len.div_ceil(self.block_tokens)
    }

    /// Copy `kv` into freshly allocated blocks (swap-out).
    pub fn store(&mut self, kv: &SlotKv) -> Result<BlockTable> {
        if kv.row != self.row {
            bail!("kv row width {} != pool row width {}", kv.row, self.row);
        }
        let need = self.blocks_for(kv.len);
        if self.free_blocks() < need {
            bail!("block pool exhausted: need {need}, free {}", self.free_blocks());
        }
        let mut blocks = Vec::with_capacity(need);
        for b in 0..need {
            let blk = match self.free.pop() {
                Some(blk) => blk,
                None => {
                    // materialise a fresh block under the capacity cap
                    let blk = self.used.len();
                    let n = self.block_tokens * self.row;
                    self.k.resize(self.k.len() + n, 0.0);
                    self.v.resize(self.v.len() + n, 0.0);
                    self.used.push(false);
                    blk
                }
            };
            debug_assert!(!self.used[blk], "free list handed out a live block");
            self.used[blk] = true;
            let rows_here = (kv.len - b * self.block_tokens).min(self.block_tokens);
            let n = rows_here * self.row;
            let src = b * self.block_tokens * self.row;
            let dst = blk * self.block_tokens * self.row;
            self.k[dst..dst + n].copy_from_slice(&kv.k[src..src + n]);
            self.v[dst..dst + n].copy_from_slice(&kv.v[src..src + n]);
            blocks.push(blk);
        }
        Ok(BlockTable { blocks, len: kv.len })
    }

    /// Materialise a parked session's rows (swap-in).
    pub fn load(&self, table: &BlockTable) -> SlotKv {
        let mut kv = SlotKv {
            len: table.len,
            row: self.row,
            k: vec![0.0; table.len * self.row],
            v: vec![0.0; table.len * self.row],
        };
        for (b, &blk) in table.blocks.iter().enumerate() {
            assert!(self.used[blk], "load from a freed block");
            let rows_here = (table.len - b * self.block_tokens).min(self.block_tokens);
            let n = rows_here * self.row;
            let src = blk * self.block_tokens * self.row;
            let dst = b * self.block_tokens * self.row;
            kv.k[dst..dst + n].copy_from_slice(&self.k[src..src + n]);
            kv.v[dst..dst + n].copy_from_slice(&self.v[src..src + n]);
        }
        kv
    }

    /// Return a table's blocks to the free list. Freeing a block twice
    /// panics (accounting bugs surface as test failures, not aliasing).
    pub fn release(&mut self, table: BlockTable) {
        for blk in table.blocks {
            assert!(self.used[blk], "double free of block {blk}");
            self.used[blk] = false;
            self.free.push(blk);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_kv(len: usize, row: usize, salt: f32) -> SlotKv {
        SlotKv {
            len,
            row,
            k: (0..len * row).map(|i| i as f32 + salt).collect(),
            v: (0..len * row).map(|i| -(i as f32) - salt).collect(),
        }
    }

    #[test]
    fn store_load_round_trip_is_bit_identical() {
        let mut pool = BlockPool::new(8, 4, 6);
        let kv = sample_kv(10, 6, 0.5); // 2.5 blocks → 3
        let t = pool.store(&kv).unwrap();
        assert_eq!(t.blocks.len(), 3);
        assert_eq!(pool.free_blocks(), 5);
        assert_eq!(pool.load(&t), kv);
        pool.release(t);
        assert_eq!(pool.free_blocks(), 8);
    }

    #[test]
    fn interleaved_sessions_do_not_alias() {
        let mut pool = BlockPool::new(6, 2, 3);
        let a = sample_kv(3, 3, 1.0);
        let b = sample_kv(4, 3, 100.0);
        let ta = pool.store(&a).unwrap();
        let tb = pool.store(&b).unwrap();
        assert_eq!(pool.load(&ta), a);
        assert_eq!(pool.load(&tb), b);
        pool.release(ta);
        // releasing a must not disturb b
        assert_eq!(pool.load(&tb), b);
        pool.release(tb);
        assert_eq!(pool.free_blocks(), 6);
    }

    #[test]
    fn pool_storage_is_lazy() {
        // a worst-case-sized pool costs nothing until blocks are used
        let mut pool = BlockPool::new(1 << 40, 16, 4096);
        assert_eq!(pool.free_blocks(), 1 << 40);
        let t = pool.store(&sample_kv(3, 4096, 0.0)).unwrap();
        assert_eq!(pool.free_blocks(), (1 << 40) - 1);
        pool.release(t);
        assert_eq!(pool.free_blocks(), 1 << 40);
    }

    #[test]
    fn exhaustion_is_an_error_not_a_panic() {
        let mut pool = BlockPool::new(2, 4, 2);
        let t = pool.store(&sample_kv(8, 2, 0.0)).unwrap();
        assert!(pool.store(&sample_kv(1, 2, 0.0)).is_err());
        pool.release(t);
        assert!(pool.store(&sample_kv(1, 2, 0.0)).is_ok());
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut pool = BlockPool::new(4, 2, 2);
        let t = pool.store(&sample_kv(3, 2, 0.0)).unwrap();
        let alias = BlockTable { blocks: t.blocks.clone(), len: t.len };
        pool.release(t);
        pool.release(alias);
    }

    #[test]
    fn row_width_mismatch_rejected() {
        let mut pool = BlockPool::new(4, 2, 2);
        assert!(pool.store(&sample_kv(2, 3, 0.0)).is_err());
    }

    #[test]
    fn empty_session_needs_no_blocks() {
        let mut pool = BlockPool::new(2, 4, 2);
        let t = pool.store(&SlotKv::empty(2)).unwrap();
        assert!(t.blocks.is_empty());
        assert_eq!(pool.free_blocks(), 2);
        assert_eq!(pool.load(&t), SlotKv::empty(2));
        pool.release(t);
    }
}
