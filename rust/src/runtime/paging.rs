//! Host-side paged KV storage: a [`BlockPool`] of fixed-size KV blocks
//! with a free-list allocator, plus the [`SlotKv`] interchange format
//! for raw committed rows of one engine slot.
//!
//! This is the storage half of the vLLM-style session paging that lets
//! the cloud serve far more *logical* sessions than the compiled batch
//! width B: a session that loses its compute slot has its committed KV
//! rows copied out into pool blocks (swap-out) and copied back into
//! whichever slot it is granted next (swap-in). Blocks are fixed-size
//! (`block_tokens` rows each) so allocation is O(1) pops off a free
//! list and there is no fragmentation; a session's blocks need not be
//! contiguous — its [`BlockTable`] records the ordering.
//!
//! Blocks are **refcounted** rather than exclusively owned: a freshly
//! stored block starts at refcount 1, [`BlockPool::share`] lets the
//! prefix cache ([`crate::runtime::prefix`]) hand the same physical
//! block to many sessions, and [`BlockPool::unref`] only returns a
//! block to the free list when the last reference drops. Shared blocks
//! are immutable by convention; a writer that must diverge goes
//! through [`BlockPool::cow`], which copies the block iff someone else
//! still references it. Dropping a reference that is already at zero
//! remains a hard error ("double free of block N") so accounting bugs
//! surface as panics, never as silent aliasing.
//!
//! The pool is engine-agnostic: it stores whatever
//! `BatchEngine::export_slot` produced and hands it back verbatim, so
//! a swapped-out-then-in session's KV is bit-identical by construction
//! (asserted by `tests/paging_invariants.rs`). Eviction *policy* (who
//! gets parked) lives in [`crate::cloud::sessions::SessionManager`];
//! this module is mechanism only.

use anyhow::{bail, Result};

/// Raw committed KV rows of one engine slot, in slot-independent
/// row-major layout: row `p` holds the concatenation over layers of
/// that position's `heads × d_head` keys (resp. values).
#[derive(Debug, Clone, PartialEq)]
pub struct SlotKv {
    /// Committed token rows.
    pub len: usize,
    /// Floats per row in each of `k`/`v` (layers × heads × d_head).
    pub row: usize,
    /// `len × row` keys.
    pub k: Vec<f32>,
    /// `len × row` values.
    pub v: Vec<f32>,
}

impl SlotKv {
    pub fn empty(row: usize) -> SlotKv {
        SlotKv { len: 0, row, k: Vec::new(), v: Vec::new() }
    }

    /// Payload size in bytes (both planes, f32) — swap-traffic accounting.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    /// Copy of rows `[from, len)` — the private tail of a session whose
    /// first `from` rows live in shared prefix blocks.
    pub fn tail(&self, from: usize) -> SlotKv {
        assert!(from <= self.len, "tail start {from} past {} rows", self.len);
        SlotKv {
            len: self.len - from,
            row: self.row,
            k: self.k[from * self.row..].to_vec(),
            v: self.v[from * self.row..].to_vec(),
        }
    }
}

/// Block table of one parked session: ordered block ids plus the row
/// count (the last block may be partially filled).
#[derive(Debug, Default)]
pub struct BlockTable {
    pub blocks: Vec<usize>,
    /// Committed token rows stored across `blocks`.
    pub len: usize,
}

impl BlockTable {
    /// Table of a brand-new session: no rows, no blocks.
    pub fn empty() -> BlockTable {
        BlockTable::default()
    }
}

/// Fixed-size host KV block pool with a free-list allocator and
/// per-block reference counts.
///
/// Backing storage grows **lazily**: `capacity` is a hard cap on live
/// blocks, but bytes are only committed when a block is first handed
/// out, so a pool sized for the worst case (every parkable session at
/// full length) costs nothing until sessions actually park.
pub struct BlockPool {
    /// Token rows per block.
    block_tokens: usize,
    /// Floats per token row (per K/V plane).
    row: usize,
    /// Storage for the blocks materialised so far (`refs.len()` blocks).
    k: Vec<f32>,
    v: Vec<f32>,
    /// Free ids among materialised blocks (LIFO).
    free: Vec<usize>,
    /// Reference count per materialised block; 0 = on the free list.
    refs: Vec<u32>,
    capacity: usize,
}

impl BlockPool {
    pub fn new(capacity: usize, block_tokens: usize, row: usize) -> BlockPool {
        assert!(block_tokens > 0 && row > 0, "degenerate block geometry");
        BlockPool {
            block_tokens,
            row,
            k: Vec::new(),
            v: Vec::new(),
            free: Vec::new(),
            refs: Vec::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Blocks still available: recycled ones plus never-materialised
    /// headroom under the capacity cap.
    pub fn free_blocks(&self) -> usize {
        self.free.len() + (self.capacity - self.refs.len())
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn row_width(&self) -> usize {
        self.row
    }

    /// Blocks needed to park `len` committed rows.
    pub fn blocks_for(&self, len: usize) -> usize {
        len.div_ceil(self.block_tokens)
    }

    /// Current reference count of a block (0 = free).
    pub fn ref_count(&self, blk: usize) -> u32 {
        self.refs[blk]
    }

    /// Pop a block off the free list or materialise a fresh one under
    /// the capacity cap; the block comes back with refcount 1.
    fn alloc_block(&mut self) -> Result<usize> {
        let blk = match self.free.pop() {
            Some(blk) => blk,
            None => {
                if self.refs.len() >= self.capacity {
                    bail!("block pool exhausted: capacity {}", self.capacity);
                }
                let blk = self.refs.len();
                let n = self.block_tokens * self.row;
                self.k.resize(self.k.len() + n, 0.0);
                self.v.resize(self.v.len() + n, 0.0);
                self.refs.push(0);
                blk
            }
        };
        debug_assert!(self.refs[blk] == 0, "free list handed out a live block");
        self.refs[blk] = 1;
        Ok(blk)
    }

    /// Copy `kv` into freshly allocated blocks (swap-out). Every block
    /// starts with refcount 1, owned by the returned table.
    pub fn store(&mut self, kv: &SlotKv) -> Result<BlockTable> {
        if kv.row != self.row {
            bail!("kv row width {} != pool row width {}", kv.row, self.row);
        }
        let need = self.blocks_for(kv.len);
        if self.free_blocks() < need {
            bail!("block pool exhausted: need {need}, free {}", self.free_blocks());
        }
        let mut blocks = Vec::with_capacity(need);
        for b in 0..need {
            let blk = self.alloc_block()?;
            let rows_here = (kv.len - b * self.block_tokens).min(self.block_tokens);
            let n = rows_here * self.row;
            let src = b * self.block_tokens * self.row;
            let dst = blk * self.block_tokens * self.row;
            self.k[dst..dst + n].copy_from_slice(&kv.k[src..src + n]);
            self.v[dst..dst + n].copy_from_slice(&kv.v[src..src + n]);
            blocks.push(blk);
        }
        Ok(BlockTable { blocks, len: kv.len })
    }

    /// Take an additional reference on a live block (prefix sharing).
    pub fn share(&mut self, blk: usize) {
        assert!(self.refs[blk] > 0, "share of freed block {blk}");
        self.refs[blk] += 1;
    }

    /// Drop one reference; the block is reclaimed onto the free list
    /// only when the count reaches 0. Dropping a reference on a block
    /// already at zero panics (accounting bugs surface as test
    /// failures, not aliasing).
    pub fn unref(&mut self, blk: usize) {
        assert!(self.refs[blk] > 0, "double free of block {blk}");
        self.refs[blk] -= 1;
        if self.refs[blk] == 0 {
            self.free.push(blk);
        }
    }

    /// Copy-on-write: make `blk` safe for exclusive mutation. If the
    /// caller holds the only reference the block is returned as-is;
    /// otherwise its contents are copied into a fresh block, the
    /// caller's reference moves to the copy, and `true` reports that a
    /// copy happened (the original keeps its remaining references and
    /// stays bit-identical).
    pub fn cow(&mut self, blk: usize) -> Result<(usize, bool)> {
        assert!(self.refs[blk] > 0, "cow of freed block {blk}");
        if self.refs[blk] == 1 {
            return Ok((blk, false));
        }
        let fresh = self.alloc_block()?;
        let n = self.block_tokens * self.row;
        let (src, dst) = (blk * n, fresh * n);
        self.k.copy_within(src..src + n, dst);
        self.v.copy_within(src..src + n, dst);
        self.refs[blk] -= 1;
        Ok((fresh, true))
    }

    /// Materialise `len` rows spread across `blocks` in order (the
    /// last block may be partial). Works for any block-id sequence, so
    /// a session's shared prefix and private tail can be concatenated.
    pub fn load_blocks(&self, blocks: &[usize], len: usize) -> SlotKv {
        let mut kv = SlotKv {
            len,
            row: self.row,
            k: vec![0.0; len * self.row],
            v: vec![0.0; len * self.row],
        };
        for (b, &blk) in blocks.iter().enumerate() {
            assert!(self.refs[blk] > 0, "load from a freed block");
            let rows_here = (len - b * self.block_tokens).min(self.block_tokens);
            let n = rows_here * self.row;
            let src = blk * self.block_tokens * self.row;
            let dst = b * self.block_tokens * self.row;
            kv.k[dst..dst + n].copy_from_slice(&self.k[src..src + n]);
            kv.v[dst..dst + n].copy_from_slice(&self.v[src..src + n]);
        }
        kv
    }

    /// Materialise a parked session's rows (swap-in).
    pub fn load(&self, table: &BlockTable) -> SlotKv {
        self.load_blocks(&table.blocks, table.len)
    }

    /// Drop the table's reference on each of its blocks. Blocks still
    /// shared elsewhere survive; exclusively-owned ones are reclaimed.
    pub fn release(&mut self, table: BlockTable) {
        for blk in table.blocks {
            self.unref(blk);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_kv(len: usize, row: usize, salt: f32) -> SlotKv {
        SlotKv {
            len,
            row,
            k: (0..len * row).map(|i| i as f32 + salt).collect(),
            v: (0..len * row).map(|i| -(i as f32) - salt).collect(),
        }
    }

    #[test]
    fn store_load_round_trip_is_bit_identical() {
        let mut pool = BlockPool::new(8, 4, 6);
        let kv = sample_kv(10, 6, 0.5); // 2.5 blocks → 3
        let t = pool.store(&kv).unwrap();
        assert_eq!(t.blocks.len(), 3);
        assert_eq!(pool.free_blocks(), 5);
        assert_eq!(pool.load(&t), kv);
        pool.release(t);
        assert_eq!(pool.free_blocks(), 8);
    }

    #[test]
    fn interleaved_sessions_do_not_alias() {
        let mut pool = BlockPool::new(6, 2, 3);
        let a = sample_kv(3, 3, 1.0);
        let b = sample_kv(4, 3, 100.0);
        let ta = pool.store(&a).unwrap();
        let tb = pool.store(&b).unwrap();
        assert_eq!(pool.load(&ta), a);
        assert_eq!(pool.load(&tb), b);
        pool.release(ta);
        // releasing a must not disturb b
        assert_eq!(pool.load(&tb), b);
        pool.release(tb);
        assert_eq!(pool.free_blocks(), 6);
    }

    #[test]
    fn pool_storage_is_lazy() {
        // a worst-case-sized pool costs nothing until blocks are used
        let mut pool = BlockPool::new(1 << 40, 16, 4096);
        assert_eq!(pool.free_blocks(), 1 << 40);
        let t = pool.store(&sample_kv(3, 4096, 0.0)).unwrap();
        assert_eq!(pool.free_blocks(), (1 << 40) - 1);
        pool.release(t);
        assert_eq!(pool.free_blocks(), 1 << 40);
    }

    #[test]
    fn exhaustion_is_an_error_not_a_panic() {
        let mut pool = BlockPool::new(2, 4, 2);
        let t = pool.store(&sample_kv(8, 2, 0.0)).unwrap();
        assert!(pool.store(&sample_kv(1, 2, 0.0)).is_err());
        pool.release(t);
        assert!(pool.store(&sample_kv(1, 2, 0.0)).is_ok());
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut pool = BlockPool::new(4, 2, 2);
        let t = pool.store(&sample_kv(3, 2, 0.0)).unwrap();
        let alias = BlockTable { blocks: t.blocks.clone(), len: t.len };
        pool.release(t);
        pool.release(alias);
    }

    #[test]
    fn row_width_mismatch_rejected() {
        let mut pool = BlockPool::new(4, 2, 2);
        assert!(pool.store(&sample_kv(2, 3, 0.0)).is_err());
    }

    #[test]
    fn empty_session_needs_no_blocks() {
        let mut pool = BlockPool::new(2, 4, 2);
        let t = pool.store(&SlotKv::empty(2)).unwrap();
        assert!(t.blocks.is_empty());
        assert_eq!(pool.free_blocks(), 2);
        assert_eq!(pool.load(&t), SlotKv::empty(2));
        pool.release(t);
    }

    #[test]
    fn shared_block_survives_until_last_unref() {
        let mut pool = BlockPool::new(4, 2, 2);
        let kv = sample_kv(2, 2, 3.0);
        let t = pool.store(&kv).unwrap();
        let blk = t.blocks[0];
        pool.share(blk);
        pool.share(blk);
        assert_eq!(pool.ref_count(blk), 3);
        pool.release(t); // ref 3 → 2, block still live
        assert_eq!(pool.ref_count(blk), 2);
        assert_eq!(pool.load_blocks(&[blk], 2), kv);
        pool.unref(blk);
        assert_eq!(pool.free_blocks(), 3);
        pool.unref(blk); // last reference → reclaimed
        assert_eq!(pool.ref_count(blk), 0);
        assert_eq!(pool.free_blocks(), 4);
    }

    #[test]
    fn cow_is_in_place_for_sole_owner() {
        let mut pool = BlockPool::new(4, 2, 2);
        let t = pool.store(&sample_kv(2, 2, 0.0)).unwrap();
        let (blk, copied) = pool.cow(t.blocks[0]).unwrap();
        assert_eq!(blk, t.blocks[0]);
        assert!(!copied);
        pool.release(t);
    }

    #[test]
    fn cow_copies_and_leaves_original_bit_identical() {
        let mut pool = BlockPool::new(4, 2, 2);
        let kv = sample_kv(2, 2, 9.0);
        let t = pool.store(&kv).unwrap();
        let orig = t.blocks[0];
        pool.share(orig); // second reference forces a real copy
        let (fresh, copied) = pool.cow(orig).unwrap();
        assert!(copied);
        assert_ne!(fresh, orig);
        assert_eq!(pool.ref_count(orig), 1);
        assert_eq!(pool.ref_count(fresh), 1);
        assert_eq!(pool.load_blocks(&[orig], 2), kv);
        assert_eq!(pool.load_blocks(&[fresh], 2), kv);
        pool.unref(fresh);
        pool.release(t);
        assert_eq!(pool.free_blocks(), 4);
    }

    #[test]
    fn cow_under_exhaustion_is_an_error() {
        let mut pool = BlockPool::new(1, 2, 2);
        let t = pool.store(&sample_kv(2, 2, 0.0)).unwrap();
        pool.share(t.blocks[0]);
        assert!(pool.cow(t.blocks[0]).is_err());
        pool.unref(t.blocks[0]);
        pool.release(t);
    }

    #[test]
    fn tail_slices_rows() {
        let kv = sample_kv(5, 3, 0.0);
        let tail = kv.tail(2);
        assert_eq!(tail.len, 3);
        assert_eq!(tail.k, kv.k[6..]);
        assert_eq!(tail.v, kv.v[6..]);
        assert_eq!(kv.tail(5), SlotKv::empty(3));
    }
}
