//! Fleet simulator: virtual-time serving of thousands of devices.
//!
//! The threaded driver (`crate::coordinator::serve`) spawns a real OS
//! thread per device, which tops out at a handful of devices — nowhere
//! near the paper's "at scale" regime. This module replaces threads
//! and sleeps with a **deterministic discrete-event simulation** in
//! which N simulated devices each run the *genuine* Synera device loop
//! (draft → [`crate::device::offload::Selector`] → parallel inference
//! via [`crate::device::parallel`] → verify) and a simulated cloud
//! tier advances the *real* [`crate::cloud::router::Router`] over `R`
//! real [`crate::cloud::scheduler::Scheduler`] replicas — each over a
//! [`crate::testutil::MockBatchEngine`] by default, or the PJRT
//! [`crate::model::CloudEngine`] on artifact machines. Each replica
//! owns its own busy-until service window on the virtual clock; router
//! rebalancing migrates parked sessions between replicas with the wire
//! seconds priced in. Thousands of devices simulate per wall-second,
//! so the queueing/fairness regime of Fig. 15 can finally be explored
//! at population scale (`benches/fig19_fleet.rs`).
//!
//! ## The virtual-clock contract
//!
//! Nothing in the simulation sleeps or reads the wall clock. Every
//! latency source *returns a delay* which the driver adds to the
//! virtual clock instead of waiting it out:
//!
//! * [`crate::net::SimLink`] already returns uplink/downlink seconds —
//!   the threaded server sleeps them, the simulator schedules events
//!   at `now + delay`;
//! * device compute is priced per draft/prefill token
//!   ([`fleet::FleetConfig::device_step_s`]);
//! * a cloud scheduler iteration costs its modelled (or, with a real
//!   engine, measured) service time, during which completed rounds'
//!   downlinks are scheduled.
//!
//! Events fire from a heap keyed by `(time, seq)`
//! ([`clock::EventQueue`]), so ties resolve by insertion order and a
//! run is bit-reproducible from its seed — `tests/fleet_sim.rs` gates
//! this, along with the weighted-fair-queueing share property of
//! [`crate::cloud::fairness`].

pub mod clock;
pub mod fleet;

pub use clock::EventQueue;
pub use fleet::{run_fleet, run_fleet_on, FleetConfig, FleetReport, SimDevice, TenantReport};
