//! The fleet driver: N simulated devices × one simulated cloud, in
//! virtual time (see the module docs in [`crate::sim`]).
//!
//! Each device runs the genuine Synera loop — synthetic draft streams
//! scored by the real [`Selector`], rejection-position prediction and
//! alternative substitution from [`crate::device::parallel`], real
//! top-k compression ([`compress_dist`]) priced by the real wire
//! format — against a cloud that is the real router-fronted replica
//! tier ([`crate::cloud::router::Router`] over `R` real schedulers
//! with the weighted-fair tenant frontend of
//! [`crate::cloud::fairness`]). Each modelled replica owns its own
//! busy-until service window on the virtual clock; router rebalancing
//! migrates quiescent sessions between replicas at round boundaries,
//! with the migration's wire seconds and radio energy charged like any
//! other traffic. Only the *model forward passes* are synthetic: draft
//! tokens/confidences/importances come from each device's seeded
//! stream, and verification runs over the engine's own logits (exact
//! speculative acceptance semantics, including corrections and bonus
//! tokens).

use std::collections::VecDeque;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::cloud::fairness::TenantStats;
use crate::cloud::router::Router;
use crate::cloud::scheduler::{CloudEvent, CloudRequest};
use crate::config::{DeviceProfile, SloPolicy, SyneraParams};
use crate::device::codec::compress_dist;
use crate::device::early_exit::SeqExitPolicy;
use crate::device::offload::{OffloadDecision, Selector};
use crate::device::parallel::{alternative_token, predict_rejection};
use crate::metrics::cost::{CostModel, PackingFactors};
use crate::metrics::energy::EnergyModel;
use crate::metrics::stats::{QuantileSketch, Summary};
use crate::model::cloud_engine::BatchEngine;
use crate::net::link::{LinkProfile, SimLink};
use crate::net::wire::{DownlinkMsg, TraceContext, UplinkMsg};
use crate::obs::export;
use crate::obs::registry::{self, RegistryShared, SloMonitor};
use crate::obs::trace::{self, tenant_pid, Ph, TraceShared, PID_CLOUD};
use crate::profiling::OffloadProfile;
use crate::sim::clock::EventQueue;
use crate::testutil::MockBatchEngine;
use crate::util::rng::Rng;
use crate::workload::synthlang::{shared_preamble, TASKS};
use crate::workload::trace::{mmpp_trace, poisson_trace, BurstProfile};
use crate::workload::vocab::{EOS, N_VALS, VAL0, VOCAB};

/// Fleet simulation configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub n_devices: usize,
    /// Arrival horizon in virtual seconds (requests in flight at the
    /// horizon still drain to completion).
    pub duration_s: f64,
    /// Aggregate offered load across the fleet (req/s).
    pub rate_rps: f64,
    /// Hard virtual-time stop: events past this instant are discarded
    /// and in-flight requests stay uncounted (`0` = run to full drain).
    /// Use it to take *windowed* measurements of an overloaded fleet,
    /// where a full drain would hide the backlog.
    pub stop_s: f64,
    /// Bursty (MMPP) arrivals instead of homogeneous Poisson.
    pub burst: Option<BurstProfile>,
    /// Number of tenants; devices map onto tenants round-robin.
    pub tenants: usize,
    /// Per-tenant WFQ weights (empty = equal weights).
    pub tenant_weights: Vec<f64>,
    /// Device/runtime parameters; `params.batch` configures the cloud
    /// (token budget, `max_sessions` paging cap, …).
    pub params: SyneraParams,
    /// Uniform link for every device; `None` = heterogeneous
    /// [`LinkProfile::fleet_mix`].
    pub link: Option<LinkProfile>,
    /// Device decode seconds per draft token.
    pub device_step_s: f64,
    /// Device prefill seconds per prompt token.
    pub device_prefill_s: f64,
    /// Modelled cloud service time per scheduler iteration (fixed part).
    pub cloud_iter_s: f64,
    /// Modelled cloud service time per executed token row.
    pub cloud_row_s: f64,
    /// Cross-replica KV migration link speed (Gbit/s) — prices the
    /// virtual seconds a router rebalance stalls the replicas involved.
    pub migrate_gbps: f64,
    /// Device energy profile for the per-tenant energy column (J/token
    /// drafting cost, J/byte radio cost).
    pub device_profile: DeviceProfile,
    /// Service-level objective (TTFT/TBT thresholds and violation
    /// budget) shared by the report columns and the registry's
    /// [`SloMonitor`] burn-rate gauges.
    pub slo: SloPolicy,
    /// Retired: per-tenant latency reservoirs were replaced by
    /// [`QuantileSketch`]es (bounded memory with a *guaranteed*
    /// relative-error bound, exact merge). The field is kept so older
    /// configs keep compiling; it is no longer read.
    pub reservoir: usize,
    /// Fraction of arrivals whose prompt is prefixed with a shared
    /// preamble ([`crate::workload::synthlang::shared_preamble`]);
    /// `> 0` also turns on the cloud's prefix cache
    /// (`BatchPolicy::prefix_cache`). `0.0` leaves the arrival trace
    /// and the paging path bit-identical to a build without prefix
    /// sharing: the preamble RNG stream is never created and no extra
    /// draws occur.
    pub prefix_share: f64,
    /// Shared-preamble length in tokens (only read when
    /// `prefix_share > 0`); multiples of the 16-token KV block size
    /// dedup fully.
    pub prefix_len: usize,
    pub seed: u64,
    /// Cloud model label for the cost model's packing factor.
    pub cloud_model: String,
    /// Attached trace sink (virtual-clock spans and events across the
    /// device, router and replica tracks); `None` = tracing off, every
    /// record site is a single branch.
    pub trace: Option<TraceShared>,
    /// Attached metrics registry, sampled on its own cadence in
    /// virtual time at replica tick boundaries; `None` = off.
    pub registry: Option<RegistryShared>,
    /// Flight-recorder output directory: when a tenant's windowed SLO
    /// burn rate ([`SloMonitor::sample`]) rises through
    /// [`FleetConfig::flight_burn`], the trace sink's retained buffer
    /// is dumped here as a Chrome-trace file
    /// (`flight-t<tenant>-<virtual-ms>.json`). Needs both `trace` and
    /// `registry` attached; `None` = off.
    pub flight_dir: Option<PathBuf>,
    /// Burn-rate threshold arming the flight recorder (1.0 = the
    /// violation budget is burning exactly at the allowed rate). Each
    /// tenant re-arms once its burn falls back below the threshold, so
    /// one sustained brownout produces one dump, not one per cadence.
    pub flight_burn: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            n_devices: 64,
            duration_s: 10.0,
            rate_rps: 32.0,
            stop_s: 0.0,
            burst: None,
            tenants: 1,
            tenant_weights: Vec::new(),
            params: SyneraParams::default(),
            link: None,
            device_step_s: 8e-3,
            device_prefill_s: 1e-3,
            cloud_iter_s: 2e-3,
            cloud_row_s: 4e-4,
            migrate_gbps: 10.0,
            device_profile: DeviceProfile::jetson_orin_50w(),
            slo: SloPolicy::default(),
            reservoir: 1 << 16,
            prefix_share: 0.0,
            prefix_len: 32,
            seed: 0xF1EE7,
            cloud_model: "l13b".into(),
            trace: None,
            registry: None,
            flight_dir: None,
            flight_burn: 2.0,
        }
    }
}

/// One tenant's slice of a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub tenant: usize,
    pub weight: f64,
    pub requests: usize,
    pub completed: usize,
    /// Time to first committed token, from request arrival.
    pub ttft: Summary,
    /// Per-request mean time between tokens.
    pub tbt: Summary,
    /// Fraction of completed requests with TTFT ≤ the SLO.
    pub slo_ttft_frac: f64,
    /// Fraction of TBT-eligible (≥2 token) completed requests with
    /// mean TBT ≤ the SLO.
    pub slo_tbt_frac: f64,
    /// Whole-run TTFT burn rate: fraction of the violation budget
    /// consumed ([`SloPolicy::burn`]; 1.0 = exactly at budget).
    pub ttft_burn: f64,
    /// Whole-run TBT burn rate.
    pub tbt_burn: f64,
    /// Engine token rows executed for this tenant (WFQ share evidence).
    pub rows_executed: u64,
    pub verifies_done: u64,
    pub draft_tokens_accepted: u64,
    /// Prompt rows served from shared prefix blocks at admission
    /// (rows the cloud never had to prefill).
    pub prefix_hit_rows: u64,
    /// Device-side energy for this tenant's fleet slice: drafting
    /// J/token plus radio J/byte over uplink, downlink and migration
    /// traffic ([`crate::metrics::energy::EnergyModel`]).
    pub energy_j: f64,
}

/// Aggregate results of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub tenants: Vec<TenantReport>,
    /// Requests offered by the arrival trace.
    pub offered: usize,
    pub completed: usize,
    /// Virtual time covered by the run: the last event's firing time,
    /// clamped to `stop_s` in windowed runs.
    pub virtual_s: f64,
    /// Wall-clock seconds the simulation itself took.
    pub wall_s: f64,
    pub generated_tokens: u64,
    pub offload_rounds: u64,
    pub local_chunks: u64,
    pub pi_hits: u64,
    pub pi_misses: u64,
    /// Draft token rows verified by the cloud (cost numerator).
    pub cloud_draft_rows: u64,
    /// Estimated serving cost (`CostModel`, arbitrary units).
    pub cost: f64,
    pub cloud_iterations: u64,
    pub swap_ins: u64,
    pub swap_outs: u64,
    pub swap_bytes: u64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    /// Scheduler replicas behind the router this run.
    pub replicas: usize,
    /// Cross-replica session migrations the router performed.
    pub migrations: u64,
    /// Wire bytes those migrations moved (priced into `cost`).
    pub migration_bytes: u64,
    /// Scheduler iterations per replica (scaling/balance evidence).
    pub replica_iterations: Vec<u64>,
    /// Engine token rows per replica.
    pub replica_rows: Vec<u64>,
}

impl FleetReport {
    /// Completed fraction of offered requests.
    pub fn completion(&self) -> f64 {
        if self.offered == 0 {
            return 1.0;
        }
        self.completed as f64 / self.offered as f64
    }

    /// Requests-weighted mean TBT across tenants (cost model `T`),
    /// weighted by *completed requests* (the sketch's `tbt.n` counts
    /// only TBT-eligible ≥2-token requests).
    pub fn mean_tbt_s(&self) -> f64 {
        let (mut num, mut den) = (0.0, 0usize);
        for t in &self.tenants {
            num += t.tbt.mean * t.completed as f64;
            den += t.completed;
        }
        if den == 0 { 0.0 } else { num / den as f64 }
    }
}

/// A drafted γ-token chunk from a device's synthetic model.
#[derive(Debug, Clone)]
pub struct DraftedChunk {
    pub tokens: Vec<u32>,
    pub confs: Vec<f32>,
    pub imps: Vec<f32>,
}

/// The simulated device model: a seeded synthetic draft stream feeding
/// the *real* offload selector, sequence-exit policy and
/// rejection-position predictor. Exposed so the sim-vs-threaded
/// cross-check in `tests/fleet_sim.rs` can drive the identical device
/// logic from OS threads.
pub struct SimDevice {
    pub id: u32,
    pub tenant: usize,
    rng: Rng,
    selector: Selector,
    seq_exit: SeqExitPolicy,
    alpha: f64,
}

impl SimDevice {
    pub fn new(
        id: u32,
        tenant: usize,
        profile: &OffloadProfile,
        params: &SyneraParams,
        seed: u64,
    ) -> SimDevice {
        let mut p = params.clone();
        // distinct, reproducible dispatch stream per device
        p.seed = seed ^ ((id as u64) << 20) ^ 0xD1CE;
        let selector = Selector::new(profile.c_th, profile.i_th_for_budget(p.budget), p.clone());
        let seq_exit = SeqExitPolicy::new(p.seq_exit_frac, p.max_new_tokens, p.early_exit);
        SimDevice {
            id,
            tenant,
            rng: Rng::new(seed ^ 0xDEC0DE ^ (id as u64).wrapping_mul(0x9E37_79B9)),
            selector,
            seq_exit,
            alpha: profile.alpha,
        }
    }

    /// Draft `n` tokens: content-range token ids with confidence and
    /// importance signals shaped to exercise both selector stages.
    pub fn draft_chunk(&mut self, n: usize) -> DraftedChunk {
        let mut ch = DraftedChunk {
            tokens: Vec::with_capacity(n),
            confs: Vec::with_capacity(n),
            imps: Vec::with_capacity(n),
        };
        for _ in 0..n {
            ch.tokens.push(VAL0 + self.rng.below(N_VALS) as u32);
            ch.confs.push((0.35 + 0.65 * self.rng.f64()) as f32);
            ch.imps.push((4.0 * self.rng.f64()) as f32);
        }
        ch
    }

    /// The real two-stage offload decision plus the sequence-exit gate
    /// (`generated` = tokens generated so far in this request).
    pub fn decide_offload(&mut self, ch: &DraftedChunk, generated: usize) -> bool {
        self.decide_offload_scored(ch, generated).0
    }

    /// [`SimDevice::decide_offload`] plus the selector's raw scores,
    /// so tracing can record *why* a chunk offloaded. Identical RNG
    /// draws to the unscored form — observing the decision never
    /// perturbs the simulation.
    pub fn decide_offload_scored(
        &mut self,
        ch: &DraftedChunk,
        generated: usize,
    ) -> (bool, OffloadDecision) {
        let d = self.selector.decide(&ch.confs, &ch.imps);
        let offload = d.offload && self.seq_exit.offload_allowed(generated);
        (offload, d)
    }

    /// The device's parallel-inference bet for an in-flight chunk:
    /// `(predicted rejection position, substituted alternative token)`.
    pub fn pi_bet(&mut self, ch: &DraftedChunk) -> Option<(usize, u32)> {
        let r_star = predict_rejection(self.alpha, &ch.confs, &mut self.rng)?;
        let probs = Self::dense_probs(ch.tokens[r_star], ch.confs[r_star]);
        Some((r_star, alternative_token(&probs, ch.tokens[r_star])))
    }

    /// Dense probability row consistent with `(token, conf)`: `conf` on
    /// the drafted token, the rest split over two deterministic rivals
    /// (enough structure for top-k compression and PI alternatives).
    pub fn dense_probs(token: u32, conf: f32) -> Vec<f32> {
        let mut p = vec![0f32; VOCAB];
        let i = (token - VAL0) as u64;
        let r1 = VAL0 + ((i + 1) % N_VALS) as u32;
        let r2 = VAL0 + ((i + 2) % N_VALS) as u32;
        p[token as usize] = conf;
        p[r1 as usize] = (1.0 - conf) * 0.7;
        p[r2 as usize] = (1.0 - conf) * 0.3;
        p
    }

    /// Deterministic continuation token `j` of a PI speculation seeded
    /// at `alt` (no RNG: the draw count must not depend on reply
    /// timing, or determinism across schedules would break).
    pub fn pi_token(alt: u32, j: usize) -> u32 {
        VAL0 + (((alt - VAL0) as u64 + 5 * (j as u64 + 1)) % N_VALS) as u32
    }
}

// ---------------------------------------------------------------------------
// driver internals
// ---------------------------------------------------------------------------

enum Ev {
    /// A request from the arrival trace lands on its device.
    Arrive { device: u32, prompt: Vec<u32> },
    /// The device finished local compute; materialise the drafted chunk
    /// and act on it.
    Wake { device: u32 },
    /// An uplink message reaches the cloud.
    Uplink { device: u32, req: CloudRequest },
    /// One scheduler iteration of replica `replica` completes.
    CloudTick { replica: u32 },
    /// A verification reply reaches its device.
    Reply { device: u32, accepted: usize, next_token: u32 },
}

struct Inflight {
    start_len: usize,
    draft: Vec<u32>,
    t_sent: f64,
    /// `(r_star, alt)` parallel-inference bet, if one was placed.
    pi: Option<(usize, u32)>,
    /// Trace context this round travelled under (joins the device and
    /// cloud tracks into one causal flow per offload round).
    ctx: TraceContext,
}

struct Active {
    req_id: u64,
    t_arrival: f64,
    /// Prompt followed by committed tokens.
    seq: Vec<u32>,
    /// Prefix of `seq` already in the cloud's KV.
    cloud_len: usize,
    generated: usize,
    t_first: Option<f64>,
    t_last: f64,
    /// Offload rounds attempted so far (trace-context round counter).
    round: u32,
    inflight: Option<Inflight>,
}

struct Dev {
    model: SimDevice,
    link: SimLink,
    pending: VecDeque<(f64, Vec<u32>)>,
    active: Option<Active>,
    next_req: u64,
}

struct TenantAcc {
    ttft: QuantileSketch,
    tbt: QuantileSketch,
    /// Device-side energy for this tenant's devices (drafting + radio).
    energy: EnergyModel,
    requests: usize,
    completed: usize,
}

struct FleetRun<'a, E: BatchEngine> {
    cfg: &'a FleetConfig,
    router: Router<E>,
    q: EventQueue<Ev>,
    devs: Vec<Dev>,
    acc: Vec<TenantAcc>,
    /// Per-tenant SLO attainment and windowed burn-rate accounting.
    slo: SloMonitor,
    /// Per replica: is a CloudTick scheduled or firing for it?
    cloud_active: Vec<bool>,
    /// Per replica: end of its last scheduled service period — one
    /// simulated replica never runs two ticks concurrently, and a
    /// migration extends the windows of both replicas involved.
    cloud_busy_until: Vec<f64>,
    measured_compute: bool,
    /// Per-tenant flight-recorder latch (see
    /// [`FleetConfig::flight_burn`]).
    flight_armed: Vec<bool>,
    flight_dumps: u64,
    offered: usize,
    completed: usize,
    generated_tokens: u64,
    offload_rounds: u64,
    local_chunks: u64,
    pi_hits: u64,
    pi_misses: u64,
    bytes_up: u64,
    bytes_down: u64,
}

impl<E: BatchEngine> FleetRun<'_, E> {
    fn on_arrive(&mut self, t: f64, device: usize, prompt: Vec<u32>) {
        self.offered += 1;
        let tenant = self.devs[device].model.tenant;
        self.acc[tenant].requests += 1;
        self.devs[device].pending.push_back((t, prompt));
        if self.cfg.trace.is_some() {
            let queued = self.devs[device].pending.len() as f64;
            trace::with(&self.cfg.trace, |s| {
                s.instant(tenant_pid(tenant), device as u32, "arrive", 0, vec![("queued", queued)])
            });
        }
        if self.devs[device].active.is_none() {
            self.start_next(t, device);
        }
    }

    /// Begin the device's next queued request: prefill, then draft the
    /// first chunk (the wake event materialises it).
    fn start_next(&mut self, t: f64, device: usize) {
        let dev = &mut self.devs[device];
        let Some((t_arrival, prompt)) = dev.pending.pop_front() else { return };
        let req_id = ((device as u64) << 32) | dev.next_req;
        dev.next_req += 1;
        let prompt_len = prompt.len();
        dev.active = Some(Active {
            req_id,
            t_arrival,
            seq: prompt,
            cloud_len: 0,
            generated: 0,
            t_first: None,
            t_last: 0.0,
            round: 0,
            inflight: None,
        });
        let tenant = dev.model.tenant;
        trace::with(&self.cfg.trace, |s| {
            s.begin(tenant_pid(tenant), device as u32, "request", req_id)
        });
        let gamma = self.chunk_len(device);
        let delay = prompt_len as f64 * self.cfg.device_prefill_s
            + gamma as f64 * self.cfg.device_step_s;
        self.q.push(t + delay, Ev::Wake { device: device as u32 });
    }

    /// Draft tokens the next chunk will hold (γ capped by the budget).
    fn chunk_len(&self, device: usize) -> usize {
        let a = self.devs[device].active.as_ref().expect("active request");
        self.cfg.params.gamma.min(self.cfg.params.max_new_tokens - a.generated).max(1)
    }

    fn on_wake(&mut self, t: f64, device: usize) -> Result<()> {
        let gamma = self.chunk_len(device);
        let step_s = self.cfg.device_step_s;
        let dev = &mut self.devs[device];
        let tenant = dev.model.tenant;
        let a = dev.active.as_mut().expect("wake without an active request");
        debug_assert!(a.inflight.is_none(), "wake while a round is in flight");
        let chunk = dev.model.draft_chunk(gamma);
        let (offload, dec) = dev.model.decide_offload_scored(&chunk, a.generated);

        if !offload {
            // commit locally; token 0 of the chunk finished drafting at
            // t − (γ−1)·step
            self.local_chunks += 1;
            if self.cfg.trace.is_some() {
                let (pid, id) = (tenant_pid(tenant), a.req_id);
                let args = vec![
                    ("gamma", gamma as f64),
                    ("p_conf", dec.p_conf),
                    ("p_imp", dec.p_imp),
                    ("mean_conf", dec.mean_conf),
                    ("mean_imp", dec.mean_imp),
                ];
                trace::with(&self.cfg.trace, |s| s.instant(pid, device as u32, "local", id, args));
            }
            let t0 = t - (gamma - 1) as f64 * step_s;
            if a.t_first.is_none() {
                a.t_first = Some(t0);
            }
            a.t_last = t;
            a.seq.extend_from_slice(&chunk.tokens);
            a.generated += chunk.tokens.len();
            self.acc[tenant].energy.record_steps(chunk.tokens.len() as u64, 1.0);
            if a.generated >= self.cfg.params.max_new_tokens {
                self.finish_request(t, device);
            } else {
                let next = self.chunk_len(device);
                self.q.push(t + next as f64 * step_s, Ev::Wake { device: device as u32 });
            }
            return Ok(());
        }

        // ---- offload round ----
        self.offload_rounds += 1;
        let uncached: Vec<u32> = a.seq[a.cloud_len..].to_vec();
        let dists: Vec<_> = chunk
            .tokens
            .iter()
            .zip(&chunk.confs)
            .map(|(&tok, &c)| compress_dist(&SimDevice::dense_probs(tok, c), 8))
            .collect();
        // charge the real wire size without materialising a message
        // just to drop it (hot path at fleet scale)
        let up_bytes = UplinkMsg::wire_bytes_for(uncached.len(), chunk.tokens.len(), &dists);
        self.bytes_up += up_bytes as u64;
        self.acc[tenant].energy.record_bytes(up_bytes as u64);
        let up_delay = dev.link.uplink_s(up_bytes);
        let pi = if self.cfg.params.parallel_inference && chunk.tokens.len() > 1 {
            dev.model.pi_bet(&chunk)
        } else {
            None
        };
        // causal context: computed unconditionally (cheap, no RNG) so
        // tracing on/off cannot perturb the simulation
        let ctx = TraceContext::for_round(a.req_id, a.round);
        a.round = a.round.wrapping_add(1);
        a.inflight = Some(Inflight {
            start_len: a.seq.len(),
            draft: chunk.tokens.clone(),
            t_sent: t,
            pi,
            ctx,
        });
        let req = CloudRequest::Verify {
            request_id: a.req_id,
            device_id: device as u32,
            uncached,
            draft: chunk.tokens,
            dists,
            greedy: self.cfg.params.greedy,
            ctx,
        };
        self.q.push(t + up_delay, Ev::Uplink { device: device as u32, req });
        if self.cfg.trace.is_some() {
            let (pid, id) = (tenant_pid(tenant), a.req_id);
            let args = vec![
                ("gamma", gamma as f64),
                ("p_conf", dec.p_conf),
                ("p_imp", dec.p_imp),
                ("mean_conf", dec.mean_conf),
                ("mean_imp", dec.mean_imp),
                ("bytes", up_bytes as f64),
                ("round", ctx.round as f64),
            ];
            trace::with(&self.cfg.trace, |s| {
                s.instant(pid, device as u32, "offload", id, args);
                s.begin(pid, device as u32, "round", id);
                s.begin(pid, device as u32, "uplink", id);
                // flow start binds to the round slice just opened;
                // the cloud scheduler steps it at verify_commit and
                // the device ends it at device_commit
                s.flow(pid, device as u32, "offload", Ph::FlowStart, ctx.parent_span);
            });
        }
        Ok(())
    }

    fn on_uplink(&mut self, t: f64, device: usize, req: CloudRequest) -> Result<()> {
        let tenant = self.devs[device].model.tenant;
        if self.cfg.trace.is_some() {
            let id = if let CloudRequest::Verify { request_id, .. } = &req {
                *request_id
            } else {
                0
            };
            trace::with(&self.cfg.trace, |s| {
                s.end(tenant_pid(tenant), device as u32, "uplink", id)
            });
        }
        let r = self.router.submit_tenant(tenant, req)?;
        self.wake_cloud(t, r);
        Ok(())
    }

    fn wake_cloud(&mut self, t: f64, replica: usize) {
        if !self.cloud_active[replica] && !self.router.replica_idle(replica) {
            self.cloud_active[replica] = true;
            // a wake landing inside the replica's previous service
            // period waits it out: one service interval at a time
            self.q.push(
                t.max(self.cloud_busy_until[replica]),
                Ev::CloudTick { replica: replica as u32 },
            );
        }
    }

    fn on_cloud_tick(&mut self, t: f64, replica: usize) -> Result<()> {
        if t < self.cloud_busy_until[replica] {
            // a migration on another replica's tick extended this
            // replica's busy window after this event was scheduled;
            // re-fire at the window's end (never into the past)
            let at = self.cloud_busy_until[replica];
            self.q.push(at, Ev::CloudTick { replica: replica as u32 });
            return Ok(());
        }
        let rows0 = self.router.replica(replica).stats.rows_executed;
        let (events, dt) = self.router.tick_replica(replica)?;
        let rows = self.router.replica(replica).stats.rows_executed - rows0;
        let service = if self.measured_compute {
            dt.max(1e-6)
        } else {
            self.cfg.cloud_iter_s + rows as f64 * self.cfg.cloud_row_s
        };
        let t_serve = t + service;
        for e in events {
            if let CloudEvent::VerifyDone { request_id, device_id, outcome } = e {
                let device = device_id as usize;
                let reply = DownlinkMsg {
                    request_id,
                    accepted: outcome.accepted as u32,
                    next_token: outcome.next_token,
                };
                let bytes = reply.wire_bytes();
                self.bytes_down += bytes as u64;
                let tenant = self.devs[device].model.tenant;
                self.acc[tenant].energy.record_bytes(bytes as u64);
                let dl = self.devs[device].link.downlink_s(bytes);
                if self.cfg.trace.is_some() {
                    // the analyzer splits this round's cloud window into
                    // service and downlink from these args; `round` joins
                    // the instant to the device-side offload context
                    let round = self.devs[device]
                        .active
                        .as_ref()
                        .and_then(|a| a.inflight.as_ref())
                        .map_or(-1.0, |i| i.ctx.round as f64);
                    let args =
                        vec![("round", round), ("service", service), ("dl", dl)];
                    trace::with(&self.cfg.trace, |s| {
                        s.instant(PID_CLOUD, replica as u32, "reply", request_id, args)
                    });
                }
                self.q.push(
                    t_serve + dl,
                    Ev::Reply {
                        device: device_id,
                        accepted: outcome.accepted,
                        next_token: outcome.next_token,
                    },
                );
            }
        }
        // rebalance at the round boundary: each migration's wire time
        // serialises after this replica's service period and extends
        // the busy windows of both replicas it touches
        let mut t_done = t_serve;
        for m in self.router.rebalance()? {
            let wire_s = m.bytes as f64 * 8.0 / (self.cfg.migrate_gbps * 1e9);
            t_done += wire_s;
            if let Some(tn) = m.tenant {
                // the migrated KV transits the cloud interconnect, but
                // the serving bytes are attributed (like swap traffic)
                // to the tenant whose session moved
                self.acc[tn].energy.record_bytes(m.bytes);
            }
            self.cloud_busy_until[m.from] = self.cloud_busy_until[m.from].max(t_done);
            self.cloud_busy_until[m.to] = self.cloud_busy_until[m.to].max(t_done);
        }
        self.cloud_busy_until[replica] = self.cloud_busy_until[replica].max(t_done);
        if self.router.replica_idle(replica) {
            self.cloud_active[replica] = false;
        } else {
            self.q.push(
                self.cloud_busy_until[replica],
                Ev::CloudTick { replica: replica as u32 },
            );
        }
        // cadence-gated metrics sample at the tick boundary, stamped
        // with virtual time
        let mut burns: Option<Vec<Option<f64>>> = None;
        if let Some(reg) = &self.cfg.registry {
            if let Ok(mut r) = reg.lock() {
                if r.due(t) {
                    registry::sample_router(&mut r, &self.router);
                    burns = Some(self.slo.sample(&mut r));
                    r.snapshot(t);
                }
            }
        }
        // the flight trigger reads the freshly-closed burn window
        // (registry lock released first — the dump locks the trace)
        if let Some(burns) = burns {
            self.maybe_flight_dump(t, &burns);
        }
        Ok(())
    }

    /// Rising-edge flight recorder: a tenant whose windowed burn rate
    /// crosses `flight_burn` while armed dumps the trace sink's
    /// retained buffer (full ring + sampler-retained + in-flight
    /// staging) as a Chrome-trace file and disarms until its burn
    /// falls back below the threshold. IO failure logs a warning and
    /// never fails the simulation.
    fn maybe_flight_dump(&mut self, t: f64, burns: &[Option<f64>]) {
        let Some(dir) = &self.cfg.flight_dir else { return };
        for (tenant, burn) in burns.iter().enumerate() {
            match burn {
                Some(b) if *b >= self.cfg.flight_burn => {
                    if !self.flight_armed[tenant] {
                        continue;
                    }
                    self.flight_armed[tenant] = false;
                    let mut snap = None;
                    trace::with(&self.cfg.trace, |s| {
                        snap = Some((s.snapshot_events(), s.dropped()));
                    });
                    let Some((events, dropped)) = snap else { continue };
                    let ms = (t * 1e3).round() as u64;
                    let path = dir.join(format!("flight-t{tenant}-{ms}.json"));
                    let doc = export::chrome_trace_string_from(&events, dropped);
                    match std::fs::write(&path, doc) {
                        Ok(()) => {
                            self.flight_dumps += 1;
                            crate::log!(
                                Warn,
                                "flight recorder: tenant {tenant} burn {b:.2} ≥ {:.2} at \
                                 t={t:.3}s → {}",
                                self.cfg.flight_burn,
                                path.display()
                            );
                        }
                        Err(e) => {
                            crate::log!(Warn, "flight dump {} failed: {e}", path.display())
                        }
                    }
                }
                // below threshold (or idle window): re-arm
                _ => self.flight_armed[tenant] = true,
            }
        }
    }

    fn on_reply(&mut self, t: f64, device: usize, accepted: usize, next_token: u32) {
        let max_new = self.cfg.params.max_new_tokens;
        let (delta, step_s) = (self.cfg.params.delta, self.cfg.device_step_s);
        let dev = &mut self.devs[device];
        let tenant = dev.model.tenant;
        let a = dev.active.as_mut().expect("reply without an active request");
        let inf = a.inflight.take().expect("reply without an in-flight round");
        let accepted = accepted.min(inf.draft.len());
        a.cloud_len = inf.start_len + accepted;

        // tokens the PI speculation managed to draft while waiting
        let mut t_now = t;
        let mut commit: Vec<u32> = Vec::new();
        let mut adopted = false;
        if let Some((r_star, alt)) = inf.pi {
            let elapsed = (t - inf.t_sent).max(0.0);
            let n_pi = ((elapsed / step_s) as usize).clamp(1, 1 + delta);
            t_now = t.max(inf.t_sent + n_pi as f64 * step_s);
            if accepted == r_star && accepted < inf.draft.len() && next_token == alt {
                self.pi_hits += 1;
                adopted = true;
                commit.extend_from_slice(&inf.draft[..r_star]);
                commit.push(alt);
                for j in 0..n_pi - 1 {
                    commit.push(SimDevice::pi_token(alt, j));
                }
            } else {
                self.pi_misses += 1;
            }
        }
        let mut ended = false;
        if !adopted {
            commit.extend_from_slice(&inf.draft[..accepted]);
            if next_token == EOS {
                ended = true; // verifier ended the sequence
            } else {
                commit.push(next_token);
                // the correction must be stepped through the device
                // before drafting resumes
                t_now += step_s;
            }
        }
        let room = max_new - a.generated;
        commit.truncate(room);
        if self.cfg.trace.is_some() {
            let (pid, id) = (tenant_pid(tenant), a.req_id);
            let mut args =
                vec![("accepted", accepted as f64), ("committed", commit.len() as f64)];
            args.push(("round", inf.ctx.round as f64));
            let flow = inf.ctx.parent_span;
            trace::with(&self.cfg.trace, |s| {
                // flow end lands while the round slice is still open so
                // `bp:"e"` binds the arrow head to it
                s.flow(pid, device as u32, "offload", Ph::FlowEnd, flow);
                s.end(pid, device as u32, "round", id);
                s.instant(pid, device as u32, "device_commit", id, args);
            });
        }
        if !commit.is_empty() {
            if a.t_first.is_none() {
                a.t_first = Some(t_now);
            }
            a.t_last = t_now;
            a.seq.extend_from_slice(&commit);
            a.generated += commit.len();
            self.acc[tenant].energy.record_steps(commit.len() as u64, 1.0);
        }
        if ended || a.generated >= max_new {
            self.finish_request(t_now, device);
        } else {
            let next = self.chunk_len(device);
            self.q.push(t_now + next as f64 * step_s, Ev::Wake { device: device as u32 });
        }
    }

    fn finish_request(&mut self, t: f64, device: usize) {
        let a = self.devs[device].active.take().expect("finishing an active request");
        let tenant = self.devs[device].model.tenant;
        trace::with(&self.cfg.trace, |s| {
            s.end(tenant_pid(tenant), device as u32, "request", a.req_id)
        });
        if a.cloud_len > 0 {
            // the cloud holds state for this session; free it
            if let Ok(r) = self.router.submit(CloudRequest::Release { request_id: a.req_id }) {
                self.wake_cloud(t, r);
            }
        }
        let acc = &mut self.acc[tenant];
        acc.completed += 1;
        self.completed += 1;
        self.generated_tokens += a.generated as u64;
        let ttft = a.t_first.unwrap_or(t) - a.t_arrival;
        acc.ttft.record(ttft);
        self.slo.record_ttft(tenant, ttft);
        let mut slo_miss = ttft > self.cfg.slo.ttft_s;
        // requests with <2 tokens have no inter-token gap: they carry
        // no TBT sample and sit outside the TBT-SLO denominator
        // (recording 0.0 would drag percentiles down and inflate SLO
        // attainment exactly when requests die early)
        if let (Some(t0), n) = (a.t_first, a.generated) {
            if n >= 2 {
                let tbt = (a.t_last - t0) / (n - 1) as f64;
                self.acc[tenant].tbt.record(tbt);
                self.slo.record_tbt(tenant, tbt);
                slo_miss |= tbt > self.cfg.slo.tbt_s;
            }
        }
        // settle the request with the sampler: an SLO-missing request
        // is tail-interesting and keeps its full event set (the
        // Release's swap_out can still land on a later tick — late
        // events follow this verdict)
        trace::with(&self.cfg.trace, |s| {
            s.complete_request(a.req_id, t - a.t_arrival, slo_miss)
        });
        self.start_next(t, device);
    }
}

/// Run the fleet over the artifact-free [`MockBatchEngine`] (one per
/// replica, per `cfg.params.batch.replicas`) with the synthetic offload
/// profile (the default, CI-friendly configuration).
pub fn run_fleet(cfg: &FleetConfig) -> Result<FleetReport> {
    let replicas = cfg.params.batch.replicas.max(1);
    let engines = (0..replicas).map(|_| MockBatchEngine::new(4, 32, VOCAB, 4096)).collect();
    run_fleet_on(cfg, engines, &OffloadProfile::synthetic(), false)
}

/// Run the fleet over arbitrary [`BatchEngine`]s, one per replica
/// (`engines.len()` must match `cfg.params.batch.replicas`, after the
/// latter is normalised to ≥ 1). With `measured_compute` the virtual
/// clock advances by each engine's *measured* per-tick compute (for
/// the real PJRT engine on artifact machines); otherwise by the
/// modelled `cloud_iter_s + rows × cloud_row_s`.
pub fn run_fleet_on<E: BatchEngine>(
    cfg: &FleetConfig,
    engines: Vec<E>,
    profile: &OffloadProfile,
    measured_compute: bool,
) -> Result<FleetReport> {
    if cfg.n_devices == 0 || cfg.tenants == 0 {
        bail!("fleet needs ≥1 device and ≥1 tenant");
    }
    let positive = |x: f64| x.is_finite() && x > 0.0;
    if !positive(cfg.duration_s) || !positive(cfg.rate_rps) {
        bail!("fleet needs a positive duration and arrival rate");
    }
    if cfg.params.max_new_tokens == 0 || cfg.params.gamma == 0 {
        bail!("fleet needs max_new_tokens ≥ 1 and gamma ≥ 1");
    }
    let weights = if cfg.tenant_weights.is_empty() {
        vec![1.0; cfg.tenants]
    } else {
        cfg.tenant_weights.clone()
    };
    if weights.len() != cfg.tenants {
        bail!("{} tenant weights for {} tenants", weights.len(), cfg.tenants);
    }
    if weights.iter().any(|w| !w.is_finite() || *w <= 0.0) {
        bail!("tenant weights must be finite and positive: {weights:?}");
    }
    let replicas = cfg.params.batch.replicas.max(1);
    if engines.len() != replicas {
        bail!("{} engines for {} configured replicas", engines.len(), replicas);
    }
    if !(0.0..=1.0).contains(&cfg.prefix_share) || !cfg.prefix_share.is_finite() {
        bail!("prefix_share must be in [0, 1], got {}", cfg.prefix_share);
    }
    if cfg.prefix_share > 0.0 && cfg.prefix_len == 0 {
        bail!("prefix_share > 0 needs prefix_len >= 1");
    }

    let t_wall = Instant::now();
    let mut policy = cfg.params.batch.clone();
    policy.tenant_weights = weights.clone();
    policy.replicas = replicas;
    if cfg.prefix_share > 0.0 {
        policy.prefix_cache = true;
    }
    // replica 0 keeps the exact pre-router seed, so an R = 1 fleet is
    // event-for-event identical to the single-scheduler driver it
    // replaced (gated by `same_seed_gives_bit_identical_reports`)
    let mut router = Router::new(engines, cfg.seed ^ 0xF1EE7, &policy)?;
    router.set_trace(cfg.trace.clone());
    let mut run = FleetRun {
        cfg,
        router,
        q: EventQueue::new(),
        devs: (0..cfg.n_devices)
            .map(|d| Dev {
                model: SimDevice::new(d as u32, d % cfg.tenants, profile, &cfg.params, cfg.seed),
                link: SimLink::new(
                    cfg.link.unwrap_or_else(|| LinkProfile::fleet_mix(d)),
                    cfg.seed ^ 0x99 ^ ((d as u64) << 8),
                ),
                pending: VecDeque::new(),
                active: None,
                next_req: 0,
            })
            .collect(),
        acc: (0..cfg.tenants)
            .map(|_| TenantAcc {
                ttft: QuantileSketch::default(),
                tbt: QuantileSketch::default(),
                energy: EnergyModel::new(
                    cfg.device_profile.joules_per_token,
                    cfg.device_profile.joules_per_byte,
                ),
                requests: 0,
                completed: 0,
            })
            .collect(),
        slo: SloMonitor::new(cfg.tenants, cfg.slo),
        cloud_active: vec![false; replicas],
        cloud_busy_until: vec![0.0; replicas],
        measured_compute,
        flight_armed: vec![true; cfg.tenants],
        flight_dumps: 0,
        offered: 0,
        completed: 0,
        generated_tokens: 0,
        offload_rounds: 0,
        local_chunks: 0,
        pi_hits: 0,
        pi_misses: 0,
        bytes_up: 0,
        bytes_down: 0,
    };

    // arrival trace (real SynthLang prompts over the task mix)
    let trace = match &cfg.burst {
        Some(p) => mmpp_trace(cfg.seed ^ 0x7ACE, cfg.n_devices, p, cfg.duration_s, &TASKS),
        None => {
            poisson_trace(cfg.seed ^ 0x7ACE, cfg.n_devices, cfg.rate_rps, cfg.duration_s, &TASKS)
        }
    };
    // shared-preamble injection: a dedicated RNG stream (never created
    // at share 0, so the trace above stays draw-for-draw identical)
    // decides per arrival whether it carries a preamble and from which
    // family, then prepends the deterministic preamble tokens
    let mut pre_rng =
        (cfg.prefix_share > 0.0).then(|| Rng::new(cfg.seed ^ 0x5052_4546_4958)); // "PREFIX"
    const PREAMBLE_FAMILIES: u64 = 4;
    for ev in trace {
        let mut prompt = ev.sample.prompt;
        if let Some(rng) = pre_rng.as_mut() {
            if rng.f64() < cfg.prefix_share {
                let mut p = shared_preamble(rng.below(PREAMBLE_FAMILIES), cfg.prefix_len);
                p.extend_from_slice(&prompt);
                prompt = p;
            }
        }
        run.q.push(ev.at_s, Ev::Arrive { device: ev.device as u32, prompt });
    }

    // drain the event heap; the cap is a runaway-loop backstop, far
    // above anything a legitimate configuration generates
    let max_events: u64 = 100_000_000;
    let mut n_events = 0u64;
    while let Some((t, ev)) = run.q.pop() {
        if cfg.stop_s > 0.0 && t > cfg.stop_s {
            break; // windowed measurement: drop the residual backlog
        }
        n_events += 1;
        if n_events > max_events {
            bail!("fleet sim exceeded {max_events} events (runaway configuration?)");
        }
        // all trace events fired by this handler carry the event's
        // virtual firing time (the clock contract in `obs::trace`)
        trace::set_now(&cfg.trace, t);
        match ev {
            Ev::Arrive { device, prompt } => run.on_arrive(t, device as usize, prompt),
            Ev::Wake { device } => run.on_wake(t, device as usize)?,
            Ev::Uplink { device, req } => run.on_uplink(t, device as usize, req)?,
            Ev::CloudTick { replica } => run.on_cloud_tick(t, replica as usize)?,
            Ev::Reply { device, accepted, next_token } => {
                run.on_reply(t, device as usize, accepted, next_token)
            }
        }
    }

    // ---- assemble the report ----
    // in a windowed run the clock has already advanced onto the first
    // discarded post-window event; clamp to the measurement window
    let virtual_s = if cfg.stop_s > 0.0 {
        run.q.now().min(cfg.stop_s)
    } else {
        run.q.now()
    };
    // one forced end-of-run snapshot: the drained end state (empty
    // queues, freed blocks, closed sessions) always lands in the
    // series regardless of cadence phase
    if let Some(reg) = &cfg.registry {
        if let Ok(mut r) = reg.lock() {
            registry::sample_router(&mut r, &run.router);
            run.slo.sample(&mut r);
            if let Some(tr) = &cfg.trace {
                if let Ok(s) = tr.lock() {
                    r.gauge_set("obs.trace_dropped", s.dropped() as f64);
                    if let Some(st) = s.sampler_stats() {
                        r.gauge_set("obs.sampler_completed", st.completed as f64);
                        r.gauge_set("obs.sampler_head_retained", st.head_retained as f64);
                        r.gauge_set("obs.sampler_tail_retained", st.tail_retained as f64);
                        r.gauge_set(
                            "obs.sampler_retained_requests",
                            st.retained_requests as f64,
                        );
                        r.gauge_set("obs.sampler_retained_events", st.retained_events as f64);
                        r.gauge_set(
                            "obs.sampler_peak_staged_events",
                            st.peak_staged_events as f64,
                        );
                        r.gauge_set(
                            "obs.sampler_discarded_events",
                            st.discarded_events as f64,
                        );
                    }
                }
            }
            r.gauge_set("obs.flight_dumps", run.flight_dumps as f64);
            r.snapshot(virtual_s);
        }
    }
    // per-tenant and aggregate cloud stats, summed across replicas
    let nrep = run.router.n_replicas();
    let mut cloud_draft_rows = 0u64;
    let mut cloud_iterations = 0u64;
    let (mut swap_ins, mut swap_outs, mut swap_bytes) = (0u64, 0u64, 0u64);
    let mut replica_iterations = Vec::with_capacity(nrep);
    let mut replica_rows = Vec::with_capacity(nrep);
    let mut tstats = vec![TenantStats::default(); cfg.tenants];
    for r in 0..nrep {
        let s = run.router.replica(r);
        cloud_draft_rows += s.stats.draft_tokens_seen;
        cloud_iterations += s.stats.iterations;
        swap_ins += s.stats.swap_ins;
        swap_outs += s.stats.swap_outs;
        swap_bytes += s.stats.swap_bytes;
        replica_iterations.push(s.stats.iterations);
        replica_rows.push(s.stats.rows_executed);
        for (t, ts) in s.tenant_stats.iter().enumerate().take(cfg.tenants) {
            tstats[t].rows_executed += ts.rows_executed;
            tstats[t].verifies_done += ts.verifies_done;
            tstats[t].draft_tokens_accepted += ts.draft_tokens_accepted;
            tstats[t].prefix_hit_rows += ts.prefix_hit_rows;
        }
    }
    let mut tenants = Vec::with_capacity(cfg.tenants);
    for (t, acc) in run.acc.iter().enumerate() {
        let (ttft_att, tbt_att) = (run.slo.ttft_attainment(t), run.slo.tbt_attainment(t));
        let tbt_sum = acc.tbt.summary();
        let tbt_has = tbt_sum.is_some();
        tenants.push(TenantReport {
            tenant: t,
            weight: weights[t],
            requests: acc.requests,
            completed: acc.completed,
            ttft: acc.ttft.summary().unwrap_or_default(),
            tbt: tbt_sum.unwrap_or_default(),
            slo_ttft_frac: ttft_att,
            slo_tbt_frac: tbt_att,
            // a tenant with no samples is unburned, not fully burned
            ttft_burn: if acc.completed > 0 { cfg.slo.burn(ttft_att) } else { 0.0 },
            tbt_burn: if tbt_has { cfg.slo.burn(tbt_att) } else { 0.0 },
            rows_executed: tstats[t].rows_executed,
            verifies_done: tstats[t].verifies_done,
            draft_tokens_accepted: tstats[t].draft_tokens_accepted,
            prefix_hit_rows: tstats[t].prefix_hit_rows,
            energy_j: acc.energy.total_joules(),
        });
    }
    let mut report = FleetReport {
        tenants,
        offered: run.offered,
        completed: run.completed,
        virtual_s,
        wall_s: t_wall.elapsed().as_secs_f64(),
        generated_tokens: run.generated_tokens,
        offload_rounds: run.offload_rounds,
        local_chunks: run.local_chunks,
        pi_hits: run.pi_hits,
        pi_misses: run.pi_misses,
        cloud_draft_rows,
        cost: 0.0,
        cloud_iterations,
        swap_ins,
        swap_outs,
        swap_bytes,
        bytes_up: run.bytes_up,
        bytes_down: run.bytes_down,
        replicas: nrep,
        migrations: run.router.stats.migrations,
        migration_bytes: run.router.stats.migration_bytes,
        replica_iterations,
        replica_rows,
    };
    let cost_model = CostModel {
        cloud_tokens: report.cloud_draft_rows,
        generated_tokens: report.generated_tokens,
        mean_tbt_s: report.mean_tbt_s(),
        cloud_model: cfg.cloud_model.clone(),
        migration_bytes: report.migration_bytes,
    };
    report.cost = cost_model.cost(&PackingFactors::default());
    Ok(report)
}
