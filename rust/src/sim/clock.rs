//! Deterministic virtual clock: a discrete-event queue keyed by
//! `(time, sequence)`.
//!
//! Events fire in non-decreasing virtual time; exact time ties resolve
//! by insertion order (the monotone `seq` counter), so a run is a pure
//! function of its inputs — no wall clock, no thread interleaving.
//! Pushing an event in the past is a logic error and panics rather
//! than silently reordering history.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<T> {
    at: f64,
    seq: u64,
    ev: T,
}

// Ordering ignores the payload: (at, seq) ascending. BinaryHeap is a
// max-heap, so comparisons are reversed here instead of wrapping every
// entry in `Reverse`.
impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.at.to_bits() == other.at.to_bits()
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        other.at.total_cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event queue + virtual clock of one simulation run.
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
    now: f64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time (the firing time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `ev` at absolute virtual time `at` (≥ now).
    pub fn push(&mut self, at: f64, ev: T) {
        assert!(at.is_finite(), "event time must be finite (got {at})");
        assert!(at >= self.now, "event scheduled in the past: {at} < now {}", self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { at, seq, ev });
    }

    /// Schedule `ev` after a non-negative delay from now.
    pub fn push_after(&mut self, delay: f64, ev: T) {
        self.push(self.now + delay.max(0.0), ev);
    }

    /// Pop the next event, advancing the clock to its firing time.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let e = self.heap.pop()?;
        self.now = e.at;
        Some((e.at, e.ev))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_order_with_seq_tiebreak() {
        let mut q = EventQueue::new();
        q.push(2.0, "late");
        q.push(1.0, "early-a");
        q.push(1.0, "early-b"); // same instant: insertion order wins
        q.push(1.5, "mid");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, ["early-a", "early-b", "mid", "late"]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(0.5, 1u32);
        q.push(0.25, 2);
        let mut last = 0.0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            assert_eq!(q.now(), t);
            last = t;
        }
    }

    #[test]
    fn push_after_is_relative_to_now() {
        let mut q = EventQueue::new();
        q.push(1.0, 0u32);
        q.pop();
        q.push_after(0.5, 1);
        let (t, _) = q.pop().unwrap();
        assert!((t - 1.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "scheduled in the past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.push(1.0, ());
        q.pop();
        q.push(0.5, ());
    }
}
