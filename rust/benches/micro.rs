//! Micro-benchmarks of the hot-path components (criterion-style timing
//! via the in-repo harness): selector, codec, wire, verifier, rouge,
//! engine step, cloud batch, scheduler bookkeeping, JSON.

use synera::bench::{fmt_s, time_it, Table};
use synera::config::SyneraParams;
use synera::device::codec::compress_dist;
use synera::device::offload::Selector;
use synera::metrics::quality::rouge1;
use synera::model::{CloudEngine, DeviceEngine, SlotChunk};
use synera::net::wire::{Dist, UplinkMsg};
use synera::runtime::Runtime;
use synera::util::json::Json;
use synera::workload::synthlang::{generate, Task};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let mut t = Table::new("micro: hot-path components", &["component", "mean", "p95"]);

    let mut sel = Selector::new(0.7, 1.0, SyneraParams::default());
    let s = time_it(100, 2000, || {
        std::hint::black_box(sel.decide(&[0.4; 4], &[0.8; 4]));
    });
    t.row(&["selector.decide (per chunk)".into(), fmt_s(s.mean), fmt_s(s.p95)]);

    let probs: Vec<f32> = (0..512).map(|i| 1.0 / (i + 1) as f32).collect();
    let s = time_it(100, 2000, || {
        std::hint::black_box(compress_dist(&probs, 8));
    });
    t.row(&["codec top-8 compress (512 vocab)".into(), fmt_s(s.mean), fmt_s(s.p95)]);

    let msg = UplinkMsg {
        request_id: 1,
        device_id: 0,
        uncached: vec![200; 12],
        draft: vec![300; 4],
        dists: vec![compress_dist(&probs, 8); 4],
        is_first: false,
        ctx: Default::default(),
    };
    let s = time_it(100, 2000, || {
        std::hint::black_box(msg.encode());
    });
    t.row(&["uplink encode".into(), fmt_s(s.mean), fmt_s(s.p95)]);

    let q_rows: Vec<Vec<f32>> = (0..5).map(|_| probs.clone()).collect();
    let dists = vec![Dist::Dense(probs.clone()); 4];
    let mut rng = synera::util::rng::Rng::new(7);
    let s = time_it(100, 2000, || {
        std::hint::black_box(synera::cloud::verifier::verify_chunk(
            &[0, 1, 2, 3],
            &dists,
            &q_rows,
            true,
            &mut rng,
        ));
    });
    t.row(&["verify_chunk (γ=4, greedy)".into(), fmt_s(s.mean), fmt_s(s.p95)]);

    let a: Vec<u32> = (0..16).collect();
    let b: Vec<u32> = (8..24).collect();
    let s = time_it(100, 5000, || {
        std::hint::black_box(rouge1(&a, &b));
    });
    t.row(&["rouge1 (16 vs 16 tokens)".into(), fmt_s(s.mean), fmt_s(s.p95)]);

    // engine steps (the PJRT hot path)
    for slm in ["s160m", "s1b", "s7b"] {
        let dev = DeviceEngine::new(rt.model(slm)?, false)?;
        let p = generate(Task::Xsum, 1, 0).prompt;
        let (sess0, out0) = dev.prefill(&p)?;
        let mut sess = sess0.clone();
        let mut tok = out0.token;
        let s = time_it(3, 60, || {
            let o = dev.step(&mut sess, tok, false, 1.0).unwrap();
            tok = o.token;
            if sess.len + 2 >= dev.model.meta.max_len {
                sess = sess0.clone();
                tok = out0.token;
            }
        });
        t.row(&[format!("{slm} decode step (full)"), fmt_s(s.mean), fmt_s(s.p95)]);
    }
    for llm in ["l13b", "l70b"] {
        let mut cloud = CloudEngine::new(rt.model(llm)?)?;
        let p = generate(Task::Xsum, 1, 1).prompt;
        let slots: Vec<usize> = (0..cloud.slots).map(|i| cloud.alloc_slot(i as u64).unwrap()).collect();
        let s = time_it(2, 40, || {
            let items: Vec<SlotChunk> = slots
                .iter()
                .map(|&sl| SlotChunk { slot: sl, tokens: p.clone() })
                .collect();
            cloud.run_batch(&items).unwrap();
            for &sl in &slots {
                cloud.rollback(sl, 0);
            }
        });
        t.row(&[
            format!("{llm} batch chunk ({}×{} tokens)", cloud.slots, p.len()),
            fmt_s(s.mean),
            fmt_s(s.p95),
        ]);
    }

    let meta_text = std::fs::read_to_string(rt.dir.join("meta.json"))?;
    let s = time_it(10, 500, || {
        std::hint::black_box(Json::parse(&meta_text).unwrap());
    });
    t.row(&["meta.json parse".into(), fmt_s(s.mean), fmt_s(s.p95)]);

    t.print();
    Ok(())
}
