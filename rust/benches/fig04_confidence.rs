//! Fig. 4 — SLM→LLM alignment vs confidence: top-1/top-5 hit rate per
//! confidence bucket (left) and the confidence CDF (right).

use synera::bench::{pct, Table};
use synera::model::logits::{argmax, top_k};
use synera::model::{CloudEngine, DeviceEngine, SlotChunk};
use synera::runtime::Runtime;
use synera::workload::trace::mixed_eval_set;
use synera::workload::vocab::EOS;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let dev = DeviceEngine::new(rt.model("s160m")?, false)?;
    let mut cloud = CloudEngine::new(rt.model("l13b")?)?;
    let v = cloud.model.meta.vocab;

    // (confidence, top1_hit, top5_hit) per drafted token
    let mut obs: Vec<(f32, bool, bool)> = Vec::new();
    for (i, s) in mixed_eval_set(6).iter().enumerate() {
        let slot = cloud.alloc_slot(i as u64).unwrap();
        let (mut sess, mut cur) = dev.prefill(&s.prompt)?;
        // device drafts 12 tokens; the cloud scores the same stream
        let mut drafted = Vec::new();
        let mut confs = Vec::new();
        for _ in 0..12 {
            let tok = argmax(&cur.probs) as u32;
            if tok == EOS {
                break;
            }
            drafted.push(tok);
            confs.push(cur.probs[tok as usize]);
            cur = dev.step(&mut sess, tok, false, 1.0)?;
        }
        if drafted.is_empty() {
            cloud.free_slot(slot);
            continue;
        }
        let mut seq = s.prompt.clone();
        seq.extend(&drafted[..drafted.len() - 1]);
        let mut rows_all: Vec<Vec<f32>> = Vec::new();
        for chunk in seq.chunks(cloud.chunk) {
            let (res, _) = cloud.run_batch(&[SlotChunk { slot, tokens: chunk.to_vec() }])?;
            for r in 0..res[0].n_rows {
                rows_all.push(res[0].rows[r * v..(r + 1) * v].to_vec());
            }
        }
        // row (prompt.len()-1+j) predicts drafted[j]
        for (j, (&tok, &conf)) in drafted.iter().zip(&confs).enumerate() {
            let q = &rows_all[s.prompt.len() - 1 + j];
            let t1 = argmax(q) as u32 == tok;
            let t5 = top_k(q, 5).iter().any(|&i| i as u32 == tok);
            obs.push((conf, t1, t5));
        }
        cloud.free_slot(slot);
    }

    let mut t = Table::new(
        "Fig 4(a): SLM hit rate vs confidence (pair s160m&l13b)",
        &["conf bucket", "n", "top-1 hit", "top-5 hit"],
    );
    for b in 0..5 {
        let lo = b as f32 * 0.2;
        let hi = lo + 0.2;
        let sel: Vec<_> = obs.iter().filter(|(c, _, _)| *c >= lo && *c < hi + 1e-6).collect();
        let n = sel.len();
        let h1 = sel.iter().filter(|(_, t1, _)| *t1).count() as f64 / n.max(1) as f64;
        let h5 = sel.iter().filter(|(_, _, t5)| *t5).count() as f64 / n.max(1) as f64;
        t.row(&[format!("{lo:.1}-{hi:.1}"), n.to_string(), pct(h1), pct(h5)]);
    }
    t.print();

    let mut t2 = Table::new("Fig 4(b): confidence CDF", &["conf ≤", "fraction"]);
    let mut confs: Vec<f32> = obs.iter().map(|(c, _, _)| *c).collect();
    confs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for q in [0.2, 0.4, 0.6, 0.8, 0.9] {
        let frac = confs.iter().filter(|&&c| c <= q).count() as f64 / confs.len().max(1) as f64;
        t2.row(&[format!("{q:.1}"), pct(frac)]);
    }
    let high = confs.iter().filter(|&&c| c > 0.8).count() as f64 / confs.len().max(1) as f64;
    t2.row(&["(>0.8)".into(), pct(high)]);
    t2.print();
    Ok(())
}
