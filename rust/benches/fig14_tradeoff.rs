//! Fig. 14 — quality–latency and quality–cost trade-offs as the
//! offloading budget sweeps 0 → 0.8 (plus 1.0 for the ceiling).

use synera::bench::{f3, Table};
use synera::config::Scenario;
use synera::coordinator::eval::{eval_with_profile, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::profiling::load_or_profile;
use synera::runtime::Runtime;
use synera::workload::synthlang::Task;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let profile = load_or_profile(&rt, "s160m", None, "l13b")?;
    let opts = EvalOptions { n_samples: 10, task: Task::Xsum };
    let mut t = Table::new(
        "Fig 14: budget trade-offs (s160m&l13b, XSum)",
        &["budget", "quality", "tbt_ms", "cost(m)", "offload rate", "W"],
    );
    for b in [0.0, 0.05, 0.1, 0.2, 0.4, 0.6, 0.8, 1.0] {
        let mut scen = Scenario::default_pair("s160m", "l13b");
        scen.params.budget = b;
        let rep = eval_with_profile(&rt, &scen, Method::Synera, &opts, &profile)?;
        t.row(&[
            format!("{b:.2}"),
            f3(rep.quality),
            format!("{:.1}", rep.tbt_s * 1e3),
            format!("{:.3}", rep.cost * 1e3),
            f3(rep.offload_rate),
            f3(rep.w),
        ]);
    }
    t.print();
    Ok(())
}
