//! Fig. 5 — importance-guided offloading: quality vs budget for
//! importance-ranked selection vs random selection (left), and the
//! importance-score CDF showing its long tail (right).

use synera::bench::{f3, Table};
use synera::config::Scenario;
use synera::coordinator::eval::{eval_with_profile, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::profiling::load_or_profile;
use synera::runtime::Runtime;
use synera::workload::synthlang::Task;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let base = Scenario::default_pair("s160m", "l13b");
    let profile = load_or_profile(&rt, "s160m", None, "l13b")?;
    let opts = EvalOptions { n_samples: 10, task: Task::Cnndm };

    let mut t = Table::new(
        "Fig 5(a): quality vs offloading budget (cnndm-sim, s160m&l13b)",
        &["budget", "importance-ranked", "random"],
    );
    for b in [0.0, 0.1, 0.2, 0.3, 0.5, 0.8, 1.0] {
        let mut s = base.clone();
        s.params.budget = b;
        s.params.use_conf = false; // isolate the importance signal
        s.params.parallel_inference = false;
        s.params.early_exit = false;
        let imp = eval_with_profile(&rt, &s, Method::Synera, &opts, &profile)?;
        s.params.random_offload = true;
        let rnd = eval_with_profile(&rt, &s, Method::Synera, &opts, &profile)?;
        t.row(&[format!("{b:.1}"), f3(imp.quality), f3(rnd.quality)]);
    }
    t.print();

    let mut t2 = Table::new(
        "Fig 5(b): chunk importance CDF (profiled)",
        &["percentile", "importance"],
    );
    for p in [10usize, 25, 50, 75, 90, 95, 99, 100] {
        t2.row(&[format!("p{p}"), f3(profile.imp_percentiles[p])]);
    }
    t2.print();
    Ok(())
}
