//! Fig. 19 — fleet-scale serving: device population × per-device
//! arrival rate, over the virtual-clock discrete-event simulator
//! (`sim::fleet`) with the real mixed-batching scheduler, paged KV
//! sessions and the weighted-fair tenant frontend behind it.
//!
//! Sweeps devices 16 → 4096 at three per-device request rates and
//! reports worst-tenant p95 TTFT with the fleet's TTFT-SLO attainment
//! fraction, locating the saturation knee of one simulated cloud.
//! Artifact-free: the cloud is the deterministic mock engine with a
//! modelled per-row service time, so this bench runs anywhere
//! `cargo bench` does.

use synera::bench::Table;
use synera::config::{BatchPolicy, SyneraParams};
use synera::sim::{run_fleet, FleetConfig};

fn main() -> anyhow::Result<()> {
    let rates = [0.125f64, 0.25, 0.5];
    let mut t = Table::new(
        "Fig 19: fleet scaling — p95 TTFT / TTFT-SLO attainment vs devices x per-device req/s",
        &["devices", "0.125 req/s/dev", "0.25 req/s/dev", "0.5 req/s/dev", "wall s"],
    );
    for devices in [16usize, 64, 256, 1024, 4096] {
        let mut cells = vec![devices.to_string()];
        let mut wall = 0.0;
        for r in rates {
            let cfg = FleetConfig {
                n_devices: devices,
                duration_s: 10.0,
                rate_rps: (devices as f64 * r).max(0.5),
                // windowed at 2× the horizon: overloaded points report
                // their backlogged latencies instead of draining forever
                stop_s: 20.0,
                tenants: 4,
                params: SyneraParams {
                    batch: BatchPolicy { max_sessions: 64, ..BatchPolicy::default() },
                    ..SyneraParams::default()
                },
                seed: 0xF19 ^ devices as u64,
                ..FleetConfig::default()
            };
            let rep = run_fleet(&cfg)?;
            wall += rep.wall_s;
            let mut slo = 0.0;
            let mut done = 0usize;
            let mut p95: f64 = 0.0;
            for tn in &rep.tenants {
                p95 = p95.max(tn.ttft.p95);
                slo += tn.slo_ttft_frac * tn.completed as f64;
                done += tn.completed;
            }
            let slo_frac = if done == 0 { 0.0 } else { slo / done as f64 };
            cells.push(format!("{:.0}ms / {:.0}%", p95 * 1e3, slo_frac * 100.0));
        }
        cells.push(format!("{wall:.2}"));
        t.row(&cells);
    }
    t.print();
    println!("(worst-tenant p95; SLO fraction is completions-weighted across tenants)");
    Ok(())
}
