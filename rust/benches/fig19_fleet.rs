//! Fig. 19 — fleet-scale serving: device population × per-device
//! arrival rate, over the virtual-clock discrete-event simulator
//! (`sim::fleet`) with the real mixed-batching scheduler, paged KV
//! sessions and the weighted-fair tenant frontend behind it.
//!
//! Sweeps devices 16 → 4096 at three per-device request rates and
//! reports worst-tenant p95 TTFT with the fleet's TTFT-SLO attainment
//! fraction, locating the saturation knee of one simulated cloud.
//! A second table holds the fleet at 4096 devices past that knee and
//! sweeps router replicas R ∈ {1, 2, 4, 8}: scaling out recovers
//! completions and SLO attainment, with migration traffic reported
//! alongside. Artifact-free: the cloud is the deterministic mock
//! engine with a modelled per-row service time, so this bench runs
//! anywhere `cargo bench` does.

//!
//! `--json` additionally writes `BENCH_fig19.json` with the raw rows
//! of both tables (device scaling and the replica sweep).

use synera::bench::{write_bench_json, Table};
use synera::config::{BatchPolicy, SyneraParams};
use synera::sim::{run_fleet, FleetConfig, FleetReport};
use synera::util::cli::Args;
use synera::util::json::Json;

/// Worst-tenant p95 TTFT and completions-weighted TTFT-SLO fraction.
fn fleet_slo(rep: &FleetReport) -> (f64, f64) {
    let mut slo = 0.0;
    let mut done = 0usize;
    let mut p95: f64 = 0.0;
    for tn in &rep.tenants {
        p95 = p95.max(tn.ttft.p95);
        slo += tn.slo_ttft_frac * tn.completed as f64;
        done += tn.completed;
    }
    (p95, if done == 0 { 0.0 } else { slo / done as f64 })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    // optional shared-preamble axis: both tables run with the same
    // prompt population (same seed ⇒ identical arrivals); 0.0 (the
    // default) keeps this bench bit-identical to builds without
    // prefix sharing
    let prefix_share = args.get_f64("prefix-share", 0.0)?;
    let prefix_len = args.get_usize("prefix-len", 32)?;
    let mut scaling_rows: Vec<Json> = Vec::new();
    let mut replica_rows: Vec<Json> = Vec::new();
    let rates = [0.125f64, 0.25, 0.5];
    let mut t = Table::new(
        "Fig 19: fleet scaling — p95 TTFT / TTFT-SLO attainment vs devices x per-device req/s",
        &["devices", "0.125 req/s/dev", "0.25 req/s/dev", "0.5 req/s/dev", "wall s"],
    );
    for devices in [16usize, 64, 256, 1024, 4096] {
        let mut cells = vec![devices.to_string()];
        let mut wall = 0.0;
        for r in rates {
            let cfg = FleetConfig {
                n_devices: devices,
                duration_s: 10.0,
                rate_rps: (devices as f64 * r).max(0.5),
                // windowed at 2× the horizon: overloaded points report
                // their backlogged latencies instead of draining forever
                stop_s: 20.0,
                tenants: 4,
                params: SyneraParams {
                    batch: BatchPolicy { max_sessions: 64, ..BatchPolicy::default() },
                    ..SyneraParams::default()
                },
                seed: 0xF19 ^ devices as u64,
                prefix_share,
                prefix_len,
                ..FleetConfig::default()
            };
            let rep = run_fleet(&cfg)?;
            wall += rep.wall_s;
            let (p95, slo_frac) = fleet_slo(&rep);
            cells.push(format!("{:.0}ms / {:.0}%", p95 * 1e3, slo_frac * 100.0));
            scaling_rows.push(Json::obj(vec![
                ("devices", Json::num(devices as f64)),
                ("rate_per_dev", Json::num(r)),
                ("completed", Json::num(rep.completed as f64)),
                ("offered", Json::num(rep.offered as f64)),
                ("p95_ttft_s", Json::num(p95)),
                ("slo_ttft_frac", Json::num(slo_frac)),
                ("wall_s", Json::num(rep.wall_s)),
            ]));
        }
        cells.push(format!("{wall:.2}"));
        t.row(&cells);
    }
    t.print();
    synera::log!(
        Info,
        "(worst-tenant p95; SLO fraction is completions-weighted across tenants)"
    );

    // ---- replica axis: scale the saturated 4096-device point out ----
    let mut t2 = Table::new(
        "Fig 19b: router replicas at 4096 devices, 0.25 req/s/dev (windowed)",
        &["replicas", "done", "p95 ttft", "slo-ttft", "migrations", "migr B", "wall s"],
    );
    for replicas in [1usize, 2, 4, 8] {
        let cfg = FleetConfig {
            n_devices: 4096,
            duration_s: 10.0,
            rate_rps: 1024.0,
            stop_s: 20.0,
            tenants: 4,
            params: SyneraParams {
                batch: BatchPolicy {
                    max_sessions: 64,
                    replicas,
                    // migrate when replica loads drift apart by > 8
                    rebalance_threshold: 8,
                    ..BatchPolicy::default()
                },
                ..SyneraParams::default()
            },
            seed: 0xF19B,
            prefix_share,
            prefix_len,
            ..FleetConfig::default()
        };
        let rep = run_fleet(&cfg)?;
        let (p95, slo_frac) = fleet_slo(&rep);
        t2.row(&[
            replicas.to_string(),
            format!("{}/{}", rep.completed, rep.offered),
            format!("{:.0}ms", p95 * 1e3),
            format!("{:.0}%", slo_frac * 100.0),
            rep.migrations.to_string(),
            rep.migration_bytes.to_string(),
            format!("{:.2}", rep.wall_s),
        ]);
        replica_rows.push(Json::obj(vec![
            ("replicas", Json::num(replicas as f64)),
            ("completed", Json::num(rep.completed as f64)),
            ("offered", Json::num(rep.offered as f64)),
            ("p95_ttft_s", Json::num(p95)),
            ("slo_ttft_frac", Json::num(slo_frac)),
            ("migrations", Json::num(rep.migrations as f64)),
            ("migration_bytes", Json::num(rep.migration_bytes as f64)),
            ("wall_s", Json::num(rep.wall_s)),
        ]));
    }
    t2.print();
    synera::log!(
        Info,
        "(same seed per row; per-tenant reports are bit-reproducible at any fixed R)"
    );
    if args.has_flag("json") {
        let results = Json::obj(vec![
            ("scaling", Json::Arr(scaling_rows)),
            ("replicas", Json::Arr(replica_rows)),
        ]);
        let path = write_bench_json("fig19", results)?;
        synera::log!(Info, "wrote {}", path.display());
    }
    Ok(())
}
