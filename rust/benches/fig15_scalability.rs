//! Fig. 15 — cloud scalability: verification latency vs offered request
//! rate for offloading budgets 0.3 / 0.6 / 0.9 (discrete-event sim over
//! the real scheduler+engine; virtual time advances by measured tick
//! compute, arrivals are Poisson).
//!
//! The second table adds a cloud-centric background load (prefill +
//! decode rows) to the verify stream: under the mixed
//! continuous-batching scheduler all three classes share iterations, so
//! verification latency degrades gracefully instead of queueing behind
//! whole prefill/decode phases.
//!
//! The third table sweeps *concurrent logical sessions* past the
//! compiled B=4 batch width: without paging (`max_sessions = B`)
//! sessions beyond B queue at admission and their rounds see the
//! latency knee at B; with paged KV (`max_sessions = sessions`) every
//! session is admitted and the knee moves out to the host-memory bound,
//! at the cost of the reported swap traffic.

//! The fourth table (15d) sweeps the *shared-prefix ratio* of the
//! arrival population at fixed host memory: as more arrivals carry a
//! common preamble, admission dedups their leading KV blocks against
//! the prefix cache and the same block budget admits more sessions
//! with less prefill and swap traffic.
//!
//! `--json` additionally writes `BENCH_fig15.json` with the raw rows
//! of all four tables (rate sweep, background load, paged sessions,
//! prefix share). Tables 15a–c need compiled model artifacts and are
//! skipped — with empty JSON rows — when none are installed; 15d runs
//! anywhere (mock-engine fleet sim).

use synera::bench::{write_bench_json, Table};
use synera::cloud::scheduler::{CloudEvent, CloudRequest, Scheduler};
use synera::config::{BatchPolicy, SyneraParams};
use synera::model::CloudEngine;
use synera::net::wire::Dist;
use synera::runtime::Runtime;
use synera::sim::{run_fleet, FleetConfig};
use synera::util::cli::Args;
use synera::util::json::Json;
use synera::util::rng::Rng;

enum Work {
    Verify { uncached: Vec<u32>, draft: Vec<u32> },
    Generate { prompt: Vec<u32>, max_new: usize },
}

struct Arrival {
    at: f64,
    id: u64,
    work: Work,
}

/// Simulate `user_rps` offloading users (plus `gen_rps` cloud-centric
/// users when non-zero); returns verify p50 latency and the completed
/// fraction across both classes.
fn simulate(
    rt: &std::rc::Rc<Runtime>,
    budget: f64,
    user_rps: f64,
    gen_rps: f64,
) -> anyhow::Result<(f64, f64)> {
    let gamma = rt.meta.gamma;
    // effective offload fraction under the importance filter (budget +
    // sigmoid smear), verifies per user request, uncached gap per verify
    let offl = (budget + 0.15).min(1.0);
    let verifies_per_req = ((16.0 * offl / gamma as f64).ceil()) as usize;
    let verify_rps = user_rps * verifies_per_req as f64;
    let uncached_len = ((gamma as f64 * (1.0 - offl) / offl).round() as usize).max(1);

    let mut rng = Rng::new(0xF15 ^ (budget * 100.0) as u64 ^ user_rps as u64);
    let horizon = 1.2; // virtual seconds
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    let mut id = 1u64;
    while t < horizon {
        t += rng.exp(verify_rps);
        if t >= horizon {
            break;
        }
        arrivals.push(Arrival {
            at: t,
            id,
            work: Work::Verify {
                uncached: (0..uncached_len).map(|_| 200 + rng.below(128) as u32).collect(),
                draft: (0..gamma).map(|_| 200 + rng.below(128) as u32).collect(),
            },
        });
        id += 1;
    }
    if gen_rps > 0.0 {
        let mut t = 0.0;
        while t < horizon {
            t += rng.exp(gen_rps);
            if t >= horizon {
                break;
            }
            arrivals.push(Arrival {
                at: t,
                id,
                work: Work::Generate {
                    prompt: (0..24).map(|_| 200 + rng.below(128) as u32).collect(),
                    max_new: 8,
                },
            });
            id += 1;
        }
        arrivals.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
    }

    // default BatchPolicy: mixed batching, budget = engine capacity
    let mut sched = Scheduler::new(CloudEngine::new(rt.model("l13b")?)?, 0x5CA1E);
    let mut now = 0.0f64;
    let mut next = 0usize;
    let mut start_at = std::collections::HashMap::new();
    let mut lats = Vec::new();
    let mut done = 0usize;
    // cap simulated work so overload points terminate
    let max_ticks = 2_500;
    for _ in 0..max_ticks {
        while next < arrivals.len() && arrivals[next].at <= now {
            let a = &arrivals[next];
            start_at.insert(a.id, a.at);
            match &a.work {
                Work::Verify { uncached, draft } => sched.submit(CloudRequest::Verify {
                    request_id: a.id,
                    device_id: a.id as u32,
                    uncached: uncached.clone(),
                    draft: draft.clone(),
                    dists: vec![Dist::Dense(vec![1.0 / 512.0; 512]); draft.len()],
                    greedy: true,
                    ctx: Default::default(),
                })?,
                Work::Generate { prompt, max_new } => sched.submit(CloudRequest::Generate {
                    request_id: a.id,
                    prompt: prompt.clone(),
                    max_new: *max_new,
                })?,
            }
            next += 1;
        }
        if sched.is_idle() {
            match arrivals.get(next) {
                Some(a) => {
                    now = a.at;
                    continue;
                }
                None => break,
            }
        }
        let (events, dt) = sched.tick()?;
        now += dt.max(1e-6);
        for e in events {
            match e {
                CloudEvent::VerifyDone { request_id, .. } => {
                    lats.push(now - start_at[&request_id]);
                    done += 1;
                    sched.submit(CloudRequest::Release { request_id })?;
                }
                CloudEvent::Generated { .. } => done += 1,
            }
        }
    }
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lats.get(lats.len() / 2).copied().unwrap_or(f64::NAN);
    let done_frac = done as f64 / arrivals.len().max(1) as f64;
    Ok((p50, done_frac))
}

/// Closed-loop sweep for the paged-KV table: `n_sessions` persistent
/// verify sessions each run `rounds` back-to-back rounds; virtual time
/// advances by measured tick compute. Returns (p50 round latency s,
/// completed fraction, swap-ins, swap-outs).
fn simulate_sessions(
    rt: &std::rc::Rc<Runtime>,
    n_sessions: usize,
    max_sessions: usize,
    rounds: usize,
) -> anyhow::Result<(f64, f64, u64, u64)> {
    let gamma = rt.meta.gamma;
    let policy = BatchPolicy { max_sessions, ..BatchPolicy::default() };
    let mut sched =
        Scheduler::with_policy(CloudEngine::new(rt.model("l13b")?)?, 0x5E55, policy);
    let mut rng = Rng::new(0xF15C ^ n_sessions as u64);
    let submit = |sched: &mut Scheduler<CloudEngine>, rng: &mut Rng, id: u64| {
        let uncached: Vec<u32> = (0..3).map(|_| 200 + rng.below(128) as u32).collect();
        let draft: Vec<u32> = (0..gamma).map(|_| 200 + rng.below(128) as u32).collect();
        let dists = vec![Dist::Dense(vec![1.0 / 512.0; 512]); draft.len()];
        sched.submit(CloudRequest::Verify {
            request_id: id,
            device_id: id as u32,
            uncached,
            draft,
            dists,
            greedy: true,
            ctx: Default::default(),
        })
    };
    let mut now = 0.0f64;
    let mut submitted_at: std::collections::HashMap<u64, f64> =
        std::collections::HashMap::new();
    let mut rounds_done: std::collections::HashMap<u64, usize> =
        std::collections::HashMap::new();
    for id in 1..=n_sessions as u64 {
        submitted_at.insert(id, 0.0);
        rounds_done.insert(id, 0);
        submit(&mut sched, &mut rng, id)?;
    }
    let total = n_sessions * rounds;
    let mut lats = Vec::with_capacity(total);
    let mut completed = 0usize;
    for _ in 0..50_000 {
        if completed == total {
            break;
        }
        let (events, dt) = sched.tick()?;
        now += dt.max(1e-6);
        for e in events {
            if let CloudEvent::VerifyDone { request_id, .. } = e {
                lats.push(now - submitted_at[&request_id]);
                completed += 1;
                let done = rounds_done.get_mut(&request_id).expect("known session");
                *done += 1;
                if *done < rounds {
                    submitted_at.insert(request_id, now);
                    submit(&mut sched, &mut rng, request_id)?;
                } else {
                    sched.submit(CloudRequest::Release { request_id })?;
                }
            }
        }
    }
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lats.get(lats.len() / 2).copied().unwrap_or(f64::NAN);
    Ok((
        p50,
        completed as f64 / total.max(1) as f64,
        sched.stats.swap_ins,
        sched.stats.swap_outs,
    ))
}

/// NaN-safe JSON number: overloaded points with no completions have no
/// p50, which must serialize as `null` rather than invalid `NaN`.
fn jnum(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let mut rate_rows: Vec<Json> = Vec::new();
    let mut bg_rows: Vec<Json> = Vec::new();
    let mut session_rows: Vec<Json> = Vec::new();
    // 15a–c drive the real engine and need compiled artifacts; on
    // machines without them (CI) the bench still runs 15d
    match Runtime::load_default() {
        Ok(rt) => {
            engine_tables(&rt, &mut rate_rows, &mut bg_rows, &mut session_rows)?
        }
        Err(e) => synera::log!(
            Info,
            "model artifacts unavailable ({e:#}); skipping Figs 15a-c, running 15d only"
        ),
    }
    let prefix_rows = prefix_share_table()?;
    if args.has_flag("json") {
        let results = Json::obj(vec![
            ("rate_sweep", Json::Arr(rate_rows)),
            ("background_load", Json::Arr(bg_rows)),
            ("paged_sessions", Json::Arr(session_rows)),
            ("prefix_share", Json::Arr(prefix_rows)),
        ]);
        let path = write_bench_json("fig15", results)?;
        synera::log!(Info, "wrote {}", path.display());
    }
    Ok(())
}

/// Figs 15a–c: the artifact-dependent tables over the real engine.
fn engine_tables(
    rt: &std::rc::Rc<Runtime>,
    rate_rows: &mut Vec<Json>,
    bg_rows: &mut Vec<Json>,
    session_rows: &mut Vec<Json>,
) -> anyhow::Result<()> {
    // warm the engine (compile) before timing-sensitive simulation
    let _ = simulate(rt, 0.3, 5.0, 0.0)?;
    let mut t = Table::new(
        "Fig 15: verification latency (p50, ms) vs offered user request rate",
        &["user req/s", "budget 0.3", "budget 0.6", "budget 0.9"],
    );
    for rps in [5.0, 15.0, 40.0, 90.0, 180.0] {
        let mut cells = vec![format!("{rps}")];
        for b in [0.3, 0.6, 0.9] {
            let (p50, done) = simulate(rt, b, rps, 0.0)?;
            if done < 0.9 {
                cells.push(format!("{:.1} (overload)", p50 * 1e3));
            } else {
                cells.push(format!("{:.1}", p50 * 1e3));
            }
            rate_rows.push(Json::obj(vec![
                ("user_rps", Json::num(rps)),
                ("budget", Json::num(b)),
                ("verify_p50_s", jnum(p50)),
                ("done_frac", Json::num(done)),
            ]));
        }
        t.row(&cells);
    }
    t.print();

    let mut t2 = Table::new(
        "Fig 15b: verify p50 (ms) with cloud-centric background load (20% of user rate)",
        &["user req/s", "budget 0.3", "budget 0.9"],
    );
    for rps in [15.0, 40.0, 90.0] {
        let mut cells = vec![format!("{rps}")];
        for b in [0.3, 0.9] {
            let (p50, done) = simulate(rt, b, rps, rps * 0.2)?;
            if done < 0.9 {
                cells.push(format!("{:.1} (overload)", p50 * 1e3));
            } else {
                cells.push(format!("{:.1}", p50 * 1e3));
            }
            bg_rows.push(Json::obj(vec![
                ("user_rps", Json::num(rps)),
                ("gen_rps", Json::num(rps * 0.2)),
                ("budget", Json::num(b)),
                ("verify_p50_s", jnum(p50)),
                ("done_frac", Json::num(done)),
            ]));
        }
        t2.row(&cells);
    }
    t2.print();

    let mut t3 = Table::new(
        "Fig 15c: paged KV — verify round p50 (ms) vs concurrent sessions (B=4 slots)",
        &["sessions", "no paging (cap=B)", "paged (cap=sessions)", "swaps in/out"],
    );
    for s in [2usize, 4, 8, 16, 32] {
        let (p_base, done_base, _, _) = simulate_sessions(rt, s, 0, 4)?;
        let (p_paged, done_paged, si, so) = simulate_sessions(rt, s, s, 4)?;
        let cell = |p: f64, done: f64| {
            if done < 1.0 {
                format!("{:.1} (incomplete)", p * 1e3)
            } else {
                format!("{:.1}", p * 1e3)
            }
        };
        t3.row(&[
            s.to_string(),
            cell(p_base, done_base),
            cell(p_paged, done_paged),
            format!("{si}/{so}"),
        ]);
        session_rows.push(Json::obj(vec![
            ("sessions", Json::num(s as f64)),
            ("p50_unpaged_s", jnum(p_base)),
            ("done_frac_unpaged", Json::num(done_base)),
            ("p50_paged_s", jnum(p_paged)),
            ("done_frac_paged", Json::num(done_paged)),
            ("swap_ins", Json::num(si as f64)),
            ("swap_outs", Json::num(so as f64)),
        ]));
    }
    t3.print();
    Ok(())
}

/// Fig 15d: shared-prefix ratio sweep at fixed host memory, over the
/// artifact-free mock-engine fleet (96 devices, one replica with 4
/// engine slots and a 48-session paged cap). Every row sees the same
/// arrival process; only the fraction of arrivals carrying a shared
/// preamble changes. Rising share turns prompt rows into prefix-cache
/// hits, which shrinks both prefill work and swap traffic.
fn prefix_share_table() -> anyhow::Result<Vec<Json>> {
    let mut rows = Vec::new();
    let mut t = Table::new(
        "Fig 15d: shared-prefix ratio at fixed host memory (96 devices, 48-session cap)",
        &["share", "done", "pfx-hit rows", "swaps in/out", "swap B", "p95 ttft"],
    );
    for share in [0.0f64, 0.3, 0.6, 0.9] {
        let cfg = FleetConfig {
            n_devices: 96,
            duration_s: 6.0,
            rate_rps: 32.0,
            tenants: 2,
            params: SyneraParams {
                batch: BatchPolicy { max_sessions: 48, ..BatchPolicy::default() },
                ..SyneraParams::default()
            },
            prefix_share: share,
            prefix_len: 32,
            seed: 0xF15D,
            ..FleetConfig::default()
        };
        let rep = run_fleet(&cfg)?;
        let hit_rows: u64 = rep.tenants.iter().map(|t| t.prefix_hit_rows).sum();
        let p95 = rep.tenants.iter().map(|t| t.ttft.p95).fold(0.0f64, f64::max);
        t.row(&[
            format!("{share:.1}"),
            format!("{}/{}", rep.completed, rep.offered),
            hit_rows.to_string(),
            format!("{}/{}", rep.swap_ins, rep.swap_outs),
            rep.swap_bytes.to_string(),
            format!("{:.0}ms", p95 * 1e3),
        ]);
        rows.push(Json::obj(vec![
            ("share", Json::num(share)),
            ("completed", Json::num(rep.completed as f64)),
            ("offered", Json::num(rep.offered as f64)),
            ("prefix_hit_rows", Json::num(hit_rows as f64)),
            ("swap_ins", Json::num(rep.swap_ins as f64)),
            ("swap_outs", Json::num(rep.swap_outs as f64)),
            ("swap_bytes", Json::num(rep.swap_bytes as f64)),
            ("p95_ttft_s", jnum(p95)),
        ]));
    }
    t.print();
    synera::log!(
        Info,
        "(same seed per row: identical arrivals, only the preamble share differs)"
    );
    Ok(rows)
}
