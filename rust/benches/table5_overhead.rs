//! Table 5 — device runtime overheads: offloading-decision latency per
//! token and energy per token across module ablations.

use std::time::Instant;

use synera::bench::{f3, Table};
use synera::config::{Scenario, SyneraParams};
use synera::coordinator::eval::{eval_with_profile, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::device::offload::Selector;
use synera::profiling::load_or_profile;
use synera::runtime::Runtime;
use synera::workload::synthlang::Task;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let profile = load_or_profile(&rt, "s1b", None, "l13b")?;
    let opts = EvalOptions { n_samples: 8, task: Task::Xsum };

    // (1) scheduling (P_conf + P_imp) latency per token — measured directly
    let mut sel = Selector::new(profile.c_th, profile.i_th_for_budget(0.2), SyneraParams::default());
    let iters = 100_000;
    let t0 = Instant::now();
    let mut acc = 0usize;
    for i in 0..iters {
        let c = 0.2 + (i % 7) as f32 * 0.1;
        let d = sel.decide(&[c; 4], &[0.5; 4]);
        acc += d.offload as usize;
    }
    let per_chunk_us = t0.elapsed().as_secs_f64() / iters as f64 * 1e6;
    std::hint::black_box(acc);

    let mut t = Table::new(
        "Table 5: device runtime overheads (s1b&l13b, XSum)",
        &["method", "sched latency/token", "energy/token (J)", "vs Edge-centric (J)"],
    );
    let mk = |f: &dyn Fn(&mut Scenario)| {
        let mut s = Scenario::default_pair("s1b", "l13b");
        f(&mut s);
        s
    };
    let variants: Vec<(&str, Method, Scenario)> = vec![
        ("Edge-centric", Method::EdgeCentric, mk(&|_| {})),
        ("Edge-centric (w/ EE)", Method::EdgeCentric, mk(&|s| {
            s.params.early_exit = true;
        })),
        ("Synera (w/o EE)", Method::Synera, mk(&|s| s.params.early_exit = false)),
        ("Synera (w/o PI)", Method::Synera, mk(&|s| s.params.parallel_inference = false)),
        ("Synera", Method::Synera, mk(&|_| {})),
    ];
    let mut base_energy = None;
    for (name, m, mut scen) in variants {
        if name == "Edge-centric (w/ EE)" {
            // eval_method would re-disable EE for the baseline; force it
            scen.params.early_exit = true;
            let rep = eval_with_profile(&rt, &scen, m, &opts, &profile)?;
            let d = rep.energy_per_token_j - base_energy.unwrap_or(rep.energy_per_token_j);
            t.row(&["Edge-centric (w/ EE)".into(), "N/A".into(), f3(rep.energy_per_token_j), format!("{d:+.3}")]);
            continue;
        }
        let scen2 = scen.clone();
        let rep = if m == Method::EdgeCentric {
            let mut s = scen2;
            s.params.early_exit = false;
            eval_with_profile(&rt, &s, m, &opts, &profile)?
        } else {
            let mut s = scen2;
            s.params = synera::coordinator::eval::method_params(m, &s.params);
            // re-apply the ablation on top of the method defaults
            if name == "Synera (w/o EE)" {
                s.params.early_exit = false;
            }
            if name == "Synera (w/o PI)" {
                s.params.parallel_inference = false;
            }
            eval_with_profile(&rt, &s, m, &opts, &profile)?
        };
        if name == "Edge-centric" {
            base_energy = Some(rep.energy_per_token_j);
        }
        let sched_cell = if m == Method::EdgeCentric {
            "N/A".to_string()
        } else {
            format!("{:.2} µs (<0.5 ms)", per_chunk_us)
        };
        let d = rep.energy_per_token_j - base_energy.unwrap_or(rep.energy_per_token_j);
        t.row(&[name.into(), sched_cell, f3(rep.energy_per_token_j), format!("{d:+.3}")]);
    }
    t.print();
    Ok(())
}
