//! Table 4 — end-to-end generation quality: 3 model pairs × 7 datasets ×
//! 4 methods (Edge-centric, EdgeFM-LLM, Hybrid, Synera).
//!
//! `SYNERA_T4_N` overrides samples/dataset (default 10).

use synera::baselines::TABLE4_METHODS;
use synera::bench::Table;
use synera::config::{PairConfig, Scenario};
use synera::coordinator::eval::{eval_method, EvalOptions};
use synera::runtime::Runtime;
use synera::workload::synthlang::TASKS;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::var("SYNERA_T4_N").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
    let rt = Runtime::load_default()?;
    for pair in PairConfig::table4_pairs() {
        let mut t = Table::new(
            &format!("Table 4: generation quality — pair {}", pair.label()),
            &["method", "CNNDM", "XSum", "SensorQA", "HeySQuAD", "CSQA", "SST2", "LLQA"],
        );
        for m in TABLE4_METHODS {
            let mut cells = vec![m.name().to_string()];
            for task in [TASKS[2], TASKS[3], TASKS[6], TASKS[5], TASKS[0], TASKS[1], TASKS[4]] {
                let mut scen = Scenario::default_pair(&pair.slm, &pair.llm);
                scen.params.budget = 0.5; // working point (see EXPERIMENTS.md §Table 4)
                let rep = eval_method(&rt, &scen, m, &EvalOptions { n_samples: n, task })?;
                cells.push(format!("{:.1}", rep.quality * 100.0));
            }
            t.row(&cells);
        }
        t.print();
    }
    Ok(())
}
