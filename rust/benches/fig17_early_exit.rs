//! Fig. 17 — layer-wise early-exit threshold sweep 0.0 → 1.0 on CNNDM:
//! quality, device latency and exit rate.

use synera::bench::{f3, pct, Table};
use synera::config::Scenario;
use synera::coordinator::eval::{eval_with_profile, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::profiling::load_or_profile;
use synera::runtime::Runtime;
use synera::workload::synthlang::Task;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let profile = load_or_profile(&rt, "s1b", None, "l13b")?;
    let opts = EvalOptions { n_samples: 10, task: Task::Cnndm };
    let mut t = Table::new(
        "Fig 17: early-exit threshold sweep (s1b&l13b, CNNDM)",
        &["threshold", "quality", "tbt_ms", "exit rate", "energy/token (J)"],
    );
    for th in [0.0, 0.2, 0.4, 0.6, 0.8, 1.0] {
        let mut scen = Scenario::default_pair("s1b", "l13b");
        scen.params.exit_threshold = th;
        let rep = eval_with_profile(&rt, &scen, Method::Synera, &opts, &profile)?;
        t.row(&[
            format!("{th:.1}"),
            f3(rep.quality),
            format!("{:.1}", rep.tbt_s * 1e3),
            pct(rep.exit_rate),
            f3(rep.energy_per_token_j),
        ]);
    }
    t.print();
    Ok(())
}
