//! Fig. 13 — impact of bandwidth: TBT under 0.1–100 Mbps for Synera,
//! Synera w/o compression, Hybrid and EdgeFM-LLM.

use synera::bench::Table;
use synera::config::Scenario;
use synera::coordinator::eval::{eval_method, eval_with_profile, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::profiling::load_or_profile;
use synera::runtime::Runtime;
use synera::workload::synthlang::Task;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let opts = EvalOptions { n_samples: 8, task: Task::Xsum };
    let profile = load_or_profile(&rt, "s1b", None, "l13b")?;
    let mut t = Table::new(
        "Fig 13: TBT (ms) vs bandwidth (s1b&l13b, XSum)",
        &["bandwidth", "Synera", "Synera w/o compr.", "Hybrid", "EdgeFM-LLM"],
    );
    for mbps in [0.1, 1.0, 10.0, 100.0] {
        let mut scen = Scenario::default_pair("s1b", "l13b");
        scen.link.bandwidth_mbps = mbps;
        let syn = eval_with_profile(&rt, &scen, Method::Synera, &opts, &profile)?;
        let mut s2 = scen.clone();
        s2.params.compression = false;
        let noc = eval_with_profile(&rt, &s2, Method::Synera, &opts, &profile)?;
        let hy = eval_method(&rt, &scen, Method::Hybrid, &opts)?;
        let ef = eval_method(&rt, &scen, Method::EdgeFmLlm, &opts)?;
        t.row(&[
            format!("{mbps} Mbps"),
            format!("{:.1}", syn.tbt_s * 1e3),
            format!("{:.1}", noc.tbt_s * 1e3),
            format!("{:.1}", hy.tbt_s * 1e3),
            format!("{:.1}", ef.tbt_s * 1e3),
        ]);
    }
    t.print();
    Ok(())
}
