//! Fig. 11 — end-to-end latency (TBT) + generation quality on XSum for
//! the five deployment configurations, including the Synera ablation
//! variants (Conf-only, Imp-only, w/o PI).

use synera::bench::{f3, Table};
use synera::config::Scenario;
use synera::coordinator::eval::{eval_method, eval_with_profile, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::profiling::load_or_profile;
use synera::runtime::Runtime;
use synera::workload::synthlang::Task;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let opts = EvalOptions { n_samples: 8, task: Task::Xsum };
    let mut t = Table::new(
        "Fig 11: TBT (ms) and quality on XSum",
        &["config", "method", "tbt_ms", "quality", "pi_pos_hit"],
    );
    for (label, scen) in Scenario::fig11_configs() {
        let profile =
            load_or_profile(&rt, &scen.pair.slm, scen.pair.slm_weights.as_deref(), &scen.pair.llm)?;
        for m in [Method::EdgeCentric, Method::EdgeFmLlm, Method::Hybrid, Method::Synera] {
            let rep = eval_method(&rt, &scen, m, &opts)?;
            t.row(&[
                label.clone(),
                m.name().into(),
                format!("{:.1}", rep.tbt_s * 1e3),
                f3(rep.quality),
                f3(rep.pi_pos_hit_rate),
            ]);
        }
        // ablation variants of Synera
        for (name, f) in [
            ("Synera (Conf.)", Box::new(|s: &mut Scenario| s.params.use_imp = false)
                as Box<dyn Fn(&mut Scenario)>),
            ("Synera (Imp.)", Box::new(|s: &mut Scenario| s.params.use_conf = false)),
            ("Synera (w/o PI)", Box::new(|s: &mut Scenario| s.params.parallel_inference = false)),
        ] {
            let mut s = scen.clone();
            f(&mut s);
            let rep = eval_with_profile(&rt, &s, Method::Synera, &opts, &profile)?;
            t.row(&[
                label.clone(),
                name.into(),
                format!("{:.1}", rep.tbt_s * 1e3),
                f3(rep.quality),
                f3(rep.pi_pos_hit_rate),
            ]);
        }
    }
    t.print();
    Ok(())
}
