//! Fig. 18 — cloud-runtime scheduling overhead vs offloading budget:
//! scheduler bookkeeping time as a fraction of engine compute (higher
//! budgets → shorter verification chunks → relatively more scheduling).
//!
//! `--json` additionally writes `BENCH_fig18.json` with the raw
//! numbers plus the per-tick phase breakdown (wfq / paging / pack /
//! engine / commit seconds) from the scheduler's phase accounting.

use synera::bench::{pct, write_bench_json, Table};
use synera::cloud::scheduler::{CloudEvent, CloudRequest, Scheduler};
use synera::model::CloudEngine;
use synera::net::wire::Dist;
use synera::runtime::Runtime;
use synera::util::cli::Args;
use synera::util::json::Json;
use synera::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let rt = Runtime::load_default()?;
    let gamma = rt.meta.gamma;
    let mut t = Table::new(
        "Fig 18: scheduler overhead vs budget (verify stream, l13b)",
        &["budget", "uncached/verify", "engine ms/verify", "sched µs/verify", "overhead"],
    );
    let mut rows: Vec<Json> = Vec::new();
    for b in [0.1, 0.2, 0.4, 0.6, 0.8, 1.0] {
        let offl = (b as f64 + 0.15).min(1.0);
        let uncached_len = ((gamma as f64 * (1.0 - offl) / offl).round() as usize).max(1);
        // default BatchPolicy: mixed batching, budget = engine capacity
        let mut sched = Scheduler::new(CloudEngine::new(rt.model("l13b")?)?, 0xF18);
        let mut rng = Rng::new(0xF18);
        let n = 40;
        for i in 0..n {
            sched.submit(CloudRequest::Verify {
                request_id: i,
                device_id: 0,
                uncached: (0..uncached_len).map(|_| 200 + rng.below(128) as u32).collect(),
                draft: (0..gamma).map(|_| 200 + rng.below(128) as u32).collect(),
                dists: vec![Dist::Dense(vec![1.0 / 512.0; 512]); gamma],
                greedy: true,
                ctx: Default::default(),
            })?;
        }
        let mut done = 0;
        while done < n {
            let (events, _) = sched.tick()?;
            for e in events {
                if let CloudEvent::VerifyDone { request_id, .. } = e {
                    sched.submit(CloudRequest::Release { request_id })?;
                    done += 1;
                }
            }
        }
        let s = &sched.stats;
        let overhead = s.sched_overhead_s / (s.sched_overhead_s + s.busy_s);
        t.row(&[
            format!("{b:.1}"),
            uncached_len.to_string(),
            format!("{:.2}", s.busy_s / n as f64 * 1e3),
            format!("{:.1}", s.sched_overhead_s / n as f64 * 1e6),
            pct(overhead),
        ]);
        rows.push(Json::obj(vec![
            ("budget", Json::num(b)),
            ("uncached_per_verify", Json::num(uncached_len as f64)),
            ("verifies", Json::num(n as f64)),
            ("iterations", Json::num(s.iterations as f64)),
            ("engine_s_per_verify", Json::num(s.busy_s / n as f64)),
            ("sched_s_per_verify", Json::num(s.sched_overhead_s / n as f64)),
            ("overhead_frac", Json::num(overhead)),
            ("phase_wfq_s", Json::num(s.phase_wfq_s)),
            ("phase_paging_s", Json::num(s.phase_paging_s)),
            ("phase_pack_s", Json::num(s.phase_pack_s)),
            ("phase_engine_s", Json::num(s.phase_engine_s)),
            ("phase_commit_s", Json::num(s.phase_commit_s)),
        ]));
    }
    t.print();
    if args.has_flag("json") {
        let path = write_bench_json("fig18", Json::Arr(rows))?;
        synera::log!(Info, "wrote {}", path.display());
    }
    Ok(())
}
