//! Fig. 16 — dual-metric ablation: P_conf-only vs P_imp-only vs both,
//! quality and offload volume at the same budget.

use synera::bench::{f3, Table};
use synera::config::Scenario;
use synera::coordinator::eval::{eval_with_profile, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::profiling::load_or_profile;
use synera::runtime::Runtime;
use synera::workload::synthlang::Task;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let mut t = Table::new(
        "Fig 16: P_conf / P_imp ablation (XSum)",
        &["pair", "variant", "quality", "tbt_ms", "offload rate", "W"],
    );
    for (slm, llm) in [("s160m", "l13b"), ("s1b", "l13b")] {
        let profile = load_or_profile(&rt, slm, None, llm)?;
        let opts = EvalOptions { n_samples: 8, task: Task::Xsum };
        for (name, conf, imp) in [
            ("Synera (Conf.)", true, false),
            ("Synera (Imp.)", false, true),
            ("Synera (both)", true, true),
        ] {
            let mut scen = Scenario::default_pair(slm, llm);
            scen.params.use_conf = conf;
            scen.params.use_imp = imp;
            let rep = eval_with_profile(&rt, &scen, Method::Synera, &opts, &profile)?;
            t.row(&[
                format!("{slm}&{llm}"),
                name.into(),
                f3(rep.quality),
                format!("{:.1}", rep.tbt_s * 1e3),
                f3(rep.offload_rate),
                f3(rep.w),
            ]);
        }
    }
    t.print();
    Ok(())
}
