//! Table 6 — Synera composed with complementary SLM acceleration
//! (BnB-4bit / AWQ weight quantization) on XSum with the s7b&l70b pair.

use synera::bench::{f2, Table};
use synera::config::Scenario;
use synera::coordinator::eval::{eval_method, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::runtime::Runtime;
use synera::workload::synthlang::Task;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let opts = EvalOptions { n_samples: 8, task: Task::Xsum };
    let mut t = Table::new(
        "Table 6: Synera + quantized SLMs (s7b&l70b, XSum)",
        &["method", "speedup (norm)", "quality", "relative quality (norm)"],
    );
    // memory-bound decode speedup from 4-bit weights (device profile)
    for (variant, qspeed) in [(None, 1.0), (Some("s7b_bnb4"), 1.15), (Some("s7b_awq"), 1.35)] {
        let mut scen = Scenario::default_pair("s7b", "l70b");
        scen.pair.slm_weights = variant.map(|s| s.to_string());
        scen.device = scen.device.with_quant_speedup(qspeed);
        let edge = eval_method(&rt, &scen, Method::EdgeCentric, &opts)?;
        let syn = eval_method(&rt, &scen, Method::Synera, &opts)?;
        let label = variant.map(|v| v.replace("s7b_", " + ")).unwrap_or_default();
        t.row(&[
            format!("Edge-centric{label}"),
            "1.00".into(),
            f2(edge.quality * 100.0),
            "1.00".into(),
        ]);
        t.row(&[
            format!("Synera{label}"),
            f2(edge.tbt_s / syn.tbt_s.max(1e-9)),
            f2(syn.quality * 100.0),
            f2(syn.quality / edge.quality.max(1e-9)),
        ]);
    }
    t.print();
    Ok(())
}
