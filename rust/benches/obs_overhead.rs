//! Observability overhead guard: with tracing disabled the obs layer
//! must cost nothing measurable, and with tail-based sampling on it
//! must stay close to the full-retention trace. Checks:
//!
//! 1. micro: per-call cost of the disabled `trace::with` hot path
//!    (one `Option` branch — should be ~1 ns);
//! 2. micro: per-event cost of the sampler staging path (stage +
//!    wholesale discard at completion), the hot loop a sampled fleet
//!    adds over the plain ring push;
//! 3. macro: the same fleet simulation run with `trace: None`, a full
//!    ring sink, and a sampled sink, reporting the wall-clock ratios.
//!
//! Wall times are reported, not asserted — bench timing is too noisy
//! for a hard CI gate. `--json` writes `BENCH_obs.json`: the timing
//! leaves (`*_s`, `*wall*`) stay informational in `bench_diff`, while
//! the sampler's deterministic retention counters gate at the default
//! tolerance, so a retention-policy regression (suddenly keeping or
//! dropping a different population) fails CI even though timing can't.

use std::hint::black_box;
use std::time::Instant;

use synera::bench::{f2, fmt_s, write_bench_json, Table};
use synera::obs::sampler::SamplerConfig;
use synera::obs::trace::{self, TraceShared, TraceSink};
use synera::sim::{run_fleet, FleetConfig};
use synera::util::cli::Args;
use synera::util::json::Json;

/// Best-of-`reps` fleet wall time under the given trace config.
fn fleet_wall(trace: Option<TraceShared>, reps: usize) -> anyhow::Result<f64> {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let cfg = FleetConfig {
            n_devices: 128,
            duration_s: 4.0,
            rate_rps: 48.0,
            tenants: 4,
            seed: 0x0B5,
            trace: trace.clone(),
            ..FleetConfig::default()
        };
        let t0 = Instant::now();
        let rep = run_fleet(&cfg)?;
        best = best.min(t0.elapsed().as_secs_f64());
        black_box(rep.completed);
    }
    Ok(best)
}

fn sampled_sink() -> TraceShared {
    trace::shared(
        TraceSink::virtual_time(1 << 20)
            .with_sampler(SamplerConfig { head_every: 64, tail_k: 32, seed: 0 }),
    )
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;

    // micro: disabled trace::with is one None branch per call
    let off: Option<TraceShared> = None;
    let iters = 50_000_000u64;
    let t0 = Instant::now();
    for i in 0..iters {
        trace::with(black_box(&off), |s| {
            s.instant(0, 0, "never", i, Vec::new());
        });
    }
    let per_call = t0.elapsed().as_secs_f64() / iters as f64;

    // micro: the sampler staging path — stage a request's events, then
    // discard them wholesale at completion (the common fate under
    // 1-in-64 head sampling). 8 events per request ≈ the fleet shape.
    let staged_iters = 200_000u64;
    let events_per_req = 8u64;
    let sh = sampled_sink();
    let t0 = Instant::now();
    trace::with(&Some(sh.clone()), |s| {
        for req in 1..=staged_iters {
            for _ in 0..events_per_req {
                s.instant(2, 0, "stage", req, Vec::new());
            }
            s.complete_request(req, 0.001, false);
        }
    });
    let per_staged_event = t0.elapsed().as_secs_f64() / (staged_iters * events_per_req) as f64;

    // macro: identical fleet run with the sink absent, full, sampled
    let wall_off = fleet_wall(None, 3)?;
    let wall_full = fleet_wall(Some(trace::shared(TraceSink::virtual_time(1 << 20))), 3)?;
    let sampled = sampled_sink();
    let wall_sampled = fleet_wall(Some(sampled.clone()), 3)?;
    // deterministic retention counters from the *last* rep (same seed
    // every rep, so any rep reads identically)
    let st = sampled.lock().unwrap().sampler_stats().expect("sampler attached");

    let mut t = Table::new(
        "obs overhead: tracing disabled must be free, sampling near-free",
        &["check", "value"],
    );
    t.row(&["disabled trace::with / call".into(), fmt_s(per_call)]);
    t.row(&["sampler staging / event".into(), fmt_s(per_staged_event)]);
    t.row(&["fleet wall, trace off".into(), fmt_s(wall_off)]);
    t.row(&["fleet wall, full trace".into(), fmt_s(wall_full)]);
    t.row(&["fleet wall, sampled trace".into(), fmt_s(wall_sampled)]);
    t.row(&["full/off ratio".into(), f2(wall_full / wall_off)]);
    t.row(&["sampled/full ratio".into(), f2(wall_sampled / wall_full)]);
    t.row(&["sampled retained events".into(), st.retained_events.to_string()]);
    t.row(&["sampled discarded events".into(), st.discarded_events.to_string()]);
    t.print();

    if args.has_flag("json") {
        let results = Json::obj(vec![
            ("disabled_with_s", Json::num(per_call)),
            ("staging_event_s", Json::num(per_staged_event)),
            ("wall_off_s", Json::num(wall_off)),
            ("wall_full_s", Json::num(wall_full)),
            ("wall_sampled_s", Json::num(wall_sampled)),
            ("full_vs_off_wall", Json::num(wall_full / wall_off)),
            ("sampled_vs_full_wall", Json::num(wall_sampled / wall_full)),
            // deterministic (same-seed) retention counters: these gate
            ("sampler_completed", Json::num(st.completed as f64)),
            ("sampler_head_retained", Json::num(st.head_retained as f64)),
            ("sampler_tail_retained", Json::num(st.tail_retained as f64)),
            ("sampler_retained_events", Json::num(st.retained_events as f64)),
            ("sampler_discarded_events", Json::num(st.discarded_events as f64)),
            ("sampler_peak_staged_events", Json::num(st.peak_staged_events as f64)),
        ]);
        let path = write_bench_json("obs", results)?;
        synera::log!(Info, "wrote {}", path.display());
    }
    Ok(())
}
