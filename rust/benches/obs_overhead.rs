//! Observability overhead guard: with tracing disabled the obs layer
//! must cost nothing measurable. Two checks:
//!
//! 1. micro: per-call cost of the disabled `trace::with` hot path
//!    (one `Option` branch — should be ~1 ns);
//! 2. macro: the same fleet simulation run with `trace: None` vs a
//!    live sink, reporting the wall-clock ratio. The disabled run is
//!    the shipping configuration; the enabled run bounds what `--trace`
//!    costs on top.
//!
//! Reported, not asserted: bench wall times are too noisy for a hard
//! CI gate, but the micro number makes regressions obvious at a
//! glance (a disabled-path regression shows up as 10-100× here).

use std::hint::black_box;
use std::time::Instant;

use synera::bench::{f2, fmt_s, Table};
use synera::obs::trace::{self, TraceShared, TraceSink};
use synera::sim::{run_fleet, FleetConfig};

/// Best-of-`reps` fleet wall time under the given trace config.
fn fleet_wall(trace: Option<TraceShared>, reps: usize) -> anyhow::Result<f64> {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let cfg = FleetConfig {
            n_devices: 128,
            duration_s: 4.0,
            rate_rps: 48.0,
            tenants: 4,
            seed: 0x0B5,
            trace: trace.clone(),
            ..FleetConfig::default()
        };
        let t0 = Instant::now();
        let rep = run_fleet(&cfg)?;
        best = best.min(t0.elapsed().as_secs_f64());
        black_box(rep.completed);
    }
    Ok(best)
}

fn main() -> anyhow::Result<()> {
    // micro: disabled trace::with is one None branch per call
    let off: Option<TraceShared> = None;
    let iters = 50_000_000u64;
    let t0 = Instant::now();
    for i in 0..iters {
        trace::with(black_box(&off), |s| {
            s.instant(0, 0, "never", i, Vec::new());
        });
    }
    let per_call = t0.elapsed().as_secs_f64() / iters as f64;

    // macro: identical fleet run with the sink absent vs live
    let wall_off = fleet_wall(None, 3)?;
    let wall_on = fleet_wall(Some(trace::shared(TraceSink::virtual_time(1 << 20))), 3)?;

    let mut t = Table::new(
        "obs overhead: tracing disabled must be free",
        &["check", "value"],
    );
    t.row(&["disabled trace::with / call".into(), fmt_s(per_call)]);
    t.row(&["fleet wall, trace off".into(), fmt_s(wall_off)]);
    t.row(&["fleet wall, trace on".into(), fmt_s(wall_on)]);
    t.row(&["on/off ratio".into(), f2(wall_on / wall_off)]);
    t.print();
    Ok(())
}
