//! Fig. 12 — estimated cloud serving cost (c = 1/Pf × T × W) on XSum
//! across the five deployment configurations.

use synera::bench::Table;
use synera::config::Scenario;
use synera::coordinator::eval::{eval_method, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::runtime::Runtime;
use synera::workload::synthlang::Task;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let opts = EvalOptions { n_samples: 8, task: Task::Xsum };
    let mut t = Table::new(
        "Fig 12: estimated cloud serving cost on XSum (milli-units)",
        &["config", "Cloud-centric", "EdgeFM-LLM", "Hybrid", "Synera", "synera vs cloud"],
    );
    for (label, scen) in Scenario::fig11_configs() {
        let mut cells = vec![label];
        let mut costs = Vec::new();
        for m in [Method::CloudCentric, Method::EdgeFmLlm, Method::Hybrid, Method::Synera] {
            let rep = eval_method(&rt, &scen, m, &opts)?;
            costs.push(rep.cost);
            cells.push(format!("{:.3}", rep.cost * 1e3));
        }
        let rel = if costs[0] > 0.0 { costs[3] / costs[0] } else { 0.0 };
        cells.push(format!("{:.1}%", rel * 100.0));
        t.row(&cells);
    }
    t.print();
    Ok(())
}
