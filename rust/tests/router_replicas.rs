//! Router/multi-replica gates — artifact-free. Exercises the
//! router-fronted cloud tier ([`synera::cloud::router::Router`]) over
//! deterministic [`MockBatchEngine`] replicas:
//!
//! * cross-replica KV migration round trips **bit-identically** through
//!   the real [`KvMigrateMsg`] wire encoding, and the migrated session
//!   keeps verifying on its new home to its exact token budget;
//! * migrated bytes are priced over the real encoding (the record's
//!   byte count equals `encode().len()`), and session affinity holds —
//!   a busy session never migrates;
//! * placement spreads a skewed tenant; threshold-driven rebalancing
//!   converges to the configured gap;
//! * under random traffic with forced rebalances, no session is ever
//!   resident on two replicas and every replica conserves its slots
//!   and blocks;
//! * the fleet simulator is bit-reproducible at R > 1 with rebalancing
//!   enabled.

use std::collections::HashSet;

use synera::cloud::router::Router;
use synera::cloud::scheduler::{CloudEvent, CloudRequest};
use synera::cloud::verifier::VerifyOutcome;
use synera::config::{BatchPolicy, SyneraParams};
use synera::model::cloud_engine::BatchEngine;
use synera::net::wire::{Dist, KvMigrateMsg};
use synera::runtime::SlotKv;
use synera::sim::{run_fleet, FleetConfig};
use synera::testutil::{check, usize_in, MockBatchEngine, MOCK_KV_ROW};

const VOCAB: usize = 64;

fn dense_dists(n: usize) -> Vec<Dist> {
    vec![Dist::Dense(vec![1.0 / VOCAB as f32; VOCAB]); n]
}

fn router_with(n: usize, policy: &BatchPolicy) -> Router<MockBatchEngine> {
    let engines = (0..n).map(|_| MockBatchEngine::new(4, 32, VOCAB, 4096)).collect();
    Router::new(engines, 0x7E57_0001, policy).unwrap()
}

fn verify_req(id: u64, uncached: Vec<u32>, draft: Vec<u32>) -> CloudRequest {
    let n = draft.len();
    CloudRequest::Verify {
        request_id: id,
        device_id: id as u32,
        uncached,
        draft,
        dists: dense_dists(n),
        greedy: true,
        ctx: Default::default(),
    }
}

/// Tick replica `r` until it surfaces `VerifyDone` for `id`.
fn drive_to_verify_done(
    router: &mut Router<MockBatchEngine>,
    r: usize,
    id: u64,
) -> VerifyOutcome {
    for _ in 0..200 {
        let (events, _) = router.tick_replica(r).unwrap();
        for e in events {
            if let CloudEvent::VerifyDone { request_id, outcome, .. } = e {
                if request_id == id {
                    return outcome;
                }
            }
        }
    }
    panic!("verify round for session {id} never completed on replica {r}");
}

/// The committed KV image of a resident session, read off the engine.
fn resident_kv(router: &Router<MockBatchEngine>, r: usize, id: u64) -> SlotKv {
    let s = router.replica(r);
    let slot = s.sessions().slot_of(id).expect("session is resident");
    s.engine.export_slot(slot)
}

fn assert_replica_conserved(router: &Router<MockBatchEngine>, r: usize) {
    let s = router.replica(r);
    assert_eq!(s.engine.free_slots(), s.engine.slots, "replica {r}: slots returned");
    assert_eq!(s.engine.allocs, s.engine.frees, "replica {r}: slot conservation");
    assert_eq!(
        s.sessions().free_blocks(),
        s.sessions().block_capacity(),
        "replica {r}: block conservation"
    );
}

/// The tentpole gate: a verify session is bounced between two replicas
/// at every round boundary. Each migration's KV image must round trip
/// bit-for-bit through the real wire encoding, its priced byte count
/// must equal the actual encoding length, and the session must keep
/// verifying on its new home replica to *exactly* its token budget.
#[test]
fn migrated_session_round_trips_bit_identical_and_finishes_its_budget() {
    let mut router = router_with(2, &BatchPolicy::default());
    const ID: u64 = 42;
    let max_new = 6usize;
    let mut seq: Vec<u32> = vec![12, 13, 14, 15]; // prompt + commits
    let mut cloud_len = 0usize;
    let mut generated = 0usize;
    let mut expected_home: Option<usize> = None;
    let mut migrations = 0u64;

    while generated < max_new {
        let draft = vec![9u32, 9];
        let room = max_new - generated;
        let start_len = seq.len();
        let uncached = seq[cloud_len..].to_vec();
        assert!(!uncached.is_empty(), "verify rounds always carry new tokens");
        let home = router.submit(verify_req(ID, uncached, draft.clone())).unwrap();
        if let Some(h) = expected_home {
            assert_eq!(home, h, "affinity must follow the migrated session");
        }
        let outcome = drive_to_verify_done(&mut router, home, ID);

        // commit exactly as the device protocol does (mock never EOS)
        let accepted = outcome.accepted.min(draft.len());
        cloud_len = start_len + accepted;
        let mut commit: Vec<u32> = draft[..accepted].to_vec();
        commit.push(outcome.next_token);
        commit.truncate(room);
        seq.extend_from_slice(&commit);
        generated += commit.len();

        // round boundary: bounce the now-quiescent session across
        let src = router.home_of(ID).expect("session stays open until release");
        let dst = 1 - src;
        let kv_before = resident_kv(&router, src, ID);
        assert_eq!(kv_before.len, cloud_len, "cloud KV holds exactly the accepted prefix");
        let rec = router.migrate_session(ID, dst).unwrap();
        migrations += 1;
        assert_eq!(rec.from, src);
        assert_eq!(rec.to, dst);
        assert_eq!(
            rec.bytes as usize,
            KvMigrateMsg::wire_bytes_for(kv_before.len, MOCK_KV_ROW),
            "priced bytes follow the wire formula"
        );
        let msg = KvMigrateMsg { request_id: ID, kv: kv_before.clone() };
        assert_eq!(rec.bytes as usize, msg.encode().len(), "priced over the real encoding");
        assert!(
            !router.replica(src).sessions().contains(ID),
            "never resident on two replicas"
        );
        assert_eq!(router.home_of(ID), Some(dst));
        let kv_after = resident_kv(&router, dst, ID);
        assert_eq!(kv_after, kv_before, "migration round trip must be bit-identical");
        expected_home = Some(dst);
    }

    assert_eq!(generated, max_new, "the token budget is hit exactly");
    assert_eq!(router.stats.migrations, migrations);
    assert!(router.stats.migration_bytes > 0, "migrated KV always carries committed rows");
    router.submit(CloudRequest::Release { request_id: ID }).unwrap();
    assert!(router.is_idle());
    assert_eq!(router.home_of(ID), None);
    for r in 0..2 {
        assert_replica_conserved(&router, r);
    }
}

/// Session affinity: a session with queued work must not migrate — and
/// the failed attempt leaves it fully functional on its home replica.
#[test]
fn busy_sessions_never_migrate() {
    let mut router = router_with(2, &BatchPolicy::default());
    let home = router.submit(verify_req(7, vec![12, 13], vec![9, 9])).unwrap();
    // round still queued: the session is busy, the move must refuse
    assert!(router.migrate_session(7, 1 - home).is_err());
    assert_eq!(router.home_of(7), Some(home), "failed migration leaves the home intact");
    let _ = drive_to_verify_done(&mut router, home, 7);
    // quiescent now: the same move succeeds
    router.migrate_session(7, 1 - home).unwrap();
    assert_eq!(router.home_of(7), Some(1 - home));
    router.submit(CloudRequest::Release { request_id: 7 }).unwrap();
    assert!(router.is_idle());
}

/// Tenant-aware placement: a single hot tenant's sessions spread
/// across replicas instead of piling onto one.
#[test]
fn skewed_tenant_spreads_across_replicas() {
    let policy = BatchPolicy { tenant_weights: vec![1.0, 1.0], ..BatchPolicy::default() };
    let mut router = router_with(2, &policy);
    let mut homes = [0usize; 2];
    for id in 0..8u64 {
        let r = router
            .submit_tenant(
                0, // every session from the same tenant
                CloudRequest::Generate { request_id: id, prompt: vec![5, 6, 7], max_new: 2 },
            )
            .unwrap();
        homes[r] += 1;
    }
    assert!(
        homes[0].abs_diff(homes[1]) <= 1,
        "skewed tenant must balance: {homes:?}"
    );
}

/// Threshold-driven rebalancing converges: pile every quiescent
/// session onto one replica, then watch `rebalance()` move the
/// cheapest ones until the gap closes to the threshold.
#[test]
fn rebalance_converges_to_the_threshold() {
    // max_sessions > engine slots: the forced 6/0 pile-up needs the
    // hot replica to park sessions beyond its 4 physical slots
    let mut router = router_with(2, &BatchPolicy { max_sessions: 8, ..BatchPolicy::default() });
    let n = 6u64;
    for id in 0..n {
        let home = router.submit(verify_req(id, vec![12, 13], vec![9, 9])).unwrap();
        let _ = drive_to_verify_done(&mut router, home, id);
    }
    // force the skew: everything onto replica 0
    for id in 0..n {
        if router.home_of(id) == Some(1) {
            router.migrate_session(id, 0).unwrap();
        }
    }
    assert_eq!(router.replica(0).active_sessions(), n as usize);
    router.rebalance_threshold = 1;
    let moves = router.rebalance().unwrap();
    assert_eq!(moves.len(), 3, "6/0 split closes to 3/3 (gap 0 ≤ threshold 1)");
    assert!(moves.iter().all(|m| m.from == 0 && m.to == 1));
    assert_eq!(router.replica(0).active_sessions(), 3);
    assert_eq!(router.replica(1).active_sessions(), 3);
    // a balanced tier rebalances to nothing
    assert!(router.rebalance().unwrap().is_empty());
    for id in 0..n {
        router.submit(CloudRequest::Release { request_id: id }).unwrap();
    }
    assert!(router.is_idle());
    for r in 0..2 {
        assert_replica_conserved(&router, r);
    }
}

/// Property: random verify/generate traffic over 2–3 replicas with
/// interleaved ticks and forced rebalances never puts one session on
/// two replicas, and after a full drain every replica conserves its
/// slots and blocks.
#[test]
fn prop_random_traffic_with_rebalances_conserves_everything() {
    check("router traffic conserves slots/blocks; single residency", |rng| {
        let nrep = usize_in(rng, 2, 3);
        let policy = BatchPolicy {
            max_sessions: 4,
            rebalance_threshold: 1,
            ..BatchPolicy::default()
        };
        let engines = (0..nrep).map(|_| MockBatchEngine::new(2, 8, VOCAB, 4096)).collect();
        let mut router: Router<MockBatchEngine> =
            Router::new(engines, 0xABCD ^ rng.below(1 << 30), &policy).unwrap();
        let mut next_id = 0u64;
        let mut open: HashSet<u64> = HashSet::new(); // sessions to release
        for _ in 0..usize_in(rng, 20, 60) {
            match rng.below(6) {
                0 => {
                    router
                        .submit(verify_req(next_id, vec![12, 13, 14], vec![9, 9]))
                        .map_err(|e| e.to_string())?;
                    open.insert(next_id);
                    next_id += 1;
                }
                1 => {
                    router
                        .submit(CloudRequest::Generate {
                            request_id: next_id,
                            prompt: vec![5, 6, 7],
                            max_new: 2,
                        })
                        .map_err(|e| e.to_string())?;
                    next_id += 1; // generations close themselves
                }
                2 => {
                    // a follow-up round for some *quiescent* open
                    // session (the protocol never overlaps rounds)
                    if let Some(&id) = open.iter().min() {
                        let quiescent = router
                            .home_of(id)
                            .is_some_and(|h| !router.replica(h).session_busy(id));
                        if quiescent {
                            router
                                .submit(verify_req(id, vec![10], vec![9]))
                                .map_err(|e| e.to_string())?;
                        }
                    }
                }
                3 => {
                    let _ = router.rebalance().map_err(|e| e.to_string())?;
                }
                _ => {
                    let r = usize_in(rng, 0, nrep - 1);
                    if !router.replica_idle(r) {
                        router.tick_replica(r).map_err(|e| e.to_string())?;
                    }
                }
            }
            // single-residency invariant, checked after every step
            for id in 0..next_id {
                let residents =
                    (0..nrep).filter(|&r| router.replica(r).sessions().contains(id)).count();
                if residents > 1 {
                    return Err(format!("session {id} resident on {residents} replicas"));
                }
            }
        }
        // drain: release every session, then tick everything to idle
        for id in open {
            router.submit(CloudRequest::Release { request_id: id }).map_err(|e| e.to_string())?;
        }
        for _ in 0..3_000 {
            if router.is_idle() {
                break;
            }
            for r in 0..nrep {
                if !router.replica_idle(r) {
                    router.tick_replica(r).map_err(|e| e.to_string())?;
                }
            }
        }
        if !router.is_idle() {
            return Err("router failed to drain".into());
        }
        for r in 0..nrep {
            let s = router.replica(r);
            if s.engine.free_slots() != s.engine.slots {
                return Err(format!("replica {r}: slot leak"));
            }
            if s.engine.allocs != s.engine.frees {
                return Err(format!("replica {r}: alloc/free imbalance"));
            }
            if s.sessions().free_blocks() != s.sessions().block_capacity() {
                return Err(format!("replica {r}: block leak"));
            }
        }
        Ok(())
    });
}

/// Same seed ⇒ bit-identical per-tenant reports at R = 2 with
/// rebalancing on — the fleet's determinism contract extends across
/// the router tier, migrations included.
#[test]
fn fleet_with_replicas_and_rebalancing_is_deterministic() {
    let cfg = FleetConfig {
        n_devices: 48,
        duration_s: 4.0,
        rate_rps: 24.0,
        tenants: 2,
        params: SyneraParams {
            batch: BatchPolicy {
                max_sessions: 32,
                replicas: 2,
                rebalance_threshold: 2,
                ..BatchPolicy::default()
            },
            ..SyneraParams::default()
        },
        seed: 0x5EED5,
        ..FleetConfig::default()
    };
    let a = run_fleet(&cfg).unwrap();
    let b = run_fleet(&cfg).unwrap();
    assert_eq!(a.replicas, 2);
    assert_eq!(
        format!("{:?}", a.tenants),
        format!("{:?}", b.tenants),
        "per-tenant reports must be bit-identical"
    );
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.migrations, b.migrations);
    assert_eq!(a.migration_bytes, b.migration_bytes);
    assert_eq!(a.replica_iterations, b.replica_iterations);
    assert_eq!(a.replica_rows, b.replica_rows);
    assert_eq!(a.generated_tokens, b.generated_tokens);
    // both replicas actually served work
    assert!(a.replica_iterations.iter().all(|&n| n > 0), "{:?}", a.replica_iterations);
}

/// The metrics registry's router snapshot mirrors the live tier: one
/// gauge set per replica, router-level counters equal to `stats`, and
/// the end-of-run sample shows the conserved (drained) state.
#[test]
fn registry_router_snapshot_matches_live_tier() {
    use synera::obs::registry::{sample_router, Registry};

    let mut router = router_with(2, &BatchPolicy { max_sessions: 8, ..BatchPolicy::default() });
    let n = 6u64;
    for id in 0..n {
        let home = router.submit(verify_req(id, vec![12, 13], vec![9, 9])).unwrap();
        let _ = drive_to_verify_done(&mut router, home, id);
    }
    // mid-run: sessions open across both replicas
    let mut reg = Registry::new(0.0);
    sample_router(&mut reg, &router);
    for r in 0..2usize {
        let g = |n: &str| reg.gauge(&format!("cloud.{n}.{r}")).unwrap();
        let live = router.replica(r);
        assert_eq!(g("sessions_open"), live.active_sessions() as f64, "replica {r}");
        assert_eq!(g("free_blocks"), live.sessions().free_blocks() as f64);
        assert_eq!(g("rows_executed"), live.stats.rows_executed as f64);
    }
    assert_eq!(reg.gauge("router.routed"), Some(router.stats.routed as f64));
    assert_eq!(
        reg.gauge("router.migrations"),
        Some(router.stats.migrations as f64)
    );
    let open: f64 = (0..2)
        .map(|r| reg.gauge(&format!("cloud.sessions_open.{r}")).unwrap())
        .sum();
    assert_eq!(open, n as f64, "every submitted session is open somewhere");

    // drain and re-sample: the gauges must show the conserved state
    for id in 0..n {
        router.submit(CloudRequest::Release { request_id: id }).unwrap();
    }
    assert!(router.is_idle());
    sample_router(&mut reg, &router);
    for r in 0..2usize {
        let g = |n: &str| reg.gauge(&format!("cloud.{n}.{r}")).unwrap();
        assert_eq!(g("sessions_open"), 0.0, "replica {r} drained");
        assert_eq!(g("free_blocks"), g("block_capacity"), "replica {r} blocks back");
        assert_eq!(g("sessions_resident"), 0.0);
        assert_replica_conserved(&router, r);
    }
}
