//! Property-based invariant suites (via the in-repo `testutil::prop`
//! driver — seeded splitmix64 case generation, failing-seed reporting).

use synera::cloud::verifier::verify_chunk;
use synera::config::SyneraParams;
use synera::device::codec::compress_dist;
use synera::device::offload::Selector;
use synera::device::parallel::predict_rejection;
use synera::metrics::quality::rouge1;
use synera::model::logits::{argmax, margin_top12, softmax, top_k};
use synera::net::wire::{Dist, UplinkMsg};
use synera::testutil::{check, f64_in, prob_vec, usize_in};
use synera::util::json::Json;
use synera::util::rng::Rng;

#[test]
fn prop_softmax_is_distribution() {
    check("softmax sums to 1 and is monotone", |rng| {
        let n = usize_in(rng, 2, 512);
        let logits: Vec<f32> = (0..n).map(|_| (f64_in(rng, -30.0, 30.0)) as f32).collect();
        let p = softmax(&logits);
        let s: f32 = p.iter().sum();
        if (s - 1.0).abs() > 1e-4 {
            return Err(format!("sum {s}"));
        }
        if argmax(&p) != argmax(&logits) {
            return Err("argmax changed".into());
        }
        let m = margin_top12(&p);
        if !(0.0..=1.0).contains(&m) {
            return Err(format!("margin {m}"));
        }
        Ok(())
    });
}

#[test]
fn prop_topk_returns_k_largest() {
    check("top_k is the k largest, descending", |rng| {
        let n = usize_in(rng, 1, 256);
        let k = usize_in(rng, 1, n);
        let xs: Vec<f32> = (0..n).map(|_| f64_in(rng, 0.0, 1.0) as f32).collect();
        let idx = top_k(&xs, k);
        if idx.len() != k {
            return Err("wrong k".into());
        }
        for w in idx.windows(2) {
            if xs[w[0]] < xs[w[1]] {
                return Err("not descending".into());
            }
        }
        let min_in = idx.iter().map(|&i| xs[i]).fold(f32::INFINITY, f32::min);
        for (i, &x) in xs.iter().enumerate() {
            if !idx.contains(&i) && x > min_in + 1e-9 {
                return Err(format!("missed larger value {x} at {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_selector_probabilities_valid_and_monotone() {
    check("P_conf/P_imp in [0,1], P_imp monotone in i", |rng| {
        let c_th = f64_in(rng, 0.1, 0.95);
        let i_th = f64_in(rng, 0.05, 5.0);
        let s = Selector::new(c_th, i_th, SyneraParams::default());
        let mut prev = -1.0;
        for i in 0..50 {
            let x = i as f64 / 49.0 * i_th * 1.4;
            let p = s.p_imp(x);
            if !(0.0..=1.0).contains(&p) || p + 1e-9 < prev {
                return Err(format!("p_imp({x}) = {p}, prev {prev}"));
            }
            prev = p;
        }
        for i in 0..50 {
            let c = i as f64 / 49.0;
            let p = s.p_conf(c);
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("p_conf({c}) = {p}"));
            }
            if c <= c_th && p != 1.0 {
                return Err("below threshold must dispatch to stage 2".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_verify_accepted_prefix_matches_greedy_argmax() {
    check("greedy verify accepts exactly the argmax-matching prefix", |rng| {
        let v = 64;
        let gamma = usize_in(rng, 1, 6);
        let draft: Vec<u32> = (0..gamma).map(|_| rng.below(v as u64) as u32).collect();
        let q_rows: Vec<Vec<f32>> = (0..=gamma).map(|_| prob_vec(rng, v)).collect();
        let dists: Vec<Dist> = (0..gamma).map(|_| Dist::Dense(prob_vec(rng, v))).collect();
        let mut vr = Rng::new(rng.next_u64());
        let out = verify_chunk(&draft, &dists, &q_rows, true, &mut vr);
        let mut expect = gamma;
        for j in 0..gamma {
            if argmax(&q_rows[j]) as u32 != draft[j] {
                expect = j;
                break;
            }
        }
        if out.accepted != expect {
            return Err(format!("accepted {} want {expect}", out.accepted));
        }
        if out.accepted < gamma && out.next_token != argmax(&q_rows[out.accepted]) as u32 {
            return Err("correction is not argmax q".into());
        }
        Ok(())
    });
}

#[test]
fn prop_stochastic_verify_never_reduces_q_support() {
    check("stochastic corrections live where q > 0", |rng| {
        let v = 32;
        let draft = vec![rng.below(v as u64) as u32];
        let q0 = prob_vec(rng, v);
        let q_rows = vec![q0.clone(), prob_vec(rng, v)];
        let dists = vec![Dist::Dense(prob_vec(rng, v))];
        let mut vr = Rng::new(rng.next_u64());
        let out = verify_chunk(&draft, &dists, &q_rows, false, &mut vr);
        if out.accepted == 0 && q0[out.next_token as usize] <= 0.0 {
            return Err("corrected token outside q support".into());
        }
        Ok(())
    });
}

#[test]
fn prop_rejection_prediction_in_range() {
    check("r* ∈ [0, γ)", |rng| {
        let gamma = usize_in(rng, 1, 8);
        let confs: Vec<f32> = (0..gamma).map(|_| f64_in(rng, 0.0, 1.0) as f32).collect();
        let alpha = f64_in(rng, 0.05, 0.95);
        let mut pr = Rng::new(rng.next_u64());
        match predict_rejection(alpha, &confs, &mut pr) {
            Some(r) if r < gamma => Ok(()),
            Some(r) => Err(format!("r*={r} out of range")),
            None => Err("unexpected None".into()),
        }
    });
}

#[test]
fn prop_codec_preserves_topk_mass_and_shrinks_wire() {
    check("compression keeps top-k probs, shrinks bytes", |rng| {
        let v = 512;
        let p = prob_vec(rng, v);
        let k = usize_in(rng, 1, 16);
        let d = compress_dist(&p, k);
        for &i in top_k(&p, k).iter() {
            let got = d.prob_of(i as u32);
            if (got - p[i]).abs() > 2e-3 {
                return Err(format!("prob {i}: {got} vs {}", p[i]));
            }
        }
        let msg = |dists: Vec<Dist>| UplinkMsg {
            request_id: 0,
            device_id: 0,
            uncached: vec![1],
            draft: vec![1],
            dists,
            is_first: false,
            ctx: Default::default(),
        };
        let dense = msg(vec![Dist::Dense(p.clone())]).wire_bytes();
        let sparse = msg(vec![d]).wire_bytes();
        if sparse * 4 > dense {
            return Err(format!("sparse {sparse} not ≪ dense {dense}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rouge_bounds_and_identity() {
    check("rouge1 ∈ [0,1], =1 on permutations", |rng| {
        let n = usize_in(rng, 1, 32);
        let a: Vec<u32> = (0..n).map(|_| rng.below(100) as u32).collect();
        let mut b = a.clone();
        // deterministic shuffle
        for i in (1..b.len()).rev() {
            let j = rng.below((i + 1) as u64) as usize;
            b.swap(i, j);
        }
        let r = rouge1(&a, &b);
        if (r - 1.0).abs() > 1e-12 {
            return Err(format!("permutation rouge {r}"));
        }
        let c: Vec<u32> = (0..n).map(|_| 200 + rng.below(50) as u32).collect();
        let r2 = rouge1(&a, &c);
        if !(0.0..=1.0).contains(&r2) {
            return Err(format!("rouge out of bounds {r2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_trees() {
    check("json write→parse is identity", |rng| {
        fn gen(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.below(2) == 1),
                2 => Json::Num((rng.below(1_000_000) as f64) - 500_000.0),
                3 => Json::Str(format!("s{}‡\n\"{}", rng.below(100), rng.below(100))),
                4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = gen(rng, 3);
        let v2 = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
        if v != v2 {
            return Err(format!("{v:?} != {v2:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_wire_sizes_scale_with_content() {
    check("uplink bytes grow with payload", |rng| {
        let n1 = usize_in(rng, 1, 10);
        let n2 = n1 + usize_in(rng, 1, 10);
        let mk = |n: usize| UplinkMsg {
            request_id: 1,
            device_id: 0,
            uncached: vec![5; n],
            draft: vec![7; 4],
            dists: vec![Dist::TopK { ids: vec![1, 2], probs_f16: vec![0, 0] }; 4],
            is_first: false,
            ctx: Default::default(),
        };
        if mk(n2).wire_bytes() <= mk(n1).wire_bytes() {
            return Err("bytes not monotone in payload".into());
        }
        Ok(())
    });
}
