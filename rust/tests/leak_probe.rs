//! RSS probe for the engine hot loop (run with --ignored).
use synera::model::{CloudEngine, SlotChunk};
use synera::runtime::Runtime;

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    s.lines().find(|l| l.starts_with("VmRSS")).unwrap()
        .split_whitespace().nth(1).unwrap().parse::<f64>().unwrap() / 1024.0
}

#[test]
#[ignore]
fn engine_loop_rss() {
    let rt = Runtime::load_default().unwrap();
    let mut eng = CloudEngine::new(rt.model("l13b").unwrap()).unwrap();
    let s = eng.alloc_slot(1).unwrap();
    println!("start rss={:.0}MB", rss_mb());
    for i in 0..300 {
        eng.run_batch(&[SlotChunk { slot: s, tokens: vec![200, 201, 202, 203] }]).unwrap();
        eng.rollback(s, 0);
        if i % 50 == 49 {
            println!("iter {i} rss={:.0}MB", rss_mb());
        }
    }
}

#[test]
#[ignore]
fn fig15_sim_rss() {
    use synera::cloud::scheduler::{CloudEvent, CloudRequest, Scheduler};
    use synera::net::wire::Dist;
    use synera::util::rng::Rng;
    let rt = Runtime::load_default().unwrap();
    let gamma = rt.meta.gamma;
    let budget = 0.3f64;
    let user_rps = 5.0;
    let offl = (budget + 0.15).min(1.0);
    let verifies_per_req = ((16.0 * offl / gamma as f64).ceil()) as usize;
    let verify_rps = user_rps * verifies_per_req as f64;
    let uncached_len = ((gamma as f64 * (1.0 - offl) / offl).round() as usize).max(1);
    println!("vpr={verifies_per_req} vrps={verify_rps} unc={uncached_len}");

    let mut rng = Rng::new(0xF15 ^ (budget * 100.0) as u64 ^ user_rps as u64);
    let horizon = 1.2;
    let mut arrivals: Vec<(f64, u64)> = Vec::new();
    let mut t = 0.0;
    let mut id = 1u64;
    while t < horizon {
        t += rng.exp(verify_rps);
        if t >= horizon { break; }
        arrivals.push((t, id));
        id += 1;
    }
    println!("arrivals={} rss={:.0}MB", arrivals.len(), rss_mb());

    let mut sched = Scheduler::new(CloudEngine::new(rt.model("l13b").unwrap()).unwrap(), 0x5CA1E);
    let mut now = 0.0f64;
    let mut next = 0usize;
    let mut done = 0usize;
    for i in 0..2_500 {
        while next < arrivals.len() && arrivals[next].0 <= now {
            let (_, aid) = arrivals[next];
            sched.submit(CloudRequest::Verify {
                request_id: aid,
                device_id: aid as u32,
                uncached: (0..uncached_len).map(|_| 200 + rng.below(128) as u32).collect(),
                draft: (0..gamma).map(|_| 200 + rng.below(128) as u32).collect(),
                dists: vec![Dist::Dense(vec![1.0 / 512.0; 512]); gamma],
                greedy: true,
                ctx: Default::default(),
            }).unwrap();
            next += 1;
        }
        if sched.is_idle() {
            match arrivals.get(next) {
                Some(a) => { now = a.0; continue; }
                None => break,
            }
        }
        let (events, dt) = sched.tick().unwrap();
        now += dt.max(1e-6);
        for e in events {
            if let CloudEvent::VerifyDone { request_id, .. } = e {
                done += 1;
                sched.submit(CloudRequest::Release { request_id }).unwrap();
            }
        }
        if i % 200 == 199 { println!("tick {i} now={now:.3} done={done} rss={:.0}MB", rss_mb()); }
    }
    println!("END done={done} rss={:.0}MB", rss_mb());
}
