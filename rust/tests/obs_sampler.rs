//! Fleet-scale observability gates: the quantile sketch answers
//! percentiles within its documented α bound and merges exactly; the
//! tail-based trace sampler retains every SLO-missing request, keeps
//! retained memory far below the full event stream, and in all-retain
//! mode reproduces the unsampled exports byte for byte.

use synera::config::{BatchPolicy, SloPolicy, SyneraParams};
use synera::metrics::stats::{QuantileSketch, Summary};
use synera::obs::export::{chrome_trace_string, events_jsonl_string};
use synera::obs::sampler::SamplerConfig;
use synera::obs::trace::{self, TraceShared, TraceSink};
use synera::sim::{run_fleet, FleetConfig, FleetReport};
use synera::util::rng::Rng;

const TRACE_CAP: usize = 1 << 20;

// ---------------------------------------------------------------------------
// quantile sketch: error bound + exact merge
// ---------------------------------------------------------------------------

/// Lognormal-shaped latencies (the TTFT regime): exp of an
/// Irwin–Hall-approximated normal.
fn lognormal_stream(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let u: f64 = (0..12).map(|_| rng.f64()).sum::<f64>() - 6.0; // ~N(0,1)
            (0.6 * u - 1.6).exp() // median ~0.2 s, heavy right tail
        })
        .collect()
}

/// MMPP-shaped latencies: a fast mode with occasional slow-mode
/// excursions (the burst regime the tail sampler exists for).
fn mmpp_stream(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| if rng.chance(1, 8) { rng.exp(0.5) } else { rng.exp(20.0) })
        .collect()
}

fn assert_within_alpha(sketch: &QuantileSketch, values: &[f64], what: &str) {
    let exact = Summary::of(values);
    let got = sketch.summary().unwrap();
    let alpha = sketch.relative_error();
    for (e, g, q) in [
        (exact.p50, got.p50, "p50"),
        (exact.p95, got.p95, "p95"),
        (exact.p99, got.p99, "p99"),
    ] {
        let rel = (g - e).abs() / e;
        assert!(rel <= alpha + 1e-12, "{what} {q}: exact {e} sketch {g} rel {rel} > α {alpha}");
    }
    // the moments are exact, not sketched
    assert_eq!(got.n, exact.n, "{what}: n");
    assert_eq!(got.min.to_bits(), exact.min.to_bits(), "{what}: min");
    assert_eq!(got.max.to_bits(), exact.max.to_bits(), "{what}: max");
    assert!((got.mean - exact.mean).abs() <= 1e-12 * exact.mean.abs(), "{what}: mean");
}

/// Every reported percentile is within the documented relative error
/// of the exact order statistic, on both workload shapes.
#[test]
fn sketch_percentiles_stay_within_the_error_bound() {
    for (name, values) in
        [("lognormal", lognormal_stream(7, 4000)), ("mmpp", mmpp_stream(11, 4000))]
    {
        let mut sk = QuantileSketch::default();
        for &v in &values {
            sk.record(v);
        }
        assert_within_alpha(&sk, &values, name);
        // the footprint is buckets, not samples
        assert!(
            sk.bucket_count() < 1500,
            "{name}: {} buckets for {} samples",
            sk.bucket_count(),
            values.len()
        );
    }
}

/// Merging partial sketches is exact (bucket counts add) and
/// associative, with deterministic serialization — the property the
/// per-tenant fleet/serve aggregation relies on.
#[test]
fn sketch_merge_is_exact_associative_and_deterministic() {
    let streams =
        [lognormal_stream(1, 1500), mmpp_stream(2, 1100), lognormal_stream(3, 700)];
    let parts: Vec<QuantileSketch> = streams
        .iter()
        .map(|s| {
            let mut sk = QuantileSketch::default();
            s.iter().for_each(|&v| sk.record(v));
            sk
        })
        .collect();
    let mut whole = QuantileSketch::default();
    streams.iter().flatten().for_each(|&v| whole.record(v));

    let mut left = parts[0].clone(); // (a ⊕ b) ⊕ c
    left.merge(&parts[1]);
    left.merge(&parts[2]);
    let mut bc = parts[1].clone(); // a ⊕ (b ⊕ c)
    bc.merge(&parts[2]);
    let mut right = parts[0].clone();
    right.merge(&bc);

    let bytes = |s: &QuantileSketch| s.to_json().to_string();
    assert_eq!(bytes(&left), bytes(&right), "merge is associative");
    assert_eq!(bytes(&left), bytes(&whole), "merged == single-stream sketch");
    let all: Vec<f64> = streams.iter().flatten().copied().collect();
    assert_within_alpha(&left, &all, "merged");
}

// ---------------------------------------------------------------------------
// trace sampler: fleet integration
// ---------------------------------------------------------------------------

/// The same small full-drain fleet `inspect_analyze` traces (24
/// devices, 3 virtual s), so the bit-identity gate covers the exact
/// export shape earlier PRs snapshotted.
fn traced_cfg(trace: Option<TraceShared>, slo: SloPolicy) -> FleetConfig {
    FleetConfig {
        n_devices: 24,
        duration_s: 3.0,
        rate_rps: 12.0,
        tenants: 3,
        params: SyneraParams {
            batch: BatchPolicy { max_sessions: 8, ..BatchPolicy::default() },
            ..SyneraParams::default()
        },
        seed: 0x0B57,
        slo,
        trace,
        ..FleetConfig::default()
    }
}

fn run_sampled(cfg_sampler: Option<SamplerConfig>, slo: SloPolicy) -> (FleetReport, TraceShared) {
    let sink = TraceSink::virtual_time(TRACE_CAP);
    let sink = match cfg_sampler {
        Some(c) => sink.with_sampler(c),
        None => sink,
    };
    let tr = trace::shared(sink);
    let rep = run_fleet(&traced_cfg(Some(tr.clone()), slo)).unwrap();
    (rep, tr)
}

/// All-retain mode (`head_every = 1`) must reproduce the unsampled
/// sink's exports byte for byte — the sampler only re-routes events
/// through per-request staging, it never reorders or rewrites them.
#[test]
fn all_retain_mode_reproduces_the_unsampled_export() {
    let slo = SloPolicy::default();
    let (rep_plain, tr_plain) = run_sampled(None, slo);
    let (rep_all, tr_all) =
        run_sampled(Some(SamplerConfig { head_every: 1, tail_k: 0, seed: 0 }), slo);
    assert!(rep_plain.completed > 0 && rep_plain.completed == rep_plain.offered);
    assert_eq!(rep_plain.completed, rep_all.completed, "sampler is a pure observer");
    assert_eq!(rep_plain.virtual_s.to_bits(), rep_all.virtual_s.to_bits());
    let (a, b) = (tr_plain.lock().unwrap(), tr_all.lock().unwrap());
    assert_eq!(a.len(), b.len(), "all-retain keeps every event");
    assert_eq!(chrome_trace_string(&a), chrome_trace_string(&b), "chrome export bit-identical");
    assert_eq!(events_jsonl_string(&a), events_jsonl_string(&b), "jsonl export bit-identical");
}

/// Same seed ⇒ byte-identical exports with sampling on: the head draw
/// is seeded per request and the top-k heap is deterministic.
#[test]
fn sampled_export_is_seed_deterministic() {
    let slo = SloPolicy::default();
    let cfg = SamplerConfig { head_every: 16, tail_k: 4, seed: 9 };
    let (_, tr_a) = run_sampled(Some(cfg), slo);
    let (_, tr_b) = run_sampled(Some(cfg), slo);
    let (a, b) = (tr_a.lock().unwrap(), tr_b.lock().unwrap());
    assert!(!a.is_empty());
    assert_eq!(chrome_trace_string(&a), chrome_trace_string(&b));
    // a different sampler seed retains a different population
    let (_, tr_c) =
        run_sampled(Some(SamplerConfig { head_every: 16, tail_k: 4, seed: 10 }), slo);
    let c = tr_c.lock().unwrap();
    assert_ne!(chrome_trace_string(&a), chrome_trace_string(&c), "seed moves the head draw");
}

/// At fleet scale, with an SLO every request misses, tail-only
/// retention keeps *every* completion — no miss is ever sampled away.
#[test]
fn every_slo_miss_is_retained_at_fleet_scale() {
    let strict = SloPolicy { ttft_s: 1e-6, tbt_s: 1e-6, violation_budget: 0.1 };
    let sink = TraceSink::virtual_time(TRACE_CAP)
        .with_sampler(SamplerConfig { head_every: 0, tail_k: 0, seed: 0 });
    let tr = trace::shared(sink);
    let cfg = FleetConfig {
        n_devices: 16384,
        duration_s: 1.5,
        rate_rps: 96.0,
        tenants: 4,
        params: SyneraParams {
            batch: BatchPolicy { max_sessions: 8, ..BatchPolicy::default() },
            ..SyneraParams::default()
        },
        seed: 0x5A11,
        slo: strict,
        trace: Some(tr.clone()),
        ..FleetConfig::default()
    };
    let rep = run_fleet(&cfg).unwrap();
    assert!(rep.completed > 50, "fleet produced work: {rep:?}");
    assert_eq!(rep.completed, rep.offered, "full drain");
    let sink = tr.lock().unwrap();
    let st = sink.sampler_stats().unwrap();
    assert_eq!(st.completed, rep.completed as u64);
    assert_eq!(st.tail_retained, st.completed, "every SLO miss is tail-interesting");
    assert_eq!(st.retained_requests, st.completed, "…and every one is retained");
    assert_eq!(st.discarded_requests, 0);
    assert_eq!(st.staged_events, 0, "drained run leaves nothing staged");
    assert!(st.peak_staged_events > 0, "staging actually saw traffic");
}

/// Under head+top-k sampling with a lax SLO most requests are
/// discarded wholesale: retained memory is a small fraction of the
/// full stream and the top-k claim stays bounded.
#[test]
fn retained_memory_stays_bounded_under_saturation() {
    let lax = SloPolicy { ttft_s: 1e9, tbt_s: 1e9, violation_budget: 0.1 };
    let sink = TraceSink::virtual_time(TRACE_CAP)
        .with_sampler(SamplerConfig { head_every: 64, tail_k: 8, seed: 3 });
    let tr = trace::shared(sink);
    let cfg = FleetConfig {
        n_devices: 64,
        duration_s: 2.0,
        rate_rps: 120.0, // well beyond service capacity, then drains
        tenants: 2,
        params: SyneraParams {
            batch: BatchPolicy { max_sessions: 8, ..BatchPolicy::default() },
            ..SyneraParams::default()
        },
        seed: 0xB0B,
        slo: lax,
        trace: Some(tr.clone()),
        ..FleetConfig::default()
    };
    let rep = run_fleet(&cfg).unwrap();
    assert_eq!(rep.completed, rep.offered, "saturated run still drains");
    let sink = tr.lock().unwrap();
    let st = sink.sampler_stats().unwrap();
    let total_request_events = st.retained_events + st.discarded_events;
    assert!(st.completed > 100, "enough completions to sample: {st:?}");
    assert!(
        st.retained_events * 4 < total_request_events,
        "retention is the minority: kept {} of {} request events",
        st.retained_events,
        total_request_events
    );
    assert!(
        st.retained_requests <= st.head_retained + st.tail_retained + 8,
        "top-k claim bounded by k: {st:?}"
    );
    assert!(st.head_retained > 0, "head draw fired");
    assert_eq!(st.staged_requests, 0, "no in-flight staging after drain");
    // exports still well-formed over the sampled stream
    assert_eq!(sink.span_imbalance(), 0, "retained spans close");
    let doc = synera::util::json::Json::parse(&chrome_trace_string(&sink)).unwrap();
    assert!(!doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
}
