//! Generator parity: replay `artifacts/golden_workload.json` (written by
//! the Python side during `make artifacts`) through the Rust SynthLang
//! mirror and require byte-identical samples. This is what guarantees
//! the Python-trained models and the Rust serving stack see the same
//! data distribution.

use synera::runtime::artifacts_dir;
use synera::util::json::Json;
use synera::workload::synthlang::{generate, Task};

#[test]
fn golden_workload_matches_python() {
    let path = artifacts_dir().join("golden_workload.json");
    let j = Json::parse_file(&path).expect("run `make artifacts` first");
    let arr = j.as_arr().unwrap();
    assert!(arr.len() >= 7 * 8, "golden file too small: {}", arr.len());
    for g in arr {
        let task = Task::from_name(g.get("task").unwrap().as_str().unwrap()).unwrap();
        let index = g.get("index").unwrap().as_usize().unwrap() as u64;
        let want_prompt: Vec<u32> = g
            .get("prompt").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap() as u32).collect();
        let want_answer: Vec<u32> = g
            .get("answer").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap() as u32).collect();
        let got = generate(task, 1, index);
        assert_eq!(got.prompt, want_prompt, "{} #{index} prompt", task.name());
        assert_eq!(got.answer, want_answer, "{} #{index} answer", task.name());
        assert_eq!(
            got.task.is_classification(),
            g.get("classification").unwrap().as_bool().unwrap(),
            "{} metric kind",
            task.name()
        );
    }
}
