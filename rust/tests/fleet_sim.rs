//! Fleet-simulator gates (artifact-free): bit-identical determinism,
//! the weighted-fair-queueing share property under saturation, and a
//! sim-vs-threaded cross-check that drives the identical device model
//! through the real scheduler from OS threads.

use std::collections::HashMap;
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::time::Duration;

use synera::cloud::fairness::WfqQueue;
use synera::cloud::scheduler::{CloudEvent, CloudRequest, Scheduler};
use synera::config::{BatchPolicy, SyneraParams};
use synera::device::codec::compress_dist;
use synera::metrics::stats::Summary;
use synera::net::LinkProfile;
use synera::profiling::OffloadProfile;
use synera::sim::{run_fleet, FleetConfig, SimDevice};
use synera::testutil::MockBatchEngine;
use synera::workload::synthlang::{generate, Task};
use synera::workload::trace::BurstProfile;
use synera::workload::vocab::VOCAB;

fn assert_summary_bits_eq(a: &Summary, b: &Summary, what: &str) {
    assert_eq!(a.n, b.n, "{what}: n");
    for (x, y, f) in [
        (a.mean, b.mean, "mean"),
        (a.min, b.min, "min"),
        (a.max, b.max, "max"),
        (a.p50, b.p50, "p50"),
        (a.p95, b.p95, "p95"),
        (a.p99, b.p99, "p99"),
        (a.std, b.std, "std"),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: {f} {x} vs {y}");
    }
}

/// Same seed ⇒ bit-identical per-tenant summaries, counters and swap
/// traffic — the virtual clock admits no wall-clock or thread-timing
/// leakage.
#[test]
fn same_seed_gives_bit_identical_reports() {
    let cfg = FleetConfig {
        n_devices: 48,
        duration_s: 4.0,
        rate_rps: 24.0,
        tenants: 3,
        tenant_weights: vec![1.0, 2.0, 3.0],
        params: SyneraParams {
            batch: BatchPolicy { max_sessions: 8, ..BatchPolicy::default() },
            ..SyneraParams::default()
        },
        reservoir: 1024,
        seed: 0xD37,
        ..FleetConfig::default()
    };
    let a = run_fleet(&cfg).unwrap();
    let b = run_fleet(&cfg).unwrap();
    assert!(a.offered > 0 && a.completed == a.offered, "trace drains: {a:?}");
    assert_eq!(a.offered, b.offered);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.generated_tokens, b.generated_tokens);
    assert_eq!(a.offload_rounds, b.offload_rounds);
    assert_eq!(a.local_chunks, b.local_chunks);
    assert_eq!(a.cloud_iterations, b.cloud_iterations);
    assert_eq!((a.swap_ins, a.swap_outs, a.swap_bytes), (b.swap_ins, b.swap_outs, b.swap_bytes));
    assert_eq!((a.bytes_up, a.bytes_down), (b.bytes_up, b.bytes_down));
    assert_eq!(a.virtual_s.to_bits(), b.virtual_s.to_bits());
    assert_eq!(a.cost.to_bits(), b.cost.to_bits());
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.requests, y.requests);
        assert_eq!(x.completed, y.completed);
        assert_eq!(x.rows_executed, y.rows_executed);
        assert_eq!(x.verifies_done, y.verifies_done);
        assert_eq!(x.draft_tokens_accepted, y.draft_tokens_accepted);
        assert_summary_bits_eq(&x.ttft, &y.ttft, "ttft");
        assert_summary_bits_eq(&x.tbt, &y.tbt, "tbt");
    }
    // a different seed must actually change the run (the comparison
    // above is not vacuous)
    let c = run_fleet(&FleetConfig { seed: 0xD38, ..cfg }).unwrap();
    assert_ne!(a.virtual_s.to_bits(), c.virtual_s.to_bits());
}

/// Under sustained saturation a weight-2 tenant receives ~2× the
/// engine rows of a weight-1 tenant, and neither starves.
#[test]
fn wfq_grants_weighted_shares_under_saturation() {
    let cfg = FleetConfig {
        n_devices: 32,
        duration_s: 8.0,
        rate_rps: 150.0, // far beyond service capacity: WFQ stays backlogged
        stop_s: 8.0,     // windowed measurement — don't drain the backlog
        tenants: 2,
        tenant_weights: vec![1.0, 2.0],
        params: SyneraParams {
            // offload every chunk: the cloud is the contended resource
            use_conf: false,
            use_imp: true,
            budget: 1.0,
            max_new_tokens: 8,
            batch: BatchPolicy { max_sessions: 6, ..BatchPolicy::default() },
            ..SyneraParams::default()
        },
        link: Some(LinkProfile::wifi()),
        seed: 0x3FA,
        ..FleetConfig::default()
    };
    let rep = run_fleet(&cfg).unwrap();
    let (t0, t1) = (&rep.tenants[0], &rep.tenants[1]);
    assert!(t0.completed > 0, "weight-1 tenant must not starve: {t0:?}");
    assert!(t1.completed > 0);
    assert!(t0.rows_executed > 0 && t1.rows_executed > 0);
    let ratio = t1.rows_executed as f64 / t0.rows_executed as f64;
    assert!(
        (1.45..=2.75).contains(&ratio),
        "rows ratio {ratio:.2} (t0={} t1={}) should track the 2:1 weights",
        t0.rows_executed,
        t1.rows_executed
    );
    // overload diagnostics stay self-consistent
    assert!(rep.offered > rep.completed, "saturation leaves a backlog");
    assert!(rep.swap_outs > 0, "6 logical sessions over 4 slots must page");
}

/// An idle tenant banks no credit: returning after a long quiet spell
/// it shares from now on instead of starving the tenants that kept the
/// queue busy (WFQ frontend semantics, asserted at the queue surface
/// the scheduler admission uses).
#[test]
fn wfq_idle_tenant_cannot_starve_active_ones() {
    let mut q: WfqQueue<u32> = WfqQueue::new(&[1.0, 1.0]).unwrap();
    // tenant 0 runs alone for a long busy period
    for i in 0..200 {
        q.push(0, 8.0, i).unwrap();
    }
    while q.pop().is_some() {}
    // tenant 1 returns from idleness; both now compete
    for i in 0..40 {
        q.push(0, 8.0, i).unwrap();
        q.push(1, 8.0, 1000 + i).unwrap();
    }
    let mut first_20 = [0usize; 2];
    for _ in 0..20 {
        first_20[q.pop().unwrap().0] += 1;
    }
    assert!(
        first_20[0] >= 8 && first_20[0] <= 12,
        "active tenant keeps ~half the service: {first_20:?}"
    );
}

/// Bursty (MMPP) arrivals drive the same machinery to a full drain.
#[test]
fn bursty_fleet_drains() {
    let cfg = FleetConfig {
        n_devices: 24,
        duration_s: 6.0,
        rate_rps: 12.0,
        burst: Some(BurstProfile::flash_crowd(12.0)),
        tenants: 2,
        seed: 0xB5,
        ..FleetConfig::default()
    };
    let rep = run_fleet(&cfg).unwrap();
    assert!(rep.offered > 0);
    assert_eq!(rep.completed, rep.offered, "bursty trace drains");
    assert_eq!(
        rep.generated_tokens,
        rep.completed as u64 * cfg.params.max_new_tokens as u64,
        "every request runs to its token budget (mock never ends early)"
    );
}

// ---------------------------------------------------------------------------
// sim vs threaded cross-check
// ---------------------------------------------------------------------------

/// Drive the *identical* `SimDevice` model + scheduler from real OS
/// threads (wall-clock, racy interleavings) and from the virtual-clock
/// sim, on a tiny 2-device workload. Timing-dependent quantities
/// (latencies, slot assignment, acceptance) may differ; the logical
/// outcome must not: every request completes with exactly its token
/// budget, and the cloud drains with slots and blocks conserved.
#[test]
fn sim_vs_threaded_cross_check_tiny_trace() {
    let params = SyneraParams {
        use_conf: false,
        use_imp: true,
        budget: 1.0, // offload every chunk: maximal cloud interaction
        max_new_tokens: 8,
        batch: BatchPolicy { max_sessions: 4, ..BatchPolicy::default() },
        ..SyneraParams::default()
    };

    // --- virtual-clock side ---
    let cfg = FleetConfig {
        n_devices: 2,
        duration_s: 3.0,
        rate_rps: 2.0,
        tenants: 1,
        params: params.clone(),
        seed: 0x2DEF,
        ..FleetConfig::default()
    };
    let rep = run_fleet(&cfg).unwrap();
    assert!(rep.offered > 0);
    assert_eq!(rep.completed, rep.offered, "sim drains the tiny trace");
    assert_eq!(
        rep.generated_tokens,
        rep.completed as u64 * params.max_new_tokens as u64,
        "sim: every request ends exactly at its token budget"
    );
    assert!(rep.offload_rounds > 0, "budget 1.0 must exercise the cloud path");

    // --- threaded side: same device model, real channels ---
    let (done, sched) = threaded_tiny_run(2, 3, &params, 0x2DEF);
    assert_eq!(done.len(), 6, "both devices finish all requests");
    for (req, tokens) in &done {
        assert_eq!(
            *tokens,
            params.max_new_tokens,
            "threaded: request {req:#x} ends exactly at its token budget"
        );
    }
    assert!(sched.is_idle(), "cloud drained");
    assert_eq!(sched.engine.free_slots(), 4, "slots conserved");
    assert_eq!(sched.engine.allocs, sched.engine.frees);
    assert_eq!(sched.sessions().free_blocks(), sched.sessions().block_capacity());
    assert!(sched.stats.verifies_done > 0);
}

/// Minimal threaded harness: one cloud thread over the mock engine,
/// `n_devices` device threads running `SimDevice` request loops.
/// Returns the per-request generated-token counts and the drained
/// scheduler for conservation checks.
fn threaded_tiny_run(
    n_devices: usize,
    requests_per_device: usize,
    params: &SyneraParams,
    seed: u64,
) -> (Vec<(u64, usize)>, Scheduler<MockBatchEngine>) {
    type Reply = (usize, u32); // (accepted, next_token)
    enum ToCloud {
        Up(CloudRequest, Sender<Reply>),
        Release(u64),
    }

    let (tx, rx) = channel::<ToCloud>();
    let policy = BatchPolicy { tenant_weights: vec![1.0], ..params.batch.clone() };
    let seed_cloud = seed;
    let cloud = std::thread::spawn(move || -> Scheduler<MockBatchEngine> {
        let engine = MockBatchEngine::new(4, 32, VOCAB, 4096);
        let mut sched = Scheduler::with_policy(engine, seed_cloud, policy);
        let mut replies: HashMap<u64, Sender<Reply>> = HashMap::new();
        let mut open = true;
        while open || !sched.is_idle() {
            loop {
                match rx.recv_timeout(Duration::from_micros(100)) {
                    Ok(ToCloud::Up(req, reply)) => {
                        let CloudRequest::Verify { request_id, .. } = &req else {
                            panic!("device sent a non-verify request")
                        };
                        replies.insert(*request_id, reply);
                        sched.submit_tenant(0, req).unwrap();
                    }
                    Ok(ToCloud::Release(id)) => {
                        sched.submit(CloudRequest::Release { request_id: id }).unwrap();
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
            let (events, _) = sched.tick().unwrap();
            for e in events {
                if let CloudEvent::VerifyDone { request_id, outcome, .. } = e {
                    if let Some(ch) = replies.get(&request_id) {
                        let _ = ch.send((outcome.accepted, outcome.next_token));
                    }
                }
            }
        }
        sched
    });

    let profile = OffloadProfile::synthetic();
    let mut workers = Vec::new();
    for d in 0..n_devices {
        let tx = tx.clone();
        let params = params.clone();
        let profile = profile.clone();
        workers.push(std::thread::spawn(move || -> Vec<(u64, usize)> {
            // the SAME constructor arguments the sim driver uses
            let mut model = SimDevice::new(d as u32, 0, &profile, &params, seed);
            let mut out = Vec::new();
            for r in 0..requests_per_device {
                let req_id = ((d as u64) << 32) | r as u64;
                let sample = generate(Task::Xsum, 1, r as u64);
                let mut seq = sample.prompt.clone();
                let mut cloud_len = 0usize;
                let mut generated = 0usize;
                while generated < params.max_new_tokens {
                    let gamma = params.gamma.min(params.max_new_tokens - generated).max(1);
                    let chunk = model.draft_chunk(gamma);
                    if !model.decide_offload(&chunk, generated) {
                        seq.extend_from_slice(&chunk.tokens);
                        generated += chunk.tokens.len();
                        continue;
                    }
                    let dists: Vec<_> = chunk
                        .tokens
                        .iter()
                        .zip(&chunk.confs)
                        .map(|(&t, &c)| compress_dist(&SimDevice::dense_probs(t, c), 8))
                        .collect();
                    let uncached: Vec<u32> = seq[cloud_len..].to_vec();
                    let start_len = seq.len();
                    // mirror the sim's RNG discipline: the PI bet is
                    // placed (and its draws consumed) before the reply
                    if params.parallel_inference && chunk.tokens.len() > 1 {
                        let _ = model.pi_bet(&chunk);
                    }
                    let (rtx, rrx) = channel::<(usize, u32)>();
                    tx.send(ToCloud::Up(
                        CloudRequest::Verify {
                            request_id: req_id,
                            device_id: d as u32,
                            uncached,
                            draft: chunk.tokens.clone(),
                            dists,
                            greedy: params.greedy,
                            ctx: Default::default(),
                        },
                        rtx,
                    ))
                    .unwrap();
                    let (accepted, next_token) =
                        rrx.recv_timeout(Duration::from_secs(30)).expect("verify reply");
                    let accepted = accepted.min(chunk.tokens.len());
                    cloud_len = start_len + accepted;
                    let room = params.max_new_tokens - generated;
                    let mut commit: Vec<u32> = chunk.tokens[..accepted].to_vec();
                    commit.push(next_token); // mock never emits EOS
                    commit.truncate(room);
                    generated += commit.len();
                    seq.extend_from_slice(&commit);
                }
                if cloud_len > 0 {
                    tx.send(ToCloud::Release(req_id)).unwrap();
                }
                out.push((req_id, generated));
            }
            out
        }));
    }
    drop(tx);
    let mut done = Vec::new();
    for w in workers {
        done.extend(w.join().expect("device thread"));
    }
    let sched = cloud.join().expect("cloud thread");
    (done, sched)
}
