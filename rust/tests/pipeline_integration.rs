//! Integration over the full pipelines: every method end-to-end on real
//! artifacts, scheduler behaviour under contention, and cross-mode
//! consistency properties.

use synera::cloud::scheduler::{CloudEvent, CloudRequest, Scheduler};
use synera::config::Scenario;
use synera::coordinator::eval::{eval_method, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::model::CloudEngine;
use synera::net::wire::Dist;
use synera::runtime::Runtime;
use synera::workload::synthlang::{generate, Task};

fn opts(task: Task, n: usize) -> EvalOptions {
    EvalOptions { n_samples: n, task }
}

#[test]
fn all_methods_complete_and_order_sanely() {
    let rt = Runtime::load_default().unwrap();
    // the weakest SLM: the quality gaps are widest here
    let scen = Scenario::default_pair("s160m", "l13b");
    let mut q = std::collections::HashMap::new();
    for m in [Method::EdgeCentric, Method::CloudCentric, Method::Hybrid, Method::Synera] {
        let rep = eval_method(&rt, &scen, m, &opts(Task::Cnndm, 6)).unwrap();
        assert_eq!(rep.n, 6);
        assert!(rep.quality >= 0.0 && rep.quality <= 1.0);
        q.insert(m.name(), rep.quality);
    }
    // quality ordering invariants that hold by construction
    assert!(q["Cloud-centric"] > q["Edge-centric"] + 0.05, "{q:?}");
    assert!(q["Synera"] > q["Edge-centric"], "{q:?}");
    assert!(q["Hybrid"] > q["Edge-centric"], "{q:?}");
}

#[test]
fn synera_offload_rate_tracks_budget() {
    let rt = Runtime::load_default().unwrap();
    let mut scen = Scenario::default_pair("s1b", "l13b");
    let mut rates = Vec::new();
    for b in [0.0, 0.3, 0.9] {
        scen.params.budget = b;
        let rep = eval_method(&rt, &scen, Method::Synera, &opts(Task::Xsum, 6)).unwrap();
        rates.push(rep.offload_rate);
    }
    assert!(rates[0] <= rates[1] + 1e-9 && rates[1] <= rates[2] + 1e-9, "{rates:?}");
    assert!(rates[0] < 0.15, "budget 0 should rarely offload: {rates:?}");
}

#[test]
fn zero_budget_synera_costs_nothing_and_matches_edge_quality_band() {
    let rt = Runtime::load_default().unwrap();
    let mut scen = Scenario::default_pair("s160m", "l13b");
    scen.params.budget = 0.0;
    scen.params.use_conf = true;
    let rep = eval_method(&rt, &scen, Method::Synera, &opts(Task::Cnndm, 6)).unwrap();
    assert!(rep.w < 0.2, "W={} at zero budget", rep.w);
}

#[test]
fn compression_reduces_uplink_bytes_noticeably() {
    let rt = Runtime::load_default().unwrap();
    let mut scen = Scenario::default_pair("s1b", "l13b");
    scen.params.budget = 0.8;
    let with = eval_method(&rt, &scen, Method::Synera, &opts(Task::Xsum, 6)).unwrap();
    scen.params.compression = false;
    let without = eval_method(&rt, &scen, Method::Synera, &opts(Task::Xsum, 6)).unwrap();
    assert!(
        (with.bytes_up as f64) < 0.25 * without.bytes_up as f64,
        "compressed {} vs dense {}",
        with.bytes_up,
        without.bytes_up
    );
}

#[test]
fn scheduler_queues_when_slots_exhausted_and_recovers() {
    let rt = Runtime::load_default().unwrap();
    let mut sched = Scheduler::new(CloudEngine::new(rt.model("l13b").unwrap()).unwrap(), 7);
    let slots = sched.engine.slots;
    let n_req = slots + 2; // oversubscribe
    for i in 0..n_req {
        let p = generate(Task::Kgqa, 1, i as u64).prompt;
        sched
            .submit(CloudRequest::Verify {
                request_id: 100 + i as u64,
                device_id: i as u32,
                uncached: p,
                draft: vec![200, 201, 202, 203],
                dists: vec![Dist::Dense(vec![1.0 / 512.0; 512]); 4],
                greedy: true,
                ctx: Default::default(),
            })
            .unwrap();
    }
    let mut done = std::collections::HashSet::new();
    for _ in 0..200 {
        let (events, _) = sched.tick().unwrap();
        for e in events {
            if let CloudEvent::VerifyDone { request_id, .. } = e {
                done.insert(request_id);
            }
        }
        // free finished sessions so queued requests get slots
        let done_now: Vec<u64> = done.iter().copied().collect();
        for id in done_now {
            sched.submit(CloudRequest::Release { request_id: id }).unwrap();
        }
        if done.len() == n_req {
            break;
        }
    }
    assert_eq!(done.len(), n_req, "all oversubscribed verifies must finish");
    assert!(sched.is_idle());
}

#[test]
fn verify_accept_counts_within_gamma() {
    let rt = Runtime::load_default().unwrap();
    let mut sched = Scheduler::new(CloudEngine::new(rt.model("l13b").unwrap()).unwrap(), 3);
    let p = generate(Task::Cnndm, 1, 0).prompt;
    sched
        .submit(CloudRequest::Verify {
            request_id: 1,
            device_id: 0,
            uncached: p,
            draft: vec![282, 303, 277, 284],
            dists: vec![Dist::Dense(vec![1.0 / 512.0; 512]); 4],
            greedy: true,
            ctx: Default::default(),
        })
        .unwrap();
    let mut seen = None;
    for _ in 0..50 {
        let (events, _) = sched.tick().unwrap();
        for e in events {
            if let CloudEvent::VerifyDone { outcome, .. } = e {
                seen = Some(outcome);
            }
        }
        if seen.is_some() {
            break;
        }
    }
    let o = seen.expect("verification completed");
    assert!(o.accepted <= 4);
    assert!((o.next_token as usize) < 512);
}

#[test]
fn edge_centric_quality_ladder_across_slms() {
    // bigger device models must not be worse on the easy classification task
    let rt = Runtime::load_default().unwrap();
    let mut quals = Vec::new();
    for slm in ["s160m", "s7b"] {
        let scen = Scenario::default_pair(slm, "l13b");
        let rep = eval_method(&rt, &scen, Method::EdgeCentric, &opts(Task::Sst2, 10)).unwrap();
        quals.push(rep.quality);
    }
    assert!(quals[1] >= quals[0], "capability ladder inverted: {quals:?}");
}
