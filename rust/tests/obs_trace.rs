//! Observability gates (artifact-free): the trace layer must be a
//! pure observer. Same seed ⇒ byte-identical exports, tracing on vs
//! off ⇒ bit-identical fleet reports, exports are valid JSON with
//! balanced spans, and the metrics registry samples on its cadence
//! with end-of-run gauges matching the drained state.

use synera::config::{BatchPolicy, SyneraParams};
use synera::metrics::stats::Summary;
use synera::obs::export::{chrome_trace_string, events_jsonl_string, metrics_jsonl_string};
use synera::obs::registry::{self, RegistryShared};
use synera::obs::trace::{self, Ph, TraceShared, TraceSink};
use synera::sim::{run_fleet, FleetConfig, FleetReport};
use synera::util::json::Json;

const TRACE_CAP: usize = 1 << 20;

/// Small full-drain fleet (stop_s = 0): every request completes, so
/// every opened span closes and gauges settle to the idle state.
fn traced_cfg(trace: Option<TraceShared>, registry: Option<RegistryShared>) -> FleetConfig {
    FleetConfig {
        n_devices: 24,
        duration_s: 3.0,
        rate_rps: 12.0,
        tenants: 3,
        params: SyneraParams {
            batch: BatchPolicy { max_sessions: 8, ..BatchPolicy::default() },
            ..SyneraParams::default()
        },
        seed: 0x0B57,
        trace,
        registry,
        ..FleetConfig::default()
    }
}

fn run_traced() -> (FleetReport, TraceShared, RegistryShared) {
    let tr = trace::shared(TraceSink::virtual_time(TRACE_CAP));
    let reg = registry::shared(0.25);
    let cfg = traced_cfg(Some(tr.clone()), Some(reg.clone()));
    let rep = run_fleet(&cfg).unwrap();
    (rep, tr, reg)
}

fn assert_summary_bits_eq(a: &Summary, b: &Summary, what: &str) {
    assert_eq!(a.n, b.n, "{what}: n");
    for (x, y, f) in [
        (a.mean, b.mean, "mean"),
        (a.p50, b.p50, "p50"),
        (a.p95, b.p95, "p95"),
        (a.max, b.max, "max"),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: {f} {x} vs {y}");
    }
}

/// Same seed ⇒ byte-identical trace and metrics exports. This is the
/// strongest determinism gate: any wall-clock or iteration-order
/// leakage into the virtual-time event stream fails it.
#[test]
fn same_seed_trace_is_byte_identical() {
    let (_, tr_a, reg_a) = run_traced();
    let (_, tr_b, reg_b) = run_traced();
    let (a, b) = (tr_a.lock().unwrap(), tr_b.lock().unwrap());
    assert!(!a.is_empty(), "trace recorded events");
    assert_eq!(a.dropped(), 0, "cap large enough for this run");
    assert_eq!(chrome_trace_string(&a), chrome_trace_string(&b));
    assert_eq!(events_jsonl_string(&a), events_jsonl_string(&b));
    let (ra, rb) = (reg_a.lock().unwrap(), reg_b.lock().unwrap());
    assert!(!ra.samples.is_empty(), "registry sampled");
    assert_eq!(metrics_jsonl_string(&ra), metrics_jsonl_string(&rb));
}

/// The Chrome export parses as JSON, carries metadata + payload
/// events, and every span opened on a track is closed (full drain).
#[test]
fn chrome_export_is_valid_and_spans_balance() {
    let (rep, tr, _) = run_traced();
    assert!(rep.offered > 0 && rep.completed == rep.offered, "full drain: {rep:?}");
    let sink = tr.lock().unwrap();
    assert_eq!(sink.span_imbalance(), 0, "all spans closed");

    let doc = Json::parse(&chrome_trace_string(&sink)).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let ph_count = |code: &str| {
        events.iter().filter(|e| e.opt("ph").and_then(|p| p.as_str().ok()) == Some(code)).count()
    };
    assert!(ph_count("M") > 0, "process/thread name metadata present");
    assert!(ph_count("B") > 0 && ph_count("B") == ph_count("E"), "B/E balance");
    assert!(ph_count("i") > 0, "instants present");
    assert!(ph_count("X") > 0, "per-tick phase slices present");

    // the request lifecycle appears: one request span per completion
    let named = |n: &str, ph: Ph| sink.events().filter(|e| e.name == n && e.ph == ph).count();
    assert_eq!(named("request", Ph::Begin), rep.completed, "request spans");
    assert!(named("round", Ph::Begin) > 0, "offload rounds traced");
    assert!(named("uplink", Ph::Begin) > 0, "uplink spans traced");
    for n in ["arrive", "enqueue", "admit", "verify_commit", "device_commit"] {
        assert!(named(n, Ph::Instant) > 0, "instant {n} present");
    }
    for n in ["wfq-drain", "paging", "pack", "engine", "commit"] {
        assert!(named(n, Ph::Complete) > 0, "phase slice {n} present");
    }
}

/// Tracing is a pure observer: enabling it must not perturb the
/// simulation (identical RNG draws, identical reports).
#[test]
fn tracing_on_vs_off_is_bit_identical() {
    let off = run_fleet(&traced_cfg(None, None)).unwrap();
    let (on, _, _) = run_traced();
    assert_eq!(off.offered, on.offered);
    assert_eq!(off.completed, on.completed);
    assert_eq!(off.generated_tokens, on.generated_tokens);
    assert_eq!(off.offload_rounds, on.offload_rounds);
    assert_eq!(off.cloud_draft_rows, on.cloud_draft_rows);
    assert_eq!(off.virtual_s.to_bits(), on.virtual_s.to_bits(), "virtual horizon");
    for (a, b) in off.tenants.iter().zip(&on.tenants) {
        assert_eq!(a.completed, b.completed, "tenant {}", a.tenant);
        assert_summary_bits_eq(&a.ttft, &b.ttft, "tenant ttft");
        assert_summary_bits_eq(&a.tbt, &b.tbt, "tenant tbt");
    }
}

/// Registry samples land on the virtual-time cadence, the JSONL
/// export parses line-by-line, and end-of-run gauges match the
/// drained scheduler state (no resident sessions, all blocks free).
#[test]
fn registry_cadence_and_end_state() {
    let (rep, _, reg) = run_traced();
    assert!(rep.completed == rep.offered, "full drain");
    let r = reg.lock().unwrap();
    assert!(r.samples.len() > 10, "multiple snapshots: {}", r.samples.len());
    let mut last = f64::NEG_INFINITY;
    for s in &r.samples {
        assert!(s.t_s >= last, "sample times monotone");
        last = s.t_s;
    }
    for line in metrics_jsonl_string(&r).lines() {
        let j = Json::parse(line).unwrap();
        assert!(j.opt("t_s").is_some() || j.opt("hist").is_some(), "line shape: {line}");
    }
    // end-of-run gauges reflect the drained state
    let free = r.gauge("cloud.free_blocks.0").unwrap();
    let cap = r.gauge("cloud.block_capacity.0").unwrap();
    assert_eq!(free, cap, "all KV blocks free after drain");
    assert_eq!(r.gauge("cloud.sessions_open.0"), Some(0.0), "no open sessions");
    assert_eq!(r.gauge("cloud.queue_depth.0"), Some(0.0), "queue drained");
    // only requests that offload at least once reach the router
    let routed = r.gauge("router.routed").unwrap();
    assert!(routed > 0.0 && routed <= rep.offered as f64, "routed {routed}");
}

/// Causal flow arrows join device and cloud tracks: every offload
/// round opens a `FlowStart` on the device, the cloud commit adds a
/// `FlowStep`, and the verdict's arrival closes with a `FlowEnd` back
/// on the device — all sharing one synthetic flow id (high bit set so
/// it can never collide with a request id).
#[test]
fn flow_arrows_join_device_and_cloud() {
    let (rep, tr, _) = run_traced();
    assert!(rep.offload_rounds > 0, "run offloaded");
    let sink = tr.lock().unwrap();
    let flows = |ph: Ph| sink.events().filter(move |e| e.name == "offload" && e.ph == ph);
    let starts = flows(Ph::FlowStart).count();
    let steps = flows(Ph::FlowStep).count();
    let ends = flows(Ph::FlowEnd).count();
    assert_eq!(starts, rep.offload_rounds as usize, "one arrow per offload round");
    assert_eq!(ends, starts, "full drain: every arrow lands back on the device");
    assert!(steps > 0 && steps <= starts, "cloud hop on the committed rounds: {steps}");
    for ph in [Ph::FlowStart, Ph::FlowStep, Ph::FlowEnd] {
        for e in flows(ph) {
            assert!(e.id >> 63 == 1, "flow id carries the sentinel bit: {:#x}", e.id);
            assert!(e.pid >= 2 || e.pid == trace::PID_CLOUD, "arrow on device/cloud track");
        }
    }
    // start ids and end ids pair up exactly (same round, same arrow)
    let ids = |ph: Ph| -> Vec<u64> {
        let mut v: Vec<u64> = flows(ph).map(|e| e.id).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(ids(Ph::FlowStart), ids(Ph::FlowEnd), "arrows open and close with one id");
}

/// With router replicas the placement/migration instants appear on
/// the router track and per-replica tick slices land on distinct
/// cloud threads.
#[test]
fn replicas_emit_router_and_per_replica_events() {
    let tr = trace::shared(TraceSink::virtual_time(TRACE_CAP));
    let cfg = FleetConfig {
        params: SyneraParams {
            batch: BatchPolicy {
                max_sessions: 8,
                replicas: 2,
                rebalance_threshold: 4,
                ..BatchPolicy::default()
            },
            ..SyneraParams::default()
        },
        ..traced_cfg(Some(tr.clone()), None)
    };
    let rep = run_fleet(&cfg).unwrap();
    assert!(rep.completed > 0);
    let sink = tr.lock().unwrap();
    let places = sink
        .events()
        .filter(|e| e.name == "place" && e.pid == trace::PID_ROUTER)
        .count();
    assert!(places > 0, "router placements traced");
    let tids: std::collections::BTreeSet<u32> = sink
        .events()
        .filter(|e| e.pid == trace::PID_CLOUD && e.ph == Ph::Complete)
        .map(|e| e.tid)
        .collect();
    assert_eq!(tids.len(), 2, "one cloud track per replica: {tids:?}");
}
