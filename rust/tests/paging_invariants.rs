//! Paged-KV subsystem invariants — artifact-free. Exercises the
//! [`BlockPool`] free-list, the [`SessionManager`] residency/eviction
//! state machine over the deterministic [`MockBatchEngine`], and
//! scheduler-level paging (more concurrent logical sessions than
//! physical slots), asserting block conservation (no leak, no double
//! free) and bit-identical KV round trips across swap-out/swap-in.

use std::collections::{HashMap, HashSet};

use synera::cloud::scheduler::{CloudEvent, CloudRequest, Scheduler};
use synera::cloud::sessions::SessionManager;
use synera::config::BatchPolicy;
use synera::model::cloud_engine::{BatchEngine, SlotChunk, SlotOwner};
use synera::net::wire::Dist;
use synera::runtime::SlotKv;
use synera::testutil::{check, usize_in, MockBatchEngine, MOCK_KV_ROW};

fn dense_dists(n: usize, vocab: usize) -> Vec<Dist> {
    vec![Dist::Dense(vec![1.0 / vocab as f32; vocab]); n]
}

fn paged_policy(max_sessions: usize) -> BatchPolicy {
    BatchPolicy { max_sessions, ..BatchPolicy::default() }
}

/// Swap-out → swap-in through the engine trait keeps the committed KV
/// rows bit-identical, even when the session lands in a different slot.
#[test]
fn mock_engine_kv_round_trip_is_bit_identical() {
    let mut eng = MockBatchEngine::new(4, 8, 64, 64);
    let a = eng.alloc_slot(SlotOwner::Request(1)).unwrap();
    eng.run_batch(&[SlotChunk { slot: a, tokens: vec![9, 10, 11] }]).unwrap();
    eng.run_batch(&[SlotChunk { slot: a, tokens: vec![12] }]).unwrap();
    let snap = eng.export_slot(a);
    assert_eq!(snap.len, 4);
    assert_eq!(snap.row, MOCK_KV_ROW);
    assert_eq!(snap.k.len(), 4 * MOCK_KV_ROW);
    eng.free_slot(a);

    let b = eng.alloc_slot(SlotOwner::Request(2)).unwrap();
    eng.import_slot(b, &snap).unwrap();
    assert_eq!(eng.slot_len[b], 4);
    assert_eq!(eng.export_slot(b), snap, "round trip not bit-identical");
}

/// Rollback before export keeps only the committed prefix in the
/// swapped image (rejected verify tails must not be resurrected).
#[test]
fn export_respects_rolled_back_length() {
    let mut eng = MockBatchEngine::new(2, 8, 64, 64);
    let s = eng.alloc_slot(SlotOwner::Request(1)).unwrap();
    eng.run_batch(&[SlotChunk { slot: s, tokens: vec![9, 10, 11, 12] }]).unwrap();
    let full = eng.export_slot(s);
    eng.rollback(s, 2);
    let rolled = eng.export_slot(s);
    assert_eq!(rolled.len, 2);
    assert_eq!(rolled.k[..], full.k[..2 * MOCK_KV_ROW]);
}

/// Property: any interleaving of open / run+swap / close conserves
/// blocks (no leak, no double free — the pool and mock panic on double
/// frees) and a swapped-out-then-in session's KV is bit-identical to
/// what it held when it lost its slot.
#[test]
fn prop_session_paging_conserves_blocks_and_preserves_kv() {
    check("session paging conserves blocks; KV round trips", |rng| {
        let slots = usize_in(rng, 2, 4);
        let max_sessions = slots + usize_in(rng, 1, 8);
        let mut eng = MockBatchEngine::new(slots, 4, 64, 64);
        let mut mgr = SessionManager::for_engine(&eng, &paged_policy(max_sessions));
        let pool_cap = mgr.block_capacity();
        let pinned: HashSet<u64> = HashSet::new();
        let mut shadow: HashMap<u64, SlotKv> = HashMap::new();
        let mut open: Vec<u64> = Vec::new();
        let mut next_id = 1u64;
        for _ in 0..usize_in(rng, 10, 60) {
            match rng.below(4) {
                0 => {
                    if mgr.can_open() {
                        mgr.open(next_id).map_err(|e| e.to_string())?;
                        shadow.insert(next_id, SlotKv::empty(MOCK_KV_ROW));
                        open.push(next_id);
                        next_id += 1;
                    }
                }
                1 | 2 => {
                    if open.is_empty() {
                        continue;
                    }
                    let id = open[usize_in(rng, 0, open.len() - 1)];
                    let slot = mgr
                        .ensure_resident(id, &mut eng, &pinned)
                        .map_err(|e| e.to_string())?
                        .expect("an unpinned victim always exists");
                    if eng.export_slot(slot) != shadow[&id] {
                        return Err(format!("session {id} KV changed across swaps"));
                    }
                    if eng.slot_len[slot] + 2 <= 64 {
                        let t = 9 + (id % 20) as u32;
                        eng.run_batch(&[SlotChunk { slot, tokens: vec![t, t + 1] }])
                            .map_err(|e| e.to_string())?;
                        mgr.note_rows(id, 2);
                        shadow.insert(id, eng.export_slot(slot));
                    }
                }
                _ => {
                    if open.is_empty() {
                        continue;
                    }
                    let i = usize_in(rng, 0, open.len() - 1);
                    let id = open.swap_remove(i);
                    mgr.close(id, &mut eng);
                    shadow.remove(&id);
                }
            }
        }
        for id in open {
            mgr.close(id, &mut eng);
        }
        if eng.free_slots() != slots {
            return Err(format!("slot leak: {} free of {slots}", eng.free_slots()));
        }
        if mgr.free_blocks() != pool_cap {
            return Err(format!("block leak: {} free of {pool_cap}", mgr.free_blocks()));
        }
        if eng.allocs != eng.frees {
            return Err(format!("alloc/free imbalance: {} vs {}", eng.allocs, eng.frees));
        }
        Ok(())
    });
}

/// Acceptance workload: 4× more concurrent verify sessions than
/// physical slots, several rounds each, all submitted up front. Every
/// round must complete (the compiled width no longer caps admission),
/// swapping must actually occur, and slots/blocks must be conserved.
#[test]
fn four_x_oversubscribed_verify_sessions_all_complete() {
    let slots = 4usize;
    let n_sessions = 16u64; // 4× the physical width
    let rounds = 3usize;
    let mut sched = Scheduler::with_policy(
        MockBatchEngine::new(slots, 8, 64, 4096),
        0x9A6E,
        paged_policy(n_sessions as usize),
    );
    let submit_round = |sched: &mut Scheduler<MockBatchEngine>, id: u64| {
        sched
            .submit(CloudRequest::Verify {
                request_id: id,
                device_id: id as u32,
                uncached: vec![12 + (id % 5) as u32; 4],
                draft: vec![9, 9],
                dists: dense_dists(2, 64),
                greedy: true,
                ctx: Default::default(),
            })
            .unwrap();
    };
    let mut rounds_done: HashMap<u64, usize> = HashMap::new();
    for id in 0..n_sessions {
        rounds_done.insert(id, 0);
        submit_round(&mut sched, id);
    }
    let total = n_sessions as usize * rounds;
    let mut completed = 0usize;
    for _ in 0..5_000 {
        let (events, _) = sched.tick().unwrap();
        for e in events {
            if let CloudEvent::VerifyDone { request_id, .. } = e {
                completed += 1;
                let done = rounds_done.get_mut(&request_id).unwrap();
                *done += 1;
                if *done < rounds {
                    submit_round(&mut sched, request_id);
                } else {
                    sched.submit(CloudRequest::Release { request_id }).unwrap();
                }
            }
        }
        if completed == total {
            break;
        }
    }
    assert_eq!(completed, total, "oversubscribed verify rounds must all finish");
    assert!(rounds_done.values().all(|&d| d == rounds), "every session ran every round");
    assert!(sched.is_idle());
    assert!(sched.stats.swap_outs > 0, "16 sessions over 4 slots must page");
    assert_eq!(sched.engine.free_slots(), slots, "all slots returned");
    assert_eq!(sched.engine.allocs, sched.engine.frees, "slot conservation");
    assert_eq!(
        sched.sessions().free_blocks(),
        sched.sessions().block_capacity(),
        "block conservation"
    );
}

/// Cloud-centric generations also page: 4× oversubscription over two
/// slots drains to completion with swapping, and nothing leaks.
#[test]
fn paged_generates_beyond_slots_all_complete() {
    let mut sched = Scheduler::with_policy(
        MockBatchEngine::new(2, 8, 64, 4096),
        0x6E4E,
        paged_policy(8),
    );
    for i in 0..8u64 {
        sched
            .submit(CloudRequest::Generate {
                request_id: i,
                prompt: vec![9; 5 + (i as usize % 7)],
                max_new: 4,
            })
            .unwrap();
    }
    let mut done = 0usize;
    for _ in 0..3_000 {
        let (events, _) = sched.tick().unwrap();
        for e in events {
            if let CloudEvent::Generated { tokens, .. } = e {
                assert_eq!(tokens.len(), 4, "mock never emits EOS: budget-bound");
                done += 1;
            }
        }
        if done == 8 {
            break;
        }
    }
    assert_eq!(done, 8, "all oversubscribed generations finish");
    assert!(sched.is_idle());
    assert!(sched.stats.swap_outs > 0, "8 sessions over 2 slots must page");
    assert_eq!(sched.engine.free_slots(), 2);
    assert_eq!(sched.engine.allocs, sched.engine.frees);
    assert_eq!(sched.sessions().free_blocks(), sched.sessions().block_capacity());
}

/// Swap-cost-aware eviction: among the LRU candidate window the victim
/// is the session with the fewest committed KV rows (cheapest to swap
/// back), not simply the least recently used one.
#[test]
fn eviction_prefers_fewest_rows_among_lru_candidates() {
    let mut eng = MockBatchEngine::new(3, 8, 64, 64);
    let mut mgr = SessionManager::for_engine(&eng, &paged_policy(8));
    let pinned: HashSet<u64> = HashSet::new();
    // residency (= LRU) order 1, 2, 3 with committed rows 8, 2, 6
    for (id, rows) in [(1u64, 8usize), (2, 2), (3, 6)] {
        mgr.open(id).unwrap();
        let slot = mgr.ensure_resident(id, &mut eng, &pinned).unwrap().unwrap();
        let toks: Vec<u32> = (0..rows as u32).map(|i| 9 + i).collect();
        eng.run_batch(&[SlotChunk { slot, tokens: toks }]).unwrap();
        mgr.note_rows(id, rows);
    }
    // the window over 3 residents spans the 2 oldest (⌈3/2⌉); pure LRU
    // would park session 1 (oldest), cost-aware parks 2
    mgr.open(4).unwrap();
    mgr.ensure_resident(4, &mut eng, &pinned).unwrap().unwrap();
    assert!(mgr.slot_of(2).is_none(), "fewest-rows session is the victim");
    assert!(mgr.slot_of(1).is_some(), "older but larger session survives");
    assert!(mgr.slot_of(3).is_some());
    assert_eq!(mgr.stats().swap_outs, 1);
}

/// ...but the cost preference only applies *within* the LRU window: a
/// cheap session that was scheduled recently enough to sit outside the
/// `EVICT_CANDIDATES` oldest residents is never chosen over them.
#[test]
fn eviction_cost_preference_is_bounded_by_the_lru_window() {
    assert!(
        synera::cloud::sessions::EVICT_CANDIDATES >= 3,
        "test layout assumes a window of 3 over 5 residents (cap ≥ ⌈5/2⌉)"
    );
    let mut eng = MockBatchEngine::new(5, 8, 64, 64);
    let mut mgr = SessionManager::for_engine(&eng, &paged_policy(12));
    let pinned: HashSet<u64> = HashSet::new();
    // LRU order 1..5; session 5 (most recent) is empty — the cheapest
    // possible swap — but sits outside the ⌈5/2⌉ = 3-oldest window
    for (id, rows) in [(1u64, 8usize), (2, 6), (3, 4), (4, 6), (5, 0)] {
        mgr.open(id).unwrap();
        let slot = mgr.ensure_resident(id, &mut eng, &pinned).unwrap().unwrap();
        if rows > 0 {
            let toks: Vec<u32> = (0..rows as u32).map(|i| 9 + i).collect();
            eng.run_batch(&[SlotChunk { slot, tokens: toks }]).unwrap();
            mgr.note_rows(id, rows);
        }
    }
    mgr.open(6).unwrap();
    mgr.ensure_resident(6, &mut eng, &pinned).unwrap().unwrap();
    assert!(mgr.slot_of(5).is_some(), "recent empty session is outside the window");
    assert!(mgr.slot_of(3).is_none(), "cheapest of the 3 oldest is the victim");
    for survivor in [1u64, 2, 4] {
        assert!(mgr.slot_of(survivor).is_some());
    }
}

/// A released-while-parked session returns its blocks to the pool.
#[test]
fn releasing_a_parked_session_frees_its_blocks() {
    // 1 slot, 3 sessions: at least two sessions sit parked at any time
    let mut sched =
        Scheduler::with_policy(MockBatchEngine::new(1, 8, 64, 4096), 0x10CB, paged_policy(3));
    for id in 0..3u64 {
        sched
            .submit(CloudRequest::Verify {
                request_id: id,
                device_id: id as u32,
                uncached: vec![12; 4],
                draft: vec![9, 9],
                dists: dense_dists(2, 64),
                greedy: true,
                ctx: Default::default(),
            })
            .unwrap();
    }
    let mut seen = 0usize;
    for _ in 0..200 {
        let (events, _) = sched.tick().unwrap();
        seen += events.len();
        if seen == 3 {
            break;
        }
    }
    assert_eq!(seen, 3, "all first rounds complete");
    // sessions keep their KV (resident or parked) until released
    assert!(sched.sessions().free_blocks() < sched.sessions().block_capacity());
    for id in 0..3u64 {
        sched.submit(CloudRequest::Release { request_id: id }).unwrap();
    }
    assert_eq!(sched.sessions().free_blocks(), sched.sessions().block_capacity());
    assert_eq!(sched.engine.free_slots(), 1);
    assert_eq!(sched.engine.allocs, sched.engine.frees);
}

/// The metrics registry's scheduler gauges mirror the live paging
/// state at every sample point, including mid-swap: no stale or
/// invariant-violating snapshot ever lands in the export.
#[test]
fn registry_gauges_track_live_paging_state() {
    use synera::obs::registry::{sample_scheduler, Registry};

    let slots = 2usize;
    let mut sched = Scheduler::with_policy(
        MockBatchEngine::new(slots, 8, 64, 4096),
        0x9A6F,
        paged_policy(8),
    );
    for id in 0..8u64 {
        sched
            .submit(CloudRequest::Verify {
                request_id: id,
                device_id: id as u32,
                uncached: vec![12 + (id % 5) as u32; 4],
                draft: vec![9, 9],
                dists: dense_dists(2, 64),
                greedy: true,
                ctx: Default::default(),
            })
            .unwrap();
    }
    let mut reg = Registry::new(0.0);
    let mut done = 0usize;
    for tick in 0..500 {
        let (events, _) = sched.tick().unwrap();
        for e in events {
            if let CloudEvent::VerifyDone { request_id, .. } = e {
                sched.submit(CloudRequest::Release { request_id }).unwrap();
                done += 1;
            }
        }
        sample_scheduler(&mut reg, 0, &sched);
        let g = |n: &str| reg.gauge(&format!("cloud.{n}.0")).unwrap();
        // gauges equal the live accessors they mirror
        assert_eq!(g("sessions_open"), sched.active_sessions() as f64, "tick {tick}");
        assert_eq!(g("free_blocks"), sched.sessions().free_blocks() as f64);
        assert_eq!(g("swap_ins"), sched.sessions().stats().swap_ins as f64);
        assert_eq!(g("swap_outs"), sched.sessions().stats().swap_outs as f64);
        // and satisfy the paging invariants at every sample point
        assert!(g("sessions_resident") <= slots as f64, "residency over width");
        assert_eq!(g("sessions_resident") + g("slots_free"), slots as f64);
        assert!(g("free_blocks") <= g("block_capacity"));
        if done == 8 {
            break;
        }
    }
    assert_eq!(done, 8, "workload drained");
    sample_scheduler(&mut reg, 0, &sched);
    let g = |n: &str| reg.gauge(&format!("cloud.{n}.0")).unwrap();
    assert!(g("swap_outs") > 0.0, "8 sessions over 2 slots must page");
    assert_eq!(g("sessions_open"), 0.0);
    assert_eq!(g("free_blocks"), g("block_capacity"), "block conservation in gauges");
    assert_eq!(g("slots_free"), slots as f64);
}
