//! Integration: PJRT runtime ↔ AOT artifacts.
//!
//! Requires `make artifacts`. Validates the executable ABI end to end:
//! determinism, split-vs-full equivalence (the python-side consistency
//! check replayed through the rust runtime), batch-slot isolation on the
//! cloud engine, and the fused importance invariant.

use synera::model::{BatchEngine, CloudEngine, DeviceEngine, SlotChunk, SlotOwner};
use synera::runtime::Runtime;
use synera::workload::{generate, Task};

fn prompt() -> Vec<u32> {
    generate(Task::Cnndm, 1, 0).prompt
}

#[test]
fn device_full_mode_is_deterministic() {
    let rt = Runtime::load_default().unwrap();
    let eng = DeviceEngine::new(rt.model("s160m").unwrap(), false).unwrap();
    let (mut s1, o1) = eng.prefill(&prompt()).unwrap();
    let (mut s2, o2) = eng.prefill(&prompt()).unwrap();
    assert_eq!(o1.token, o2.token);
    assert_eq!(o1.probs, o2.probs);
    let mut t1 = o1.token;
    let mut t2 = o2.token;
    for _ in 0..8 {
        let a = eng.step(&mut s1, t1, false, 1.0).unwrap();
        let b = eng.step(&mut s2, t2, false, 1.0).unwrap();
        assert_eq!(a.token, b.token);
        t1 = a.token;
        t2 = b.token;
    }
}

#[test]
fn split_mode_without_exits_matches_full_mode() {
    let rt = Runtime::load_default().unwrap();
    let model = rt.model("s160m").unwrap();
    let full = DeviceEngine::new(model.clone(), false).unwrap();
    let split = DeviceEngine::new(model, true).unwrap();
    let (mut sf, of) = full.prefill(&prompt()).unwrap();
    let (mut ss, os) = split.prefill(&prompt()).unwrap();
    assert_eq!(of.token, os.token);
    let mut tok = of.token;
    for i in 0..10 {
        // threshold 2.0 can never fire (margin ≤ 1), so split must equal full
        let a = full.step(&mut sf, tok, true, 2.0).unwrap();
        let b = split.step(&mut ss, tok, true, 2.0).unwrap();
        assert!(!b.exited);
        assert_eq!(a.token, b.token, "step {i}");
        let max_dp = a
            .probs
            .iter()
            .zip(&b.probs)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(max_dp < 5e-4, "probs diverged by {max_dp} at step {i}");
        tok = a.token;
    }
}

#[test]
fn split_mode_with_exits_keeps_running_and_backfills() {
    let rt = Runtime::load_default().unwrap();
    let eng = DeviceEngine::new(rt.model("s160m").unwrap(), true).unwrap();
    let (mut s, o) = eng.prefill(&prompt()).unwrap();
    let mut tok = o.token;
    let mut n_exits = 0;
    for _ in 0..12 {
        // threshold 0 exits whenever allowed
        let st = eng.step(&mut s, tok, true, 0.0).unwrap();
        n_exits += st.exited as usize;
        assert!(st.probs.len() == eng.model.meta.vocab);
        tok = st.token;
    }
    assert!(n_exits > 0, "threshold 0 must trigger exits");
    // deep cache can lag at most the backfill capacity
    assert!(s.len - s.p2_len <= 4);
}

#[test]
fn importance_mass_tracks_prompt_length() {
    let rt = Runtime::load_default().unwrap();
    let eng = DeviceEngine::new(rt.model("s160m").unwrap(), false).unwrap();
    let p = prompt();
    let (sess, _) = eng.prefill(&p).unwrap();
    let h = eng.model.meta.n_heads as f32;
    let total: f32 = sess.importance.iter().sum();
    // per executed chunk, each live query row distributes H probability
    // mass per layer; the L2 graph averages over layers → ≈ P×H total
    let expect = p.len() as f32 * h;
    assert!(
        (total - expect).abs() / expect < 0.05,
        "importance mass {total} vs expected {expect}"
    );
}

#[test]
fn cloud_slots_are_isolated() {
    let rt = Runtime::load_default().unwrap();
    let mut eng = CloudEngine::new(rt.model("l13b").unwrap()).unwrap();
    let p = prompt();
    let a = eng.alloc_slot(1).unwrap();
    let b = eng.alloc_slot(2).unwrap();
    assert_ne!(a, b);

    // same content in two slots, one batched with a different third slot:
    // rows must be identical regardless of what other slots do
    let (r1, _) = eng
        .run_batch(&[SlotChunk { slot: a, tokens: p.clone() }])
        .unwrap();
    let c = eng.alloc_slot(3).unwrap();
    let other = generate(Task::Kgqa, 1, 5).prompt;
    let (r2, _) = eng
        .run_batch(&[
            SlotChunk { slot: b, tokens: p.clone() },
            SlotChunk { slot: c, tokens: other },
        ])
        .unwrap();
    let rows_a = &r1[0];
    let rows_b = r2.iter().find(|r| r.slot == b).unwrap();
    assert_eq!(rows_a.n_rows, rows_b.n_rows);
    let max_d = rows_a
        .rows
        .iter()
        .zip(&rows_b.rows)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_d < 1e-4, "slot isolation violated: {max_d}");
}

#[test]
fn cloud_rollback_masks_stale_kv() {
    let rt = Runtime::load_default().unwrap();
    let mut eng = CloudEngine::new(rt.model("l13b").unwrap()).unwrap();
    let p = prompt();
    let s = eng.alloc_slot(1).unwrap();
    let (_, _) = eng.run_batch(&[SlotChunk { slot: s, tokens: p.clone() }]).unwrap();
    let base_len = eng.slot_len[s];

    // extend with junk, roll back, extend with the real continuation:
    // logits must match a fresh run that never saw the junk
    let junk = vec![400u32, 401, 402];
    eng.run_batch(&[SlotChunk { slot: s, tokens: junk }]).unwrap();
    eng.rollback(s, base_len);
    let cont = vec![200u32, 201];
    let (r_rolled, _) = eng
        .run_batch(&[SlotChunk { slot: s, tokens: cont.clone() }])
        .unwrap();

    let s2 = eng.alloc_slot(9).unwrap();
    let mut full = p;
    full.extend_from_slice(&cont);
    let (r_fresh, _) = eng.run_batch(&[SlotChunk { slot: s2, tokens: full }]).unwrap();
    let v = eng.model.meta.vocab;
    let tail_fresh = &r_fresh[0].rows[(r_fresh[0].n_rows - 2) * v..];
    let max_d = r_rolled[0]
        .rows
        .iter()
        .zip(tail_fresh)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_d < 1e-3, "rollback leaked stale KV: {max_d}");
}

#[test]
fn warmup_runs_in_a_free_slot_and_preserves_committed_kv() {
    let rt = Runtime::load_default().unwrap();
    let mut eng = CloudEngine::new(rt.model("l13b").unwrap()).unwrap();
    let p = prompt();
    // occupy slot 0 with committed KV, as a live session would
    let s = eng.alloc_slot(7).unwrap();
    eng.run_batch(&[SlotChunk { slot: s, tokens: p.clone() }]).unwrap();
    let len = eng.slot_len[s];

    // regression: warmup used to run throwaway rows at positions 0–1 of
    // slot 0, silently clobbering the session's KV
    eng.warmup().unwrap();
    assert_eq!(eng.slot_len[s], len, "warmup altered a busy slot's length");
    assert_eq!(
        eng.slot_owner[s],
        Some(SlotOwner::Request(7)),
        "warmup altered slot ownership"
    );

    // the continuation must match a fresh engine that never warmed up
    let cont = vec![200u32, 201];
    let (r_warm, _) = eng.run_batch(&[SlotChunk { slot: s, tokens: cont.clone() }]).unwrap();
    let mut fresh = CloudEngine::new(rt.model("l13b").unwrap()).unwrap();
    let s2 = fresh.alloc_slot(1).unwrap();
    fresh.run_batch(&[SlotChunk { slot: s2, tokens: p }]).unwrap();
    let (r_fresh, _) = fresh.run_batch(&[SlotChunk { slot: s2, tokens: cont }]).unwrap();
    let max_d = r_warm[0]
        .rows
        .iter()
        .zip(&r_fresh[0].rows)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_d < 1e-4, "warmup corrupted committed KV: {max_d}");
}

#[test]
fn warmup_bails_when_every_slot_is_busy() {
    let rt = Runtime::load_default().unwrap();
    let mut eng = CloudEngine::new(rt.model("l13b").unwrap()).unwrap();
    for i in 0..eng.slots {
        eng.alloc_slot(i as u64).unwrap();
    }
    assert!(eng.warmup().is_err(), "warmup must refuse to touch occupied slots");
}

#[test]
fn export_import_slot_round_trips_committed_kv() {
    let rt = Runtime::load_default().unwrap();
    let mut eng = CloudEngine::new(rt.model("l13b").unwrap()).unwrap();
    let p = prompt();
    let a = eng.alloc_slot(1).unwrap();
    eng.run_batch(&[SlotChunk { slot: a, tokens: p.clone() }]).unwrap();
    let snap = eng.export_slot(a);
    assert_eq!(snap.len, eng.slot_len[a]);
    assert_eq!(snap.row, eng.kv_row_width());

    // restore into a different slot: continuations must match exactly
    // (paged swap-in is a verbatim copy) and re-export bit-identically
    let b = eng.alloc_slot(2).unwrap();
    eng.import_slot(b, &snap).unwrap();
    assert_eq!(eng.export_slot(b), snap, "swap round trip not bit-identical");
    let cont = vec![200u32, 201];
    let (ra, _) = eng.run_batch(&[SlotChunk { slot: a, tokens: cont.clone() }]).unwrap();
    let (rb, _) = eng.run_batch(&[SlotChunk { slot: b, tokens: cont }]).unwrap();
    let max_d = ra[0]
        .rows
        .iter()
        .zip(&rb[0].rows)
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_d < 1e-4, "imported KV diverged from source: {max_d}");
}

#[test]
fn run_decode_rejects_bad_and_duplicate_slots() {
    let rt = Runtime::load_default().unwrap();
    let mut eng = CloudEngine::new(rt.model("l13b").unwrap()).unwrap();
    let s = eng.alloc_slot(1).unwrap();
    eng.run_batch(&[SlotChunk { slot: s, tokens: vec![1, 5] }]).unwrap();
    // regression: these used to panic on raw indexing instead of Err-ing
    assert!(eng.run_decode(&[(eng.slots + 3, 7)]).is_err(), "out-of-range slot");
    assert!(eng.run_decode(&[(s, 7), (s, 8)]).is_err(), "duplicate slot");
    // the valid path still works and is one row long
    let (r, _) = eng.run_decode(&[(s, 7)]).unwrap();
    assert_eq!(r.len(), 1);
    assert_eq!(r[0].n_rows, 1);
    assert_eq!(r[0].rows.len(), eng.model.meta.vocab);
}

#[test]
fn quantized_variants_load_and_differ() {
    let rt = Runtime::load_default().unwrap();
    let base = DeviceEngine::new(rt.model("s7b").unwrap(), false).unwrap();
    let bnb = DeviceEngine::new(rt.model_variant("s7b", Some("s7b_bnb4")).unwrap(), false).unwrap();
    let (_, ob) = base.prefill(&prompt()).unwrap();
    let (_, oq) = bnb.prefill(&prompt()).unwrap();
    assert_ne!(ob.probs, oq.probs, "quantized weights should alter logits");
}
