//! Shared-prefix KV cache gates (artifact-free): refcount conservation
//! over the [`BlockPool`]/[`PrefixIndex`] pair under randomized
//! admit/park/release traffic, copy-on-write divergence that leaves the
//! canonical block bit-identical for its other holders, full-block-only
//! radix matching, migration round trips without cross-replica
//! aliasing, same-seed fleet determinism with sharing enabled, and the
//! Fig 15d knee direction (host blocks per admitted session fall as the
//! prefix-share ratio rises).

use std::collections::{HashMap, HashSet};

use synera::cloud::scheduler::{CloudRequest, Scheduler};
use synera::cloud::sessions::{SessionManager, BLOCK_TOKENS};
use synera::config::{BatchPolicy, SyneraParams};
use synera::model::cloud_engine::{BatchEngine, SlotChunk, SlotOwner};
use synera::net::wire::Dist;
use synera::runtime::prefix::{chain_hash, Inserted, ROOT};
use synera::runtime::{BlockPool, PrefixIndex, SlotKv};
use synera::sim::{run_fleet, FleetConfig};
use synera::testutil::{check, usize_in, MockBatchEngine, MOCK_KV_ROW};

fn dense_dists(n: usize, vocab: usize) -> Vec<Dist> {
    vec![Dist::Dense(vec![1.0 / vocab as f32; vocab]); n]
}

fn shared_policy(max_sessions: usize) -> BatchPolicy {
    BatchPolicy { max_sessions, prefix_cache: true, ..BatchPolicy::default() }
}

/// Reference KV image: what the mock engine commits for `tokens` from
/// position 0 (content + position addressed, so any session holding the
/// same chain at the same positions holds bit-identical rows).
fn reference_kv(tokens: &[u32]) -> SlotKv {
    let mut eng = MockBatchEngine::new(1, tokens.len().max(1), 64, tokens.len().max(1));
    let slot = eng.alloc_slot(SlotOwner::Request(999)).unwrap();
    eng.run_batch(&[SlotChunk { slot, tokens: tokens.to_vec() }]).unwrap();
    eng.export_slot(slot)
}

/// Deterministic per-family prompt material (distinct families never
/// share a first block, so their chains never collide).
fn family_tokens(family: u64, len: usize) -> Vec<u32> {
    (0..len).map(|i| 9 + ((family * 17 + i as u64) % 31) as u32).collect()
}

/// Property: randomized admit / park / release traffic over the raw
/// pool + index pair conserves references exactly — the pool's live
/// block set always equals the union of per-session private blocks,
/// per-session shared blocks and index-held canonicals, with the shadow
/// refcount matching `ref_count` block by block. Full teardown returns
/// every block to the free list.
#[test]
fn prop_pool_and_index_conserve_refcounts() {
    struct Sess {
        tokens: Vec<u32>,
        shared: Vec<usize>,
        table: Option<synera::runtime::BlockTable>,
    }
    check("prefix pool/index refcount conservation", |rng| {
        let bt = 4usize;
        let cap = 1024usize; // far past any reachable footprint: store never exhausts
        let row = 2usize;
        let mut pool = BlockPool::new(cap, bt, row);
        let mut idx = PrefixIndex::new(bt);
        let mut refs: HashMap<usize, u32> = HashMap::new();
        let mut idx_blocks: HashSet<usize> = HashSet::new();
        let mut sessions: Vec<Sess> = Vec::new();

        let audit = |pool: &BlockPool,
                     refs: &HashMap<usize, u32>,
                     idx_blocks: &HashSet<usize>,
                     sessions: &[Sess]|
         -> Result<(), String> {
            let in_use = pool.capacity() - pool.free_blocks();
            if in_use != refs.len() {
                return Err(format!("{in_use} blocks in use, shadow says {}", refs.len()));
            }
            for (&b, &r) in refs {
                if pool.ref_count(b) != r {
                    return Err(format!("block {b}: refs {} vs shadow {r}", pool.ref_count(b)));
                }
            }
            let mut live: HashSet<usize> = idx_blocks.clone();
            for s in sessions {
                live.extend(s.shared.iter().copied());
                if let Some(t) = &s.table {
                    live.extend(t.blocks.iter().copied());
                }
            }
            if live.len() != refs.len() {
                return Err(format!(
                    "live set {} != private+shared+index {}",
                    refs.len(),
                    live.len()
                ));
            }
            Ok(())
        };

        for _ in 0..usize_in(rng, 20, 80) {
            match rng.below(3) {
                // admit: radix-match a family prompt, take shared refs
                0 => {
                    let family = rng.below(3);
                    let len = usize_in(rng, 2, 5) * bt;
                    let tokens = family_tokens(family, len);
                    let mut shared = Vec::new();
                    for hit in idx.match_prefix(&tokens, tokens.len() - 1) {
                        pool.share(hit.block);
                        *refs.get_mut(&hit.block).expect("matched block live") += 1;
                        shared.push(hit.block);
                    }
                    // partial blocks are never matched — and the final
                    // block is withheld so at least one row is left
                    assert!(shared.len() * bt <= tokens.len() - 1);
                    sessions.push(Sess { tokens, shared, table: None });
                }
                // park the session's tail, then offer full blocks to
                // the index (dedup onto canonicals where chains meet)
                1 => {
                    let parked: Vec<usize> = (0..sessions.len())
                        .filter(|&i| sessions[i].table.is_none())
                        .collect();
                    if parked.is_empty() {
                        continue;
                    }
                    let si = parked[usize_in(rng, 0, parked.len() - 1)];
                    let s = &mut sessions[si];
                    let matched = s.shared.len() * bt;
                    let rows = s.tokens.len() - matched;
                    let kv = SlotKv {
                        len: rows,
                        row,
                        k: vec![si as f32; rows * row],
                        v: vec![-(si as f32); rows * row],
                    };
                    let mut table = pool.store(&kv).map_err(|e| e.to_string())?;
                    for &b in &table.blocks {
                        refs.insert(b, 1);
                    }
                    let mut parent = ROOT;
                    for c in s.tokens[..matched].chunks(bt) {
                        parent = chain_hash(parent, c);
                    }
                    let mut off = matched;
                    while off + bt <= s.tokens.len() && !table.blocks.is_empty() {
                        let want = &s.tokens[off..off + bt];
                        let blk = table.blocks.remove(0);
                        match idx.insert(parent, want, blk, &mut pool) {
                            Inserted::New(h) => {
                                *refs.get_mut(&blk).unwrap() += 1;
                                idx_blocks.insert(blk);
                                s.shared.push(blk);
                                parent = h;
                            }
                            Inserted::Existing { hash, block } => {
                                pool.share(block);
                                *refs.get_mut(&block).unwrap() += 1;
                                pool.unref(blk);
                                refs.remove(&blk);
                                s.shared.push(block);
                                parent = hash;
                            }
                            Inserted::Skipped => {
                                table.blocks.insert(0, blk);
                                break;
                            }
                        }
                        off += bt;
                    }
                    table.len = s.tokens.len() - s.shared.len() * bt;
                    s.table = Some(table);
                }
                // release: drop shared refs and the private tail
                _ => {
                    if sessions.is_empty() {
                        continue;
                    }
                    let s = sessions.swap_remove(usize_in(rng, 0, sessions.len() - 1));
                    for b in s.shared {
                        pool.unref(b);
                        let r = refs.get_mut(&b).expect("shared block live");
                        *r -= 1;
                        if *r == 0 {
                            refs.remove(&b);
                        }
                    }
                    if let Some(t) = s.table {
                        for &b in &t.blocks {
                            let r = refs.get_mut(&b).expect("private block live");
                            *r -= 1;
                            if *r == 0 {
                                refs.remove(&b);
                            }
                        }
                        pool.release(t);
                    }
                }
            }
            audit(&pool, &refs, &idx_blocks, &sessions)?;
        }
        while let Some(s) = sessions.pop() {
            for b in s.shared {
                pool.unref(b);
            }
            if let Some(t) = s.table {
                pool.release(t);
            }
        }
        idx.clear(&mut pool);
        if pool.free_blocks() != cap {
            return Err(format!("teardown leak: {} free of {cap}", pool.free_blocks()));
        }
        Ok(())
    });
}

/// Radix matching only ever covers whole blocks, and never the entire
/// prompt: a prompt of exactly the indexed length still leaves its last
/// block (and at least one token) to the engine.
#[test]
fn admission_matching_never_covers_partial_blocks() {
    let mut eng = MockBatchEngine::new(1, 32, 64, 256);
    let mut mgr = SessionManager::for_engine(&eng, &shared_policy(6));
    let pinned: HashSet<u64> = HashSet::new();
    let prompt = family_tokens(0, 2 * BLOCK_TOKENS);

    // seed the index: run the prompt in session 1, then park it by
    // making session 2 resident (1 physical slot)
    mgr.open(1).unwrap();
    let slot = mgr.ensure_resident(1, &mut eng, &pinned).unwrap().unwrap();
    eng.run_batch(&[SlotChunk { slot, tokens: prompt.clone() }]).unwrap();
    mgr.note_rows(1, prompt.len());
    mgr.note_tokens(1, &prompt);
    mgr.open(2).unwrap();
    mgr.ensure_resident(2, &mut eng, &pinned).unwrap().unwrap();
    assert!(mgr.slot_of(1).is_none(), "session 1 parked");
    let seeded = mgr.blocks_in_use();
    assert!(seeded >= 2, "both full prompt blocks parked and indexed");

    // identical prompt: the final block is withheld so the engine sees
    // at least one token — exactly one block (16 rows) matches
    let m = mgr.open_with_prompt(3, &prompt).unwrap();
    assert_eq!(m, BLOCK_TOKENS, "never the whole prompt");
    assert_eq!(mgr.shared_len_of(3), BLOCK_TOKENS);

    // a one-block prompt can never match (15 usable rows < one block)
    let m = mgr.open_with_prompt(4, &prompt[..BLOCK_TOKENS]).unwrap();
    assert_eq!(m, 0, "partial block never matched");
    assert_eq!(mgr.shared_len_of(4), 0);

    // a block-and-a-bit prompt matches the block, not the bit
    let m = mgr.open_with_prompt(5, &prompt[..BLOCK_TOKENS + 5]).unwrap();
    assert_eq!(m, BLOCK_TOKENS, "matched length is a whole-block multiple");

    let ps = mgr.prefix_stats();
    assert_eq!((ps.hits, ps.misses), (2, 1));
    assert_eq!(ps.hit_rows, 2 * BLOCK_TOKENS as u64);
    // sharing allocates nothing: every admission above reuses the two
    // canonical blocks session 1 parked
    assert_eq!(mgr.blocks_in_use(), seeded);
}

/// Copy-on-write divergence: truncating a parked session into a shared
/// block privatises the boundary block, and every other holder of the
/// canonical chain still swaps in bit-identical rows afterwards.
#[test]
fn cow_divergence_leaves_canonical_blocks_bit_identical() {
    let mut eng = MockBatchEngine::new(1, 64, 64, 256);
    let mut mgr = SessionManager::for_engine(&eng, &shared_policy(6));
    let pinned: HashSet<u64> = HashSet::new();
    let pre = family_tokens(1, 2 * BLOCK_TOKENS); // 2 full blocks
    let mut full = pre.clone();
    full.extend(family_tokens(2, 8)); // private tail past the preamble
    let pre_ref = reference_kv(&pre);
    let full_ref = reference_kv(&full);

    // session 1 commits preamble + tail, parks (indexing the preamble)
    mgr.open(1).unwrap();
    let slot = mgr.ensure_resident(1, &mut eng, &pinned).unwrap().unwrap();
    eng.run_batch(&[SlotChunk { slot, tokens: full.clone() }]).unwrap();
    mgr.note_rows(1, full.len());
    mgr.note_tokens(1, &full);
    mgr.open(2).unwrap();
    mgr.ensure_resident(2, &mut eng, &pinned).unwrap().unwrap();
    assert_eq!(mgr.shared_len_of(1), 2 * BLOCK_TOKENS, "preamble indexed at park");

    // session 3 admits onto the shared preamble (refcount only)
    let matched = mgr.open_with_prompt(3, &full).unwrap();
    assert_eq!(matched, 2 * BLOCK_TOKENS);

    // diverge: roll session 1 back to 24 rows — 8 rows into the second
    // shared block. The boundary block must be privatised via CoW, not
    // edited in place.
    let cut = BLOCK_TOKENS + 8;
    mgr.set_len(1, cut);
    assert_eq!(mgr.prefix_stats().cow_copies, 1, "boundary block was copied");
    assert_eq!(mgr.len_of(1), cut);
    assert_eq!(mgr.shared_len_of(1), BLOCK_TOKENS, "only the intact block stays shared");

    // the canonical chain session 3 holds is untouched: swapping it in
    // materialises the exact preamble image
    let slot3 = mgr.ensure_resident(3, &mut eng, &pinned).unwrap().unwrap();
    let got = eng.export_slot(slot3);
    assert_eq!(got.len, 2 * BLOCK_TOKENS);
    assert_eq!(got, pre_ref, "shared original not bit-identical after CoW");

    // and the truncated session swaps back in with its surviving rows
    // (served partly from the CoW copy) bit-identical to the original
    let slot1 = mgr.ensure_resident(1, &mut eng, &pinned).unwrap().unwrap();
    let got = eng.export_slot(slot1);
    assert_eq!(got.len, cut);
    assert_eq!(got.k[..], full_ref.k[..cut * MOCK_KV_ROW]);
    assert_eq!(got.v[..], full_ref.v[..cut * MOCK_KV_ROW]);
}

/// Two identical waves of shared-preamble verify traffic: every block
/// allocated by a wave is returned when its sessions release, leaving
/// only the index-held canonicals — the steady-state footprint does not
/// grow wave over wave, and the second wave's admissions all hit.
#[test]
fn shared_traffic_conserves_blocks_across_waves() {
    let pre = family_tokens(3, 2 * BLOCK_TOKENS);
    let mut sched = Scheduler::with_policy(
        MockBatchEngine::new(2, 8, 64, 4096),
        0x5A17,
        shared_policy(8),
    );
    let wave = |sched: &mut Scheduler<MockBatchEngine>, base: u64| {
        for i in 0..8u64 {
            let mut uncached = pre.clone();
            uncached.extend(vec![40 + i as u32; 4]);
            sched
                .submit(CloudRequest::Verify {
                    request_id: base + i,
                    device_id: (base + i) as u32,
                    uncached,
                    draft: vec![9, 9],
                    dists: dense_dists(2, 64),
                    greedy: true,
                    ctx: Default::default(),
                })
                .unwrap();
        }
        let mut done = 0usize;
        for _ in 0..3_000 {
            let (events, _) = sched.tick().unwrap();
            done += events.len();
            if done == 8 {
                break;
            }
        }
        assert_eq!(done, 8, "wave drained");
        for i in 0..8u64 {
            sched.submit(CloudRequest::Release { request_id: base + i }).unwrap();
        }
    };
    wave(&mut sched, 0);
    let after_one = sched.sessions().blocks_in_use();
    assert!(after_one > 0, "index keeps the canonical preamble blocks");
    let hits_one = sched.sessions().prefix_stats().hits;

    wave(&mut sched, 100);
    assert_eq!(
        sched.sessions().blocks_in_use(),
        after_one,
        "second wave leaks no blocks past the shared canonicals"
    );
    let ps = sched.sessions().prefix_stats();
    assert!(
        ps.hits >= hits_one + 8,
        "wave 2 admissions all hit the populated index ({} -> {})",
        hits_one,
        ps.hits
    );
    assert_eq!(sched.engine.free_slots(), 2);
    assert_eq!(sched.engine.allocs, sched.engine.frees);
}

/// Migration of a shared-prefix session: the exported image is a deep
/// copy (materialised, never aliased), it round-trips bit-identically
/// through a second scheduler, and the donor's canonical blocks keep
/// serving its remaining sessions untouched.
#[test]
fn shared_prefix_migration_round_trips_without_aliasing() {
    let pre = family_tokens(4, 2 * BLOCK_TOKENS);
    let pre_ref = reference_kv(&pre);
    let mut a = Scheduler::with_policy(
        MockBatchEngine::new(2, 8, 64, 4096),
        0x417A,
        shared_policy(6),
    );
    let submit = |s: &mut Scheduler<MockBatchEngine>, id: u64| {
        let mut uncached = pre.clone();
        uncached.extend(vec![50 + id as u32; 4]);
        s.submit(CloudRequest::Verify {
            request_id: id,
            device_id: id as u32,
            uncached,
            draft: vec![9, 9],
            dists: dense_dists(2, 64),
            greedy: true,
            ctx: Default::default(),
        })
        .unwrap();
    };
    let drain = |s: &mut Scheduler<MockBatchEngine>, n: usize| {
        let mut done = 0usize;
        for _ in 0..2_000 {
            let (events, _) = s.tick().unwrap();
            done += events.len();
            if done == n {
                return;
            }
        }
        panic!("verify wave did not drain");
    };
    // first wave populates the index (3 sessions over 2 slots must
    // park); a second round over the same sessions then guarantees a
    // full-length park — every preamble block indexed — before the
    // second wave admits onto it
    for id in 0..3u64 {
        submit(&mut a, id);
    }
    drain(&mut a, 3);
    for id in 0..3u64 {
        a.submit(CloudRequest::Verify {
            request_id: id,
            device_id: id as u32,
            uncached: vec![30 + id as u32; 2],
            draft: vec![9, 9],
            dists: dense_dists(2, 64),
            greedy: true,
            ctx: Default::default(),
        })
        .unwrap();
    }
    drain(&mut a, 3);
    for id in 3..6u64 {
        submit(&mut a, id);
    }
    drain(&mut a, 3);
    let migrant =
        (3..6u64).find(|&id| a.sessions().shared_len_of(id) > 0).expect("a session admitted onto the shared preamble");
    let rows = a.sessions().len_of(migrant);
    assert!(rows > 2 * BLOCK_TOKENS);

    let (kv, tenant) = a.export_session(migrant).unwrap();
    assert_eq!(kv.len, rows, "export materialises the full image, shared rows included");
    assert_eq!(kv.k[..pre_ref.k.len()], pre_ref.k[..], "shared rows exported by value");
    let orig = kv.clone();

    let mut b = Scheduler::with_policy(
        MockBatchEngine::new(2, 8, 64, 4096),
        0x417B,
        shared_policy(6),
    );
    assert!(b.can_import(kv.len));
    b.import_session(migrant, tenant, &kv).unwrap();
    // defacing the wire image after import must not reach either side:
    // the adopter copied it, the donor never shared it
    let mut defaced = kv;
    defaced.k[0] += 1.0;
    let (kv2, t2) = b.export_session(migrant).unwrap();
    assert_eq!(kv2, orig, "migration round trip not bit-identical");
    assert_eq!(t2, tenant);

    // donor canonicals survive: another admitted session still exports
    // the exact preamble rows
    let stay = (3..6u64)
        .find(|&id| id != migrant && a.sessions().shared_len_of(id) > 0)
        .expect("another shared-prefix session remains on the donor");
    let (kv3, _) = a.export_session(stay).unwrap();
    assert_eq!(kv3.k[..pre_ref.k.len()], pre_ref.k[..], "donor canonical blocks untouched");
}

/// Same seed + sharing enabled ⇒ bit-identical fleet reports (the
/// preamble RNG and radix cache add no nondeterminism), the sharing
/// axis genuinely engages, and share 0 reports zero prefix traffic.
#[test]
fn fleet_with_sharing_is_deterministic() {
    let cfg = FleetConfig {
        n_devices: 32,
        duration_s: 3.0,
        rate_rps: 48.0, // saturating: cloud sessions contend and park
        stop_s: 12.0,
        tenants: 2,
        params: SyneraParams {
            batch: BatchPolicy { max_sessions: 8, ..BatchPolicy::default() },
            ..SyneraParams::default()
        },
        reservoir: 1024,
        seed: 0x5AFE,
        prefix_share: 0.8,
        prefix_len: 32,
        ..FleetConfig::default()
    };
    let a = run_fleet(&cfg).unwrap();
    let b = run_fleet(&cfg).unwrap();
    assert!(a.offered > 0 && a.completed > 0, "{a:?}");
    assert_eq!(a.offered, b.offered);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.generated_tokens, b.generated_tokens);
    assert_eq!((a.swap_ins, a.swap_outs, a.swap_bytes), (b.swap_ins, b.swap_outs, b.swap_bytes));
    assert_eq!(a.virtual_s.to_bits(), b.virtual_s.to_bits());
    let mut hit_rows = 0u64;
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.completed, y.completed);
        assert_eq!(x.rows_executed, y.rows_executed);
        assert_eq!(x.prefix_hit_rows, y.prefix_hit_rows);
        assert_eq!(x.ttft.p95.to_bits(), y.ttft.p95.to_bits());
        hit_rows += x.prefix_hit_rows;
    }
    assert!(hit_rows > 0, "shared preambles must produce admission hits under contention");

    // share 0: no preamble stream, no prefix traffic anywhere
    let z = run_fleet(&FleetConfig { prefix_share: 0.0, ..cfg }).unwrap();
    assert!(z.tenants.iter().all(|t| t.prefix_hit_rows == 0), "share 0 stays inert");
}

/// Fig 15d knee direction: at a fixed session population and identical
/// per-session KV footprint, raising the fraction of sessions that
/// carry a common preamble strictly lowers the host blocks held —
/// park-time dedup collapses the shared chains onto canonicals.
#[test]
fn host_blocks_fall_as_prefix_share_rises() {
    let blocks_at = |sharing: usize| -> (usize, u64) {
        let n = 16u64;
        let mut sched = Scheduler::with_policy(
            MockBatchEngine::new(2, 16, 64, 4096),
            0xF15D,
            shared_policy(n as usize),
        );
        let pre = family_tokens(5, 4 * BLOCK_TOKENS);
        for id in 0..n {
            // same total length either way: 4 preamble-or-unique blocks
            // plus a one-block unique tail — savings are dedup, not
            // shorter prompts
            let mut prompt: Vec<u32> = if (id as usize) < sharing {
                pre.clone()
            } else {
                vec![10 + id as u32; 4 * BLOCK_TOKENS]
            };
            prompt.extend(vec![44 + id as u32; BLOCK_TOKENS]);
            sched
                .submit(CloudRequest::Verify {
                    request_id: id,
                    device_id: id as u32,
                    uncached: prompt,
                    draft: vec![9, 9],
                    dists: dense_dists(2, 64),
                    greedy: true,
                    ctx: Default::default(),
                })
                .unwrap();
        }
        let mut done = 0usize;
        for _ in 0..5_000 {
            let (events, _) = sched.tick().unwrap();
            done += events.len();
            if done == n as usize {
                break;
            }
        }
        assert_eq!(done, n as usize, "all first verify rounds complete");
        assert!(sched.stats.swap_outs > 0, "16 sessions over 2 slots must page");
        assert_eq!(sched.sessions().prefix_stats().cow_copies, 0, "parking never copies");
        (sched.sessions().blocks_in_use(), sched.stats.prefix_hit_rows)
    };
    let (b0, _) = blocks_at(0);
    let (b8, _) = blocks_at(8);
    let (b16, _) = blocks_at(16);
    assert!(
        b8 < b0,
        "host blocks must fall when half the fleet shares a preamble ({b0} -> {b8})"
    );
    assert!(
        b16 < b8,
        "and fall further when the whole fleet shares it ({b8} -> {b16})"
    );
}
