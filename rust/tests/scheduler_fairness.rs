//! Scheduler fairness + slot-accounting suite over the deterministic
//! [`MockBatchEngine`] — runs without PJRT or compiled artifacts, so
//! the mixed continuous-batching policy is exercised on every `cargo
//! test`, not only on artifact-bearing machines.

use synera::cloud::scheduler::{CloudEvent, CloudRequest, Scheduler};
use synera::config::BatchPolicy;
use synera::net::wire::Dist;
use synera::testutil::{check, usize_in, MockBatchEngine};

fn dense_dists(n: usize, vocab: usize) -> Vec<Dist> {
    vec![Dist::Dense(vec![1.0 / vocab as f32; vocab]); n]
}

/// (a) every submitted request eventually completes under slot
/// contention; (b) no slot is leaked or double-freed.
#[test]
fn all_generates_complete_under_contention() {
    let mut sched = Scheduler::new(MockBatchEngine::new(4, 8, 64, 4096), 0xFA1);
    let n_req = 16usize; // 4× oversubscribed
    for i in 0..n_req {
        let plen = 1 + (i * 3) % 20;
        sched
            .submit(CloudRequest::Generate {
                request_id: i as u64,
                prompt: vec![9; plen],
                max_new: 4,
            })
            .unwrap();
    }
    let mut done = Vec::new();
    for _ in 0..2_000 {
        let (events, _) = sched.tick().unwrap();
        for e in events {
            if let CloudEvent::Generated { request_id, tokens } = e {
                assert_eq!(tokens.len(), 4, "mock never emits EOS: budget-bound");
                done.push(request_id);
            }
        }
        if done.len() == n_req {
            break;
        }
    }
    assert_eq!(done.len(), n_req, "oversubscribed generations must all finish");
    assert!(sched.is_idle());
    assert_eq!(sched.engine.free_slots(), 4, "all slots returned");
    assert_eq!(sched.engine.allocs, sched.engine.frees, "slot conservation");
}

/// (c) decode jobs make progress while a long prefill stream keeps
/// arriving — the head-of-line blocking the phase-exclusive scheduler
/// exhibited.
#[test]
fn decode_progresses_during_prefill_stream() {
    let mut sched = Scheduler::new(MockBatchEngine::new(4, 8, 64, 4096), 0xDEC);
    sched
        .submit(CloudRequest::Generate { request_id: 1, prompt: vec![9, 10], max_new: 6 })
        .unwrap();
    let mut done_at = None;
    for tick in 0..40u64 {
        // a fresh long prompt arrives every iteration, forever
        sched
            .submit(CloudRequest::Generate {
                request_id: 100 + tick,
                prompt: vec![11; 64],
                max_new: 2,
            })
            .unwrap();
        let (events, _) = sched.tick().unwrap();
        for e in events {
            if let CloudEvent::Generated { request_id, .. } = e {
                if request_id == 1 {
                    done_at = Some(tick);
                }
            }
        }
        if done_at.is_some() {
            break;
        }
    }
    let done_at = done_at.expect("short request finished despite the prefill stream");
    assert!(done_at <= 10, "decode starved behind prefill: finished at tick {done_at}");
    // its decode rows really were co-scheduled with prefill chunks
    let mixed_call = sched.engine.calls.iter().any(|items| {
        items.iter().any(|it| it.tokens.len() == 1) && items.iter().any(|it| it.tokens.len() > 1)
    });
    assert!(mixed_call, "no engine call mixed decode and prefill rows");
    assert!(sched.stats.mixed_iters > 0);
}

/// One tick co-schedules all three work classes in a single engine
/// call, and a finished verify commits exactly prefix+uncached+accepted.
#[test]
fn mixed_tick_coschedules_prefill_verify_and_decode() {
    let mut sched = Scheduler::new(MockBatchEngine::new(4, 8, 64, 4096), 0x3C0);
    // request 1: becomes a decode job after one tick
    sched
        .submit(CloudRequest::Generate { request_id: 1, prompt: vec![9, 10], max_new: 4 })
        .unwrap();
    let (_, _) = sched.tick().unwrap();
    // request 2: a verify round (4 uncached + 2 draft = 6 rows)
    sched
        .submit(CloudRequest::Verify {
            request_id: 2,
            device_id: 0,
            uncached: vec![12, 13, 14, 15],
            draft: vec![9, 9],
            dists: dense_dists(2, 64),
            greedy: true,
            ctx: Default::default(),
        })
        .unwrap();
    // request 3: a long prefill
    sched
        .submit(CloudRequest::Generate { request_id: 3, prompt: vec![16; 20], max_new: 2 })
        .unwrap();
    let (events, _) = sched.tick().unwrap();

    let items = sched.engine.calls.last().unwrap();
    let mut lens: Vec<usize> = items.iter().map(|it| it.tokens.len()).collect();
    lens.sort_unstable();
    assert_eq!(lens, vec![1, 6, 8], "decode row + full verify + capped prefill chunk");
    assert_eq!(sched.stats.mixed_iters, 1);

    // the verify round finished in that same tick and rolled back to
    // base + uncached + accepted
    let outcome = events
        .iter()
        .find_map(|e| match e {
            CloudEvent::VerifyDone { request_id: 2, outcome, .. } => Some(outcome.clone()),
            _ => None,
        })
        .expect("verify finished");
    assert!(outcome.accepted <= 2);
    let vslot = items.iter().find(|it| it.tokens.len() == 6).unwrap().slot;
    assert_eq!(
        sched.engine.slot_len[vslot],
        4 + outcome.accepted,
        "committed length = uncached + accepted prefix"
    );
}

/// A constrained token budget saturated by verify rounds cannot starve
/// prefill forever: aging promotes the waiting job.
#[test]
fn aged_prefill_breaks_through_verify_stream() {
    let policy = BatchPolicy {
        token_budget: 8,
        prefill_share: 0.5,
        age_threshold: 3,
        ..BatchPolicy::default()
    };
    let mut sched =
        Scheduler::with_policy(MockBatchEngine::new(2, 8, 64, 4096), 0xA6E, policy);
    sched
        .submit(CloudRequest::Verify {
            request_id: 7,
            device_id: 0,
            uncached: vec![12; 6],
            draft: vec![9, 9],
            dists: dense_dists(2, 64),
            greedy: true,
            ctx: Default::default(),
        })
        .unwrap();
    sched
        .submit(CloudRequest::Generate { request_id: 8, prompt: vec![16; 20], max_new: 2 })
        .unwrap();
    let mut done = false;
    for _ in 0..200 {
        let (events, _) = sched.tick().unwrap();
        for e in events {
            match e {
                // keep the verify pressure up: a new round per completion
                CloudEvent::VerifyDone { request_id, .. } => {
                    sched
                        .submit(CloudRequest::Verify {
                            request_id,
                            device_id: 0,
                            uncached: vec![12; 6],
                            draft: vec![9, 9],
                            dists: dense_dists(2, 64),
                            greedy: true,
                            ctx: Default::default(),
                        })
                        .unwrap();
                }
                CloudEvent::Generated { request_id, .. } => {
                    assert_eq!(request_id, 8);
                    done = true;
                }
            }
        }
        if done {
            break;
        }
    }
    assert!(done, "prefill starved behind the verify stream");
    assert!(sched.stats.aged_promotions > 0, "completion must come via aging");
}

/// A new verify session cannot starve in the admission queue behind a
/// continuous stream of cloud-centric generations: free slots are
/// shared round-robin between the two queues.
#[test]
fn verify_admission_survives_generate_flood() {
    let mut sched = Scheduler::new(MockBatchEngine::new(2, 8, 64, 4096), 0xF100D);
    let mut verify_done = false;
    let mut next_gen = 0u64;
    for tick in 0..200u64 {
        // keep the generate queue permanently non-empty
        while sched.queue_depth() < 4 {
            sched
                .submit(CloudRequest::Generate {
                    request_id: 100 + next_gen,
                    prompt: vec![9; 4],
                    max_new: 2,
                })
                .unwrap();
            next_gen += 1;
        }
        if tick == 3 {
            sched
                .submit(CloudRequest::Verify {
                    request_id: 7,
                    device_id: 0,
                    uncached: vec![12; 4],
                    draft: vec![9, 9],
                    dists: dense_dists(2, 64),
                    greedy: true,
                    ctx: Default::default(),
                })
                .unwrap();
        }
        let (events, _) = sched.tick().unwrap();
        for e in events {
            if let CloudEvent::VerifyDone { request_id: 7, .. } = e {
                verify_done = true;
            }
        }
        if verify_done {
            assert!(tick < 30, "verify starved in admission until tick {tick}");
            break;
        }
    }
    assert!(verify_done, "verify session never admitted under generate flood");
}

/// Releasing a session while its verify round is in flight must not
/// hand the slot (and its live KV positions) to another job; the free
/// happens when the round completes.
#[test]
fn release_during_inflight_verify_defers_slot_free() {
    // 1 slot: any premature free would immediately be re-allocated
    let mut sched = Scheduler::new(MockBatchEngine::new(1, 4, 64, 4096), 0x8E1);
    sched
        .submit(CloudRequest::Verify {
            request_id: 7,
            device_id: 0,
            uncached: vec![12; 10], // 3 ticks of chunk-4 forwarding
            draft: vec![9, 9],
            dists: dense_dists(2, 64),
            greedy: true,
            ctx: Default::default(),
        })
        .unwrap();
    let (_, _) = sched.tick().unwrap(); // round is now mid-flight
    sched.submit(CloudRequest::Release { request_id: 7 }).unwrap();
    // a generate now competes for the (still busy) slot
    sched
        .submit(CloudRequest::Generate { request_id: 1, prompt: vec![9, 10], max_new: 2 })
        .unwrap();
    let mut verify_done = false;
    let mut gen_done = false;
    for _ in 0..100 {
        let (events, _) = sched.tick().unwrap();
        for e in events {
            match e {
                CloudEvent::VerifyDone { request_id, .. } => {
                    assert_eq!(request_id, 7);
                    assert!(!gen_done, "generate ran before the verify round finished");
                    verify_done = true;
                }
                CloudEvent::Generated { request_id, .. } => {
                    assert_eq!(request_id, 1);
                    gen_done = true;
                }
            }
        }
        if gen_done {
            break;
        }
    }
    assert!(verify_done && gen_done);
    assert!(sched.is_idle());
    assert_eq!(sched.engine.free_slots(), 1, "released slot reclaimed exactly once");
    assert_eq!(sched.engine.allocs, sched.engine.frees);
}

/// Requests that can never fit the slot cache are rejected at submit
/// instead of failing (and killing) the scheduling loop mid-tick.
#[test]
fn oversized_and_degenerate_requests_rejected_at_submit() {
    let mut sched = Scheduler::new(MockBatchEngine::new(2, 8, 64, 16), 0x0F10);
    assert!(sched
        .submit(CloudRequest::Generate { request_id: 1, prompt: vec![9; 12], max_new: 8 })
        .is_err(), "prompt + max_new exceeds the slot cache");
    assert!(sched
        .submit(CloudRequest::Generate { request_id: 2, prompt: vec![9; 4], max_new: 0 })
        .is_err(), "zero-budget generation is degenerate");
    assert!(sched
        .submit(CloudRequest::Verify {
            request_id: 3,
            device_id: 0,
            uncached: vec![12; 15],
            draft: vec![9, 9],
            dists: dense_dists(2, 64),
            greedy: true,
            ctx: Default::default(),
        })
        .is_err(), "verify round larger than the slot cache");
    assert!(sched.is_idle(), "rejected requests must not be enqueued");
}

/// A verify session whose accumulated rounds hit the KV capacity is
/// ended gracefully (EOS correction) rather than erroring the tick.
#[test]
fn verify_session_at_kv_capacity_ends_with_eos() {
    let mut sched = Scheduler::new(MockBatchEngine::new(1, 8, 64, 10), 0xCAFE);
    let round = |sched: &mut Scheduler<MockBatchEngine>| {
        sched
            .submit(CloudRequest::Verify {
                request_id: 7,
                device_id: 0,
                uncached: vec![12; 6],
                draft: vec![9, 9],
                dists: dense_dists(2, 64),
                greedy: true,
                ctx: Default::default(),
            })
            .unwrap();
    };
    round(&mut sched);
    let (events, _) = sched.tick().unwrap();
    assert_eq!(events.len(), 1, "first round fits (8 rows ≤ 10) and completes");
    // the committed prefix now occupies the slot; another full round
    // would overflow the 10-row cache
    round(&mut sched);
    let (events, _) = sched.tick().unwrap();
    let CloudEvent::VerifyDone { outcome, .. } = &events[0] else {
        panic!("expected a VerifyDone, got {events:?}");
    };
    assert_eq!(outcome.accepted, 0);
    assert_eq!(outcome.next_token, synera::workload::vocab::EOS, "session force-ended");
    assert!(sched.is_idle(), "no job may be left behind for the overflowing round");
}

/// Two rounds of the same brand-new session submitted back-to-back
/// stay serialised: one slot, one round in flight at a time.
#[test]
fn pipelined_rounds_of_new_session_stay_serialised() {
    let mut sched = Scheduler::new(MockBatchEngine::new(4, 8, 64, 4096), 0x5E51);
    for _ in 0..2 {
        sched
            .submit(CloudRequest::Verify {
                request_id: 7,
                device_id: 0,
                uncached: vec![12; 4],
                draft: vec![9, 9],
                dists: dense_dists(2, 64),
                greedy: true,
                ctx: Default::default(),
            })
            .unwrap();
    }
    let mut done = 0;
    for _ in 0..20 {
        let (events, _) = sched.tick().unwrap();
        done += events.len();
        if done == 2 {
            break;
        }
    }
    assert_eq!(done, 2, "both rounds completed");
    assert_eq!(sched.engine.allocs, 1, "one session ⇒ one slot, no leak");
    sched.submit(CloudRequest::Release { request_id: 7 }).unwrap();
    assert_eq!(sched.engine.free_slots(), 4);
}

/// Property: random mixed traffic always drains, slots are conserved,
/// and nothing is double-freed (the mock panics on double-free).
/// `max_sessions` ranges below, at and above the slot count, so the
/// paged-KV admission path is exercised under the same invariants.
#[test]
fn prop_random_traffic_drains_and_conserves_slots() {
    check("mixed traffic drains; slots conserved", |rng| {
        let slots = usize_in(rng, 2, 4);
        let chunk = usize_in(rng, 2, 8);
        let policy = BatchPolicy {
            token_budget: usize_in(rng, 1, slots * chunk),
            prefill_share: 0.5,
            age_threshold: usize_in(rng, 1, 6) as u64,
            max_sessions: usize_in(rng, 0, 10),
            ..BatchPolicy::default()
        };
        let mut sched = Scheduler::with_policy(
            MockBatchEngine::new(slots, chunk, 64, 4096),
            rng.next_u64(),
            policy,
        );
        let n_req = usize_in(rng, 1, 12);
        let mut expect_gen = 0usize;
        let mut expect_ver = 0usize;
        for i in 0..n_req {
            if rng.chance(1, 2) {
                sched
                    .submit(CloudRequest::Generate {
                        request_id: 1_000 + i as u64,
                        prompt: vec![9; usize_in(rng, 1, 20)],
                        max_new: usize_in(rng, 1, 5),
                    })
                    .map_err(|e| e.to_string())?;
                expect_gen += 1;
            } else {
                let gamma = usize_in(rng, 1, 4);
                sched
                    .submit(CloudRequest::Verify {
                        request_id: 2_000 + i as u64,
                        device_id: i as u32,
                        uncached: vec![12; usize_in(rng, 1, 10)],
                        draft: vec![9; gamma],
                        dists: dense_dists(gamma, 64),
                        greedy: true,
                        ctx: Default::default(),
                    })
                    .map_err(|e| e.to_string())?;
                expect_ver += 1;
            }
        }
        let mut got_gen = 0usize;
        let mut got_ver = 0usize;
        for _ in 0..5_000 {
            let (events, _) = sched.tick().map_err(|e| e.to_string())?;
            for e in events {
                match e {
                    CloudEvent::Generated { .. } => got_gen += 1,
                    CloudEvent::VerifyDone { request_id, .. } => {
                        got_ver += 1;
                        sched
                            .submit(CloudRequest::Release { request_id })
                            .map_err(|e| e.to_string())?;
                    }
                }
            }
            if sched.is_idle() {
                break;
            }
        }
        if !sched.is_idle() {
            return Err("scheduler failed to drain".into());
        }
        if got_gen != expect_gen || got_ver != expect_ver {
            return Err(format!(
                "lost work: gen {got_gen}/{expect_gen}, verify {got_ver}/{expect_ver}"
            ));
        }
        if sched.engine.free_slots() != slots {
            return Err(format!("leaked slots: {} free of {slots}", sched.engine.free_slots()));
        }
        if sched.engine.allocs != sched.engine.frees {
            return Err(format!(
                "alloc/free imbalance: {} vs {}",
                sched.engine.allocs, sched.engine.frees
            ));
        }
        if sched.sessions().free_blocks() != sched.sessions().block_capacity() {
            return Err(format!(
                "leaked KV blocks: {} free of {}",
                sched.sessions().free_blocks(),
                sched.sessions().block_capacity()
            ));
        }
        Ok(())
    });
}

/// Paged admission keeps the existing fairness machinery intact: with
/// more logical sessions than slots, a short decode-bound request still
/// completes promptly while oversubscribed verify sessions churn.
#[test]
fn paged_oversubscription_does_not_starve_decode() {
    let policy = BatchPolicy { max_sessions: 12, ..BatchPolicy::default() };
    let mut sched =
        Scheduler::with_policy(MockBatchEngine::new(4, 8, 64, 4096), 0xBEEF, policy);
    sched
        .submit(CloudRequest::Generate { request_id: 1, prompt: vec![9, 10], max_new: 4 })
        .unwrap();
    for id in 100..110u64 {
        sched
            .submit(CloudRequest::Verify {
                request_id: id,
                device_id: id as u32,
                uncached: vec![12; 6],
                draft: vec![9, 9],
                dists: dense_dists(2, 64),
                greedy: true,
                ctx: Default::default(),
            })
            .unwrap();
    }
    let mut done_at = None;
    for tick in 0..80u64 {
        let (events, _) = sched.tick().unwrap();
        for e in events {
            match e {
                CloudEvent::Generated { request_id, .. } => {
                    assert_eq!(request_id, 1);
                    done_at = Some(tick);
                }
                // keep verify pressure up: a fresh round per completion
                CloudEvent::VerifyDone { request_id, .. } => {
                    sched
                        .submit(CloudRequest::Verify {
                            request_id,
                            device_id: request_id as u32,
                            uncached: vec![12; 6],
                            draft: vec![9, 9],
                            dists: dense_dists(2, 64),
                            greedy: true,
                            ctx: Default::default(),
                        })
                        .unwrap();
                }
            }
        }
        if done_at.is_some() {
            break;
        }
    }
    let done_at = done_at.expect("decode-bound request finished under paged churn");
    assert!(done_at <= 40, "decode starved behind paged verify churn: tick {done_at}");
}

/// Weighted-fair admission: with tenant weights 1:3 and single-round
/// sessions released on completion, the weight-3 tenant's sessions are
/// granted (and complete) ~3× as often over any service window — and
/// the light tenant is never starved outright.
#[test]
fn wfq_admission_tracks_tenant_weights() {
    let policy = BatchPolicy {
        max_sessions: 4,
        tenant_weights: vec![1.0, 3.0],
        ..BatchPolicy::default()
    };
    let mut sched =
        Scheduler::with_policy(MockBatchEngine::new(4, 8, 64, 4096), 0x3FA2, policy);
    // equal backlogged demand from both tenants, submitted up front
    for i in 0..30u64 {
        for (tenant, base) in [(0usize, 1000u64), (1, 2000)] {
            sched
                .submit_tenant(
                    tenant,
                    CloudRequest::Verify {
                        request_id: base + i,
                        device_id: (base + i) as u32,
                        uncached: vec![12; 4],
                        draft: vec![9, 9],
                        dists: dense_dists(2, 64),
                        greedy: true,
                        ctx: Default::default(),
                    },
                )
                .unwrap();
        }
    }
    let mut done = 0usize;
    for _ in 0..2_000 {
        let (events, _) = sched.tick().unwrap();
        for e in events {
            if let CloudEvent::VerifyDone { request_id, .. } = e {
                sched.submit(CloudRequest::Release { request_id }).unwrap();
                done += 1;
            }
        }
        if done >= 24 {
            break;
        }
    }
    assert!(done >= 24, "only {done} rounds completed");
    let (t0, t1) =
        (sched.tenant_stats[0].verifies_done, sched.tenant_stats[1].verifies_done);
    assert!(t0 >= 2, "light tenant starved: {t0} vs {t1}");
    assert!(
        t1 >= 2 * t0 && t1 <= 5 * t0.max(1),
        "completions {t1}:{t0} should track the 3:1 weights"
    );
    assert!(
        sched.tenant_stats[1].rows_executed > sched.tenant_stats[0].rows_executed,
        "row accounting follows admissions"
    );
}

/// Tenant-tagged submission validates the tenant index, and untagged
/// traffic still flows when the frontend is enabled.
#[test]
fn wfq_submit_validation_and_untagged_bypass() {
    let policy = BatchPolicy { tenant_weights: vec![1.0, 1.0], ..BatchPolicy::default() };
    let mut sched =
        Scheduler::with_policy(MockBatchEngine::new(2, 8, 64, 4096), 0x3FA3, policy);
    let bad = sched.submit_tenant(
        7,
        CloudRequest::Verify {
            request_id: 1,
            device_id: 1,
            uncached: vec![12; 2],
            draft: vec![9],
            dists: dense_dists(1, 64),
            greedy: true,
            ctx: Default::default(),
        },
    );
    assert!(bad.is_err(), "tenant index out of range must be rejected");
    // untagged generate rides the plain FIFO path alongside the frontend
    sched
        .submit(CloudRequest::Generate { request_id: 2, prompt: vec![9; 3], max_new: 2 })
        .unwrap();
    sched
        .submit_tenant(
            1,
            CloudRequest::Verify {
                request_id: 3,
                device_id: 3,
                uncached: vec![12; 2],
                draft: vec![9],
                dists: dense_dists(1, 64),
                greedy: true,
                ctx: Default::default(),
            },
        )
        .unwrap();
    let mut gen_done = false;
    let mut ver_done = false;
    for _ in 0..100 {
        let (events, _) = sched.tick().unwrap();
        for e in events {
            match e {
                CloudEvent::Generated { request_id, .. } => {
                    assert_eq!(request_id, 2);
                    gen_done = true;
                }
                CloudEvent::VerifyDone { request_id, .. } => {
                    assert_eq!(request_id, 3);
                    sched.submit(CloudRequest::Release { request_id }).unwrap();
                    ver_done = true;
                }
            }
        }
        if gen_done && ver_done {
            break;
        }
    }
    assert!(gen_done && ver_done, "both admission paths drain");
    assert!(sched.is_idle());
    assert_eq!(sched.engine.allocs, sched.engine.frees);
}

/// An open-session follow-up round queued in the WFQ *behind* a
/// capacity-blocked new-session head must still be admitted (it
/// consumes no session capacity, and the capacity-holding session is
/// waiting on it) — the regression here was a scheduler deadlock.
#[test]
fn wfq_follow_up_behind_blocked_head_does_not_deadlock() {
    let policy = BatchPolicy {
        max_sessions: 1,
        tenant_weights: vec![1.0, 1.0],
        ..BatchPolicy::default()
    };
    let mut sched =
        Scheduler::with_policy(MockBatchEngine::new(4, 8, 64, 4096), 0x0D1C, policy);
    let verify = |id: u64| CloudRequest::Verify {
        request_id: id,
        device_id: id as u32,
        uncached: vec![12; 2],
        draft: vec![9, 9],
        dists: dense_dists(2, 64),
        greedy: true,
        ctx: Default::default(),
    };
    // tenant 0: two rounds of session 7, both stamped before the
    // session opens (the second would previously wait on capacity)
    sched.submit_tenant(0, verify(7)).unwrap();
    sched.submit_tenant(0, verify(7)).unwrap();
    // tenant 1: a new session whose stamp lands between them — it
    // blocks the WFQ head once the single session slot is taken
    sched.submit_tenant(1, verify(9)).unwrap();
    let (mut done_7, mut done_9) = (0usize, 0usize);
    for _ in 0..300 {
        let (events, _) = sched.tick().unwrap();
        for e in events {
            if let CloudEvent::VerifyDone { request_id, .. } = e {
                match request_id {
                    7 => {
                        done_7 += 1;
                        if done_7 == 2 {
                            // device is finished with session 7
                            sched.submit(CloudRequest::Release { request_id: 7 }).unwrap();
                        }
                    }
                    9 => {
                        done_9 += 1;
                        sched.submit(CloudRequest::Release { request_id: 9 }).unwrap();
                    }
                    other => panic!("unexpected completion {other}"),
                }
            }
        }
        if done_7 == 2 && done_9 == 1 {
            break;
        }
    }
    assert_eq!((done_7, done_9), (2, 1), "all rounds complete — no WFQ deadlock");
    assert!(sched.is_idle());
    assert_eq!(sched.engine.allocs, sched.engine.frees);
}
