//! `synera inspect` gates: the critical-path analyzer must reconcile
//! exactly with the fleet simulator that produced the trace.
//!
//! * every completed request is attributed (zero partials on a
//!   full-drain run) and per-tenant counts match the `FleetReport`;
//! * the six components sum to the measured request latency to float
//!   rounding — the attribution is a decomposition, not an estimate;
//! * pipeline stall is ~0 in the virtual-clock sim (each round's RTT
//!   is fully explained by uplink + queue + cloud window + downlink),
//!   so a nonzero stall in production traces is a real bubble;
//! * same-seed traces inspect to byte-identical table and JSONL.

use std::collections::BTreeMap;

use synera::config::{BatchPolicy, SyneraParams};
use synera::obs::analyze::{analyze_chrome_trace, requests_jsonl_string, table_string};
use synera::obs::export::chrome_trace_string;
use synera::obs::trace::{self, TraceShared, TraceSink};
use synera::sim::{run_fleet, FleetConfig, FleetReport};
use synera::util::json::Json;

const TRACE_CAP: usize = 1 << 20;

fn traced_fleet(seed: u64) -> (FleetReport, TraceShared) {
    let tr = trace::shared(TraceSink::virtual_time(TRACE_CAP));
    let cfg = FleetConfig {
        n_devices: 24,
        duration_s: 3.0,
        rate_rps: 12.0,
        tenants: 3,
        params: SyneraParams {
            batch: BatchPolicy { max_sessions: 8, ..BatchPolicy::default() },
            ..SyneraParams::default()
        },
        seed,
        trace: Some(tr.clone()),
        ..FleetConfig::default()
    };
    let rep = run_fleet(&cfg).unwrap();
    (rep, tr)
}

fn export(tr: &TraceShared) -> String {
    chrome_trace_string(&tr.lock().unwrap())
}

#[test]
fn fleet_round_trip_reconciles_with_report() {
    let (rep, tr) = traced_fleet(0x1A57);
    assert!(rep.completed > 0 && rep.completed == rep.offered, "full drain");
    let ins = analyze_chrome_trace(&export(&tr)).unwrap();

    assert_eq!(ins.partial, 0, "full drain leaves no partial event sets");
    assert_eq!(ins.requests.len(), rep.completed, "every completion attributed");
    assert!(ins.requests.iter().any(|b| b.rounds > 0), "offloading requests present");

    // per-tenant attribution counts match the simulator's own report
    let mut per_tenant: BTreeMap<usize, usize> = BTreeMap::new();
    for b in &ins.requests {
        *per_tenant.entry(b.tenant).or_default() += 1;
    }
    for t in &rep.tenants {
        assert_eq!(
            per_tenant.get(&t.tenant).copied().unwrap_or(0),
            t.completed,
            "tenant {} attributed count",
            t.tenant
        );
    }
    for t in &ins.tenants {
        assert!(t.latency_s > 0.0 && t.requests > 0);
    }
}

#[test]
fn components_sum_to_measured_latency() {
    let (_, tr) = traced_fleet(0x1A57);
    let ins = analyze_chrome_trace(&export(&tr)).unwrap();
    for b in &ins.requests {
        let sum = b.component_sum_s();
        assert!(
            (sum - b.latency_s).abs() < 1e-9,
            "request {}: components {sum} vs latency {}",
            b.request_id,
            b.latency_s
        );
        for (name, v) in [
            ("device", b.device_s),
            ("queue", b.queue_s),
            ("paging", b.paging_s),
            ("engine", b.engine_s),
            ("network", b.network_s),
            ("stall", b.stall_s),
        ] {
            assert!(v >= 0.0, "request {}: {name} = {v}", b.request_id);
        }
        // the sim advances no virtual time for swaps, so paged-KV work
        // must attribute 0 s here (wall durations are zeroed)
        assert_eq!(b.paging_s, 0.0, "request {}", b.request_id);
    }
}

/// In the DES every round's RTT is exactly uplink + queue wait +
/// cloud window + downlink: the simulated device never idles on a
/// verdict beyond what the cloud accounts for. The analyzer must
/// recover that identity (stall ≈ 0) from the exported trace alone.
#[test]
fn sim_traces_carry_no_pipeline_stall() {
    let (_, tr) = traced_fleet(0x1A58);
    let ins = analyze_chrome_trace(&export(&tr)).unwrap();
    assert!(!ins.requests.is_empty());
    for b in &ins.requests {
        assert!(
            b.stall_s.abs() < 1e-6,
            "request {}: stall {} (perfect-pipeline sim)",
            b.request_id,
            b.stall_s
        );
    }
}

#[test]
fn same_seed_inspect_output_is_byte_identical() {
    let (_, tr_a) = traced_fleet(0xB17E);
    let (_, tr_b) = traced_fleet(0xB17E);
    let (ia, ib) = (
        analyze_chrome_trace(&export(&tr_a)).unwrap(),
        analyze_chrome_trace(&export(&tr_b)).unwrap(),
    );
    let table = table_string(&ia);
    assert_eq!(table, table_string(&ib), "critical-path table bytes");
    let jsonl = requests_jsonl_string(&ia);
    assert_eq!(jsonl, requests_jsonl_string(&ib), "per-request JSONL bytes");
    assert!(!jsonl.is_empty());
    for line in jsonl.lines() {
        let j = Json::parse(line).unwrap();
        for key in [
            "request_id",
            "tenant",
            "device",
            "t_start_s",
            "latency_s",
            "rounds",
            "device_s",
            "queue_s",
            "paging_s",
            "engine_s",
            "network_s",
            "stall_s",
        ] {
            assert!(j.opt(key).is_some(), "JSONL line missing {key}: {line}");
        }
    }
    assert_eq!(table.lines().count(), ia.tenants.len() + 1, "header + one row per tenant");
}
