"""SynthLang generator invariants + cross-language stability."""

from compile import synthlang as sl


def test_determinism_and_split_separation():
    for task in sl.TASKS:
        a = sl.generate(task, 1, 5)
        b = sl.generate(task, 1, 5)
        assert a.prompt == b.prompt and a.answer == b.answer
        c = sl.generate(task, 0, 5)
        assert (a.prompt, a.answer) != (c.prompt, c.answer) or task == "sst2"


def test_prompt_budgets():
    for task in sl.TASKS:
        for i in range(100):
            s = sl.generate(task, 1, i)
            assert len(s.prompt) <= 40, (task, len(s.prompt))
            assert 1 <= len(s.answer) <= 8
            assert all(0 < t < sl.VOCAB for t in s.prompt + s.answer)


def test_kgqa_consistent_with_fact_table():
    for i in range(30):
        s = sl.generate("kgqa", 1, i)
        ent, rel = s.prompt[2] - sl.ENT0, s.prompt[3] - sl.REL0
        assert s.answer == [sl.kg_value(ent, rel)]


def test_sst2_label_matches_majority():
    for i in range(30):
        s = sl.generate("sst2", 1, i)
        words = s.prompt[1:-1]
        pos = sum(sl.value_polarity(w) for w in words)
        want = sl.POS_TOK if 2 * pos > len(words) else sl.NEG_TOK
        assert s.answer[0] == want


def test_training_sequence_padded_and_weighted():
    toks, ws = sl.training_sequence(7, 48)
    assert len(toks) == len(ws) == 48
    assert any(w == 4.0 for w in ws)  # answer region upweighted
    # padding has zero weight
    for t, w in zip(toks, ws):
        if t == sl.PAD:
            assert w == 0.0


def test_corpus_cycling():
    a = sl.training_sequence(3, 48)
    b = sl.training_sequence(3 + sl.CORPUS_SIZE, 48)
    assert a == b


def test_splitmix_rust_parity_vector():
    # pinned output — the same constants are asserted in rust (util::rng)
    state, z = sl.splitmix64(0)
    assert state == 0x9E3779B97F4A7C15
    rng = sl.Rng(42)
    seq = [rng.below(17) for _ in range(5)]
    rng2 = sl.Rng(42)
    assert seq == [rng2.below(17) for _ in range(5)]
