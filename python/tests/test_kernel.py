"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Deterministic cases cover the contract's edges (decode step, prefill
chunk, partial tail chunk, empty cache prefix); the hypothesis sweep
walks shapes/dtypes/positions and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import chunk_attention_importance
from compile.kernels.ref import chunk_attention_importance_ref

jax.config.update("jax_platform_name", "cpu")


def _mk(c, m, h, dh, dtype, seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    q = jax.random.normal(k1, (c, h, dh), dtype=jnp.float32).astype(dtype)
    kc = jax.random.normal(k2, (m, h, dh), dtype=jnp.float32).astype(dtype)
    vc = jax.random.normal(k3, (m, h, dh), dtype=jnp.float32).astype(dtype)
    return q, kc, vc


def _check(c, m, h, dh, pos_base, n_valid, dtype=jnp.float32, block_k=32, seed=0):
    q, kc, vc = _mk(c, m, h, dh, dtype, seed)
    pos = jnp.array(pos_base, dtype=jnp.int32)
    nv = jnp.array(n_valid, dtype=jnp.int32)
    out, imp = chunk_attention_importance(q, kc, vc, pos, nv, block_k=block_k)
    out_r, imp_r = chunk_attention_importance_ref(q, kc, vc, pos, nv)
    live = np.arange(c) < n_valid
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32)[live],
        np.asarray(out_r, dtype=np.float32)[live],
        atol=atol,
        rtol=1e-3 if dtype == jnp.float32 else 3e-2,
    )
    np.testing.assert_allclose(np.asarray(imp), np.asarray(imp_r), atol=atol, rtol=1e-3)
    return out, imp


class TestDeterministic:
    def test_decode_step(self):
        # C=1 decode over a half-full cache: the common device hot path.
        _check(c=1, m=64, h=2, dh=16, pos_base=31, n_valid=1)

    def test_prefill_chunk(self):
        _check(c=32, m=128, h=4, dh=16, pos_base=0, n_valid=32)

    def test_partial_tail_chunk(self):
        # last prefill chunk only partially filled
        _check(c=32, m=128, h=2, dh=16, pos_base=40, n_valid=7)

    def test_partial_prefill_verify(self):
        # cloud verification: gamma+uncached tokens appended to a cached prefix
        _check(c=8, m=256, h=4, dh=32, pos_base=100, n_valid=8, block_k=64)

    def test_empty_prefix(self):
        _check(c=4, m=32, h=1, dh=8, pos_base=0, n_valid=4, block_k=16)

    def test_full_cache(self):
        _check(c=1, m=64, h=2, dh=16, pos_base=63, n_valid=1)

    def test_bf16(self):
        _check(c=16, m=64, h=2, dh=16, pos_base=10, n_valid=16, dtype=jnp.bfloat16)

    def test_importance_mass_conservation(self):
        # each live query row distributes exactly H units of prob mass
        c, m, h, dh = 8, 64, 4, 16
        _, imp = _check(c=c, m=m, h=h, dh=dh, pos_base=20, n_valid=8)
        np.testing.assert_allclose(float(jnp.sum(imp)), c * h, rtol=1e-4)

    def test_causality(self):
        # perturbing K/V beyond the visible prefix must not change outputs
        c, m, h, dh = 4, 64, 2, 16
        q, kc, vc = _mk(c, m, h, dh, jnp.float32)
        pos = jnp.array(12, dtype=jnp.int32)
        out1, _ = chunk_attention_importance(q, kc, vc, pos, block_k=16)
        kc2 = kc.at[16 + c :].set(99.0)
        vc2 = vc.at[16 + c :].set(-99.0)
        out2, _ = chunk_attention_importance(q, kc2, vc2, pos, block_k=16)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))

    def test_block_k_invariance(self):
        q, kc, vc = _mk(8, 128, 2, 16, jnp.float32)
        pos = jnp.array(50, dtype=jnp.int32)
        o1, i1 = chunk_attention_importance(q, kc, vc, pos, block_k=16)
        o2, i2 = chunk_attention_importance(q, kc, vc, pos, block_k=128)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
        np.testing.assert_allclose(np.asarray(i1), np.asarray(i2), atol=1e-5)

    def test_vmap_batch(self):
        # L2 vmaps the kernel over the batch dimension
        b, c, m, h, dh = 3, 4, 32, 2, 8
        key = jax.random.PRNGKey(7)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, c, h, dh))
        kc = jax.random.normal(ks[1], (b, m, h, dh))
        vc = jax.random.normal(ks[2], (b, m, h, dh))
        pos = jnp.array([0, 5, 11], dtype=jnp.int32)
        nv = jnp.array([4, 4, 2], dtype=jnp.int32)
        f = jax.vmap(
            lambda qq, kk, vv, pp, nn: chunk_attention_importance(
                qq, kk, vv, pp, nn, block_k=16
            )
        )
        out, imp = f(q, kc, vc, pos, nv)
        for i in range(b):
            out_r, imp_r = chunk_attention_importance_ref(
                q[i], kc[i], vc[i], pos[i], nv[i]
            )
            live = np.arange(c) < int(nv[i])
            np.testing.assert_allclose(
                np.asarray(out[i])[live], np.asarray(out_r)[live], atol=2e-5, rtol=1e-3
            )
            np.testing.assert_allclose(
                np.asarray(imp[i]), np.asarray(imp_r), atol=2e-5, rtol=1e-3
            )


@settings(max_examples=40, deadline=None)
@given(
    c=st.sampled_from([1, 2, 4, 8, 16, 32]),
    mblocks=st.integers(1, 4),
    h=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16, 32]),
    block_k=st.sampled_from([16, 32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    data=st.data(),
)
def test_hypothesis_sweep(c, mblocks, h, dh, block_k, dtype, data):
    m = mblocks * block_k
    if m < c:
        m = ((c + block_k - 1) // block_k) * block_k
    pos_base = data.draw(st.integers(0, max(0, m - c)))
    n_valid = data.draw(st.integers(1, c))
    seed = data.draw(st.integers(0, 2**16))
    _check(c, m, h, dh, pos_base, n_valid, dtype=dtype, block_k=block_k, seed=seed)
