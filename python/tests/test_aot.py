"""AOT artifacts: weight binary format + executable plans + HLO text."""

import json
import struct
from pathlib import Path

import jax
import numpy as np

from compile.aot import (
    CLOUD_MODELS, DEVICE_MODELS, config_fingerprint, exec_plan, lower_exec,
    weight_shapes, write_weights, MAGIC,
)
from compile.model import MODEL_ZOO, WEIGHT_ORDER, init_params


def test_weight_file_roundtrip(tmp_path):
    cfg = MODEL_ZOO["s160m"]
    params = init_params(cfg, jax.random.PRNGKey(0))
    path = tmp_path / "w.bin"
    write_weights(path, params)
    raw = path.read_bytes()
    assert raw[: len(MAGIC)] == MAGIC
    hlen = struct.unpack("<I", raw[len(MAGIC) : len(MAGIC) + 4])[0]
    header = json.loads(raw[len(MAGIC) + 4 : len(MAGIC) + 4 + hlen])
    names = [t["name"] for t in header["tensors"]]
    assert names == WEIGHT_ORDER
    shapes = weight_shapes(cfg)
    for t in header["tensors"]:
        assert tuple(t["shape"]) == tuple(shapes[t["name"]])
    # payload parses back to the exact arrays
    payload = raw[len(MAGIC) + 4 + hlen :]
    t0 = header["tensors"][0]
    n = int(np.prod(t0["shape"]))
    arr = np.frombuffer(payload[t0["offset"] : t0["offset"] + 4 * n], np.float32)
    np.testing.assert_array_equal(arr.reshape(t0["shape"]), np.asarray(params["emb"]))


def test_exec_plan_roles():
    for name in DEVICE_MODELS:
        tags = {e["tag"] for e in exec_plan(name)}
        assert tags == {"chunk_b1_c32", "step_full", "step_p1", "step_p2", "p2_c4"}
    for name in CLOUD_MODELS:
        tags = {e["tag"] for e in exec_plan(name)}
        assert tags == {"chunk_b4_c32", "step_b4"}


def test_lowered_hlo_is_parseable_text():
    cfg = MODEL_ZOO["s160m"]
    text = lower_exec(cfg, b=1, c=1, lo=0, hi=cfg.n_layers, part2=False, exit_logits=False)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # must NOT be a serialized proto (the 0.5.1 interchange constraint)
    assert "\x00" not in text[:200]


def test_fingerprint_stability():
    assert config_fingerprint() == config_fingerprint()


def test_built_artifacts_match_meta(tmp_path):
    meta_path = Path(__file__).resolve().parents[2] / "artifacts" / "meta.json"
    if not meta_path.exists():
        import pytest
        pytest.skip("artifacts not built")
    meta = json.loads(meta_path.read_text())
    for name, m in meta["models"].items():
        d = meta_path.parent
        assert (d / m["weights"]).exists()
        for e in m["execs"]:
            assert (d / f"{name}_{e['tag']}.hlo.txt").exists()
