"""L2 model invariants: split-vs-full equivalence, masking, training step."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import MODEL_ZOO, chunk_forward, init_params, lm_loss, train_forward

jax.config.update("jax_platform_name", "cpu")

CFG = MODEL_ZOO["s160m"]
PARAMS = init_params(CFG, jax.random.PRNGKey(0))


def _kv(layers, b=1):
    m, h, dh = CFG.max_len, CFG.n_heads, CFG.d_head
    return jnp.zeros((layers, b, m, h, dh))


def test_split_equals_full():
    toks = jnp.array([[1, 10, 4, 100, 170]], jnp.int32)
    pos = jnp.array([0], jnp.int32)
    nv = jnp.array([5], jnp.int32)
    L, k = CFG.n_layers, CFG.split_layer
    logits, kk, vv, _ = chunk_forward(PARAMS, CFG, toks, pos, nv, _kv(L), _kv(L))
    (hid, exit_logits), kk1, _, _ = chunk_forward(
        PARAMS, CFG, toks, pos, nv, _kv(k), _kv(k),
        layer_lo=0, layer_hi=k, emit_exit_logits=True)
    logits2, kk2, _, _ = chunk_forward(
        PARAMS, CFG, hid, pos, nv, _kv(L - k), _kv(L - k), layer_lo=k, layer_hi=L)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(kk[:k]), np.asarray(kk1), atol=1e-5)
    assert exit_logits.shape == logits.shape


def test_chunk_forward_matches_train_forward():
    """KV-cache incremental forward == dense training forward."""
    seq = [1, 12, 350, 133, 171, 311, 3, 282]
    toks = jnp.array([seq], jnp.int32)
    dense_logits = train_forward(PARAMS, CFG, toks)  # [1, S, V]

    L = CFG.n_layers
    kvk, kvv = _kv(L), _kv(L)
    # feed one token at a time through the cache path
    rows = []
    for i, t in enumerate(seq):
        lg, kvk, kvv, _ = chunk_forward(
            PARAMS, CFG, jnp.array([[t]], jnp.int32),
            jnp.array([i], jnp.int32), jnp.array([1], jnp.int32), kvk, kvv)
        rows.append(np.asarray(lg)[0, 0])
    np.testing.assert_allclose(
        np.stack(rows), np.asarray(dense_logits)[0], atol=2e-4, rtol=1e-3)


def test_idle_slot_isolation():
    """A slot with n_valid=0 must not disturb other slots."""
    b = 2
    toks = jnp.array([[10, 11], [0, 0]], jnp.int32)
    pos = jnp.array([0, 0], jnp.int32)
    lg2, _, _, _ = chunk_forward(
        PARAMS, CFG, toks, pos, jnp.array([2, 0], jnp.int32), _kv(CFG.n_layers, b), _kv(CFG.n_layers, b))
    lg1, _, _, _ = chunk_forward(
        PARAMS, CFG, toks[:1], pos[:1], jnp.array([2], jnp.int32), _kv(CFG.n_layers, 1), _kv(CFG.n_layers, 1))
    np.testing.assert_allclose(np.asarray(lg2)[0], np.asarray(lg1)[0], atol=1e-4)


def test_loss_decreases_quickly():
    cfg = dataclasses.replace(CFG, train_steps=10)
    from compile.train import adamw_init, adamw_update, make_batch
    params = init_params(cfg, jax.random.PRNGKey(1))
    opt = adamw_init(params)
    toks, ws = make_batch(cfg, 0)
    l0 = float(lm_loss(params, cfg, toks, ws))
    step = jax.jit(lambda p, o, t, w: _step(p, o, t, w, cfg))
    for i in range(10):
        toks, ws = make_batch(cfg, i)
        params, opt, loss = step(params, opt, toks, ws)
    assert float(loss) < l0


def _step(params, opt, toks, ws, cfg):
    from compile.train import adamw_update
    loss, grads = jax.value_and_grad(lambda p: lm_loss(p, cfg, toks, ws))(params)
    params, opt = adamw_update(params, grads, opt, 3e-3)
    return params, opt, loss
