"""AOT pipeline: train the model zoo → lower every runtime executable to
HLO **text** → write weight binaries + meta.json.

Python runs exactly once (``make artifacts``); the Rust coordinator is
self-contained afterwards.  Interchange is HLO text, NOT
``lowered.compiler_ir("hlo")``/``.serialize()``: the image's
xla_extension 0.5.1 rejects jax≥0.5 protos (64-bit instruction ids); the
text parser reassigns ids (see /opt/xla-example/README.md).

Executable ABI (argument order — rust/src/runtime must match):

  *_chunk_* / *_step_full / *_step_p1 :
      tokens i32[B,C], pos_base i32[B], n_valid i32[B],
      kv_k f32[Lp,B,M,H,Dh], kv_v f32[Lp,B,M,H,Dh], <weights WEIGHT_ORDER>
  *_step_p2 / *_p2_c4 :
      hidden f32[B,C,D] instead of tokens, rest identical (kv = part-2 layers)

Outputs (always a tuple):
  full depth : (logits f32[B,C,V], kv_k', kv_v', importance f32[B,M])
  part 1     : (hidden f32[B,C,D], exit_logits f32[B,C,V], kv_k', kv_v', imp)
  part 2     : (logits, kv_k', kv_v', imp)
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import struct
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import synthlang
from .model import MODEL_ZOO, ModelConfig, WEIGHT_ORDER, chunk_forward
from .quantize import VARIANTS
from .train import eval_model, train_model

DEVICE_MODELS = ["s160m", "s1b", "s7b"]
CLOUD_MODELS = ["l13b", "l70b"]
CLOUD_SLOTS = 4  # B for cloud executables
CHUNK = 32  # prefill / partial-prefill chunk length (paper §4.5)
GAMMA = 4  # draft chunk length (paper §5)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ----------------------------- weights format ------------------------------
MAGIC = b"SYNW1\n"


def write_weights(path: Path, params: dict) -> None:
    """MAGIC, u32 header_len, JSON header, raw little-endian f32 payload."""
    tensors, payload, off = [], [], 0
    for name in WEIGHT_ORDER:
        arr = np.ascontiguousarray(np.asarray(params[name], dtype=np.float32))
        tensors.append({"name": name, "shape": list(arr.shape), "offset": off})
        payload.append(arr.tobytes())
        off += arr.nbytes
    header = json.dumps({"tensors": tensors, "total_bytes": off}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for p in payload:
            f.write(p)


# ----------------------------- lowering ------------------------------------
def lower_exec(cfg: ModelConfig, *, b: int, c: int, lo: int, hi: int,
               part2: bool, exit_logits: bool) -> str:
    m, h, dh, d = cfg.max_len, cfg.n_heads, cfg.d_head, cfg.d_model
    lp = hi - lo

    def fn(tokens_or_hidden, pos_base, n_valid, kv_k, kv_v, *weights):
        params = dict(zip(WEIGHT_ORDER, weights))
        out = chunk_forward(
            params, cfg, tokens_or_hidden, pos_base, n_valid, kv_k, kv_v,
            layer_lo=lo, layer_hi=hi, emit_exit_logits=exit_logits,
        )
        res, kk, vv, imp = out
        if exit_logits:
            hidden, xl = res
            return hidden, xl, kk, vv, imp
        return res, kk, vv, imp

    tok_spec = (
        jax.ShapeDtypeStruct((b, c, d), jnp.float32)
        if part2
        else jax.ShapeDtypeStruct((b, c), jnp.int32)
    )
    ivec = jax.ShapeDtypeStruct((b,), jnp.int32)
    kv = jax.ShapeDtypeStruct((lp, b, m, h, dh), jnp.float32)
    wspecs = []
    shapes = weight_shapes(cfg)
    for name in WEIGHT_ORDER:
        wspecs.append(jax.ShapeDtypeStruct(shapes[name], jnp.float32))
    lowered = jax.jit(fn).lower(tok_spec, ivec, ivec, kv, kv, *wspecs)
    return to_hlo_text(lowered)


def weight_shapes(cfg: ModelConfig) -> dict:
    d, l, f, v = cfg.d_model, cfg.n_layers, cfg.d_ff, cfg.vocab
    return {
        "emb": (v, d), "ln1": (l, d), "wq": (l, d, d), "wk": (l, d, d),
        "wv": (l, d, d), "wo": (l, d, d), "ln2": (l, d),
        "w_gate": (l, d, f), "w_up": (l, d, f), "w_down": (l, f, d),
        "ln_f": (d,),
    }


def exec_plan(name: str) -> list[dict]:
    """Which executables to export for a model (see DESIGN.md §2)."""
    cfg = MODEL_ZOO[name]
    k, L = cfg.split_layer, cfg.n_layers
    if name in DEVICE_MODELS:
        return [
            dict(tag="chunk_b1_c32", b=1, c=CHUNK, lo=0, hi=L, part2=False, exit_logits=False),
            dict(tag="step_full", b=1, c=1, lo=0, hi=L, part2=False, exit_logits=False),
            dict(tag="step_p1", b=1, c=1, lo=0, hi=k, part2=False, exit_logits=True),
            dict(tag="step_p2", b=1, c=1, lo=k, hi=L, part2=True, exit_logits=False),
            dict(tag="p2_c4", b=1, c=GAMMA, lo=k, hi=L, part2=True, exit_logits=False),
        ]
    return [
        dict(tag="chunk_b4_c32", b=CLOUD_SLOTS, c=CHUNK, lo=0, hi=L, part2=False, exit_logits=False),
        dict(tag="step_b4", b=CLOUD_SLOTS, c=1, lo=0, hi=L, part2=False, exit_logits=False),
    ]


def config_fingerprint() -> str:
    blob = json.dumps(
        {
            "zoo": {k: v.to_json() for k, v in MODEL_ZOO.items()},
            "chunk": CHUNK, "slots": CLOUD_SLOTS, "gamma": GAMMA,
            "world": synthlang.WORLD_SEED,
            "version": 3,
        },
        sort_keys=True,
    ).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def build(out_dir: Path, fast: bool = False) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    fp = config_fingerprint() + ("-fast" if fast else "")
    stamp = out_dir / "meta.json"
    if stamp.exists():
        try:
            if json.loads(stamp.read_text()).get("fingerprint") == fp:
                print(f"artifacts up-to-date (fingerprint {fp}); nothing to do")
                return
        except (json.JSONDecodeError, KeyError):
            pass

    train_logs, model_meta = {}, {}
    for name, cfg in MODEL_ZOO.items():
        if fast:
            import dataclasses
            cfg = dataclasses.replace(cfg, train_steps=30)
        print(f"=== training {name} ({cfg.train_steps} steps) ===")
        params, log = train_model(cfg)
        scores = eval_model(params, cfg, n_per_task=8 if fast else 16)
        log["eval"] = scores
        train_logs[name] = log
        print(f"[{name}] eval: {scores}")
        write_weights(out_dir / f"{name}.weights.bin", params)
        if name == "s7b":  # Table-6 quantized variants
            for vname, qfn in VARIANTS.items():
                qp = qfn({k: np.asarray(v) for k, v in params.items()})
                write_weights(out_dir / f"{name}_{vname}.weights.bin", qp)

        execs = []
        for spec in exec_plan(name):
            tag = spec.pop("tag")
            print(f"  lowering {name}_{tag} ...")
            text = lower_exec(cfg, **spec)
            (out_dir / f"{name}_{tag}.hlo.txt").write_text(text)
            execs.append({"tag": tag, **spec})
        model_meta[name] = {
            "config": cfg.to_json(),
            "weights": f"{name}.weights.bin",
            "execs": execs,
            "role": "device" if name in DEVICE_MODELS else "cloud",
        }

    (out_dir / "train_log.json").write_text(json.dumps(train_logs, indent=1))
    write_golden(out_dir)
    meta = {
        "fingerprint": fp,
        "chunk": CHUNK, "cloud_slots": CLOUD_SLOTS, "gamma": GAMMA,
        "vocab": synthlang.VOCAB,
        "models": model_meta,
        "weight_order": WEIGHT_ORDER,
    }
    stamp.write_text(json.dumps(meta, indent=1))
    print(f"artifacts written to {out_dir} (fingerprint {fp})")


def write_golden(out_dir: Path, n: int = 8) -> None:
    """Golden workload samples replayed by a Rust test (generator parity)."""
    golden = []
    for task in synthlang.TASKS:
        for i in range(n):
            s = synthlang.generate(task, 1, i)
            golden.append(
                {"task": task, "index": i, "prompt": s.prompt, "answer": s.answer,
                 "classification": s.is_classification}
            )
    (out_dir / "golden_workload.json").write_text(json.dumps(golden))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="30-step training for CI smoke builds")
    args = ap.parse_args()
    build(Path(args.out).resolve(), fast=args.fast)


if __name__ == "__main__":
    main()
