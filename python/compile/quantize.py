"""Build-time weight quantization variants for Table 6.

Two quantize→dequantize schemes standing in for the paper's on-device
accelerators (DESIGN.md §1):

* ``bnb4`` — per-output-channel int4 round-to-nearest, the shape of
  bitsandbytes 4-bit: cheap, noticeable quality hit.
* ``awq``  — per-group (g=32) int4 with a scale search that protects
  salient channels (activation-aware in spirit): slightly better quality
  at the same bit width.

Both return f32 weights (dequantized) so the same HLO executables serve
all variants; the *speedup* of quantized execution is modelled in the
Rust device profile (4-bit ⇒ memory-bound decode runs faster), which is
exactly the axis Table 6 reports.
"""

from __future__ import annotations

import numpy as np

QUANT_KEYS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "emb")


def _rtn_int4(w: np.ndarray, axis: int) -> np.ndarray:
    """Symmetric round-to-nearest int4 along ``axis`` (per-channel scales)."""
    amax = np.max(np.abs(w), axis=axis, keepdims=True)
    scale = np.where(amax > 0, amax / 7.0, 1.0)
    q = np.clip(np.round(w / scale), -8, 7)
    return (q * scale).astype(np.float32)


def quantize_bnb4(params: dict) -> dict:
    out = {}
    for k, v in params.items():
        v = np.asarray(v)
        out[k] = _rtn_int4(v, axis=-1) if k in QUANT_KEYS else v.copy()
    return out


def _awq_group(w: np.ndarray, group: int = 32) -> np.ndarray:
    """Group-wise int4 with a per-group scale search over a small grid."""
    orig_shape = w.shape
    flat = w.reshape(-1, orig_shape[-1])
    d = flat.shape[-1]
    pad = (-d) % group
    if pad:
        flat = np.pad(flat, ((0, 0), (0, pad)))
    g = flat.reshape(flat.shape[0], -1, group)
    amax = np.maximum(np.max(np.abs(g), axis=-1, keepdims=True), 1e-12)
    best = None
    best_err = None
    # scale-search: try shrinking the clip range; keeps salient weights exact
    for ratio in (1.0, 0.9, 0.8, 0.7):
        scale = amax * ratio / 7.0
        q = np.clip(np.round(g / scale), -8, 7) * scale
        err = np.sum((q - g) ** 2, axis=-1, keepdims=True)
        if best is None:
            best, best_err = q, err
        else:
            take = err < best_err
            best = np.where(take, q, best)
            best_err = np.where(take, err, best_err)
    deq = best.reshape(flat.shape[0], -1)[:, :d]
    return deq.reshape(orig_shape).astype(np.float32)


def quantize_awq(params: dict) -> dict:
    out = {}
    for k, v in params.items():
        v = np.asarray(v)
        out[k] = _awq_group(v) if k in QUANT_KEYS else v.copy()
    return out


VARIANTS = {"bnb4": quantize_bnb4, "awq": quantize_awq}
