"""Build-time training of the SynthLang model zoo (AdamW, hand-rolled).

Trains each ``model.MODEL_ZOO`` entry on the SynthLang task mixture and
records the loss curve plus held-out per-task quality into
``artifacts/train_log.json`` — this is the repo's capability-ladder
evidence (the stand-in for the paper's Table 3 accuracy column) and the
end-to-end "train a small model, log the loss curve" validation run.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import synthlang
from .model import ModelConfig, init_params, lm_loss, train_forward


def make_batch(cfg: ModelConfig, step: int):
    toks, ws = [], []
    for i in range(cfg.batch_size):
        t, w = synthlang.training_sequence(step * cfg.batch_size + i, cfg.seq_len)
        toks.append(t)
        ws.append(w)
    return jnp.array(toks, jnp.int32), jnp.array(ws, jnp.float32)


def adamw_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)

    def upd(p, m, v):
        return p - lr * (m * mhat_scale / (jnp.sqrt(v * vhat_scale) + eps) + wd * p)

    params = jax.tree_util.tree_map(upd, params, m, v)
    return params, {"m": m, "v": v, "t": t}


def train_model(cfg: ModelConfig, log_every: int = 50) -> tuple[dict, dict]:
    """Returns (params, log) — log has the loss curve and timing."""
    key = jax.random.PRNGKey(hash(cfg.name) & 0x7FFFFFFF)
    params = init_params(cfg, key)
    opt = adamw_init(params)

    @jax.jit
    def step_fn(params, opt, toks, ws, lr):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, toks, ws)
        )(params)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    curve = []
    t0 = time.time()
    warmup = max(10, cfg.train_steps // 20)
    for step in range(cfg.train_steps):
        toks, ws = make_batch(cfg, step)
        # linear warmup then cosine decay
        if step < warmup:
            lr = cfg.lr * (step + 1) / warmup
        else:
            frac = (step - warmup) / max(1, cfg.train_steps - warmup)
            lr = cfg.lr * 0.5 * (1 + np.cos(np.pi * frac))
        params, opt, loss = step_fn(params, opt, toks, ws, jnp.float32(lr))
        if step % log_every == 0 or step == cfg.train_steps - 1:
            curve.append({"step": step, "loss": float(loss)})
            print(f"[{cfg.name}] step {step}/{cfg.train_steps} loss {float(loss):.4f}")
    wall = time.time() - t0
    log = {"name": cfg.name, "steps": cfg.train_steps, "wall_s": wall, "curve": curve}
    return params, log


# ------------------------- held-out evaluation ------------------------------
def eval_model(params, cfg: ModelConfig, n_per_task: int = 16) -> dict:
    """Teacher-forced answer-token accuracy per task (held-out split).

    One fixed-shape jitted forward per task batch — this is capability-
    ladder evidence for train_log.json; the real free-running generation
    metrics (Rouge-1/accuracy) are computed by the Rust harness.
    """
    fwd = jax.jit(lambda p, t: train_forward(p, cfg, t))
    scores = {}
    for task in synthlang.TASKS:
        toks = np.zeros((n_per_task, cfg.seq_len), np.int32)
        mask = np.zeros((n_per_task, cfg.seq_len), bool)
        for i in range(n_per_task):
            s = synthlang.generate(task, 1, i)
            seq = [synthlang.BOS] + s.prompt + s.answer + [synthlang.EOS]
            seq = seq[: cfg.seq_len]
            toks[i, : len(seq)] = seq
            a0 = 1 + len(s.prompt)
            mask[i, a0 : len(seq)] = True  # answer + EOS positions
        logits = np.asarray(fwd(params, jnp.array(toks)))
        pred = logits[:, :-1].argmax(-1)  # predicts token t+1
        ok = (pred == toks[:, 1:]) & mask[:, 1:]
        scores[task] = float(ok.sum() / max(1, mask[:, 1:].sum()))
    scores["mean"] = float(np.mean([scores[t] for t in synthlang.TASKS]))
    return scores
