"""SynthLang: the synthetic language + task suite standing in for the paper's
seven datasets (CNNDM, XSum, CSQA, SST2, LLQA, HeySQuAD, SensorQA).

Everything is a pure function of (world_seed, sample_index) via splitmix64,
and the exact same generator is re-implemented in ``rust/src/workload/`` —
``tests/test_synthlang.py`` writes a golden file that a Rust integration
test replays byte-for-byte, so the Python-trained models and the Rust
serving stack always agree on the data distribution.

Why this reproduces the paper's evaluation *shape* (DESIGN.md §1): each
task isolates one capability axis —
  * kgqa / summarisation: parametric memory (a 1024-fact knowledge graph
    and a 32×8 topic-keyword table that models must memorise during
    training) — bigger models recall more, giving the Table-4 quality gap;
  * sentiment / llqa: easy in-context tasks — small models are decent,
    matching the paper's SST2 rows;
  * sensorqa: aggregation (mode over readings) — mid-hard;
  * heysquad: retrieval under 10% token noise — robustness axis.
"""

from __future__ import annotations

from dataclasses import dataclass, field

MASK64 = (1 << 64) - 1

# ---- vocabulary layout (mirrored in rust/src/workload/vocab.rs) ----------
VOCAB = 512
PAD, BOS, EOS, SEP, QUERY = 0, 1, 2, 3, 4
TM_KGQA, TM_SENT, TM_SUM, TM_XSUM, TM_LLQA, TM_HEY, TM_SENSOR = range(10, 17)
POS_TOK, NEG_TOK = 20, 21
AGG_MODE = 24
UNIT = 25
SLOT0, N_SLOTS = 30, 16
ACT0, N_ACTS = 50, 32
ENT0, N_ENTS = 100, 48
REL0, N_RELS = 170, 8
VAL0, N_VALS = 200, 128
TOPIC0, N_TOPICS = 350, 24
FILL0, N_FILLS = 400, 112

N_KEYWORDS = 8  # keywords per topic
WORLD_SEED = 0x53594E45524121  # "SYNERA!" — fixed world identity

TASKS = ["kgqa", "sst2", "cnndm", "xsum", "llqa", "heysquad", "sensorqa"]


def splitmix64(state: int):
    """One splitmix64 step. Returns (new_state, output). Mirrored in Rust."""
    state = (state + 0x9E3779B97F4A7C15) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    z = z ^ (z >> 31)
    return state, z


class Rng:
    """Deterministic stream RNG over splitmix64 (identical in Rust)."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state, z = splitmix64(self.state)
        return z

    def below(self, n: int) -> int:
        return self.next_u64() % n

    def chance(self, num: int, den: int) -> bool:
        return self.below(den) < num


def hash2(a: int, b: int) -> int:
    """Order-sensitive 2-arg hash used for the static world tables."""
    _, z = splitmix64((WORLD_SEED ^ (a * 0x9E3779B97F4A7C15) ^ b) & MASK64)
    return z


# ---- static world ---------------------------------------------------------
def kg_value(ent: int, rel: int) -> int:
    """The knowledge-graph fact table: value token for (entity, relation)."""
    return VAL0 + hash2(ent * N_RELS + rel, 0x4B47) % N_VALS


def topic_keyword(topic: int, i: int) -> int:
    return VAL0 + hash2(topic * N_KEYWORDS + i, 0x544F) % N_VALS


def value_polarity(val_tok: int) -> int:
    """0 = negative-leaning, 1 = positive-leaning."""
    return hash2(val_tok, 0x504F) % 2


@dataclass
class Sample:
    task: str
    prompt: list[int] = field(default_factory=list)
    answer: list[int] = field(default_factory=list)  # excludes EOS
    # classification tasks report exact-match accuracy; others Rouge-1
    is_classification: bool = False


def sample_seed(task_idx: int, split: int, index: int) -> int:
    """split: 0 = train, 1 = eval."""
    return (WORLD_SEED ^ (task_idx * 0x1000003) ^ (split << 40) ^ index) & MASK64


def gen_kgqa(rng: Rng) -> Sample:
    ent = ENT0 + rng.below(N_ENTS)
    rel = REL0 + rng.below(N_RELS)
    prompt = [TM_KGQA, QUERY, ent, rel, SEP]
    return Sample("kgqa", prompt, [kg_value(ent - ENT0, rel - REL0)], True)


def gen_sst2(rng: Rng) -> Sample:
    n = 8 + rng.below(5)
    label = rng.below(2)
    words = []
    for _ in range(n):
        if rng.chance(7, 10):
            # draw a word of the label's polarity
            while True:
                w = VAL0 + rng.below(N_VALS)
                if value_polarity(w) == label:
                    break
        else:
            w = VAL0 + rng.below(N_VALS)
        words.append(w)
    # exact label = majority polarity of what was actually sampled
    pos = sum(value_polarity(w) for w in words)
    lab_tok = POS_TOK if 2 * pos > len(words) else NEG_TOK
    return Sample("sst2", [TM_SENT] + words + [SEP], [lab_tok], True)


def _doc_sentences(rng: Rng, n_sents: int):
    sents, ents = [], []
    for _ in range(n_sents):
        e = rng.below(N_ENTS)
        r = rng.below(N_RELS)
        ents.append(e)
        sents.append(
            [ENT0 + e, REL0 + r, kg_value(e, r), FILL0 + rng.below(N_FILLS)]
        )
    return sents, ents


def gen_cnndm(rng: Rng) -> Sample:
    topic = rng.below(N_TOPICS)
    sents, _ = _doc_sentences(rng, 4 + rng.below(3))
    prompt = [TM_SUM, TOPIC0 + topic]
    for s in sents:
        prompt += s
    prompt.append(SEP)
    answer = [topic_keyword(topic, i) for i in range(N_KEYWORDS)]
    return Sample("cnndm", prompt, answer)


def gen_xsum(rng: Rng) -> Sample:
    topic = rng.below(N_TOPICS)
    sents, ents = _doc_sentences(rng, 4 + rng.below(3))
    prompt = [TM_XSUM, TOPIC0 + topic]
    for s in sents:
        prompt += s
    prompt.append(SEP)
    # harder/abstractive: 4 keywords, rotation keyed on the majority entity
    e_major = max(set(ents), key=lambda e: (ents.count(e), -e))
    rot = e_major % 4
    answer = [topic_keyword(topic, (rot + i) % N_KEYWORDS) for i in range(4)]
    return Sample("xsum", prompt, answer)


def gen_llqa(rng: Rng) -> Sample:
    n = 6 + rng.below(5)
    slots = list(range(N_SLOTS))
    # fisher-yates with our rng for a deterministic shuffle
    for i in range(N_SLOTS - 1, 0, -1):
        j = rng.below(i + 1)
        slots[i], slots[j] = slots[j], slots[i]
    chosen = sorted(slots[:n])
    log, acts = [], {}
    for s in chosen:
        a = rng.below(N_ACTS)
        acts[s] = a
        log += [SLOT0 + s, ACT0 + a]
    q = chosen[rng.below(n)]
    prompt = [TM_LLQA] + log + [QUERY, SLOT0 + q, SEP]
    return Sample("llqa", prompt, [ACT0 + acts[q]], True)


def gen_heysquad(rng: Rng) -> Sample:
    # context states 3 facts; one is queried; 10% of context tokens noised
    facts = []
    for _ in range(3):
        e, r = rng.below(N_ENTS), rng.below(N_RELS)
        facts.append((e, r))
    ctx = []
    for e, r in facts:
        ctx += [ENT0 + e, REL0 + r, kg_value(e, r), FILL0 + rng.below(N_FILLS)]
    qe, qr = facts[rng.below(3)]
    answer = [kg_value(qe, qr)]
    noisy = [
        (VAL0 + rng.below(N_VALS)) if rng.chance(1, 10) else t for t in ctx
    ]
    prompt = [TM_HEY] + noisy + [QUERY, ENT0 + qe, REL0 + qr, SEP]
    return Sample("heysquad", prompt, answer)


def gen_sensorqa(rng: Rng) -> Sample:
    n_kinds = 3 + rng.below(3)
    kinds = [VAL0 + rng.below(N_VALS) for _ in range(n_kinds)]
    n = 10 + rng.below(6)
    readings = [kinds[rng.below(n_kinds)] for _ in range(n)]
    counts = {}
    for r in readings:
        counts[r] = counts.get(r, 0) + 1
    # mode; ties broken toward the smaller token id (same rule in rust)
    mode = min(counts, key=lambda k: (-counts[k], k))
    prompt = [TM_SENSOR] + readings + [QUERY, AGG_MODE, SEP]
    return Sample("sensorqa", prompt, [mode, UNIT])


GENERATORS = {
    "kgqa": gen_kgqa,
    "sst2": gen_sst2,
    "cnndm": gen_cnndm,
    "xsum": gen_xsum,
    "llqa": gen_llqa,
    "heysquad": gen_heysquad,
    "sensorqa": gen_sensorqa,
}


def generate(task: str, split: int, index: int) -> Sample:
    """The cross-language entry point: same (task, split, index) → same sample."""
    rng = Rng(sample_seed(TASKS.index(task), split, index))
    return GENERATORS[task](rng)


# training mixture weights (kgqa and summarisation heavier: parametric memory)
MIXTURE = [
    ("kgqa", 3),
    ("sst2", 2),
    ("cnndm", 3),
    ("xsum", 2),
    ("llqa", 2),
    ("heysquad", 2),
    ("sensorqa", 2),
]


CORPUS_SIZE = 4096  # fixed training corpus; steps cycle through it (epochs)


def training_sequence(index: int, seq_len: int) -> tuple[list[int], list[int]]:
    """Padded LM training sequence + per-token loss weights (answer ×4)."""
    index = index % CORPUS_SIZE
    total = sum(w for _, w in MIXTURE)
    rng = Rng(sample_seed(31, 0, index))
    pick = rng.below(total)
    acc = 0
    task = MIXTURE[-1][0]
    for t, w in MIXTURE:
        acc += w
        if pick < acc:
            task = t
            break
    s = generate(task, 0, index)
    toks = [BOS] + s.prompt + s.answer + [EOS]
    n_ans = len(s.answer) + 1  # answer + EOS
    if len(toks) > seq_len:  # truncate prompt head, keep answer
        toks = toks[len(toks) - seq_len :]
    weights = [1.0] * (len(toks) - n_ans) + [4.0] * n_ans
    pad = seq_len - len(toks)
    return toks + [PAD] * pad, weights + [0.0] * pad
