"""L1 Pallas kernel: fused chunk attention + importance score.

This is Synera's compute hot spot. One kernel serves prefill chunks,
single-token decode steps, and cloud-side partial-prefill verification:
a chunk of C query tokens attends over a padded KV cache of M slots
(positions ``0 .. pos_base+C`` are live, the rest masked), and the same
pass accumulates the paper's *importance score* (Fig. 2): the column-wise
sum of the attention matrix, reduced over heads and query rows.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * grid over heads; K/V stream through VMEM in ``block_k``-sized tiles
    (``BlockSpec`` plays the role CUDA threadblocks play in the paper's
    A6000 kernels),
  * Q·Kᵀ and P·V are MXU-shaped contractions accumulated in f32,
  * pass 1 is an online-softmax (running max / denominator) flash loop,
  * pass 2 re-walks the VMEM-resident tiles to emit normalised column
    sums — the importance reduction is fused instead of being a second
    HBM round-trip.

Lowered with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the kernel runs as plain HLO ops on this image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attention_kernel(
    pos_ref,  # [1] int32, number of tokens cached before this chunk
    nvalid_ref,  # [1] int32, number of valid query rows in the chunk
    q_ref,  # [C, Dh]
    k_ref,  # [M, Dh]
    v_ref,  # [M, Dh]
    out_ref,  # [C, Dh]
    imp_ref,  # [M] f32, accumulated across heads
    *,
    block_k: int,
    scale: float,
):
    h = pl.program_id(0)
    c, dh = q_ref.shape[1], q_ref.shape[2]
    m_total = k_ref.shape[1]
    nblocks = m_total // block_k

    pos_base = pos_ref[0]
    n_valid = nvalid_ref[0]

    q = q_ref[0, :, :].astype(jnp.float32) * scale
    row_pos = pos_base + jax.lax.iota(jnp.int32, c)  # global position per query
    row_live = jax.lax.iota(jnp.int32, c) < n_valid

    def block_scores(j):
        """Masked attention scores of the C queries against KV tile j."""
        k_tile = k_ref[0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q,
            k_tile,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [C, BK]
        col = j * block_k + jax.lax.iota(jnp.int32, block_k)
        # causal within the live prefix: query at global row_pos may see
        # cache positions <= row_pos (its own K/V is already written).
        mask = col[None, :] <= row_pos[:, None]
        mask = jnp.logical_and(mask, row_live[:, None])
        return jnp.where(mask, s, NEG_INF)

    # ---- pass 1: online softmax over KV tiles -------------------------
    def p1(j, carry):
        m_run, l_run, acc = carry
        s = block_scores(j)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_run * alpha + jnp.sum(p, axis=1)
        v_tile = v_ref[0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v_tile, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_new = acc * alpha[:, None] + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((c,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((c,), dtype=jnp.float32)
    a0 = jnp.zeros((c, dh), dtype=jnp.float32)
    m_fin, l_fin, acc = jax.lax.fori_loop(0, nblocks, p1, (m0, l0, a0))

    inv_l = jnp.where(l_fin > 0.0, 1.0 / l_fin, 0.0)
    out_ref[0, :, :] = (acc * inv_l[:, None]).astype(out_ref.dtype)

    # ---- pass 2: normalised column sums (importance) ------------------
    # imp[j] = sum_{heads, live rows} exp(s_ij - m_i) / l_i.  Needs the
    # final m/l, hence a second walk over the (VMEM-resident) tiles.
    @pl.when(h == 0)
    def _init():
        imp_ref[...] = jnp.zeros_like(imp_ref)

    def p2(j, _):
        s = block_scores(j)
        p = jnp.exp(s - m_fin[:, None]) * inv_l[:, None]
        p = jnp.where(row_live[:, None], p, 0.0)
        colsum = jnp.sum(p, axis=0)  # [BK]
        sl = pl.dslice(j * block_k, block_k)
        imp_ref[sl] = imp_ref[sl] + colsum
        return 0

    jax.lax.fori_loop(0, nblocks, p2, 0)


def chunk_attention_importance(
    q: jax.Array,  # [C, H, Dh]
    k_cache: jax.Array,  # [M, H, Dh] (chunk K already written at pos_base..)
    v_cache: jax.Array,  # [M, H, Dh]
    pos_base: jax.Array,  # [] or [1] int32
    n_valid: jax.Array | None = None,  # [] int32, defaults to C
    *,
    block_k: int = 64,
    interpret: bool = True,
):
    """Fused attention + importance for one sequence.

    Returns ``(out [C,H,Dh] in q.dtype, importance [M] f32)``.
    ``importance[m]`` is the total attention probability mass that the
    chunk's live queries (all heads) paid to cache slot ``m``.
    """
    c, h, dh = q.shape
    m_total = k_cache.shape[0]
    if m_total % block_k != 0:
        raise ValueError(f"cache length {m_total} not divisible by block_k {block_k}")
    if n_valid is None:
        n_valid = jnp.array(c, dtype=jnp.int32)
    pos = jnp.reshape(pos_base, (1,)).astype(jnp.int32)
    nv = jnp.reshape(n_valid, (1,)).astype(jnp.int32)

    qh = jnp.transpose(q, (1, 0, 2))  # [H, C, Dh]
    kh = jnp.transpose(k_cache, (1, 0, 2))  # [H, M, Dh]
    vh = jnp.transpose(v_cache, (1, 0, 2))

    kernel = functools.partial(
        _attention_kernel, block_k=block_k, scale=1.0 / (dh**0.5)
    )
    out_h, imp = pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1, c, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m_total, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m_total, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((m_total,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, c, dh), q.dtype),
            jax.ShapeDtypeStruct((m_total,), jnp.float32),
        ],
        interpret=interpret,
    )(pos, nv, qh, kh, vh)
    return jnp.transpose(out_h, (1, 0, 2)), imp
