"""Pure-jnp correctness oracle for the fused attention+importance kernel.

Dense (no tiling, no online softmax) implementation of exactly the same
contract as :func:`attention.chunk_attention_importance`.  Every pytest
and hypothesis sweep asserts the Pallas kernel against this.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def chunk_attention_importance_ref(
    q: jax.Array,  # [C, H, Dh]
    k_cache: jax.Array,  # [M, H, Dh]
    v_cache: jax.Array,  # [M, H, Dh]
    pos_base: jax.Array,  # [] int32
    n_valid: jax.Array | None = None,  # [] int32, defaults to C
):
    """Returns ``(out [C,H,Dh], importance [M] f32)``; see kernel docstring."""
    c, h, dh = q.shape
    m_total = k_cache.shape[0]
    if n_valid is None:
        n_valid = jnp.array(c, dtype=jnp.int32)
    pos_base = jnp.asarray(pos_base, dtype=jnp.int32).reshape(())
    n_valid = jnp.asarray(n_valid, dtype=jnp.int32).reshape(())

    qf = q.astype(jnp.float32) / (dh**0.5)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)

    # scores [H, C, M]
    s = jnp.einsum("chd,mhd->hcm", qf, kf)
    row_pos = pos_base + jnp.arange(c, dtype=jnp.int32)  # [C]
    col = jnp.arange(m_total, dtype=jnp.int32)  # [M]
    row_live = jnp.arange(c, dtype=jnp.int32) < n_valid
    mask = (col[None, :] <= row_pos[:, None]) & row_live[:, None]  # [C, M]
    s = jnp.where(mask[None, :, :], s, NEG_INF)

    m_max = jnp.max(s, axis=-1, keepdims=True)
    p_un = jnp.exp(s - m_max)
    denom = jnp.sum(p_un, axis=-1, keepdims=True)
    p = jnp.where(denom > 0.0, p_un / denom, 0.0)  # [H, C, M]

    out = jnp.einsum("hcm,mhd->chd", p, vf).astype(q.dtype)
    p_live = jnp.where(row_live[None, :, None], p, 0.0)
    importance = jnp.sum(p_live, axis=(0, 1)).astype(jnp.float32)  # [M]
    return out, importance
