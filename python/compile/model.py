"""L2: the Synera transformer in JAX.

One compute graph — ``chunk_forward`` — serves every runtime call site
(paper Takeaway-3): device prefill chunks, device decode steps, and the
cloud's partial-prefill verification batches are all "C query tokens over
a padded per-slot KV cache", differing only in (B, C) and the layer range
(split layer ranges implement the device's layer-wise early exit).
Attention + importance go through the L1 Pallas kernel.

``train_forward`` is the dense training-time graph (no KV cache, no
Pallas): it shares every parameter with ``chunk_forward`` and exists only
in the build path.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp

from .kernels.attention import chunk_attention_importance
from . import synthlang


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = synthlang.VOCAB
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 256
    max_len: int = 64  # KV cache slots per sequence (prompts ≤32, gen ≤16)
    # early-exit split point: part1 = layers [0, split), part2 = [split, L)
    split_layer: int = 1
    train_steps: int = 200
    batch_size: int = 12
    lr: float = 3e-3
    seq_len: int = 48

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def to_json(self) -> dict:
        d = asdict(self)
        d["d_head"] = self.d_head
        return d


# The capability ladder standing in for the paper's Table-3 model zoo.
# Names echo the paper's roles; sizes are scaled to this CPU testbed and
# train_steps grows with size so quality gaps are real, not cosmetic.
MODEL_ZOO = {
    "s160m": ModelConfig("s160m", d_model=48, n_layers=2, n_heads=2, d_ff=192,
                         split_layer=1, train_steps=250, lr=4e-3),
    "s1b": ModelConfig("s1b", d_model=80, n_layers=3, n_heads=4, d_ff=320,
                       split_layer=2, train_steps=400, lr=3.5e-3),
    "s7b": ModelConfig("s7b", d_model=112, n_layers=4, n_heads=4, d_ff=448,
                       split_layer=3, train_steps=500, lr=3e-3),
    "l13b": ModelConfig("l13b", d_model=144, n_layers=4, n_heads=8, d_ff=576,
                        split_layer=3, train_steps=550, lr=3e-3),
    "l70b": ModelConfig("l70b", d_model=176, n_layers=5, n_heads=8, d_ff=704,
                        split_layer=4, train_steps=650, lr=2.5e-3),
}

# weight tensor order — the runtime ABI; rust/src/runtime/weights.rs must match
WEIGHT_ORDER = [
    "emb", "ln1", "wq", "wk", "wv", "wo", "ln2", "w_gate", "w_up", "w_down", "ln_f",
]


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    d, l, f, v = cfg.d_model, cfg.n_layers, cfg.d_ff, cfg.vocab
    ks = jax.random.split(key, 8)

    def nrm(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(jnp.float32)

    s_attn = d ** -0.5
    s_ff = d ** -0.5
    s_out = (2 * l) ** -0.5
    return {
        "emb": nrm(ks[0], (v, d), 0.02 * d ** 0.5),
        "ln1": jnp.ones((l, d), jnp.float32),
        "wq": nrm(ks[1], (l, d, d), s_attn),
        "wk": nrm(ks[2], (l, d, d), s_attn),
        "wv": nrm(ks[3], (l, d, d), s_attn),
        "wo": nrm(ks[4], (l, d, d), s_attn * s_out),
        "ln2": jnp.ones((l, d), jnp.float32),
        "w_gate": nrm(ks[5], (l, d, f), s_ff),
        "w_up": nrm(ks[6], (l, d, f), s_ff),
        "w_down": nrm(ks[7], (l, f, d), (f ** -0.5) * s_out),
        "ln_f": jnp.ones((d,), jnp.float32),
    }


def rmsnorm(x, scale):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def rope(x, positions):
    """x: [..., T, H, Dh]; positions: [..., T] int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def logits_head(params, x):
    return rmsnorm(x, params["ln_f"]) @ params["emb"].T


def _layer_slice(params, lo, hi):
    return {
        k: (v if k in ("emb", "ln_f") else jax.lax.slice_in_dim(v, lo, hi, axis=0))
        for k, v in params.items()
    }


def chunk_forward(
    params: dict,
    cfg: ModelConfig,
    tokens_or_hidden: jax.Array,  # [B, C] i32 | [B, C, D] f32 (part2)
    pos_base: jax.Array,  # [B] i32, cached tokens per slot
    n_valid: jax.Array,  # [B] i32, live query rows per slot (0 = idle slot)
    kv_k: jax.Array,  # [Lpart, B, M, H, Dh] f32
    kv_v: jax.Array,
    *,
    layer_lo: int = 0,
    layer_hi: int | None = None,
    emit_exit_logits: bool = False,
    interpret: bool = True,
):
    """Run layers [layer_lo, layer_hi) over a chunk.

    Returns ``(out, kv_k', kv_v', importance[B, M])`` where ``out`` is
    ``logits [B,C,V]`` when layer_hi == n_layers, else
    ``(hidden [B,C,D], exit_logits)`` for the early-exit part-1 split.
    Importance is the per-layer-mean fused column-sum from the L1 kernel.
    """
    layer_hi = cfg.n_layers if layer_hi is None else layer_hi
    n_part = layer_hi - layer_lo
    h, dh, m = cfg.n_heads, cfg.d_head, cfg.max_len

    if tokens_or_hidden.dtype in (jnp.int32, jnp.int64):
        x = params["emb"][tokens_or_hidden]  # [B, C, D]
    else:
        x = tokens_or_hidden
    b, c, d = x.shape

    positions = pos_base[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]
    p = _layer_slice(params, layer_lo, layer_hi)
    layer_ws = {k: p[k] for k in ("ln1", "wq", "wk", "wv", "wo", "ln2",
                                  "w_gate", "w_up", "w_down")}

    def one_layer(carry, lw):
        x, = carry
        ln1 = rmsnorm(x, lw["ln1"])
        q = (ln1 @ lw["wq"]).reshape(b, c, h, dh)
        k = (ln1 @ lw["wk"]).reshape(b, c, h, dh)
        v = (ln1 @ lw["wv"]).reshape(b, c, h, dh)
        q = rope(q, positions)
        k = rope(k, positions)

        # scatter this chunk's K/V into the per-slot cache at pos_base
        def upd(cache, new):
            def per_seq(cache_s, new_s, p0):
                return jax.lax.dynamic_update_slice(
                    cache_s, new_s, (p0, jnp.int32(0), jnp.int32(0))
                )
            return jax.vmap(per_seq)(cache, new, pos_base)

        kk = upd(lw["kv_k"], k)
        vv = upd(lw["kv_v"], v)

        attn = jax.vmap(
            lambda qq, kc, vc, pb, nv: chunk_attention_importance(
                qq, kc, vc, pb, nv, block_k=64, interpret=interpret
            )
        )
        out, imp = attn(q, kk, vv, pos_base, n_valid)  # [B,C,H,Dh], [B,M]
        x = x + out.reshape(b, c, d) @ lw["wo"]
        ln2 = rmsnorm(x, lw["ln2"])
        ff = (jax.nn.silu(ln2 @ lw["w_gate"]) * (ln2 @ lw["w_up"])) @ lw["w_down"]
        x = x + ff
        return (x,), (kk, vv, imp)

    scan_ws = dict(layer_ws)
    scan_ws["kv_k"] = kv_k
    scan_ws["kv_v"] = kv_v
    (x,), (kv_k_new, kv_v_new, imps) = jax.lax.scan(one_layer, (x,), scan_ws)

    importance = jnp.mean(imps, axis=0)  # [B, M] mean over executed layers
    if layer_hi == cfg.n_layers:
        return logits_head(params, x), kv_k_new, kv_v_new, importance
    if emit_exit_logits:
        return (x, logits_head(params, x)), kv_k_new, kv_v_new, importance
    return x, kv_k_new, kv_v_new, importance


# --------------------------- training graph --------------------------------
def train_forward(params: dict, cfg: ModelConfig, tokens: jax.Array):
    """Dense causal LM forward for training. tokens: [B, S] i32 → logits."""
    b, s = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head
    x = params["emb"][tokens]
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, axis=0)
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))

    def one_layer(x, lw):
        ln1 = rmsnorm(x, lw["ln1"])
        q = rope((ln1 @ lw["wq"]).reshape(b, s, h, dh), positions)
        k = rope((ln1 @ lw["wk"]).reshape(b, s, h, dh), positions)
        v = (ln1 @ lw["wv"]).reshape(b, s, h, dh)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (dh ** 0.5)
        scores = jnp.where(causal[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, -1)
        x = x + out @ lw["wo"]
        ln2 = rmsnorm(x, lw["ln2"])
        x = x + (jax.nn.silu(ln2 @ lw["w_gate"]) * (ln2 @ lw["w_up"])) @ lw["w_down"]
        return x, None

    layer_ws = {k: params[k] for k in ("ln1", "wq", "wk", "wv", "wo", "ln2",
                                       "w_gate", "w_up", "w_down")}
    x, _ = jax.lax.scan(one_layer, x, layer_ws)
    return logits_head(params, x)


def lm_loss(params, cfg, tokens, weights):
    """Weighted next-token cross-entropy; weights==0 masks (padding)."""
    logits = train_forward(params, cfg, tokens)  # [B, S, V]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    w = weights[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
