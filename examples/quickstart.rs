//! Quickstart: serve one request with Synera and inspect what happened.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use synera::config::Scenario;
use synera::coordinator::pipeline::{run_request, CloudClock, Method, PipelineCtx};
use synera::metrics::quality::score_sample;
use synera::model::{CloudEngine, DeviceEngine};
use synera::net::SimLink;
use synera::profiling::load_or_profile;
use synera::runtime::Runtime;
use synera::util::rng::Rng;
use synera::workload::synthlang::{generate, Task};

fn main() -> anyhow::Result<()> {
    // 1. load the AOT artifacts (built once by `make artifacts`)
    let rt = Runtime::load_default()?;
    let scen = Scenario::default_pair("s1b", "l13b");

    // 2. offline profile (paper §5) — cached in artifacts/
    let profile = load_or_profile(&rt, "s1b", None, "l13b")?;
    println!(
        "profile: c_th={:.3} α={:.3} i_th(budget 0.2)={:.3}",
        profile.c_th,
        profile.alpha,
        profile.i_th_for_budget(0.2)
    );

    // 3. engines: device SLM (split for early exit) + cloud LLM batch engine
    let dev = DeviceEngine::new(rt.model("s1b")?, true)?;
    let mut sched =
        synera::cloud::Scheduler::new(CloudEngine::new(rt.model("l13b")?)?, 42);
    let mut link = SimLink::new(scen.link, 42);
    let mut clock = CloudClock::default();
    let mut rng = Rng::new(42);

    // 4. one summarisation request, end to end
    let sample = generate(Task::Cnndm, 1, 3);
    let mut ctx = PipelineCtx {
        dev: &dev,
        sched: &mut sched,
        scen: &scen,
        profile: &profile,
        link: &mut link,
        cloud_clock: &mut clock,
        rng: &mut rng,
    };
    let rep = run_request(&mut ctx, Method::Synera, &sample.prompt)?;

    println!("\nprompt    ({} tokens): {:?}", sample.prompt.len(), sample.prompt);
    println!("reference : {:?}", sample.answer);
    println!("generated : {:?}", rep.generated);
    println!("\nRouge-1   : {:.3}", score_sample(&sample, &rep.generated));
    println!("latency   : {:.1} ms  (TBT {:.1} ms)", rep.total_s * 1e3, rep.tbt() * 1e3);
    println!(
        "offloaded : {}/{} chunks  | early exits: {}/{} steps | PI: {} hits / {} rounds",
        rep.offload_chunks,
        rep.offload_chunks + rep.local_chunks,
        rep.exits,
        rep.steps,
        rep.pi_hits,
        rep.pi_hits + rep.pi_misses,
    );
    println!(
        "network   : {} B up, {} B down | stall {:.1} ms | energy {:.2} J",
        rep.bytes_up, rep.bytes_down, rep.stall_s * 1e3, rep.energy_j
    );
    Ok(())
}
