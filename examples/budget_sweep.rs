//! Budget sweep: the paper's central trade-off (Fig. 14) as a runnable
//! example — quality, latency and cloud cost as the offloading budget
//! turns from 0 (pure edge) toward 1 (verify everything).

use synera::config::Scenario;
use synera::coordinator::eval::{eval_with_profile, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::profiling::load_or_profile;
use synera::runtime::Runtime;
use synera::workload::synthlang::Task;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let profile = load_or_profile(&rt, "s160m", None, "l13b")?;
    println!("pair s160m&l13b, task cnndm-sim, 8 samples per point\n");
    println!("{:>6} {:>9} {:>9} {:>10} {:>9}", "budget", "quality", "tbt(ms)", "cost(m)", "offload");
    for b in [0.0, 0.1, 0.2, 0.3, 0.5, 0.8] {
        let mut scen = Scenario::default_pair("s160m", "l13b");
        scen.params.budget = b;
        let rep = eval_with_profile(
            &rt,
            &scen,
            Method::Synera,
            &EvalOptions { n_samples: 8, task: Task::Cnndm },
            &profile,
        )?;
        println!(
            "{b:>6.2} {:>9.3} {:>9.1} {:>10.3} {:>9.2}",
            rep.quality,
            rep.tbt_s * 1e3,
            rep.cost * 1e3,
            rep.offload_rate
        );
    }
    println!("\n(the knee around budget ≈ 0.2–0.3 is the paper's working point)");
    Ok(())
}
