//! End-to-end driver: REAL multi-threaded serving of batched requests.
//!
//! One cloud thread runs the verification-aware scheduler over the PJRT
//! batch engine; N device threads each run the full Synera device loop
//! (draft → select → compress → offload → stall-free PI) over their own
//! PJRT runtime, with simulated link delays injected as real sleeps.
//! Reports wall-clock throughput, latency percentiles and quality — the
//! run recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! cargo run --release --example multi_device_serving -- [n_devices] [reqs/dev]
//! ```

use synera::config::{Scenario, SloPolicy};
use synera::coordinator::serve::{run_threaded, ServeConfig};
use synera::runtime::artifacts_dir;
use synera::workload::synthlang::Task;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_devices = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let requests = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(6);

    let cfg = ServeConfig {
        scenario: Scenario::default_pair("s1b", "l13b"),
        task: Task::Cnndm,
        n_devices,
        requests_per_device: requests,
        artifacts: artifacts_dir(),
        trace: None,
        slo: SloPolicy::default(),
    };
    println!(
        "multi-device serving: {n_devices} devices × {requests} requests (pair {}, {})",
        cfg.scenario.pair.label(),
        cfg.task.name()
    );
    let rep = run_threaded(&cfg)?;
    println!("\n== results ==");
    println!("completed     : {} requests in {:.2}s wall", rep.completed, rep.wall_s);
    println!("throughput    : {:.2} req/s | {:.1} tokens/s", rep.throughput_rps, rep.tokens_per_s);
    println!(
        "e2e latency   : p50 {:.0} ms, p95 {:.0} ms, max {:.0} ms",
        rep.e2e_latency.p50 * 1e3,
        rep.e2e_latency.p95 * 1e3,
        rep.e2e_latency.max * 1e3
    );
    println!(
        "verify RTT    : p50 {:.0} ms, p95 {:.0} ms",
        rep.verify_rtt.p50 * 1e3,
        rep.verify_rtt.p95 * 1e3
    );
    println!("quality       : {:.3} (Rouge-1)", rep.quality);
    println!("offload rate  : {:.2}", rep.offload_rate);
    Ok(())
}
