//! Bandwidth resilience (Fig. 13): Synera under links from 0.1 to
//! 100 Mbps, with and without top-k distribution compression.

use synera::config::Scenario;
use synera::coordinator::eval::{eval_with_profile, EvalOptions};
use synera::coordinator::pipeline::Method;
use synera::profiling::load_or_profile;
use synera::runtime::Runtime;
use synera::workload::synthlang::Task;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let profile = load_or_profile(&rt, "s1b", None, "l13b")?;
    let opts = EvalOptions { n_samples: 8, task: Task::Xsum };
    println!("pair s1b&l13b, task xsum-sim\n");
    println!(
        "{:>10} {:>14} {:>18} {:>12}",
        "bandwidth", "synera tbt", "w/o compression", "bytes saved"
    );
    for mbps in [0.1, 0.5, 1.0, 5.0, 10.0, 100.0] {
        let mut scen = Scenario::default_pair("s1b", "l13b");
        scen.link.bandwidth_mbps = mbps;
        let with = eval_with_profile(&rt, &scen, Method::Synera, &opts, &profile)?;
        let mut s2 = scen.clone();
        s2.params.compression = false;
        let without = eval_with_profile(&rt, &s2, Method::Synera, &opts, &profile)?;
        println!(
            "{:>8.1}Mb {:>11.1}ms {:>15.1}ms {:>11.1}%",
            mbps,
            with.tbt_s * 1e3,
            without.tbt_s * 1e3,
            100.0 * (1.0 - with.bytes_up as f64 / without.bytes_up.max(1) as f64),
        );
    }
    Ok(())
}
